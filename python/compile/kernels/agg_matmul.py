"""Layer-1 Pallas kernels: the GCN layer's compute hot-spot.

The per-partition GraphSAGE layer is two GEMM-shaped contractions:

    z   = P · H                      (aggregation)
    pre = z · W_neigh + H_in · W_self  (transform)

On the paper's GPUs these are cuSPARSE/cuBLAS calls; the TPU adaptation
(DESIGN.md §Hardware-Adaptation) tiles both onto the 128×128 MXU with
VMEM-resident blocks expressed through ``BlockSpec``:

* ``matmul``       — k-blocked tiled matmul; the grid's third axis walks
  the reduction dimension and revisits the same output block, which keeps
  one (bm×bn) accumulator tile resident in VMEM per output block.
* ``fused_transform`` — the SAGE transform with **both** matmuls fused
  over a shared output tile: ``z·W_neigh + H_in·W_self`` accumulates into
  one block without materializing either partial product in HBM.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so kernels lower to plain HLO and run (and AOT-export)
correctly on CPU; real-TPU performance is *estimated* in EXPERIMENTS.md
§Perf from the BlockSpec footprint, never measured from interpret-mode
timings.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM budget note (v4-class core, 16 MiB VMEM): the default 128×128 f32
# accumulator tile is 64 KiB; x/y streaming tiles at bk=128 are 64 KiB
# each — triple-buffered this stays ≪ VMEM, leaving room for the fused
# second operand pair.
_BLOCK_CANDIDATES = (128, 64, 32, 16, 8)


def _pick_block(dim: int, cap: int = 128) -> int:
    """Largest candidate ≤ cap that divides dim, else dim itself."""
    for c in _BLOCK_CANDIDATES:
        if c <= cap and dim % c == 0:
            return c
    return dim


def _mm_kernel(x_ref, y_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x, y, *, bm=None, bn=None, bk=None):
    """Tiled ``x @ y`` via Pallas (interpret mode).

    Grid = (M/bm, N/bn, K/bk); the k axis revisits the same output block
    so the accumulator tile stays resident (MXU-friendly schedule).
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"matmul shape mismatch {x.shape} @ {y.shape}"
    bm = bm or _pick_block(m)
    bn = bn or _pick_block(n)
    bk = bk or _pick_block(k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, y)


def _fused_kernel(z_ref, h_ref, wn_ref, ws_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        z_ref[...], wn_ref[...], preferred_element_type=o_ref.dtype
    ) + jnp.dot(h_ref[...], ws_ref[...], preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def fused_transform(z, h_inner, w_neigh, w_self, *, bm=None, bn=None, bk=None):
    """``z @ w_neigh + h_inner @ w_self`` in one fused Pallas kernel.

    Both contractions share the reduction width (f_in) and the output
    tile, so one VMEM accumulator serves both — the SAGE transform never
    materializes a partial product in HBM.
    """
    m, k = z.shape
    assert h_inner.shape == (m, k), (z.shape, h_inner.shape)
    k2, n = w_neigh.shape
    assert k == k2 and w_self.shape == (k, n)
    bm = bm or _pick_block(m)
    bn = bn or _pick_block(n)
    bk = bk or _pick_block(k)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _fused_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), z.dtype),
        interpret=True,
    )(z, h_inner, w_neigh, w_self)


def vmem_footprint_bytes(bm: int, bn: int, bk: int, fused: bool, itemsize: int = 4) -> int:
    """Estimated VMEM bytes of one grid step (accumulator + operand tiles,
    double-buffered operands). Used by the §Perf roofline notes."""
    acc = bm * bn * itemsize
    operands = (bm * bk + bk * bn) * itemsize * (2 if fused else 1)
    return acc + 2 * operands  # ×2: double buffering of streamed tiles
