"""Pure-jnp oracles for the Pallas kernels (the correctness reference).

Everything here is deliberately written with plain ``jnp`` contractions —
no Pallas, no custom tiling — so a kernel bug cannot hide in a shared
code path.
"""

import jax.numpy as jnp


def matmul(x, y):
    return jnp.dot(x, y, preferred_element_type=x.dtype)


def fused_transform(z, h_inner, w_neigh, w_self):
    return jnp.dot(z, w_neigh) + jnp.dot(h_inner, w_self)


def sage_fwd(p, h, w_neigh, w_self):
    """Reference forward: z = P·H ; pre = z·Wn + H[:inner]·Ws."""
    inner = p.shape[0]
    z = jnp.dot(p, h)
    pre = jnp.dot(z, w_neigh) + jnp.dot(h[:inner], w_self)
    return z, pre


def sage_bwd(p, h, z, m, w_neigh, w_self):
    """Reference backward (same math as runtime/native.rs):
    g_neigh = zᵀ·m ; g_self = H[:inner]ᵀ·m ;
    j = Pᵀ·(m·Wnᵀ) + pad_inner(m·Wsᵀ).
    """
    inner = p.shape[0]
    g_neigh = jnp.dot(z.T, m)
    g_self = jnp.dot(h[:inner].T, m)
    dz = jnp.dot(m, w_neigh.T)
    j = jnp.dot(p.T, dz)
    j = j.at[:inner].add(jnp.dot(m, w_self.T))
    return g_neigh, g_self, j
