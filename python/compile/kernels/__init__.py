"""Layer-1 Pallas kernels + pure-jnp reference oracle."""
