"""AOT compile path: lower the Layer-2 functions (with their Layer-1
Pallas kernels inlined) to **HLO text** artifacts the Rust runtime loads
via the ``xla`` crate.

HLO *text* — not ``lowered.compile().serialize()`` and not the serialized
``HloModuleProto`` — is the interchange format: jax ≥ 0.5 emits protos
with 64-bit instruction ids that the crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage: ``python -m compile.aot --out ../artifacts``
The output directory gets one ``.hlo.txt`` per (pass, f_in, f_out) plus a
``manifest.json`` describing shapes, so the Rust side never hard-codes a
layer list.
"""

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(fn, example_args) -> str:
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_artifacts(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    dims = model.DIMS
    manifest = {
        "n_pad": model.N_PAD,
        "l_pad": model.L_PAD,
        "dims": dims,
        "artifacts": [],
    }
    seen = set()
    for l in range(len(dims) - 1):
        f_in, f_out = dims[l], dims[l + 1]
        if (f_in, f_out) in seen:
            continue
        seen.add((f_in, f_out))
        for name, fn, shapes in (
            ("sage_fwd", model.sage_fwd, model.fwd_shapes(f_in, f_out)),
            ("sage_bwd", model.sage_bwd, model.bwd_shapes(f_in, f_out)),
        ):
            text = to_hlo_text(fn, shapes)
            fname = f"{name}_i{model.N_PAD}_l{model.L_PAD}_in{f_in}_out{f_out}.hlo.txt"
            path = os.path.join(out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            manifest["artifacts"].append(
                {
                    "pass": name,
                    "f_in": f_in,
                    "f_out": f_out,
                    "file": fname,
                    "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
                    "bytes": len(text),
                }
            )
            print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
        f.write("\n")
    print(f"wrote {out_dir}/manifest.json ({len(manifest['artifacts'])} artifacts)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact output dir")
    args = ap.parse_args()
    build_artifacts(args.out)


if __name__ == "__main__":
    main()
