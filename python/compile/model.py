"""Layer-2: the per-partition GraphSAGE layer forward/backward in JAX,
calling the Layer-1 Pallas kernels, with fixed padded shapes for AOT
export.

Padded layout contract (shared with ``rust/src/runtime/xla.rs``):

* ``P``      : (N_PAD, L_PAD) dense — rows 0..n_inner are the partition's
  propagation rows, the rest zero; columns 0..n_inner map inner nodes,
  columns N_PAD..N_PAD+n_halo map halo nodes, everything else zero.
* ``H``      : (L_PAD, f_in) — inner rows at 0.., halo rows at N_PAD..,
  padding rows zero.
* outputs follow the same row conventions; zero padding is preserved by
  the math (zero P rows/cols ⇒ zero contributions), which the tests
  verify explicitly.

The backward here mirrors ``runtime/native.rs`` exactly; pytest checks it
against ``jax.vjp`` of the forward so the two backends cannot drift.
"""

import jax
import jax.numpy as jnp

from .kernels import agg_matmul as kernels

# Padded shapes for the quickstart config ("tiny" preset, ≤2–4 partitions).
# Rust asserts real shapes fit; aot.py bakes these into the artifacts.
N_PAD = 320  # max inner nodes per partition
L_PAD = 576  # max inner + halo nodes per partition
DIMS = [32, 32, 8]  # tiny preset: feat 32 → hidden 32 → 8 classes


def sage_fwd(p, h, w_neigh, w_self):
    """One SAGE-mean layer forward on padded shapes.

    Returns ``(z_agg, pre)`` — activation choice (ReLU / logits) lives in
    the Rust trainer so one artifact serves hidden and output layers.
    """
    inner = p.shape[0]
    z = kernels.matmul(p, h)
    pre = kernels.fused_transform(z, h[:inner], w_neigh, w_self)
    return z, pre


def sage_bwd(p, h, z, m, w_neigh, w_self):
    """Backward of :func:`sage_fwd` given ``m = ∂L/∂pre``.

    Returns ``(g_neigh, g_self, j_full)``.
    """
    inner = p.shape[0]
    g_neigh = kernels.matmul(z.T, m)
    g_self = kernels.matmul(h[:inner].T, m)
    dz = kernels.matmul(m, w_neigh.T)
    j = kernels.matmul(p.T, dz)
    j = j.at[:inner].add(kernels.matmul(m, w_self.T))
    return g_neigh, g_self, j


def fwd_shapes(f_in: int, f_out: int):
    """Example-argument shapes for AOT lowering of sage_fwd."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((N_PAD, L_PAD), f32),  # p
        jax.ShapeDtypeStruct((L_PAD, f_in), f32),  # h
        jax.ShapeDtypeStruct((f_in, f_out), f32),  # w_neigh
        jax.ShapeDtypeStruct((f_in, f_out), f32),  # w_self
    )


def bwd_shapes(f_in: int, f_out: int):
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((N_PAD, L_PAD), f32),  # p
        jax.ShapeDtypeStruct((L_PAD, f_in), f32),  # h
        jax.ShapeDtypeStruct((N_PAD, f_in), f32),  # z
        jax.ShapeDtypeStruct((N_PAD, f_out), f32),  # m
        jax.ShapeDtypeStruct((f_in, f_out), f32),  # w_neigh
        jax.ShapeDtypeStruct((f_in, f_out), f32),  # w_self
    )
