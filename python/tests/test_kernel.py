"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes (divisible and prime/ragged), block choices, and
dtypes; fixed cases pin the exact artifact shapes used by AOT export.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import agg_matmul as k
from compile.kernels import ref


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


def assert_close(a, b, dtype):
    # f32 tolerance covers k-blocked accumulation reordering at K≈600
    tol = 2e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=tol, atol=tol)


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 96),
    kk=st.integers(1, 96),
    n=st.integers(1, 48),
    seed=st.integers(0, 2**31),
)
def test_matmul_matches_ref_random_shapes(m, kk, n, seed):
    x = _rand((m, kk), jnp.float32, seed)
    y = _rand((kk, n), jnp.float32, seed + 1)
    assert_close(k.matmul(x, y), ref.matmul(x, y), jnp.float32)


@settings(max_examples=15, deadline=None)
@given(
    m=st.sampled_from([8, 64, 128, 320]),
    kk=st.sampled_from([8, 32, 64, 576]),
    n=st.sampled_from([8, 32]),
    bm=st.sampled_from([None, 8]),
    seed=st.integers(0, 2**31),
)
def test_matmul_block_choices(m, kk, n, bm, seed):
    x = _rand((m, kk), jnp.float32, seed)
    y = _rand((kk, n), jnp.float32, seed + 1)
    assert_close(k.matmul(x, y, bm=bm), ref.matmul(x, y), jnp.float32)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_dtypes(dtype):
    x = _rand((32, 64), dtype, 0)
    y = _rand((64, 16), dtype, 1)
    out = k.matmul(x, y)
    assert out.dtype == dtype
    assert_close(out.astype(jnp.float32), ref.matmul(x, y).astype(jnp.float32), dtype)


@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([16, 64, 320]),
    kk=st.sampled_from([8, 32, 64]),
    n=st.sampled_from([8, 32]),
    seed=st.integers(0, 2**31),
)
def test_fused_transform_matches_ref(m, kk, n, seed):
    z = _rand((m, kk), jnp.float32, seed)
    h = _rand((m, kk), jnp.float32, seed + 1)
    wn = _rand((kk, n), jnp.float32, seed + 2)
    ws = _rand((kk, n), jnp.float32, seed + 3)
    assert_close(
        k.fused_transform(z, h, wn, ws),
        ref.fused_transform(z, h, wn, ws),
        jnp.float32,
    )


def test_artifact_shapes_exact():
    """The exact padded shapes the AOT artifacts are built with."""
    from compile import model

    for f_in, f_out in [(32, 32), (32, 8)]:
        p = _rand((model.N_PAD, model.L_PAD), jnp.float32, 5)
        h = _rand((model.L_PAD, f_in), jnp.float32, 6)
        wn = _rand((f_in, f_out), jnp.float32, 7)
        ws = _rand((f_in, f_out), jnp.float32, 8)
        z, pre = model.sage_fwd(p, h, wn, ws)
        z_r, pre_r = ref.sage_fwd(p, h, wn, ws)
        assert_close(z, z_r, jnp.float32)
        assert_close(pre, pre_r, jnp.float32)


def test_zero_padding_preserved():
    """Zero P rows/cols must produce zero output rows (padding contract)."""
    from compile import model

    inner_real, halo_real, f_in, f_out = 100, 50, 32, 32
    rng = np.random.default_rng(0)
    p = np.zeros((model.N_PAD, model.L_PAD), np.float32)
    p[:inner_real, :inner_real] = rng.random((inner_real, inner_real)) * (
        rng.random((inner_real, inner_real)) < 0.05
    )
    p[:inner_real, model.N_PAD : model.N_PAD + halo_real] = rng.random(
        (inner_real, halo_real)
    ) * (rng.random((inner_real, halo_real)) < 0.05)
    h = np.zeros((model.L_PAD, f_in), np.float32)
    h[:inner_real] = rng.standard_normal((inner_real, f_in))
    h[model.N_PAD : model.N_PAD + halo_real] = rng.standard_normal((halo_real, f_in))
    wn = rng.standard_normal((f_in, f_out)).astype(np.float32)
    ws = rng.standard_normal((f_in, f_out)).astype(np.float32)
    z, pre = model.sage_fwd(jnp.asarray(p), jnp.asarray(h), jnp.asarray(wn), jnp.asarray(ws))
    z = np.asarray(z)
    pre = np.asarray(pre)
    # rows beyond inner_real: z rows are zero (zero P rows); pre rows are
    # zero too (zero z row and zero h row in the padding band)
    assert np.all(z[inner_real:] == 0.0)
    assert np.all(pre[inner_real:] == 0.0)


def test_vmem_footprint_estimate_sane():
    b = k.vmem_footprint_bytes(128, 128, 128, fused=False)
    assert b < 16 * 2**20  # fits v4 VMEM comfortably
    assert k.vmem_footprint_bytes(128, 128, 128, fused=True) > b
