"""L2 correctness: the hand-written backward must equal jax.vjp of the
forward — the same invariant the Rust native backend proves against
finite differences, closing the loop between the two implementations.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


def _sparse_p(inner, local, seed, density=0.08):
    rng = np.random.default_rng(seed)
    p = rng.random((inner, local)) * (rng.random((inner, local)) < density)
    return jnp.asarray(p, dtype=jnp.float32)


@settings(max_examples=10, deadline=None)
@given(
    inner=st.sampled_from([8, 32, 64]),
    extra=st.sampled_from([0, 16, 64]),
    f_in=st.sampled_from([8, 32]),
    f_out=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31),
)
def test_bwd_matches_autodiff(inner, extra, f_in, f_out, seed):
    local = inner + extra
    p = _sparse_p(inner, local, seed)
    h = _rand((local, f_in), seed + 1)
    wn = _rand((f_in, f_out), seed + 2)
    ws = _rand((f_in, f_out), seed + 3)
    m = _rand((inner, f_out), seed + 4)  # upstream gradient on `pre`

    def fwd_pre(h_, wn_, ws_):
        _, pre = ref.sage_fwd(p, h_, wn_, ws_)
        return pre

    _, vjp = jax.vjp(fwd_pre, h, wn, ws)
    want_j, want_gn, want_gs = vjp(m)

    z, _ = ref.sage_fwd(p, h, wn, ws)
    g_neigh, g_self, j = model.sage_bwd(p, h, z, m, wn, ws)

    np.testing.assert_allclose(np.asarray(g_neigh), np.asarray(want_gn), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(g_self), np.asarray(want_gs), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(j), np.asarray(want_j), rtol=1e-4, atol=1e-4)


def test_fwd_pallas_equals_ref_on_artifact_shape():
    p = _sparse_p(model.N_PAD, model.L_PAD, 0)
    h = _rand((model.L_PAD, 32), 1)
    wn = _rand((32, 32), 2)
    ws = _rand((32, 32), 3)
    z_k, pre_k = model.sage_fwd(p, h, wn, ws)
    z_r, pre_r = ref.sage_fwd(p, h, wn, ws)
    np.testing.assert_allclose(np.asarray(z_k), np.asarray(z_r), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(pre_k), np.asarray(pre_r), rtol=1e-4, atol=1e-4)


def test_bwd_pallas_equals_ref_on_artifact_shape():
    p = _sparse_p(model.N_PAD, model.L_PAD, 4)
    h = _rand((model.L_PAD, 32), 5)
    wn = _rand((32, 8), 6)
    ws = _rand((32, 8), 7)
    z, _ = ref.sage_fwd(p, h, wn, ws)
    m = _rand((model.N_PAD, 8), 8)
    out_k = model.sage_bwd(p, h, z, m, wn, ws)
    out_r = ref.sage_bwd(p, h, z, m, wn, ws)
    for a, b in zip(out_k, out_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_aot_lowering_produces_hlo_text(tmp_path):
    """End-to-end compile path: lower both passes for one layer config and
    sanity-check the HLO text (module header + tuple root)."""
    from compile import aot

    text = aot.to_hlo_text(model.sage_fwd, model.fwd_shapes(32, 8))
    assert "HloModule" in text
    assert "f32[320,576]" in text  # P operand shape baked in
    text_b = aot.to_hlo_text(model.sage_bwd, model.bwd_shapes(32, 8))
    assert "HloModule" in text_b
    assert "f32[576,32]" in text_b  # j_full output / h operand
