//! Quickstart — the end-to-end three-layer-stack driver.
//!
//! Loads the AOT artifacts (JAX + Pallas kernels lowered to HLO text by
//! `make artifacts`), partitions a synthetic dataset, and trains both
//! vanilla partition-parallel GCN and PipeGCN **through the XLA/PJRT
//! backend** — Python is not involved at runtime. Prints the loss curve,
//! test accuracy, and the simulated epoch-time comparison on the paper's
//! 2080Ti rig. Falls back to the native backend (with a notice) when
//! artifacts haven't been built.
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! For genuinely distributed training (one OS process per partition over
//! real localhost TCP sockets — the `net` subsystem), use the CLI:
//!
//! ```text
//! cargo run --release -- launch --parts 4 --dataset reddit-sim --epochs 3
//! ```
//!
//! `launch` binds a rendezvous port, spawns `--parts` children running
//! `pipegcn worker --rank R --parts K --coord HOST:PORT ...`, and waits.
//! Each worker rebuilds the dataset/partition deterministically from the
//! shared seed, joins the all-to-all socket mesh, and trains; rank 0
//! gathers losses and reports (`--out results.json`, `--log run.ndjson`).
//! The loss curve is bit-identical to `pipegcn train` on the same flags
//! (staleness lives in message tags, not timing).

use pipegcn::coordinator::{trainer, Optimizer, PipeOpts, TrainConfig, Variant};
use pipegcn::graph::presets;
use pipegcn::model::ModelConfig;
use pipegcn::partition::{partition, quality, Method};
use pipegcn::runtime::{native::NativeBackend, xla::XlaBackend, Backend};
use pipegcn::sim::Mode;
use pipegcn::util::{fmt_bytes, fmt_secs};

fn main() -> pipegcn::util::error::Result<()> {
    let preset = presets::by_name("tiny").unwrap();
    let epochs = 40;
    println!("== PipeGCN quickstart ==");
    println!(
        "dataset: {} ({} nodes, feat {}, {} classes) | model: {}-layer GraphSAGE-{}",
        preset.name, preset.n, preset.feat_dim, preset.n_classes, preset.layers, preset.hidden
    );

    let g = preset.build(42);
    let pt = partition(&g, 2, Method::Multilevel, 1);
    let q = quality(&g, &pt);
    println!(
        "partitioned 2-way (multilevel): edge-cut {}, boundary replicas {}, balance {:.2}",
        q.edge_cut, q.comm_volume, q.balance
    );

    // Backend: AOT XLA artifacts if built AND the xla feature is compiled
    // in (the default build ships a stub backend), else native with a
    // notice.
    let artifacts = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    let use_xla = cfg!(feature = "xla")
        && std::path::Path::new(&format!("{artifacts}/manifest.json")).exists();
    let make_backend = || -> Box<dyn Backend> {
        if use_xla {
            let b = XlaBackend::from_artifacts(&artifacts).expect("loading artifacts");
            Box::new(b)
        } else {
            eprintln!(
                "NOTE: artifacts missing or `xla` feature off — run `make artifacts` and \
                 build with --features xla for the XLA path; using native backend"
            );
            Box::new(NativeBackend::new())
        }
    };
    println!("backend: {}", if use_xla { "xla (AOT PJRT artifacts)" } else { "native" });

    let mut results = Vec::new();
    for variant in [Variant::Vanilla, Variant::Pipe(PipeOpts::plain())] {
        let cfg = TrainConfig {
            model: ModelConfig::sage(
                preset.feat_dim,
                preset.hidden,
                preset.layers,
                preset.n_classes,
                0.0,
            ),
            variant,
            optimizer: Optimizer::Adam,
            lr: preset.lr,
            epochs,
            seed: 7,
            eval_every: 10,
            probe_errors: false,
        };
        let mut backend = make_backend();
        let r = trainer::train(&g, &pt, &cfg, backend.as_mut());
        println!("\n-- {} --", r.variant);
        for e in &r.curve {
            if !e.val.is_nan() {
                println!(
                    "  epoch {:3}  loss {:.4}  val {:.4}  test {:.4}",
                    e.epoch, e.train_loss, e.val, e.test
                );
            }
        }
        println!(
            "  comm/epoch {} | wall {}",
            fmt_bytes(r.comm_bytes_epoch),
            fmt_secs(r.wall_secs)
        );
        results.push(r);
    }

    // simulated comparison on the paper's single-chassis rig
    let (profile, topo) = pipegcn::sim::profiles::rig_2080ti(2);
    let scale = preset.sim_scale;
    let v = pipegcn::sim::epoch_time(
        &pipegcn::exp::scale_works(&results[0].works, scale),
        results[0].model_elems,
        &profile,
        &topo,
        Mode::Vanilla,
    );
    let p = pipegcn::sim::epoch_time(
        &pipegcn::exp::scale_works(&results[1].works, scale),
        results[1].model_elems,
        &profile,
        &topo,
        Mode::Pipelined,
    );
    println!("\n-- simulated epoch time (2× RTX-2080Ti rig) --");
    println!(
        "  GCN     : total {} (compute {}, comm {})",
        fmt_secs(v.total),
        fmt_secs(v.compute),
        fmt_secs(v.comm_total)
    );
    println!(
        "  PipeGCN : total {} (compute {}, comm exposed {})",
        fmt_secs(p.total),
        fmt_secs(p.compute),
        fmt_secs(p.comm_exposed)
    );
    println!("  throughput speedup: {:.2}×", v.total / p.total);
    println!(
        "\naccuracy: GCN {:.4} vs PipeGCN {:.4} (same-accuracy claim: Δ {:+.4})",
        results[0].final_test,
        results[1].final_test,
        results[1].final_test - results[0].final_test
    );
    Ok(())
}
