//! Quickstart — the Session front door, end to end:
//!
//! 1. train through [`pipegcn::session::Session`] (one builder for every
//!    engine: sequential, threaded, multi-process TCP),
//! 2. check the engines agree **bit-for-bit** (staleness lives in
//!    message tags, not timing),
//! 3. distill the training checkpoint into a standalone params artifact
//!    (`pipegcn export-params`'s library path),
//! 4. serve it over TCP and answer a feature→logit query
//!    (`pipegcn serve` / `pipegcn query`'s library path).
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! The same flow from the CLI:
//!
//! ```text
//! pipegcn train --dataset tiny --parts 2 --method pipegcn --epochs 40 \
//!               --ckpt-dir /tmp/qs-ckpt
//! pipegcn export-params --from-ckpt /tmp/qs-ckpt --dataset tiny --parts 2 \
//!               --out /tmp/qs-params.pgp
//! pipegcn serve --params /tmp/qs-params.pgp --dataset tiny --addr-file /tmp/qs.addr &
//! pipegcn query --addr "$(cat /tmp/qs.addr)" --nodes 0,1,2 --repeat 20
//! ```
//!
//! For genuinely distributed training (one OS process per partition over
//! real localhost TCP sockets), swap the engine:
//! `.engine(Engine::Tcp { max_restarts: 3 })`, or use `pipegcn launch`.
//! (The AOT XLA/PJRT backend demo lives in `tests/xla_parity.rs`; build
//! with `make artifacts` and `--features xla`.)

use pipegcn::ckpt::Policy;
use pipegcn::graph::presets;
use pipegcn::model::{artifact, ModelConfig};
use pipegcn::serve::{Client, Server};
use pipegcn::session::{Engine, Session};
use pipegcn::util::fmt_bytes;

fn main() -> pipegcn::util::error::Result<()> {
    println!("== PipeGCN quickstart ==");
    let preset = presets::by_name("tiny").unwrap();
    println!(
        "dataset: {} ({} nodes, feat {}, {} classes) | model: {}-layer GraphSAGE-{}",
        preset.name, preset.n, preset.feat_dim, preset.n_classes, preset.layers, preset.hidden
    );

    // --- 1) train both methods through the Session builder -------------
    let scratch = std::env::temp_dir().join(format!("pipegcn_quickstart_{}", std::process::id()));
    let ckpt_dir = scratch.join("ckpt").to_string_lossy().into_owned();
    let epochs = 40;
    let mut trained = None;
    for method in ["gcn", "pipegcn"] {
        let mut session = Session::preset("tiny")
            .parts(2)
            .variant(method)
            .epochs(epochs)
            .seed(7)
            .eval_every(10);
        if method == "pipegcn" {
            // checkpoint the pipelined run — step 3 distills it
            session = session.ckpt(Policy { dir: ckpt_dir.clone(), every: epochs });
        }
        let report = session.run()?;
        println!("\n-- {method} ({} engine) --", report.engine);
        println!(
            "  final loss {:.4} | test {:.4} | comm {}",
            report.losses.last().unwrap(),
            report.final_test,
            fmt_bytes(report.comm_bytes),
        );
        trained = Some(report);
    }
    let trained = trained.unwrap();

    // --- 2) engines are interchangeable and bit-identical ---------------
    let seq = Session::preset("tiny").parts(2).variant("pipegcn").epochs(10).seed(7).run()?;
    let thr = Session::preset("tiny")
        .parts(2)
        .variant("pipegcn")
        .epochs(10)
        .seed(7)
        .engine(Engine::Threaded)
        .run()?;
    assert_eq!(
        seq.losses.last().unwrap().to_bits(),
        thr.losses.last().unwrap().to_bits(),
    );
    println!("\nsequential and threaded engines agree bit-for-bit over 10 epochs");

    // --- 3) checkpoint → standalone params artifact ---------------------
    let cfg = ModelConfig::from_preset(preset);
    let (pf, epoch) = artifact::export_from_ckpt(&ckpt_dir, 2, &cfg, None)?;
    let params_path = scratch.join("params.pgp").to_string_lossy().into_owned();
    artifact::save(&params_path, &pf)?;
    println!(
        "exported the epoch-{epoch} checkpoint to {params_path} ({} parameters, no optimizer state)",
        pf.params.n_elems()
    );

    // --- 4) serve it and query logits over TCP --------------------------
    // the same graph seed the training run used, so the served model
    // sees the graph it was trained on
    let server = Server::from_parts(preset.build(7), pf.config, pf.params)?;
    let addr = server.addr().to_string();
    let handle = std::thread::spawn(move || server.run(Some(1)));
    let mut client = Client::connect(&addr)?;
    let logits = client.query(&[0, 1, 2, 3])?;
    client.close();
    handle.join().expect("serve thread panicked")?;
    println!(
        "served logits for {} nodes × {} classes from {addr} (trained test metric {:.4})",
        logits.rows, logits.cols, trained.final_test
    );

    std::fs::remove_dir_all(&scratch).ok();
    Ok(())
}
