//! Smoothing decay-rate ablation (paper Fig. 6 + Fig. 7): PipeGCN-GF on
//! products-sim at 10 partitions under γ ∈ {0, 0.5, 0.7, 0.95}, recording
//! test-accuracy convergence and per-layer staleness errors.
//!
//! ```text
//! cargo run --release --example gamma_sweep [-- --epochs 80 --gammas 0,0.5,0.7,0.95]
//! ```

use pipegcn::exp::RunOpts;
use pipegcn::graph::io::append_csv;
use pipegcn::session::Session;
use pipegcn::util::cli::Args;

fn main() -> pipegcn::util::error::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let epochs = args.get_usize("epochs", 60);
    let gammas = args.get_f32_list("gammas", &[0.0, 0.5, 0.7, 0.95]);
    let parts = args.get_usize("parts", 10);

    println!("== products-sim γ sweep (Fig. 6/7 analogue), {parts} partitions ==");
    println!("{:>6} {:>10} {:>10} {:>12} {:>12}", "γ", "best", "final", "feat err", "grad err");
    for &gamma in &gammas {
        let out = Session::preset("products-sim")
            .parts(parts)
            .variant("pipegcn-gf")
            .run_opts(RunOpts { epochs, gamma, probe_errors: true, eval_every: 5, ..Default::default() })
            .run()?
            .into_output();
        // mean post-warmup relative errors across layers (Fig. 7)
        let post: Vec<_> =
            out.result.probes.iter().filter(|p| p.epoch > epochs / 3).collect();
        let mean = |f: &dyn Fn(&&pipegcn::coordinator::ErrorProbe) -> f64| -> f64 {
            if post.is_empty() {
                0.0
            } else {
                post.iter().map(f).sum::<f64>() / post.len() as f64
            }
        };
        let feat_err = mean(&|p| if p.feat_ref > 0.0 { p.feat_err / p.feat_ref } else { 0.0 });
        let grad_err = mean(&|p| if p.grad_ref > 0.0 { p.grad_err / p.grad_ref } else { 0.0 });
        println!(
            "{:>6.2} {:>10.4} {:>10.4} {:>12.4} {:>12.4}",
            gamma, out.result.best_val_test, out.result.final_test, feat_err, grad_err
        );
        let rows: Vec<String> = out
            .result
            .curve
            .iter()
            .filter(|e| !e.val.is_nan())
            .map(|e| format!("{gamma},{},{:.6},{:.6}", e.epoch, e.val, e.test))
            .collect();
        append_csv("results/f6_gamma_convergence.csv", "gamma,epoch,val,test", &rows)?;
        let prows: Vec<String> = out
            .result
            .probes
            .iter()
            .map(|p| {
                format!(
                    "{gamma},{},{},{:.6},{:.6},{:.6},{:.6}",
                    p.epoch, p.layer, p.feat_err, p.feat_ref, p.grad_err, p.grad_ref
                )
            })
            .collect();
        append_csv(
            "results/f7_gamma_errors.csv",
            "gamma,epoch,layer,feat_err,feat_ref,grad_err,grad_ref",
            &prows,
        )?;
    }
    println!("\ncurves → results/f6_gamma_convergence.csv, errors → results/f7_gamma_errors.csv");
    Ok(())
}
