//! Multi-server scaling (paper Appendix E, Tables 7 & 8): reddit-sim
//! across (#nodes × #gpus) grids on the MI60/10GbE testbed profile —
//! accuracy of every PipeGCN variant, and throughput speedup over vanilla
//! partition-parallel training.
//!
//! ```text
//! cargo run --release --example multi_server [-- --epochs 40]
//! ```

use pipegcn::exp::{self, RunOpts};
use pipegcn::session::Session;
use pipegcn::sim::{profiles::rig_mi60, Mode};
use pipegcn::util::cli::Args;

fn main() -> pipegcn::util::error::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let epochs = args.get_usize("epochs", 40);
    let grids: &[(usize, usize)] =
        &[(1, 2), (1, 3), (1, 4), (2, 2), (2, 3), (2, 4), (3, 3), (4, 4)];

    println!("== reddit-sim over MI60 multi-server testbed (Tables 7/8 analogue) ==");
    println!(
        "{:<10} {:>6} {:>9} {:>10} {:>10} {:>10}",
        "topology", "parts", "GCN", "PipeGCN", "Pipe-GF", "speedup"
    );
    for &(nodes, per) in grids {
        let parts = nodes * per;
        let (profile, topo) = rig_mi60(nodes, per);
        let mut row = format!("{:<10} {:>6}", format!("{nodes}x{per}"), parts);
        let mut vanilla_total = 0.0;
        let mut pipe_total = 0.0;
        for method in ["gcn", "pipegcn", "pipegcn-gf"] {
            let out = Session::preset("reddit-sim")
                .parts(parts)
                .variant(method)
                .run_opts(RunOpts { epochs, eval_every: epochs, ..Default::default() })
                .run()?
                .into_output();
            let mode = if method == "gcn" { Mode::Vanilla } else { Mode::Pipelined };
            let sim = exp::simulate(&out, &profile, &topo, mode);
            if method == "gcn" {
                vanilla_total = sim.total;
                row += &format!(" {:>8.4}", out.result.final_test);
            } else {
                row += &format!(" {:>9.4}", out.result.final_test);
            }
            if method == "pipegcn" {
                pipe_total = sim.total;
            }
        }
        row += &format!(" {:>9.2}x", vanilla_total / pipe_total);
        println!("{row}");
    }
    println!("\n(accuracy columns: final test accuracy; speedup: PipeGCN vs GCN simulated epoch time)");
    Ok(())
}
