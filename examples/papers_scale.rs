//! Largest-scale run (paper §4.5 + Table 5, papers-sim preset mirroring
//! ogbn-papers100M): 32 partitions over 4 servers × 8 MI60 GPUs with
//! 10 Gbps Ethernet — where communication dominates even more than on a
//! single chassis. Reports the Table-5 rows: total vs communication time
//! per epoch for GCN / PipeGCN / PipeGCN-GF, plus real training accuracy
//! on the scaled dataset.
//!
//! ```text
//! cargo run --release --example papers_scale [-- --epochs 30]
//! ```

use pipegcn::exp::{self, RunOpts};
use pipegcn::session::Session;
use pipegcn::sim::{profiles::rig_mi60, Mode};
use pipegcn::util::cli::Args;
use pipegcn::util::fmt_secs;

fn main() -> pipegcn::util::error::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let epochs = args.get_usize("epochs", 30);
    let (profile, topo) = rig_mi60(4, 8);
    let parts = 32;

    println!("== papers-sim × {parts} partitions on 4×8 MI60 / 10GbE (Table 5 analogue) ==");
    println!("{:<12} {:>12} {:>14} {:>10} {:>10}", "method", "total", "communication", "ratio", "test");
    let mut base = (1.0, 1.0);
    for method in ["gcn", "pipegcn", "pipegcn-gf"] {
        let out = Session::preset("papers-sim")
            .parts(parts)
            .variant(method)
            .run_opts(RunOpts { epochs, eval_every: epochs, ..Default::default() })
            .run()?
            .into_output();
        let mode = if method == "gcn" { Mode::Vanilla } else { Mode::Pipelined };
        let sim = exp::simulate(&out, &profile, &topo, mode);
        let comm = sim.comm_exposed + sim.reduce;
        if method == "gcn" {
            base = (sim.total, comm);
        }
        println!(
            "{:<12} {:>7.2}x ({}) {:>7.2}x ({}) {:>9.1}% {:>9.4}",
            out.result.variant,
            sim.total / base.0,
            fmt_secs(sim.total),
            comm / base.1,
            fmt_secs(comm),
            100.0 * comm / sim.total,
            out.result.final_test,
        );
    }
    println!("\npaper Table 5: GCN 1.00× (10.5s) / comm 1.00× (6.6s); PipeGCN 0.62× / 0.39×; PipeGCN-GF 0.64× / 0.42×");
    Ok(())
}
