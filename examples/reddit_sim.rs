//! Reddit-scale study (paper Table 4 + Fig. 4, reddit-sim preset):
//! trains GCN and all four PipeGCN variants at 2 and 4 partitions,
//! printing Table-4-style rows and writing per-epoch convergence CSVs
//! under results/ for Fig. 4.
//!
//! ```text
//! cargo run --release --example reddit_sim [-- --epochs 120 --parts 2,4]
//! ```

use pipegcn::exp::{self, RunOpts};
use pipegcn::graph::io::append_csv;
use pipegcn::session::Session;
use pipegcn::sim::Mode;
use pipegcn::util::cli::Args;

fn main() -> pipegcn::util::error::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let epochs = args.get_usize("epochs", 60);
    let parts_list = args.get_usize_list("parts", &[2, 4]);
    let methods = ["gcn", "pipegcn", "pipegcn-g", "pipegcn-f", "pipegcn-gf"];

    println!("== reddit-sim: accuracy + throughput (Table 4 analogue) ==");
    for &parts in &parts_list {
        println!("\n-- {parts} partitions --");
        println!("{:<12} {:>10} {:>12} {:>12}", "method", "test", "epochs/s", "speedup");
        let mut vanilla_total = 0.0f64;
        for method in methods {
            let out = Session::preset("reddit-sim")
                .parts(parts)
                .variant(method)
                .run_opts(RunOpts { epochs, eval_every: 5, ..Default::default() })
                .run()?
                .into_output();
            let mode = if method == "gcn" { Mode::Vanilla } else { Mode::Pipelined };
            let sim = exp::simulate_default(&out, mode);
            if method == "gcn" {
                vanilla_total = sim.total;
            }
            println!(
                "{:<12} {:>9.4} {:>12.2} {:>11.2}x",
                out.result.variant,
                out.result.best_val_test,
                exp::sim_epochs_per_s(&sim),
                vanilla_total / sim.total
            );
            // Fig. 4 data: epoch-to-accuracy curve
            let rows: Vec<String> = out
                .result
                .curve
                .iter()
                .filter(|e| !e.val.is_nan())
                .map(|e| {
                    format!(
                        "{},{},{},{:.6},{:.6},{:.6}",
                        parts, out.result.variant, e.epoch, e.train_loss, e.val, e.test
                    )
                })
                .collect();
            append_csv(
                "results/f4_reddit_convergence.csv",
                "parts,method,epoch,loss,val,test",
                &rows,
            )?;
        }
    }
    println!("\nconvergence curves → results/f4_reddit_convergence.csv");
    Ok(())
}
