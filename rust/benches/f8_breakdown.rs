//! Figure 8 — training time breakdown bars (compute / communication /
//! reduce) for GCN, PipeGCN, PipeGCN-GF across every dataset × partition
//! configuration of Table 4.
//!
//! Paper shape: comm dominates GCN; PipeGCN hides it (fully at 2-part
//! Reddit / 3-part Yelp, mostly at 10-part products); smoothing adds
//! only minimal overhead.

use pipegcn::exp::{self, RunOpts};
use pipegcn::session::Session;
use pipegcn::sim::Mode;
use pipegcn::util::json::Json;

fn main() -> pipegcn::util::error::Result<()> {
    let cases: &[(&str, usize)] = &[
        ("reddit-sim", 2),
        ("reddit-sim", 4),
        ("products-sim", 5),
        ("products-sim", 10),
        ("yelp-sim", 3),
        ("yelp-sim", 6),
    ];
    println!("== Fig. 8: time breakdown (simulated seconds/epoch) ==");
    println!(
        "{:<14} {:>5} {:<12} {:>9} {:>9} {:>8} {:>8}",
        "dataset", "parts", "method", "compute", "comm", "reduce", "total"
    );
    let mut rows = Vec::new();
    for &(ds, parts) in cases {
        for method in ["gcn", "pipegcn", "pipegcn-gf"] {
            let out = Session::preset(ds)
                .parts(parts)
                .variant(method)
                .run_opts(RunOpts { epochs: 3, eval_every: 0, ..Default::default() })
                .run()
                .expect("session run")
                .into_output();
            let mode = if method == "gcn" { Mode::Vanilla } else { Mode::Pipelined };
            let sim = exp::simulate_default(&out, mode);
            println!(
                "{:<14} {:>5} {:<12} {:>9.3} {:>9.3} {:>8.3} {:>8.3}",
                ds, parts, out.result.variant, sim.compute, sim.comm_exposed, sim.reduce, sim.total
            );
            rows.push(
                Json::obj()
                    .set("dataset", ds)
                    .set("parts", parts)
                    .set("method", out.result.variant.clone())
                    .set("compute_s", sim.compute)
                    .set("comm_s", sim.comm_exposed)
                    .set("reduce_s", sim.reduce)
                    .set("total_s", sim.total),
            );
        }
    }
    Json::obj().set("figure", "8").set("rows", Json::Arr(rows)).write_file("results/f8_breakdown.json")?;
    println!("→ results/f8_breakdown.json");
    Ok(())
}
