//! Table 6 — epoch-time breakdown of every full-graph training method on
//! Reddit-scale, 2 and 4 GPUs: ROC, CAGNET(c=1), CAGNET(c=2), vanilla
//! GCN, PipeGCN.

use pipegcn::baselines::{cagnet_epoch, reddit_inputs, roc_epoch};
use pipegcn::exp::{self, RunOpts};
use pipegcn::partition::quality;
use pipegcn::session::Session;
use pipegcn::sim::{profiles::rig_2080ti, EpochBreakdown, Mode};
use pipegcn::util::json::Json;

fn row(name: &str, b: &EpochBreakdown, paper: (f64, f64, f64, f64)) -> Json {
    println!(
        "{:<18} {:>7.2} {:>8.2} {:>8.2} {:>7.2} | paper: {:>5.2} {:>5.2} {:>5.2} {:>5.2}",
        name, b.total, b.compute, b.comm_exposed, b.reduce, paper.0, paper.1, paper.2, paper.3
    );
    Json::obj()
        .set("method", name)
        .set("total", b.total)
        .set("compute", b.compute)
        .set("comm", b.comm_exposed)
        .set("reduce", b.reduce)
        .set("paper_total", paper.0)
        .set("paper_compute", paper.1)
        .set("paper_comm", paper.2)
        .set("paper_reduce", paper.3)
}

fn main() -> pipegcn::util::error::Result<()> {
    println!("== Table 6: epoch time breakdown, Reddit-scale (seconds) ==");
    let mut rows = Vec::new();
    for gpus in [2usize, 4] {
        println!(
            "\n-- {gpus} GPUs --\n{:<18} {:>7} {:>8} {:>8} {:>7}",
            "method", "total", "compute", "comm", "reduce"
        );
        let (profile, topo) = rig_2080ti(gpus);
        let out_g = Session::preset("reddit-sim")
            .parts(gpus)
            .variant("gcn")
            .run_opts(RunOpts { epochs: 3, eval_every: 0, ..Default::default() })
            .run()
            .expect("session run")
            .into_output();
        let q = quality(&out_g.graph, &out_g.parts);
        let inputs = reddit_inputs(gpus, q.replication_factor);
        // paper rows: (total, compute, comm, reduce)
        let paper: &[(&str, (f64, f64, f64, f64))] = if gpus == 2 {
            &[
                ("ROC", (3.63, 0.50, 3.13, 0.00)),
                ("CAGNET (c=1)", (2.74, 1.91, 0.65, 0.18)),
                ("CAGNET (c=2)", (5.41, 4.36, 0.09, 0.96)),
                ("GCN", (0.52, 0.17, 0.34, 0.01)),
                ("PipeGCN", (0.27, 0.25, 0.00, 0.02)),
            ]
        } else {
            &[
                ("ROC", (3.34, 0.42, 2.92, 0.00)),
                ("CAGNET (c=1)", (2.31, 0.97, 1.23, 0.11)),
                ("CAGNET (c=2)", (2.26, 1.03, 0.55, 0.68)),
                ("GCN", (0.48, 0.07, 0.40, 0.01)),
                ("PipeGCN", (0.23, 0.10, 0.10, 0.03)),
            ]
        };
        let roc = roc_epoch(&inputs, &profile, &topo);
        let c1 = cagnet_epoch(&inputs, 1, &profile, &topo);
        let c2 = cagnet_epoch(&inputs, 2, &profile, &topo);
        let gcn = exp::simulate(&out_g, &profile, &topo, Mode::Vanilla);
        let out_p = Session::preset("reddit-sim")
            .parts(gpus)
            .variant("pipegcn")
            .run_opts(RunOpts { epochs: 3, eval_every: 0, ..Default::default() })
            .run()
            .expect("session run")
            .into_output();
        let pipe = exp::simulate(&out_p, &profile, &topo, Mode::Pipelined);
        for (i, b) in [roc, c1, c2, gcn, pipe].iter().enumerate() {
            let mut j = row(paper[i].0, b, paper[i].1);
            j = j.set("gpus", gpus);
            rows.push(j);
        }
    }
    Json::obj().set("table", "6").set("rows", Json::Arr(rows)).write_file("results/t6_breakdown.json")?;
    println!("\n→ results/t6_breakdown.json");
    Ok(())
}
