//! Figure 3 — training throughput (epochs/s) of GCN and PipeGCN vs the
//! full-graph comparators ROC and CAGNET (c=2), across partition counts.
//!
//! Paper headline: GCN 3.1×~16.4× over ROC, 2.1×~10.2× over CAGNET(c=2);
//! PipeGCN 5.6×~28.5× over ROC, 3.9×~17.7× over CAGNET(c=2).

use pipegcn::baselines::{cagnet_epoch, reddit_inputs, roc_epoch, BaselineInputs};
use pipegcn::exp::{self, RunOpts};
use pipegcn::partition::quality;
use pipegcn::session::Session;
use pipegcn::sim::{profiles::rig_2080ti, Mode};
use pipegcn::util::json::Json;

fn main() -> pipegcn::util::error::Result<()> {
    println!("== Fig. 3: throughput (simulated epochs/s, Reddit-scale) ==");
    println!(
        "{:<7} {:>9} {:>12} {:>9} {:>9} | {:>12} {:>12}",
        "parts", "ROC", "CAGNET(c=2)", "GCN", "PipeGCN", "GCN/ROC", "Pipe/CAGNET"
    );
    let mut rows = Vec::new();
    for parts in [2usize, 4, 6, 8, 10] {
        let (profile, topo) = rig_2080ti(parts);
        let out_g = Session::preset("reddit-sim")
            .parts(parts)
            .variant("gcn")
            .run_opts(RunOpts { epochs: 3, eval_every: 0, ..Default::default() })
            .run()
            .expect("session run")
            .into_output();
        let q = quality(&out_g.graph, &out_g.parts);
        let inputs: BaselineInputs = reddit_inputs(parts, q.replication_factor);
        let roc = 1.0 / roc_epoch(&inputs, &profile, &topo).total;
        let cagnet = 1.0 / cagnet_epoch(&inputs, 2, &profile, &topo).total;
        let gcn = 1.0 / exp::simulate(&out_g, &profile, &topo, Mode::Vanilla).total;
        let out_p = Session::preset("reddit-sim")
            .parts(parts)
            .variant("pipegcn")
            .run_opts(RunOpts { epochs: 3, eval_every: 0, ..Default::default() })
            .run()
            .expect("session run")
            .into_output();
        let pipe = 1.0 / exp::simulate(&out_p, &profile, &topo, Mode::Pipelined).total;
        println!(
            "{:<7} {:>9.2} {:>12.2} {:>9.2} {:>9.2} | {:>11.1}x {:>11.1}x",
            parts,
            roc,
            cagnet,
            gcn,
            pipe,
            gcn / roc,
            pipe / cagnet
        );
        rows.push(
            Json::obj()
                .set("parts", parts)
                .set("roc_eps", roc)
                .set("cagnet2_eps", cagnet)
                .set("gcn_eps", gcn)
                .set("pipegcn_eps", pipe),
        );
    }
    println!("\npaper: GCN beats ROC 3.1–16.4×, CAGNET(c=2) 2.1–10.2×; PipeGCN beats ROC 5.6–28.5×, CAGNET(c=2) 3.9–17.7×");
    Json::obj().set("figure", "3").set("rows", Json::Arr(rows)).write_file("results/f3_throughput.json")?;
    println!("→ results/f3_throughput.json");
    Ok(())
}
