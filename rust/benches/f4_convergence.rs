//! Figures 4 & 9 — epoch-to-accuracy convergence of GCN vs PipeGCN
//! variants (Reddit-like, products-like; Yelp-like = Fig. 9).
//!
//! Paper shape: PipeGCN converges slightly slower early, catches up;
//! smoothing variants match vanilla convergence.

use pipegcn::exp::RunOpts;
use pipegcn::graph::io::append_csv;
use pipegcn::session::Session;

fn main() -> pipegcn::util::error::Result<()> {
    let cases: &[(&str, usize, &str)] = &[
        ("reddit-sim", 2, "fig4"),
        ("products-sim", 10, "fig4"),
        ("yelp-sim", 6, "fig9"),
    ];
    let methods = ["gcn", "pipegcn", "pipegcn-g", "pipegcn-f", "pipegcn-gf"];
    std::fs::remove_file("results/f4_convergence.csv").ok();
    for &(ds, parts, fig) in cases {
        println!("== {fig}: {ds} ({parts} partitions) convergence ==");
        for method in methods {
            let out = Session::preset(ds)
                .parts(parts)
                .variant(method)
                .run_opts(RunOpts { epochs: 0, eval_every: 2, ..Default::default() })
                .run()
                .expect("session run")
                .into_output();
            // half-way and final accuracy summarize the curve shape
            let evals: Vec<_> = out.result.curve.iter().filter(|e| !e.val.is_nan()).collect();
            let half = evals[evals.len() / 2];
            let last = evals.last().unwrap();
            println!(
                "  {:<12} @ half: {:.4}  final: {:.4}",
                out.result.variant, half.test, last.test
            );
            let rows: Vec<String> = evals
                .iter()
                .map(|e| {
                    format!(
                        "{fig},{ds},{parts},{},{},{:.6},{:.6},{:.6}",
                        out.result.variant, e.epoch, e.train_loss, e.val, e.test
                    )
                })
                .collect();
            append_csv(
                "results/f4_convergence.csv",
                "figure,dataset,parts,method,epoch,loss,val,test",
                &rows,
            )?;
        }
    }
    println!("→ results/f4_convergence.csv");
    Ok(())
}
