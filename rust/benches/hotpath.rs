//! Hot-path microbenchmarks (§Perf): SpMM, GEMM variants, halo
//! gather/scatter, ring all-reduce, and one full training iteration.
//! Timings are real single-core wall clock on the native backend.

use pipegcn::comm::allreduce::ring_allreduce;
use pipegcn::comm::Fabric;
use pipegcn::exp::RunOpts;
use pipegcn::session::Session;
use pipegcn::tensor::{Csr, Mat};
use pipegcn::util::rng::Rng;
use pipegcn::util::timer::Stopwatch;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    // warmup
    f();
    let sw = Stopwatch::start();
    for _ in 0..iters {
        f();
    }
    let per = sw.elapsed_secs() / iters as f64;
    println!("{name:<44} {:>10.3} ms/iter", per * 1e3);
    per
}

fn random_csr(rng: &mut Rng, rows: usize, cols: usize, nnz_per_row: usize) -> Csr {
    let mut trip = Vec::with_capacity(rows * nnz_per_row);
    for r in 0..rows {
        for _ in 0..nnz_per_row {
            trip.push((r as u32, rng.gen_range(cols) as u32, rng.next_f32()));
        }
    }
    Csr::from_triplets(rows, cols, trip)
}

fn main() {
    let mut rng = Rng::new(1);
    println!("== hot-path microbenchmarks (native backend, 1 core) ==");

    // SpMM: reddit-sim scale per partition (2 parts)
    let p = random_csr(&mut rng, 2000, 2600, 48);
    let h = Mat::randn(2600, 128, 1.0, &mut rng);
    let mut out = Mat::zeros(2000, 128);
    bench("spmm 2000x2600 nnz≈96k, f=128", 20, || p.spmm_into(&h, &mut out));

    let pt = p.transpose();
    let m = Mat::randn(2000, 128, 1.0, &mut rng);
    let mut out_t = Mat::zeros(2600, 128);
    bench("spmm_t (via transpose) 2600 rows, f=128", 20, || pt.spmm_into(&m, &mut out_t));

    // GEMM variants at layer shapes
    let a = Mat::randn(2600, 128, 1.0, &mut rng);
    let w = Mat::randn(128, 64, 1.0, &mut rng);
    let mut c = Mat::zeros(2600, 64);
    bench("gemm    2600x128 @ 128x64", 20, || a.matmul_into(&w, &mut c));
    let zt = Mat::randn(2000, 128, 1.0, &mut rng);
    let mm = Mat::randn(2000, 64, 1.0, &mut rng);
    bench("gemm_tn (128x2000)ᵀ @ 2000x64", 20, || {
        let _ = zt.matmul_tn(&mm);
    });
    bench("gemm_nt 2000x64 @ (128x64)ᵀ", 20, || {
        let _ = mm.matmul_nt(&w);
    });

    // halo gather + ring all-reduce
    let fabric = Fabric::new(4);
    let mut bufs: Vec<Vec<f32>> = (0..4).map(|_| vec![1.0f32; 40_000]).collect();
    bench("ring all-reduce 4×40k f32", 20, || {
        ring_allreduce(&fabric, &mut bufs, 0).unwrap();
    });

    // end-to-end iteration (reddit-sim, 4 parts)
    let sw = Stopwatch::start();
    let out = Session::preset("reddit-sim")
        .parts(4)
        .variant("pipegcn")
        .run_opts(RunOpts { epochs: 5, eval_every: 0, ..Default::default() })
        .run()
        .expect("session run")
        .into_output();
    let total = sw.elapsed_secs();
    println!(
        "{:<44} {:>10.3} ms/epoch (5 epochs, incl. setup {:.2}s)",
        "train epoch reddit-sim ×4 (pipegcn)",
        out.result.wall_secs / 5.0 * 1e3,
        total - out.result.wall_secs
    );
}
