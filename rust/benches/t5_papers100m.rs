//! Table 5 — papers100M-scale epoch time on 4 servers × 8 MI60 / 10 GbE:
//! total and communication time of GCN vs PipeGCN vs PipeGCN-GF.
//!
//! Paper: GCN 1.00× (10.5 s) comm 1.00× (6.6 s); PipeGCN 0.62× / 0.39×;
//! PipeGCN-GF 0.64× / 0.42×.

use pipegcn::exp::{self, RunOpts};
use pipegcn::session::Session;
use pipegcn::sim::{profiles::rig_mi60, Mode};
use pipegcn::util::fmt_secs;
use pipegcn::util::json::Json;

fn main() -> pipegcn::util::error::Result<()> {
    let (profile, topo) = rig_mi60(4, 8);
    let parts = 32;
    let paper: &[(&str, f64, f64)] =
        &[("GCN", 1.00, 1.00), ("PipeGCN", 0.62, 0.39), ("PipeGCN-GF", 0.64, 0.42)];
    println!("== Table 5: papers-sim × {parts} on 4×8 MI60 / 10GbE ==");
    println!(
        "{:<12} {:>16} {:>16} {:>10} {:>10}",
        "method", "total (rel)", "comm (rel)", "paper tot", "paper comm"
    );
    let mut base = (0.0f64, 0.0f64);
    let mut rows = Vec::new();
    for (i, method) in ["gcn", "pipegcn", "pipegcn-gf"].iter().enumerate() {
        let out = Session::preset("papers-sim")
            .parts(parts)
            .variant(method)
            .run_opts(RunOpts { epochs: 6, eval_every: 0, ..Default::default() })
            .run()
            .expect("session run")
            .into_output();
        let mode = if *method == "gcn" { Mode::Vanilla } else { Mode::Pipelined };
        let sim = exp::simulate(&out, &profile, &topo, mode);
        let comm = sim.comm_exposed + sim.reduce;
        if i == 0 {
            base = (sim.total, comm);
        }
        println!(
            "{:<12} {:>7.2}x ({:>7}) {:>6.2}x ({:>7}) {:>9.2}x {:>9.2}x",
            out.result.variant,
            sim.total / base.0,
            fmt_secs(sim.total),
            comm / base.1,
            fmt_secs(comm),
            paper[i].1,
            paper[i].2,
        );
        rows.push(
            Json::obj()
                .set("method", out.result.variant.clone())
                .set("total_s", sim.total)
                .set("total_rel", sim.total / base.0)
                .set("comm_s", comm)
                .set("comm_rel", comm / base.1)
                .set("paper_total_rel", paper[i].1)
                .set("paper_comm_rel", paper[i].2),
        );
    }
    Json::obj().set("table", "5").set("rows", Json::Arr(rows)).write_file("results/t5_papers100m.json")?;
    println!("→ results/t5_papers100m.json");
    Ok(())
}
