//! Table 2 — communication ratio of vanilla partition-parallel training.
//!
//! Paper (comm time / total time): Reddit 2→65.83% 4→82.89%,
//! ogbn-products 5→76.17% 10→85.79%, Yelp 3→61.16% 6→76.84%.

use pipegcn::exp::{self, RunOpts};
use pipegcn::session::Session;
use pipegcn::sim::Mode;
use pipegcn::util::json::Json;

fn main() -> pipegcn::util::error::Result<()> {
    let cases: &[(&str, usize, f64)] = &[
        ("reddit-sim", 2, 65.83),
        ("reddit-sim", 4, 82.89),
        ("products-sim", 5, 76.17),
        ("products-sim", 10, 85.79),
        ("yelp-sim", 3, 61.16),
        ("yelp-sim", 6, 76.84),
    ];
    println!("== Table 2: comm ratio of vanilla partition-parallel training ==");
    println!(
        "{:<14} {:>6} {:>14} {:>12}",
        "dataset", "parts", "measured", "paper"
    );
    let mut rows = Vec::new();
    for &(ds, parts, paper) in cases {
        let out = Session::preset(ds)
            .parts(parts)
            .variant("gcn")
            .run_opts(RunOpts { epochs: 3, eval_every: 0, ..Default::default() })
            .run()
            .expect("session run")
            .into_output();
        let sim = exp::simulate_default(&out, Mode::Vanilla);
        let measured = 100.0 * sim.comm_ratio();
        println!("{:<14} {:>6} {:>13.2}% {:>11.2}%", ds, parts, measured, paper);
        rows.push(
            Json::obj()
                .set("dataset", ds)
                .set("parts", parts)
                .set("measured_pct", measured)
                .set("paper_pct", paper),
        );
    }
    Json::obj().set("table", "2").set("rows", Json::Arr(rows)).write_file("results/t2_comm_ratio.json")?;
    println!("→ results/t2_comm_ratio.json");
    Ok(())
}
