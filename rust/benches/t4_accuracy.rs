//! Table 4 — test score + training throughput of GCN vs PipeGCN variants
//! on all three single-chassis datasets at the paper's partition counts.
//!
//! Paper shape: PipeGCN* within ±0.3 of vanilla accuracy; throughput
//! 1.7×–2.2× vanilla. (Absolute accuracy differs: synthetic SBM data.)

use pipegcn::exp::{self, RunOpts};
use pipegcn::session::Session;
use pipegcn::sim::Mode;
use pipegcn::util::json::Json;

fn main() -> pipegcn::util::error::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let cases: &[(&str, usize)] = &[
        ("reddit-sim", 2),
        ("reddit-sim", 4),
        ("products-sim", 5),
        ("products-sim", 10),
        ("yelp-sim", 3),
        ("yelp-sim", 6),
    ];
    let methods = ["gcn", "pipegcn", "pipegcn-g", "pipegcn-f", "pipegcn-gf"];
    println!("== Table 4: test score + throughput ==");
    let mut rows = Vec::new();
    for &(ds, parts) in cases {
        println!("\n-- {ds} ({parts} partitions) --");
        println!("{:<12} {:>10} {:>12} {:>10}", "method", "test", "epochs/s", "vs GCN");
        let mut vanilla = 0.0f64;
        for method in methods {
            let out = Session::preset(ds)
                .parts(parts)
                .variant(method)
                .run_opts(RunOpts { epochs: if quick { 10 } else { 0 }, eval_every: 5, ..Default::default() })
                .run()
                .expect("session run")
                .into_output();
            let mode = if method == "gcn" { Mode::Vanilla } else { Mode::Pipelined };
            let sim = exp::simulate_default(&out, mode);
            let eps = exp::sim_epochs_per_s(&sim);
            if method == "gcn" {
                vanilla = eps;
            }
            println!(
                "{:<12} {:>10.4} {:>12.2} {:>9.2}x",
                out.result.variant,
                out.result.best_val_test,
                eps,
                eps / vanilla
            );
            rows.push(
                Json::obj()
                    .set("dataset", ds)
                    .set("parts", parts)
                    .set("method", out.result.variant.clone())
                    .set("test", out.result.best_val_test)
                    .set("final_test", out.result.final_test)
                    .set("epochs_per_s", eps)
                    .set("speedup_vs_gcn", eps / vanilla),
            );
        }
    }
    println!("\npaper: PipeGCN* matches vanilla accuracy (Δ within ±0.3) at 1.7–2.2× throughput");
    Json::obj().set("table", "4").set("rows", Json::Arr(rows)).write_file("results/t4_accuracy.json")?;
    println!("→ results/t4_accuracy.json");
    Ok(())
}
