//! Figure 6 — test-accuracy convergence of PipeGCN-GF under different
//! smoothing decay rates γ on products-like (10 partitions).
//!
//! Paper shape: large γ (0.7/0.95) converges fast but overfits; small γ
//! (0–0.5) mitigates overfitting; γ=0.5 best trade-off.

use pipegcn::exp::RunOpts;
use pipegcn::graph::io::append_csv;
use pipegcn::session::Session;
use pipegcn::util::json::Json;

fn main() -> pipegcn::util::error::Result<()> {
    let gammas = [0.0f32, 0.5, 0.7, 0.95];
    println!("== Fig. 6: γ sweep convergence (products-sim, 10 partitions) ==");
    println!("{:>6} {:>12} {:>12} {:>12}", "γ", "best test", "final test", "overfit Δ");
    std::fs::remove_file("results/f6_gamma_convergence.csv").ok();
    let mut rows = Vec::new();
    for &gamma in &gammas {
        let out = Session::preset("products-sim")
            .parts(10)
            .variant("pipegcn-gf")
            .run_opts(RunOpts { epochs: 0, gamma, eval_every: 2, ..Default::default() })
            .run()
            .expect("session run")
            .into_output();
        let evals: Vec<_> = out.result.curve.iter().filter(|e| !e.val.is_nan()).collect();
        let best = evals.iter().map(|e| e.test).fold(f64::MIN, f64::max);
        let last = evals.last().unwrap().test;
        println!("{:>6.2} {:>12.4} {:>12.4} {:>12.4}", gamma, best, last, best - last);
        let csv: Vec<String> = evals
            .iter()
            .map(|e| format!("{gamma},{},{:.6},{:.6}", e.epoch, e.val, e.test))
            .collect();
        append_csv("results/f6_gamma_convergence.csv", "gamma,epoch,val,test", &csv)?;
        rows.push(
            Json::obj()
                .set("gamma", gamma)
                .set("best_test", best)
                .set("final_test", last),
        );
    }
    println!("\npaper: γ=0.95 fast but overfits; γ=0.5 combines both worlds");
    Json::obj().set("figure", "6").set("rows", Json::Arr(rows)).write_file("results/f6_gamma.json")?;
    println!("→ results/f6_gamma_convergence.csv, results/f6_gamma.json");
    Ok(())
}
