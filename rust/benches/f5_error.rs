//! Figure 5 — staleness error per GCN layer on Reddit-like (2 parts):
//! feature-gradient error and feature error for PipeGCN vs PipeGCN-G/-F
//! (γ = 0.95).
//!
//! Paper shape: smoothing reduces both errors substantially at every
//! layer.

use pipegcn::coordinator::{trainer, Optimizer, TrainConfig, Variant};
use pipegcn::graph::io::append_csv;

fn main() -> pipegcn::util::error::Result<()> {
    let epochs = 60;
    println!("== Fig. 5: staleness errors per layer (reddit-sim, 2 partitions) ==");
    std::fs::remove_file("results/f5_errors.csv").ok();
    let mut summary: Vec<(String, Vec<f64>, Vec<f64>)> = Vec::new();
    for method in ["pipegcn", "pipegcn-g", "pipegcn-f"] {
        // Paper setting: errors are measured during *active* training
        // (Reddit trains 3000 epochs; gradients are near-stationary over
        // the probed window). Mirror that with a small lr so per-epoch
        // drift stays below the fluctuation scale, and report per-epoch
        // RELATIVE errors so magnitude decay cancels.
        let preset = pipegcn::graph::presets::by_name("reddit-sim").unwrap();
        let g = preset.build(1);
        let pt = pipegcn::partition::partition(&g, 2, pipegcn::partition::Method::Multilevel, 1);
        let cfg = TrainConfig {
            model: pipegcn::model::ModelConfig::sage(
                preset.feat_dim, preset.hidden, preset.layers, preset.n_classes, preset.dropout,
            ),
            variant: Variant::parse(method, 0.95).unwrap(),
            optimizer: Optimizer::Adam,
            lr: 0.001,
            epochs,
            seed: 1,
            eval_every: 0,
            probe_errors: true,
        };
        let mut backend = pipegcn::runtime::native::NativeBackend::new();
        let result =
            trainer::train_resumable(&g, &pt, &cfg, &mut backend, None, None, None).unwrap();
        let layers = preset.layers;
        let mut grad = vec![0.0f64; layers];
        let mut feat = vec![0.0f64; layers];
        let mut counts = vec![0usize; layers];
        let rows: Vec<String> = result
            .probes
            .iter()
            .map(|p| {
                if p.epoch > epochs / 4 {
                    if p.grad_ref > 0.0 {
                        grad[p.layer] += p.grad_err / p.grad_ref;
                    }
                    if p.feat_ref > 0.0 {
                        feat[p.layer] += p.feat_err / p.feat_ref;
                    }
                    counts[p.layer] += 1;
                }
                format!(
                    "{},{},{},{:.6},{:.6}",
                    result.variant, p.epoch, p.layer, p.feat_err, p.grad_err
                )
            })
            .collect();
        append_csv(
            "results/f5_errors.csv",
            "method,epoch,layer,feat_err,grad_err",
            &rows,
        )?;
        for l in 0..layers {
            if counts[l] > 0 {
                grad[l] /= counts[l] as f64;
                feat[l] /= counts[l] as f64;
            }
        }
        summary.push((result.variant.clone(), feat, grad));
    }
    println!("\nmean post-warmup RELATIVE errors (‖used−fresh‖/‖fresh‖):");
    println!("{:<12} {:<30} {:<30}", "method", "feature err / layer", "grad err / layer");
    for (name, feat, grad) in &summary {
        let f: Vec<String> = feat.iter().map(|v| format!("{v:.3}")).collect();
        let g: Vec<String> = grad.iter().map(|v| format!("{v:.3}")).collect();
        println!("{:<12} {:<30} {:<30}", name, f.join(" "), g.join(" "));
    }
    // the paper's claim, checked numerically: -G reduces grad error, -F
    // reduces feature error, vs plain PipeGCN
    let plain = &summary[0];
    let g_var = &summary[1];
    let f_var = &summary[2];
    let mean = |v: &Vec<f64>| v.iter().sum::<f64>() / v.len() as f64;
    println!(
        "\ngrad error: PipeGCN {:.3} → PipeGCN-G {:.3} ({:+.1}%)",
        mean(&plain.2),
        mean(&g_var.2),
        100.0 * (mean(&g_var.2) / mean(&plain.2) - 1.0)
    );
    println!(
        "feat error: PipeGCN {:.3} → PipeGCN-F {:.3} ({:+.1}%)",
        mean(&plain.1),
        mean(&f_var.1),
        100.0 * (mean(&f_var.1) / mean(&plain.1) - 1.0)
    );
    println!("→ results/f5_errors.csv");
    Ok(())
}
