//! Figure 7 — per-layer staleness errors under different γ on
//! products-like (10 partitions).
//!
//! Paper shape: larger γ → lower approximation error (more stable
//! gradients/features); γ=0 highest error.

use pipegcn::exp::RunOpts;
use pipegcn::graph::io::append_csv;
use pipegcn::session::Session;

fn main() -> pipegcn::util::error::Result<()> {
    let gammas = [0.0f32, 0.5, 0.95];
    let epochs = 40;
    println!("== Fig. 7: per-layer errors vs γ (products-sim, 10 partitions) ==");
    std::fs::remove_file("results/f7_gamma_errors.csv").ok();
    println!("{:>6} {:<28} {:<28}", "γ", "feat err / layer", "grad err / layer");
    let mut means = Vec::new();
    for &gamma in &gammas {
        let out = Session::preset("products-sim")
            .parts(10)
            .variant("pipegcn-gf")
            .run_opts(RunOpts { epochs, gamma, probe_errors: true, eval_every: 0, ..Default::default() })
            .run()
            .expect("session run")
            .into_output();
        let layers = out.preset.layers;
        let mut feat = vec![0.0f64; layers];
        let mut grad = vec![0.0f64; layers];
        let mut n = vec![0usize; layers];
        let rows: Vec<String> = out
            .result
            .probes
            .iter()
            .map(|p| {
                if p.epoch > epochs / 4 {
                    feat[p.layer] += p.feat_err;
                    grad[p.layer] += p.grad_err;
                    n[p.layer] += 1;
                }
                format!(
                    "{gamma},{},{},{:.6},{:.6}",
                    p.epoch, p.layer, p.feat_err, p.grad_err
                )
            })
            .collect();
        append_csv(
            "results/f7_gamma_errors.csv",
            "gamma,epoch,layer,feat_err,grad_err",
            &rows,
        )?;
        for l in 0..layers {
            if n[l] > 0 {
                feat[l] /= n[l] as f64;
                grad[l] /= n[l] as f64;
            }
        }
        let fs: Vec<String> = feat.iter().map(|v| format!("{v:.3}")).collect();
        let gs: Vec<String> = grad.iter().map(|v| format!("{v:.3}")).collect();
        println!("{:>6.2} {:<28} {:<28}", gamma, fs.join(" "), gs.join(" "));
        means.push((
            gamma,
            feat.iter().sum::<f64>() / layers as f64,
            grad.iter().sum::<f64>() / layers as f64,
        ));
    }
    // paper's monotonicity: γ=0.95 error < γ=0 error
    let lo = means.iter().find(|m| m.0 == 0.0).unwrap();
    let hi = means.iter().find(|m| m.0 == 0.95).unwrap();
    println!(
        "\nγ=0.95 vs γ=0: feat {:.3} vs {:.3}, grad {:.3} vs {:.3} (paper: larger γ → lower error)",
        hi.1, lo.1, hi.2, lo.2
    );
    println!("→ results/f7_gamma_errors.csv");
    Ok(())
}
