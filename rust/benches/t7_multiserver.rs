//! Tables 7 & 8 — multi-server scaling on the MI60/10GbE testbed:
//! accuracy of PipeGCN variants (T7) and throughput speedup over vanilla
//! (T8) across (#nodes × #gpus) grids.
//!
//! Paper: accuracy flat (~97.0–97.2 on Reddit) across 2–16 partitions;
//! speedups 1.16×–1.65×.

use pipegcn::exp::{self, RunOpts};
use pipegcn::session::Session;
use pipegcn::sim::{profiles::rig_mi60, Mode};
use pipegcn::util::json::Json;

fn main() -> pipegcn::util::error::Result<()> {
    let grids: &[(usize, usize)] = &[
        (1, 2),
        (1, 3),
        (1, 4),
        (2, 2),
        (2, 3),
        (2, 4),
        (3, 2),
        (3, 3),
        (3, 4),
        (4, 2),
        (4, 3),
        (4, 4),
    ];
    let methods = ["gcn", "pipegcn", "pipegcn-g", "pipegcn-f", "pipegcn-gf"];
    println!("== Tables 7/8: multi-server accuracy + speedup (reddit-sim) ==");
    println!(
        "{:<8} {:>6} | {:>8} {:>8} {:>8} {:>8} {:>8} | {:>8}",
        "topology", "parts", "GCN", "Pipe", "Pipe-G", "Pipe-F", "Pipe-GF", "speedup"
    );
    let mut rows = Vec::new();
    for &(nodes, per) in grids {
        let parts = nodes * per;
        let (profile, topo) = rig_mi60(nodes, per);
        let mut accs = Vec::new();
        let mut vanilla_total = 0.0;
        let mut pipe_total = 0.0;
        for method in methods {
            let out = Session::preset("reddit-sim")
                .parts(parts)
                .variant(method)
                .run_opts(RunOpts { epochs: 30, eval_every: 30, ..Default::default() })
                .run()
                .expect("session run")
                .into_output();
            let mode = if method == "gcn" { Mode::Vanilla } else { Mode::Pipelined };
            let sim = exp::simulate(&out, &profile, &topo, mode);
            if method == "gcn" {
                vanilla_total = sim.total;
            }
            if method == "pipegcn" {
                pipe_total = sim.total;
            }
            accs.push(out.result.final_test);
        }
        let speedup = vanilla_total / pipe_total;
        println!(
            "{:<8} {:>6} | {:>8.4} {:>8.4} {:>8.4} {:>8.4} {:>8.4} | {:>7.2}x",
            format!("{nodes}x{per}"),
            parts,
            accs[0],
            accs[1],
            accs[2],
            accs[3],
            accs[4],
            speedup
        );
        rows.push(
            Json::obj()
                .set("nodes", nodes)
                .set("gpus_per_node", per)
                .set("parts", parts)
                .set("acc_gcn", accs[0])
                .set("acc_pipegcn", accs[1])
                .set("acc_pipegcn_g", accs[2])
                .set("acc_pipegcn_f", accs[3])
                .set("acc_pipegcn_gf", accs[4])
                .set("speedup", speedup),
        );
    }
    println!("\npaper T8 speedups: 1.16× (1×2) … 1.65× (3×2), dipping when 10GbE saturates");
    Json::obj().set("tables", "7+8").set("rows", Json::Arr(rows)).write_file("results/t7_t8_multiserver.json")?;
    println!("→ results/t7_t8_multiserver.json");
    Ok(())
}
