//! Schedule-IR conformance (property test): for every cell of
//! (parts × variant × layers × epochs), the transport operations the
//! engines actually perform — observed at the transport layer through
//! the process-global event sink — must equal, per rank and in order,
//! the statically generated [`pipegcn::comm::schedule::Schedule`].
//!
//! Two executors are checked against their respective styles:
//! the sequential replay (`trainer::train_resumable`) against
//! [`Style::Inline`], and the threaded engine (`run_threaded_ctl`)
//! against [`Style::Prefetched`]. Both runs must also produce
//! bit-identical loss curves — the schedule describes message identity,
//! not timing, so the dataflow cannot depend on which executor runs it.
//!
//! The event sink is process-global and cargo runs the tests of one
//! binary on parallel threads, so every test here serializes on
//! `SINK_LOCK` before installing a sink.

use pipegcn::comm::schedule::{self, Op, OpKind, Recorder, Schedule, Style};
use pipegcn::comm::{Phase, Tag};
use pipegcn::coordinator::{
    halo, threaded, trainer, Optimizer, PipeOpts, TrainConfig, Variant,
};
use pipegcn::graph::presets;
use pipegcn::graph::Graph;
use pipegcn::model::ModelConfig;
use pipegcn::partition::{partition, Method, Partitioning};
use pipegcn::runtime::native::NativeBackend;
use std::sync::{Mutex, MutexGuard};

static SINK_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    SINK_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn cfg_for(variant: Variant, layers: usize, epochs: usize, g: &Graph) -> TrainConfig {
    TrainConfig {
        model: ModelConfig::sage(g.feat_dim(), 8, layers, g.labels.n_classes(), 0.0),
        variant,
        optimizer: Optimizer::Adam,
        lr: 0.01,
        epochs,
        seed: 11,
        eval_every: 0,
        probe_errors: false,
    }
}

/// Per-rank communication links, derived the same way the engines do it
/// (from the halo plan's views).
fn links_of(g: &Graph, pt: &Partitioning, cfg: &TrainConfig) -> Vec<schedule::RankLinks> {
    let plan = halo::build(g, pt, cfg.model.kind);
    (0..pt.n_parts).map(|r| plan.view(r).comm_links()).collect()
}

/// Observability sentinel traffic (trace clock-sync / span shipping)
/// rides `Phase::Setup` at reserved top iteration values and is not
/// schedule traffic — the same filter [`schedule::Conformance`] applies.
fn recorded(rec: &Recorder, rank: usize) -> Vec<Op> {
    rec.by_rank(rank)
        .into_iter()
        .filter(|o| !(o.tag.phase == Phase::Setup && o.tag.iter >= pipegcn::obs::trace::SHIP_ITER))
        .collect()
}

fn scheduled(sched: &Schedule, rank: usize) -> Vec<Op> {
    sched.ranks[rank]
        .windows
        .iter()
        .flat_map(|w| w.events.iter().map(|e| e.to_op(rank)))
        .collect()
}

fn assert_stream(cell: &str, engine: &str, rank: usize, got: &[Op], want: &[Op]) {
    if got == want {
        return;
    }
    let i = got
        .iter()
        .zip(want.iter())
        .position(|(g, w)| g != w)
        .unwrap_or_else(|| got.len().min(want.len()));
    panic!(
        "{cell} [{engine}] rank {rank}: op stream diverges from the IR at index {i}\n  \
         performed: {:?}\n  scheduled: {:?}\n  \
         ({} ops performed vs {} scheduled)",
        got.get(i),
        want.get(i),
        got.len(),
        want.len()
    );
}

#[test]
fn engines_replay_exactly_the_generated_schedule() {
    let _guard = lock();
    let g = presets::by_name("tiny").unwrap().build(42);
    for parts in [1usize, 2, 4] {
        let pt = partition(&g, parts, Method::Multilevel, 2);
        for variant in [Variant::Vanilla, Variant::Pipe(PipeOpts::plain())] {
            for layers in [2usize, 3] {
                for epochs in [1usize, 3] {
                    let cell = format!(
                        "parts={parts} variant={} layers={layers} epochs={epochs}",
                        variant.name()
                    );
                    let cfg = cfg_for(variant, layers, epochs, &g);
                    let links = links_of(&g, &pt, &cfg);
                    let pipe = variant.is_pipelined();

                    // Sequential replay ↔ Style::Inline.
                    let inline = Schedule::generate(
                        &links,
                        Style::Inline,
                        pipe,
                        layers,
                        1,
                        epochs as u32,
                    )
                    .unwrap();
                    assert!(
                        schedule::verify(&inline).is_empty(),
                        "{cell}: inline IR fails static verification"
                    );
                    let rec = Recorder::new();
                    schedule::set_sink(Box::new(rec.clone()));
                    let mut b = NativeBackend::new();
                    let seq = trainer::train_resumable(&g, &pt, &cfg, &mut b, None, None, None);
                    schedule::clear_sink();
                    let seq = seq.unwrap();
                    for r in 0..parts {
                        let want = scheduled(&inline, r);
                        assert_stream(&cell, "sequential", r, &recorded(&rec, r), &want);
                    }

                    // Threaded engine ↔ Style::Prefetched.
                    let prefetched = Schedule::generate(
                        &links,
                        Style::Prefetched,
                        pipe,
                        layers,
                        1,
                        epochs as u32,
                    )
                    .unwrap();
                    assert!(
                        schedule::verify(&prefetched).is_empty(),
                        "{cell}: prefetched IR fails static verification"
                    );
                    let rec = Recorder::new();
                    schedule::set_sink(Box::new(rec.clone()));
                    let thr =
                        threaded::run_threaded_ctl(&g, &pt, &cfg, threaded::ThreadedCtl::default());
                    schedule::clear_sink();
                    let thr = thr.unwrap().0;
                    for r in 0..parts {
                        let want = scheduled(&prefetched, r);
                        assert_stream(&cell, "threaded", r, &recorded(&rec, r), &want);
                    }

                    // Same schedule semantics ⇒ same dataflow: loss
                    // curves are bit-identical across the executors.
                    assert_eq!(seq.curve.len(), epochs);
                    assert_eq!(thr.losses.len(), epochs);
                    for (e, stat) in seq.curve.iter().enumerate() {
                        assert_eq!(
                            stat.train_loss.to_bits(),
                            thr.losses[e].to_bits(),
                            "{cell} epoch {}: sequential {} vs threaded {}",
                            e + 1,
                            stat.train_loss,
                            thr.losses[e]
                        );
                    }
                }
            }
        }
    }
}

/// Regression (loss-tag punning): loss partials used to ride
/// `Phase::Setup` with the source rank packed into the layer field,
/// which aliased the setup exchange once three or more parts were in
/// play. [`Tag::loss`] carries `Phase::Loss`, so at parts ≥ 3 every
/// loss message must reach rank 0 under its own phase, once per source
/// per epoch, and no loss tag may collide with any setup-window tag.
#[test]
fn loss_tags_do_not_pun_setup_at_three_plus_parts() {
    let _guard = lock();
    let g = presets::by_name("tiny").unwrap().build(42);
    let parts = 3usize;
    let epochs = 2usize;
    let pt = partition(&g, parts, Method::Multilevel, 2);
    let cfg = cfg_for(Variant::Pipe(PipeOpts::plain()), 2, epochs, &g);

    let rec = Recorder::new();
    schedule::set_sink(Box::new(rec.clone()));
    let mut b = NativeBackend::new();
    let r = trainer::train_resumable(&g, &pt, &cfg, &mut b, None, None, None);
    schedule::clear_sink();
    r.unwrap();

    let rank0 = recorded(&rec, 0);
    for t in 1..=epochs as u32 {
        let want = Tag::loss(t);
        assert_eq!(want.phase, Phase::Loss);
        for src in 1..parts {
            let n = rank0
                .iter()
                .filter(|o| o.kind == OpKind::Claim && o.peer == src && o.tag == want)
                .count();
            assert_eq!(n, 1, "epoch {t}: rank 0 claimed {n} loss partials from rank {src}");
        }
    }
    // The punning bug made a loss tag equal a setup tag; assert the
    // phases now keep the two streams disjoint by construction.
    let setup = schedule::setup_tag();
    assert_eq!(setup.phase, Phase::Setup);
    assert!(rank0
        .iter()
        .filter(|o| o.tag.phase == Phase::Loss)
        .all(|o| o.tag != setup));
}
