//! Integration tests of the `obs` subsystem: histogram quantile error
//! bounds against exact percentiles, registry correctness under
//! concurrent updates from the compute pool, Chrome trace-event export
//! well-formedness, and the regression pinning per-link byte counters
//! to the aggregate `comm_bytes` accounting.

use pipegcn::comm::{Phase, Tag, Transport};
use pipegcn::net::localhost_mesh;
use pipegcn::obs::trace::{chrome_trace_json, write_chrome_trace, Kind, Span};
use pipegcn::obs::Registry;
use pipegcn::runtime::pool::Pool;
use pipegcn::util::json::Json;
use pipegcn::util::rng::Rng;

/// Log-bucketed histograms answer quantiles from bucket upper edges:
/// the estimate can be off by at most one bucket ratio (2^(1/4)) plus
/// the difference between the two percentile definitions at repeated
/// values. Half an octave in log2 space covers both with margin.
#[test]
fn histogram_quantiles_within_bucket_error_of_exact() {
    let reg = Registry::new();
    let hist = reg.histogram("test_quantile_bounds_ms", &[]);
    let mut rng = Rng::new(7);
    // three decades of spread, strictly positive
    let samples: Vec<f64> = (0..2000).map(|_| 0.1 + 100.0 * rng.next_f64().powi(2)).collect();
    for &v in &samples {
        hist.record(v);
    }
    let mut sorted = samples.clone();
    sorted.sort_by(f64::total_cmp);
    for q in [0.50, 0.90, 0.99] {
        let exact = pipegcn::perf::percentile(&sorted, q);
        let est = hist.quantile(q);
        let err = (est / exact).log2().abs();
        assert!(
            err <= 0.5,
            "q={q}: histogram {est} vs exact {exact} ({err:.3} octaves apart)"
        );
    }
    // quantiles are monotone in q
    assert!(hist.quantile(0.50) <= hist.quantile(0.90));
    assert!(hist.quantile(0.90) <= hist.quantile(0.99));
    // count is exact; sum matches up to FP reassociation
    assert_eq!(hist.count(), samples.len() as u64);
    let total: f64 = samples.iter().sum();
    assert!((hist.sum() - total).abs() <= 1e-6 * total.abs());
}

/// Counters, gauges, and histograms must tally exactly when hammered
/// from every pool worker at once — the registry hands out lock-free
/// handles, so contention must never drop an update.
#[test]
fn registry_exact_under_concurrent_pool_updates() {
    let reg = Registry::new();
    let counter = reg.counter("test_concurrent_total", &[]);
    let gauge = reg.gauge("test_concurrent_gauge", &[]);
    let hist = reg.histogram("test_concurrent_ms", &[]);
    let pool = Pool::new(4);
    const CHUNKS: usize = 400;
    const PER_CHUNK: usize = 25;
    pool.run(CHUNKS, |i| {
        for k in 0..PER_CHUNK {
            counter.add(1.0);
            gauge.add(1.0);
            hist.record((1 + (i + k) % 16) as f64);
        }
    });
    let n = (CHUNKS * PER_CHUNK) as f64;
    assert_eq!(counter.get(), n);
    assert_eq!(gauge.get(), n);
    assert_eq!(hist.count(), CHUNKS as u64 * PER_CHUNK as u64);
    // every recorded value was an integer in [1, 16]
    assert!(hist.sum() >= n && hist.sum() <= 16.0 * n);
    // the lookup path sees the same numbers as the handles
    assert_eq!(reg.value("test_concurrent_total", &[]), Some(n));
    assert_eq!(reg.value("test_concurrent_gauge", &[]), Some(n));
    // labeled series stay independent: same family, distinct labels
    let a = reg.counter("test_concurrent_labeled", &[("side", "a")]);
    let b = reg.counter("test_concurrent_labeled", &[("side", "b")]);
    pool.run(64, |i| if i % 2 == 0 { a.inc() } else { b.inc() });
    assert_eq!(a.get(), 32.0);
    assert_eq!(b.get(), 32.0);
}

/// The exported Chrome trace must round-trip through our own JSON
/// parser and carry one complete ("X") event per span, with `pid` =
/// rank so multi-rank merges read as separate processes.
#[test]
fn chrome_trace_export_is_well_formed_json() {
    let spans = vec![
        Span { rank: 0, layer: 0, epoch: 1, kind: Kind::FwdLayer, start_us: 10, end_us: 25 },
        Span { rank: 0, layer: 0, epoch: 1, kind: Kind::CommWait, start_us: 25, end_us: 40 },
        Span { rank: 1, layer: 1, epoch: 1, kind: Kind::BwdLayer, start_us: 12, end_us: 30 },
        Span { rank: 1, layer: 0, epoch: 1, kind: Kind::Epoch, start_us: 0, end_us: 55 },
    ];
    let doc = chrome_trace_json(&spans);
    let reparsed = Json::parse(&doc.to_compact()).expect("export must be parseable JSON");
    let events = reparsed
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents array");
    assert_eq!(events.len(), spans.len());
    for (ev, s) in events.iter().zip(&spans) {
        assert_eq!(ev.get("ph").and_then(Json::as_str), Some("X"));
        assert_eq!(ev.get("pid").and_then(Json::as_f64), Some(s.rank as f64));
        assert_eq!(ev.get("ts").and_then(Json::as_f64), Some(s.start_us as f64));
        assert_eq!(
            ev.get("dur").and_then(Json::as_f64),
            Some((s.end_us - s.start_us) as f64)
        );
        let args = ev.get("args").expect("args object");
        assert_eq!(args.get("epoch").and_then(Json::as_f64), Some(s.epoch as f64));
    }
    // the file writer produces the identical document
    let path = std::env::temp_dir().join("pipegcn_obs_trace_test.json");
    let path = path.to_str().expect("temp path");
    write_chrome_trace(path, &spans).expect("write trace");
    let from_file = std::fs::read_to_string(path).expect("read trace back");
    assert_eq!(from_file, doc.to_compact());
    let _ = std::fs::remove_file(path);
}

/// Regression: the per-link byte counters must sum to the aggregate
/// `payload_bytes_sent` that the `comm_bytes` reports are built on —
/// per-link observability must never drift from the totals.
#[test]
fn per_link_byte_counters_sum_to_payload_total() {
    const PARTS: usize = 3;
    let mesh = localhost_mesh(PARTS).expect("mesh");
    let handles: Vec<_> = mesh
        .into_iter()
        .enumerate()
        .map(|(rank, mut t)| {
            std::thread::spawn(move || {
                // every rank sends a differently-sized payload to every
                // peer, twice, so links carry distinct byte counts
                for round in 0..2u32 {
                    let tag = Tag::new(round, 0, Phase::FwdFeat);
                    for dst in 0..PARTS {
                        if dst != rank {
                            t.send(rank, dst, tag, vec![rank as f32; 5 + 3 * rank + dst]);
                        }
                    }
                    for src in 0..PARTS {
                        if src != rank {
                            let got = t.recv_blocking(src, rank, tag);
                            assert_eq!(got.len(), 5 + 3 * src + rank);
                        }
                    }
                }
                let links = t.link_payload_bytes_sent();
                let total = t.payload_bytes_sent();
                t.shutdown();
                (rank, links, total)
            })
        })
        .collect();
    for h in handles {
        let (rank, links, total) = h.join().expect("rank thread");
        assert_eq!(links.len(), PARTS);
        assert_eq!(links[rank], 0, "rank {rank} recorded bytes to itself");
        let link_sum: u64 = links.iter().sum();
        assert_eq!(
            link_sum, total,
            "rank {rank}: per-link bytes {links:?} don't sum to payload total {total}"
        );
        // 2 rounds × 2 peers, 4 bytes per f32, payload sizes as sent
        let expected: u64 = (0..PARTS)
            .filter(|&d| d != rank)
            .map(|d| 2 * 4 * (5 + 3 * rank + d) as u64)
            .sum();
        assert_eq!(total, expected, "rank {rank} payload byte count");
    }
}
