//! End-to-end tests of the serving tier: request coalescing, activation
//! caching, the replica router, drains, and rolling reloads.
//!
//! The acceptance oracle everywhere: logits produced through the tier —
//! batched, cached, routed, mid-failover, mid-reload — are
//! **bit-identical** to [`full_graph_forward`] on the params that
//! answered, and the version stamp names which params those were.

use pipegcn::coordinator::{forward_registered, forward_with_features, full_graph_forward};
use pipegcn::graph::presets;
use pipegcn::model::{artifact, ModelConfig, Params};
use pipegcn::runtime::native::NativeBackend;
use pipegcn::runtime::Backend;
use pipegcn::serve::tier::{
    ActivationCache, Coalescer, Reply, Router, RouterOpts, TierOpts,
};
use pipegcn::serve::{ctx_from_parts, Client, Query, ServeState, Server};
use pipegcn::tensor::Mat;
use pipegcn::util::rng::Rng;

fn tiny_model() -> (pipegcn::graph::Graph, ModelConfig, Params) {
    let p = presets::by_name("tiny").unwrap();
    let g = p.build(1);
    let cfg = ModelConfig::from_preset(p);
    let params = Params::init(&cfg, &mut Rng::new(3));
    (g, cfg, params)
}

/// Concurrent submitters get fused into one kernel pass (batch_size > 1
/// on at least one reply) and every reply carries the exact forward
/// bits for its own rows.
#[test]
fn coalescer_fuses_concurrent_queries_bitwise() {
    let (g, cfg, params) = tiny_model();
    let mut b = NativeBackend::new();
    let want = full_graph_forward(&g, &params, cfg.kind, &mut b);
    let state = ServeState::new(ctx_from_parts(g, cfg, params).unwrap());
    // a long window so every submitter lands inside one batch even on a
    // loaded CI box
    let co = Coalescer::start(
        state,
        TierOpts { window_ms: 200.0, max_batch: 16, cache: true, queue: 64 },
    );
    let n_threads = 8;
    let barrier = std::sync::Barrier::new(n_threads);
    let replies: Vec<Reply> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..n_threads)
            .map(|i| {
                let sub = co.submitter();
                let barrier = &barrier;
                s.spawn(move || {
                    barrier.wait();
                    sub.submit(Query { rows: vec![i * 3], feats: Vec::new() }).unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let max_batch = replies.iter().map(|r| r.batch_size).max().unwrap();
    assert!(max_batch > 1, "no queries fused (max batch {max_batch})");
    for (i, r) in replies.iter().enumerate() {
        let want_row = want.row(i * 3);
        assert_eq!(r.logits.len(), want_row.len());
        for (a, b) in r.logits.iter().zip(want_row) {
            assert_eq!(a.to_bits(), b.to_bits(), "submitter {i}");
        }
    }
    drop(co);
}

/// The full tier over real sockets — batching window on, cache on — is
/// invisible in the bits: plain queries (cold and warm), an override,
/// and a post-override plain query all match the local forwards.
#[test]
fn tier_server_is_bit_transparent_over_sockets() {
    let (g, cfg, params) = tiny_model();
    let fd = g.feat_dim();
    let mut b = NativeBackend::new();
    let want = full_graph_forward(&g, &params, cfg.kind, &mut b);
    let ids: Vec<u32> = vec![4, 10];
    let mut rng = Rng::new(9);
    let fresh = Mat::randn(ids.len(), fd, 1.0, &mut rng);
    let mut patched = g.features.clone();
    for (i, &id) in ids.iter().enumerate() {
        patched.set_row(id as usize, fresh.row(i));
    }
    let mut b2 = NativeBackend::new();
    let want_over = forward_with_features(&g, &params, cfg.kind, &mut b2, &patched);

    let server = Server::from_parts(g, cfg, params).unwrap();
    let addr = server.addr().to_string();
    let tier = TierOpts { window_ms: 2.0, max_batch: 8, cache: true, queue: 64 };
    let handle = std::thread::spawn(move || server.run_tier(Some(1), tier));
    let mut client = Client::connect(&addr).unwrap();
    // cold query (warms the cache), then a warm one — both exact
    for pass in 0..2 {
        let got = client.query(&ids).unwrap();
        for (i, &id) in ids.iter().enumerate() {
            for (a, b) in got.row(i).iter().zip(want.row(id as usize)) {
                assert_eq!(a.to_bits(), b.to_bits(), "pass {pass} node {id}");
            }
        }
    }
    // an override answers from the patched state…
    let got = client.query_with_features(&ids, &fresh).unwrap();
    for (i, &id) in ids.iter().enumerate() {
        for (a, b) in got.row(i).iter().zip(want_over.row(id as usize)) {
            assert_eq!(a.to_bits(), b.to_bits(), "override node {id}");
        }
    }
    // …and leaves the cache clean: the next plain query is exact again
    let got = client.query(&ids).unwrap();
    for (i, &id) in ids.iter().enumerate() {
        for (a, b) in got.row(i).iter().zip(want.row(id as usize)) {
            assert_eq!(a.to_bits(), b.to_bits(), "post-override node {id}");
        }
    }
    client.close();
    handle.join().unwrap().unwrap();
}

/// Property test of the cone-invalidation path: for random override
/// sets, the cached answer is bit-equal to a cold full forward over the
/// patched features, and afterwards the cache and scratch are restored
/// so *every* plain row still matches the base forward.
#[test]
fn cache_invalidation_recomputes_exactly_the_dependent_rows() {
    let (g, cfg, params) = tiny_model();
    let n = g.n;
    let fd = g.feat_dim();
    let base_features = g.features.clone();
    let ctx = ctx_from_parts(g, cfg, params).unwrap();
    let mut be = NativeBackend::new();
    let pid = be.register_prop(&ctx.prop);
    let base = forward_registered(pid, &ctx.params, &mut be, &ctx.features);
    let mut cache = ActivationCache::new(&ctx);
    cache.warm(&ctx);
    let mut scratch = (*ctx.features).clone();
    let mut rng = Rng::new(123);
    let all: Vec<usize> = (0..n).collect();
    for trial in 0..6 {
        let k = 1 + rng.gen_range(4);
        let rows = rng.sample_indices(n, k);
        let mut feats = Vec::with_capacity(k * fd);
        for _ in 0..k * fd {
            feats.push(rng.normal());
        }
        // the oracle: a cold full forward over patched features
        let mut patched = base_features.clone();
        for (i, &r) in rows.iter().enumerate() {
            patched.set_row(r, &feats[i * fd..(i + 1) * fd]);
        }
        let want = forward_registered(pid, &ctx.params, &mut be, &patched);
        let (got, invalidated) = cache.override_rows(&ctx, &mut scratch, &rows, &feats);
        assert!(
            invalidated > 0 || ctx.params.layers.len() == 1,
            "a multi-layer override must invalidate some cached rows"
        );
        for (i, &r) in rows.iter().enumerate() {
            let got_row = &got[i * ctx.n_classes..(i + 1) * ctx.n_classes];
            for (a, b) in got_row.iter().zip(want.row(r)) {
                assert_eq!(a.to_bits(), b.to_bits(), "trial {trial} override row {r}");
            }
        }
        // restoration: the whole graph still answers the base bits
        let plain = cache.final_rows(&ctx, &all);
        for r in 0..n {
            let got_row = &plain[r * ctx.n_classes..(r + 1) * ctx.n_classes];
            for (a, b) in got_row.iter().zip(base.row(r)) {
                assert_eq!(a.to_bits(), b.to_bits(), "trial {trial} restored row {r}");
            }
        }
        assert_eq!(scratch.data, ctx.features.data, "trial {trial}: scratch not restored");
    }
}

/// A single-replica drain: the server's unbounded run loop returns after
/// a `Ctrl` drain, with the in-flight connection's queries finished.
#[test]
fn drain_stops_an_unbounded_server_cleanly() {
    let (g, cfg, params) = tiny_model();
    let server = Server::from_parts(g, cfg, params).unwrap();
    let addr = server.addr().to_string();
    let handle = std::thread::spawn(move || server.run(None));
    let mut client = Client::connect(&addr).unwrap();
    let got = client.query(&[0, 1]).unwrap();
    assert!(!got.data.is_empty());
    let mut ctl = Client::connect(&addr).unwrap();
    ctl.drain().unwrap();
    ctl.close();
    client.close();
    handle.join().unwrap().unwrap();
}

fn wait_addr(path: &str) -> String {
    let mut waited = 0u32;
    loop {
        if let Ok(a) = std::fs::read_to_string(path) {
            if !a.is_empty() {
                return a;
            }
        }
        waited += 1;
        assert!(waited < 400, "replica never wrote {path}");
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
}

/// Failover: two real `pipegcn serve` replica processes behind an
/// in-process router; one replica is killed mid-load. Zero client
/// queries fail and every answer stays bit-identical.
#[test]
fn router_failover_loses_no_queries() {
    let bin = env!("CARGO_BIN_EXE_pipegcn");
    let base = format!("/tmp/pipegcn_tier_failover_{}", std::process::id());
    let _ = std::fs::remove_dir_all(&base);
    std::fs::create_dir_all(&base).unwrap();
    let (g, cfg, params) = tiny_model();
    let mut b = NativeBackend::new();
    let want = full_graph_forward(&g, &params, cfg.kind, &mut b);
    let path = format!("{base}/params.pgp");
    artifact::save(&path, &artifact::ParamsFile { config: cfg, params }).unwrap();

    let spawn_replica = |i: usize| {
        let addr_file = format!("{base}/replica{i}.addr");
        let child = std::process::Command::new(bin)
            .args(["serve", "--dataset", "tiny"])
            .args(["--params", &path, "--addr-file", &addr_file])
            .spawn()
            .expect("spawning a serve replica");
        (child, addr_file)
    };
    let (mut c0, f0) = spawn_replica(0);
    let (mut c1, f1) = spawn_replica(1);
    let (a0, a1) = (wait_addr(&f0), wait_addr(&f1));

    let router = Router::bind(&RouterOpts {
        bind: "127.0.0.1:0".to_string(),
        replicas: vec![a0, a1],
        probe_ms: 100,
    })
    .unwrap();
    let raddr = router.addr().to_string();
    let rh = std::thread::spawn(move || router.run(None));

    let ids: Vec<u32> = vec![1, 2, 3];
    let mut client = Client::connect(&raddr).unwrap();
    for q in 0..60 {
        if q == 20 {
            c0.kill().expect("killing replica 0");
            let _ = c0.wait();
        }
        let got = client.query(&ids).unwrap_or_else(|e| {
            panic!("query {q} failed during failover: {e}");
        });
        for (i, &id) in ids.iter().enumerate() {
            for (a, b) in got.row(i).iter().zip(want.row(id as usize)) {
                assert_eq!(a.to_bits(), b.to_bits(), "query {q} node {id}");
            }
        }
    }
    client.close();
    let mut ctl = Client::connect(&raddr).unwrap();
    ctl.drain().unwrap();
    ctl.close();
    rh.join().unwrap().unwrap();
    c1.kill().ok();
    let _ = c1.wait();
    std::fs::remove_dir_all(&base).ok();
}

/// Rolling reload: two in-process replicas behind a router; a reload to
/// a second artifact runs concurrently with a query loop. Zero queries
/// fail, every response is bit-exact under the artifact its stamp
/// names, and after the roll everything answers from the new artifact.
#[test]
fn rolling_reload_is_zero_downtime_and_stamped() {
    let p = presets::by_name("tiny").unwrap();
    let cfg = ModelConfig::from_preset(p);
    let params_a = Params::init(&cfg, &mut Rng::new(3));
    let params_b = Params::init(&cfg, &mut Rng::new(31));
    let g = p.build(1);
    let mut b = NativeBackend::new();
    let want_a = full_graph_forward(&g, &params_a, cfg.kind, &mut b);
    let want_b = full_graph_forward(&g, &params_b, cfg.kind, &mut b);
    let pf_a = artifact::ParamsFile { config: cfg.clone(), params: params_a.clone() };
    let pf_b = artifact::ParamsFile { config: cfg.clone(), params: params_b.clone() };
    let va = artifact::content_version(&pf_a);
    let vb = artifact::content_version(&pf_b);
    assert_ne!(va, vb);
    let path_b = format!("/tmp/pipegcn_tier_reload_{}.pgp", std::process::id());
    artifact::save(&path_b, &pf_b).unwrap();

    let mk = || {
        let server =
            Server::from_parts(p.build(1), cfg.clone(), params_a.clone()).unwrap();
        let addr = server.addr().to_string();
        let h = std::thread::spawn(move || server.run_tier(None, TierOpts::default()));
        (addr, h)
    };
    let (a0, h0) = mk();
    let (a1, h1) = mk();
    let router = Router::bind(&RouterOpts {
        bind: "127.0.0.1:0".to_string(),
        replicas: vec![a0.clone(), a1.clone()],
        probe_ms: 50,
    })
    .unwrap();
    let raddr = router.addr().to_string();
    let rh = std::thread::spawn(move || router.run(None));

    // the roll runs concurrently with the query loop below
    let reload_handle = {
        let raddr = raddr.clone();
        let path_b = path_b.clone();
        std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(50));
            let mut ctl = Client::connect(&raddr).unwrap();
            let ack = ctl.reload(&path_b).unwrap();
            ctl.close();
            ack
        })
    };
    let ids: Vec<u32> = vec![0, 6];
    let mut client = Client::connect(&raddr).unwrap();
    for q in 0..60 {
        let got = client.query(&ids).unwrap_or_else(|e| {
            panic!("query {q} failed during the rolling reload: {e}");
        });
        let version = client.artifact_version().expect("v2 responses are stamped");
        let want = if version == va {
            &want_a
        } else if version == vb {
            &want_b
        } else {
            panic!("query {q}: unknown version stamp {version}");
        };
        for (i, &id) in ids.iter().enumerate() {
            for (x, y) in got.row(i).iter().zip(want.row(id as usize)) {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "query {q} node {id} under version {version}"
                );
            }
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let ack = reload_handle.join().unwrap();
    assert!(ack.contains(&format!("={vb}")), "reload ack names the new version: {ack}");
    // after the roll, every answer comes from the new artifact
    let got = client.query(&ids).unwrap();
    assert_eq!(client.artifact_version(), Some(vb));
    for (i, &id) in ids.iter().enumerate() {
        for (x, y) in got.row(i).iter().zip(want_b.row(id as usize)) {
            assert_eq!(x.to_bits(), y.to_bits(), "post-roll node {id}");
        }
    }
    client.close();
    // tear the tier down: router first, then each replica directly
    let mut ctl = Client::connect(&raddr).unwrap();
    ctl.drain().unwrap();
    ctl.close();
    rh.join().unwrap().unwrap();
    for a in [a0, a1] {
        let mut ctl = Client::connect(&a).unwrap();
        ctl.drain().unwrap();
        ctl.close();
    }
    h0.join().unwrap().unwrap();
    h1.join().unwrap().unwrap();
    std::fs::remove_file(&path_b).ok();
}
