//! Session-API equivalence: runs built through the [`Session`] builder
//! must reproduce the engine cores — and each other — **bit-for-bit**,
//! across the sequential, threaded, and multi-process TCP engines (the
//! refactor moved plumbing, not numerics).

use pipegcn::coordinator::{threaded, trainer};
use pipegcn::exp::{self, RunOpts};
use pipegcn::runtime::native::NativeBackend;
use pipegcn::session::{Engine, Session};

fn bits(losses: &[f64]) -> Vec<u64> {
    losses.iter().map(|l| l.to_bits()).collect()
}

/// Sequential engine through the builder vs the engine core called
/// directly with identical inputs (the pre-refactor path).
#[test]
fn session_sequential_matches_trainer_core_bitwise() {
    let opts = RunOpts { epochs: 5, eval_every: 0, gamma: 0.9, ..Default::default() };
    let (_p, g, pt, cfg) = exp::try_prepare("tiny", 3, "pipegcn-gf", opts).unwrap();
    let mut b = NativeBackend::new();
    let want = trainer::train_resumable(&g, &pt, &cfg, &mut b, None, None, None).unwrap();

    let report = Session::preset("tiny")
        .parts(3)
        .variant("pipegcn-gf")
        .gamma(0.9)
        .epochs(5)
        .eval_every(0)
        .run()
        .unwrap();
    assert_eq!(report.engine, "sequential");
    assert_eq!(report.start_epoch, 0);
    assert_eq!(report.losses.len(), 5);
    let want_losses: Vec<f64> = want.curve.iter().map(|e| e.train_loss).collect();
    assert_eq!(bits(&want_losses), bits(&report.losses));
    // the sequential engine carries the full result for the simulator
    let train = report.train.as_ref().expect("sequential engine captures TrainResult");
    assert!(train.works[0].fwd[0].total() > 0.0);
    assert_eq!(report.final_test.to_bits(), want.final_test.to_bits());
}

/// Threaded engine through the builder vs the sequential engine — and
/// vs the threaded engine core called directly.
#[test]
fn session_threaded_matches_sequential_bitwise() {
    let build = || {
        Session::preset("tiny").parts(3).variant("pipegcn").epochs(5).eval_every(0)
    };
    let seq = build().run().unwrap();
    let thr = build().engine(Engine::Threaded).run().unwrap();
    assert_eq!(thr.engine, "threaded");
    assert_eq!(bits(&seq.losses), bits(&thr.losses));
    assert!(thr.params.is_some(), "threaded engine returns final params");
    assert!(thr.comm_bytes > 0);

    let opts = RunOpts { epochs: 5, eval_every: 0, ..Default::default() };
    let (_p, g, pt, cfg) = exp::try_prepare("tiny", 3, "pipegcn", opts).unwrap();
    let core = threaded::run_threaded_ctl(&g, &pt, &cfg, threaded::ThreadedCtl::default())
        .unwrap()
        .0;
    assert_eq!(bits(&core.losses), bits(&thr.losses));
}

/// TCP engine through the builder: real worker *processes* over
/// localhost sockets, loss curve bit-identical to the sequential engine.
/// (`.binary(..)` points the launcher at the CLI — the test harness
/// binary is not `pipegcn`.)
#[test]
fn session_tcp_matches_sequential_bitwise() {
    let seq = Session::preset("tiny")
        .parts(2)
        .variant("pipegcn")
        .epochs(3)
        .eval_every(0)
        .run()
        .unwrap();
    let tcp = Session::preset("tiny")
        .parts(2)
        .variant("pipegcn")
        .epochs(3)
        .engine(Engine::Tcp { max_restarts: 0 })
        .binary(env!("CARGO_BIN_EXE_pipegcn"))
        .run()
        .unwrap();
    assert_eq!(tcp.engine, "tcp");
    assert_eq!(tcp.start_epoch, 0);
    assert_eq!(bits(&seq.losses), bits(&tcp.losses));
    assert!(tcp.comm_bytes > 0, "rank 0 sent payload over TCP");
    assert!(tcp.wire_bytes > tcp.comm_bytes, "framing overhead is on the wire");
    assert!(tcp.final_test > 0.0);
}

/// A graph-source Session (explicit graph + partitioning + config) runs
/// the local engines and matches the preset-source run that used the
/// same inputs.
#[test]
fn session_graph_source_matches_preset_source() {
    let opts = RunOpts { epochs: 4, eval_every: 0, ..Default::default() };
    let (_p, g, pt, cfg) = exp::try_prepare("tiny", 2, "pipegcn", opts).unwrap();
    let from_preset = Session::preset("tiny")
        .parts(2)
        .variant("pipegcn")
        .epochs(4)
        .eval_every(0)
        .run()
        .unwrap();
    for engine in [Engine::Sequential, Engine::Threaded] {
        let report = Session::graph(g.clone(), pt.clone(), cfg.clone())
            .engine(engine)
            .run()
            .unwrap();
        assert_eq!(bits(&from_preset.losses), bits(&report.losses));
    }
}

/// `.gamma()` on a graph-source Session must override the smoothing
/// decay baked into the TrainConfig even when `.variant()` is not set.
#[test]
fn session_graph_source_gamma_override_applies() {
    let opts = RunOpts { epochs: 4, eval_every: 0, gamma: 0.9, ..Default::default() };
    let (_p, g, pt, cfg) = exp::try_prepare("tiny", 2, "pipegcn-gf", opts).unwrap();
    let want = Session::preset("tiny")
        .parts(2)
        .variant("pipegcn-gf")
        .gamma(0.5)
        .epochs(4)
        .eval_every(0)
        .run()
        .unwrap();
    // cfg carries gamma 0.9; the builder's 0.5 must win
    let got = Session::graph(g, pt, cfg).gamma(0.5).run().unwrap();
    assert_eq!(bits(&want.losses), bits(&got.losses));
}

/// The builder's validation errors carry the valid-value lists
/// (satellite: `Variant::parse` returns `Err` with the known methods).
#[test]
fn session_errors_carry_valid_value_lists() {
    let e = Session::preset("tiny").variant("nope").epochs(1).run().unwrap_err();
    let msg = e.to_string();
    for name in ["gcn", "pipegcn", "pipegcn-g", "pipegcn-f", "pipegcn-gf"] {
        assert!(msg.contains(name), "'{msg}' misses '{name}'");
    }
    let e = Session::preset("nope").epochs(1).run().unwrap_err();
    assert!(e.to_string().contains("unknown preset"), "{e}");
    let e = Session::preset("tiny").parts(0).epochs(1).run().unwrap_err();
    assert!(e.to_string().contains("at least 1"), "{e}");
    // and the tcp engine validates before spawning any worker
    let e = Session::preset("tiny")
        .variant("nope")
        .engine(Engine::Tcp { max_restarts: 0 })
        .binary(env!("CARGO_BIN_EXE_pipegcn"))
        .run()
        .unwrap_err();
    assert!(e.to_string().contains("pipegcn-gf"), "{e}");
}
