//! Shared conformance suite for the nonblocking, handle-based
//! [`Transport`] contract: both implementations — the in-process
//! `Fabric` and `TcpTransport` over real sockets — must behave
//! identically under `post_recv`/`try_take`/`wait`, FIFO per tag, drops
//! without a wait, and byte accounting. A regression test also pins the
//! prefetched schedule's NDJSON trace rows (per-(layer, phase)
//! `comm_wait` breakdown summing to `comm_wait_ms`, plus
//! `overlap_ratio`).

use pipegcn::comm::{Fabric, Phase, Tag, Transport, WaitStats};
use pipegcn::net::chaos::ChaosProfile;
use pipegcn::net::rendezvous::ConnectOpts;
use pipegcn::net::{localhost_mesh, localhost_mesh_with};
use pipegcn::session::{Engine, Session};
use pipegcn::util::json::{parse_ndjson, Json};
use std::time::Duration;

/// Run the suite with `sender` sending as rank `src` and `receiver`
/// receiving as rank `dst` (the same object for the Fabric; two mesh
/// endpoints for TCP). Every check drains what it sends, so the caller
/// can assert `pending() == 0` afterwards.
fn conformance(sender: &dyn Transport, receiver: &dyn Transport, src: usize, dst: usize) {
    let tag = |iter: u32, layer: u16| Tag::new(iter, layer, Phase::FwdFeat);

    // -- post before send: try_take stays None, wait claims the payload
    let mut h = receiver.post_recv(src, dst, tag(1, 0));
    assert_eq!(h.src(), src);
    assert_eq!(h.dst(), dst);
    assert_eq!(h.tag(), tag(1, 0));
    assert_eq!(h.try_take(), None, "nothing sent yet");
    sender.send(src, dst, tag(1, 0), vec![1.0, 2.0]);
    let mut st = WaitStats::default();
    assert_eq!(h.wait(&mut st), vec![1.0, 2.0]);
    assert_eq!(st.hidden() + st.exposed(), 1, "exactly one receive waited");

    // -- wait parks across threads until the send lands
    let h = receiver.post_recv(src, dst, tag(2, 0));
    std::thread::scope(|s| {
        let waiter = s.spawn(move || {
            let mut st = WaitStats::default();
            let v = h.wait(&mut st);
            (v, st)
        });
        std::thread::sleep(Duration::from_millis(20));
        sender.send(src, dst, tag(2, 0), vec![3.0]);
        let (v, st) = waiter.join().unwrap();
        assert_eq!(v, vec![3.0]);
        assert_eq!(st.hidden() + st.exposed(), 1);
    });

    // -- FIFO per tag, interleaved with another tag
    let t3 = tag(3, 0);
    let other = Tag::new(3, 0, Phase::BwdGrad);
    sender.send(src, dst, t3, vec![10.0]);
    sender.send(src, dst, other, vec![99.0]);
    sender.send(src, dst, t3, vec![20.0]);
    let mut st = WaitStats::default();
    assert_eq!(receiver.post_recv(src, dst, t3).wait(&mut st), vec![10.0]);
    assert_eq!(receiver.post_recv(src, dst, t3).wait(&mut st), vec![20.0]);
    assert_eq!(receiver.post_recv(src, dst, other).wait(&mut st), vec![99.0]);

    // -- reservations posted before any send are served in post order
    let t4 = tag(4, 0);
    let h1 = receiver.post_recv(src, dst, t4);
    let h2 = receiver.post_recv(src, dst, t4);
    sender.send(src, dst, t4, vec![1.0]);
    sender.send(src, dst, t4, vec![2.0]);
    let mut st = WaitStats::default();
    assert_eq!(h1.wait(&mut st), vec![1.0]);
    assert_eq!(h2.wait(&mut st), vec![2.0]);

    // -- a handle dropped while still pending leaks nothing: the next
    //    send is delivered normally
    let t5 = tag(5, 0);
    drop(receiver.post_recv(src, dst, t5));
    sender.send(src, dst, t5, vec![7.5]);
    assert_eq!(receiver.recv_blocking(src, dst, t5), vec![7.5]);

    // -- a handle dropped *fulfilled but untaken* requeues its payload
    //    at the head of the FIFO (no message ever lost). The fence tag
    //    exploits same-channel FIFO: once it arrives, both t6 payloads
    //    have been delivered on the receiver side.
    let t6 = tag(6, 0);
    let fence = tag(6, 1);
    sender.send(src, dst, t6, vec![1.25]);
    sender.send(src, dst, t6, vec![2.25]);
    sender.send(src, dst, fence, vec![0.0]);
    assert_eq!(receiver.recv_blocking(src, dst, fence), vec![0.0]);
    drop(receiver.post_recv(src, dst, t6)); // claims 1.25, never takes it
    assert_eq!(receiver.recv_blocking(src, dst, t6), vec![1.25]);
    assert_eq!(receiver.recv_blocking(src, dst, t6), vec![2.25]);

    // -- a fulfilled handle dropped while a *sibling* reservation is
    //    still pending must hand its payload to that sibling (the
    //    transport only fulfills each message once, so a requeue that
    //    ignored pending reservations would strand the sibling forever)
    let t65 = tag(6, 5);
    let fence65 = tag(6, 6);
    sender.send(src, dst, t65, vec![3.75]);
    sender.send(src, dst, fence65, vec![0.0]);
    assert_eq!(receiver.recv_blocking(src, dst, fence65), vec![0.0]);
    let h_old = receiver.post_recv(src, dst, t65); // claims 3.75
    let h_next = receiver.post_recv(src, dst, t65); // pending sibling
    drop(h_old);
    let mut st = WaitStats::default();
    assert_eq!(h_next.wait(&mut st), vec![3.75]);

    // -- several fulfilled handles dropped untaken, in any order,
    //    restore exact send order (payloads carry delivery sequence
    //    numbers, so recovery is position-preserving, not head-insert)
    let t67 = tag(6, 7);
    let fence67 = tag(6, 8);
    sender.send(src, dst, t67, vec![1.0]);
    sender.send(src, dst, t67, vec![2.0]);
    sender.send(src, dst, fence67, vec![0.0]);
    assert_eq!(receiver.recv_blocking(src, dst, fence67), vec![0.0]);
    let h1 = receiver.post_recv(src, dst, t67); // claims 1.0
    let h2 = receiver.post_recv(src, dst, t67); // claims 2.0
    drop(h1);
    drop(h2);
    assert_eq!(receiver.recv_blocking(src, dst, t67), vec![1.0]);
    assert_eq!(receiver.recv_blocking(src, dst, t67), vec![2.0]);

    // -- bytes accounting: sends are charged 4 bytes per f32 regardless
    //    of how (or whether) the receive side claims them
    let before = sender.bytes_sent(src);
    let t7 = tag(7, 0);
    sender.send(src, dst, t7, vec![0.0; 25]);
    assert_eq!(sender.bytes_sent(src) - before, 100);
    assert_eq!(receiver.recv_blocking(src, dst, t7).len(), 25);
    assert_eq!(sender.bytes_sent(src) - before, 100, "receives never change accounting");

    // -- WaitStats attribution: a payload that arrived before the wait
    //    counts as hidden, under the handle's (layer, phase) key
    let t8 = Tag::new(8, 2, Phase::BwdGrad);
    let fence2 = tag(8, 9);
    sender.send(src, dst, t8, vec![5.0]);
    sender.send(src, dst, fence2, vec![0.0]);
    assert_eq!(receiver.recv_blocking(src, dst, fence2), vec![0.0]);
    let mut st = WaitStats::default();
    let h = receiver.post_recv(src, dst, t8); // fulfilled at post time
    assert_eq!(h.wait(&mut st), vec![5.0]);
    assert_eq!(st.hidden(), 1, "a pre-arrived payload is a hidden receive");
    assert_eq!(st.exposed(), 0);
    assert!(st.entries_ms().iter().any(|(k, _)| k == "bwd_l2"), "{:?}", st.entries_ms());

    // -- recv_blocking is the default-method shim over post_recv + wait
    let t9 = tag(9, 0);
    sender.send(src, dst, t9, vec![4.5]);
    assert_eq!(receiver.recv_blocking(src, dst, t9), vec![4.5]);
}

#[test]
fn fabric_satisfies_the_transport_conformance_suite() {
    let f = Fabric::new(2);
    conformance(&f, &f, 0, 1);
    assert_eq!(f.pending(), 0, "the suite must drain everything it sends");
}

#[test]
fn tcp_satisfies_the_transport_conformance_suite() {
    let mut mesh = localhost_mesh(2).unwrap();
    conformance(&mesh[0], &mesh[1], 0, 1);
    assert_eq!(mesh[1].pending(), 0, "the suite must drain everything it sends");
    for m in &mut mesh {
        m.shutdown();
    }
}

/// The whole contract — FIFO per tag, drop recovery, byte accounting —
/// must survive an actively hostile wire. The chaos injector delays and
/// "drops" (withholds for an RTO, then retransmits) frames on the writer
/// path; none of that may reorder a link, lose a message, or change what
/// the sender's accounting says went out. Several seeds, so different
/// drop patterns all hold.
#[test]
fn tcp_satisfies_the_conformance_suite_under_chaos() {
    for seed in [1u64, 2, 7] {
        let profile = ChaosProfile::parse(&format!(
            r#"{{"seed": {seed},
                 "default": {{"latency_ms": 1, "jitter_ms": 2, "drop": 0.2, "rto_ms": 3}}}}"#
        ))
        .unwrap();
        let opts = ConnectOpts { chaos: Some(profile), ..ConnectOpts::default() };
        let mut mesh = localhost_mesh_with(2, &opts).unwrap();
        let wire_before = mesh[0].wire_bytes_sent();
        conformance(&mesh[0], &mesh[1], 0, 1);
        assert_eq!(mesh[1].pending(), 0, "seed {seed}: the suite must drain everything");
        assert!(
            mesh[0].wire_bytes_sent() > wire_before,
            "seed {seed}: chaos never suppresses a frame — every send hits the wire"
        );
        for m in &mut mesh {
            m.shutdown();
        }
    }
}

/// Regression for the per-layer overlap traces: every epoch row rank 0
/// streams under the prefetched schedule must carry a `comm_wait`
/// breakdown whose keys sum to `comm_wait_ms`, plus an `overlap_ratio`.
#[test]
fn prefetched_schedule_log_rows_carry_comm_wait_breakdown() {
    let path = format!("/tmp/pipegcn_overlap_rows_{}.ndjson", std::process::id());
    let _ = std::fs::remove_file(&path);
    let report = Session::preset("tiny")
        .parts(3)
        .variant("pipegcn")
        .epochs(4)
        .log(&path)
        .engine(Engine::Threaded)
        .run()
        .unwrap();
    assert!(report.comm_wait_ms >= 0.0);
    assert!((0.0..=1.0).contains(&report.overlap_ratio), "{}", report.overlap_ratio);
    let rows = parse_ndjson(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert_eq!(rows.len(), 1 + 4, "header + one row per epoch");
    for row in &rows[1..] {
        let total = row.get("comm_wait_ms").unwrap().as_f64().unwrap();
        let Some(Json::Obj(pairs)) = row.get("comm_wait") else {
            panic!("missing comm_wait breakdown in {row:?}")
        };
        assert!(!pairs.is_empty(), "empty breakdown");
        let keys: Vec<&str> = pairs.iter().map(|(k, _)| k.as_str()).collect();
        assert!(keys.iter().any(|k| k.starts_with("fwd_l")), "{keys:?}");
        assert!(keys.contains(&"reduce"), "{keys:?}");
        let sum: f64 = pairs.iter().map(|(_, v)| v.as_f64().unwrap()).sum();
        assert!(
            (sum - total).abs() <= 1e-9 * total.max(1.0),
            "breakdown keys sum to {sum}, comm_wait_ms says {total}"
        );
        let r = row.get("overlap_ratio").unwrap().as_f64().unwrap();
        assert!((0.0..=1.0).contains(&r), "overlap_ratio {r}");
        let epoch_ms = row.get("epoch_ms").unwrap().as_f64().unwrap();
        let comp_ms = row.get("comp_ms").unwrap().as_f64().unwrap();
        assert!(comp_ms <= epoch_ms + 1e-9, "comp {comp_ms} > epoch {epoch_ms}");
    }
    std::fs::remove_file(&path).ok();
}
