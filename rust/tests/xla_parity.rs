//! Integration: the XLA/PJRT backend (AOT JAX+Pallas artifacts) must be
//! numerically interchangeable with the native Rust backend — per-op and
//! across a whole training run.
//!
//! Requires `make artifacts`; tests skip (with a notice) if the artifact
//! directory is absent so `cargo test` stays green pre-build.

use pipegcn::coordinator::{trainer, Optimizer, TrainConfig, Variant};
use pipegcn::graph::presets;
use pipegcn::model::{ModelConfig, Params};
use pipegcn::partition::{partition, Method};
use pipegcn::runtime::{native::NativeBackend, xla::XlaBackend, Backend};
use pipegcn::tensor::{Csr, Mat};
use pipegcn::util::rng::Rng;

fn artifacts_dir() -> Option<String> {
    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    if std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: {dir}/manifest.json missing — run `make artifacts`");
        None
    }
}

fn random_prop(rng: &mut Rng, rows: usize, cols: usize, density: f32) -> Csr {
    let mut trip = Vec::new();
    for r in 0..rows {
        trip.push((r as u32, r as u32, 0.3));
        for c in 0..cols {
            if rng.bernoulli(density) {
                trip.push((r as u32, c as u32, rng.next_f32()));
            }
        }
    }
    Csr::from_triplets(rows, cols, trip)
}

#[test]
fn xla_layer_ops_match_native() {
    let Some(dir) = artifacts_dir() else { return };
    let mut xla = XlaBackend::from_artifacts(&dir).expect("load artifacts");
    let mut native = NativeBackend::new();
    let mut rng = Rng::new(42);
    for &(f_in, f_out) in &xla.layer_configs().clone() {
        for &(inner, halo) in &[(64usize, 32usize), (320, 256), (7, 3)] {
            let prop = random_prop(&mut rng, inner, inner + halo, 0.05);
            let h = Mat::randn(inner + halo, f_in, 1.0, &mut rng);
            let wn = Mat::randn(f_in, f_out, 0.5, &mut rng);
            let ws = Mat::randn(f_in, f_out, 0.5, &mut rng);
            let px = xla.register_prop(&prop);
            let pn = native.register_prop(&prop);
            // forward parity
            let fx = xla.layer_fwd(px, &h, Some(&ws), &wn);
            let fnat = native.layer_fwd(pn, &h, Some(&ws), &wn);
            pipegcn::util::prop::assert_close(&fx.z_agg.data, &fnat.z_agg.data, 1e-4)
                .unwrap_or_else(|e| panic!("z ({f_in},{f_out},{inner}): {e}"));
            pipegcn::util::prop::assert_close(&fx.pre.data, &fnat.pre.data, 1e-4)
                .unwrap_or_else(|e| panic!("pre ({f_in},{f_out},{inner}): {e}"));
            // backward parity
            let m = Mat::randn(inner, f_out, 1.0, &mut rng);
            let bx = xla.layer_bwd(px, &h, &fx.z_agg, &m, Some(&ws), &wn, true);
            let bn = native.layer_bwd(pn, &h, &fnat.z_agg, &m, Some(&ws), &wn, true);
            pipegcn::util::prop::assert_close(&bx.g_neigh.data, &bn.g_neigh.data, 1e-4)
                .unwrap_or_else(|e| panic!("g_neigh: {e}"));
            pipegcn::util::prop::assert_close(
                &bx.g_self.as_ref().unwrap().data,
                &bn.g_self.as_ref().unwrap().data,
                1e-4,
            )
            .unwrap_or_else(|e| panic!("g_self: {e}"));
            pipegcn::util::prop::assert_close(
                &bx.j_full.as_ref().unwrap().data,
                &bn.j_full.as_ref().unwrap().data,
                1e-4,
            )
            .unwrap_or_else(|e| panic!("j_full: {e}"));
        }
    }
}

#[test]
fn xla_gcn_mode_zero_self_weight() {
    let Some(dir) = artifacts_dir() else { return };
    let mut xla = XlaBackend::from_artifacts(&dir).expect("load artifacts");
    let mut native = NativeBackend::new();
    let mut rng = Rng::new(7);
    let (f_in, f_out) = xla.layer_configs()[0];
    let prop = random_prop(&mut rng, 40, 60, 0.1);
    let h = Mat::randn(60, f_in, 1.0, &mut rng);
    let wn = Mat::randn(f_in, f_out, 0.5, &mut rng);
    let px = xla.register_prop(&prop);
    let pn = native.register_prop(&prop);
    let fx = xla.layer_fwd(px, &h, None, &wn);
    let fnat = native.layer_fwd(pn, &h, None, &wn);
    pipegcn::util::prop::assert_close(&fx.pre.data, &fnat.pre.data, 1e-4).unwrap();
    let m = Mat::randn(40, f_out, 1.0, &mut rng);
    let bx = xla.layer_bwd(px, &h, &fx.z_agg, &m, None, &wn, true);
    assert!(bx.g_self.is_none());
}

/// Whole-training parity: the tiny preset trained end-to-end through the
/// XLA backend must match the native backend loss curve (same seeds, SGD
/// to avoid Adam's noise amplification) and reach the same accuracy.
#[test]
fn xla_training_run_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let p = presets::by_name("tiny").unwrap();
    let g = p.build(42);
    let pt = partition(&g, 2, Method::Multilevel, 1);
    let cfg = TrainConfig {
        model: ModelConfig::sage(g.feat_dim(), 32, 2, g.labels.n_classes(), 0.0),
        variant: Variant::Vanilla,
        optimizer: Optimizer::Sgd,
        lr: 0.05,
        epochs: 5,
        seed: 9,
        eval_every: 0,
        probe_errors: false,
    };
    let mut nat = NativeBackend::new();
    let r_native = trainer::train_resumable(&g, &pt, &cfg, &mut nat, None, None, None).unwrap();
    let mut xla = XlaBackend::from_artifacts(&dir).expect("load artifacts");
    let r_xla = trainer::train_resumable(&g, &pt, &cfg, &mut xla, None, None, None).unwrap();
    for (a, b) in r_native.curve.iter().zip(&r_xla.curve) {
        assert!(
            (a.train_loss - b.train_loss).abs() < 1e-3,
            "epoch {}: native {} vs xla {}",
            a.epoch,
            a.train_loss,
            b.train_loss
        );
    }
}

/// Params must be shape-compatible with the quickstart artifacts.
#[test]
fn artifact_manifest_covers_tiny_model() {
    let Some(dir) = artifacts_dir() else { return };
    let xla = XlaBackend::from_artifacts(&dir).expect("load artifacts");
    let p = presets::by_name("tiny").unwrap();
    let cfg = ModelConfig::sage(p.feat_dim, p.hidden, p.layers, p.n_classes, 0.0);
    let mut rng = Rng::new(1);
    let params = Params::init(&cfg, &mut rng);
    let configs = xla.layer_configs();
    for lp in &params.layers {
        assert!(
            configs.contains(&(lp.w_neigh.rows, lp.w_neigh.cols)),
            "missing artifact for ({}, {})",
            lp.w_neigh.rows,
            lp.w_neigh.cols
        );
    }
}
