//! Scale-path integration tests: sharded dataset construction
//! (per-shard concatenation == monolithic build), the multilevel
//! partitioner's quality edge over the simple hash baseline, and the
//! per-rank lazy Tcp training path.

use pipegcn::graph::presets::{self, PRESETS};
use pipegcn::graph::{Labels, Topology};
use pipegcn::partition::{partition_adj, quality_adj, Method};
use pipegcn::session::{Engine, Session};

fn bits(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Concatenating per-shard subgraphs over parts ∈ {1, 2, 4, 8} yields
/// the identical edge set and feature/label/mask bits as the monolithic
/// build at the same seed — on the canonical stream (tiny at its preset
/// n), a scaled single-label preset, and a scaled multi-label preset.
#[test]
fn shard_concat_matches_monolithic_build() {
    for (preset_name, n) in [("tiny", 512usize), ("products-sim", 1200), ("yelp-sim", 900)] {
        let p = presets::by_name(preset_name).unwrap();
        let mono = p.build_scaled(n, 7);
        let topo = p.build_topology_scaled(n, 7);
        assert_eq!(topo.indptr, mono.indptr, "{preset_name}: topology indptr");
        assert_eq!(topo.indices, mono.indices, "{preset_name}: topology indices");
        for parts in [1usize, 2, 4, 8] {
            let pt = partition_adj(topo.adj(), parts, Method::Hash, 7);
            let mut train = Vec::new();
            let mut val = Vec::new();
            let mut test = Vec::new();
            let mut edge_union: Vec<(u32, u32)> = Vec::new();
            let mut covered = vec![false; n];
            for part in 0..parts {
                let sh = p.build_shard_scaled(n, 7, &pt.assign, part as u32);
                assert_eq!(sh.n, n);
                assert_eq!(sh.total_train, mono.train_mask.len());
                for (i, &v) in sh.owned.iter().enumerate() {
                    assert!(!covered[v as usize], "node {v} owned twice");
                    covered[v as usize] = true;
                    let got: Vec<u32> = sh.features.row(i).iter().map(|x| x.to_bits()).collect();
                    let want: Vec<u32> =
                        mono.features.row(v as usize).iter().map(|x| x.to_bits()).collect();
                    assert_eq!(
                        got, want,
                        "{preset_name} n={n} parts={parts} node {v} feature bits"
                    );
                }
                match (&sh.labels, &mono.labels) {
                    (Labels::Single { labels: sl, .. }, Labels::Single { labels: ml, .. }) => {
                        for (i, &v) in sh.owned.iter().enumerate() {
                            assert_eq!(sl[i], ml[v as usize], "node {v} label");
                        }
                    }
                    (Labels::Multi { targets: st }, Labels::Multi { targets: mt }) => {
                        for (i, &v) in sh.owned.iter().enumerate() {
                            assert_eq!(st.row(i), mt.row(v as usize), "node {v} targets");
                        }
                    }
                    _ => panic!("{preset_name}: label kinds diverge between shard and mono"),
                }
                train.extend_from_slice(&sh.train_mask);
                val.extend_from_slice(&sh.val_mask);
                test.extend_from_slice(&sh.test_mask);
                edge_union.extend_from_slice(&sh.edges);
            }
            assert!(covered.iter().all(|&c| c), "every node owned by some shard");
            for m in [&mut train, &mut val, &mut test] {
                m.sort_unstable();
            }
            assert_eq!(train, mono.train_mask, "{preset_name} parts={parts} train mask");
            assert_eq!(val, mono.val_mask, "{preset_name} parts={parts} val mask");
            assert_eq!(test, mono.test_mask, "{preset_name} parts={parts} test mask");
            // raw sampled edges with an owned endpoint, unioned over the
            // shards, rebuild the exact global CSR structure
            let rebuilt = Topology::from_edges(n, &edge_union);
            assert_eq!(rebuilt.indptr, mono.indptr, "{preset_name} parts={parts} edges");
            assert_eq!(rebuilt.indices, mono.indices, "{preset_name} parts={parts} edges");
        }
    }
}

/// Regression guard for the default partitioner: multilevel's edge cut
/// beats the simple hash baseline on every preset (structure-aware
/// coarsening vs a random split). Big presets are exercised at a scaled
/// node count that still gives every community a few members.
#[test]
fn multilevel_beats_simple_hash_on_every_preset() {
    for p in &PRESETS {
        let n = p.n.min((p.communities * 4).max(600));
        let topo = p.build_topology_scaled(n, 1);
        let parts = 4;
        let ml = partition_adj(topo.adj(), parts, Method::Multilevel, 1);
        let hs = partition_adj(topo.adj(), parts, Method::Hash, 1);
        let qm = quality_adj(topo.adj(), &ml);
        let qh = quality_adj(topo.adj(), &hs);
        assert!(
            qm.edge_cut < qh.edge_cut,
            "{} (n={n}): multilevel edge_cut {} not below simple hash {}",
            p.name,
            qm.edge_cut,
            qh.edge_cut
        );
    }
}

/// Tentpole oracle: a scaled Tcp mesh — every rank lazily building only
/// its own shard from `(seed, part, parts)`, no process ever holding the
/// full graph — trains bit-identically to the sequential engine over the
/// fully materialized scaled graph.
#[test]
fn scaled_tcp_matches_sequential_bitwise() {
    let seq = Session::preset("tiny")
        .parts(2)
        .variant("pipegcn")
        .epochs(3)
        .eval_every(0)
        .scale(700)
        .run()
        .unwrap();
    let tcp = Session::preset("tiny")
        .parts(2)
        .variant("pipegcn")
        .epochs(3)
        .scale(700)
        .engine(Engine::Tcp { max_restarts: 0 })
        .binary(env!("CARGO_BIN_EXE_pipegcn"))
        .run()
        .unwrap();
    assert_eq!(seq.losses.len(), 3);
    assert_eq!(bits(&seq.losses), bits(&tcp.losses));
    // scaled workers never hold the full graph, so they skip the
    // full-graph evaluation pass and report NaN metrics
    assert!(tcp.final_val.is_nan());
    assert!(tcp.final_test.is_nan());
    assert!(tcp.comm_bytes > 0);
}

/// The simple hash partitioner stays reachable behind its flag and
/// produces a different (worse) mesh than the multilevel default, while
/// both remain bit-deterministic in the seed.
#[test]
fn partitioner_flag_selects_hash() {
    let a = Session::preset("tiny")
        .parts(4)
        .variant("pipegcn")
        .epochs(2)
        .eval_every(0)
        .partitioner("simple")
        .run()
        .unwrap();
    let b = Session::preset("tiny")
        .parts(4)
        .variant("pipegcn")
        .epochs(2)
        .eval_every(0)
        .partitioner("simple")
        .run()
        .unwrap();
    assert_eq!(bits(&a.losses), bits(&b.losses), "hash partitioner is deterministic");
    let q_hash = a.quality.expect("local run reports quality");
    let q_ml = Session::preset("tiny")
        .parts(4)
        .variant("pipegcn")
        .epochs(2)
        .eval_every(0)
        .run()
        .unwrap()
        .quality
        .expect("local run reports quality");
    assert!(q_ml.edge_cut < q_hash.edge_cut, "multilevel default beats simple hash");
}
