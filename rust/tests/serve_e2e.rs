//! End-to-end tests of the serving path: params artifact → server →
//! client, in-process and through the real CLI binaries.
//!
//! The acceptance oracle: logits answered by `pipegcn serve` over TCP
//! are **bit-identical** to [`full_graph_forward`] on the same params —
//! the serving path reuses the training kernels, so there is exactly one
//! forward semantics in the crate.

use pipegcn::ckpt;
use pipegcn::coordinator::{forward_with_features, full_graph_forward};
use pipegcn::graph::presets;
use pipegcn::model::{artifact, ModelConfig, Params};
use pipegcn::runtime::native::NativeBackend;
use pipegcn::serve::{Client, Server};
use pipegcn::session::Session;
use pipegcn::tensor::Mat;
use pipegcn::util::json::Json;
use pipegcn::util::rng::Rng;

fn tiny_model() -> (pipegcn::graph::Graph, ModelConfig, Params) {
    let p = presets::by_name("tiny").unwrap();
    let g = p.build(1);
    let cfg = ModelConfig::from_preset(p);
    let params = Params::init(&cfg, &mut Rng::new(3));
    (g, cfg, params)
}

/// Spawn a server accepting `conns` connections and return its address
/// plus the join handle.
fn spawn_server(
    g: pipegcn::graph::Graph,
    cfg: ModelConfig,
    params: Params,
    conns: usize,
) -> (String, std::thread::JoinHandle<pipegcn::util::error::Result<()>>) {
    let server = Server::from_parts(g, cfg, params).unwrap();
    let addr = server.addr().to_string();
    let handle = std::thread::spawn(move || server.run(Some(conns)));
    (addr, handle)
}

#[test]
fn serve_logits_bit_identical_to_full_graph_forward() {
    let (g, cfg, params) = tiny_model();
    let mut b = NativeBackend::new();
    let want = full_graph_forward(&g, &params, cfg.kind, &mut b);
    let expect_version = artifact::content_version(&artifact::ParamsFile {
        config: cfg.clone(),
        params: params.clone(),
    });

    let (addr, handle) = spawn_server(g, cfg, params, 1);
    let mut client = Client::connect(&addr).unwrap();
    assert_eq!(client.artifact_version(), None, "no stamp before the first query");
    // a scattered batch…
    let ids: Vec<u32> = vec![0, 5, 17, 511];
    let got = client.query(&ids).unwrap();
    // every v2 response is stamped with the serving artifact's version
    assert_eq!(client.artifact_version(), Some(expect_version));
    assert_eq!((got.rows, got.cols), (ids.len(), want.cols));
    for (i, &id) in ids.iter().enumerate() {
        for (a, b) in got.row(i).iter().zip(want.row(id as usize)) {
            assert_eq!(a.to_bits(), b.to_bits(), "node {id}");
        }
    }
    // …and the full graph, on the same connection
    let all: Vec<u32> = (0..want.rows as u32).collect();
    let got = client.query(&all).unwrap();
    for r in 0..want.rows {
        for (a, b) in got.row(r).iter().zip(want.row(r)) {
            assert_eq!(a.to_bits(), b.to_bits(), "node {r}");
        }
    }
    client.close();
    handle.join().unwrap().unwrap();
}

/// Stamp negotiation is backward compatible: a client that sends the
/// old (v1) hello gets unstamped responses with the exact same logits
/// bits, so pre-tier clients keep parsing against a tier server.
#[test]
fn v1_clients_still_parse_unstamped_responses() {
    let (g, cfg, params) = tiny_model();
    let mut b = NativeBackend::new();
    let want = full_graph_forward(&g, &params, cfg.kind, &mut b);
    let (addr, handle) = spawn_server(g, cfg, params, 1);
    let mut client = Client::connect_v1(&addr).unwrap();
    let ids: Vec<u32> = vec![2, 7];
    let got = client.query(&ids).unwrap();
    assert_eq!(client.artifact_version(), None, "v1 responses carry no stamp");
    assert_eq!((got.rows, got.cols), (ids.len(), want.cols));
    for (i, &id) in ids.iter().enumerate() {
        for (a, b) in got.row(i).iter().zip(want.row(id as usize)) {
            assert_eq!(a.to_bits(), b.to_bits(), "node {id}");
        }
    }
    client.close();
    handle.join().unwrap().unwrap();
}

/// The online scenario: a query shipping fresh features for its batch
/// gets logits computed from those features (bit-identical to a local
/// forward over the patched feature matrix).
#[test]
fn serve_feature_override_matches_local_forward() {
    let (g, cfg, params) = tiny_model();
    let ids: Vec<u32> = vec![3, 9];
    let mut rng = Rng::new(8);
    let fresh = Mat::randn(ids.len(), g.feat_dim(), 1.0, &mut rng);
    let mut patched = g.features.clone();
    for (i, &id) in ids.iter().enumerate() {
        patched.set_row(id as usize, fresh.row(i));
    }
    let mut b = NativeBackend::new();
    let want = forward_with_features(&g, &params, cfg.kind, &mut b, &patched);

    let (addr, handle) = spawn_server(g, cfg, params, 1);
    let mut client = Client::connect(&addr).unwrap();
    let got = client.query_with_features(&ids, &fresh).unwrap();
    for (i, &id) in ids.iter().enumerate() {
        for (a, b) in got.row(i).iter().zip(want.row(id as usize)) {
            assert_eq!(a.to_bits(), b.to_bits(), "node {id}");
        }
    }
    client.close();
    handle.join().unwrap().unwrap();
}

/// Session-trained checkpoint → export_from_ckpt → artifact roundtrip →
/// served logits equal the forward on the exported params.
#[test]
fn export_params_from_training_checkpoint_serves_trained_model() {
    let base = format!("/tmp/pipegcn_serve_export_{}", std::process::id());
    let _ = std::fs::remove_dir_all(&base);
    let ckpt_dir = format!("{base}/ckpt");
    let report = Session::preset("tiny")
        .parts(2)
        .variant("pipegcn")
        .epochs(3)
        .eval_every(0)
        .ckpt(ckpt::Policy { dir: ckpt_dir.clone(), every: 1 })
        .run()
        .unwrap();
    assert_eq!(report.losses.len(), 3);

    let preset = presets::by_name("tiny").unwrap();
    let cfg = ModelConfig::from_preset(preset);
    let (pf, epoch) = artifact::export_from_ckpt(&ckpt_dir, 2, &cfg, None).unwrap();
    assert_eq!(epoch, 3);
    let path = format!("{base}/params.pgp");
    artifact::save(&path, &pf).unwrap();
    let loaded = artifact::load(&path).unwrap();
    assert_eq!(loaded, pf);

    // the served logits are the trained model's logits
    let g = preset.build(1); // training's default seed
    let mut b = NativeBackend::new();
    let want = full_graph_forward(&g, &loaded.params, loaded.config.kind, &mut b);
    let (addr, handle) = spawn_server(g, loaded.config, loaded.params, 1);
    let mut client = Client::connect(&addr).unwrap();
    let got = client.query(&[0, 100, 200]).unwrap();
    for (i, &id) in [0u32, 100, 200].iter().enumerate() {
        for (a, b) in got.row(i).iter().zip(want.row(id as usize)) {
            assert_eq!(a.to_bits(), b.to_bits(), "node {id}");
        }
    }
    client.close();
    handle.join().unwrap().unwrap();
    std::fs::remove_dir_all(&base).ok();
}

/// The full CLI flow, real binaries end to end:
/// `train --ckpt-dir` → `export-params` → `serve` → `query`.
#[test]
fn cli_train_export_serve_query_flow() {
    let bin = env!("CARGO_BIN_EXE_pipegcn");
    let base = format!("/tmp/pipegcn_serve_cli_{}", std::process::id());
    let _ = std::fs::remove_dir_all(&base);
    std::fs::create_dir_all(&base).unwrap();
    let ckpt_dir = format!("{base}/ckpt");
    let params_path = format!("{base}/params.pgp");
    let addr_file = format!("{base}/serve.addr");
    let report_path = format!("{base}/lat.ndjson");

    let status = std::process::Command::new(bin)
        .args([
            "train", "--dataset", "tiny", "--parts", "2", "--method", "pipegcn",
            "--epochs", "2", "--eval-every", "0", "--ckpt-every", "1",
        ])
        .args(["--ckpt-dir", &ckpt_dir])
        .status()
        .expect("running pipegcn train");
    assert!(status.success(), "train exited with {status}");

    let status = std::process::Command::new(bin)
        .args(["export-params", "--dataset", "tiny", "--parts", "2"])
        .args(["--from-ckpt", &ckpt_dir, "--out", &params_path])
        .status()
        .expect("running pipegcn export-params");
    assert!(status.success(), "export-params exited with {status}");

    // serve in a real process: 2 connections (our bit-check client, then
    // the CLI query client), then exit
    let mut serve = std::process::Command::new(bin)
        .args(["serve", "--dataset", "tiny", "--max-conns", "2"])
        .args(["--params", &params_path, "--addr-file", &addr_file])
        .spawn()
        .expect("spawning pipegcn serve");
    let addr = {
        let mut waited = 0u32;
        loop {
            if let Ok(a) = std::fs::read_to_string(&addr_file) {
                if !a.is_empty() {
                    break a;
                }
            }
            waited += 1;
            assert!(waited < 200, "serve never wrote its addr file");
            std::thread::sleep(std::time::Duration::from_millis(50));
        }
    };

    // bit-identity through the running server: logits equal the local
    // forward on the exported params
    let loaded = artifact::load(&params_path).unwrap();
    let g = presets::by_name("tiny").unwrap().build(1);
    let mut b = NativeBackend::new();
    let want = full_graph_forward(&g, &loaded.params, loaded.config.kind, &mut b);
    let mut client = Client::connect(&addr).unwrap();
    let ids: Vec<u32> = vec![0, 1, 2, 3];
    let got = client.query(&ids).unwrap();
    assert!(!got.data.is_empty(), "serve answered no logits");
    for (i, &id) in ids.iter().enumerate() {
        for (a, b) in got.row(i).iter().zip(want.row(id as usize)) {
            assert_eq!(a.to_bits(), b.to_bits(), "node {id}");
        }
    }
    client.close();

    let out = std::process::Command::new(bin)
        .args(["query", "--nodes", "0,1,2", "--repeat", "3"])
        .args(["--addr", &addr, "--report", &report_path])
        .output()
        .expect("running pipegcn query");
    assert!(out.status.success(), "query exited with {}", out.status);
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("ok:"), "query output: {stdout}");

    let rows =
        pipegcn::util::json::parse_ndjson(&std::fs::read_to_string(&report_path).unwrap())
            .unwrap();
    // header + 3 per-query rows + summary
    assert_eq!(rows.len(), 5);
    assert_eq!(rows[0].get("batch").and_then(Json::as_usize), Some(3));
    let summary = rows.last().unwrap();
    assert!(summary.get("p50_ms").and_then(Json::as_f64).unwrap() > 0.0);
    assert!(summary.get("qps").and_then(Json::as_f64).unwrap() > 0.0);

    let status = serve.wait().expect("waiting for serve");
    assert!(status.success(), "serve exited with {status}");
    std::fs::remove_dir_all(&base).ok();
}
