//! End-to-end tests of the `net` subsystem: the TCP transport must
//! reproduce the sequential and threaded engines bit-for-bit (the
//! dataflow is deterministic — staleness lives in message tags), both
//! with in-process transports over real sockets and with genuinely
//! separate OS processes via `pipegcn launch`.

use pipegcn::coordinator::{
    halo, threaded, trainer, Optimizer, PipeOpts, TrainConfig, Variant,
};
use pipegcn::exp::RunOpts;
use pipegcn::graph::presets;
use pipegcn::model::ModelConfig;
use pipegcn::net::localhost_mesh;
use pipegcn::partition::{partition, Method};
use pipegcn::runtime::native::NativeBackend;
use pipegcn::session::Session;
use pipegcn::util::json::Json;
use std::sync::Arc;

fn tiny_cfg(variant: Variant, dropout: f32, epochs: usize) -> (TrainConfig, usize) {
    let g = presets::by_name("tiny").unwrap().build(42);
    let cfg = TrainConfig {
        model: ModelConfig::sage(g.feat_dim(), 16, 2, g.labels.n_classes(), dropout),
        variant,
        optimizer: Optimizer::Adam,
        lr: 0.01,
        epochs,
        seed: 11,
        eval_every: 0,
        probe_errors: false,
    };
    (cfg, g.n)
}

/// Drive `run_rank` over real localhost sockets (one thread per rank,
/// each owning its own `TcpTransport`) and return the global loss curve.
fn tcp_losses(parts: usize, variant: Variant, dropout: f32, epochs: usize) -> Vec<f64> {
    let g = presets::by_name("tiny").unwrap().build(42);
    let pt = partition(&g, parts, Method::Multilevel, 2);
    let (cfg, _) = tiny_cfg(variant, dropout, epochs);
    let plan = Arc::new(halo::build(&g, &pt, cfg.model.kind));
    let cfg = Arc::new(cfg);
    let mesh = localhost_mesh(parts).expect("mesh");
    let handles: Vec<_> = mesh
        .into_iter()
        .enumerate()
        .map(|(rank, mut transport)| {
            let plan = plan.clone();
            let cfg = cfg.clone();
            std::thread::spawn(move || {
                let (losses, _params) = threaded::run_rank(&transport, &plan.view(rank), &cfg);
                let sent = transport.payload_bytes_sent();
                transport.shutdown();
                (losses, sent)
            })
        })
        .collect();
    let mut per_rank: Vec<(Vec<f64>, u64)> =
        handles.into_iter().map(|h| h.join().expect("rank thread")).collect();
    for (rank, (_, sent)) in per_rank.iter().enumerate() {
        assert!(*sent > 0, "rank {rank} sent nothing over TCP");
    }
    // rank 0 holds the global losses (per-epoch loss reduction)
    let losses = per_rank.swap_remove(0).0;
    assert_eq!(losses.len(), cfg.epochs);
    losses
}

#[test]
fn tcp_matches_sequential_and_threaded_bitwise() {
    for (variant, dropout) in [
        (Variant::Vanilla, 0.0f32),
        (Variant::Pipe(PipeOpts::plain()), 0.0),
        (Variant::Pipe(PipeOpts { smooth_feat: true, smooth_grad: true, gamma: 0.7 }), 0.5),
    ] {
        let g = presets::by_name("tiny").unwrap().build(42);
        let pt = partition(&g, 3, Method::Multilevel, 2);
        let (cfg, _) = tiny_cfg(variant, dropout, 5);
        let mut b = NativeBackend::new();
        let seq = trainer::train_resumable(&g, &pt, &cfg, &mut b, None, None, None).unwrap();
        let thr = threaded::run_threaded_ctl(&g, &pt, &cfg, threaded::ThreadedCtl::default())
            .unwrap()
            .0;
        let tcp = tcp_losses(3, variant, dropout, 5);
        for (e, stat) in seq.curve.iter().enumerate() {
            assert_eq!(
                stat.train_loss.to_bits(),
                tcp[e].to_bits(),
                "{variant:?} epoch {}: sequential {} vs tcp {}",
                e + 1,
                stat.train_loss,
                tcp[e]
            );
            assert_eq!(
                thr.losses[e].to_bits(),
                tcp[e].to_bits(),
                "{variant:?} epoch {}: threaded vs tcp",
                e + 1
            );
        }
    }
}

#[test]
fn tcp_transport_fifo_and_accounting_through_schedule() {
    // 2-rank pipe run; after shutdown no messages may be left queued
    // (wrong tags / leaks would strand payloads)
    let g = presets::by_name("tiny").unwrap().build(42);
    let pt = partition(&g, 2, Method::Multilevel, 2);
    let (cfg, _) = tiny_cfg(Variant::Pipe(PipeOpts::plain()), 0.0, 4);
    let plan = Arc::new(halo::build(&g, &pt, cfg.model.kind));
    let cfg = Arc::new(cfg);
    let mesh = localhost_mesh(2).unwrap();
    let handles: Vec<_> = mesh
        .into_iter()
        .enumerate()
        .map(|(rank, mut transport)| {
            let plan = plan.clone();
            let cfg = cfg.clone();
            std::thread::spawn(move || {
                let _ = threaded::run_rank(&transport, &plan.view(rank), &cfg);
                transport.shutdown();
                (transport.pending(), transport.payload_bytes_sent())
            })
        })
        .collect();
    let mut sent_total = 0;
    for h in handles {
        let (pending, sent) = h.join().unwrap();
        assert_eq!(pending, 0, "messages stranded in a TCP inbox");
        sent_total += sent;
    }
    // total payload over TCP equals the threaded fabric's accounting
    let thr = threaded::run_threaded_ctl(&g, &pt, &cfg, threaded::ThreadedCtl::default())
        .unwrap()
        .0;
    assert_eq!(sent_total, thr.comm_bytes);
}

/// The acceptance path: `pipegcn launch --parts 2` spawns two real OS
/// processes that train over localhost TCP, and the final loss matches
/// the sequential trainer bit-for-bit (through the roundtrip-exact JSON
/// result file).
#[test]
fn launch_two_processes_matches_sequential_bitwise() {
    let bin = env!("CARGO_BIN_EXE_pipegcn");
    let out_path = format!(
        "/tmp/pipegcn_launch_e2e_{}.json",
        std::process::id()
    );
    let status = std::process::Command::new(bin)
        .args([
            "launch", "--parts", "2", "--dataset", "tiny", "--method", "pipegcn",
            "--epochs", "3", "--seed", "1", "--out",
        ])
        .arg(&out_path)
        .status()
        .expect("running pipegcn launch");
    assert!(status.success(), "launch exited with {status}");

    let text = std::fs::read_to_string(&out_path).expect("result json");
    let result = Json::parse(&text).expect("parse result json");
    assert_eq!(result.get("engine").and_then(Json::as_str), Some("tcp"));
    let losses: Vec<f64> = result
        .get("losses")
        .and_then(Json::as_arr)
        .expect("losses array")
        .iter()
        .map(|v| v.as_f64().unwrap())
        .collect();
    assert_eq!(losses.len(), 3);

    let seq = Session::preset("tiny")
        .parts(2)
        .variant("pipegcn")
        .run_opts(RunOpts { epochs: 3, ..Default::default() })
        .run()
        .unwrap()
        .into_output();
    for (e, stat) in seq.result.curve.iter().enumerate() {
        assert_eq!(
            stat.train_loss.to_bits(),
            losses[e].to_bits(),
            "epoch {}: sequential {} vs 2-process tcp {}",
            e + 1,
            stat.train_loss,
            losses[e]
        );
    }
    let final_loss = result.get("final_loss").and_then(Json::as_f64).unwrap();
    assert_eq!(
        final_loss.to_bits(),
        seq.result.curve.last().unwrap().train_loss.to_bits(),
        "final loss must match the sequential trainer bit-for-bit"
    );
    std::fs::remove_file(&out_path).ok();
}

/// `launch` streams an NDJSON run log from rank 0 — rows are emitted
/// live as epochs finish (per-epoch loss reduction), not post-hoc.
#[test]
fn launch_writes_run_log() {
    let bin = env!("CARGO_BIN_EXE_pipegcn");
    let log_path = format!("/tmp/pipegcn_launch_log_{}.ndjson", std::process::id());
    let status = std::process::Command::new(bin)
        .args([
            "launch", "--parts", "2", "--dataset", "tiny", "--method", "gcn",
            "--epochs", "2", "--log",
        ])
        .arg(&log_path)
        .status()
        .expect("running pipegcn launch");
    assert!(status.success(), "launch exited with {status}");
    let text = std::fs::read_to_string(&log_path).expect("run log");
    let rows = pipegcn::util::json::parse_ndjson(&text).unwrap();
    assert_eq!(rows.len(), 3); // header + 2 epochs
    assert_eq!(rows[0].get("engine").and_then(Json::as_str), Some("tcp"));
    assert!(rows[0].get("post_hoc").is_none(), "rows stream live now");
    assert_eq!(rows[2].get("epoch").and_then(Json::as_usize), Some(2));
    assert!(rows[2].get("loss").and_then(Json::as_f64).is_some());
    std::fs::remove_file(&log_path).ok();
}

/// The crash-recovery acceptance path: a 2-process launch with fault
/// injection loses rank 1 after epoch 3; the launcher must relaunch the
/// mesh from the epoch-2 checkpoint and finish, and the recovered run's
/// loss curve (epochs 3..6) must match the uninterrupted sequential
/// reference bit-for-bit.
#[test]
fn launch_recovers_from_worker_death_and_matches_sequential() {
    let bin = env!("CARGO_BIN_EXE_pipegcn");
    let base = format!("/tmp/pipegcn_recover_{}", std::process::id());
    let _ = std::fs::remove_dir_all(&base);
    let ckpt_dir = format!("{base}/ckpt");
    let out_path = format!("{base}/out.json");
    let status = std::process::Command::new(bin)
        .args([
            "launch", "--parts", "2", "--dataset", "tiny", "--method", "pipegcn",
            "--epochs", "6", "--seed", "1", "--ckpt-every", "2",
            "--fail-rank", "1", "--fail-epoch", "3",
        ])
        .args(["--ckpt-dir", &ckpt_dir, "--out", &out_path])
        .status()
        .expect("running pipegcn launch");
    assert!(status.success(), "launch must survive a worker death, got {status}");

    let result = Json::parse(&std::fs::read_to_string(&out_path).expect("result json"))
        .expect("parse result json");
    // the final generation resumed from the epoch-2 checkpoint
    assert_eq!(result.get("start_epoch").and_then(Json::as_usize), Some(2));
    assert_eq!(result.get("epochs").and_then(Json::as_usize), Some(6));
    let losses: Vec<f64> = result
        .get("losses")
        .and_then(Json::as_arr)
        .expect("losses array")
        .iter()
        .map(|v| v.as_f64().unwrap())
        .collect();
    assert_eq!(losses.len(), 4); // epochs 3..=6

    let seq = Session::preset("tiny")
        .parts(2)
        .variant("pipegcn")
        .run_opts(RunOpts { epochs: 6, ..Default::default() })
        .run()
        .unwrap()
        .into_output();
    for (i, &loss) in losses.iter().enumerate() {
        let want = seq.result.curve[2 + i].train_loss;
        assert_eq!(
            want.to_bits(),
            loss.to_bits(),
            "epoch {}: sequential {} vs recovered {}",
            3 + i,
            want,
            loss
        );
    }
    // the job left complete checkpoints behind (epochs 2, 4, 6)
    assert_eq!(pipegcn::ckpt::latest_complete(&ckpt_dir, 2).unwrap(), Some(6));
    // and it recovered by live rejoin — rank 0's process survived the
    // death and re-entered the rendezvous instead of being relaunched
    assert_eq!(
        result.get("rejoins").and_then(Json::as_usize),
        Some(1),
        "rank 0 must heal in place, not restart"
    );
    std::fs::remove_dir_all(&base).ok();
}

/// Recovery of recovery: `--fail-epoch 3,5` arms the original rank 1
/// *and* its replacement, so the mesh is broken twice. Each rejoin round
/// must heal the previous one's replacement, and the final curve still
/// matches the uninterrupted sequential run bit-for-bit.
#[test]
fn launch_survives_two_generations_of_worker_death() {
    let bin = env!("CARGO_BIN_EXE_pipegcn");
    let base = format!("/tmp/pipegcn_rerecover_{}", std::process::id());
    let _ = std::fs::remove_dir_all(&base);
    let ckpt_dir = format!("{base}/ckpt");
    let out_path = format!("{base}/out.json");
    let status = std::process::Command::new(bin)
        .args([
            "launch", "--parts", "2", "--dataset", "tiny", "--method", "pipegcn",
            "--epochs", "6", "--seed", "1", "--ckpt-every", "2",
            "--fail-rank", "1", "--fail-epoch", "3,5",
        ])
        .args(["--ckpt-dir", &ckpt_dir, "--out", &out_path])
        .status()
        .expect("running pipegcn launch");
    assert!(status.success(), "launch must survive both deaths, got {status}");

    let result = Json::parse(&std::fs::read_to_string(&out_path).expect("result json"))
        .expect("parse result json");
    // second death lands after epoch 5, so the last recovery rolled back
    // to the epoch-4 checkpoint
    assert_eq!(result.get("start_epoch").and_then(Json::as_usize), Some(4));
    assert_eq!(
        result.get("rejoins").and_then(Json::as_usize),
        Some(2),
        "rank 0 must rejoin once per death"
    );
    let losses: Vec<f64> = result
        .get("losses")
        .and_then(Json::as_arr)
        .expect("losses array")
        .iter()
        .map(|v| v.as_f64().unwrap())
        .collect();
    assert_eq!(losses.len(), 2); // epochs 5..=6

    let seq = Session::preset("tiny")
        .parts(2)
        .variant("pipegcn")
        .run_opts(RunOpts { epochs: 6, ..Default::default() })
        .run()
        .unwrap()
        .into_output();
    for (i, &loss) in losses.iter().enumerate() {
        let want = seq.result.curve[4 + i].train_loss;
        assert_eq!(
            want.to_bits(),
            loss.to_bits(),
            "epoch {}: sequential {} vs twice-recovered {}",
            5 + i,
            want,
            loss
        );
    }
    assert_eq!(pipegcn::ckpt::latest_complete(&ckpt_dir, 2).unwrap(), Some(6));
    std::fs::remove_dir_all(&base).ok();
}

/// A real worker process presenting the wrong mesh secret is turned
/// away: the rendezvous error names the rejected rank and the worker
/// exits nonzero instead of joining.
#[test]
fn worker_process_with_wrong_secret_is_rejected() {
    use pipegcn::net::rendezvous::{serve_with, ServeOpts};
    let bin = env!("CARGO_BIN_EXE_pipegcn");
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let coord = listener.local_addr().unwrap().to_string();
    // the round wants 2 ranks, but the auth check fires per hello — the
    // bad join is rejected without waiting for anyone else
    let server = std::thread::spawn(move || {
        let sopts = ServeOpts { secret: Some("right".to_string()), ..ServeOpts::default() };
        serve_with(&listener, 2, &sopts)
    });
    let out = std::process::Command::new(bin)
        .args([
            "worker", "--rank", "0", "--parts", "2", "--dataset", "tiny",
            "--epochs", "1", "--mesh-secret", "wrong", "--coord",
        ])
        .arg(&coord)
        .output()
        .expect("running pipegcn worker");
    assert!(!out.status.success(), "a wrong-secret worker must not join");
    let e = server.join().unwrap().expect_err("rendezvous must reject the join");
    let msg = e.to_string();
    assert!(msg.contains("mesh auth failed"), "{msg}");
    assert!(msg.contains("rank 0"), "the rejection must name the rank: {msg}");
}

/// With matching secrets everywhere (the launcher hands workers the
/// secret via PIPEGCN_MESH_SECRET), an authenticated 2-process launch
/// trains end to end and still matches the sequential run bit-for-bit.
#[test]
fn launch_with_mesh_secret_matches_sequential_bitwise() {
    let bin = env!("CARGO_BIN_EXE_pipegcn");
    let out_path = format!("/tmp/pipegcn_auth_launch_{}.json", std::process::id());
    let status = std::process::Command::new(bin)
        .args([
            "launch", "--parts", "2", "--dataset", "tiny", "--method", "pipegcn",
            "--epochs", "2", "--seed", "1", "--mesh-secret", "hunter2", "--out",
        ])
        .arg(&out_path)
        .status()
        .expect("running pipegcn launch");
    assert!(status.success(), "authenticated launch exited with {status}");
    let result = Json::parse(&std::fs::read_to_string(&out_path).unwrap()).unwrap();
    let final_loss = result.get("final_loss").and_then(Json::as_f64).unwrap();
    let seq = Session::preset("tiny")
        .parts(2)
        .variant("pipegcn")
        .run_opts(RunOpts { epochs: 2, ..Default::default() })
        .run()
        .unwrap()
        .into_output();
    assert_eq!(
        final_loss.to_bits(),
        seq.result.curve.last().unwrap().train_loss.to_bits(),
        "auth must not perturb training"
    );
    std::fs::remove_file(&out_path).ok();
}

/// Chaos shapes *when* frames arrive, never *what* a tag resolves to: a
/// 2-process launch under per-link latency/jitter/drops must produce a
/// loss curve bit-identical to the sequential trainer, while the result
/// file reports the injected fault count.
#[test]
fn launch_under_chaos_is_bit_identical_and_counts_faults() {
    let bin = env!("CARGO_BIN_EXE_pipegcn");
    let base = format!("/tmp/pipegcn_chaos_{}", std::process::id());
    let _ = std::fs::remove_dir_all(&base);
    std::fs::create_dir_all(&base).unwrap();
    let profile = format!("{base}/chaos.json");
    std::fs::write(
        &profile,
        r#"{"seed": 7, "default": {"latency_ms": 2, "jitter_ms": 1, "drop": 0.05, "rto_ms": 3}}"#,
    )
    .unwrap();
    let out_path = format!("{base}/out.json");
    let status = std::process::Command::new(bin)
        .args([
            "launch", "--parts", "2", "--dataset", "tiny", "--method", "pipegcn",
            "--epochs", "3", "--seed", "1",
        ])
        .args(["--chaos", &profile, "--out", &out_path])
        .status()
        .expect("running pipegcn launch");
    assert!(status.success(), "chaos launch exited with {status}");

    let result = Json::parse(&std::fs::read_to_string(&out_path).unwrap()).unwrap();
    let losses: Vec<f64> = result
        .get("losses")
        .and_then(Json::as_arr)
        .expect("losses array")
        .iter()
        .map(|v| v.as_f64().unwrap())
        .collect();
    let seq = Session::preset("tiny")
        .parts(2)
        .variant("pipegcn")
        .run_opts(RunOpts { epochs: 3, ..Default::default() })
        .run()
        .unwrap()
        .into_output();
    for (e, stat) in seq.result.curve.iter().enumerate() {
        assert_eq!(
            stat.train_loss.to_bits(),
            losses[e].to_bits(),
            "epoch {}: chaos changed the bits (sequential {} vs {})",
            e + 1,
            stat.train_loss,
            losses[e]
        );
    }
    // every frame on rank 0's outgoing links paid a delay, so the
    // injected-fault counter must be live and nonzero
    let faults = result
        .get("link_faults")
        .and_then(Json::as_usize)
        .expect("chaos runs report link_faults");
    assert!(faults > 0, "a 2ms-latency profile must count delay faults");
    std::fs::remove_dir_all(&base).ok();
}

/// `launch --resume` continues a finished checkpoint trail: a first job
/// stops at epoch 4, a second resumes from its checkpoints and runs to
/// epoch 6 with a loss curve bit-identical to one uninterrupted run.
#[test]
fn launch_resume_flag_continues_previous_job() {
    let bin = env!("CARGO_BIN_EXE_pipegcn");
    let base = format!("/tmp/pipegcn_resume_{}", std::process::id());
    let _ = std::fs::remove_dir_all(&base);
    let ckpt_dir = format!("{base}/ckpt");
    let out_path = format!("{base}/out.json");
    let first = std::process::Command::new(bin)
        .args([
            "launch", "--parts", "2", "--dataset", "tiny", "--method", "pipegcn",
            "--epochs", "4", "--seed", "1", "--ckpt-every", "2",
        ])
        .args(["--ckpt-dir", &ckpt_dir])
        .status()
        .expect("first launch");
    assert!(first.success(), "first launch exited with {first}");
    assert_eq!(pipegcn::ckpt::latest_complete(&ckpt_dir, 2).unwrap(), Some(4));

    let second = std::process::Command::new(bin)
        .args([
            "launch", "--parts", "2", "--dataset", "tiny", "--method", "pipegcn",
            "--epochs", "6", "--seed", "1",
        ])
        .args(["--resume", &ckpt_dir, "--out", &out_path])
        .status()
        .expect("second launch");
    assert!(second.success(), "resumed launch exited with {second}");

    let result = Json::parse(&std::fs::read_to_string(&out_path).unwrap()).unwrap();
    assert_eq!(result.get("start_epoch").and_then(Json::as_usize), Some(4));
    let losses: Vec<f64> = result
        .get("losses")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap())
        .collect();
    assert_eq!(losses.len(), 2); // epochs 5..=6
    let seq = Session::preset("tiny")
        .parts(2)
        .variant("pipegcn")
        .run_opts(RunOpts { epochs: 6, ..Default::default() })
        .run()
        .unwrap()
        .into_output();
    for (i, &loss) in losses.iter().enumerate() {
        assert_eq!(
            seq.result.curve[4 + i].train_loss.to_bits(),
            loss.to_bits(),
            "epoch {}",
            5 + i
        );
    }
    std::fs::remove_dir_all(&base).ok();
}
