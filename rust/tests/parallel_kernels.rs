//! Parallel kernels must be **bit-identical** to the serial path.
//!
//! The pool parallelizes over disjoint output-row blocks, so every
//! output element keeps a single owner and the serial f32 summation
//! order — these tests pin that contract for all four GEMM variants,
//! `spmm`/`spmm_t`, the elementwise passes, the Adam step, and a full
//! training run at `--threads 1` vs `--threads 4`.
//!
//! This binary owns the global pool's thread count. The pool is
//! process-global and the test harness runs `#[test]`s concurrently,
//! so every test that reconfigures it takes [`pool_lock`] first —
//! otherwise the "serial" baseline could silently execute on a
//! multi-thread pool rebuilt by a neighboring test, and a determinism
//! regression would compare parallel against parallel and vacuously
//! pass.

use pipegcn::exp::RunOpts;
use pipegcn::model::adam::Adam;
use pipegcn::perf::random_csr;
use pipegcn::runtime::pool;
use pipegcn::session::Session;
use pipegcn::tensor::{ops, Mat};
use pipegcn::util::prop;
use pipegcn::util::rng::Rng;
use std::sync::{Mutex, MutexGuard};

/// Serializes every test in this binary that touches the global pool's
/// thread count, so `with_threads(1, …)` really runs serial.
static POOL_LOCK: Mutex<()> = Mutex::new(());

fn pool_lock() -> MutexGuard<'static, ()> {
    // a panicked holder doesn't invalidate the lock's purpose
    POOL_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

fn with_threads<T>(n: usize, f: impl FnOnce() -> T) -> T {
    pool::set_threads(n);
    f()
}

const THREAD_COUNTS: [usize; 3] = [2, 3, 8];

#[test]
fn gemm_variants_bit_identical_across_thread_counts() {
    let _serial = pool_lock();
    prop::check("par gemm == serial", 6, |rng| {
        // shapes straddle the parallel-dispatch cutoff so both paths run
        let m = 1 + rng.gen_range(300);
        let k = 1 + rng.gen_range(150);
        let n = 1 + rng.gen_range(90);
        let a = Mat::randn(m, k, 1.0, rng);
        let b = Mat::randn(k, n, 1.0, rng);
        let bm = Mat::randn(m, n, 1.0, rng); // for tn: same rows as a
        let bk = Mat::randn(n, k, 1.0, rng); // for nt: same cols as a
        let base = with_threads(1, || {
            (a.matmul(&b), a.matmul_tn(&bm), a.matmul_nt(&bk))
        });
        for t in THREAD_COUNTS {
            let got = with_threads(t, || {
                (a.matmul(&b), a.matmul_tn(&bm), a.matmul_nt(&bk))
            });
            pipegcn::prop_assert!(
                bits(&base.0.data) == bits(&got.0.data),
                "matmul bits differ at {t} threads ({m}x{k}x{n})"
            );
            pipegcn::prop_assert!(
                bits(&base.1.data) == bits(&got.1.data),
                "matmul_tn bits differ at {t} threads ({m}x{k}x{n})"
            );
            pipegcn::prop_assert!(
                bits(&base.2.data) == bits(&got.2.data),
                "matmul_nt bits differ at {t} threads ({m}x{k}x{n})"
            );
        }
        Ok(())
    });
}

#[test]
fn matmul_into_bit_identical_across_thread_counts() {
    let _serial = pool_lock();
    prop::check("par matmul_into == serial", 4, |rng| {
        let (m, k, n) = (64 + rng.gen_range(200), 32 + rng.gen_range(64), 8 + rng.gen_range(48));
        let a = Mat::randn(m, k, 1.0, rng);
        let b = Mat::randn(k, n, 1.0, rng);
        let mut c1 = Mat::zeros(m, n);
        with_threads(1, || a.matmul_into(&b, &mut c1));
        for t in THREAD_COUNTS {
            let mut ct = Mat::zeros(m, n);
            with_threads(t, || a.matmul_into(&b, &mut ct));
            pipegcn::prop_assert!(
                bits(&c1.data) == bits(&ct.data),
                "matmul_into bits differ at {t} threads"
            );
        }
        Ok(())
    });
}

#[test]
fn spmm_and_spmm_t_bit_identical_across_thread_counts() {
    let _serial = pool_lock();
    prop::check("par spmm == serial", 6, |rng| {
        let rows = 1 + rng.gen_range(300);
        let cols = 1 + rng.gen_range(200);
        let f = 1 + rng.gen_range(64);
        let s = random_csr(rng, rows, cols, 0.15);
        let h = Mat::randn(cols, f, 1.0, rng);
        let m = Mat::randn(rows, f, 1.0, rng);
        let base = with_threads(1, || (s.spmm(&h), s.spmm_t(&m)));
        for t in THREAD_COUNTS {
            let got = with_threads(t, || (s.spmm(&h), s.spmm_t(&m)));
            pipegcn::prop_assert!(
                bits(&base.0.data) == bits(&got.0.data),
                "spmm bits differ at {t} threads ({rows}x{cols}x{f})"
            );
            pipegcn::prop_assert!(
                bits(&base.1.data) == bits(&got.1.data),
                "spmm_t bits differ at {t} threads ({rows}x{cols}x{f})"
            );
        }
        Ok(())
    });
}

#[test]
fn elementwise_and_adam_bit_identical_across_thread_counts() {
    let _serial = pool_lock();
    let mut rng = Rng::new(9);
    let z = Mat::randn(300, 70, 1.0, &mut rng); // > the parallel cutoff
    let g0 = Mat::randn(300, 70, 1.0, &mut rng);
    let mask = ops::dropout_mask(300, 70, 0.5, &mut rng);
    let n = 40_000;
    let grad: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
    let run = |t: usize| {
        with_threads(t, || {
            let r = ops::relu(&z);
            let mut g = g0.clone();
            ops::relu_grad_inplace(&mut g, &z);
            let mut h = g0.clone();
            ops::hadamard_inplace(&mut h, &mask);
            let mut params = vec![0.1f32; n];
            let mut adam = Adam::new(0.01, n);
            for _ in 0..3 {
                adam.step(&mut params, &grad);
            }
            (r, g, h, params)
        })
    };
    let base = run(1);
    for t in THREAD_COUNTS {
        let got = run(t);
        assert_eq!(bits(&base.0.data), bits(&got.0.data), "relu at {t} threads");
        assert_eq!(bits(&base.1.data), bits(&got.1.data), "relu_grad at {t} threads");
        assert_eq!(bits(&base.2.data), bits(&got.2.data), "hadamard at {t} threads");
        assert_eq!(bits(&base.3), bits(&got.3), "adam at {t} threads");
    }
}

/// The acceptance oracle: a full training run (all engines share these
/// kernels) produces a bit-identical loss curve at 1 vs 4 threads.
#[test]
fn training_loss_curve_bit_identical_threads_1_vs_4() {
    let _serial = pool_lock();
    let run = |t: usize| {
        with_threads(t, || {
            Session::preset("tiny")
                .parts(3)
                .variant("pipegcn-gf")
                .run_opts(RunOpts { epochs: 5, eval_every: 0, ..Default::default() })
                .run()
                .unwrap()
                .into_output()
        })
    };
    let a = run(1);
    let b = run(4);
    assert_eq!(a.result.curve.len(), b.result.curve.len());
    for (x, y) in a.result.curve.iter().zip(&b.result.curve) {
        assert_eq!(
            x.train_loss.to_bits(),
            y.train_loss.to_bits(),
            "epoch {}: 1-thread {} vs 4-thread {}",
            x.epoch,
            x.train_loss,
            y.train_loss
        );
    }
    // the epoch stats carry the new breakdown fields
    for e in &a.result.curve {
        assert!(e.comp_ms >= 0.0 && e.comm_wait_ms == 0.0);
    }
}

/// `pipegcn bench --smoke` roundtrip: NDJSON rows for every kernel at
/// every swept thread count, the end-to-end epoch rows, and a summary.
#[test]
fn smoke_bench_writes_ndjson_rows() {
    let _serial = pool_lock();
    let path = format!("/tmp/pipegcn_bench_test_{}.ndjson", std::process::id());
    let o = pipegcn::perf::BenchOpts {
        out: path.clone(),
        threads: vec![1, 2],
        smoke: true,
        preset: "tiny".into(),
        parts: 2,
        epochs: 2,
        scale: false,
    };
    pipegcn::perf::run_bench(&o).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let rows = pipegcn::util::json::parse_ndjson(&text).unwrap();
    // header + 5 kernels × 2 thread counts + 2 epoch rows + 2 overlap
    // rows + 2 serve rows (min and max thread count) + summary
    assert_eq!(rows.len(), 1 + 10 + 2 + 2 + 2 + 1, "{text}");
    assert_eq!(rows[0].get("bench").unwrap().as_str(), Some("pipegcn-kernels"));
    for row in &rows[1..13] {
        assert!(row.get("ns_iter").unwrap().as_f64().unwrap() > 0.0);
        assert!(row.get("gflops").unwrap().as_f64().unwrap() >= 0.0);
        assert!(row.get("threads").unwrap().as_usize().unwrap() >= 1);
    }
    // the overlap sweep: one threaded multi-rank run per thread count,
    // reporting rank 0's parked time and hidden-receive fraction
    for row in &rows[13..15] {
        assert_eq!(row.get("kernel").unwrap().as_str(), Some("overlap"));
        assert!(row.get("comm_wait_ms").unwrap().as_f64().unwrap() >= 0.0);
        let r = row.get("overlap_ratio").unwrap().as_f64().unwrap();
        assert!((0.0..=1.0).contains(&r), "overlap_ratio {r}");
    }
    for row in &rows[15..17] {
        assert_eq!(row.get("kernel").unwrap().as_str(), Some("serve"));
        assert!(row.get("p50_ms").unwrap().as_f64().unwrap() > 0.0);
        assert!(row.get("p99_ms").unwrap().as_f64().unwrap() > 0.0);
        assert!(row.get("qps").unwrap().as_f64().unwrap() > 0.0);
    }
    let last = rows.last().unwrap();
    assert_eq!(last.get("kernel").unwrap().as_str(), Some("summary"));
    assert!(last.get("spmm_gemm_speedup").unwrap().as_f64().unwrap() > 0.0);
    std::fs::remove_file(&path).ok();
}
