//! Cross-module integration and property tests: partitioner invariants
//! over random graph families, end-to-end training on non-SBM graphs,
//! GCN-kind layers, failure injection, and experiment-harness plumbing.

use pipegcn::coordinator::{trainer, Optimizer, PipeOpts, TrainConfig, Variant};
use pipegcn::graph::{generate, presets, Graph, Labels};
use pipegcn::model::{LayerKind, ModelConfig};
use pipegcn::partition::{partition, quality, Method, Partitioning};
use pipegcn::prop_assert;
use pipegcn::runtime::native::NativeBackend;
use pipegcn::runtime::Backend;
use pipegcn::tensor::Mat;
use pipegcn::util::prop;
use pipegcn::util::rng::Rng;

fn random_graph(rng: &mut Rng) -> Graph {
    // one of three graph families
    let n = 120 + rng.gen_range(280);
    let edges = match rng.gen_range(3) {
        0 => generate::erdos_renyi_edges(n, 4.0 + rng.next_f64() * 6.0, rng),
        1 => generate::barabasi_albert_edges(n, 2 + rng.gen_range(3), rng),
        _ => {
            let cfg = generate::SbmConfig::new(n, 4 + rng.gen_range(6), 6.0, 1.5);
            generate::sbm_edges(&cfg, rng).0
        }
    };
    let feats = Mat::randn(n, 8, 1.0, rng);
    let labels = Labels::Single {
        labels: (0..n).map(|_| rng.gen_range(4) as u32).collect(),
        n_classes: 4,
    };
    let mut g = Graph::from_edges(n, &edges, feats, labels);
    g.random_split(0.6, 0.2, rng);
    g
}

#[test]
fn partition_invariants_hold_over_graph_families() {
    prop::check("partition invariants", 20, |rng| {
        let g = random_graph(rng);
        let k = 2 + rng.gen_range(6);
        let method = match rng.gen_range(3) {
            0 => Method::Multilevel,
            1 => Method::Bfs,
            _ => Method::Hash,
        };
        let p = partition(&g, k, method, rng.next_u64());
        p.validate(g.n).map_err(|e| format!("{method:?} k={k}: {e}"))?;
        let q = quality(&g, &p);
        prop_assert!(q.balance < 2.5, "{method:?} k={k} balance {}", q.balance);
        // comm volume is bounded by Σ min(deg, k-1)
        let bound: usize =
            (0..g.n).map(|v| g.degree(v).min(k - 1)).sum();
        prop_assert!(
            q.comm_volume <= bound,
            "comm volume {} > bound {bound}",
            q.comm_volume
        );
        Ok(())
    });
}

#[test]
fn halo_plan_consistent_over_graph_families() {
    prop::check("halo plan", 10, |rng| {
        let g = random_graph(rng);
        let k = 2 + rng.gen_range(4);
        let p = partition(&g, k, Method::Multilevel, rng.next_u64());
        let plan = pipegcn::coordinator::halo::build(&g, &p, LayerKind::SageMean);
        plan.validate()?;
        let q = quality(&g, &p);
        prop_assert!(
            plan.total_halo() == q.comm_volume,
            "halo {} vs quality {}",
            plan.total_halo(),
            q.comm_volume
        );
        Ok(())
    });
}

#[test]
fn training_works_on_power_law_graph() {
    // PipeGCN on a Barabási–Albert graph: hubs make boundary sets highly
    // skewed — a stress case for the halo plan.
    let mut rng = Rng::new(9);
    let n = 600;
    let edges = generate::barabasi_albert_edges(n, 4, &mut rng);
    let community: Vec<u32> = (0..n).map(|v| (v % 4) as u32).collect();
    let labels =
        pipegcn::graph::features::labels_from_communities(&community, 4, false, &mut rng);
    let feats =
        pipegcn::graph::features::class_features(&labels, &community, 16, 0.5, &mut rng);
    let mut g = Graph::from_edges(n, &edges, feats, labels);
    g.random_split(0.6, 0.2, &mut rng);
    let pt = partition(&g, 4, Method::Multilevel, 1);
    let cfg = TrainConfig {
        model: ModelConfig::sage(16, 16, 2, 4, 0.0),
        variant: Variant::Pipe(PipeOpts::plain()),
        optimizer: Optimizer::Adam,
        lr: 0.01,
        epochs: 25,
        seed: 5,
        eval_every: 25,
        probe_errors: false,
    };
    let mut b = NativeBackend::new();
    let r = trainer::train_resumable(&g, &pt, &cfg, &mut b, None, None, None).unwrap();
    assert!(
        r.curve.last().unwrap().train_loss < 0.8 * r.curve[0].train_loss,
        "loss {} -> {}",
        r.curve[0].train_loss,
        r.curve.last().unwrap().train_loss
    );
    assert!(r.final_test > 0.4, "test {}", r.final_test);
}

#[test]
fn gcn_layer_kind_trains() {
    // the paper's formal analysis uses the GCN form σ(P·H·W); make sure
    // the w_self-free path trains end to end in both modes
    let g = presets::by_name("tiny").unwrap().build(42);
    let pt = partition(&g, 3, Method::Multilevel, 1);
    for variant in [Variant::Vanilla, Variant::Pipe(PipeOpts::plain())] {
        let cfg = TrainConfig {
            model: ModelConfig::gcn(g.feat_dim(), 24, 2, g.labels.n_classes(), 0.0),
            variant,
            optimizer: Optimizer::Adam,
            lr: 0.01,
            epochs: 30,
            seed: 3,
            eval_every: 30,
            probe_errors: false,
        };
        let mut b = NativeBackend::new();
        let r = trainer::train_resumable(&g, &pt, &cfg, &mut b, None, None, None).unwrap();
        assert!(r.final_test > 0.6, "{variant:?} test {}", r.final_test);
    }
}

#[test]
fn pipegcn_variants_converge_close_to_vanilla() {
    // Table 4's core claim at test scale: every PipeGCN variant lands
    // within a small band of vanilla accuracy.
    let g = presets::by_name("tiny").unwrap().build(7);
    let pt = partition(&g, 4, Method::Multilevel, 2);
    let mut scores = Vec::new();
    for m in ["gcn", "pipegcn", "pipegcn-g", "pipegcn-f", "pipegcn-gf"] {
        let cfg = TrainConfig {
            model: ModelConfig::sage(g.feat_dim(), 24, 2, g.labels.n_classes(), 0.0),
            variant: Variant::parse(m, 0.95).unwrap(),
            optimizer: Optimizer::Adam,
            lr: 0.01,
            epochs: 40,
            seed: 2,
            eval_every: 40,
            probe_errors: false,
        };
        let mut b = NativeBackend::new();
        let r = trainer::train_resumable(&g, &pt, &cfg, &mut b, None, None, None).unwrap();
        scores.push((m, r.final_test));
    }
    let vanilla = scores[0].1;
    for &(m, s) in &scores[1..] {
        assert!(
            (s - vanilla).abs() < 0.1,
            "{m}: {s} vs vanilla {vanilla} (all: {scores:?})"
        );
    }
}

#[test]
fn stale_buffers_warm_up_from_zero() {
    // Alg. 1 line 6: iteration 1 aggregates zeros from boundary, so the
    // first-epoch loss of PipeGCN differs from vanilla, then converges.
    let g = presets::by_name("tiny").unwrap().build(11);
    let pt = partition(&g, 4, Method::Multilevel, 3);
    let run = |variant| {
        let cfg = TrainConfig {
            model: ModelConfig::sage(g.feat_dim(), 16, 2, g.labels.n_classes(), 0.0),
            variant,
            optimizer: Optimizer::Sgd,
            lr: 0.05,
            epochs: 3,
            seed: 4,
            eval_every: 0,
            probe_errors: false,
        };
        let mut b = NativeBackend::new();
        trainer::train_resumable(&g, &pt, &cfg, &mut b, None, None, None).unwrap()
    };
    let v = run(Variant::Vanilla);
    let p = run(Variant::Pipe(PipeOpts::plain()));
    // epoch 1 forward differs (zero halos)…
    assert!(
        (v.curve[0].train_loss - p.curve[0].train_loss).abs() > 1e-6,
        "epoch-1 losses should differ"
    );
    // …but remain finite and comparable
    assert!(p.curve.iter().all(|e| e.train_loss.is_finite()));
}

// ---------------- failure injection ----------------

#[test]
fn corrupted_graph_file_rejected() {
    let mut rng = Rng::new(1);
    let g = random_graph(&mut rng);
    let path = "/tmp/pipegcn_corrupt_test.bin";
    pipegcn::graph::io::save(&g, path).unwrap();
    let mut bytes = std::fs::read(path).unwrap();
    bytes.truncate(bytes.len() / 2); // torn write
    std::fs::write(path, &bytes).unwrap();
    assert!(pipegcn::graph::io::load(path).is_err());
    // corrupted magic
    let mut bytes = std::fs::read(path).unwrap();
    bytes[0] ^= 0xFF;
    std::fs::write(path, &bytes).unwrap();
    assert!(pipegcn::graph::io::load(path).is_err());
    std::fs::remove_file(path).ok();
}

#[test]
fn missing_artifacts_dir_is_clean_error() {
    let err = pipegcn::runtime::xla::XlaBackend::from_artifacts("/tmp/definitely-missing-dir")
        .err()
        .expect("should fail");
    let msg = format!("{err:#}");
    assert!(msg.contains("manifest.json"), "{msg}");
}

#[test]
fn mismatched_partitioning_detected() {
    let mut rng = Rng::new(2);
    let g = random_graph(&mut rng);
    let p = Partitioning::new(2, vec![0; g.n + 5]); // wrong length
    assert!(p.validate(g.n).is_err());
}

#[test]
#[should_panic(expected = "exceeds artifact padding")]
fn xla_backend_rejects_oversized_partition() {
    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    if !std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        panic!("exceeds artifact padding (SKIP: artifacts missing)");
    }
    let mut backend = pipegcn::runtime::xla::XlaBackend::from_artifacts(&dir).unwrap();
    // 1000 inner rows > N_PAD=320 must be rejected loudly
    let trip: Vec<(u32, u32, f32)> = (0..1000u32).map(|i| (i, i, 1.0)).collect();
    let big = pipegcn::tensor::Csr::from_triplets(1000, 1000, trip);
    backend.register_prop(&big);
}

// ---------------- experiment harness plumbing ----------------

#[test]
fn full_works_projection_shapes() {
    let out = pipegcn::session::Session::preset("tiny")
        .parts(2)
        .variant("gcn")
        .run_opts(pipegcn::exp::RunOpts { epochs: 2, eval_every: 0, ..Default::default() })
        .run()
        .unwrap()
        .into_output();
    let (works, model_elems) = pipegcn::exp::full_works(&out);
    assert_eq!(works.len(), 2);
    assert_eq!(works[0].fwd.len(), out.preset.layers);
    assert!(model_elems > 0);
    // tiny's full == sim scale, so projected spmm flops should be within
    // ~2× of the measured ones (projection uses analytic 2·nnz·f)
    let measured = out.result.works[0].fwd[0].spmm_flops;
    let projected = works[0].fwd[0].spmm_flops;
    assert!(
        projected > 0.3 * measured && projected < 3.0 * measured,
        "measured {measured} projected {projected}"
    );
}

#[test]
fn results_json_roundtrip() {
    use pipegcn::util::json::Json;
    let j = Json::obj()
        .set("table", "t")
        .set("rows", Json::Arr(vec![Json::obj().set("x", 1.5f64)]));
    let path = "/tmp/pipegcn_results_test.json";
    j.write_file(path).unwrap();
    let back = Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
    assert_eq!(back, j);
    std::fs::remove_file(path).ok();
}
