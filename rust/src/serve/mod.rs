//! Online inference: `pipegcn serve` / `pipegcn query`.
//!
//! The serving workload the ROADMAP calls for, built on the pieces that
//! already exist: a [`Server`] loads a params artifact
//! ([`crate::model::artifact`] — weights + model shape, no optimizer
//! state), rebuilds its preset graph deterministically, binds a TCP
//! listener speaking the existing [`crate::net::frame`] protocol, and
//! answers feature→logit queries by running the batch through
//! [`crate::coordinator::forward_registered`] — the same kernels (on
//! the [`crate::runtime::pool`]) and numerics as training, so a query
//! over the stored features is **bit-identical** to
//! [`crate::coordinator::full_graph_forward`] (asserted in
//! `tests/serve_e2e.rs`). The propagation matrix is built once at bind
//! time and registered once per connection; the per-query cost is the
//! forward kernels alone.
//!
//! ## Wire protocol
//!
//! One connection, many queries. The client introduces itself with a
//! `Hello` frame, then sends one `Data` frame per query and reads one
//! `Data` frame back; `Shutdown` (or EOF) ends the connection. A query
//! payload is bit-packed into the f32 channel exactly like the training
//! control messages:
//!
//! ```text
//! [0]            batch size n (u32 bits)
//! [1 .. 1+n]     node ids (u32 bits each)
//! [1+n ..]       optional feature override, n × feat_dim floats,
//!                row i replacing node ids[i]'s stored features
//! ```
//!
//! The response payload is the batch's logits, n × n_classes floats.
//! Payloads travel as raw bit patterns end to end, so logits reach the
//! client with the exact bits the kernels produced. Queries larger than
//! one frame (64 MiB) are rejected — batch accordingly.

use crate::comm::{Phase, Tag};
use crate::coordinator::forward_registered;
use crate::graph::presets::{self, Preset};
use crate::graph::Graph;
use crate::model::{artifact, LayerKind, ModelConfig, Params};
use crate::net::frame::{self, Frame};
use crate::partition::Method;
use crate::runtime::native::NativeBackend;
use crate::runtime::Backend;
use crate::tensor::{Csr, Mat};
use crate::util::error::{Context, Result};
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

/// How to stand up a server from the CLI.
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// params artifact written by `pipegcn export-params`
    pub params_path: String,
    /// preset whose graph the params were trained on
    pub dataset: String,
    /// dataset build seed — must match the training run's
    pub seed: u64,
    /// listen address (`127.0.0.1:0` picks an ephemeral port)
    pub bind: String,
    /// rebuild the preset at this node count (None = preset default)
    pub nodes: Option<usize>,
    /// serve only partition `I` of `K` (`--shard I/K`): load just the
    /// artifact's required subgraph — owned nodes plus their L-hop
    /// closure — instead of materializing the full graph
    pub shard: Option<(usize, usize)>,
}

/// Everything a query needs, shared read-only across connections. The
/// propagation matrix is built **once** here — per-query work is just
/// the forward kernels, not an O(edges) matrix rebuild.
pub struct ServeCtx {
    /// global node-id space (queries address nodes by global id)
    pub n: usize,
    pub feat_dim: usize,
    /// feature rows the forward runs over: all `n` nodes, or just the
    /// scope's closure rows (row i = `scope.closure[i]`'s features)
    pub features: Mat,
    /// normalized propagation matrix for `kind` (full-graph, or
    /// restricted to the closure with **global** degree weights)
    pub prop: Csr,
    pub params: Params,
    pub kind: LayerKind,
    pub n_classes: usize,
    /// `Some` when serving one partition's subgraph only
    pub scope: Option<ServeScope>,
}

/// The subgraph a sharded server loaded: partition `part` of `parts`.
/// Only `owned` nodes are answerable — their logits are bit-identical to
/// the full-graph forward because the closure covers every node whose
/// value can reach them within `n_layers` propagation steps, and the
/// restricted propagation matrix keeps the full graph's degree weights.
pub struct ServeScope {
    pub part: usize,
    pub parts: usize,
    /// global ids this shard answers for, ascending
    pub owned: Vec<u32>,
    /// global ids of the L-hop closure, ascending — the row space of
    /// `features` and `prop`
    pub closure: Vec<u32>,
}

/// A bound (not yet accepting) inference server.
pub struct Server {
    listener: TcpListener,
    ctx: Arc<ServeCtx>,
    addr: String,
}

fn io_err(msg: String) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
}

impl Server {
    /// Load the artifact, rebuild the preset graph (or, with
    /// `shard = Some((part, parts))`, only the artifact's required
    /// subgraph — `part`'s owned nodes plus their L-hop closure),
    /// validate that the model fits it, and bind the listener.
    pub fn bind(o: &ServeOpts) -> Result<Server> {
        let pf = artifact::load(&o.params_path)?;
        let preset = presets::by_name(&o.dataset).ok_or_else(|| {
            crate::err_msg!("unknown preset '{}' (try: {:?})", o.dataset, presets::names())
        })?;
        match o.shard {
            None => {
                let graph = match o.nodes {
                    Some(n) => preset.build_scaled(n, o.seed),
                    None => preset.build(o.seed),
                };
                Server::from_parts_on(graph, pf.config, pf.params, &o.bind)
            }
            Some((part, parts)) => {
                if parts == 0 || part >= parts {
                    crate::bail!("--shard {part}/{parts}: part must be < parts");
                }
                let n = o.nodes.unwrap_or(preset.n);
                let ctx = scoped_ctx(preset, n, o.seed, part, parts, pf.config, pf.params)?;
                Server::from_ctx(ctx, &o.bind)
            }
        }
    }

    /// Stand up a server from in-memory parts (tests, benches, library
    /// embedding) on an ephemeral localhost port.
    pub fn from_parts(graph: Graph, config: ModelConfig, params: Params) -> Result<Server> {
        Server::from_parts_on(graph, config, params, "127.0.0.1:0")
    }

    fn from_parts_on(
        graph: Graph,
        config: ModelConfig,
        params: Params,
        bind: &str,
    ) -> Result<Server> {
        if config.dims[0] != graph.feat_dim() {
            crate::bail!(
                "params expect feature dim {} but the graph has {} — wrong dataset or seed?",
                config.dims[0],
                graph.feat_dim()
            );
        }
        let n_classes = *config.dims.last().unwrap();
        if n_classes != graph.labels.n_classes() {
            crate::bail!(
                "params produce {} classes but the graph has {} — wrong dataset or seed?",
                n_classes,
                graph.labels.n_classes()
            );
        }
        let prop = match config.kind {
            LayerKind::Gcn => graph.propagation_matrix(),
            LayerKind::SageMean => graph.mean_propagation_matrix(),
        };
        let ctx = ServeCtx {
            n: graph.n,
            feat_dim: graph.feat_dim(),
            features: graph.features,
            prop,
            params,
            kind: config.kind,
            n_classes,
            scope: None,
        };
        Server::from_ctx(ctx, bind)
    }

    /// Bind a listener around an already-assembled context.
    fn from_ctx(ctx: ServeCtx, bind: &str) -> Result<Server> {
        let listener = TcpListener::bind(bind).with_context(|| format!("binding {bind}"))?;
        let addr = listener.local_addr()?.to_string();
        Ok(Server { listener, ctx: Arc::new(ctx), addr })
    }

    /// The bound address (`host:port`).
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Shared query context (library embedding).
    pub fn ctx(&self) -> Arc<ServeCtx> {
        self.ctx.clone()
    }

    /// Accept connections, one handler thread each. With `max_conns`,
    /// return after that many connections finish (deterministic
    /// shutdown for tests and the CI smoke job); without it, serve
    /// forever with handler threads detached, so nothing accumulates
    /// per connection. A malformed query closes its connection with a
    /// logged diagnostic — it never takes the server down.
    pub fn run(self, max_conns: Option<usize>) -> Result<()> {
        let mut handles = Vec::new();
        let mut served = 0usize;
        loop {
            if let Some(m) = max_conns {
                if served >= m {
                    break;
                }
            }
            let (stream, peer) =
                self.listener.accept().context("accepting a query connection")?;
            served += 1;
            let ctx = self.ctx.clone();
            let handle = std::thread::spawn(move || {
                if let Err(e) = handle_conn(&ctx, stream) {
                    eprintln!("serve: connection {peer}: {e}");
                }
            });
            // only a bounded run joins its handlers; an unbounded server
            // must not grow a handle per connection forever
            if max_conns.is_some() {
                handles.push(handle);
            }
        }
        for h in handles {
            let _ = h.join();
        }
        Ok(())
    }
}

/// Build a sharded serving context: partition the topology, take
/// partition `part`'s owned nodes plus their `n_layers`-hop closure,
/// materialize features for the closure only (one replay of the
/// deterministic shard builder), and restrict the propagation matrix to
/// closure×closure while keeping **full-graph** degree weights. Owned
/// logits stay bit-identical to the full-graph forward: after layer `l`
/// the values on the closure's `(L-l)`-hop interior match the full run
/// (boundary rows drop out-of-closure terms, but no owned node ever
/// reads one within `L` steps), and the restricted matrix is a monotone
/// renumbering of the full matrix's closure rows, so per-row summation
/// order in the SpMM is unchanged.
fn scoped_ctx(
    preset: &Preset,
    n: usize,
    seed: u64,
    part: usize,
    parts: usize,
    config: ModelConfig,
    params: Params,
) -> Result<ServeCtx> {
    let topo = preset.build_topology_scaled(n, seed);
    let adj = topo.adj();
    let pt = crate::partition::partition_adj(adj, parts, Method::Multilevel, seed);
    let owned: Vec<u32> = (0..n as u32).filter(|&v| pt.assign[v as usize] == part as u32).collect();
    // L-hop ball around the owned set: every node a forward of
    // `n_layers` propagation steps can read from
    let mut in_closure = vec![false; n];
    for &v in &owned {
        in_closure[v as usize] = true;
    }
    let mut frontier: Vec<u32> = owned.clone();
    for _ in 0..config.n_layers() {
        let mut next = Vec::new();
        for &v in &frontier {
            for &u in adj.neighbors(v as usize) {
                if !in_closure[u as usize] {
                    in_closure[u as usize] = true;
                    next.push(u);
                }
            }
        }
        frontier = next;
    }
    let closure: Vec<u32> = (0..n as u32).filter(|&v| in_closure[v as usize]).collect();
    // features for exactly the closure: replay the generator with an
    // indicator assignment under which "partition 0" owns the closure
    let indicator: Vec<u32> = in_closure.iter().map(|&k| if k { 0 } else { 1 }).collect();
    let shard = preset.build_shard_scaled(n, seed, &indicator, 0);
    debug_assert_eq!(shard.owned, closure);
    if config.dims[0] != shard.feat_dim() {
        crate::bail!(
            "params expect feature dim {} but the graph has {} — wrong dataset or seed?",
            config.dims[0],
            shard.feat_dim()
        );
    }
    let n_classes = *config.dims.last().unwrap();
    if n_classes != shard.labels.n_classes() {
        crate::bail!(
            "params produce {} classes but the graph has {} — wrong dataset or seed?",
            n_classes,
            shard.labels.n_classes()
        );
    }
    let local = |u: u32| closure.binary_search(&u).unwrap() as u32;
    let m = closure.len();
    let mut trip = Vec::new();
    match config.kind {
        LayerKind::Gcn => {
            for (i, &v) in closure.iter().enumerate() {
                let dv = (adj.degree(v as usize) + 1) as f32;
                trip.push((i as u32, i as u32, 1.0 / dv));
                for &u in adj.neighbors(v as usize) {
                    if in_closure[u as usize] {
                        let du = (adj.degree(u as usize) + 1) as f32;
                        trip.push((i as u32, local(u), 1.0 / (dv.sqrt() * du.sqrt())));
                    }
                }
            }
        }
        LayerKind::SageMean => {
            for (i, &v) in closure.iter().enumerate() {
                let inv = 1.0 / (adj.degree(v as usize) + 1) as f32;
                trip.push((i as u32, i as u32, inv));
                for &u in adj.neighbors(v as usize) {
                    if in_closure[u as usize] {
                        trip.push((i as u32, local(u), inv));
                    }
                }
            }
        }
    }
    let prop = Csr::from_triplets(m, m, trip);
    Ok(ServeCtx {
        n,
        feat_dim: shard.feat_dim(),
        features: shard.features,
        prop,
        params,
        kind: config.kind,
        n_classes,
        scope: Some(ServeScope { part, parts, owned, closure }),
    })
}

/// Serve one client connection: loop over query frames until shutdown.
/// The propagation matrix is registered with the connection's backend
/// exactly once — queries pay only for the forward kernels.
fn handle_conn(ctx: &ServeCtx, mut stream: TcpStream) -> std::io::Result<()> {
    // connection-lifetime metrics: the gauge must fall on *every* exit
    // path (clean shutdown, malformed query, I/O error), so its
    // decrement rides a drop guard
    let reg = crate::obs::global();
    let lat = reg.histogram("serve_query_ms", &[]);
    let queries = reg.counter("serve_queries_total", &[]);
    struct ConnGuard(crate::obs::Gauge);
    impl Drop for ConnGuard {
        fn drop(&mut self) {
            self.0.add(-1.0);
        }
    }
    let active = reg.gauge("serve_active_connections", &[]);
    active.add(1.0);
    let _guard = ConnGuard(active);
    let mut backend = NativeBackend::new();
    let prop_id = backend.register_prop(&ctx.prop);
    // feature-override scratch: cloned lazily on this connection's first
    // override query, then patched/restored row-wise per query
    let mut scratch: Option<Mat> = None;
    loop {
        match frame::read_frame(&mut stream)? {
            None | Some(Frame::Shutdown { .. }) => return Ok(()),
            Some(Frame::Hello { .. }) => {}
            Some(Frame::Data { tag, payload, .. }) => {
                let watch = crate::util::timer::Stopwatch::start();
                let logits = answer(ctx, &mut backend, prop_id, &mut scratch, &payload)
                    .map_err(io_err)?;
                frame::write_frame(
                    &mut stream,
                    &Frame::Data { src: 0, dst: 1, tag, payload: logits },
                )?;
                stream.flush()?;
                lat.record(watch.elapsed_secs() * 1e3);
                queries.inc();
            }
            Some(other) => {
                return Err(io_err(format!("unexpected frame in a query stream: {other:?}")))
            }
        }
    }
}

/// Decode one query payload and run the batch inference. Validation
/// errors come back as messages (the connection is closed with a
/// diagnostic, the server keeps running).
fn answer(
    ctx: &ServeCtx,
    backend: &mut dyn Backend,
    prop_id: usize,
    scratch: &mut Option<Mat>,
    payload: &[f32],
) -> std::result::Result<Vec<f32>, String> {
    if payload.is_empty() {
        return Err("empty query".to_string());
    }
    let n = payload[0].to_bits() as usize;
    if n == 0 {
        return Err("query names no nodes".to_string());
    }
    if payload.len() < 1 + n {
        return Err(format!("query claims {n} ids but carries {}", payload.len() - 1));
    }
    let ids: Vec<u32> = payload[1..1 + n].iter().map(|v| v.to_bits()).collect();
    // map global ids to feature/logit rows (identity when unscoped)
    let mut rows = Vec::with_capacity(ids.len());
    for &id in &ids {
        if id as usize >= ctx.n {
            return Err(format!("node id {id} out of range (graph has {} nodes)", ctx.n));
        }
        let row = match &ctx.scope {
            None => id as usize,
            Some(s) => {
                if s.owned.binary_search(&id).is_err() {
                    return Err(format!(
                        "node id {id} is not owned by shard {}/{} — query the rank that owns it",
                        s.part, s.parts
                    ));
                }
                s.closure.binary_search(&id).unwrap()
            }
        };
        rows.push(row);
    }
    let feats = &payload[1 + n..];
    let fd = ctx.feat_dim;
    let logits = if feats.is_empty() {
        forward_registered(prop_id, &ctx.params, backend, &ctx.features)
    } else {
        if feats.len() != n * fd {
            return Err(format!(
                "feature override must be {n}×{fd} values, got {}",
                feats.len()
            ));
        }
        // patch the connection's scratch copy row-wise instead of
        // cloning the whole feature matrix per query
        let features = scratch.get_or_insert_with(|| ctx.features.clone());
        for (i, &r) in rows.iter().enumerate() {
            features.set_row(r, &feats[i * fd..(i + 1) * fd]);
        }
        let out = forward_registered(prop_id, &ctx.params, backend, features);
        // restore the stored rows so later queries see clean features
        for &r in &rows {
            features.set_row(r, ctx.features.row(r));
        }
        out
    };
    let mut out = Vec::with_capacity(n * ctx.n_classes);
    for &r in &rows {
        out.extend_from_slice(logits.row(r));
    }
    Ok(out)
}

/// A blocking query client for one server connection.
pub struct Client {
    stream: TcpStream,
    next_query: u32,
}

impl Client {
    /// Connect and introduce ourselves.
    pub fn connect(addr: &str) -> std::io::Result<Client> {
        let mut stream = TcpStream::connect(addr)?;
        frame::write_frame(&mut stream, &Frame::Hello { rank: 0, addr: String::new() })?;
        stream.flush()?;
        Ok(Client { stream, next_query: 1 })
    }

    /// Logits for `ids` over the graph's stored features — bit-identical
    /// to the server-side full-graph forward. Returns an
    /// `ids.len() × n_classes` matrix, one row per queried node.
    pub fn query(&mut self, ids: &[u32]) -> std::io::Result<Mat> {
        self.query_impl(ids, None)
    }

    /// Logits for `ids` with fresh features (row i of `features`
    /// replaces node `ids[i]`'s stored row) — the online feature-update
    /// scenario.
    pub fn query_with_features(&mut self, ids: &[u32], features: &Mat) -> std::io::Result<Mat> {
        self.query_impl(ids, Some(features))
    }

    fn query_impl(&mut self, ids: &[u32], features: Option<&Mat>) -> std::io::Result<Mat> {
        if ids.is_empty() {
            return Err(io_err("a query must name at least one node".to_string()));
        }
        if let Some(f) = features {
            if f.rows != ids.len() {
                return Err(io_err(format!(
                    "feature override has {} rows for {} ids",
                    f.rows,
                    ids.len()
                )));
            }
        }
        let n_feats = features.map(|f| f.data.len()).unwrap_or(0);
        let mut payload = Vec::with_capacity(1 + ids.len() + n_feats);
        payload.push(f32::from_bits(ids.len() as u32));
        payload.extend(ids.iter().map(|&v| f32::from_bits(v)));
        if let Some(f) = features {
            payload.extend_from_slice(&f.data);
        }
        let tag = Tag::new(self.next_query, 0, Phase::FwdFeat);
        self.next_query += 1;
        frame::write_frame(&mut self.stream, &Frame::Data { src: 1, dst: 0, tag, payload })?;
        self.stream.flush()?;
        match frame::read_frame(&mut self.stream)? {
            Some(Frame::Data { payload, .. }) => {
                if payload.is_empty() || payload.len() % ids.len() != 0 {
                    return Err(io_err(format!(
                        "logits payload of {} values does not shape into {} rows",
                        payload.len(),
                        ids.len()
                    )));
                }
                let cols = payload.len() / ids.len();
                Ok(Mat::from_vec(ids.len(), cols, payload))
            }
            other => Err(io_err(format!("expected a logits frame, got {other:?}"))),
        }
    }

    /// Graceful goodbye (the server also tolerates a plain disconnect).
    pub fn close(mut self) {
        let _ = frame::write_frame(&mut self.stream, &Frame::Shutdown { src: 1 });
        let _ = self.stream.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny_ctx() -> (Graph, ModelConfig, Params) {
        let p = presets::by_name("tiny").unwrap();
        let g = p.build(1);
        let cfg = ModelConfig::from_preset(p);
        let params = Params::init(&cfg, &mut Rng::new(3));
        (g, cfg, params)
    }

    #[test]
    fn shape_mismatches_are_diagnostics() {
        let (g, mut cfg, params) = tiny_ctx();
        cfg.dims[0] += 1;
        let e = Server::from_parts(g, cfg, params).err().expect("should fail");
        assert!(e.to_string().contains("feature dim"), "{e}");
    }

    #[test]
    fn malformed_queries_rejected_without_killing_the_server() {
        let (g, cfg, params) = tiny_ctx();
        let n = g.n;
        let prop = g.mean_propagation_matrix();
        let ctx = ServeCtx {
            n: g.n,
            feat_dim: g.feat_dim(),
            features: g.features,
            prop,
            params,
            kind: cfg.kind,
            n_classes: *cfg.dims.last().unwrap(),
            scope: None,
        };
        let mut backend = NativeBackend::new();
        let pid = backend.register_prop(&ctx.prop);
        let mut scratch: Option<Mat> = None;
        let mut ask = |payload: &[f32]| answer(&ctx, &mut backend, pid, &mut scratch, payload);
        assert!(ask(&[]).is_err());
        assert!(ask(&[f32::from_bits(0)]).is_err());
        // claims 3 ids, carries 1
        assert!(ask(&[f32::from_bits(3), f32::from_bits(0)]).is_err());
        // out-of-range id
        assert!(ask(&[f32::from_bits(1), f32::from_bits(n as u32)]).is_err());
        // wrong feature-override length
        assert!(ask(&[f32::from_bits(1), f32::from_bits(0), 1.0]).is_err());
        // a valid query still works on the same connection state
        let ok = ask(&[f32::from_bits(1), f32::from_bits(0)]).unwrap();
        assert_eq!(ok.len(), ctx.n_classes);
    }

    #[test]
    fn override_scratch_restores_stored_features() {
        let (g, cfg, params) = tiny_ctx();
        let prop = g.mean_propagation_matrix();
        let fd = g.feat_dim();
        let ctx = ServeCtx {
            n: g.n,
            feat_dim: fd,
            features: g.features,
            prop,
            params,
            kind: cfg.kind,
            n_classes: *cfg.dims.last().unwrap(),
            scope: None,
        };
        let mut backend = NativeBackend::new();
        let pid = backend.register_prop(&ctx.prop);
        let mut scratch: Option<Mat> = None;
        let plain = [f32::from_bits(1), f32::from_bits(0)];
        let base = answer(&ctx, &mut backend, pid, &mut scratch, &plain).unwrap();
        // an override query mutates the scratch copy…
        let mut over: Vec<f32> = plain.to_vec();
        over.extend(vec![2.5f32; fd]);
        let changed = answer(&ctx, &mut backend, pid, &mut scratch, &over).unwrap();
        assert_ne!(base, changed, "override should change node 0's logits");
        // …but restores it, so the next plain forward over the scratch
        // state would match the stored features bit-for-bit
        assert_eq!(scratch.as_ref().unwrap().data, ctx.features.data);
        let again = answer(&ctx, &mut backend, pid, &mut scratch, &plain).unwrap();
        assert_eq!(base, again);
    }

    #[test]
    fn scoped_ctx_matches_full_graph_logits_bitwise() {
        let p = presets::by_name("tiny").unwrap();
        let (g, cfg, params) = tiny_ctx();
        let prop = match cfg.kind {
            LayerKind::Gcn => g.propagation_matrix(),
            LayerKind::SageMean => g.mean_propagation_matrix(),
        };
        let mut backend = NativeBackend::new();
        let pid = backend.register_prop(&prop);
        let full = forward_registered(pid, &params, &mut backend, &g.features);
        let parts = 3;
        let mut seen = vec![false; g.n];
        for part in 0..parts {
            let ctx = scoped_ctx(p, p.n, 1, part, parts, cfg.clone(), params.clone()).unwrap();
            let scope = ctx.scope.as_ref().unwrap();
            assert_eq!(ctx.features.rows, scope.closure.len());
            let mut be = NativeBackend::new();
            let spid = be.register_prop(&ctx.prop);
            let logits = forward_registered(spid, &params, &mut be, &ctx.features);
            for &v in &scope.owned {
                assert!(!seen[v as usize], "node {v} owned twice");
                seen[v as usize] = true;
                let row = scope.closure.binary_search(&v).unwrap();
                let got: Vec<u32> = logits.row(row).iter().map(|x| x.to_bits()).collect();
                let want: Vec<u32> = full.row(v as usize).iter().map(|x| x.to_bits()).collect();
                assert_eq!(got, want, "node {v} logits diverge from the full-graph forward");
            }
        }
        assert!(seen.iter().all(|&s| s), "every node must be owned by exactly one shard");
    }
}
