//! Online inference: `pipegcn serve` / `pipegcn query` / `pipegcn
//! route`.
//!
//! The serving workload the ROADMAP calls for, built on the pieces that
//! already exist: a [`Server`] loads a params artifact
//! ([`crate::model::artifact`] — weights + model shape, no optimizer
//! state), rebuilds its preset graph deterministically, binds a TCP
//! listener speaking the existing [`crate::net::frame`] protocol, and
//! answers feature→logit queries with the same kernels (on the
//! [`crate::runtime::pool`]) and numerics as training, so a query over
//! the stored features is **bit-identical** to
//! [`crate::coordinator::full_graph_forward`] (asserted in
//! `tests/serve_e2e.rs` and `tests/serve_tier.rs`). The propagation
//! matrix is built once at bind time and registered once with the
//! executor; the per-query cost is the forward kernels alone — and with
//! the [`tier`] (request coalescing + activation caching, on by
//! default), usually just the final layer over the queried rows.
//!
//! ## Wire protocol
//!
//! One connection, many queries. The client introduces itself with a
//! `Hello` frame — carrying [`PROTO_V2`] in the `addr` field to opt in
//! to version-stamped responses — then sends one `Data` frame per query
//! and reads one `Data` frame back; `Shutdown` (or EOF) ends the
//! connection. A query payload is bit-packed into the f32 channel
//! exactly like the training control messages:
//!
//! ```text
//! [0]            batch size n (u32 bits)
//! [1 .. 1+n]     node ids (u32 bits each)
//! [1+n ..]       optional feature override, n × feat_dim floats,
//!                row i replacing node ids[i]'s stored features
//! ```
//!
//! The response payload is the batch's logits, n × n_classes floats;
//! for a v2 client it is prefixed with one value carrying the
//! answering `artifact_version` (u32 bits), so a rolling reload's
//! mixed-version window is observable per response. Clients that sent
//! a plain hello get the unprefixed v1 payload — old clients keep
//! parsing. Payloads travel as raw bit patterns end to end, so logits
//! reach the client with the exact bits the kernels produced. Queries
//! larger than one frame (64 MiB) are rejected — batch accordingly.
//!
//! `Ctrl` frames carry the serving control plane on the same
//! connection: ping (answers the artifact version), drain (stop
//! accepting, finish in-flight work, exit — how `pipegcn route` takes
//! a replica down for zero-downtime rolls), and reload (hot-swap the
//! params artifact in place).

use crate::comm::{Phase, Tag};
use crate::graph::presets::{self, Preset};
use crate::graph::Graph;
use crate::model::{artifact, LayerKind, ModelConfig, Params};
use crate::net::frame::{self, Frame};
use crate::partition::Method;
use crate::tensor::{Csr, Mat};
use crate::util::error::{Context, Result};
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

pub mod tier;

/// Hello `addr` marker for protocol v2 (version-stamped responses). A
/// plain hello selects v1 payloads, so old clients interoperate.
pub const PROTO_V2: &str = "pgql/2";

/// How often an idle connection wakes to check for a drain.
const IDLE_POLL: Duration = Duration::from_millis(100);

/// How to stand up a server from the CLI.
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// params artifact written by `pipegcn export-params`
    pub params_path: String,
    /// preset whose graph the params were trained on
    pub dataset: String,
    /// dataset build seed — must match the training run's
    pub seed: u64,
    /// listen address (`127.0.0.1:0` picks an ephemeral port)
    pub bind: String,
    /// rebuild the preset at this node count (None = preset default)
    pub nodes: Option<usize>,
    /// serve only partition `I` of `K` (`--shard I/K`): load just the
    /// artifact's required subgraph — owned nodes plus their L-hop
    /// closure — instead of materializing the full graph
    pub shard: Option<(usize, usize)>,
}

/// Everything a query needs, shared read-only across connections. The
/// propagation matrix is built **once** here — per-query work is just
/// the forward kernels, not an O(edges) matrix rebuild. Features and
/// propagation ride in `Arc`s so a reload (new params, same graph) is
/// a cheap context swap, not a graph rebuild.
pub struct ServeCtx {
    /// global node-id space (queries address nodes by global id)
    pub n: usize,
    pub feat_dim: usize,
    /// feature rows the forward runs over: all `n` nodes, or just the
    /// scope's closure rows (row i = `scope.closure[i]`'s features)
    pub features: Arc<Mat>,
    /// normalized propagation matrix for `kind` (full-graph, or
    /// restricted to the closure with **global** degree weights)
    pub prop: Arc<Csr>,
    pub params: Params,
    pub kind: LayerKind,
    pub n_classes: usize,
    /// `Some` when serving one partition's subgraph only
    pub scope: Option<ServeScope>,
    /// content version of the loaded artifact (CRC of its encoding) —
    /// stamped into v2 responses, keys the activation cache
    pub artifact_version: u32,
    /// fingerprint of the graph side of the context (size, structure,
    /// scope) — the activation cache's other key half
    pub graph_version: u64,
}

/// The subgraph a sharded server loaded: partition `part` of `parts`.
/// Only `owned` nodes are answerable — their logits are bit-identical to
/// the full-graph forward because the closure covers every node whose
/// value can reach them within `n_layers` propagation steps, and the
/// restricted propagation matrix keeps the full graph's degree weights.
#[derive(Clone)]
pub struct ServeScope {
    pub part: usize,
    pub parts: usize,
    /// global ids this shard answers for, ascending
    pub owned: Vec<u32>,
    /// global ids of the L-hop closure, ascending — the row space of
    /// `features` and `prop`
    pub closure: Vec<u32>,
}

/// Mutable server state shared by the accept loop, every connection
/// handler, and the tier executor: the current context (swapped
/// atomically on reload) and the drain flag.
pub struct ServeState {
    ctx: Mutex<Arc<ServeCtx>>,
    draining: AtomicBool,
}

impl ServeState {
    pub fn new(ctx: ServeCtx) -> Arc<ServeState> {
        crate::obs::global()
            .gauge("serve_artifact_version", &[])
            .set(ctx.artifact_version as f64);
        Arc::new(ServeState { ctx: Mutex::new(Arc::new(ctx)), draining: AtomicBool::new(false) })
    }

    /// Snapshot of the current context (cheap `Arc` clone).
    pub fn current(&self) -> Arc<ServeCtx> {
        self.ctx.lock().unwrap().clone()
    }

    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    /// Stop accepting new connections; in-flight queries finish, then
    /// [`Server::run_tier`] returns.
    pub fn start_drain(&self) {
        self.draining.store(true, Ordering::SeqCst);
    }

    /// Hot-swap the params artifact: load + verify `path`, check it
    /// fits this server's graph, and publish a new context. Queries
    /// already executing finish on the old weights (their responses
    /// carry the old stamp); the next batch picks up the new ones.
    /// Returns the new `artifact_version`.
    pub fn reload(&self, path: &str) -> std::result::Result<u32, String> {
        let pf = artifact::load(path).map_err(|e| e.to_string())?;
        let cur = self.current();
        if pf.config.kind != cur.kind {
            return Err(
                "reload cannot change the layer kind — the propagation matrix depends on it"
                    .to_string(),
            );
        }
        if pf.config.dims[0] != cur.feat_dim {
            return Err(format!(
                "reload artifact expects feature dim {} but this server has {}",
                pf.config.dims[0], cur.feat_dim
            ));
        }
        if *pf.config.dims.last().unwrap() != cur.n_classes {
            return Err(format!(
                "reload artifact produces {} classes but this server has {}",
                pf.config.dims.last().unwrap(),
                cur.n_classes
            ));
        }
        if cur.scope.is_some() && pf.config.n_layers() != cur.params.layers.len() {
            return Err(
                "reload on a sharded server cannot change the layer count — the loaded \
                 closure is exactly layer-count hops deep"
                    .to_string(),
            );
        }
        let version = artifact::content_version(&pf);
        let next = ServeCtx {
            n: cur.n,
            feat_dim: cur.feat_dim,
            features: cur.features.clone(),
            prop: cur.prop.clone(),
            params: pf.params,
            kind: cur.kind,
            n_classes: cur.n_classes,
            scope: cur.scope.clone(),
            artifact_version: version,
            graph_version: cur.graph_version,
        };
        *self.ctx.lock().unwrap() = Arc::new(next);
        let reg = crate::obs::global();
        reg.counter("serve_reloads_total", &[]).inc();
        reg.gauge("serve_artifact_version", &[]).set(version as f64);
        Ok(version)
    }
}

/// A bound (not yet accepting) inference server.
pub struct Server {
    listener: TcpListener,
    state: Arc<ServeState>,
    addr: String,
}

fn io_err(msg: String) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
}

impl Server {
    /// Load the artifact, rebuild the preset graph (or, with
    /// `shard = Some((part, parts))`, only the artifact's required
    /// subgraph — `part`'s owned nodes plus their L-hop closure),
    /// validate that the model fits it, and bind the listener.
    pub fn bind(o: &ServeOpts) -> Result<Server> {
        let pf = artifact::load(&o.params_path)?;
        let preset = presets::by_name(&o.dataset).ok_or_else(|| {
            crate::err_msg!("unknown preset '{}' (try: {:?})", o.dataset, presets::names())
        })?;
        match o.shard {
            None => {
                let graph = match o.nodes {
                    Some(n) => preset.build_scaled(n, o.seed),
                    None => preset.build(o.seed),
                };
                Server::from_parts_on(graph, pf.config, pf.params, &o.bind)
            }
            Some((part, parts)) => {
                if parts == 0 || part >= parts {
                    crate::bail!("--shard {part}/{parts}: part must be < parts");
                }
                let n = o.nodes.unwrap_or(preset.n);
                let ctx = scoped_ctx(preset, n, o.seed, part, parts, pf.config, pf.params)?;
                Server::from_ctx(ctx, &o.bind)
            }
        }
    }

    /// Stand up a server from in-memory parts (tests, benches, library
    /// embedding) on an ephemeral localhost port.
    pub fn from_parts(graph: Graph, config: ModelConfig, params: Params) -> Result<Server> {
        Server::from_parts_on(graph, config, params, "127.0.0.1:0")
    }

    fn from_parts_on(
        graph: Graph,
        config: ModelConfig,
        params: Params,
        bind: &str,
    ) -> Result<Server> {
        Server::from_ctx(ctx_from_parts(graph, config, params)?, bind)
    }

    /// Bind a listener around an already-assembled context.
    fn from_ctx(ctx: ServeCtx, bind: &str) -> Result<Server> {
        let listener = TcpListener::bind(bind).with_context(|| format!("binding {bind}"))?;
        let addr = listener.local_addr()?.to_string();
        Ok(Server { listener, state: ServeState::new(ctx), addr })
    }

    /// The bound address (`host:port`).
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Shared query context (library embedding; reflects reloads).
    pub fn ctx(&self) -> Arc<ServeCtx> {
        self.state.current()
    }

    /// The shared mutable state (drain flag, reload entry point).
    pub fn state(&self) -> Arc<ServeState> {
        self.state.clone()
    }

    /// [`Server::run_tier`] with default tier knobs (1 ms batch
    /// window, max batch 32, activation caching on).
    pub fn run(self, max_conns: Option<usize>) -> Result<()> {
        self.run_tier(max_conns, tier::TierOpts::default())
    }

    /// Accept connections, one handler thread each, all queries funneled
    /// through the coalescing executor. Returns after `max_conns`
    /// connections have been accepted and finished (deterministic
    /// shutdown for tests and the CI smoke job) — or, at any
    /// `max_conns`, after a `Ctrl` drain: the listener stops admitting,
    /// every in-flight query and connection finishes, the executor
    /// drains, then this returns `Ok`. A malformed query closes its
    /// connection with a logged diagnostic — it never takes the server
    /// down.
    pub fn run_tier(self, max_conns: Option<usize>, tier: tier::TierOpts) -> Result<()> {
        let coalescer = tier::Coalescer::start(self.state.clone(), tier);
        self.listener.set_nonblocking(true).context("serve listener nonblocking")?;
        let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
        let mut served = 0usize;
        loop {
            if self.state.is_draining() {
                break;
            }
            if let Some(m) = max_conns {
                if served >= m {
                    break;
                }
            }
            match self.listener.accept() {
                Ok((stream, peer)) => {
                    served += 1;
                    let state = self.state.clone();
                    let sub = coalescer.submitter();
                    handles.push(std::thread::spawn(move || {
                        if let Err(e) = handle_conn(&state, &sub, stream) {
                            eprintln!("serve: connection {peer}: {e}");
                        }
                    }));
                    // reap finished handlers so an unbounded server does
                    // not grow a handle per connection forever
                    handles.retain(|h| !h.is_finished());
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => return Err(e).context("accepting a query connection"),
            }
        }
        for h in handles {
            let _ = h.join();
        }
        // joins the executor after the last submitter is gone
        drop(coalescer);
        Ok(())
    }
}

/// Assemble an unscoped serving context from in-memory parts — the
/// validation, propagation build, and version stamping shared by
/// [`Server::bind`], the tier tests, and the benches.
pub fn ctx_from_parts(graph: Graph, config: ModelConfig, params: Params) -> Result<ServeCtx> {
    if config.dims[0] != graph.feat_dim() {
        crate::bail!(
            "params expect feature dim {} but the graph has {} — wrong dataset or seed?",
            config.dims[0],
            graph.feat_dim()
        );
    }
    let n_classes = *config.dims.last().unwrap();
    if n_classes != graph.labels.n_classes() {
        crate::bail!(
            "params produce {} classes but the graph has {} — wrong dataset or seed?",
            n_classes,
            graph.labels.n_classes()
        );
    }
    let prop = match config.kind {
        LayerKind::Gcn => graph.propagation_matrix(),
        LayerKind::SageMean => graph.mean_propagation_matrix(),
    };
    let feat_dim = graph.feat_dim();
    // version the artifact by its encoded content, then take the
    // params back out (no weight clone)
    let pf = artifact::ParamsFile { config, params };
    let artifact_version = artifact::content_version(&pf);
    let artifact::ParamsFile { config, params } = pf;
    let graph_version = graph_version(graph.n, &prop, feat_dim, n_classes, None);
    Ok(ServeCtx {
        n: graph.n,
        feat_dim,
        features: Arc::new(graph.features),
        prop: Arc::new(prop),
        params,
        kind: config.kind,
        n_classes,
        scope: None,
        artifact_version,
        graph_version,
    })
}

/// A stable fingerprint (FNV-1a) of the graph side of a context: size,
/// propagation structure, dims, and shard scope. Together with
/// `artifact_version` it keys the activation cache — equal keys mean
/// byte-identical answers.
fn graph_version(
    n: usize,
    prop: &Csr,
    feat_dim: usize,
    n_classes: usize,
    scope: Option<(usize, usize)>,
) -> u64 {
    fn mix(mut h: u64, v: u64) -> u64 {
        for b in v.to_le_bytes() {
            h = (h ^ u64::from(b)).wrapping_mul(0x100_0000_01b3);
        }
        h
    }
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    h = mix(h, n as u64);
    h = mix(h, prop.nnz() as u64);
    h = mix(h, feat_dim as u64);
    h = mix(h, n_classes as u64);
    match scope {
        None => h = mix(h, 0),
        Some((part, parts)) => {
            h = mix(h, 1);
            h = mix(h, part as u64);
            h = mix(h, parts as u64);
        }
    }
    h
}

/// Build a sharded serving context: partition the topology, take
/// partition `part`'s owned nodes plus their `n_layers`-hop closure,
/// materialize features for the closure only (one replay of the
/// deterministic shard builder), and restrict the propagation matrix to
/// closure×closure while keeping **full-graph** degree weights. Owned
/// logits stay bit-identical to the full-graph forward: after layer `l`
/// the values on the closure's `(L-l)`-hop interior match the full run
/// (boundary rows drop out-of-closure terms, but no owned node ever
/// reads one within `L` steps), and the restricted matrix is a monotone
/// renumbering of the full matrix's closure rows, so per-row summation
/// order in the SpMM is unchanged.
fn scoped_ctx(
    preset: &Preset,
    n: usize,
    seed: u64,
    part: usize,
    parts: usize,
    config: ModelConfig,
    params: Params,
) -> Result<ServeCtx> {
    let pf = artifact::ParamsFile { config, params };
    let artifact_version = artifact::content_version(&pf);
    let artifact::ParamsFile { config, params } = pf;
    let topo = preset.build_topology_scaled(n, seed);
    let adj = topo.adj();
    let pt = crate::partition::partition_adj(adj, parts, Method::Multilevel, seed);
    let owned: Vec<u32> = (0..n as u32).filter(|&v| pt.assign[v as usize] == part as u32).collect();
    // L-hop ball around the owned set: every node a forward of
    // `n_layers` propagation steps can read from
    let mut in_closure = vec![false; n];
    for &v in &owned {
        in_closure[v as usize] = true;
    }
    let mut frontier: Vec<u32> = owned.clone();
    for _ in 0..config.n_layers() {
        let mut next = Vec::new();
        for &v in &frontier {
            for &u in adj.neighbors(v as usize) {
                if !in_closure[u as usize] {
                    in_closure[u as usize] = true;
                    next.push(u);
                }
            }
        }
        frontier = next;
    }
    let closure: Vec<u32> = (0..n as u32).filter(|&v| in_closure[v as usize]).collect();
    // features for exactly the closure: replay the generator with an
    // indicator assignment under which "partition 0" owns the closure
    let indicator: Vec<u32> = in_closure.iter().map(|&k| if k { 0 } else { 1 }).collect();
    let shard = preset.build_shard_scaled(n, seed, &indicator, 0);
    debug_assert_eq!(shard.owned, closure);
    if config.dims[0] != shard.feat_dim() {
        crate::bail!(
            "params expect feature dim {} but the graph has {} — wrong dataset or seed?",
            config.dims[0],
            shard.feat_dim()
        );
    }
    let n_classes = *config.dims.last().unwrap();
    if n_classes != shard.labels.n_classes() {
        crate::bail!(
            "params produce {} classes but the graph has {} — wrong dataset or seed?",
            n_classes,
            shard.labels.n_classes()
        );
    }
    let local = |u: u32| closure.binary_search(&u).unwrap() as u32;
    let m = closure.len();
    let mut trip = Vec::new();
    match config.kind {
        LayerKind::Gcn => {
            for (i, &v) in closure.iter().enumerate() {
                let dv = (adj.degree(v as usize) + 1) as f32;
                trip.push((i as u32, i as u32, 1.0 / dv));
                for &u in adj.neighbors(v as usize) {
                    if in_closure[u as usize] {
                        let du = (adj.degree(u as usize) + 1) as f32;
                        trip.push((i as u32, local(u), 1.0 / (dv.sqrt() * du.sqrt())));
                    }
                }
            }
        }
        LayerKind::SageMean => {
            for (i, &v) in closure.iter().enumerate() {
                let inv = 1.0 / (adj.degree(v as usize) + 1) as f32;
                trip.push((i as u32, i as u32, inv));
                for &u in adj.neighbors(v as usize) {
                    if in_closure[u as usize] {
                        trip.push((i as u32, local(u), inv));
                    }
                }
            }
        }
    }
    let prop = Csr::from_triplets(m, m, trip);
    let feat_dim = shard.feat_dim();
    let graph_version = graph_version(n, &prop, feat_dim, n_classes, Some((part, parts)));
    Ok(ServeCtx {
        n,
        feat_dim,
        features: Arc::new(shard.features),
        prop: Arc::new(prop),
        params,
        kind: config.kind,
        n_classes,
        scope: Some(ServeScope { part, parts, owned, closure }),
        artifact_version,
        graph_version,
    })
}

/// A decoded, validated query: scope-mapped feature/logit rows (in
/// request order, duplicates allowed) and the optional flattened
/// feature override (`rows.len() × feat_dim`, empty = none).
pub struct Query {
    pub rows: Vec<usize>,
    pub feats: Vec<f32>,
}

/// Decode one query payload against `ctx`. Validation errors come back
/// as messages (the connection is closed with a diagnostic, the server
/// keeps running).
pub fn parse_query(ctx: &ServeCtx, payload: &[f32]) -> std::result::Result<Query, String> {
    if payload.is_empty() {
        return Err("empty query".to_string());
    }
    let n = payload[0].to_bits() as usize;
    if n == 0 {
        return Err("query names no nodes".to_string());
    }
    if payload.len() < 1 + n {
        return Err(format!("query claims {n} ids but carries {}", payload.len() - 1));
    }
    let ids: Vec<u32> = payload[1..1 + n].iter().map(|v| v.to_bits()).collect();
    // map global ids to feature/logit rows (identity when unscoped)
    let mut rows = Vec::with_capacity(ids.len());
    for &id in &ids {
        if id as usize >= ctx.n {
            return Err(format!("node id {id} out of range (graph has {} nodes)", ctx.n));
        }
        let row = match &ctx.scope {
            None => id as usize,
            Some(s) => {
                if s.owned.binary_search(&id).is_err() {
                    return Err(format!(
                        "node id {id} is not owned by shard {}/{} — query the rank that owns it",
                        s.part, s.parts
                    ));
                }
                s.closure.binary_search(&id).unwrap()
            }
        };
        rows.push(row);
    }
    let feats = &payload[1 + n..];
    if !feats.is_empty() && feats.len() != n * ctx.feat_dim {
        return Err(format!(
            "feature override must be {n}×{} values, got {}",
            ctx.feat_dim,
            feats.len()
        ));
    }
    Ok(Query { rows, feats: feats.to_vec() })
}

/// Serve one client connection: parse queries, submit them to the
/// coalescing executor, stream stamped responses back. Idle
/// connections poll for the drain flag (via `peek` under a read
/// timeout, so a frame mid-flight is never split) and close when the
/// server drains.
fn handle_conn(
    state: &ServeState,
    sub: &tier::Submitter,
    mut stream: TcpStream,
) -> std::io::Result<()> {
    // connection-lifetime metrics: the gauge must fall on *every* exit
    // path (clean shutdown, malformed query, I/O error), so its
    // decrement rides a drop guard
    let reg = crate::obs::global();
    let lat = reg.histogram("serve_query_ms", &[]);
    let queries = reg.counter("serve_queries_total", &[]);
    struct ConnGuard(crate::obs::Gauge);
    impl Drop for ConnGuard {
        fn drop(&mut self) {
            self.0.add(-1.0);
        }
    }
    let active = reg.gauge("serve_active_connections", &[]);
    active.add(1.0);
    let _guard = ConnGuard(active);
    let mut v2 = false;
    stream.set_read_timeout(Some(IDLE_POLL))?;
    loop {
        let mut peek = [0u8; 1];
        match stream.peek(&mut peek) {
            Ok(0) => return Ok(()), // EOF
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if state.is_draining() {
                    return Ok(());
                }
                continue;
            }
            Err(e) => return Err(e),
        }
        // a frame is on the wire: read it whole, then re-arm the poll
        stream.set_read_timeout(None)?;
        let f = frame::read_frame(&mut stream)?;
        stream.set_read_timeout(Some(IDLE_POLL))?;
        match f {
            None | Some(Frame::Shutdown { .. }) => return Ok(()),
            Some(Frame::Hello { addr, .. }) => v2 = addr == PROTO_V2,
            Some(Frame::Data { tag, payload, .. }) => {
                let watch = crate::util::timer::Stopwatch::start();
                let ctx = state.current();
                let q = parse_query(&ctx, &payload).map_err(io_err)?;
                let reply = sub.submit(q).map_err(io_err)?;
                let mut out = Vec::with_capacity(reply.logits.len() + 1);
                if v2 {
                    out.push(f32::from_bits(reply.artifact_version));
                }
                out.extend_from_slice(&reply.logits);
                frame::write_frame(
                    &mut stream,
                    &Frame::Data { src: 0, dst: 1, tag, payload: out },
                )?;
                stream.flush()?;
                lat.record(watch.elapsed_secs() * 1e3);
                queries.inc();
            }
            Some(Frame::Ctrl { op, arg }) => {
                let reply = match op {
                    frame::CTRL_PING => Ok(state.current().artifact_version.to_string()),
                    frame::CTRL_DRAIN => {
                        state.start_drain();
                        Ok("draining".to_string())
                    }
                    frame::CTRL_RELOAD => state.reload(&arg).map(|v| v.to_string()),
                    other => Err(format!("unknown ctrl op {other}")),
                };
                let f = match reply {
                    Ok(arg) => Frame::Ctrl { op: frame::CTRL_ACK, arg },
                    Err(arg) => Frame::Ctrl { op: frame::CTRL_ERR, arg },
                };
                frame::write_frame(&mut stream, &f)?;
                stream.flush()?;
            }
            Some(other) => {
                return Err(io_err(format!("unexpected frame in a query stream: {other:?}")))
            }
        }
    }
}

/// A blocking query client for one server (or router) connection.
pub struct Client {
    stream: TcpStream,
    next_query: u32,
    v2: bool,
    last_version: Option<u32>,
}

impl Client {
    /// Connect speaking protocol v2: responses carry the answering
    /// artifact version (see [`Client::artifact_version`]).
    pub fn connect(addr: &str) -> std::io::Result<Client> {
        Client::connect_proto(addr, true)
    }

    /// Connect speaking the v1 protocol (unstamped responses) — what a
    /// pre-tier client sends; kept callable so compatibility stays
    /// testable.
    pub fn connect_v1(addr: &str) -> std::io::Result<Client> {
        Client::connect_proto(addr, false)
    }

    fn connect_proto(addr: &str, v2: bool) -> std::io::Result<Client> {
        let mut stream = TcpStream::connect(addr)?;
        let hello = if v2 { PROTO_V2.to_string() } else { String::new() };
        frame::write_frame(&mut stream, &Frame::Hello { rank: 0, addr: hello })?;
        stream.flush()?;
        Ok(Client { stream, next_query: 1, v2, last_version: None })
    }

    /// The artifact version stamped on the most recent response (None
    /// before the first query or on a v1 connection).
    pub fn artifact_version(&self) -> Option<u32> {
        self.last_version
    }

    /// Logits for `ids` over the graph's stored features — bit-identical
    /// to the server-side full-graph forward. Returns an
    /// `ids.len() × n_classes` matrix, one row per queried node.
    pub fn query(&mut self, ids: &[u32]) -> std::io::Result<Mat> {
        self.query_impl(ids, None)
    }

    /// Logits for `ids` with fresh features (row i of `features`
    /// replaces node `ids[i]`'s stored row) — the online feature-update
    /// scenario.
    pub fn query_with_features(&mut self, ids: &[u32], features: &Mat) -> std::io::Result<Mat> {
        self.query_impl(ids, Some(features))
    }

    fn query_impl(&mut self, ids: &[u32], features: Option<&Mat>) -> std::io::Result<Mat> {
        if ids.is_empty() {
            return Err(io_err("a query must name at least one node".to_string()));
        }
        if let Some(f) = features {
            if f.rows != ids.len() {
                return Err(io_err(format!(
                    "feature override has {} rows for {} ids",
                    f.rows,
                    ids.len()
                )));
            }
        }
        let n_feats = features.map(|f| f.data.len()).unwrap_or(0);
        let mut payload = Vec::with_capacity(1 + ids.len() + n_feats);
        payload.push(f32::from_bits(ids.len() as u32));
        payload.extend(ids.iter().map(|&v| f32::from_bits(v)));
        if let Some(f) = features {
            payload.extend_from_slice(&f.data);
        }
        let tag = Tag::new(self.next_query, 0, Phase::FwdFeat);
        self.next_query += 1;
        frame::write_frame(&mut self.stream, &Frame::Data { src: 1, dst: 0, tag, payload })?;
        self.stream.flush()?;
        match frame::read_frame(&mut self.stream)? {
            Some(Frame::Data { payload, .. }) => {
                let body = if self.v2 {
                    if payload.is_empty() {
                        return Err(io_err(
                            "v2 response is missing its version stamp".to_string(),
                        ));
                    }
                    self.last_version = Some(payload[0].to_bits());
                    payload[1..].to_vec()
                } else {
                    payload
                };
                if body.is_empty() || body.len() % ids.len() != 0 {
                    return Err(io_err(format!(
                        "logits payload of {} values does not shape into {} rows",
                        body.len(),
                        ids.len()
                    )));
                }
                let cols = body.len() / ids.len();
                Ok(Mat::from_vec(ids.len(), cols, body))
            }
            other => Err(io_err(format!("expected a logits frame, got {other:?}"))),
        }
    }

    /// One ctrl round trip; the ack's argument string on success.
    fn ctrl(&mut self, op: u8, arg: &str) -> std::io::Result<String> {
        frame::write_frame(&mut self.stream, &Frame::Ctrl { op, arg: arg.to_string() })?;
        self.stream.flush()?;
        match frame::read_frame(&mut self.stream)? {
            Some(Frame::Ctrl { op: frame::CTRL_ACK, arg }) => Ok(arg),
            Some(Frame::Ctrl { op: frame::CTRL_ERR, arg }) => Err(io_err(arg)),
            other => Err(io_err(format!("expected a ctrl reply, got {other:?}"))),
        }
    }

    /// Health check: the server's (or, at a router, the tier's) status
    /// string — a serve replica answers with its artifact version.
    pub fn ping(&mut self) -> std::io::Result<String> {
        self.ctrl(frame::CTRL_PING, "")
    }

    /// Ask the server to drain: stop accepting, finish in-flight
    /// queries, exit its run loop.
    pub fn drain(&mut self) -> std::io::Result<()> {
        self.ctrl(frame::CTRL_DRAIN, "").map(|_| ())
    }

    /// Hot-swap the server's params artifact (at a router: a rolling
    /// reload across replicas). Returns the ack detail — the new
    /// version, or per-replica `addr=version` pairs from a router.
    pub fn reload(&mut self, path: &str) -> std::io::Result<String> {
        self.ctrl(frame::CTRL_RELOAD, path)
    }

    /// Graceful goodbye (the server also tolerates a plain disconnect).
    pub fn close(mut self) {
        let _ = frame::write_frame(&mut self.stream, &Frame::Shutdown { src: 1 });
        let _ = self.stream.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::forward_registered;
    use crate::runtime::native::NativeBackend;
    use crate::runtime::Backend;
    use crate::util::rng::Rng;

    fn tiny_ctx() -> (Graph, ModelConfig, Params) {
        let p = presets::by_name("tiny").unwrap();
        let g = p.build(1);
        let cfg = ModelConfig::from_preset(p);
        let params = Params::init(&cfg, &mut Rng::new(3));
        (g, cfg, params)
    }

    #[test]
    fn shape_mismatches_are_diagnostics() {
        let (g, mut cfg, params) = tiny_ctx();
        cfg.dims[0] += 1;
        let e = Server::from_parts(g, cfg, params).err().expect("should fail");
        assert!(e.to_string().contains("feature dim"), "{e}");
    }

    #[test]
    fn malformed_queries_rejected() {
        let (g, cfg, params) = tiny_ctx();
        let n = g.n;
        let fd = g.feat_dim();
        let ctx = ctx_from_parts(g, cfg, params).unwrap();
        assert!(parse_query(&ctx, &[]).is_err());
        assert!(parse_query(&ctx, &[f32::from_bits(0)]).is_err());
        // claims 3 ids, carries 1
        assert!(parse_query(&ctx, &[f32::from_bits(3), f32::from_bits(0)]).is_err());
        // out-of-range id
        assert!(parse_query(&ctx, &[f32::from_bits(1), f32::from_bits(n as u32)]).is_err());
        // wrong feature-override length
        assert!(parse_query(&ctx, &[f32::from_bits(1), f32::from_bits(0), 1.0]).is_err());
        // a valid plain query maps ids to rows in order
        let q = parse_query(&ctx, &[f32::from_bits(2), f32::from_bits(3), f32::from_bits(0)])
            .unwrap();
        assert_eq!(q.rows, vec![3, 0]);
        assert!(q.feats.is_empty());
        // a valid override carries n × feat_dim values
        let mut over = vec![f32::from_bits(1), f32::from_bits(0)];
        over.extend(vec![0.5f32; fd]);
        let q = parse_query(&ctx, &over).unwrap();
        assert_eq!(q.feats.len(), fd);
    }

    #[test]
    fn reload_swaps_params_and_version() {
        let (g, cfg, params) = tiny_ctx();
        let params2 = Params::init(&cfg, &mut Rng::new(44));
        let pf2 = artifact::ParamsFile { config: cfg.clone(), params: params2.clone() };
        let v2 = artifact::content_version(&pf2);
        let path = format!("/tmp/pipegcn_reload_{}.pgp", std::process::id());
        artifact::save(&path, &pf2).unwrap();
        let state = ServeState::new(ctx_from_parts(g, cfg.clone(), params).unwrap());
        let v1 = state.current().artifact_version;
        assert_ne!(v1, v2, "distinct params must version differently");
        let got = state.reload(&path).unwrap();
        assert_eq!(got, v2);
        assert_eq!(state.current().artifact_version, v2);
        assert_eq!(state.current().params, params2);
        // graph side is untouched — same Arcs, same graph_version
        let cur = state.current();
        assert_eq!(
            cur.graph_version,
            graph_version(cur.n, &cur.prop, cur.feat_dim, cur.n_classes, None)
        );
        // a mismatched artifact is rejected and the state keeps serving
        let mut bad_cfg = cfg.clone();
        bad_cfg.dims[0] += 1;
        let bad = artifact::ParamsFile {
            params: Params::init(&bad_cfg, &mut Rng::new(5)),
            config: bad_cfg,
        };
        artifact::save(&path, &bad).unwrap();
        let e = state.reload(&path).unwrap_err();
        assert!(e.contains("feature dim"), "{e}");
        assert_eq!(state.current().artifact_version, v2);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn scoped_ctx_matches_full_graph_logits_bitwise() {
        let p = presets::by_name("tiny").unwrap();
        let (g, cfg, params) = tiny_ctx();
        let prop = match cfg.kind {
            LayerKind::Gcn => g.propagation_matrix(),
            LayerKind::SageMean => g.mean_propagation_matrix(),
        };
        let mut backend = NativeBackend::new();
        let pid = backend.register_prop(&prop);
        let full = forward_registered(pid, &params, &mut backend, &g.features);
        let parts = 3;
        let mut seen = vec![false; g.n];
        for part in 0..parts {
            let ctx = scoped_ctx(p, p.n, 1, part, parts, cfg.clone(), params.clone()).unwrap();
            let scope = ctx.scope.as_ref().unwrap();
            assert_eq!(ctx.features.rows, scope.closure.len());
            let mut be = NativeBackend::new();
            let spid = be.register_prop(&ctx.prop);
            let logits = forward_registered(spid, &params, &mut be, &ctx.features);
            for &v in &scope.owned {
                assert!(!seen[v as usize], "node {v} owned twice");
                seen[v as usize] = true;
                let row = scope.closure.binary_search(&v).unwrap();
                let got: Vec<u32> = logits.row(row).iter().map(|x| x.to_bits()).collect();
                let want: Vec<u32> = full.row(v as usize).iter().map(|x| x.to_bits()).collect();
                assert_eq!(got, want, "node {v} logits diverge from the full-graph forward");
            }
        }
        assert!(seen.iter().all(|&s| s), "every node must be owned by exactly one shard");
    }
}
