//! Online inference: `pipegcn serve` / `pipegcn query`.
//!
//! The serving workload the ROADMAP calls for, built on the pieces that
//! already exist: a [`Server`] loads a params artifact
//! ([`crate::model::artifact`] — weights + model shape, no optimizer
//! state), rebuilds its preset graph deterministically, binds a TCP
//! listener speaking the existing [`crate::net::frame`] protocol, and
//! answers feature→logit queries by running the batch through
//! [`crate::coordinator::forward_registered`] — the same kernels (on
//! the [`crate::runtime::pool`]) and numerics as training, so a query
//! over the stored features is **bit-identical** to
//! [`crate::coordinator::full_graph_forward`] (asserted in
//! `tests/serve_e2e.rs`). The propagation matrix is built once at bind
//! time and registered once per connection; the per-query cost is the
//! forward kernels alone.
//!
//! ## Wire protocol
//!
//! One connection, many queries. The client introduces itself with a
//! `Hello` frame, then sends one `Data` frame per query and reads one
//! `Data` frame back; `Shutdown` (or EOF) ends the connection. A query
//! payload is bit-packed into the f32 channel exactly like the training
//! control messages:
//!
//! ```text
//! [0]            batch size n (u32 bits)
//! [1 .. 1+n]     node ids (u32 bits each)
//! [1+n ..]       optional feature override, n × feat_dim floats,
//!                row i replacing node ids[i]'s stored features
//! ```
//!
//! The response payload is the batch's logits, n × n_classes floats.
//! Payloads travel as raw bit patterns end to end, so logits reach the
//! client with the exact bits the kernels produced. Queries larger than
//! one frame (64 MiB) are rejected — batch accordingly.

use crate::comm::{Phase, Tag};
use crate::coordinator::forward_registered;
use crate::graph::{presets, Graph};
use crate::model::{artifact, LayerKind, ModelConfig, Params};
use crate::net::frame::{self, Frame};
use crate::runtime::native::NativeBackend;
use crate::runtime::Backend;
use crate::tensor::{Csr, Mat};
use crate::util::error::{Context, Result};
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

/// How to stand up a server from the CLI.
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// params artifact written by `pipegcn export-params`
    pub params_path: String,
    /// preset whose graph the params were trained on
    pub dataset: String,
    /// dataset build seed — must match the training run's
    pub seed: u64,
    /// listen address (`127.0.0.1:0` picks an ephemeral port)
    pub bind: String,
}

/// Everything a query needs, shared read-only across connections. The
/// propagation matrix is built **once** here — per-query work is just
/// the forward kernels, not an O(edges) matrix rebuild.
pub struct ServeCtx {
    pub graph: Graph,
    /// normalized propagation matrix for `kind`, prebuilt from `graph`
    pub prop: Csr,
    pub params: Params,
    pub kind: LayerKind,
    pub n_classes: usize,
}

/// A bound (not yet accepting) inference server.
pub struct Server {
    listener: TcpListener,
    ctx: Arc<ServeCtx>,
    addr: String,
}

fn io_err(msg: String) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
}

impl Server {
    /// Load the artifact, rebuild the preset graph, validate that the
    /// model fits it, and bind the listener.
    pub fn bind(o: &ServeOpts) -> Result<Server> {
        let pf = artifact::load(&o.params_path)?;
        let preset = presets::by_name(&o.dataset).ok_or_else(|| {
            crate::err_msg!("unknown preset '{}' (try: {:?})", o.dataset, presets::names())
        })?;
        let graph = preset.build(o.seed);
        Server::from_parts_on(graph, pf.config, pf.params, &o.bind)
    }

    /// Stand up a server from in-memory parts (tests, benches, library
    /// embedding) on an ephemeral localhost port.
    pub fn from_parts(graph: Graph, config: ModelConfig, params: Params) -> Result<Server> {
        Server::from_parts_on(graph, config, params, "127.0.0.1:0")
    }

    fn from_parts_on(
        graph: Graph,
        config: ModelConfig,
        params: Params,
        bind: &str,
    ) -> Result<Server> {
        if config.dims[0] != graph.feat_dim() {
            crate::bail!(
                "params expect feature dim {} but the graph has {} — wrong dataset or seed?",
                config.dims[0],
                graph.feat_dim()
            );
        }
        let n_classes = *config.dims.last().unwrap();
        if n_classes != graph.labels.n_classes() {
            crate::bail!(
                "params produce {} classes but the graph has {} — wrong dataset or seed?",
                n_classes,
                graph.labels.n_classes()
            );
        }
        let listener =
            TcpListener::bind(bind).with_context(|| format!("binding {bind}"))?;
        let addr = listener.local_addr()?.to_string();
        let prop = match config.kind {
            LayerKind::Gcn => graph.propagation_matrix(),
            LayerKind::SageMean => graph.mean_propagation_matrix(),
        };
        Ok(Server {
            listener,
            ctx: Arc::new(ServeCtx { graph, prop, params, kind: config.kind, n_classes }),
            addr,
        })
    }

    /// The bound address (`host:port`).
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Shared query context (library embedding).
    pub fn ctx(&self) -> Arc<ServeCtx> {
        self.ctx.clone()
    }

    /// Accept connections, one handler thread each. With `max_conns`,
    /// return after that many connections finish (deterministic
    /// shutdown for tests and the CI smoke job); without it, serve
    /// forever with handler threads detached, so nothing accumulates
    /// per connection. A malformed query closes its connection with a
    /// logged diagnostic — it never takes the server down.
    pub fn run(self, max_conns: Option<usize>) -> Result<()> {
        let mut handles = Vec::new();
        let mut served = 0usize;
        loop {
            if let Some(m) = max_conns {
                if served >= m {
                    break;
                }
            }
            let (stream, peer) =
                self.listener.accept().context("accepting a query connection")?;
            served += 1;
            let ctx = self.ctx.clone();
            let handle = std::thread::spawn(move || {
                if let Err(e) = handle_conn(&ctx, stream) {
                    eprintln!("serve: connection {peer}: {e}");
                }
            });
            // only a bounded run joins its handlers; an unbounded server
            // must not grow a handle per connection forever
            if max_conns.is_some() {
                handles.push(handle);
            }
        }
        for h in handles {
            let _ = h.join();
        }
        Ok(())
    }
}

/// Serve one client connection: loop over query frames until shutdown.
/// The propagation matrix is registered with the connection's backend
/// exactly once — queries pay only for the forward kernels.
fn handle_conn(ctx: &ServeCtx, mut stream: TcpStream) -> std::io::Result<()> {
    // connection-lifetime metrics: the gauge must fall on *every* exit
    // path (clean shutdown, malformed query, I/O error), so its
    // decrement rides a drop guard
    let reg = crate::obs::global();
    let lat = reg.histogram("serve_query_ms", &[]);
    let queries = reg.counter("serve_queries_total", &[]);
    struct ConnGuard(crate::obs::Gauge);
    impl Drop for ConnGuard {
        fn drop(&mut self) {
            self.0.add(-1.0);
        }
    }
    let active = reg.gauge("serve_active_connections", &[]);
    active.add(1.0);
    let _guard = ConnGuard(active);
    let mut backend = NativeBackend::new();
    let prop_id = backend.register_prop(&ctx.prop);
    loop {
        match frame::read_frame(&mut stream)? {
            None | Some(Frame::Shutdown { .. }) => return Ok(()),
            Some(Frame::Hello { .. }) => {}
            Some(Frame::Data { tag, payload, .. }) => {
                let watch = crate::util::timer::Stopwatch::start();
                let logits =
                    answer(ctx, &mut backend, prop_id, &payload).map_err(io_err)?;
                frame::write_frame(
                    &mut stream,
                    &Frame::Data { src: 0, dst: 1, tag, payload: logits },
                )?;
                stream.flush()?;
                lat.record(watch.elapsed_secs() * 1e3);
                queries.inc();
            }
            Some(other) => {
                return Err(io_err(format!("unexpected frame in a query stream: {other:?}")))
            }
        }
    }
}

/// Decode one query payload and run the batch inference. Validation
/// errors come back as messages (the connection is closed with a
/// diagnostic, the server keeps running).
fn answer(
    ctx: &ServeCtx,
    backend: &mut dyn Backend,
    prop_id: usize,
    payload: &[f32],
) -> std::result::Result<Vec<f32>, String> {
    if payload.is_empty() {
        return Err("empty query".to_string());
    }
    let n = payload[0].to_bits() as usize;
    if n == 0 {
        return Err("query names no nodes".to_string());
    }
    if payload.len() < 1 + n {
        return Err(format!("query claims {n} ids but carries {}", payload.len() - 1));
    }
    let ids: Vec<u32> = payload[1..1 + n].iter().map(|v| v.to_bits()).collect();
    for &id in &ids {
        if id as usize >= ctx.graph.n {
            return Err(format!(
                "node id {id} out of range (graph has {} nodes)",
                ctx.graph.n
            ));
        }
    }
    let feats = &payload[1 + n..];
    let fd = ctx.graph.feat_dim();
    let logits = if feats.is_empty() {
        forward_registered(prop_id, &ctx.params, backend, &ctx.graph.features)
    } else {
        if feats.len() != n * fd {
            return Err(format!(
                "feature override must be {n}×{fd} values, got {}",
                feats.len()
            ));
        }
        let mut features = ctx.graph.features.clone();
        for (i, &id) in ids.iter().enumerate() {
            features.set_row(id as usize, &feats[i * fd..(i + 1) * fd]);
        }
        forward_registered(prop_id, &ctx.params, backend, &features)
    };
    let mut out = Vec::with_capacity(n * ctx.n_classes);
    for &id in &ids {
        out.extend_from_slice(logits.row(id as usize));
    }
    Ok(out)
}

/// A blocking query client for one server connection.
pub struct Client {
    stream: TcpStream,
    next_query: u32,
}

impl Client {
    /// Connect and introduce ourselves.
    pub fn connect(addr: &str) -> std::io::Result<Client> {
        let mut stream = TcpStream::connect(addr)?;
        frame::write_frame(&mut stream, &Frame::Hello { rank: 0, addr: String::new() })?;
        stream.flush()?;
        Ok(Client { stream, next_query: 1 })
    }

    /// Logits for `ids` over the graph's stored features — bit-identical
    /// to the server-side full-graph forward. Returns an
    /// `ids.len() × n_classes` matrix, one row per queried node.
    pub fn query(&mut self, ids: &[u32]) -> std::io::Result<Mat> {
        self.query_impl(ids, None)
    }

    /// Logits for `ids` with fresh features (row i of `features`
    /// replaces node `ids[i]`'s stored row) — the online feature-update
    /// scenario.
    pub fn query_with_features(&mut self, ids: &[u32], features: &Mat) -> std::io::Result<Mat> {
        self.query_impl(ids, Some(features))
    }

    fn query_impl(&mut self, ids: &[u32], features: Option<&Mat>) -> std::io::Result<Mat> {
        if ids.is_empty() {
            return Err(io_err("a query must name at least one node".to_string()));
        }
        if let Some(f) = features {
            if f.rows != ids.len() {
                return Err(io_err(format!(
                    "feature override has {} rows for {} ids",
                    f.rows,
                    ids.len()
                )));
            }
        }
        let n_feats = features.map(|f| f.data.len()).unwrap_or(0);
        let mut payload = Vec::with_capacity(1 + ids.len() + n_feats);
        payload.push(f32::from_bits(ids.len() as u32));
        payload.extend(ids.iter().map(|&v| f32::from_bits(v)));
        if let Some(f) = features {
            payload.extend_from_slice(&f.data);
        }
        let tag = Tag::new(self.next_query, 0, Phase::FwdFeat);
        self.next_query += 1;
        frame::write_frame(&mut self.stream, &Frame::Data { src: 1, dst: 0, tag, payload })?;
        self.stream.flush()?;
        match frame::read_frame(&mut self.stream)? {
            Some(Frame::Data { payload, .. }) => {
                if payload.is_empty() || payload.len() % ids.len() != 0 {
                    return Err(io_err(format!(
                        "logits payload of {} values does not shape into {} rows",
                        payload.len(),
                        ids.len()
                    )));
                }
                let cols = payload.len() / ids.len();
                Ok(Mat::from_vec(ids.len(), cols, payload))
            }
            other => Err(io_err(format!("expected a logits frame, got {other:?}"))),
        }
    }

    /// Graceful goodbye (the server also tolerates a plain disconnect).
    pub fn close(mut self) {
        let _ = frame::write_frame(&mut self.stream, &Frame::Shutdown { src: 1 });
        let _ = self.stream.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny_ctx() -> (Graph, ModelConfig, Params) {
        let p = presets::by_name("tiny").unwrap();
        let g = p.build(1);
        let cfg = ModelConfig::from_preset(p);
        let params = Params::init(&cfg, &mut Rng::new(3));
        (g, cfg, params)
    }

    #[test]
    fn shape_mismatches_are_diagnostics() {
        let (g, mut cfg, params) = tiny_ctx();
        cfg.dims[0] += 1;
        let e = Server::from_parts(g, cfg, params).err().expect("should fail");
        assert!(e.to_string().contains("feature dim"), "{e}");
    }

    #[test]
    fn malformed_queries_rejected_without_killing_the_server() {
        let (g, cfg, params) = tiny_ctx();
        let n = g.n;
        let prop = g.mean_propagation_matrix();
        let ctx = ServeCtx {
            graph: g,
            prop,
            params,
            kind: cfg.kind,
            n_classes: *cfg.dims.last().unwrap(),
        };
        let mut backend = NativeBackend::new();
        let pid = backend.register_prop(&ctx.prop);
        let mut ask = |payload: &[f32]| answer(&ctx, &mut backend, pid, payload);
        assert!(ask(&[]).is_err());
        assert!(ask(&[f32::from_bits(0)]).is_err());
        // claims 3 ids, carries 1
        assert!(ask(&[f32::from_bits(3), f32::from_bits(0)]).is_err());
        // out-of-range id
        assert!(ask(&[f32::from_bits(1), f32::from_bits(n as u32)]).is_err());
        // wrong feature-override length
        assert!(ask(&[f32::from_bits(1), f32::from_bits(0), 1.0]).is_err());
        // a valid query still works on the same connection state
        let ok = ask(&[f32::from_bits(1), f32::from_bits(0)]).unwrap();
        assert_eq!(ok.len(), ctx.n_classes);
    }
}
