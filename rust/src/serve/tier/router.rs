//! Replica router: one front door for N `pipegcn serve` replicas.
//!
//! `pipegcn route` binds a client-facing listener speaking the same
//! frame protocol as `serve` and forwards each query to the healthiest,
//! least-loaded replica. Replicas are health-checked on a timer (a
//! `Ctrl` ping over a fresh connection; `pipegcn_replica_up` per
//! replica); a replica that fails a probe or a query is marked down,
//! its pooled connections are discarded, and the query is resent to
//! another replica — queries are idempotent reads, so resend-on-failure
//! is safe and a replica death mid-load loses no client queries.
//!
//! A `Ctrl` reload request triggers a **rolling** artifact reload: one
//! replica at a time is taken out of admission, its in-flight queries
//! drain, it swaps to the new artifact (`Ctrl` reload on the replica),
//! and it is readmitted before the next replica starts — so the tier
//! never has zero admitting replicas and clients see zero failures.
//! Responses carry the answering replica's `artifact_version` stamp,
//! which makes the mixed-version window during a roll observable
//! instead of silent.

use crate::comm::Tag;
use crate::net::frame::{self, Frame};
use crate::obs::{Counter, Gauge};
use crate::serve::PROTO_V2;
use crate::util::error::{Context, Result};
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// How to stand up a router from the CLI.
#[derive(Clone, Debug)]
pub struct RouterOpts {
    /// client-facing listen address (`127.0.0.1:0` = ephemeral port)
    pub bind: String,
    /// replica addresses (`pipegcn serve` processes)
    pub replicas: Vec<String>,
    /// health-probe period in milliseconds
    pub probe_ms: u64,
}

/// How long a query waits for *some* replica before failing, and how
/// long a rolling reload waits for one replica's in-flight queries.
const DISPATCH_DEADLINE: Duration = Duration::from_secs(30);

/// One replica as the router sees it.
struct Slot {
    addr: String,
    /// last probe/query outcome
    healthy: AtomicBool,
    /// false only while a rolling reload drains this replica
    admitting: AtomicBool,
    in_flight: AtomicUsize,
    /// idle pooled connections (hello already sent, v2)
    idle: Mutex<Vec<TcpStream>>,
    up: Gauge,
    inflight_g: Gauge,
    version_g: Gauge,
}

impl Slot {
    fn new(addr: String) -> Slot {
        let reg = crate::obs::global();
        let labels: &[(&str, &str)] = &[("replica", &addr)];
        Slot {
            healthy: AtomicBool::new(false),
            admitting: AtomicBool::new(true),
            in_flight: AtomicUsize::new(0),
            idle: Mutex::new(Vec::new()),
            up: reg.gauge("replica_up", labels),
            inflight_g: reg.gauge("replica_in_flight", labels),
            version_g: reg.gauge("replica_artifact_version", labels),
            addr,
        }
    }

    fn mark_down(&self) {
        self.healthy.store(false, Ordering::SeqCst);
        self.up.set(0.0);
        self.idle.lock().unwrap().clear();
    }

    fn mark_up(&self, version: Option<u32>) {
        self.healthy.store(true, Ordering::SeqCst);
        self.up.set(1.0);
        if let Some(v) = version {
            self.version_g.set(v as f64);
        }
    }
}

struct RouterState {
    slots: Vec<Slot>,
    draining: AtomicBool,
    queries: Counter,
    retries: Counter,
    reloads: Counter,
}

/// A bound (not yet accepting) router.
pub struct Router {
    listener: TcpListener,
    state: Arc<RouterState>,
    probe_ms: u64,
    addr: String,
}

fn io_err(msg: String) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
}

impl Router {
    /// Bind the client listener and probe every replica once (a dead
    /// replica at startup is not fatal — the health loop readmits it
    /// when it appears).
    pub fn bind(o: &RouterOpts) -> Result<Router> {
        if o.replicas.is_empty() {
            crate::bail!("route needs at least one replica address");
        }
        let reg = crate::obs::global();
        let state = Arc::new(RouterState {
            slots: o.replicas.iter().map(|a| Slot::new(a.clone())).collect(),
            draining: AtomicBool::new(false),
            queries: reg.counter("route_queries_total", &[]),
            retries: reg.counter("route_retries_total", &[]),
            reloads: reg.counter("route_reloads_total", &[]),
        });
        for slot in &state.slots {
            probe(slot);
        }
        let listener =
            TcpListener::bind(&o.bind).with_context(|| format!("binding {}", o.bind))?;
        let addr = listener.local_addr()?.to_string();
        Ok(Router { listener, state, probe_ms: o.probe_ms.max(10), addr })
    }

    /// The bound client-facing address (`host:port`).
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Accept client connections until drained (or `max_conns` have
    /// finished), with the health loop probing replicas in the
    /// background. Returns cleanly after a `Ctrl` drain: the listener
    /// stops admitting, in-flight client connections finish, then the
    /// health loop stops.
    pub fn run(self, max_conns: Option<usize>) -> Result<()> {
        let stop = Arc::new(AtomicBool::new(false));
        let health = {
            let state = self.state.clone();
            let stop = stop.clone();
            let period = Duration::from_millis(self.probe_ms);
            std::thread::spawn(move || {
                while !stop.load(Ordering::SeqCst) {
                    for slot in &state.slots {
                        let was = slot.healthy.load(Ordering::SeqCst);
                        if !probe(slot) && was {
                            slot.mark_down();
                        }
                    }
                    std::thread::sleep(period);
                }
            })
        };
        self.listener.set_nonblocking(true).context("router listener nonblocking")?;
        let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
        let mut served = 0usize;
        loop {
            if self.state.draining.load(Ordering::SeqCst) {
                break;
            }
            if let Some(m) = max_conns {
                if served >= m {
                    break;
                }
            }
            match self.listener.accept() {
                Ok((stream, peer)) => {
                    served += 1;
                    let state = self.state.clone();
                    handles.push(std::thread::spawn(move || {
                        if let Err(e) = handle_client(&state, stream) {
                            eprintln!("route: connection {peer}: {e}");
                        }
                    }));
                    handles.retain(|h| !h.is_finished());
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => return Err(e).context("accepting a router connection"),
            }
        }
        for h in handles {
            let _ = h.join();
        }
        stop.store(true, Ordering::SeqCst);
        let _ = health.join();
        Ok(())
    }
}

/// Open a v2 connection to a replica (hello already sent on return).
fn replica_connect(addr: &str) -> std::io::Result<TcpStream> {
    let mut stream = TcpStream::connect(addr)?;
    frame::write_frame(&mut stream, &Frame::Hello { rank: 0, addr: PROTO_V2.to_string() })?;
    stream.flush()?;
    Ok(stream)
}

/// One ctrl round trip on a fresh replica connection.
fn replica_ctrl(addr: &str, op: u8, arg: &str) -> std::io::Result<String> {
    let mut stream = replica_connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(10)))?;
    frame::write_frame(&mut stream, &Frame::Ctrl { op, arg: arg.to_string() })?;
    stream.flush()?;
    let reply = match frame::read_frame(&mut stream)? {
        Some(Frame::Ctrl { op: frame::CTRL_ACK, arg }) => Ok(arg),
        Some(Frame::Ctrl { op: frame::CTRL_ERR, arg }) => Err(io_err(arg)),
        other => Err(io_err(format!("replica sent {other:?} to a ctrl request"))),
    };
    let _ = frame::write_frame(&mut stream, &Frame::Shutdown { src: 0 });
    let _ = stream.flush();
    reply
}

/// Ping one replica; on success mark it up (with its artifact version)
/// and return true.
fn probe(slot: &Slot) -> bool {
    match replica_ctrl(&slot.addr, frame::CTRL_PING, "") {
        Ok(arg) => {
            slot.mark_up(arg.trim().parse::<u32>().ok());
            true
        }
        Err(_) => false,
    }
}

/// in-flight accounting that survives early returns
struct FlightGuard<'a>(&'a Slot);

impl Drop for FlightGuard<'_> {
    fn drop(&mut self) {
        self.0.in_flight.fetch_sub(1, Ordering::SeqCst);
        self.0.inflight_g.add(-1.0);
    }
}

/// Send one query to `slot` (pooled connection or a fresh one) and read
/// the stamped response. The connection returns to the pool on success.
fn query_replica(slot: &Slot, tag: Tag, payload: &[f32]) -> std::io::Result<Vec<f32>> {
    slot.in_flight.fetch_add(1, Ordering::SeqCst);
    slot.inflight_g.add(1.0);
    let _guard = FlightGuard(slot);
    let pooled = slot.idle.lock().unwrap().pop();
    let mut stream = match pooled {
        Some(s) => s,
        None => replica_connect(&slot.addr)?,
    };
    frame::write_frame(
        &mut stream,
        &Frame::Data { src: 0, dst: 0, tag, payload: payload.to_vec() },
    )?;
    stream.flush()?;
    match frame::read_frame(&mut stream)? {
        Some(Frame::Data { payload, .. }) => {
            if payload.is_empty() {
                return Err(io_err("replica sent an empty response".to_string()));
            }
            slot.version_g.set(payload[0].to_bits() as f64);
            slot.idle.lock().unwrap().push(stream);
            Ok(payload)
        }
        other => Err(io_err(format!("replica sent {other:?} to a query"))),
    }
}

/// Pick the healthiest, least-loaded admitting replica.
fn pick(state: &RouterState) -> Option<usize> {
    state
        .slots
        .iter()
        .enumerate()
        .filter(|(_, s)| {
            s.healthy.load(Ordering::SeqCst) && s.admitting.load(Ordering::SeqCst)
        })
        .min_by_key(|(_, s)| s.in_flight.load(Ordering::SeqCst))
        .map(|(i, _)| i)
}

/// Route one query: least-loaded dispatch with failover. A failed
/// replica is marked down and the query resent elsewhere; only running
/// out of replicas for [`DISPATCH_DEADLINE`] fails the query.
fn dispatch(state: &RouterState, tag: Tag, payload: &[f32]) -> std::io::Result<Vec<f32>> {
    let deadline = Instant::now() + DISPATCH_DEADLINE;
    loop {
        let Some(i) = pick(state) else {
            if Instant::now() >= deadline {
                return Err(io_err("no admitting replica".to_string()));
            }
            std::thread::sleep(Duration::from_millis(20));
            continue;
        };
        match query_replica(&state.slots[i], tag, payload) {
            Ok(resp) => {
                state.queries.inc();
                return Ok(resp);
            }
            Err(e) => {
                state.slots[i].mark_down();
                state.retries.inc();
                if Instant::now() >= deadline {
                    return Err(e);
                }
            }
        }
    }
}

/// Reload every healthy replica in sequence: stop admitting → wait for
/// its in-flight queries → `Ctrl` reload → readmit. The drain wait
/// keeps the version flip clean per replica; a query that races past it
/// still gets a correct (stamped) answer from the old or new artifact.
fn rolling_reload(state: &RouterState, path: &str) -> std::result::Result<String, String> {
    let healthy: Vec<usize> = (0..state.slots.len())
        .filter(|&i| state.slots[i].healthy.load(Ordering::SeqCst))
        .collect();
    if healthy.is_empty() {
        return Err("no healthy replica to reload".to_string());
    }
    let mut versions = Vec::new();
    for i in healthy {
        let slot = &state.slots[i];
        slot.admitting.store(false, Ordering::SeqCst);
        let deadline = Instant::now() + DISPATCH_DEADLINE;
        while slot.in_flight.load(Ordering::SeqCst) > 0 {
            if Instant::now() >= deadline {
                slot.admitting.store(true, Ordering::SeqCst);
                return Err(format!("timed out draining {}", slot.addr));
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        match replica_ctrl(&slot.addr, frame::CTRL_RELOAD, path) {
            Ok(v) => {
                slot.version_g.set(v.trim().parse::<u32>().unwrap_or(0) as f64);
                versions.push(format!("{}={}", slot.addr, v));
            }
            Err(e) => {
                slot.admitting.store(true, Ordering::SeqCst);
                return Err(format!("reload on {}: {}", slot.addr, e));
            }
        }
        slot.admitting.store(true, Ordering::SeqCst);
    }
    state.reloads.inc();
    Ok(versions.join(","))
}

/// Serve one client connection: queries are dispatched to replicas,
/// ctrl requests are handled by the router itself (ping = tier health,
/// drain = stop the router, reload = rolling reload across replicas).
fn handle_client(state: &RouterState, mut stream: TcpStream) -> std::io::Result<()> {
    let mut v2 = false;
    stream.set_read_timeout(Some(Duration::from_millis(100)))?;
    loop {
        let mut peek = [0u8; 1];
        match stream.peek(&mut peek) {
            Ok(0) => return Ok(()),
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if state.draining.load(Ordering::SeqCst) {
                    return Ok(());
                }
                continue;
            }
            Err(e) => return Err(e),
        }
        stream.set_read_timeout(None)?;
        let f = frame::read_frame(&mut stream)?;
        stream.set_read_timeout(Some(Duration::from_millis(100)))?;
        match f {
            None | Some(Frame::Shutdown { .. }) => return Ok(()),
            Some(Frame::Hello { addr, .. }) => v2 = addr == PROTO_V2,
            Some(Frame::Data { tag, payload, .. }) => {
                let mut resp = dispatch(state, tag, &payload)?;
                if !v2 {
                    // old clients negotiated no version stamp
                    resp.remove(0);
                }
                frame::write_frame(
                    &mut stream,
                    &Frame::Data { src: 0, dst: 1, tag, payload: resp },
                )?;
                stream.flush()?;
            }
            Some(Frame::Ctrl { op, arg }) => {
                let reply = match op {
                    frame::CTRL_PING => {
                        let up = state
                            .slots
                            .iter()
                            .filter(|s| s.healthy.load(Ordering::SeqCst))
                            .count();
                        Ok(format!("{up}/{} replicas healthy", state.slots.len()))
                    }
                    frame::CTRL_DRAIN => {
                        state.draining.store(true, Ordering::SeqCst);
                        Ok("draining".to_string())
                    }
                    frame::CTRL_RELOAD => rolling_reload(state, &arg),
                    other => Err(format!("unknown ctrl op {other}")),
                };
                let f = match reply {
                    Ok(arg) => Frame::Ctrl { op: frame::CTRL_ACK, arg },
                    Err(arg) => Frame::Ctrl { op: frame::CTRL_ERR, arg },
                };
                frame::write_frame(&mut stream, &f)?;
                stream.flush()?;
            }
            Some(other) => {
                return Err(io_err(format!("unexpected frame at the router: {other:?}")))
            }
        }
    }
}
