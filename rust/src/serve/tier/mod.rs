//! The serving tier: what turns one `serve` process into a production
//! front.
//!
//! Three coupled layers over the PR-4 inference path, every one of them
//! bit-transparent (logits identical to the unbatched, uncached,
//! single-replica forward — asserted in `tests/serve_tier.rs`):
//!
//! - [`batch`] — request coalescing: a bounded queue micro-batches
//!   queued queries into one kernel pass under a latency budget
//!   (`--batch-window-ms`, `--max-batch`), with per-query scatter-back.
//! - [`cache`] — per-layer activation caching keyed by
//!   `(artifact_version, graph_version)`: plain queries reuse layers
//!   `1..L−1` and pay only the final layer; feature overrides
//!   invalidate exactly the dependent rows (the override's propagation
//!   cone) and restore them afterwards.
//! - [`router`] — `pipegcn route`: N `serve` replicas behind one
//!   address, health-checked, least-loaded, with automatic failover and
//!   rolling artifact reload for zero-downtime model updates.
//!
//! [`loadgen`] drives it all: closed-loop (`--concurrency`) and
//! open-loop (`--rate`) generation for the sustained-QPS rows in
//! `BENCH_serve.json`.

pub mod batch;
pub mod cache;
pub mod loadgen;
pub mod router;

pub use batch::{Coalescer, Reply, Submitter, TierOpts};
pub use cache::ActivationCache;
pub use loadgen::{LoadMode, LoadOpts, LoadReport};
pub use router::{Router, RouterOpts};
