//! Load generation for the serving tier (`pipegcn query --concurrency
//! / --rate`).
//!
//! Two classic modes. **Closed loop** (`--concurrency N`): N workers,
//! each with its own connection, issue the next query the moment the
//! previous answer lands — measures the tier's saturated throughput and
//! the latency it sustains there. **Open loop** (`--rate QPS`): queries
//! are scheduled on a fixed global timeline and latency is measured
//! from the *scheduled* send time, so a slow server shows up as rising
//! latency instead of silently slowing the generator down (the
//! coordinated-omission trap closed-loop numbers fall into).
//!
//! Workers reconnect and keep going after an error; the report carries
//! the error count so "zero failed queries" is an assertable outcome,
//! not an assumption.

use crate::perf::percentile;
use crate::serve::Client;
use crate::util::timer::Stopwatch;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Closed loop (fixed concurrency) or open loop (fixed arrival rate).
#[derive(Clone, Copy, Debug)]
pub enum LoadMode {
    Closed { concurrency: usize },
    Open { rate: f64, workers: usize },
}

/// One load-generation run against a serve or route address.
#[derive(Clone, Debug)]
pub struct LoadOpts {
    pub addr: String,
    /// node ids to rotate through (one id per query)
    pub ids: Vec<u32>,
    pub mode: LoadMode,
    pub duration_s: f64,
}

/// What one run measured — one NDJSON row in `BENCH_serve.json`.
#[derive(Clone, Debug)]
pub struct LoadReport {
    pub mode: &'static str,
    pub concurrency: usize,
    /// requested open-loop rate (0 for closed loop)
    pub rate_qps: f64,
    /// actual wall-clock of the run
    pub duration_s: f64,
    pub queries: u64,
    pub errors: u64,
    pub qps: f64,
    pub p50_ms: f64,
    pub p90_ms: f64,
    pub p99_ms: f64,
}

/// Run the load and aggregate per-worker latencies into one report.
pub fn run(o: &LoadOpts) -> LoadReport {
    assert!(!o.ids.is_empty(), "load generation needs at least one node id");
    let (workers, mode, rate) = match o.mode {
        LoadMode::Closed { concurrency } => (concurrency.max(1), "closed", 0.0),
        LoadMode::Open { rate, workers } => (workers.max(1), "open", rate),
    };
    let t0 = Instant::now();
    let stop_at = t0 + Duration::from_secs_f64(o.duration_s.max(0.01));
    let tick = AtomicU64::new(0);
    let results: Vec<(Vec<f64>, u64)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                let tick = &tick;
                s.spawn(move || match o.mode {
                    LoadMode::Closed { .. } => closed_worker(&o.addr, &o.ids, w, stop_at),
                    LoadMode::Open { rate, .. } => {
                        open_worker(&o.addr, &o.ids, tick, rate, (t0, stop_at))
                    }
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("load worker panicked")).collect()
    });
    let elapsed = t0.elapsed().as_secs_f64();
    let mut lats: Vec<f64> = Vec::new();
    let mut errors = 0u64;
    for (l, e) in results {
        lats.extend(l);
        errors += e;
    }
    let queries = lats.len() as u64;
    lats.sort_by(f64::total_cmp);
    let pct = |q: f64| if lats.is_empty() { 0.0 } else { percentile(&lats, q) };
    LoadReport {
        mode,
        concurrency: workers,
        rate_qps: rate,
        duration_s: elapsed,
        queries,
        errors,
        qps: queries as f64 / elapsed.max(1e-12),
        p50_ms: pct(0.50),
        p90_ms: pct(0.90),
        p99_ms: pct(0.99),
    }
}

fn closed_worker(addr: &str, ids: &[u32], seed: usize, stop_at: Instant) -> (Vec<f64>, u64) {
    let mut lats = Vec::new();
    let mut errors = 0u64;
    let mut client: Option<Client> = None;
    let mut k = seed; // stagger workers across the id list
    while Instant::now() < stop_at {
        let Some(c) = ensure_client(&mut client, addr, &mut errors) else { continue };
        let id = ids[k % ids.len()];
        k += 1;
        let watch = Stopwatch::start();
        match c.query(&[id]) {
            Ok(_) => lats.push(watch.elapsed_secs() * 1e3),
            Err(_) => {
                errors += 1;
                client = None;
            }
        }
    }
    (lats, errors)
}

fn open_worker(
    addr: &str,
    ids: &[u32],
    tick: &AtomicU64,
    rate: f64,
    window: (Instant, Instant),
) -> (Vec<f64>, u64) {
    let (t0, stop_at) = window;
    let rate = rate.max(0.1);
    let mut lats = Vec::new();
    let mut errors = 0u64;
    let mut client: Option<Client> = None;
    loop {
        let t = tick.fetch_add(1, Ordering::SeqCst);
        let sched = t0 + Duration::from_secs_f64(t as f64 / rate);
        if sched >= stop_at {
            return (lats, errors);
        }
        let now = Instant::now();
        if sched > now {
            std::thread::sleep(sched - now);
        }
        let Some(c) = ensure_client(&mut client, addr, &mut errors) else { continue };
        let id = ids[(t as usize) % ids.len()];
        match c.query(&[id]) {
            // latency from the *scheduled* time: queueing delay counts
            Ok(_) => lats.push(sched.elapsed().as_secs_f64() * 1e3),
            Err(_) => {
                errors += 1;
                client = None;
            }
        }
    }
}

/// Connect lazily and reconnect after failures (counted, throttled).
fn ensure_client<'a>(
    client: &'a mut Option<Client>,
    addr: &str,
    errors: &mut u64,
) -> Option<&'a mut Client> {
    if client.is_none() {
        match Client::connect(addr) {
            Ok(c) => *client = Some(c),
            Err(_) => {
                *errors += 1;
                std::thread::sleep(Duration::from_millis(10));
                return None;
            }
        }
    }
    client.as_mut()
}
