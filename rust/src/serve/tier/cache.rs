//! Per-layer activation caching keyed by `(artifact_version,
//! graph_version)`.
//!
//! The forward pass over the *stored* features is the same for every
//! plain query, so the hidden activations `h_1..h_{L-1}` are computed
//! once per (artifact, graph) key and every plain query pays only the
//! final layer over its requested rows. A feature-override query
//! recomputes exactly the rows its change can reach — the override's
//! propagation cone, one hop wider per layer — answers from the patched
//! state, and restores every touched row, so the cache stays clean for
//! the next query. A rolling reload changes `artifact_version`, which
//! invalidates the whole cache; the executor rebuilds it lazily.
//!
//! ## Bit-identity
//!
//! [`ActivationCache::warm`] replays the exact op order of the native
//! backend's `layer_fwd` + [`ops::relu`] (spmm → matmul → add_assign →
//! relu), and the row paths use the shared row kernels
//! ([`Csr::spmm_row`], [`dense::gemm_row`]) that the full-matrix
//! kernels are themselves defined by, with the same per-row summation
//! order. Cached logits therefore carry the exact bits of an uncached
//! [`crate::coordinator::forward_registered`] pass — asserted bitwise
//! in `tests/serve_tier.rs`, including under random override sets.

use crate::serve::ServeCtx;
use crate::tensor::{dense, ops, Csr, Mat};

/// Cached hidden activations for one serving context.
pub struct ActivationCache {
    artifact_version: u32,
    graph_version: u64,
    /// post-ReLU activations `h_1..h_{L-1}` over the stored features;
    /// empty for a single-layer model or before the first warm
    hidden: Vec<Mat>,
    warmed: bool,
    /// reverse propagation adjacency (column → reading rows), built
    /// lazily on the first override query
    rev: Option<Csr>,
}

impl ActivationCache {
    pub fn new(ctx: &ServeCtx) -> ActivationCache {
        ActivationCache {
            artifact_version: ctx.artifact_version,
            graph_version: ctx.graph_version,
            hidden: Vec::new(),
            warmed: false,
            rev: None,
        }
    }

    /// Does this cache still describe `ctx`? False after a reload (new
    /// `artifact_version`) or against a different graph.
    pub fn matches(&self, ctx: &ServeCtx) -> bool {
        self.artifact_version == ctx.artifact_version && self.graph_version == ctx.graph_version
    }

    pub fn is_warm(&self) -> bool {
        self.warmed
    }

    /// Compute `h_1..h_{L-1}` over the stored features: one pass of
    /// every layer but the last, in `layer_fwd`'s exact op order.
    pub fn warm(&mut self, ctx: &ServeCtx) {
        let nl = ctx.params.layers.len();
        self.hidden.clear();
        for l in 0..nl.saturating_sub(1) {
            let lp = &ctx.params.layers[l];
            let mut pre = {
                let cur: &Mat =
                    if l == 0 { ctx.features.as_ref() } else { &self.hidden[l - 1] };
                let z = ctx.prop.spmm(cur);
                let mut pre = z.matmul(&lp.w_neigh);
                if let Some(ws) = &lp.w_self {
                    // layer_fwd takes rows_range(0, inner) first, but in
                    // serving inner == all rows, so the copy is
                    // value-identical to `cur`
                    pre.add_assign(&cur.matmul(ws));
                }
                pre
            };
            ops::relu_inplace(&mut pre);
            self.hidden.push(pre);
        }
        self.warmed = true;
    }

    /// Logits for `rows` (scope-mapped feature-row indices, duplicates
    /// allowed, response order preserved) from the warm cache: only the
    /// final layer runs, and only over the requested rows.
    pub fn final_rows(&self, ctx: &ServeCtx, rows: &[usize]) -> Vec<f32> {
        debug_assert!(self.warmed, "final_rows on a cold cache");
        let nl = ctx.params.layers.len();
        let h: &Mat = if nl == 1 { ctx.features.as_ref() } else { &self.hidden[nl - 2] };
        last_layer_rows(ctx, h, rows)
    }

    /// Answer an override query against the warm cache: patch `scratch`
    /// (the executor's mutable copy of the stored features), recompute
    /// exactly the dependent cached rows layer by layer, read the
    /// requested logits from the patched state, then restore every
    /// touched row. Returns the logits and the number of cached rows
    /// invalidated (recomputed) across the hidden layers.
    pub fn override_rows(
        &mut self,
        ctx: &ServeCtx,
        scratch: &mut Mat,
        rows: &[usize],
        feats: &[f32],
    ) -> (Vec<f32>, usize) {
        debug_assert!(self.warmed, "override_rows on a cold cache");
        let fd = ctx.feat_dim;
        for (i, &r) in rows.iter().enumerate() {
            scratch.set_row(r, &feats[i * fd..(i + 1) * fd]);
        }
        if self.rev.is_none() {
            self.rev = Some(ctx.prop.transpose());
        }
        let nl = ctx.params.layers.len();
        let mut dirty: Vec<usize> = rows.to_vec();
        dirty.sort_unstable();
        dirty.dedup();
        let mut saved: Vec<Vec<(usize, Vec<f32>)>> = Vec::new();
        let mut invalidated = 0usize;
        for l in 0..nl.saturating_sub(1) {
            // the cone: rows whose layer-l output reads a dirty input —
            // prop readers of dirty columns (via the reverse adjacency)
            // plus the dirty rows themselves (w_self reads row r).
            // Over-approximation is safe: recomputing an unchanged row
            // from identical inputs reproduces identical bits.
            let rev = self.rev.as_ref().unwrap();
            let m = scratch.rows;
            let mut mark = vec![false; m];
            for &d in &dirty {
                mark[d] = true;
                for (r, _) in rev.row_entries(d) {
                    mark[r] = true;
                }
            }
            let cone: Vec<usize> = (0..m).filter(|&r| mark[r]).collect();
            invalidated += cone.len();
            let lp = &ctx.params.layers[l];
            let mut updates: Vec<(usize, Vec<f32>)> = Vec::with_capacity(cone.len());
            {
                let h_prev: &Mat = if l == 0 { &*scratch } else { &self.hidden[l - 1] };
                let mut z = vec![0.0f32; lp.w_neigh.rows];
                let mut s = vec![0.0f32; lp.w_neigh.cols];
                for &r in &cone {
                    ctx.prop.spmm_row(r, h_prev, &mut z);
                    let mut pre = vec![0.0f32; lp.w_neigh.cols];
                    dense::gemm_row(&z, &lp.w_neigh, &mut pre);
                    if let Some(ws) = &lp.w_self {
                        dense::gemm_row(h_prev.row(r), ws, &mut s);
                        for (p, sv) in pre.iter_mut().zip(s.iter()) {
                            *p += *sv;
                        }
                    }
                    for p in pre.iter_mut() {
                        *p = p.max(0.0);
                    }
                    updates.push((r, pre));
                }
            }
            let mut layer_saved = Vec::with_capacity(updates.len());
            for (r, new_row) in updates {
                layer_saved.push((r, self.hidden[l].row(r).to_vec()));
                self.hidden[l].set_row(r, &new_row);
            }
            saved.push(layer_saved);
            dirty = cone;
        }
        let out = {
            let h: &Mat = if nl == 1 { &*scratch } else { &self.hidden[nl - 2] };
            last_layer_rows(ctx, h, rows)
        };
        // restore the cached rows, then the scratch feature rows
        for (l, layer_saved) in saved.into_iter().enumerate() {
            for (r, row) in layer_saved {
                self.hidden[l].set_row(r, &row);
            }
        }
        for &r in rows {
            scratch.set_row(r, ctx.features.row(r));
        }
        (out, invalidated)
    }
}

/// The final (ReLU-less) layer for each requested row: spmm_row +
/// gemm_row (+ the w_self row term) — the exact per-row decomposition
/// of `spmm`/`matmul`/`add_assign`, so the bits match the full pass.
fn last_layer_rows(ctx: &ServeCtx, h: &Mat, rows: &[usize]) -> Vec<f32> {
    let lp = ctx.params.layers.last().unwrap();
    let mut out = Vec::with_capacity(rows.len() * ctx.n_classes);
    let mut z = vec![0.0f32; lp.w_neigh.rows];
    let mut pre = vec![0.0f32; ctx.n_classes];
    let mut s = vec![0.0f32; ctx.n_classes];
    for &r in rows {
        ctx.prop.spmm_row(r, h, &mut z);
        dense::gemm_row(&z, &lp.w_neigh, &mut pre);
        if let Some(ws) = &lp.w_self {
            dense::gemm_row(h.row(r), ws, &mut s);
            for (p, sv) in pre.iter_mut().zip(s.iter()) {
                *p += *sv;
            }
        }
        out.extend_from_slice(&pre);
    }
    out
}
