//! Request coalescing: a bounded queue in front of the forward path.
//!
//! Connection handlers parse queries and hand them to a [`Submitter`];
//! one executor thread drains the queue into micro-batches — up to
//! `max_batch` queries, waiting at most `window_ms` after the first
//! arrival — and answers the whole batch from shared work: one warm
//! activation cache (or, with caching off, one full forward pass)
//! instead of one full pass per query. Each query's logits are
//! scattered back bit-identically to the unbatched forward, so
//! coalescing is invisible to clients except in throughput.
//!
//! The queue is bounded (`TierOpts::queue`): when the executor falls
//! behind, submitters block inside `send`, which backpressures the
//! connection threads instead of growing an unbounded backlog.

use super::cache::ActivationCache;
use crate::coordinator::forward_registered;
use crate::obs::{Counter, Gauge};
use crate::runtime::native::NativeBackend;
use crate::serve::{Query, ServeCtx, ServeState};
use crate::tensor::Mat;
use std::sync::mpsc::{self, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Knobs for the coalescing/caching layer (`pipegcn serve
/// --batch-window-ms / --max-batch / --no-cache`).
#[derive(Clone, Copy, Debug)]
pub struct TierOpts {
    /// how long the executor waits to fill a batch after the first
    /// query arrives, in milliseconds (0 = no waiting: fuse only what
    /// is already queued)
    pub window_ms: f64,
    /// most queries fused into one pass
    pub max_batch: usize,
    /// per-layer activation caching; off = every query is a full
    /// forward pass (the pre-tier behavior)
    pub cache: bool,
    /// bounded queue depth; submitters block (backpressure) when full
    pub queue: usize,
}

impl Default for TierOpts {
    fn default() -> TierOpts {
        TierOpts { window_ms: 1.0, max_batch: 32, cache: true, queue: 256 }
    }
}

/// What the executor sends back for one query.
#[derive(Clone, Debug)]
pub struct Reply {
    /// requested logits, `rows.len() × n_classes`, exact forward bits
    pub logits: Vec<f32>,
    /// the artifact the answer came from (stamped into v2 responses)
    pub artifact_version: u32,
    /// how many queries shared this kernel pass (observability, tests)
    pub batch_size: usize,
}

/// One queued query and the channel its reply goes back on.
struct Job {
    q: Query,
    reply: mpsc::Sender<Result<Reply, String>>,
}

/// Cache-effectiveness counters, bundled so the batch runner stays
/// under the argument-count lint.
struct CacheStats {
    hits: Counter,
    misses: Counter,
    invalidated: Counter,
}

/// The coalescing front: owns the queue and the executor thread.
/// Dropping it closes the queue and joins the executor.
pub struct Coalescer {
    tx: Option<SyncSender<Job>>,
    depth: Gauge,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Coalescer {
    /// Spawn the executor thread. It owns the backend (the propagation
    /// matrix is registered exactly once) and picks up artifact reloads
    /// from `state` between batches.
    pub fn start(state: Arc<ServeState>, opts: TierOpts) -> Coalescer {
        let depth = crate::obs::global().gauge("serve_queue_depth", &[]);
        let (tx, rx) = mpsc::sync_channel::<Job>(opts.queue.max(1));
        let exec_depth = depth.clone();
        let handle = std::thread::spawn(move || executor(&state, opts, &rx, &exec_depth));
        Coalescer { tx: Some(tx), depth, handle: Some(handle) }
    }

    /// A submission handle for one connection thread.
    pub fn submitter(&self) -> Submitter {
        Submitter { tx: self.tx.as_ref().unwrap().clone(), depth: self.depth.clone() }
    }
}

impl Drop for Coalescer {
    fn drop(&mut self) {
        // closing the channel ends the executor loop once outstanding
        // submitters are gone; join so in-flight batches finish
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Cloneable handle for submitting parsed queries to the executor.
#[derive(Clone)]
pub struct Submitter {
    tx: SyncSender<Job>,
    depth: Gauge,
}

impl Submitter {
    /// Queue one query and wait for its reply. Blocks while the bounded
    /// queue is full and while the batch runs.
    pub fn submit(&self, q: Query) -> Result<Reply, String> {
        let (rtx, rrx) = mpsc::channel();
        self.depth.add(1.0);
        if self.tx.send(Job { q, reply: rtx }).is_err() {
            self.depth.add(-1.0);
            return Err("serving executor is gone".to_string());
        }
        match rrx.recv() {
            Ok(r) => r,
            Err(_) => Err("serving executor dropped the query".to_string()),
        }
    }
}

fn executor(state: &ServeState, opts: TierOpts, rx: &mpsc::Receiver<Job>, depth: &Gauge) {
    let reg = crate::obs::global();
    let batch_hist = reg.histogram("serve_batch_size", &[]);
    let stats = CacheStats {
        hits: reg.counter("serve_cache_hits_total", &[]),
        misses: reg.counter("serve_cache_misses_total", &[]),
        invalidated: reg.counter("serve_cache_rows_invalidated_total", &[]),
    };
    let mut backend = NativeBackend::new();
    // the propagation matrix never changes across reloads (only params
    // do), so one registration serves the executor's whole life
    let prop_id = backend.register_prop(&state.current().prop);
    let mut scratch: Option<Mat> = None;
    let mut cache: Option<ActivationCache> = None;
    let max_batch = opts.max_batch.max(1);
    loop {
        let first = match rx.recv() {
            Ok(j) => j,
            Err(_) => return,
        };
        depth.add(-1.0);
        let mut jobs = vec![first];
        if opts.window_ms > 0.0 {
            let deadline = Instant::now() + Duration::from_secs_f64(opts.window_ms / 1e3);
            while jobs.len() < max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(j) => {
                        depth.add(-1.0);
                        jobs.push(j);
                    }
                    Err(_) => break,
                }
            }
        } else {
            while jobs.len() < max_batch {
                match rx.try_recv() {
                    Ok(j) => {
                        depth.add(-1.0);
                        jobs.push(j);
                    }
                    Err(_) => break,
                }
            }
        }
        batch_hist.record(jobs.len() as f64);
        let ctx = state.current();
        if opts.cache {
            if !cache.as_ref().is_some_and(|c| c.matches(&ctx)) {
                cache = Some(ActivationCache::new(&ctx));
            }
        } else {
            cache = None;
        }
        run_batch(&ctx, &mut backend, prop_id, &mut scratch, cache.as_mut(), jobs, &stats);
    }
}

/// Answer one fused batch. Plain queries share the warm cache (or one
/// full pass with caching off); override queries run individually with
/// patch/restore semantics, exactly like the pre-tier server.
fn run_batch(
    ctx: &ServeCtx,
    backend: &mut NativeBackend,
    prop_id: usize,
    scratch: &mut Option<Mat>,
    cache: Option<&mut ActivationCache>,
    jobs: Vec<Job>,
    stats: &CacheStats,
) {
    let batch_size = jobs.len();
    let reply_of = |logits: Vec<f32>| Reply {
        logits,
        artifact_version: ctx.artifact_version,
        batch_size,
    };
    let (mut plain, mut over) = (Vec::new(), Vec::new());
    for j in jobs {
        if j.q.feats.is_empty() {
            plain.push(j);
        } else {
            over.push(j);
        }
    }
    if let Some(c) = cache {
        let was_warm = c.is_warm();
        if !was_warm {
            c.warm(ctx);
        }
        if was_warm {
            stats.hits.add(batch_size as f64);
        } else {
            stats.misses.add(batch_size as f64);
        }
        for j in plain {
            let logits = c.final_rows(ctx, &j.q.rows);
            let _ = j.reply.send(Ok(reply_of(logits)));
        }
        for j in over {
            let scr = scratch.get_or_insert_with(|| (*ctx.features).clone());
            let (logits, inv) = c.override_rows(ctx, scr, &j.q.rows, &j.q.feats);
            stats.invalidated.add(inv as f64);
            let _ = j.reply.send(Ok(reply_of(logits)));
        }
        return;
    }
    if !plain.is_empty() {
        // one full pass answers every plain query in the batch — the
        // forward is deterministic, so the shared pass carries the
        // exact bits each per-query pass would have produced
        let full = forward_registered(prop_id, &ctx.params, backend, &ctx.features);
        for j in plain {
            let mut logits = Vec::with_capacity(j.q.rows.len() * ctx.n_classes);
            for &r in &j.q.rows {
                logits.extend_from_slice(full.row(r));
            }
            let _ = j.reply.send(Ok(reply_of(logits)));
        }
    }
    for j in over {
        let scr = scratch.get_or_insert_with(|| (*ctx.features).clone());
        let fd = ctx.feat_dim;
        for (i, &r) in j.q.rows.iter().enumerate() {
            scr.set_row(r, &j.q.feats[i * fd..(i + 1) * fd]);
        }
        let full = forward_registered(prop_id, &ctx.params, backend, scr);
        for &r in &j.q.rows {
            scr.set_row(r, ctx.features.row(r));
        }
        let mut logits = Vec::with_capacity(j.q.rows.len() * ctx.n_classes);
        for &r in &j.q.rows {
            logits.extend_from_slice(full.row(r));
        }
        let _ = j.reply.send(Ok(reply_of(logits)));
    }
}
