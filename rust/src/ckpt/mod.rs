//! Crash-safe checkpoint/restore of distributed training state.
//!
//! A checkpoint is one directory per epoch (`<dir>/ep<NNNNNNNN>/`)
//! holding one file per rank (`rank<r>.ckpt`). Each file is a versioned,
//! dependency-free binary snapshot of everything a resumed run needs to
//! reproduce the uninterrupted run **bit-for-bit**: the epoch counter,
//! the flattened parameters, the Adam moments (m, v, t), and the PipeGCN
//! stale buffers (`feat_buf` / `grad_buf` per layer). Dropout masks need
//! no state — they are a pure function of `(seed, epoch, rank, layer)`.
//!
//! Framing follows the [`crate::net::frame`] conventions (little-endian
//! fixed-width fields, f32 payloads as raw bit patterns) plus a trailing
//! CRC-32 over the whole body, so a torn or corrupted file is rejected
//! instead of silently resuming from garbage. Writes are atomic
//! (temp file + rename), and a checkpoint only counts as *complete* when
//! all `n` rank files of its epoch decode cleanly — the unit
//! [`latest_complete`] scans for when the launcher recovers a mesh after
//! a worker death.

pub(crate) mod codec;

use crate::tensor::Mat;
use crate::util::error::{Context, Result};
use codec::{put_f32s, put_mats, put_u32, put_u64, Cursor};
use std::path::PathBuf;

/// File magic of a rank snapshot.
pub const MAGIC: [u8; 4] = *b"PGCK";
/// Current snapshot format version.
pub const VERSION: u32 = 1;

/// When and where an engine snapshots: every `every` epochs into `dir`.
#[derive(Clone, Debug)]
pub struct Policy {
    pub dir: String,
    pub every: usize,
}

impl Policy {
    /// Is a snapshot due after completing `epoch`?
    pub fn due(&self, epoch: usize) -> bool {
        self.every > 0 && epoch % self.every == 0
    }
}

/// The serializable training state of one rank at an epoch boundary.
///
/// The model/optimizer fields are replicated (identical on every rank,
/// like the live training state they snapshot); the stale buffers are
/// per-rank. Keeping the replicated state in every rank file makes the
/// format engine-independent: the sequential engine writes the same `n`
/// files a TCP mesh would, so either side can resume the other's run.
#[derive(Clone, Debug, PartialEq)]
pub struct RankState {
    pub rank: u32,
    pub n_ranks: u32,
    /// completed epochs at snapshot time
    pub epoch: u32,
    /// Adam step counter
    pub adam_t: u64,
    /// flattened parameters
    pub flat: Vec<f32>,
    /// Adam first moment
    pub adam_m: Vec<f32>,
    /// Adam second moment
    pub adam_v: Vec<f32>,
    /// stale halo-feature buffers, one per layer
    pub feat_buf: Vec<Mat>,
    /// stale boundary-gradient buffers, one per layer
    pub grad_buf: Vec<Mat>,
}

// ---------------------------------------------------------------------
// CRC-32 (IEEE, reflected) — dependency-free integrity check
// ---------------------------------------------------------------------

/// CRC-32 of `data` (IEEE polynomial, as used by gzip/PNG).
pub fn crc32(data: &[u8]) -> u32 {
    let mut table = [0u32; 256];
    for (i, slot) in table.iter_mut().enumerate() {
        let mut c = i as u32;
        for _ in 0..8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
        }
        *slot = c;
    }
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc = table[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    crc ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------------
// Encoding (net::frame conventions: LE fields, f32 as raw bits), via the
// shared [`codec`] also used by `model::artifact` params files
// ---------------------------------------------------------------------

impl RankState {
    /// Serialize to the versioned, CRC-trailed binary format.
    pub fn encode(&self) -> Vec<u8> {
        let elems = self.flat.len() + self.adam_m.len() + self.adam_v.len();
        let mut out = Vec::with_capacity(64 + 4 * elems);
        out.extend_from_slice(&MAGIC);
        put_u32(&mut out, VERSION);
        put_u32(&mut out, self.rank);
        put_u32(&mut out, self.n_ranks);
        put_u32(&mut out, self.epoch);
        put_u64(&mut out, self.adam_t);
        put_f32s(&mut out, &self.flat);
        put_f32s(&mut out, &self.adam_m);
        put_f32s(&mut out, &self.adam_v);
        put_mats(&mut out, &self.feat_buf);
        put_mats(&mut out, &self.grad_buf);
        let crc = crc32(&out);
        put_u32(&mut out, crc);
        out
    }

    /// Parse a snapshot, verifying the CRC, magic, and version first.
    pub fn decode(buf: &[u8]) -> std::result::Result<RankState, String> {
        if buf.len() < MAGIC.len() + 4 + 4 {
            return Err(format!("snapshot too short ({} bytes)", buf.len()));
        }
        let (body, crc_bytes) = buf.split_at(buf.len() - 4);
        let stored = u32::from_le_bytes([crc_bytes[0], crc_bytes[1], crc_bytes[2], crc_bytes[3]]);
        let computed = crc32(body);
        if stored != computed {
            return Err(format!("CRC mismatch: stored {stored:#010x}, computed {computed:#010x}"));
        }
        let mut c = Cursor::new(body);
        let magic = c.take(4)?;
        if magic != MAGIC {
            return Err(format!("bad magic {magic:?}"));
        }
        let version = c.u32()?;
        if version != VERSION {
            return Err(format!(
                "unsupported snapshot version {version} (this build reads {VERSION})"
            ));
        }
        let st = RankState {
            rank: c.u32()?,
            n_ranks: c.u32()?,
            epoch: c.u32()?,
            adam_t: c.u64()?,
            flat: c.f32s()?,
            adam_m: c.f32s()?,
            adam_v: c.f32s()?,
            feat_buf: c.mats()?,
            grad_buf: c.mats()?,
        };
        if c.pos() != body.len() {
            return Err(format!("trailing bytes in snapshot ({} of {})", c.pos(), body.len()));
        }
        Ok(st)
    }
}

// ---------------------------------------------------------------------
// Directory protocol
// ---------------------------------------------------------------------

/// Directory of the epoch-`epoch` checkpoint under `dir`.
pub fn epoch_dir(dir: &str, epoch: usize) -> PathBuf {
    std::path::Path::new(dir).join(format!("ep{epoch:08}"))
}

/// Path of rank `rank`'s snapshot file in the epoch-`epoch` checkpoint.
pub fn rank_file(dir: &str, epoch: usize, rank: usize) -> PathBuf {
    epoch_dir(dir, epoch).join(format!("rank{rank}.ckpt"))
}

/// Atomically write `st` into its epoch directory under `dir` (temp file
/// + rename, so a crash mid-write never leaves a half snapshot behind).
pub fn save(dir: &str, st: &RankState) -> Result<()> {
    let d = epoch_dir(dir, st.epoch as usize);
    std::fs::create_dir_all(&d)
        .with_context(|| format!("creating checkpoint dir {}", d.display()))?;
    let path = d.join(format!("rank{}.ckpt", st.rank));
    let tmp = d.join(format!(".rank{}.ckpt.tmp", st.rank));
    std::fs::write(&tmp, st.encode())
        .with_context(|| format!("writing checkpoint {}", tmp.display()))?;
    std::fs::rename(&tmp, &path)
        .with_context(|| format!("publishing checkpoint {}", path.display()))?;
    Ok(())
}

/// Load rank `rank`'s snapshot of the epoch-`epoch` checkpoint.
pub fn load(dir: &str, epoch: usize, rank: usize) -> Result<RankState> {
    let path = rank_file(dir, epoch, rank);
    let bytes = std::fs::read(&path)
        .with_context(|| format!("reading checkpoint {}", path.display()))?;
    RankState::decode(&bytes)
        .map_err(|e| crate::err_msg!("corrupt checkpoint {}: {e}", path.display()))
}

/// Highest epoch under `dir` whose checkpoint is **complete**: all
/// `n_ranks` rank files exist, decode with valid CRCs, and agree on the
/// epoch and mesh size. Incomplete or torn checkpoints (a rank died
/// mid-write) are skipped, so recovery always lands on consistent state.
pub fn latest_complete(dir: &str, n_ranks: usize) -> Result<Option<usize>> {
    let rd = match std::fs::read_dir(dir) {
        Ok(rd) => rd,
        Err(_) => return Ok(None), // no checkpoints yet
    };
    let mut epochs: Vec<usize> = rd
        .flatten()
        .filter_map(|e| {
            e.file_name()
                .into_string()
                .ok()
                .and_then(|name| name.strip_prefix("ep").and_then(|n| n.parse().ok()))
        })
        .collect();
    epochs.sort_unstable_by(|a, b| b.cmp(a));
    'epochs: for &epoch in &epochs {
        for rank in 0..n_ranks {
            match load(dir, epoch, rank) {
                Ok(st)
                    if st.epoch as usize == epoch
                        && st.n_ranks as usize == n_ranks
                        && st.rank as usize == rank => {}
                _ => continue 'epochs,
            }
        }
        return Ok(Some(epoch));
    }
    Ok(None)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(rank: u32, epoch: u32) -> RankState {
        RankState {
            rank,
            n_ranks: 2,
            epoch,
            adam_t: epoch as u64,
            flat: vec![1.0, -2.5, 3.25e-8, f32::MIN_POSITIVE],
            adam_m: vec![0.0, -0.0, 0.5, 1.0],
            adam_v: vec![0.125; 4],
            feat_buf: vec![Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0])],
            grad_buf: vec![Mat::zeros(0, 3), Mat::from_vec(1, 2, vec![7.0, 8.0])],
        }
    }

    fn tmp_dir(tag: &str) -> String {
        let d = format!("/tmp/pipegcn_ckpt_{tag}_{}", std::process::id());
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn encode_decode_roundtrip_bitwise() {
        let st = sample(1, 7);
        let back = RankState::decode(&st.encode()).unwrap();
        assert_eq!(back, st);
        // f32 payloads travel as raw bits: NaN patterns survive too
        let mut nan = sample(0, 1);
        nan.flat = vec![f32::from_bits(0x7FC0_1234)];
        nan.adam_m = vec![0.0];
        nan.adam_v = vec![0.0];
        let back = RankState::decode(&nan.encode()).unwrap();
        assert_eq!(back.flat[0].to_bits(), 0x7FC0_1234);
    }

    #[test]
    fn corruption_is_detected() {
        let bytes = sample(0, 3).encode();
        for pos in [0, 5, bytes.len() / 2, bytes.len() - 1] {
            let mut bad = bytes.clone();
            bad[pos] ^= 0x40;
            assert!(RankState::decode(&bad).is_err(), "flip at {pos} accepted");
        }
        assert!(RankState::decode(&bytes[..bytes.len() - 3]).is_err());
        assert!(RankState::decode(&[]).is_err());
    }

    #[test]
    fn version_is_enforced() {
        let mut bytes = sample(0, 1).encode();
        bytes[4] = 9; // version field
        let body_len = bytes.len() - 4;
        let crc = crc32(&bytes[..body_len]).to_le_bytes();
        bytes[body_len..].copy_from_slice(&crc);
        let err = RankState::decode(&bytes).unwrap_err();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn save_load_and_latest_complete() {
        let dir = tmp_dir("latest");
        assert_eq!(latest_complete(&dir, 2).unwrap(), None);
        for epoch in [2u32, 4] {
            for rank in 0..2u32 {
                save(&dir, &sample(rank, epoch)).unwrap();
            }
        }
        assert_eq!(latest_complete(&dir, 2).unwrap(), Some(4));
        let st = load(&dir, 4, 1).unwrap();
        assert_eq!(st, sample(1, 4));
        // an epoch missing one rank file is not complete
        save(&dir, &sample(0, 6)).unwrap();
        assert_eq!(latest_complete(&dir, 2).unwrap(), Some(4));
        // ...and a corrupted rank file disqualifies its epoch
        std::fs::write(rank_file(&dir, 4, 0), b"garbage").unwrap();
        assert_eq!(latest_complete(&dir, 2).unwrap(), Some(2));
        // wrong mesh size never matches
        assert_eq!(latest_complete(&dir, 3).unwrap(), None);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tmp_files_are_ignored() {
        let dir = tmp_dir("tmpfiles");
        save(&dir, &sample(0, 2)).unwrap();
        save(&dir, &sample(1, 2)).unwrap();
        // a torn write from a killed rank leaves only a .tmp behind
        std::fs::create_dir_all(epoch_dir(&dir, 8)).unwrap();
        std::fs::write(epoch_dir(&dir, 8).join(".rank0.ckpt.tmp"), b"partial").unwrap();
        assert_eq!(latest_complete(&dir, 2).unwrap(), Some(2));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn crc32_known_vector() {
        // standard test vector: crc32(b"123456789") = 0xCBF43926
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }
}
