//! Shared binary codec for on-disk snapshot formats ([`super`] rank
//! checkpoints and [`crate::model::artifact`] params files): little-endian
//! fixed-width fields, f32 payloads as raw bit patterns (NaN-safe), and a
//! bounds-checked [`Cursor`] for decoding. The CRC-32 trailer both formats
//! append is [`super::crc32`].

use crate::tensor::Mat;

pub(crate) fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    put_u64(out, xs.len() as u64);
    for x in xs {
        put_u32(out, x.to_bits());
    }
}

pub(crate) fn put_mat(out: &mut Vec<u8>, m: &Mat) {
    put_u32(out, m.rows as u32);
    put_u32(out, m.cols as u32);
    for x in &m.data {
        put_u32(out, x.to_bits());
    }
}

pub(crate) fn put_mats(out: &mut Vec<u8>, ms: &[Mat]) {
    put_u32(out, ms.len() as u32);
    for m in ms {
        put_mat(out, m);
    }
}

/// Bounds-checked reader over a decoded body (everything before the CRC
/// trailer). Every accessor validates lengths so a truncated or hostile
/// file is a diagnostic, never a panic or an implausible allocation.
pub(crate) struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, pos: 0 }
    }

    pub(crate) fn pos(&self) -> usize {
        self.pos
    }

    pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.pos + n > self.buf.len() {
            return Err(format!(
                "truncated snapshot: wanted {n} bytes at offset {}, body is {}",
                self.pos,
                self.buf.len()
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub(crate) fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> Result<u32, String> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub(crate) fn u64(&mut self) -> Result<u64, String> {
        let b = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }

    pub(crate) fn f32s(&mut self) -> Result<Vec<f32>, String> {
        let n = self.u64()? as usize;
        if n > self.buf.len() / 4 {
            return Err(format!("implausible vector length {n}"));
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(f32::from_bits(self.u32()?));
        }
        Ok(out)
    }

    pub(crate) fn mat(&mut self) -> Result<Mat, String> {
        let rows = self.u32()? as usize;
        let cols = self.u32()? as usize;
        if rows.saturating_mul(cols) > self.buf.len() / 4 {
            return Err(format!("implausible matrix shape {rows}×{cols}"));
        }
        let mut data = Vec::with_capacity(rows * cols);
        for _ in 0..rows * cols {
            data.push(f32::from_bits(self.u32()?));
        }
        Ok(Mat::from_vec(rows, cols, data))
    }

    pub(crate) fn mats(&mut self) -> Result<Vec<Mat>, String> {
        let n = self.u32()? as usize;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.mat()?);
        }
        Ok(out)
    }
}
