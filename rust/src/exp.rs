//! Experiment harness: builds a preset dataset, partitions it, derives
//! the training config ([`try_prepare`] — shared by every engine so
//! distributed runs are guaranteed the same inputs as the sequential
//! reference), and projects recorded schedules onto the paper's
//! simulated testbeds.
//!
//! Runs are built through [`crate::session::Session`] (the old
//! `run`/`run_logged`/`run_resumable` shims are gone); use
//! [`RunReport::into_output`](crate::session::RunReport::into_output)
//! to feed [`simulate`] / [`full_works`].

use crate::coordinator::{Optimizer, TrainConfig, TrainResult, Variant};
use crate::graph::presets::{by_name, Preset};
use crate::graph::Graph;
use crate::model::ModelConfig;
use crate::partition::{partition, Method, Partitioning};
use crate::sim::{epoch_time, DeviceProfile, EpochBreakdown, Mode, PartitionWork};
use crate::comm::topology::Topology;

/// One experiment run bundle.
pub struct RunOutput {
    pub preset: &'static Preset,
    pub graph: Graph,
    pub parts: Partitioning,
    pub result: TrainResult,
}

/// Options for [`run`]. `epochs = 0` keeps the preset default;
/// `nodes = 0` keeps the preset node count (any other value builds the
/// degree-preserving scaled variant via
/// [`Preset::build_scaled`](crate::graph::presets::Preset::build_scaled)).
#[derive(Clone, Copy, Debug)]
pub struct RunOpts {
    pub epochs: usize,
    pub seed: u64,
    pub probe_errors: bool,
    pub gamma: f32,
    pub eval_every: usize,
    /// Partitioner for `parts > 1` (multilevel is the default; `Hash`
    /// is the `--partitioner simple` escape hatch).
    pub partitioner: Method,
    /// Override node count (0 = preset default).
    pub nodes: usize,
}

impl Default for RunOpts {
    fn default() -> RunOpts {
        RunOpts {
            epochs: 0,
            seed: 1,
            probe_errors: false,
            gamma: 0.95,
            eval_every: 5,
            partitioner: Method::Multilevel,
            nodes: 0,
        }
    }
}

/// Build the dataset, partition it (multilevel, the paper's METIS role),
/// and derive the training config — everything a run needs except the
/// engine. Shared by [`run`] (sequential) and `pipegcn worker`
/// (multi-process TCP), so a distributed run's inputs are guaranteed
/// identical to the sequential reference it is compared against.
///
/// Inputs are validated **before** any expensive work, so a bad preset
/// or method name from the CLI surfaces as a diagnostic, not a panic
/// mid-build.
pub fn try_prepare(
    preset_name: &str,
    n_parts: usize,
    variant_name: &str,
    opts: RunOpts,
) -> crate::util::error::Result<(&'static Preset, Graph, Partitioning, TrainConfig)> {
    let (preset, cfg) = try_config(preset_name, n_parts, variant_name, opts)?;
    let graph = if opts.nodes > 0 && opts.nodes != preset.n {
        preset.build_scaled(opts.nodes, opts.seed)
    } else {
        preset.build(opts.seed)
    };
    let parts = partition(&graph, n_parts, opts.partitioner, opts.seed);
    Ok((preset, graph, parts, cfg))
}

/// The validation + config half of [`try_prepare`]: resolves the preset
/// and training config **without building a graph** — the scale path
/// (per-rank lazy construction) calls this, then materializes only its
/// own shard from `(seed, part, parts)`.
pub fn try_config(
    preset_name: &str,
    n_parts: usize,
    variant_name: &str,
    opts: RunOpts,
) -> crate::util::error::Result<(&'static Preset, TrainConfig)> {
    let preset = by_name(preset_name).ok_or_else(|| {
        crate::err_msg!(
            "unknown preset '{preset_name}' (try: {:?})",
            crate::graph::presets::names()
        )
    })?;
    // Variant::parse's error already names every valid method
    let variant = Variant::parse(variant_name, opts.gamma)?;
    if n_parts == 0 {
        crate::bail!("partition count must be at least 1");
    }
    let cfg = TrainConfig {
        model: ModelConfig::from_preset(preset),
        variant,
        optimizer: Optimizer::Adam,
        lr: preset.lr,
        epochs: if opts.epochs > 0 { opts.epochs } else { preset.epochs },
        seed: opts.seed,
        eval_every: opts.eval_every,
        probe_errors: opts.probe_errors,
    };
    Ok((preset, cfg))
}

/// [`try_prepare`], panicking on bad inputs (library/test convenience).
pub fn prepare(
    preset_name: &str,
    n_parts: usize,
    variant_name: &str,
    opts: RunOpts,
) -> (&'static Preset, Graph, Partitioning, TrainConfig) {
    try_prepare(preset_name, n_parts, variant_name, opts).unwrap_or_else(|e| panic!("{e}"))
}

/// Scale a recorded per-iteration work description to the mirrored
/// full-size dataset: FLOPs and bytes grow ~linearly with node count at
/// fixed density and partition count (documented approximation,
/// DESIGN.md §1).
pub fn scale_works(works: &[PartitionWork], factor: f64) -> Vec<PartitionWork> {
    works
        .iter()
        .map(|w| PartitionWork {
            fwd: w
                .fwd
                .iter()
                .map(|l| crate::sim::LayerCompute {
                    spmm_flops: l.spmm_flops * factor,
                    gemm_flops: l.gemm_flops * factor,
                })
                .collect(),
            bwd: w
                .bwd
                .iter()
                .map(|l| crate::sim::LayerCompute {
                    spmm_flops: l.spmm_flops * factor,
                    gemm_flops: l.gemm_flops * factor,
                })
                .collect(),
            fwd_comm: w
                .fwd_comm
                .iter()
                .map(|layer| {
                    layer.iter().map(|&(p, b)| (p, (b as f64 * factor) as u64)).collect()
                })
                .collect(),
            bwd_comm: w
                .bwd_comm
                .iter()
                .map(|layer| {
                    layer.iter().map(|&(p, b)| (p, (b as f64 * factor) as u64)).collect()
                })
                .collect(),
        })
        .collect()
}

/// Project the **measured partition structure** onto the mirrored
/// dataset's true scale (paper Table 3) and build the per-partition work
/// description the timeline simulator consumes.
///
/// Shares are measured, magnitudes are real: each partition's node/edge
/// share and its per-pair boundary-replica counts come from the actual
/// partitioned run; node count, edge count, and layer widths come from
/// `preset.full`. This keeps the compute:communication balance of the
/// full dataset (a uniformly scaled small graph would not — its degree
/// and feature widths are ~10× smaller, inflating the comm ratio).
pub fn full_works(out: &RunOutput) -> (Vec<PartitionWork>, usize) {
    let full = &out.preset.full;
    let plan = crate::coordinator::halo::build(
        &out.graph,
        &out.parts,
        crate::model::LayerKind::SageMean,
    );
    let k = plan.n_parts;
    let n_ratio = full.n / out.graph.n as f64;
    let nnz_sim_total: f64 = plan.parts.iter().map(|p| p.prop.nnz() as f64).sum();
    // full layer widths
    let layers = out.preset.layers;
    let mut dims = vec![full.feat];
    for _ in 0..layers - 1 {
        dims.push(full.hidden);
    }
    dims.push(full.classes);
    let works = (0..k)
        .map(|i| {
            let p = &plan.parts[i];
            let nnz_share = p.prop.nnz() as f64 / nnz_sim_total;
            let nnz_full = full.nnz * nnz_share;
            let rows_full = p.n_local() as f64 * n_ratio;
            let mut fwd = Vec::new();
            let mut bwd = Vec::new();
            let mut fwd_comm = Vec::new();
            let mut bwd_comm = Vec::new();
            for l in 0..layers {
                let (f_in, f_out) = (dims[l] as f64, dims[l + 1] as f64);
                let lc = crate::sim::LayerCompute {
                    spmm_flops: 2.0 * nnz_full * f_in,
                    gemm_flops: 2.0 * rows_full * f_in * f_out * 2.0,
                };
                fwd.push(lc);
                bwd.push(crate::sim::LayerCompute {
                    spmm_flops: 2.0 * lc.spmm_flops,
                    gemm_flops: 2.0 * lc.gemm_flops,
                });
                let pair_bytes = |f: f64| -> Vec<(usize, u64)> {
                    (0..k)
                        .filter(|&j| j != i)
                        .filter_map(|j| {
                            let cnt = p.send_sets[j].len() + p.halo_ranges[j].len();
                            if cnt == 0 {
                                None
                            } else {
                                Some((j, (cnt as f64 * n_ratio * f * 4.0) as u64))
                            }
                        })
                        .collect()
                };
                fwd_comm.push(pair_bytes(f_in));
                bwd_comm.push(if l == 0 { Vec::new() } else { pair_bytes(f_in) });
            }
            PartitionWork { fwd, bwd, fwd_comm, bwd_comm }
        })
        .collect();
    // full model parameter count (dual SAGE weights)
    let model_elems: usize =
        (0..layers).map(|l| dims[l] * dims[l + 1] * 2).sum();
    (works, model_elems)
}

/// Project a run's schedule onto a simulated testbed at full dataset
/// scale (see [`full_works`]).
pub fn simulate(
    out: &RunOutput,
    profile: &DeviceProfile,
    topo: &Topology,
    mode: Mode,
) -> EpochBreakdown {
    let (works, model_elems) = full_works(out);
    epoch_time(&works, model_elems, profile, topo, mode)
}

/// Simulated epoch time on the default single-chassis rig.
pub fn simulate_default(out: &RunOutput, mode: Mode) -> EpochBreakdown {
    let (profile, topo) = crate::sim::profiles::rig_2080ti(out.parts.n_parts);
    simulate(out, &profile, &topo, mode)
}

/// Paper-style throughput line: epochs/s on the simulated testbed.
pub fn sim_epochs_per_s(b: &EpochBreakdown) -> f64 {
    if b.total > 0.0 {
        1.0 / b.total
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A sequential Session run repackaged for the simulation helpers.
    fn run(preset: &str, parts: usize, method: &str, opts: RunOpts) -> RunOutput {
        crate::session::Session::preset(preset)
            .parts(parts)
            .variant(method)
            .run_opts(opts)
            .run()
            .unwrap_or_else(|e| panic!("{e}"))
            .into_output()
    }

    #[test]
    fn run_tiny_end_to_end() {
        let out = run(
            "tiny",
            2,
            "pipegcn",
            RunOpts { epochs: 8, eval_every: 8, ..Default::default() },
        );
        assert_eq!(out.result.curve.len(), 8);
        assert!(out.result.final_test > 0.0);
        let v = simulate_default(&out, Mode::Vanilla);
        let p = simulate_default(&out, Mode::Pipelined);
        assert!(p.total < v.total, "pipelined {p:?} vs vanilla {v:?}");
    }

    #[test]
    fn scaling_multiplies_flops_and_bytes() {
        let out = run("tiny", 2, "gcn", RunOpts { epochs: 2, ..Default::default() });
        let scaled = scale_works(&out.result.works, 10.0);
        let f0 = out.result.works[0].fwd[0].spmm_flops;
        assert!((scaled[0].fwd[0].spmm_flops - 10.0 * f0).abs() < 1e-6 * f0.max(1.0));
        let b0: u64 = out.result.works[0].fwd_comm[0].iter().map(|&(_, b)| b).sum();
        let b1: u64 = scaled[0].fwd_comm[0].iter().map(|&(_, b)| b).sum();
        assert_eq!(b1, 10 * b0);
    }

    #[test]
    #[should_panic(expected = "unknown preset")]
    fn unknown_preset_panics() {
        run("nope", 2, "gcn", RunOpts::default());
    }

    /// CLI paths validate before any expensive work: bad inputs come
    /// back as diagnostics, not panics deep inside the build.
    #[test]
    fn try_prepare_rejects_bad_inputs_with_diagnostics() {
        let e = try_prepare("nope", 2, "gcn", RunOpts::default()).unwrap_err();
        assert!(e.to_string().contains("unknown preset"), "{e}");
        let e = try_prepare("tiny", 2, "nope", RunOpts::default()).unwrap_err();
        assert!(e.to_string().contains("unknown method"), "{e}");
        let e = try_prepare("tiny", 0, "gcn", RunOpts::default()).unwrap_err();
        assert!(e.to_string().contains("at least 1"), "{e}");
    }
}
