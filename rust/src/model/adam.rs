//! Adam optimizer over flat parameter vectors (paper Table 3: Adam for
//! all datasets).
//!
//! The distributed trainer keeps identical Adam state on every partition
//! (the all-reduced gradient is identical everywhere, as in Alg. 1
//! line 32-33), so a single instance updates the shared flat weights.

use crate::runtime::pool;

#[derive(Clone, Debug)]
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

impl Adam {
    pub fn new(lr: f32, n_params: usize) -> Adam {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: vec![0.0; n_params],
            v: vec![0.0; n_params],
            t: 0,
        }
    }

    /// One update step: `params -= lr * m̂ / (√v̂ + ε)`.
    ///
    /// Elementwise with one owner per index, so the pool-parallel path
    /// is bit-identical to the serial one at any thread count.
    pub fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        assert_eq!(params.len(), self.m.len());
        assert_eq!(grad.len(), self.m.len());
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        let (lr, beta1, beta2, eps) = (self.lr, self.beta1, self.beta2, self.eps);
        let step_range = |ps: &mut [f32], ms: &mut [f32], vs: &mut [f32], gs: &[f32]| {
            for i in 0..ps.len() {
                let g = gs[i];
                ms[i] = beta1 * ms[i] + (1.0 - beta1) * g;
                vs[i] = beta2 * vs[i] + (1.0 - beta2) * g * g;
                let mhat = ms[i] / b1t;
                let vhat = vs[i] / b2t;
                ps[i] -= lr * mhat / (vhat.sqrt() + eps);
            }
        };
        let n = params.len();
        let pl = pool::global();
        if pl.threads() == 1 || n < 1 << 14 {
            step_range(params, &mut self.m, &mut self.v, grad);
            return;
        }
        let p = pool::SendPtr(params.as_mut_ptr());
        let m = pool::SendPtr(self.m.as_mut_ptr());
        let v = pool::SendPtr(self.v.as_mut_ptr());
        pool::for_ranges(&pl, n, |r| {
            // SAFETY: for_ranges hands out disjoint ranges — every index
            // has exactly one owner task
            let (ps, ms, vs) = unsafe {
                (
                    std::slice::from_raw_parts_mut(p.0.add(r.start), r.len()),
                    std::slice::from_raw_parts_mut(m.0.add(r.start), r.len()),
                    std::slice::from_raw_parts_mut(v.0.add(r.start), r.len()),
                )
            };
            step_range(ps, ms, vs, &grad[r]);
        });
    }

    pub fn step_count(&self) -> u64 {
        self.t
    }

    /// Optimizer state view for checkpointing: `(m, v, t)`.
    pub fn state(&self) -> (&[f32], &[f32], u64) {
        (&self.m, &self.v, self.t)
    }

    /// Rebuild an optimizer mid-run from checkpointed moments — the
    /// resumed instance continues the uninterrupted trajectory exactly
    /// (the bias corrections depend only on `t`).
    pub fn restore(lr: f32, m: Vec<f32>, v: Vec<f32>, t: u64) -> Adam {
        assert_eq!(m.len(), v.len(), "Adam moment length mismatch");
        Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, m, v, t }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimizes_quadratic() {
        // f(x) = Σ (x_i - target_i)^2
        let target = [3.0f32, -2.0, 0.5];
        let mut x = vec![0.0f32; 3];
        let mut adam = Adam::new(0.05, 3);
        for _ in 0..800 {
            let grad: Vec<f32> = x.iter().zip(&target).map(|(xi, ti)| 2.0 * (xi - ti)).collect();
            adam.step(&mut x, &grad);
        }
        for (xi, ti) in x.iter().zip(&target) {
            assert!((xi - ti).abs() < 1e-2, "{xi} vs {ti}");
        }
    }

    #[test]
    fn first_step_size_is_lr() {
        // classic Adam property: |Δx| ≈ lr on the first step regardless of
        // gradient scale
        let mut x = vec![0.0f32];
        let mut adam = Adam::new(0.01, 1);
        adam.step(&mut x, &[1234.5]);
        assert!((x[0] + 0.01).abs() < 1e-4, "{}", x[0]);
    }

    #[test]
    fn zero_grad_no_movement() {
        let mut x = vec![1.0f32, 2.0];
        let mut adam = Adam::new(0.1, 2);
        adam.step(&mut x, &[0.0, 0.0]);
        assert_eq!(x, vec![1.0, 2.0]);
    }

    #[test]
    fn deterministic() {
        let run = || {
            let mut x = vec![1.0f32; 4];
            let mut adam = Adam::new(0.02, 4);
            for i in 0..50 {
                let g: Vec<f32> = x.iter().map(|v| v * 0.5 + i as f32 * 0.01).collect();
                adam.step(&mut x, &g);
            }
            x
        };
        assert_eq!(run(), run());
    }
}
