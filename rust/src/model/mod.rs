//! GCN / GraphSAGE model definition: configs, parameters, initialization,
//! flattening for the gradient all-reduce, and the Adam optimizer.
//!
//! The layer math itself executes through a [`crate::runtime::Backend`]
//! so the same trainer runs on the native Rust kernels or the AOT XLA
//! artifacts.

pub mod adam;
pub mod artifact;

use crate::tensor::Mat;
use crate::util::rng::Rng;

/// Layer flavor.
///
/// * `Gcn` — Kipf & Welling: `H' = σ(P·H·W)` with symmetric-normalized P.
/// * `SageMean` — GraphSAGE mean aggregator as in the paper's experiments:
///   `H' = σ(H·W_self + (P_mean·H)·W_neigh)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    Gcn,
    SageMean,
}

impl LayerKind {
    /// Parse a layer-kind name. The error names every accepted spelling,
    /// so a CLI typo comes back with the list instead of a bare
    /// "unknown" (same contract as [`crate::coordinator::Variant::parse`]).
    pub fn parse(s: &str) -> Result<LayerKind, String> {
        match s {
            "gcn" => Ok(LayerKind::Gcn),
            "sage" | "sage-mean" | "graphsage" => Ok(LayerKind::SageMean),
            _ => Err(format!(
                "unknown layer kind '{s}' (known: gcn, sage, sage-mean, graphsage)"
            )),
        }
    }

    /// Stable on-disk encoding (used by [`artifact`] params files).
    pub fn code(self) -> u8 {
        match self {
            LayerKind::Gcn => 0,
            LayerKind::SageMean => 1,
        }
    }

    pub fn from_code(c: u8) -> Option<LayerKind> {
        match c {
            0 => Some(LayerKind::Gcn),
            1 => Some(LayerKind::SageMean),
            _ => None,
        }
    }
}

/// Model hyper-parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub kind: LayerKind,
    /// layer widths: `[f_in, hidden, ..., n_classes]` (len = layers+1)
    pub dims: Vec<usize>,
    pub dropout: f32,
}

impl ModelConfig {
    pub fn sage(f_in: usize, hidden: usize, layers: usize, n_classes: usize, dropout: f32) -> Self {
        assert!(layers >= 1);
        let mut dims = vec![f_in];
        for _ in 0..layers - 1 {
            dims.push(hidden);
        }
        dims.push(n_classes);
        ModelConfig { kind: LayerKind::SageMean, dims, dropout }
    }

    pub fn gcn(f_in: usize, hidden: usize, layers: usize, n_classes: usize, dropout: f32) -> Self {
        let mut cfg = Self::sage(f_in, hidden, layers, n_classes, dropout);
        cfg.kind = LayerKind::Gcn;
        cfg
    }

    /// The model a dataset preset trains. Training (`exp::try_prepare`),
    /// `pipegcn export-params`, and `pipegcn serve` all derive their
    /// shapes from this one place, so a checkpoint exported for a preset
    /// can never silently disagree with the model that produced it.
    pub fn from_preset(p: &crate::graph::presets::Preset) -> ModelConfig {
        ModelConfig::sage(p.feat_dim, p.hidden, p.layers, p.n_classes, p.dropout)
    }

    pub fn n_layers(&self) -> usize {
        self.dims.len() - 1
    }
}

/// One layer's weights. GCN layers have `w_self = None`.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerParams {
    pub w_self: Option<Mat>,
    pub w_neigh: Mat,
}

/// Full parameter set.
#[derive(Clone, Debug, PartialEq)]
pub struct Params {
    pub layers: Vec<LayerParams>,
}

impl Params {
    /// Glorot-uniform initialization, deterministic in `rng`.
    pub fn init(cfg: &ModelConfig, rng: &mut Rng) -> Params {
        let mut layers = Vec::with_capacity(cfg.n_layers());
        for l in 0..cfg.n_layers() {
            let (fi, fo) = (cfg.dims[l], cfg.dims[l + 1]);
            let a = (6.0 / (fi + fo) as f32).sqrt();
            let w_neigh = Mat::rand_uniform(fi, fo, a, rng);
            let w_self = match cfg.kind {
                LayerKind::SageMean => Some(Mat::rand_uniform(fi, fo, a, rng)),
                LayerKind::Gcn => None,
            };
            layers.push(LayerParams { w_self, w_neigh });
        }
        Params { layers }
    }

    /// Total scalar count (for all-reduce sizing and Adam state).
    pub fn n_elems(&self) -> usize {
        self.layers
            .iter()
            .map(|l| {
                l.w_neigh.data.len() + l.w_self.as_ref().map(|w| w.data.len()).unwrap_or(0)
            })
            .sum()
    }

    /// Flatten all weights into one vector (w_neigh then w_self per layer).
    pub fn flatten(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.n_elems());
        for l in &self.layers {
            out.extend_from_slice(&l.w_neigh.data);
            if let Some(w) = &l.w_self {
                out.extend_from_slice(&w.data);
            }
        }
        out
    }

    /// Overwrite weights from a flat vector (inverse of [`flatten`]).
    pub fn unflatten(&mut self, flat: &[f32]) {
        let mut off = 0usize;
        for l in &mut self.layers {
            let n = l.w_neigh.data.len();
            l.w_neigh.data.copy_from_slice(&flat[off..off + n]);
            off += n;
            if let Some(w) = &mut l.w_self {
                let n = w.data.len();
                w.data.copy_from_slice(&flat[off..off + n]);
                off += n;
            }
        }
        assert_eq!(off, flat.len(), "flat size mismatch");
    }

    /// Zeroed gradient accumulator with the same shapes.
    pub fn zeros_like(&self) -> Params {
        Params {
            layers: self
                .layers
                .iter()
                .map(|l| LayerParams {
                    w_self: l.w_self.as_ref().map(|w| Mat::zeros(w.rows, w.cols)),
                    w_neigh: Mat::zeros(l.w_neigh.rows, l.w_neigh.cols),
                })
                .collect(),
        }
    }

    pub fn add_assign(&mut self, other: &Params) {
        for (a, b) in self.layers.iter_mut().zip(&other.layers) {
            a.w_neigh.add_assign(&b.w_neigh);
            if let (Some(ws), Some(wo)) = (&mut a.w_self, &b.w_self) {
                ws.add_assign(wo);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_shapes() {
        let cfg = ModelConfig::sage(10, 16, 3, 4, 0.0);
        assert_eq!(cfg.dims, vec![10, 16, 16, 4]);
        let mut rng = Rng::new(1);
        let p = Params::init(&cfg, &mut rng);
        assert_eq!(p.layers.len(), 3);
        assert_eq!(p.layers[0].w_neigh.rows, 10);
        assert_eq!(p.layers[0].w_neigh.cols, 16);
        assert_eq!(p.layers[2].w_neigh.cols, 4);
        assert!(p.layers[0].w_self.is_some());
    }

    #[test]
    fn gcn_has_no_self_weight() {
        let cfg = ModelConfig::gcn(8, 8, 2, 3, 0.0);
        let mut rng = Rng::new(2);
        let p = Params::init(&cfg, &mut rng);
        assert!(p.layers.iter().all(|l| l.w_self.is_none()));
    }

    #[test]
    fn flatten_roundtrip() {
        let cfg = ModelConfig::sage(5, 7, 2, 3, 0.0);
        let mut rng = Rng::new(3);
        let p = Params::init(&cfg, &mut rng);
        let flat = p.flatten();
        assert_eq!(flat.len(), p.n_elems());
        let mut q = p.clone();
        q.layers[0].w_neigh.fill(0.0);
        q.unflatten(&flat);
        assert_eq!(p, q);
    }

    #[test]
    fn zeros_like_and_accumulate() {
        let cfg = ModelConfig::sage(3, 4, 2, 2, 0.0);
        let mut rng = Rng::new(4);
        let p = Params::init(&cfg, &mut rng);
        let mut acc = p.zeros_like();
        acc.add_assign(&p);
        acc.add_assign(&p);
        let want: Vec<f32> = p.flatten().iter().map(|x| 2.0 * x).collect();
        crate::util::prop::assert_close(&acc.flatten(), &want, 1e-6).unwrap();
    }

    #[test]
    fn layer_kind_parse_lists_valid_values_on_error() {
        assert_eq!(LayerKind::parse("gcn"), Ok(LayerKind::Gcn));
        for s in ["sage", "sage-mean", "graphsage"] {
            assert_eq!(LayerKind::parse(s), Ok(LayerKind::SageMean));
        }
        let e = LayerKind::parse("mlp").unwrap_err();
        assert!(e.contains("sage-mean") && e.contains("gcn"), "{e}");
        for k in [LayerKind::Gcn, LayerKind::SageMean] {
            assert_eq!(LayerKind::from_code(k.code()), Some(k));
        }
        assert_eq!(LayerKind::from_code(9), None);
    }

    #[test]
    fn glorot_scale_reasonable() {
        let cfg = ModelConfig::sage(100, 100, 1, 100, 0.0);
        let mut rng = Rng::new(5);
        let p = Params::init(&cfg, &mut rng);
        let w = &p.layers[0].w_neigh;
        let a = (6.0f32 / 200.0).sqrt();
        assert!(w.data.iter().all(|&x| x.abs() <= a));
        let mean: f32 = w.data.iter().sum::<f32>() / w.data.len() as f32;
        assert!(mean.abs() < 0.01);
    }
}
