//! Standalone inference artifact: `ModelConfig` + weights, nothing else.
//!
//! A training checkpoint ([`crate::ckpt`]) snapshots everything a resumed
//! run needs — Adam moments, stale PipeGCN buffers, the epoch counter.
//! Serving needs none of that, so `pipegcn export-params` distills a
//! checkpoint into this much smaller file: the model shape and the final
//! weights, in the same dependency-free binary framing (little-endian
//! fields, f32 weights as raw bit patterns, trailing CRC-32), versioned
//! and magic-tagged so a torn or mismatched file is rejected with a
//! diagnostic instead of serving garbage logits.
//!
//! `pipegcn serve` loads this file; it never touches checkpoint
//! directories, so a serving host needs exactly one artifact.

use super::{LayerKind, LayerParams, ModelConfig, Params};
use crate::ckpt::codec::{put_mat, put_u32, Cursor};
use crate::ckpt::crc32;
use crate::util::error::{Context, Result};

/// File magic of a params artifact ("PipeGcn ParaMs").
pub const MAGIC: [u8; 4] = *b"PGPM";
/// Current artifact format version.
pub const VERSION: u32 = 1;

/// The decoded artifact: enough to rebuild the forward pass, nothing
/// more.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamsFile {
    pub config: ModelConfig,
    pub params: Params,
}

impl ParamsFile {
    /// Serialize to the versioned, CRC-trailed binary format.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(64 + 4 * self.params.n_elems());
        out.extend_from_slice(&MAGIC);
        put_u32(&mut out, VERSION);
        out.push(self.config.kind.code());
        put_u32(&mut out, self.config.dropout.to_bits());
        put_u32(&mut out, self.config.dims.len() as u32);
        for &d in &self.config.dims {
            put_u32(&mut out, d as u32);
        }
        put_u32(&mut out, self.params.layers.len() as u32);
        for l in &self.params.layers {
            put_mat(&mut out, &l.w_neigh);
            out.push(l.w_self.is_some() as u8);
            if let Some(w) = &l.w_self {
                put_mat(&mut out, w);
            }
        }
        let crc = crc32(&out);
        put_u32(&mut out, crc);
        out
    }

    /// Parse an artifact, verifying CRC, magic, version, and that every
    /// weight shape matches the declared layer dims.
    pub fn decode(buf: &[u8]) -> std::result::Result<ParamsFile, String> {
        if buf.len() < MAGIC.len() + 4 + 4 {
            return Err(format!("params file too short ({} bytes)", buf.len()));
        }
        let (body, crc_bytes) = buf.split_at(buf.len() - 4);
        let stored = u32::from_le_bytes([crc_bytes[0], crc_bytes[1], crc_bytes[2], crc_bytes[3]]);
        let computed = crc32(body);
        if stored != computed {
            return Err(format!("CRC mismatch: stored {stored:#010x}, computed {computed:#010x}"));
        }
        let mut c = Cursor::new(body);
        let magic = c.take(4)?;
        if magic != MAGIC {
            return Err(format!("bad magic {magic:?} (not a params artifact)"));
        }
        let version = c.u32()?;
        if version != VERSION {
            return Err(format!(
                "unsupported params-file version {version} (this build reads {VERSION})"
            ));
        }
        let kind_code = c.u8()?;
        let kind = LayerKind::from_code(kind_code)
            .ok_or_else(|| format!("bad layer-kind code {kind_code}"))?;
        let dropout = f32::from_bits(c.u32()?);
        let n_dims = c.u32()? as usize;
        if !(2..=64).contains(&n_dims) {
            return Err(format!("implausible dim count {n_dims}"));
        }
        let mut dims = Vec::with_capacity(n_dims);
        for _ in 0..n_dims {
            dims.push(c.u32()? as usize);
        }
        let n_layers = c.u32()? as usize;
        if n_layers != n_dims - 1 {
            return Err(format!("{n_layers} layers do not match {n_dims} dims"));
        }
        let mut layers = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            let w_neigh = c.mat()?;
            let w_self = if c.u8()? != 0 { Some(c.mat()?) } else { None };
            let want = (dims[l], dims[l + 1]);
            if (w_neigh.rows, w_neigh.cols) != want {
                return Err(format!(
                    "layer {l}: w_neigh is {}×{}, dims say {}×{}",
                    w_neigh.rows, w_neigh.cols, want.0, want.1
                ));
            }
            if let Some(w) = &w_self {
                if (w.rows, w.cols) != want {
                    return Err(format!(
                        "layer {l}: w_self is {}×{}, dims say {}×{}",
                        w.rows, w.cols, want.0, want.1
                    ));
                }
            }
            layers.push(LayerParams { w_self, w_neigh });
        }
        if c.pos() != body.len() {
            return Err(format!("trailing bytes in params file ({} of {})", c.pos(), body.len()));
        }
        Ok(ParamsFile { config: ModelConfig { kind, dims, dropout }, params: Params { layers } })
    }
}

/// Content-addressed artifact version: the CRC-32 of the encoded file.
/// Any weight or config change produces a different version, identical
/// content always produces the same one, so the serving tier can key
/// activation caches on it and stamp it into query responses without a
/// separate version registry.
pub fn content_version(pf: &ParamsFile) -> u32 {
    crc32(&pf.encode())
}

/// Atomically write the artifact (temp file + rename, like [`crate::ckpt`]).
pub fn save(path: &str, pf: &ParamsFile) -> Result<()> {
    let p = std::path::Path::new(path);
    if let Some(dir) = p.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating params dir {}", dir.display()))?;
        }
    }
    let tmp = format!("{path}.tmp");
    std::fs::write(&tmp, pf.encode()).with_context(|| format!("writing params file {tmp}"))?;
    std::fs::rename(&tmp, path).with_context(|| format!("publishing params file {path}"))?;
    Ok(())
}

/// Load and verify a params artifact.
pub fn load(path: &str) -> Result<ParamsFile> {
    let bytes =
        std::fs::read(path).with_context(|| format!("reading params file {path}"))?;
    ParamsFile::decode(&bytes).map_err(|e| crate::err_msg!("corrupt params file {path}: {e}"))
}

/// Distill a training checkpoint into a params artifact: take rank 0's
/// snapshot of `epoch` (default: the latest complete checkpoint for
/// `n_ranks`), drop the optimizer/staleness state, and unflatten the
/// parameters into `cfg`'s shapes. Returns the artifact and the epoch it
/// came from.
pub fn export_from_ckpt(
    dir: &str,
    n_ranks: usize,
    cfg: &ModelConfig,
    epoch: Option<usize>,
) -> Result<(ParamsFile, usize)> {
    let epoch = match epoch {
        Some(e) => e,
        None => crate::ckpt::latest_complete(dir, n_ranks)?.ok_or_else(|| {
            crate::err_msg!("no complete checkpoint for {n_ranks} ranks under {dir}")
        })?,
    };
    let snap = crate::ckpt::load(dir, epoch, 0)?;
    // parameters are replicated across ranks, so rank 0's copy is the model
    let mut params = Params::init(cfg, &mut crate::util::rng::Rng::new(0));
    if snap.flat.len() != params.n_elems() {
        crate::bail!(
            "checkpoint {dir} (epoch {epoch}) holds {} parameters but the dims {:?} model \
             needs {} — wrong --dataset for this checkpoint?",
            snap.flat.len(),
            cfg.dims,
            params.n_elems()
        );
    }
    params.unflatten(&snap.flat);
    Ok((ParamsFile { config: cfg.clone(), params }, epoch))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample() -> ParamsFile {
        let config = ModelConfig::sage(6, 5, 2, 3, 0.25);
        let params = Params::init(&config, &mut Rng::new(11));
        ParamsFile { config, params }
    }

    #[test]
    fn encode_decode_roundtrip_bitwise() {
        let pf = sample();
        let back = ParamsFile::decode(&pf.encode()).unwrap();
        assert_eq!(back, pf);
        // GCN configs (no w_self) roundtrip too, and NaN bit patterns
        // survive exactly
        let config = ModelConfig::gcn(4, 4, 2, 2, 0.0);
        let mut params = Params::init(&config, &mut Rng::new(2));
        params.layers[0].w_neigh.data[0] = f32::from_bits(0x7FC0_1234);
        let pf = ParamsFile { config, params };
        let back = ParamsFile::decode(&pf.encode()).unwrap();
        assert!(back.params.layers.iter().all(|l| l.w_self.is_none()));
        assert_eq!(back.params.layers[0].w_neigh.data[0].to_bits(), 0x7FC0_1234);
    }

    #[test]
    fn corruption_is_detected() {
        let bytes = sample().encode();
        for pos in [0, 6, bytes.len() / 2, bytes.len() - 1] {
            let mut bad = bytes.clone();
            bad[pos] ^= 0x20;
            assert!(ParamsFile::decode(&bad).is_err(), "flip at {pos} accepted");
        }
        assert!(ParamsFile::decode(&bytes[..bytes.len() - 5]).is_err());
        assert!(ParamsFile::decode(&[]).is_err());
    }

    #[test]
    fn version_is_enforced() {
        let mut bytes = sample().encode();
        bytes[4] = 9; // version field
        let body_len = bytes.len() - 4;
        let crc = crc32(&bytes[..body_len]).to_le_bytes();
        bytes[body_len..].copy_from_slice(&crc);
        let err = ParamsFile::decode(&bytes).unwrap_err();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn content_version_tracks_weight_bits() {
        let pf = sample();
        let v = content_version(&pf);
        assert_eq!(v, content_version(&pf), "version must be deterministic");
        let mut changed = pf.clone();
        let bits = changed.params.layers[0].w_neigh.data[0].to_bits();
        changed.params.layers[0].w_neigh.data[0] = f32::from_bits(bits ^ 1);
        assert_ne!(v, content_version(&changed), "a one-bit weight flip must change the version");
    }

    #[test]
    fn save_load_roundtrip() {
        let pf = sample();
        let path = format!("/tmp/pipegcn_params_{}.pgp", std::process::id());
        save(&path, &pf).unwrap();
        assert_eq!(load(&path).unwrap(), pf);
        std::fs::write(&path, b"garbage").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn export_from_ckpt_takes_latest_complete_and_checks_shape() {
        let dir = format!("/tmp/pipegcn_export_{}", std::process::id());
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = ModelConfig::sage(6, 5, 2, 3, 0.0);
        let params = Params::init(&cfg, &mut Rng::new(4));
        let flat = params.flatten();
        for epoch in [2u32, 5] {
            for rank in 0..2u32 {
                let snap = crate::ckpt::RankState {
                    rank,
                    n_ranks: 2,
                    epoch,
                    adam_t: epoch as u64,
                    flat: flat.clone(),
                    adam_m: vec![0.0; flat.len()],
                    adam_v: vec![0.0; flat.len()],
                    feat_buf: Vec::new(),
                    grad_buf: Vec::new(),
                };
                crate::ckpt::save(&dir, &snap).unwrap();
            }
        }
        let (pf, epoch) = export_from_ckpt(&dir, 2, &cfg, None).unwrap();
        assert_eq!(epoch, 5);
        assert_eq!(pf.params.flatten(), flat);
        assert_eq!(pf.config, cfg);
        let (_, epoch) = export_from_ckpt(&dir, 2, &cfg, Some(2)).unwrap();
        assert_eq!(epoch, 2);
        // a mismatched model shape is a diagnostic, not a bad unflatten
        let wrong = ModelConfig::sage(7, 5, 2, 3, 0.0);
        let e = export_from_ckpt(&dir, 2, &wrong, None).unwrap_err();
        assert!(e.to_string().contains("parameters"), "{e}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
