//! The crate's front door: one builder for every way to run training.
//!
//! Historically each concern (logging, checkpointing, threads, fault
//! injection) grew its own entry point — nine near-duplicates
//! (`train`/`train_logged`, `exp::run`/`run_logged`/`run_resumable`,
//! `train_threaded`, …), each threading a different subset of options by
//! hand. [`Session`] collapsed them — the shims have since been deleted;
//! only the engine cores (`trainer::train_resumable`,
//! `threaded::run_rank_ctl`/`run_threaded_ctl`) remain underneath — one
//! builder, one [`run`](Session::run), one [`RunReport`], with the
//! execution strategy picked by [`Engine`]:
//!
//! * [`Engine::Sequential`] — every rank round-robin on one thread
//!   ([`trainer::train_resumable`]); the only engine that captures work
//!   descriptions and error probes, so it feeds the simulator.
//! * [`Engine::Threaded`] — one OS thread per partition over the
//!   in-process fabric ([`threaded::run_threaded_ctl`]).
//! * [`Engine::Tcp`] — one OS *process* per partition over real
//!   localhost sockets ([`crate::net::launch`]), supervised, with
//!   crash recovery from checkpoints.
//! * [`Engine::TcpWorker`] — a single rank of a TCP mesh
//!   ([`crate::net::worker`]; normally spawned by the `Tcp` engine).
//!
//! The engines are interchangeable: the schedule is deterministic
//! (staleness lives in message tags), so the loss curve is bit-identical
//! across all of them — asserted in `tests/session_api.rs`.
//!
//! ```no_run
//! use pipegcn::session::{Engine, Session};
//! let report = Session::preset("reddit-sim")
//!     .parts(4)
//!     .variant("pipegcn-gf")
//!     .epochs(20)
//!     .engine(Engine::Threaded)
//!     .run()
//!     .unwrap();
//! println!("final test metric: {:.4}", report.final_test);
//! ```

use crate::ckpt;
use crate::coordinator::{threaded, trainer, TrainConfig, TrainResult, Variant};
use crate::exp::{try_prepare, RunOpts, RunOutput};
use crate::graph::presets::{self, Preset};
use crate::graph::Graph;
use crate::model::Params;
use crate::net::launch::{self, LaunchOpts};
use crate::net::worker::{self, WorkerOpts};
use crate::partition::{Method, Partitioning};
use crate::runtime::native::NativeBackend;
use crate::runtime::pool;
use crate::util::error::{Context, Result};
use crate::util::json::{FileEmitter, Json};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Execution strategy for a [`Session`].
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub enum Engine {
    /// All ranks round-robin on the calling thread (instrumented
    /// reference engine; captures works/probes for the simulator).
    #[default]
    Sequential,
    /// One OS thread per partition over the in-process fabric.
    Threaded,
    /// One OS process per partition over localhost TCP: spawns
    /// `pipegcn worker` children, serves their rendezvous, supervises
    /// them, and (with a checkpoint policy) heals a worker death in
    /// place: only the dead rank is respawned, survivors re-rendezvous
    /// on the same address, and every rank rolls back to the latest
    /// complete checkpoint — falling back to a full mesh relaunch when a
    /// rejoin round cannot form. At most `max_restarts` recovery rounds.
    Tcp {
        /// recovery rounds allowed after a failure (needs `.ckpt(..)`)
        max_restarts: usize,
    },
    /// One rank of a TCP mesh, joining via the `coord` rendezvous
    /// address (this is what a `pipegcn worker` process runs).
    TcpWorker { rank: usize, coord: String },
}

/// What a [`Session::run`] produces, uniform across engines. Fields an
/// engine cannot measure are `None`/empty/NaN (e.g. a non-zero TCP
/// worker rank never sees the global loss; only the sequential engine
/// captures a full [`TrainResult`]).
#[derive(Debug)]
pub struct RunReport {
    /// which engine produced this report: `"sequential"`, `"threaded"`,
    /// `"tcp"`, or `"tcp-worker"`
    pub engine: String,
    /// per-epoch global train loss for the epochs this run executed
    /// (`start_epoch + 1 ..= epochs`); bit-identical across engines
    pub losses: Vec<f64>,
    /// completed epochs restored from a checkpoint (0 on a fresh run)
    pub start_epoch: usize,
    /// final val metric (NaN where the engine does not evaluate)
    pub final_val: f64,
    pub final_test: f64,
    /// payload bytes: total fabric traffic (sequential/threaded), or
    /// rank 0's sent payload (tcp engines)
    pub comm_bytes: u64,
    /// actual wire bytes incl. frame headers (tcp engines only, else 0)
    pub wire_bytes: u64,
    /// rank 0's total ms parked in receives under the prefetched
    /// schedule (structurally 0 on the sequential engine)
    pub comm_wait_ms: f64,
    /// fraction of rank 0's posted receives already complete when
    /// waited on (1.0 = communication fully hidden behind compute)
    pub overlap_ratio: f64,
    /// NDJSON rows streamed to a `.log(path)` run log opened by this
    /// process (0 when unused or when rank 0 of a `Tcp` launch owns it)
    pub log_rows: usize,
    /// quality of the partitioning the run trained on (edge cut, comm
    /// volume, replication factor, balance); `None` only on a non-zero
    /// TCP worker rank, which reports nothing
    pub quality: Option<crate::partition::Quality>,
    /// peak resident set size (`VmHWM`) of the reporting process at the
    /// end of the run — rank 0's for the `Tcp` engine; 0 off-Linux
    pub peak_rss_bytes: u64,
    /// the sequential engine's full result (works, probes, epoch stats)
    pub train: Option<TrainResult>,
    /// final parameters (threaded engine and TCP worker rank 0)
    pub params: Option<Params>,
    /// run inputs, when this process built them (local engines; the
    /// `Tcp` launcher only knows the preset)
    pub preset: Option<&'static Preset>,
    pub graph: Option<Graph>,
    pub parts: Option<Partitioning>,
}

impl RunReport {
    /// Repackage as the experiment bundle [`crate::exp`]'s simulation
    /// helpers consume. Panics unless this was a preset-built
    /// *sequential* run (the only engine that captures works/probes).
    pub fn into_output(self) -> RunOutput {
        match (self.preset, self.graph, self.parts, self.train) {
            (Some(preset), Some(graph), Some(parts), Some(result)) => {
                RunOutput { preset, graph, parts, result }
            }
            _ => panic!(
                "RunReport::into_output needs a preset-built sequential run \
                 (this was engine '{}')",
                self.engine
            ),
        }
    }
}

// a Graph source is much bigger than a preset name, but a Session is a
// short-lived one-per-run config object — boxing would only add noise
#[allow(clippy::large_enum_variant)]
enum Source {
    Preset(String),
    Graph { graph: Graph, parts: Partitioning, cfg: TrainConfig },
}

enum LogSink<'a> {
    Path(String),
    Emitter(&'a mut FileEmitter),
}

/// Builder for one training (or worker) run. See the module docs for the
/// engine semantics; every option not set keeps the preset/CLI default.
pub struct Session<'a> {
    source: Source,
    parts: usize,
    method: Option<String>,
    scale: Option<usize>,
    partitioner: Option<String>,
    epochs: Option<usize>,
    seed: Option<u64>,
    gamma: Option<f32>,
    eval_every: Option<usize>,
    probe_errors: bool,
    threads: Option<usize>,
    log: Option<LogSink<'a>>,
    out: Option<String>,
    ckpt: Option<ckpt::Policy>,
    resume: Option<String>,
    fail: Option<(usize, Vec<usize>)>,
    engine: Engine,
    binary: Option<PathBuf>,
    bind: Option<String>,
    connect_timeout: Option<u64>,
    connect_retries: Option<usize>,
    trace: Option<String>,
    metrics_addr: Option<String>,
    chaos: Option<String>,
    mesh_secret: Option<String>,
    form_deadline: Option<u64>,
    recv_deadline: Option<u64>,
    rejoin: bool,
}

/// Distinguishes concurrent sessions' scratch report files within one
/// process (tests run many sessions in parallel threads).
static TEMP_SEQ: AtomicUsize = AtomicUsize::new(0);

impl<'a> Session<'a> {
    fn new(source: Source) -> Session<'a> {
        Session {
            source,
            parts: 2,
            method: None,
            scale: None,
            partitioner: None,
            epochs: None,
            seed: None,
            gamma: None,
            eval_every: None,
            probe_errors: false,
            threads: None,
            log: None,
            out: None,
            ckpt: None,
            resume: None,
            fail: None,
            engine: Engine::Sequential,
            binary: None,
            bind: None,
            connect_timeout: None,
            connect_retries: None,
            trace: None,
            metrics_addr: None,
            chaos: None,
            mesh_secret: None,
            form_deadline: None,
            recv_deadline: None,
            rejoin: false,
        }
    }

    /// Run on a named dataset preset (see `pipegcn presets`), rebuilt
    /// deterministically from the seed — required by the TCP engines,
    /// whose worker processes rebuild their inputs independently.
    pub fn preset(name: &str) -> Session<'a> {
        Session::new(Source::Preset(name.to_string()))
    }

    /// Run on an explicit graph + partitioning + full [`TrainConfig`]
    /// (library use; local engines only). Builder setters like
    /// [`variant`](Session::variant) / [`epochs`](Session::epochs)
    /// override the corresponding `cfg` fields.
    pub fn graph(graph: Graph, parts: Partitioning, cfg: TrainConfig) -> Session<'a> {
        Session::new(Source::Graph { graph, parts, cfg })
    }

    /// Partition count (preset source; a graph source carries its own
    /// partitioning). Default 2.
    pub fn parts(mut self, n: usize) -> Self {
        self.parts = n;
        self
    }

    /// Training method: `gcn`, `pipegcn`, `pipegcn-g`, `pipegcn-f`,
    /// `pipegcn-gf` (default `pipegcn`).
    pub fn variant(mut self, method: &str) -> Self {
        self.method = Some(method.to_string());
        self
    }

    /// Rebuild the preset at `n` nodes (degree-preserving scaled variant;
    /// preset source only). On the `Tcp`/`TcpWorker` engines this also
    /// switches workers to per-rank lazy construction: the launch ships a
    /// partition spec, and each rank materializes only its own shard —
    /// no process ever holds the full graph.
    pub fn scale(mut self, n: usize) -> Self {
        self.scale = Some(n);
        self
    }

    /// Partitioner for `parts > 1`: `multilevel` (default), `simple`
    /// (hash), `range`, or `bfs`. Preset source only.
    pub fn partitioner(mut self, name: &str) -> Self {
        self.partitioner = Some(name.to_string());
        self
    }

    /// Epoch count; 0 keeps the preset default.
    pub fn epochs(mut self, n: usize) -> Self {
        self.epochs = Some(n);
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = Some(seed);
        self
    }

    /// Smoothing decay rate γ for the `-g`/`-f`/`-gf` variants.
    pub fn gamma(mut self, gamma: f32) -> Self {
        self.gamma = Some(gamma);
        self
    }

    /// Evaluate val/test every N epochs (sequential engine; 0 = only at
    /// the end).
    pub fn eval_every(mut self, n: usize) -> Self {
        self.eval_every = Some(n);
        self
    }

    /// Record staleness error probes (sequential engine, pipe variants).
    pub fn probe_errors(mut self, on: bool) -> Self {
        self.probe_errors = on;
        self
    }

    /// Set every experiment knob at once from an [`exp::RunOpts`]
    /// bundle (the experiment harness's option struct).
    pub fn run_opts(mut self, o: RunOpts) -> Self {
        self.epochs = Some(o.epochs);
        self.seed = Some(o.seed);
        self.gamma = Some(o.gamma);
        self.eval_every = Some(o.eval_every);
        self.probe_errors = o.probe_errors;
        self
    }

    /// Kernel-pool worker threads (local engines set the global pool;
    /// the `Tcp` engine forwards `--threads` to every worker).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = Some(n);
        self
    }

    /// Stream an NDJSON run log (one row per epoch, written live) to
    /// `path`. On the `Tcp` engine the path is handed to rank 0.
    pub fn log(mut self, path: &str) -> Self {
        self.log = Some(LogSink::Path(path.to_string()));
        self
    }

    /// Stream the run log into an existing emitter (library use; local
    /// engines only — no header row is written).
    pub fn log_emitter(mut self, em: &'a mut FileEmitter) -> Self {
        self.log = Some(LogSink::Emitter(em));
        self
    }

    /// Write the engine's result JSON to `path` (TCP engines: rank 0's
    /// report file).
    pub fn out(mut self, path: &str) -> Self {
        self.out = Some(path.to_string());
        self
    }

    /// Snapshot full training state under `policy.dir` every
    /// `policy.every` epochs (enables crash recovery on the `Tcp`
    /// engine).
    pub fn ckpt(mut self, policy: ckpt::Policy) -> Self {
        self.ckpt = Some(policy);
        self
    }

    /// Resume from the latest complete checkpoint under `dir`
    /// (bit-identical to the uninterrupted run).
    pub fn resume(mut self, dir: &str) -> Self {
        self.resume = Some(dir.to_string());
        self
    }

    /// Fault injection for the recovery tests: `rank` exits(13) right
    /// after completing `epoch`. TCP engines only — a process can die,
    /// a thread cannot without taking the mesh with it.
    pub fn fail_epoch(mut self, rank: usize, epoch: usize) -> Self {
        self.fail = Some((rank, vec![epoch]));
        self
    }

    /// Fault injection with one entry per spawn of `rank`: the original
    /// dies after `epochs[0]`, its replacement after `epochs[1]`, and so
    /// on — recovery-of-recovery is testable this way. TCP engines only.
    pub fn fail_epochs(mut self, rank: usize, epochs: Vec<usize>) -> Self {
        self.fail = Some((rank, epochs));
        self
    }

    /// Inject deterministic per-link faults (latency, jitter, bandwidth
    /// caps, frame drops) from a chaos profile JSON at `path` — see
    /// [`crate::net::chaos`]. TCP engines only.
    pub fn chaos(mut self, path: &str) -> Self {
        self.chaos = Some(path.to_string());
        self
    }

    /// Authenticate mesh formation with a shared secret: every join
    /// answers an HMAC challenge, and joins that cannot are rejected
    /// with the offender named. TCP engines only.
    pub fn mesh_secret(mut self, secret: &str) -> Self {
        self.mesh_secret = Some(secret.to_string());
        self
    }

    /// Mesh-formation deadline in seconds (`--form-deadline`; default
    /// 60). A rendezvous that cannot gather every rank in time fails
    /// naming the ranks that never arrived. TCP engines only.
    pub fn form_deadline(mut self, secs: u64) -> Self {
        self.form_deadline = Some(secs);
        self
    }

    /// Receive-watchdog deadline in seconds (`--recv-deadline`; default
    /// 300): a parked receive past this fails naming the exact
    /// `(src, dst, tag)` link. TCP engines only.
    pub fn recv_deadline(mut self, secs: u64) -> Self {
        self.recv_deadline = Some(secs);
        self
    }

    /// Join a live-rejoin round (`--rejoin`): the rendezvous must name a
    /// checkpoint epoch to restore. Set by the launcher on replacement
    /// workers; `TcpWorker` engine only.
    pub fn rejoin(mut self, on: bool) -> Self {
        self.rejoin = on;
        self
    }

    pub fn engine(mut self, engine: Engine) -> Self {
        self.engine = engine;
        self
    }

    /// The `pipegcn` binary the `Tcp` engine spawns workers from
    /// (default: `current_exe()` — override from test harnesses, whose
    /// own executable is not the CLI).
    pub fn binary(mut self, path: impl Into<PathBuf>) -> Self {
        self.binary = Some(path.into());
        self
    }

    /// `TcpWorker` engine: bind the mesh listener on `HOST:PORT`
    /// (`--bind`; default loopback). Must name an interface peers can
    /// route to — wildcard addresses are rejected at mesh formation.
    pub fn bind(mut self, addr: &str) -> Self {
        self.bind = Some(addr.to_string());
        self
    }

    /// `TcpWorker` engine: rendezvous dial deadline in seconds
    /// (`--connect-timeout`; default: the 60 s formation deadline).
    pub fn connect_timeout(mut self, secs: u64) -> Self {
        self.connect_timeout = Some(secs);
        self
    }

    /// `TcpWorker` engine: rendezvous dial attempts before giving up
    /// (`--connect-retries`; 0 = unlimited within the timeout).
    pub fn connect_retries(mut self, n: usize) -> Self {
        self.connect_retries = Some(n);
        self
    }

    /// Record per-rank spans (layer kernels, comm waits, drains, the
    /// ring reduce, whole epochs) and write a merged Chrome trace-event
    /// JSON to `path` when the run finishes — open it in
    /// `chrome://tracing` or Perfetto. On the `Tcp` engine every worker
    /// records; rank 0 collects the buffers over the mesh (clock-aligned
    /// NTP-style) and writes the file. Tracing is observation-only: the
    /// schedule, tags, and loss bits are identical with it on or off.
    pub fn trace(mut self, path: &str) -> Self {
        self.trace = Some(path.to_string());
        self
    }

    /// Serve live Prometheus text on `HOST:PORT` for the lifetime of the
    /// run. On the `Tcp` engine rank i serves on `PORT+i` (co-located
    /// workers need distinct ports).
    pub fn metrics_addr(mut self, addr: &str) -> Self {
        self.metrics_addr = Some(addr.to_string());
        self
    }

    /// Execute the run on the configured engine.
    pub fn run(self) -> Result<RunReport> {
        let Session {
            source,
            parts,
            method,
            scale,
            partitioner,
            epochs,
            seed,
            gamma,
            eval_every,
            probe_errors,
            threads,
            log,
            out,
            ckpt: ckpt_policy,
            resume,
            fail,
            engine,
            binary,
            bind,
            connect_timeout,
            connect_retries,
            trace,
            metrics_addr,
            chaos,
            mesh_secret,
            form_deadline,
            recv_deadline,
            rejoin,
        } = self;

        if threads == Some(0) {
            crate::bail!("threads must be at least 1");
        }
        // the mesh-side net knobs only mean something on a worker; a
        // silent no-op on the other engines would hide a misconfigured
        // multi-node launch
        if (bind.is_some() || connect_timeout.is_some() || connect_retries.is_some())
            && !matches!(engine, Engine::TcpWorker { .. })
        {
            crate::bail!(
                "bind/connect_timeout/connect_retries configure a TcpWorker's mesh \
                 joining; the other engines bind loopback listeners themselves"
            );
        }
        // hostile-network knobs describe a real socket mesh; on the
        // in-process engines there is no wire to disturb or authenticate
        if (chaos.is_some()
            || mesh_secret.is_some()
            || form_deadline.is_some()
            || recv_deadline.is_some())
            && !matches!(engine, Engine::Tcp { .. } | Engine::TcpWorker { .. })
        {
            crate::bail!(
                "chaos/mesh_secret/form_deadline/recv_deadline shape the TCP mesh; \
                 the in-process engines have no wire (use Engine::Tcp or \
                 Engine::TcpWorker)"
            );
        }
        if rejoin && !matches!(engine, Engine::TcpWorker { .. }) {
            crate::bail!(
                "rejoin marks a replacement TcpWorker joining a live-rejoin round; \
                 the launcher sets it — it is meaningless on other engines"
            );
        }
        if let Some(p) = &ckpt_policy {
            if p.every == 0 {
                crate::bail!("checkpoint policy: every must be at least 1");
            }
        }
        // scale/partitioner reshape how the preset is built — a Graph
        // source already carries its graph and partitioning
        if (scale.is_some() || partitioner.is_some()) && matches!(source, Source::Graph { .. }) {
            crate::bail!(
                "scale/partitioner rebuild the dataset from its preset; \
                 use Session::preset(..)"
            );
        }
        let partitioner_method = match partitioner.as_deref() {
            None => Method::Multilevel,
            Some(name) => Method::parse(name).ok_or_else(|| {
                crate::err_msg!(
                    "unknown partitioner '{name}' (try: multilevel, simple, range, bfs)"
                )
            })?,
        };
        let method_name = method.as_deref().unwrap_or("pipegcn").to_string();
        let opts = RunOpts {
            epochs: epochs.unwrap_or(0),
            seed: seed.unwrap_or(1),
            probe_errors,
            gamma: gamma.unwrap_or(0.95),
            eval_every: eval_every.unwrap_or(5),
            partitioner: partitioner_method,
            nodes: scale.unwrap_or(0),
        };
        // knobs only the sequential engine honors must not silently
        // change meaning on the others
        if matches!(engine, Engine::Tcp { .. } | Engine::TcpWorker { .. })
            && (eval_every.is_some() || probe_errors)
        {
            crate::bail!(
                "eval_every/probe_errors are sequential-engine knobs; the tcp engines \
                 evaluate once at the end and record no probes"
            );
        }

        match engine {
            Engine::Sequential | Engine::Threaded => {
                if fail.is_some() {
                    crate::bail!(
                        "fault injection (fail_epoch) needs a process-per-rank engine \
                         (Engine::Tcp)"
                    );
                }
                let threaded_engine = engine == Engine::Threaded;
                let engine_name = if threaded_engine { "threaded" } else { "sequential" };
                if let Some(t) = threads {
                    pool::set_threads(t);
                }
                let dataset_label = match &source {
                    Source::Preset(name) => name.clone(),
                    Source::Graph { .. } => "custom".to_string(),
                };
                let (preset, graph, pt, cfg) = match source {
                    Source::Preset(name) => {
                        let (p, g, pt, cfg) = try_prepare(&name, parts, &method_name, opts)?;
                        (Some(p), g, pt, cfg)
                    }
                    Source::Graph { graph, parts: pt, cfg } => {
                        let mut cfg = cfg;
                        if let Some(m) = &method {
                            cfg.variant = Variant::parse(m, opts.gamma)?;
                        } else if let (Some(g), Variant::Pipe(mut o)) = (gamma, cfg.variant) {
                            // .gamma() must bite even without .variant()
                            o.gamma = g;
                            cfg.variant = Variant::Pipe(o);
                        }
                        if opts.epochs > 0 {
                            cfg.epochs = opts.epochs;
                        }
                        if let Some(s) = seed {
                            cfg.seed = s;
                        }
                        if let Some(e) = eval_every {
                            cfg.eval_every = e;
                        }
                        cfg.probe_errors |= probe_errors;
                        (None, graph, pt, cfg)
                    }
                };
                let quality = crate::partition::quality(&graph, &pt);
                // live metrics endpoint, up for the duration of the run
                let _metrics = match &metrics_addr {
                    Some(addr) => Some(
                        crate::obs::http::serve(addr)
                            .with_context(|| format!("metrics endpoint {addr}"))?,
                    ),
                    None => None,
                };
                // in-process engines: every rank lives in this process,
                // one clock — no offset estimation, no span shipping
                if trace.is_some() {
                    crate::obs::trace::enable();
                }
                // run-log plumbing: a path gets the standard header; an
                // existing emitter is used as-is
                let mut owned_em: Option<FileEmitter> = None;
                let em: Option<&mut FileEmitter> = match log {
                    None => None,
                    Some(LogSink::Emitter(e)) => Some(e),
                    Some(LogSink::Path(p)) => {
                        let header = Json::obj()
                            .set("dataset", dataset_label.as_str())
                            .set("parts", pt.n_parts)
                            .set("method", cfg.variant.name())
                            .set("seed", cfg.seed)
                            .set("engine", engine_name)
                            .set("quality", quality.to_json());
                        // resuming appends, so pre-crash epoch rows survive
                        let e = if resume.is_some() {
                            FileEmitter::append_or_create(&p, header)
                        } else {
                            FileEmitter::create(&p, header)
                        }
                        .with_context(|| format!("creating run log {p}"))?;
                        owned_em = Some(e);
                        owned_em.as_mut()
                    }
                };

                let mut report = if threaded_engine {
                    let ctl = threaded::ThreadedCtl {
                        ckpt: ckpt_policy.as_ref(),
                        resume: resume.as_deref(),
                        log: em,
                    };
                    let (r, start_epoch) = threaded::run_threaded_ctl(&graph, &pt, &cfg, ctl)?;
                    RunReport {
                        engine: engine_name.to_string(),
                        losses: r.losses,
                        start_epoch,
                        final_val: r.final_val,
                        final_test: r.final_test,
                        comm_bytes: r.comm_bytes,
                        wire_bytes: 0,
                        comm_wait_ms: r.comm_wait_ms,
                        overlap_ratio: r.overlap_ratio,
                        log_rows: 0,
                        quality: Some(quality),
                        peak_rss_bytes: 0,
                        train: None,
                        params: Some(r.params),
                        preset,
                        graph: Some(graph),
                        parts: Some(pt),
                    }
                } else {
                    let mut backend = NativeBackend::new();
                    let result = trainer::train_resumable(
                        &graph,
                        &pt,
                        &cfg,
                        &mut backend,
                        em,
                        ckpt_policy.as_ref(),
                        resume.as_deref(),
                    )?;
                    let start_epoch =
                        result.curve.first().map(|e| e.epoch - 1).unwrap_or(cfg.epochs);
                    let comm_bytes = result.setup_bytes
                        + result.curve.iter().map(|e| e.comm_bytes).sum::<u64>();
                    RunReport {
                        engine: engine_name.to_string(),
                        losses: result.curve.iter().map(|e| e.train_loss).collect(),
                        start_epoch,
                        final_val: result.final_val,
                        final_test: result.final_test,
                        comm_bytes,
                        wire_bytes: 0,
                        // the sequential replay never parks: its
                        // receives are structurally immediate
                        comm_wait_ms: 0.0,
                        overlap_ratio: 1.0,
                        log_rows: 0,
                        quality: Some(quality),
                        peak_rss_bytes: 0,
                        train: Some(result),
                        params: None,
                        preset,
                        graph: Some(graph),
                        parts: Some(pt),
                    }
                };
                report.log_rows = owned_em.as_ref().map(|e| e.rows()).unwrap_or(0);
                report.peak_rss_bytes = crate::obs::peak_rss_bytes().unwrap_or(0);
                if let Some(path) = &trace {
                    let (spans, _dropped) = crate::obs::trace::take();
                    crate::obs::trace::write_chrome_trace(path, &spans)?;
                }
                Ok(report)
            }

            Engine::Tcp { max_restarts } => {
                let Source::Preset(dataset) = source else {
                    crate::bail!(
                        "the tcp engine's workers rebuild the dataset from its preset; \
                         use Session::preset(..)"
                    );
                };
                // validate before spawning: a bad flag must fail here, not
                // as K worker panics followed by a rendezvous timeout
                Variant::parse(&method_name, opts.gamma)?;
                if presets::by_name(&dataset).is_none() {
                    crate::bail!(
                        "unknown preset '{dataset}' (try: {:?})",
                        presets::names()
                    );
                }
                if matches!(log, Some(LogSink::Emitter(_))) {
                    crate::bail!(
                        "the tcp engine streams its run log from rank 0's process; \
                         pass a path with .log(..)"
                    );
                }
                if let Some(dir) = &resume {
                    if ckpt::latest_complete(dir, parts)?.is_none() {
                        crate::bail!(
                            "resume {dir}: no complete checkpoint for {parts} ranks"
                        );
                    }
                }
                // rank 0 always writes a report file so the launcher can
                // hand back a RunReport; without .out(..) it is scratch
                let (out_path, scratch) = match &out {
                    Some(p) => (p.clone(), false),
                    None => {
                        let p = std::env::temp_dir().join(format!(
                            "pipegcn_session_{}_{}.json",
                            std::process::id(),
                            TEMP_SEQ.fetch_add(1, Ordering::Relaxed)
                        ));
                        (p.to_string_lossy().into_owned(), true)
                    }
                };
                let lopts = LaunchOpts {
                    parts,
                    dataset: dataset.clone(),
                    method: method_name,
                    nodes: opts.nodes,
                    partitioner,
                    epochs: opts.epochs,
                    seed: opts.seed,
                    gamma: opts.gamma,
                    log: match log {
                        Some(LogSink::Path(p)) => Some(p),
                        _ => None,
                    },
                    out: Some(out_path.clone()),
                    ckpt_dir: ckpt_policy.as_ref().map(|p| p.dir.clone()),
                    ckpt_every: ckpt_policy.as_ref().map(|p| p.every).unwrap_or(1),
                    resume,
                    max_restarts,
                    threads,
                    fail_rank: fail.as_ref().map(|(r, _)| *r),
                    fail_epochs: fail.map(|(_, es)| es).unwrap_or_default(),
                    trace,
                    metrics_addr,
                    chaos,
                    mesh_secret,
                    form_deadline_secs: form_deadline,
                    recv_deadline_secs: recv_deadline,
                };
                let bin = match binary {
                    Some(b) => b,
                    None => std::env::current_exe()
                        .context("resolving the pipegcn binary path")?,
                };
                launch::launch(&bin, &lopts)?;
                let text = std::fs::read_to_string(&out_path)
                    .with_context(|| format!("reading rank-0 report {out_path}"))?;
                if scratch {
                    std::fs::remove_file(&out_path).ok();
                }
                let j = Json::parse(&text)
                    .map_err(|e| crate::err_msg!("parsing rank-0 report {out_path}: {e}"))?;
                let losses: Vec<f64> = j
                    .get("losses")
                    .and_then(Json::as_arr)
                    .map(|a| a.iter().filter_map(Json::as_f64).collect())
                    .unwrap_or_default();
                Ok(RunReport {
                    engine: "tcp".to_string(),
                    losses,
                    start_epoch: j.get("start_epoch").and_then(Json::as_usize).unwrap_or(0),
                    final_val: j.get("final_val").and_then(Json::as_f64).unwrap_or(f64::NAN),
                    final_test: j
                        .get("final_test")
                        .and_then(Json::as_f64)
                        .unwrap_or(f64::NAN),
                    comm_bytes: j
                        .get("payload_bytes_sent")
                        .and_then(Json::as_f64)
                        .unwrap_or(0.0) as u64,
                    wire_bytes: j
                        .get("wire_bytes_sent")
                        .and_then(Json::as_f64)
                        .unwrap_or(0.0) as u64,
                    comm_wait_ms: j.get("comm_wait_ms").and_then(Json::as_f64).unwrap_or(f64::NAN),
                    overlap_ratio: j
                        .get("overlap_ratio")
                        .and_then(Json::as_f64)
                        .unwrap_or(f64::NAN),
                    log_rows: 0,
                    quality: j
                        .get("quality")
                        .and_then(crate::partition::Quality::from_json),
                    peak_rss_bytes: j
                        .get("peak_rss_bytes")
                        .and_then(Json::as_f64)
                        .unwrap_or(0.0) as u64,
                    train: None,
                    params: None,
                    preset: presets::by_name(&dataset),
                    graph: None,
                    parts: None,
                })
            }

            Engine::TcpWorker { rank, coord } => {
                let Source::Preset(dataset) = source else {
                    crate::bail!(
                        "a tcp worker rebuilds the dataset from its preset; \
                         use Session::preset(..)"
                    );
                };
                if let Some(t) = threads {
                    pool::set_threads(t);
                }
                if matches!(log, Some(LogSink::Emitter(_))) {
                    crate::bail!("the tcp worker opens its own run log; pass a path with .log(..)");
                }
                let wopts = WorkerOpts {
                    rank,
                    parts,
                    coord,
                    dataset,
                    method: method_name,
                    nodes: opts.nodes,
                    partitioner,
                    epochs: opts.epochs,
                    seed: opts.seed,
                    gamma: opts.gamma,
                    log: match log {
                        Some(LogSink::Path(p)) => Some(p),
                        _ => None,
                    },
                    out,
                    ckpt_dir: ckpt_policy.as_ref().map(|p| p.dir.clone()),
                    ckpt_every: ckpt_policy.as_ref().map(|p| p.every).unwrap_or(1),
                    resume,
                    fail_epoch: match fail {
                        Some((r, es)) if r == rank => es.first().copied(),
                        _ => None,
                    },
                    bind,
                    connect_timeout_secs: connect_timeout,
                    connect_retries,
                    trace,
                    metrics_addr,
                    chaos,
                    mesh_secret,
                    form_deadline_secs: form_deadline,
                    recv_deadline_secs: recv_deadline,
                    rejoin,
                };
                let summary = worker::run_worker(&wopts)?;
                Ok(match summary {
                    Some(s) => RunReport {
                        engine: "tcp-worker".to_string(),
                        losses: s.losses,
                        start_epoch: s.start_epoch,
                        final_val: s.final_val,
                        final_test: s.final_test,
                        comm_bytes: s.payload_bytes_sent,
                        wire_bytes: s.wire_bytes_sent,
                        comm_wait_ms: s.comm_wait_ms,
                        overlap_ratio: s.overlap_ratio,
                        log_rows: 0,
                        quality: Some(s.quality),
                        peak_rss_bytes: crate::obs::peak_rss_bytes().unwrap_or(0),
                        train: None,
                        params: None,
                        preset: None,
                        graph: None,
                        parts: None,
                    },
                    // non-zero ranks train but never see global metrics
                    None => RunReport {
                        engine: "tcp-worker".to_string(),
                        losses: Vec::new(),
                        start_epoch: 0,
                        final_val: f64::NAN,
                        final_test: f64::NAN,
                        comm_bytes: 0,
                        wire_bytes: 0,
                        comm_wait_ms: f64::NAN,
                        overlap_ratio: f64::NAN,
                        log_rows: 0,
                        quality: None,
                        peak_rss_bytes: crate::obs::peak_rss_bytes().unwrap_or(0),
                        train: None,
                        params: None,
                        preset: None,
                        graph: None,
                        parts: None,
                    },
                })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // engine-equivalence and end-to-end coverage lives in
    // `tests/session_api.rs`; here only the cheap validation paths

    #[test]
    fn builder_rejects_bad_inputs_before_any_work() {
        let e = Session::preset("tiny").threads(0).run().unwrap_err();
        assert!(e.to_string().contains("at least 1"), "{e}");
        let e = Session::preset("tiny")
            .ckpt(ckpt::Policy { dir: "/tmp/x".into(), every: 0 })
            .run()
            .unwrap_err();
        assert!(e.to_string().contains("every"), "{e}");
        let e = Session::preset("tiny").fail_epoch(0, 2).run().unwrap_err();
        assert!(e.to_string().contains("Tcp"), "{e}");
        // sequential-only knobs are rejected on the tcp engines instead
        // of silently changing the run (and before anything spawns)
        let e = Session::preset("tiny")
            .eval_every(1)
            .engine(Engine::Tcp { max_restarts: 0 })
            .run()
            .unwrap_err();
        assert!(e.to_string().contains("sequential-engine"), "{e}");
        // parse errors surface the valid-value lists (satellite bugfix)
        let e = Session::preset("tiny").variant("nope").epochs(1).run().unwrap_err();
        assert!(e.to_string().contains("pipegcn-gf"), "{e}");
        let e = Session::preset("nope").epochs(1).run().unwrap_err();
        assert!(e.to_string().contains("unknown preset"), "{e}");
        let e = Session::preset("tiny").partitioner("nope").epochs(1).run().unwrap_err();
        assert!(e.to_string().contains("unknown partitioner"), "{e}");
        // mesh-side net knobs are worker-only — a silent no-op elsewhere
        // would hide a misconfigured multi-node launch
        let e = Session::preset("tiny").bind("10.0.0.5:0").epochs(1).run().unwrap_err();
        assert!(e.to_string().contains("TcpWorker"), "{e}");
        let e = Session::preset("tiny").connect_retries(3).epochs(1).run().unwrap_err();
        assert!(e.to_string().contains("TcpWorker"), "{e}");
        // hostile-network knobs need a real wire
        let e = Session::preset("tiny").chaos("p.json").epochs(1).run().unwrap_err();
        assert!(e.to_string().contains("Engine::Tcp"), "{e}");
        let e = Session::preset("tiny").mesh_secret("s").epochs(1).run().unwrap_err();
        assert!(e.to_string().contains("Engine::Tcp"), "{e}");
        let e = Session::preset("tiny").form_deadline(5).epochs(1).run().unwrap_err();
        assert!(e.to_string().contains("Engine::Tcp"), "{e}");
        let e = Session::preset("tiny").recv_deadline(5).epochs(1).run().unwrap_err();
        assert!(e.to_string().contains("Engine::Tcp"), "{e}");
        let e = Session::preset("tiny").rejoin(true).epochs(1).run().unwrap_err();
        assert!(e.to_string().contains("replacement"), "{e}");
        let e = Session::preset("tiny")
            .rejoin(true)
            .engine(Engine::Tcp { max_restarts: 0 })
            .run()
            .unwrap_err();
        assert!(e.to_string().contains("TcpWorker"), "{e}");
    }

    #[test]
    fn tcp_engine_requires_a_preset_source() {
        let g = crate::graph::presets::by_name("tiny").unwrap().build(1);
        let pt = crate::partition::partition(&g, 2, crate::partition::Method::Multilevel, 1);
        let cfg = TrainConfig::from_preset(
            crate::graph::presets::by_name("tiny").unwrap(),
            Variant::Vanilla,
        );
        let e = Session::graph(g.clone(), pt.clone(), cfg.clone())
            .engine(Engine::Tcp { max_restarts: 0 })
            .run()
            .unwrap_err();
        assert!(e.to_string().contains("preset"), "{e}");
        // scale/partitioner rebuild from a preset — meaningless on a
        // graph source that already carries its graph and partitioning
        let e = Session::graph(g, pt, cfg).scale(1000).run().unwrap_err();
        assert!(e.to_string().contains("preset"), "{e}");
    }
}
