//! Cost models of the full-graph training comparators in Fig. 3 /
//! Table 6: **ROC** (Jia et al., MLSys'20) and **CAGNET** (Tripathy et
//! al., SC'20).
//!
//! Neither system is open to this environment (ROC needs its own runtime,
//! CAGNET is built on torch.distributed + SUMMA), so — per the
//! substitution rule — we reimplement their *communication schedules* as
//! cost models over the same device/link profiles used for GCN/PipeGCN:
//!
//! * **ROC**: partition-parallel compute, but partitions live in host
//!   memory and are swapped CPU↔GPU every layer, both passes. The paper's
//!   Table 6 shows the swap path dominating (3.13 s of 3.63 s on 2 GPUs);
//!   its effective swap bandwidth (≈0.45 GB/s) reflects ROC's
//!   gather/scatter + synchronous cudaMemcpy pipeline, which we encode as
//!   `ROC_SWAP_BYTES_PER_S` rather than raw PCIe bandwidth.
//! * **CAGNET (c)**: 1.5D SUMMA-like: each layer broadcasts full feature
//!   blocks among p/c groups (volume `N·f·4·(p−c)/(p·c)` per GPU per
//!   direction) and all-reduces partial activations for c>1. Compute is
//!   inflated by dense-block redundancy (`CAGNET_COMPUTE_FACTOR`).
//!
//! Constants are calibrated against Table 6 (Reddit, 2/4 GPUs) and the
//! bench `t6_breakdown` prints model-vs-paper side by side; Fig. 3 then
//! reuses the same models across partition counts.

use crate::comm::topology::Topology;
use crate::sim::{DeviceProfile, EpochBreakdown};

/// Graph + model scale factors every baseline consumes.
#[derive(Clone, Debug)]
pub struct BaselineInputs {
    /// total nodes
    pub n: f64,
    /// directed edge count (nnz of Ã)
    pub nnz: f64,
    /// layer widths `[f_in, hidden.., classes]`
    pub dims: Vec<usize>,
    pub n_parts: usize,
    /// average replication factor of the partitioning (inner+halo)/inner
    pub replication: f64,
}

impl BaselineInputs {
    /// Per-GPU per-layer compute of the partition-parallel schedule
    /// (fwd + bwd ≈ 3× fwd), in seconds.
    fn partition_compute(&self, p: &DeviceProfile) -> f64 {
        let k = self.n_parts as f64;
        let mut secs = 0.0;
        for l in 0..self.dims.len() - 1 {
            let (f_in, f_out) = (self.dims[l] as f64, self.dims[l + 1] as f64);
            let spmm = 2.0 * (self.nnz / k) * f_in;
            let rows = self.n / k * self.replication;
            let gemm = 2.0 * rows * f_in * f_out * 2.0; // neigh + self weights
            secs += 3.0 * (spmm / p.spmm_flops + gemm / p.gemm_flops);
            secs += 2.0 * p.layer_overhead_s;
        }
        secs
    }
}

/// ROC's effective host↔GPU swap bandwidth, **shared across all GPUs**
/// (one host memory complex serves every partition — which is exactly why
/// the paper's ROC rows barely improve from 2→4 GPUs: 3.63 s → 3.34 s).
/// Calibrated: ≈2.7 GB of per-epoch activation traffic ≈ 3.1 s.
pub const ROC_SWAP_BYTES_PER_S: f64 = 0.85e9;

/// CAGNET dense-block compute inflation over partition-parallel SpMM
/// (Table 6: CAGNET c=1 compute 0.97 s vs GCN 0.07 s on 4 GPUs — the
/// SUMMA formulation computes on dense broadcast blocks and cannot skip
/// the zero structure a locality-aware partitioning exposes).
pub const CAGNET_COMPUTE_FACTOR: f64 = 12.0;

/// Additional skew for feature-split replication (c>1) on few GPUs:
/// skinny SUMMA panels underutilize the GEMM pipeline (Table 6 shows
/// c=2 compute 4.36 s vs c=1 1.91 s on 2 GPUs, converging by 4 GPUs).
pub fn cagnet_c_penalty(c: f64, p: f64) -> f64 {
    1.0 + 5.12 * (c - 1.0) / (p * p)
}

/// ROC epoch estimate.
pub fn roc_epoch(inp: &BaselineInputs, profile: &DeviceProfile, _topo: &Topology) -> EpochBreakdown {
    let compute = inp.partition_compute(profile);
    // swap: layer inputs streamed in (fwd) and gradients streamed out
    // (bwd) for EVERY partition through the shared host link — total
    // volume is independent of the GPU count, hence ROC's flat scaling.
    let mut swap_bytes = 0.0;
    for l in 0..inp.dims.len() - 1 {
        let (f_in, f_out) = (inp.dims[l] as f64, inp.dims[l + 1] as f64);
        let rows_total = inp.n * inp.replication;
        swap_bytes += rows_total * (f_in + f_out) * 4.0;
    }
    let swap = swap_bytes / ROC_SWAP_BYTES_PER_S;
    EpochBreakdown {
        compute,
        comm_total: swap,
        comm_exposed: swap,
        reduce: 0.0,
        total: compute + swap,
    }
}

/// CAGNET(c) epoch estimate.
pub fn cagnet_epoch(
    inp: &BaselineInputs,
    c: usize,
    profile: &DeviceProfile,
    topo: &Topology,
) -> EpochBreakdown {
    let p = inp.n_parts as f64;
    let c = c as f64;
    let link = topo.ring_bottleneck();
    let compute =
        inp.partition_compute(profile) * CAGNET_COMPUTE_FACTOR * cagnet_c_penalty(c, p);
    // broadcast volume per GPU per layer per pass: N·f/c · (p−c)/p values
    let mut bcast_bytes = 0.0;
    let mut reduce_bytes = 0.0;
    for l in 0..inp.dims.len() - 1 {
        let f_in = inp.dims[l] as f64;
        let vol = inp.n * f_in * 4.0 / c * (p - c).max(0.0) / p;
        bcast_bytes += 2.0 * vol; // fwd + bwd
        if c > 1.0 {
            // partial-activation all-reduce within c-groups
            reduce_bytes += 2.0 * inp.n / p * f_in * 4.0 * (c - 1.0);
        }
    }
    let comm = bcast_bytes / link.bytes_per_s
        + (inp.dims.len() - 1) as f64 * 2.0 * profile.barrier_s * (p - 1.0);
    let reduce = reduce_bytes / link.bytes_per_s;
    EpochBreakdown {
        compute,
        comm_total: comm,
        comm_exposed: comm,
        reduce,
        total: compute + comm + reduce,
    }
}

/// Reddit-scale inputs used by Table 6 / Fig. 3 (full-size dataset,
/// 4-layer GraphSAGE-256; replication measured from our partitioner is
/// substituted by the paper-typical ≈1.3 at small k).
pub fn reddit_inputs(n_parts: usize, replication: f64) -> BaselineInputs {
    BaselineInputs {
        n: 233_000.0,
        nnz: 114_000_000.0,
        dims: vec![602, 256, 256, 256, 41],
        n_parts,
        replication,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::profiles::rig_2080ti;

    /// Table 6 ordering: ROC and CAGNET are far slower than vanilla
    /// partition-parallel training, which PipeGCN then halves.
    #[test]
    fn table6_relative_standings_2gpu() {
        let (profile, topo) = rig_2080ti(2);
        let inp = reddit_inputs(2, 1.32);
        let roc = roc_epoch(&inp, &profile, &topo);
        let c1 = cagnet_epoch(&inp, 1, &profile, &topo);
        let cagnet2 = cagnet_epoch(&inp, 2, &profile, &topo);
        // paper: ROC 3.63s, CAGNET c=1 2.74s, c=2 5.41s, GCN 0.52s
        assert!(roc.total > 2.0 && roc.total < 6.0, "roc {:.2}", roc.total);
        assert!(c1.total > 1.5 && c1.total < 5.0, "c1 {:.2}", c1.total);
        assert!(
            cagnet2.total > 3.0 && cagnet2.total < 9.0,
            "cagnet2 {:.2}",
            cagnet2.total
        );
        // c=2 slower than c=1 on 2 GPUs, exactly as in Table 6
        assert!(cagnet2.total > c1.total);
    }

    #[test]
    fn table6_relative_standings_4gpu() {
        let (profile, topo) = rig_2080ti(4);
        let inp = reddit_inputs(4, 1.5);
        let roc = roc_epoch(&inp, &profile, &topo);
        let c1 = cagnet_epoch(&inp, 1, &profile, &topo);
        let c2 = cagnet_epoch(&inp, 2, &profile, &topo);
        // paper: ROC 3.34, CAGNET c=1 2.31, c=2 2.26
        assert!(roc.total > 1.5 && roc.total < 6.0, "roc {:.2}", roc.total);
        assert!(c1.total > 1.0 && c1.total < 4.5, "c1 {:.2}", c1.total);
        assert!(c2.total > 1.0 && c2.total < 4.5, "c2 {:.2}", c2.total);
        // c=2 trades broadcast for reduce: comm shrinks, reduce grows
        assert!(c2.comm_total < c1.comm_total);
        assert!(c2.reduce > c1.reduce);
    }

    #[test]
    fn roc_swap_dominates_compute() {
        let (profile, topo) = rig_2080ti(2);
        let inp = reddit_inputs(2, 1.32);
        let roc = roc_epoch(&inp, &profile, &topo);
        assert!(roc.comm_total > 3.0 * roc.compute, "{roc:?}");
    }

    #[test]
    fn cagnet_scales_with_partitions() {
        let inp4 = reddit_inputs(4, 1.5);
        let inp8 = reddit_inputs(8, 1.8);
        let (profile, topo4) = rig_2080ti(4);
        let (_, topo8) = rig_2080ti(8);
        let t4 = cagnet_epoch(&inp4, 1, &profile, &topo4);
        let t8 = cagnet_epoch(&inp8, 1, &profile, &topo8);
        // broadcast volume per GPU shrinks sublinearly; compute drops ~2×
        assert!(t8.total < t4.total, "t4 {:.2} t8 {:.2}", t4.total, t8.total);
    }
}
