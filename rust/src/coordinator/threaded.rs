//! Transport-generic per-rank runner + the threaded engine.
//!
//! [`run_rank`] is one rank's complete training schedule — the same
//! dataflow as the sequential engine core
//! ([`super::trainer::train_resumable`]) — written against the
//! [`Transport`] contract, so the identical code drives:
//!
//! * [`run_threaded_ctl`]: one OS thread per partition over the
//!   in-process [`Fabric`] (single process) — the `Engine::Threaded`
//!   adapter behind [`crate::session::Session`], and
//! * the multi-process engine: one OS process per partition over
//!   [`crate::net::TcpTransport`] (real sockets), launched by
//!   `pipegcn launch` / driven by [`crate::net::worker`].
//!
//! **The schedule is prefetched** (Alg. 1's pipelining, made explicit in
//! the API): at the start of every epoch the rank posts *all* of the
//! epoch's receives — boundary features per layer, boundary gradients
//! per layer, the rank-0 loss partials — as nonblocking
//! [`crate::comm::RecvHandle`]s, and only [`RecvHandle::wait`]s at each
//! payload's true point of use. In the pipelined variants the fresh
//! tag-`t` tensors are not needed until the stale buffers are updated,
//! so their waits sink all the way to a drain step after the backward
//! pass — the transport completes them behind the epoch's entire
//! forward/backward compute. Time actually spent parked is attributed
//! per `(layer, phase)` in a [`WaitStats`], and rank 0's NDJSON run-log
//! rows carry the breakdown (`comm_wait` keys summing to
//! `comm_wait_ms`) plus the hidden-receive `overlap_ratio`.
//!
//! Every epoch ends with a loss reduction to rank 0 (each rank ships its
//! partial loss, rank 0 sums in rank order), so rank 0 always holds the
//! live global loss — it can stream run-log rows as epochs finish.
//! [`run_rank_ctl`] additionally snapshots the full [`TrainState`]
//! through [`crate::ckpt`] every `--ckpt-every` epochs (the drain runs
//! before the snapshot, so checkpoints hold exactly the buffers the
//! sequential engine would) and can start from a restored state, which
//! is how `pipegcn launch` survives a worker death.
//!
//! The integration tests assert the loss curve is identical to the
//! sequential engine — prefetching moves *when receives are posted*,
//! never which payload a tag resolves to, so staleness stays encoded in
//! message tags, not timing luck.
//!
//! Scope: no probes / work capture (the sequential engine owns those);
//! evaluation only at the end.

use super::halo::{self, PartView, PlanLabels};
use super::state::TrainState;
use super::{TrainConfig, Variant};
use crate::ckpt;
use crate::comm::schedule::{self, Cursor, Event, Style};
use crate::comm::{
    decode_f64s, decode_u32s, encode_f64s, encode_u32s, Fabric, Phase, RecvHandle, Tag,
    Transport, WaitStats,
};
use crate::graph::Graph;
use crate::model::Params;
use crate::partition::Partitioning;
use crate::runtime::native::NativeBackend;
use crate::runtime::Backend;
use crate::tensor::{ops, Mat};
use crate::util::json::{FileEmitter, Json};
use crate::util::timer::Stopwatch;
use std::collections::HashMap;
use std::collections::VecDeque;

/// Result of a threaded run.
pub struct ThreadedResult {
    /// per-epoch global train loss
    pub losses: Vec<f64>,
    /// final parameters (identical on every rank; rank 0's copy)
    pub params: Params,
    pub final_val: f64,
    pub final_test: f64,
    /// total payload bytes through the fabric (setup + all epochs)
    pub comm_bytes: u64,
    /// rank 0's total ms parked in receives (prefetched schedule)
    pub comm_wait_ms: f64,
    /// rank 0's fraction of receives already complete when waited on
    pub overlap_ratio: f64,
}

/// What one rank's executed epochs hand back: the losses plus the
/// measured comm/compute overlap of the prefetched schedule.
#[derive(Clone, Debug)]
pub struct RankReport {
    /// per-epoch losses (**global** on rank 0, which drives the
    /// per-epoch loss reduction; this rank's partials elsewhere)
    pub losses: Vec<f64>,
    /// total ms parked in receives across the executed epochs
    pub comm_wait_ms: f64,
    /// fraction of waited receives already complete at their wait point
    /// (1.0 = every receive fully hidden behind compute)
    pub overlap_ratio: f64,
    /// parked ms per schedule point (`fwd_l{l}` / `bwd_l{l}` / `reduce`
    /// / `loss` / `setup`), summing to `comm_wait_ms`
    pub comm_wait_by: Vec<(String, f64)>,
}

/// Per-rank ring all-reduce over any transport, driven by the schedule
/// IR's ring segment (`events`: the [`Style::Prefetched`] layout of
/// [`schedule::ring_events`]). Every step's receive is posted up front
/// (step tags are unique within an iteration), so the transport can
/// complete step `s+1`'s payload while step `s` still folds; parked
/// time lands in `stats` under the `reduce` key. The chunk arithmetic
/// stays here; message identity comes from the events.
fn ring_allreduce_rank(
    transport: &dyn Transport,
    rank: usize,
    n: usize,
    buf: &mut [f32],
    events: &[Event],
    stats: &mut WaitStats,
) {
    if n <= 1 || buf.is_empty() {
        return;
    }
    let steps = 2 * (n - 1);
    assert_eq!(events.len(), 3 * steps, "ring segment has the wrong shape");
    let len = buf.len();
    let starts: Vec<usize> = (0..=n).map(|c| c * len / n).collect();
    let chunk = |c: usize| starts[c % n]..starts[c % n + 1];
    let prev = (rank + n - 1) % n;
    let send_of = |s: usize| match events[steps + 2 * s] {
        Event::Send { dst, tag } => (dst, tag),
        other => panic!("ring schedule: expected a send at step {s}, got {other:?}"),
    };
    let mut handles: VecDeque<RecvHandle> = VecDeque::with_capacity(steps);
    for ev in &events[..steps] {
        match *ev {
            Event::PostRecv { src, tag } => {
                handles.push_back(transport.post_recv(src, rank, tag))
            }
            ref other => panic!("ring schedule: expected posted receives first, got {other:?}"),
        }
    }
    for s in 0..n - 1 {
        let (dst, tag) = send_of(s);
        let c_send = (rank + n - s) % n;
        transport.send(rank, dst, tag, buf[chunk(c_send)].to_vec());
        let c_recv = (prev + n - s) % n;
        let recv = handles.pop_front().unwrap().wait(stats);
        for (d, v) in buf[chunk(c_recv)].iter_mut().zip(recv) {
            *d += v;
        }
    }
    for s in 0..n - 1 {
        let (dst, tag) = send_of(n - 1 + s);
        let c_send = (rank + 1 + n - s) % n;
        transport.send(rank, dst, tag, buf[chunk(c_send)].to_vec());
        let c_recv = (prev + 1 + n - s) % n;
        let recv = handles.pop_front().unwrap().wait(stats);
        buf[chunk(c_recv)].copy_from_slice(&recv);
    }
}

/// Send half of the boundary-set exchange (`Phase::Setup`, Alg. 1
/// lines 1–5 made real): ship each peer the global ids of the halo rows
/// this rank needs from it, per the schedule's setup sends. Moving this
/// through the transport makes byte accounting include the setup
/// traffic a real wire sees.
pub fn setup_send(transport: &dyn Transport, view: &PartView<'_>, cur: &mut Cursor<'_>) {
    let rank = view.rank();
    let p = view.part;
    for ev in cur.take_sends(Phase::Setup, 0) {
        let j = ev.peer();
        let range = p.halo_ranges[j].clone();
        transport.send(rank, j, ev.tag(), encode_u32s(&p.halo[range]));
    }
}

/// Verify half: receive each peer's request (the schedule's setup
/// receive pairs) and check it matches the plan's send set — this is
/// what establishes `S_{i,j}` on a real deployment, and over TCP it
/// validates the mesh wiring before any tensor moves. On the scale path
/// it doubles as a cross-check that two ranks' independently built
/// plans agree on the boundary.
pub fn setup_verify(transport: &dyn Transport, view: &PartView<'_>, cur: &mut Cursor<'_>) {
    let rank = view.rank();
    let p = view.part;
    while let Some((j, tag)) = cur.take_recv_pair(Phase::Setup) {
        let ids = decode_u32s(&transport.recv_blocking(j, rank, tag));
        let want: Vec<u32> = p.send_sets[j].iter().map(|&li| p.inner[li as usize]).collect();
        assert_eq!(ids, want, "rank {rank}: peer {j} requested a different boundary set");
    }
}

/// Full per-rank boundary-set exchange over the schedule's setup window
/// (concurrent engines: every rank runs send-then-verify; sends never
/// block, so this cannot deadlock).
pub fn setup_exchange(transport: &dyn Transport, view: &PartView<'_>, window: &schedule::Window) {
    let mut cur = Cursor::new(&window.events);
    setup_send(transport, view, &mut cur);
    setup_verify(transport, view, &mut cur);
    cur.finish();
}

/// Side-channel controls for [`run_rank_ctl`]: checkpointing, live run
/// logging (rank 0), and fault injection for the recovery tests.
#[derive(Default)]
pub struct RankCtl<'a> {
    /// snapshot the full training state into `policy.dir` every
    /// `policy.every` epochs
    pub ckpt: Option<&'a ckpt::Policy>,
    /// rank 0 only: emit one NDJSON row per epoch, live — `{epoch,
    /// loss, epoch_ms, comp_ms, comm_wait_ms, overlap_ratio, comm_wait,
    /// rss}` where `comm_wait` is the per-(layer, phase) breakdown and
    /// `rss` is the process peak RSS in bytes (`VmHWM`, 0 off-Linux)
    pub log: Option<&'a mut FileEmitter>,
    /// fault injection (`pipegcn worker --fail-epoch`): exit(13) right
    /// after this epoch completes, simulating a worker death mid-run
    pub kill_after_epoch: Option<usize>,
}

/// Run rank `rank`'s full training schedule over `transport`, starting
/// from a fresh state. Numerics match
/// [`super::trainer::train_resumable`] exactly (same seeds ⇒ same
/// parameters); returns the rank's per-epoch losses (**global** on
/// rank 0; this rank's partials elsewhere) and its final parameter copy
/// (identical on every rank).
pub fn run_rank(
    transport: &dyn Transport,
    view: &PartView<'_>,
    cfg: &TrainConfig,
) -> (Vec<f64>, Params) {
    let mut st = TrainState::init(cfg, view.part);
    let rep = run_rank_ctl(transport, view, cfg, &mut st, RankCtl::default())
        .expect("run_rank without checkpointing has no I/O to fail");
    (rep.losses, st.params)
}

/// [`run_rank`] over an explicit [`TrainState`] — fresh or restored from
/// a checkpoint — with optional snapshotting and live run logging.
/// Epochs `st.epoch + 1 ..= cfg.epochs` are trained; the returned report
/// covers exactly those epochs.
pub fn run_rank_ctl(
    transport: &dyn Transport,
    view: &PartView<'_>,
    cfg: &TrainConfig,
    st: &mut TrainState,
    mut ctl: RankCtl<'_>,
) -> crate::util::error::Result<RankReport> {
    let k = view.n_parts;
    let rank = view.rank();
    assert_eq!(transport.n_ranks(), k);
    let n_layers = cfg.model.n_layers();
    let dims = cfg.model.dims.clone();
    let (pipe, opts) = match cfg.variant {
        Variant::Vanilla => (false, super::PipeOpts::plain()),
        Variant::Pipe(o) => (true, o),
    };
    let p = view.part;

    // Pre-registered observability handles — one registry lock per
    // series here, lock-free atomic updates on the epoch path. The
    // registry is process-global: over TCP each process is one rank, so
    // a worker's metrics endpoint shows exactly its own rank; in the
    // threaded engine every rank's thread folds into the same series.
    // All of it is observation-only — no effect on schedule, tags, or
    // numerics (the bit-identity oracle below stays the proof).
    let reg = crate::obs::global();
    let fwd_ms: Vec<crate::obs::Histogram> = (0..n_layers)
        .map(|l| reg.histogram("layer_fwd_ms", &[("layer", &l.to_string())]))
        .collect();
    let bwd_ms: Vec<crate::obs::Histogram> = (0..n_layers)
        .map(|l| reg.histogram("layer_bwd_ms", &[("layer", &l.to_string())]))
        .collect();
    let per_layer = |family: &str, kind: &str| -> Vec<crate::obs::Gauge> {
        (0..n_layers)
            .map(|l| reg.gauge(family, &[("layer", &l.to_string()), ("kind", kind)]))
            .collect()
    };
    let stale_feat = per_layer("staleness_age_epochs", "feat");
    let stale_grad = per_layer("staleness_age_epochs", "grad");
    let resid_feat = per_layer("gamma_residual_norm", "feat");
    let resid_grad = per_layer("gamma_residual_norm", "grad");
    let epoch_hist = reg.histogram("epoch_ms", &[]);
    let epochs_total = reg.counter("epochs_total", &[]);

    // the schedule IR this rank executes — every (peer, tag) below comes
    // from these generated windows, never from inline derivation
    let links = view.comm_links();
    setup_exchange(transport, view, &schedule::setup_window(&links));

    let mut backend = NativeBackend::new();
    let prop_id = backend.register_prop(&p.prop);
    let dropout = cfg.model.dropout;
    let total_train = view.total_train.max(1) as f64;
    let start = st.epoch + 1;
    let mut losses = Vec::with_capacity(cfg.epochs.saturating_sub(st.epoch));
    let mut run_stats = WaitStats::default();
    for t in start..=cfg.epochs {
        let epoch_watch = Stopwatch::start();
        let epoch_t0 = crate::obs::trace::now_us();
        let mut stats = WaitStats::default();
        // this rank's γ-smoothing residuals ‖stale − fresh‖_F, filled in
        // the drain below (rank 0 publishes them as gauges)
        let mut resid_feat_acc = vec![0.0f64; n_layers];
        let mut resid_grad_acc = vec![0.0f64; n_layers];
        // ---- prefetch: post every receive of the epoch ----
        // The tags of an epoch are fully known up front (they encode
        // (iter, layer, phase)); posting them all here lets the
        // transport complete each one the moment its peer sends, while
        // this rank is inside the kernels below.
        let window = schedule::epoch_window(&links, Style::Prefetched, pipe, n_layers, t as u32)?;
        let mut cur = Cursor::new(&window.events);
        let mut posted: HashMap<(usize, Tag), RecvHandle> = HashMap::new();
        for ev in cur.take_posts() {
            posted.insert((ev.peer(), ev.tag()), transport.post_recv(ev.peer(), rank, ev.tag()));
        }
        // ---- forward ----
        let mut h_src: Vec<Mat> = vec![p.features.clone()];
        let mut h_full_c: Vec<Mat> = Vec::new();
        let mut masks: Vec<Option<Mat>> = Vec::new();
        let mut z_aggs: Vec<Mat> = Vec::new();
        let mut pres: Vec<Mat> = Vec::new();
        for l in 0..n_layers {
            let f_in = dims[l];
            for ev in cur.take_sends(Phase::FwdFeat, l as u16) {
                transport.send(rank, ev.peer(), ev.tag(), p.gather_send(ev.peer(), &h_src[l]));
            }
            let halo_mat = if !pipe {
                // synchronous exchange: this layer's fresh features are
                // needed right now — wait at the point of use
                let mut m = Mat::zeros(p.halo.len(), f_in);
                for ev in cur.take_waits(Phase::FwdFeat, l as u16) {
                    let range = p.halo_ranges[ev.peer()].clone();
                    let payload = posted
                        .remove(&(ev.peer(), ev.tag()))
                        .expect("receive posted at epoch start")
                        .wait(&mut stats);
                    let cols = m.cols;
                    m.data[range.start * cols..range.start * cols + payload.len()]
                        .copy_from_slice(&payload);
                }
                m
            } else {
                // Alg. 1: compute on the iteration-(t−1) buffer; the
                // fresh tag-t payloads keep arriving behind the posted
                // handles and are drained after the backward pass
                st.feat_buf[l].clone()
            };
            let mut assembled = h_src[l].vcat(&halo_mat);
            let (hf, mask) = if dropout > 0.0 {
                let mut r = super::trainer::dropout_rng(cfg.seed, t, rank, l);
                let m = ops::dropout_mask(assembled.rows, assembled.cols, dropout, &mut r);
                ops::hadamard_inplace(&mut assembled, &m);
                (assembled, Some(m))
            } else {
                (assembled, None)
            };
            let lp = &st.params.layers[l];
            let kernel_watch = Stopwatch::start();
            let kernel_t0 = crate::obs::trace::now_us();
            let out = backend.layer_fwd(prop_id, &hf, lp.w_self.as_ref(), &lp.w_neigh);
            fwd_ms[l].record(kernel_watch.elapsed_secs() * 1e3);
            if crate::obs::trace::enabled() {
                crate::obs::trace::span(rank, crate::obs::trace::Kind::FwdLayer, l, t, kernel_t0);
            }
            let h_next = if l + 1 < n_layers { ops::relu(&out.pre) } else { out.pre.clone() };
            h_full_c.push(hf);
            masks.push(mask);
            z_aggs.push(out.z_agg);
            pres.push(out.pre);
            h_src.push(h_next);
        }
        // ---- loss + per-epoch reduction to rank 0 ----
        let logits = &pres[n_layers - 1];
        let local = p.train_mask.len() as f64;
        let (loss_i, mut j_cur) = match &p.labels {
            PlanLabels::Single(labels) => ops::softmax_xent(logits, labels, &p.train_mask),
            PlanLabels::Multi(targets) => ops::sigmoid_bce(logits, targets, &p.train_mask),
        };
        j_cur.scale((local / total_train) as f32);
        let partial = loss_i * local / total_train;
        let epoch_loss = if rank == 0 {
            // sum in rank order — the f64 accumulation order matches the
            // sequential engine, keeping the curve bit-identical
            let mut tot = partial;
            for ev in cur.take_waits(Phase::Loss, 0) {
                let payload = posted
                    .remove(&(ev.peer(), ev.tag()))
                    .expect("loss receive posted at epoch start")
                    .wait(&mut stats);
                tot += decode_f64s(&payload)[0];
            }
            tot
        } else {
            for ev in cur.take_sends(Phase::Loss, 0) {
                transport.send(rank, ev.peer(), ev.tag(), encode_f64s(&[partial]));
            }
            partial
        };
        losses.push(epoch_loss);
        // ---- backward ----
        let mut grads = st.params.zeros_like();
        for l in (0..n_layers).rev() {
            let f_in = dims[l];
            let mut m = j_cur.clone();
            if l + 1 < n_layers {
                ops::relu_grad_inplace(&mut m, &pres[l]);
            }
            let lp = &st.params.layers[l];
            let kernel_watch = Stopwatch::start();
            let kernel_t0 = crate::obs::trace::now_us();
            let bwd = backend.layer_bwd(
                prop_id,
                &h_full_c[l],
                &z_aggs[l],
                &m,
                lp.w_self.as_ref(),
                &lp.w_neigh,
                l > 0,
            );
            bwd_ms[l].record(kernel_watch.elapsed_secs() * 1e3);
            if crate::obs::trace::enabled() {
                crate::obs::trace::span(rank, crate::obs::trace::Kind::BwdLayer, l, t, kernel_t0);
            }
            grads.layers[l].w_neigh = bwd.g_neigh;
            if let Some(gs) = bwd.g_self {
                grads.layers[l].w_self = Some(gs);
            }
            if l > 0 {
                let mut j_full = bwd.j_full.unwrap();
                if let Some(mask) = &masks[l] {
                    ops::hadamard_inplace(&mut j_full, mask);
                }
                let n_inner = p.n_inner();
                for ev in cur.take_sends(Phase::BwdGrad, l as u16) {
                    let range = p.halo_ranges[ev.peer()].clone();
                    let payload = j_full.data
                        [(n_inner + range.start) * f_in..(n_inner + range.end) * f_in]
                        .to_vec();
                    transport.send(rank, ev.peer(), ev.tag(), payload);
                }
                let mut jg = j_full.rows_range(0, n_inner);
                if !pipe {
                    for ev in cur.take_waits(Phase::BwdGrad, l as u16) {
                        let j = ev.peer();
                        let payload = posted
                            .remove(&(j, ev.tag()))
                            .expect("receive posted at epoch start")
                            .wait(&mut stats);
                        super::trainer::scatter_add_rows(&mut jg, &p.send_sets[j], &payload);
                    }
                } else {
                    // stale contributions only (zeros at t = 1); fresh
                    // tag-t gradients are drained after the pass
                    jg.add_assign(&st.grad_buf[l]);
                }
                j_cur = jg;
            }
        }
        // ---- drain (pipelined variants) ----
        // Fold the epoch's fresh boundary tensors — posted at epoch
        // start, arriving behind the entire forward/backward compute —
        // into the stale buffers for iteration t+1. This runs before the
        // checkpoint hook so snapshots hold exactly the buffers the
        // sequential engine writes.
        let drain_t0 = crate::obs::trace::now_us();
        if pipe {
            for l in 0..n_layers {
                let f_in = dims[l];
                let mut fresh = Mat::zeros(p.halo.len(), f_in);
                for ev in cur.take_waits(Phase::FwdFeat, l as u16) {
                    let range = p.halo_ranges[ev.peer()].clone();
                    let payload = posted
                        .remove(&(ev.peer(), ev.tag()))
                        .expect("receive posted at epoch start")
                        .wait(&mut stats);
                    let cols = fresh.cols;
                    fresh.data[range.start * cols..range.start * cols + payload.len()]
                        .copy_from_slice(&payload);
                }
                if opts.smooth_feat && t > 1 {
                    resid_feat_acc[l] = st.feat_buf[l].fro_dist(&fresh);
                    st.feat_buf[l].scale(opts.gamma);
                    st.feat_buf[l].axpy(1.0 - opts.gamma, &fresh);
                } else {
                    st.feat_buf[l] = fresh;
                }
            }
            for l in 1..n_layers {
                let f_in = dims[l];
                let mut fresh = Mat::zeros(p.n_inner(), f_in);
                for ev in cur.take_waits(Phase::BwdGrad, l as u16) {
                    let j = ev.peer();
                    let payload = posted
                        .remove(&(j, ev.tag()))
                        .expect("receive posted at epoch start")
                        .wait(&mut stats);
                    super::trainer::scatter_add_rows(&mut fresh, &p.send_sets[j], &payload);
                }
                if opts.smooth_grad && t > 1 {
                    resid_grad_acc[l] = st.grad_buf[l].fro_dist(&fresh);
                    st.grad_buf[l].scale(opts.gamma);
                    st.grad_buf[l].axpy(1.0 - opts.gamma, &fresh);
                } else {
                    st.grad_buf[l] = fresh;
                }
            }
        }
        if pipe && crate::obs::trace::enabled() {
            crate::obs::trace::span(rank, crate::obs::trace::Kind::Drain, 0, t, drain_t0);
        }
        debug_assert!(posted.is_empty(), "unconsumed posted receives at epoch end");
        // ---- all-reduce + update (replicated Adam) ----
        let mut gbuf = grads.flatten();
        let reduce_t0 = crate::obs::trace::now_us();
        ring_allreduce_rank(transport, rank, k, &mut gbuf, cur.take_ring(), &mut stats);
        cur.finish();
        if crate::obs::trace::enabled() {
            crate::obs::trace::span(rank, crate::obs::trace::Kind::Reduce, 0, t, reduce_t0);
        }
        match cfg.optimizer {
            super::Optimizer::Adam => st.adam.step(&mut st.flat, &gbuf),
            super::Optimizer::Sgd => {
                for (pv, gv) in st.flat.iter_mut().zip(&gbuf) {
                    *pv -= cfg.lr * *gv;
                }
            }
        }
        st.params.unflatten(&st.flat);
        st.epoch = t;
        // per-phase wall breakdown: everything not spent parked in a
        // receive is compute. comm_wait_ms is defined as the exact sum
        // of the per-(layer, phase) breakdown values (checkpoint I/O
        // excluded from the epoch account).
        let epoch_ms = epoch_watch.elapsed_secs() * 1e3;
        let entries = stats.entries_ms();
        let comm_wait_ms: f64 = entries.iter().map(|(_, v)| v).sum();
        let comp_ms = (epoch_ms - comm_wait_ms).max(0.0);
        if crate::obs::trace::enabled() {
            crate::obs::trace::span(rank, crate::obs::trace::Kind::Epoch, 0, t, epoch_t0);
        }
        // per-epoch metric publication (counters/gauges/histograms only
        // — the schedule and numerics above are untouched)
        crate::obs::record_wait_stats(&stats);
        let peak_rss = crate::obs::sample_peak_rss(&reg).unwrap_or(0);
        if rank == 0 {
            epoch_hist.record(epoch_ms);
            epochs_total.inc();
            for l in 0..n_layers {
                // staleness is structural: pipelined variants consume
                // iteration-(t−1) boundary tensors, vanilla waits for
                // fresh ones; layer 0 never exchanges gradients
                stale_feat[l].set(if pipe { 1.0 } else { 0.0 });
                stale_grad[l].set(if pipe && l > 0 { 1.0 } else { 0.0 });
                if opts.smooth_feat && t > 1 {
                    resid_feat[l].set(resid_feat_acc[l]);
                }
                if opts.smooth_grad && t > 1 {
                    resid_grad[l].set(resid_grad_acc[l]);
                }
            }
        }
        if let Some(em) = ctl.log.take() {
            let mut breakdown = Json::obj();
            for (key, ms) in &entries {
                breakdown = breakdown.set(key, *ms);
            }
            let row = Json::obj()
                .set("epoch", t)
                .set("loss", epoch_loss)
                .set("epoch_ms", epoch_ms)
                .set("comp_ms", comp_ms)
                .set("comm_wait_ms", comm_wait_ms)
                .set("overlap_ratio", stats.overlap_ratio())
                .set("comm_wait", breakdown)
                .set("rss", peak_rss);
            match em.emit(&row) {
                Ok(()) => ctl.log = Some(em),
                // stop logging, keep training
                Err(e) => eprintln!("run-log write failed: {e}"),
            }
        }
        run_stats.merge(&stats);
        if let Some(pol) = ctl.ckpt {
            if pol.due(t) {
                ckpt::save(&pol.dir, &st.snapshot(rank, k))?;
            }
        }
        if ctl.kill_after_epoch == Some(t) {
            eprintln!("[rank {rank}] fault injection: dying after epoch {t}");
            std::process::exit(13);
        }
    }
    let comm_wait_by = run_stats.entries_ms();
    Ok(RankReport {
        losses,
        comm_wait_ms: comm_wait_by.iter().map(|(_, v)| v).sum(),
        overlap_ratio: run_stats.overlap_ratio(),
        comm_wait_by,
    })
}

/// Side-channel controls for [`run_threaded_ctl`] — the threaded
/// engine's analogue of the per-rank [`RankCtl`]: checkpoint policy,
/// resume directory, and a live rank-0 run log.
#[derive(Default)]
pub struct ThreadedCtl<'a> {
    /// snapshot every rank's state into `policy.dir` every
    /// `policy.every` epochs
    pub ckpt: Option<&'a ckpt::Policy>,
    /// restore the latest complete checkpoint under this directory and
    /// train only the remaining epochs
    pub resume: Option<&'a str>,
    /// rank 0's live NDJSON run log (one row per epoch)
    pub log: Option<&'a mut FileEmitter>,
}

/// The threaded engine core (the `Engine::Threaded` adapter behind
/// [`crate::session::Session`]): one OS thread per partition over the
/// in-process [`Fabric`], each running [`run_rank_ctl`] — so the
/// checkpoint files, run-log rows, and loss bits are identical to the
/// sequential and TCP engines. Returns the result plus the epoch the run
/// started from (0 on a fresh run).
///
/// Every rank's state is restored (or initialized) *before* any thread
/// starts, so a corrupt checkpoint is a clean error, not a stalled
/// mesh. The one failure this engine cannot surface cleanly is a
/// checkpoint **write** error mid-run on a single rank: that rank exits
/// with the error while its peers block on its next message, stalling
/// the run (a thread cannot die without taking the mesh's progress with
/// it). Runs that need supervised fault tolerance belong on the TCP
/// engine, whose launcher detects a dead worker and relaunches the mesh
/// from the latest complete checkpoint.
pub fn run_threaded_ctl(
    g: &Graph,
    pt: &Partitioning,
    cfg: &TrainConfig,
    ctl: ThreadedCtl<'_>,
) -> crate::util::error::Result<(ThreadedResult, usize)> {
    let plan = halo::build(g, pt, cfg.model.kind);
    let k = plan.n_parts;
    let start_epoch = match ctl.resume {
        None => 0,
        Some(dir) => {
            let epoch = ckpt::latest_complete(dir, k)?.ok_or_else(|| {
                crate::err_msg!("resume {dir}: no complete checkpoint for {k} ranks")
            })?;
            if epoch >= cfg.epochs {
                crate::bail!(
                    "resume {dir}: checkpoint epoch {epoch} already covers epochs {}",
                    cfg.epochs
                );
            }
            epoch
        }
    };
    let states: Vec<TrainState> = match ctl.resume {
        None => (0..k).map(|i| TrainState::init(cfg, &plan.parts[i])).collect(),
        Some(dir) => (0..k)
            .map(|i| {
                TrainState::from_snapshot(ckpt::load(dir, start_epoch, i)?, cfg, &plan.parts[i])
            })
            .collect::<crate::util::error::Result<Vec<_>>>()?,
    };
    let fabric = Fabric::new(k);
    // runtime conformance mode (debug builds, PIPEGCN_CONFORMANCE=1):
    // generate the full prefetched schedule for every rank and make the
    // transport hooks cross-check each live operation against it
    let conformance = schedule::conformance_requested();
    if conformance {
        let all_links: Vec<schedule::RankLinks> =
            (0..k).map(|i| plan.view(i).comm_links()).collect();
        let sched = schedule::Schedule::generate(
            &all_links,
            Style::Prefetched,
            matches!(cfg.variant, Variant::Pipe(_)),
            cfg.model.n_layers(),
            start_epoch as u32 + 1,
            cfg.epochs as u32,
        )?;
        schedule::set_sink(Box::new(schedule::Conformance::new(&sched)));
    }
    let ckpt_policy = ctl.ckpt;
    let mut log = ctl.log;
    let plan_ref = &plan;
    let fabric_ref = &fabric;
    // what one rank's thread hands back: its report and final state
    type RankRun = crate::util::error::Result<(RankReport, TrainState)>;
    let results: Vec<RankRun> = std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(k);
        for (rank, mut st) in states.into_iter().enumerate() {
            let log_slot = if rank == 0 { log.take() } else { None };
            handles.push(s.spawn(move || -> RankRun {
                let rc = RankCtl {
                    ckpt: ckpt_policy,
                    log: log_slot,
                    kill_after_epoch: None,
                };
                let rep = run_rank_ctl(fabric_ref, &plan_ref.view(rank), cfg, &mut st, rc)?;
                Ok((rep, st))
            }));
        }
        handles.into_iter().map(|h| h.join().expect("rank thread panicked")).collect()
    });
    if conformance {
        schedule::clear_sink();
    }
    let mut per_rank =
        results.into_iter().collect::<crate::util::error::Result<Vec<_>>>()?;
    // rank 0 already holds the global per-epoch losses (it drives the
    // per-epoch loss reduction, summing partials in rank order — the
    // same f64 order as the sequential engine, so sums stay bit-identical)
    let (rep0, st0) = per_rank.swap_remove(0);
    let (final_val, final_test) = super::evaluate(g, &st0.params, cfg.model.kind);
    Ok((
        ThreadedResult {
            losses: rep0.losses,
            params: st0.params,
            final_val,
            final_test,
            comm_bytes: fabric.total_bytes(),
            comm_wait_ms: rep0.comm_wait_ms,
            overlap_ratio: rep0.overlap_ratio,
        },
        start_epoch,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{trainer, Optimizer, PipeOpts, TrainConfig};
    use crate::graph::presets;
    use crate::model::ModelConfig;
    use crate::partition::{partition, Method};
    use std::sync::Arc;

    /// The engine core without controls.
    fn train_threaded(g: &Graph, pt: &Partitioning, cfg: &TrainConfig) -> ThreadedResult {
        run_threaded_ctl(g, pt, cfg, ThreadedCtl::default()).unwrap().0
    }

    fn cfg(g: &Graph, variant: Variant, dropout: f32) -> TrainConfig {
        TrainConfig {
            model: ModelConfig::sage(g.feat_dim(), 16, 2, g.labels.n_classes(), dropout),
            variant,
            optimizer: Optimizer::Adam,
            lr: 0.01,
            epochs: 6,
            seed: 11,
            eval_every: 0,
            probe_errors: false,
        }
    }

    /// Threads + posted receives must reproduce the sequential engine
    /// bit-for-bit (staleness lives in tags, not timing) — the oracle
    /// that pins the prefetched schedule to Algorithm 1.
    #[test]
    fn threaded_matches_sequential_all_variants() {
        let g = presets::by_name("tiny").unwrap().build(42);
        let pt = partition(&g, 3, Method::Multilevel, 2);
        for (variant, dropout) in [
            (Variant::Vanilla, 0.0f32),
            (Variant::Pipe(PipeOpts::plain()), 0.0),
            (
                Variant::Pipe(PipeOpts { smooth_feat: true, smooth_grad: true, gamma: 0.7 }),
                0.5,
            ),
        ] {
            let c = cfg(&g, variant, dropout);
            let mut b = crate::runtime::native::NativeBackend::new();
            let seq = trainer::train_resumable(&g, &pt, &c, &mut b, None, None, None).unwrap();
            let thr = train_threaded(&g, &pt, &c);
            for (e, (a, l)) in seq.curve.iter().zip(&thr.losses).enumerate() {
                assert!(
                    (a.train_loss - l).abs() < 1e-9,
                    "{variant:?} epoch {e}: seq {} vs threaded {l}",
                    a.train_loss
                );
            }
        }
    }

    #[test]
    fn threaded_final_metrics_reasonable() {
        let g = presets::by_name("tiny").unwrap().build(42);
        let pt = partition(&g, 2, Method::Multilevel, 3);
        let mut c = cfg(&g, Variant::Pipe(PipeOpts::plain()), 0.0);
        c.epochs = 25;
        let r = train_threaded(&g, &pt, &c);
        assert!(r.final_test > 0.5, "test {}", r.final_test);
        assert!(r.losses.last().unwrap() < &r.losses[0]);
        assert!(r.comm_bytes > 0);
        // the overlap instrumentation is populated and sane
        assert!(r.comm_wait_ms >= 0.0);
        assert!((0.0..=1.0).contains(&r.overlap_ratio), "{}", r.overlap_ratio);
    }

    /// Setup + per-epoch traffic through the threaded fabric must equal
    /// the sequential fabric's accounting — the volumes experiments
    /// report are engine-independent.
    #[test]
    fn threaded_comm_bytes_match_sequential() {
        let g = presets::by_name("tiny").unwrap().build(42);
        let pt = partition(&g, 3, Method::Multilevel, 2);
        let c = cfg(&g, Variant::Pipe(PipeOpts::plain()), 0.0);
        let mut b = crate::runtime::native::NativeBackend::new();
        let seq = trainer::train_resumable(&g, &pt, &c, &mut b, None, None, None).unwrap();
        let thr = train_threaded(&g, &pt, &c);
        // every epoch moves the same message sizes, so the full run is
        // setup + epochs × steady-state-epoch bytes
        let seq_total = seq.setup_bytes + c.epochs as u64 * seq.comm_bytes_epoch;
        assert_eq!(thr.comm_bytes, seq_total);
    }

    /// The per-rank report's breakdown keys must sum to its total — the
    /// invariant the NDJSON regression test also pins end to end.
    #[test]
    fn rank_report_breakdown_sums_to_total() {
        let g = presets::by_name("tiny").unwrap().build(42);
        let pt = partition(&g, 3, Method::Multilevel, 2);
        let c = cfg(&g, Variant::Pipe(PipeOpts::plain()), 0.0);
        let plan = halo::build(&g, &pt, c.model.kind);
        let fabric = Fabric::new(3);
        let reports: Vec<RankReport> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..3)
                .map(|rank| {
                    let (fabric, plan, c) = (&fabric, &plan, &c);
                    s.spawn(move || {
                        let mut st = TrainState::init(c, &plan.parts[rank]);
                        run_rank_ctl(fabric, &plan.view(rank), c, &mut st, RankCtl::default())
                            .unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let mut union: Vec<&str> = Vec::new();
        for (rank, rep) in reports.iter().enumerate() {
            assert!(!rep.comm_wait_by.is_empty(), "rank {rank}: empty breakdown");
            let sum: f64 = rep.comm_wait_by.iter().map(|(_, v)| v).sum();
            assert!(
                (sum - rep.comm_wait_ms).abs() <= 1e-9 * rep.comm_wait_ms.max(1.0),
                "rank {rank}: {} vs {}",
                sum,
                rep.comm_wait_ms
            );
            assert!((0.0..=1.0).contains(&rep.overlap_ratio), "rank {rank}");
            union.extend(rep.comm_wait_by.iter().map(|(k2, _)| k2.as_str()));
        }
        // a 2-layer pipe run waits (at least trivially) on features per
        // layer, gradients at l≥1, and the ring, somewhere in the mesh
        for key in ["fwd_l0", "fwd_l1", "bwd_l1", "reduce"] {
            assert!(union.contains(&key), "missing {key} in {union:?}");
        }
    }

    /// Regression for the u16 tag wraparound: the rank-driven all-reduce
    /// must stay correct past the old n ≈ 182 overflow boundary, with
    /// every rank on its own thread (real posted receives).
    #[test]
    fn rank_driven_allreduce_correct_past_tag_boundary() {
        let n = 190;
        let len = 97;
        let fabric = Arc::new(Fabric::new(n));
        let handles: Vec<_> = (0..n)
            .map(|r| {
                let f = fabric.clone();
                std::thread::spawn(move || {
                    let mut buf: Vec<f32> = (0..len).map(|i| ((r + i) % 5) as f32).collect();
                    let ev = schedule::ring_events(Style::Prefetched, 1, r, n).unwrap();
                    ring_allreduce_rank(
                        f.as_ref(),
                        r,
                        n,
                        &mut buf,
                        &ev,
                        &mut WaitStats::default(),
                    );
                    buf
                })
            })
            .collect();
        let mut want = vec![0.0f32; len]; // small integers: f32-exact
        for r in 0..n {
            for (i, w) in want.iter_mut().enumerate() {
                *w += ((r + i) % 5) as f32;
            }
        }
        for (r, h) in handles.into_iter().enumerate() {
            let got = h.join().unwrap();
            crate::util::prop::assert_close(&got, &want, 1e-4)
                .unwrap_or_else(|e| panic!("rank {r}: {e}"));
        }
        assert_eq!(fabric.pending(), 0);
    }

    /// A run driven through run_threaded_ctl with checkpointing, then
    /// resumed from a mid-run snapshot, must reproduce the uninterrupted
    /// loss curve bit-for-bit (the determinism oracle behind crash
    /// recovery). The drain step updates the stale buffers before the
    /// snapshot hook, so this also pins checkpoint equivalence under the
    /// prefetched schedule.
    #[test]
    fn threaded_resume_from_checkpoint_is_bitwise_identical() {
        let g = presets::by_name("tiny").unwrap().build(42);
        let pt = partition(&g, 2, Method::Multilevel, 3);
        let c = cfg(&g, Variant::Pipe(PipeOpts::plain()), 0.3);
        let dir = format!("/tmp/pipegcn_thr_ckpt_{}", std::process::id());
        let _ = std::fs::remove_dir_all(&dir);

        let policy = ckpt::Policy { dir: dir.clone(), every: 2 };
        let ctl = ThreadedCtl { ckpt: Some(&policy), ..ThreadedCtl::default() };
        let (full, start) = run_threaded_ctl(&g, &pt, &c, ctl).unwrap();
        assert_eq!(start, 0);
        assert_eq!(ckpt::latest_complete(&dir, 2).unwrap(), Some(6));
        // drop the final checkpoint so the resume lands on the mid-run
        // epoch-4 snapshot (latest_complete must skip to it): epochs 5..6
        std::fs::remove_dir_all(ckpt::epoch_dir(&dir, 6)).unwrap();
        let ctl = ThreadedCtl { resume: Some(&dir), ..ThreadedCtl::default() };
        let (resumed, start) = run_threaded_ctl(&g, &pt, &c, ctl).unwrap();
        assert_eq!(start, 4);
        assert_eq!(resumed.losses.len(), 2);
        for (i, (a, b)) in full.losses[4..].iter().zip(&resumed.losses).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "epoch {}: {a} vs {b}", 5 + i);
        }
        // resuming past --epochs is a diagnostic, not an empty run
        let mut short = c.clone();
        short.epochs = 3;
        let ctl = ThreadedCtl { resume: Some(&dir), ..ThreadedCtl::default() };
        assert!(run_threaded_ctl(&g, &pt, &short, ctl).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
