//! Transport-generic per-rank runner + the threaded engine.
//!
//! [`run_rank`] is one rank's complete training schedule — the same
//! dataflow as [`super::trainer::train`] — written against the
//! [`Transport`] contract, so the identical code drives:
//!
//! * [`train_threaded`]: one OS thread per partition over the in-process
//!   [`Fabric`] (concurrent blocking receives, single process), and
//! * the multi-process engine: one OS process per partition over
//!   [`crate::net::TcpTransport`] (real localhost sockets), launched by
//!   `pipegcn launch` / driven by [`crate::net::worker`].
//!
//! On a 1-core testbed these demonstrate *correctness* of the concurrent
//! schedule, not speedup: the integration tests assert the loss curve is
//! identical to the sequential engine (the dataflow is deterministic —
//! staleness is encoded in message tags, not timing luck).
//!
//! Scope: no probes / work capture (the sequential engine owns those);
//! evaluation only at the end.

use super::halo::{self, HaloPlan, PlanLabels};
use super::{TrainConfig, Variant};
use crate::comm::{decode_u32s, encode_u32s, Fabric, Phase, Tag, Transport};
use crate::graph::Graph;
use crate::model::{adam::Adam, Params};
use crate::partition::Partitioning;
use crate::runtime::native::NativeBackend;
use crate::runtime::Backend;
use crate::tensor::{ops, Mat};
use std::sync::Arc;

/// Result of a threaded run.
pub struct ThreadedResult {
    /// per-epoch global train loss
    pub losses: Vec<f64>,
    /// final parameters (identical on every rank; rank 0's copy)
    pub params: Params,
    pub final_val: f64,
    pub final_test: f64,
    /// total payload bytes through the fabric (setup + all epochs)
    pub comm_bytes: u64,
}

/// Per-rank ring all-reduce over any transport (blocking receives).
fn ring_allreduce_rank(
    transport: &dyn Transport,
    rank: usize,
    n: usize,
    buf: &mut [f32],
    iter: u32,
) {
    if n <= 1 || buf.is_empty() {
        return;
    }
    let len = buf.len();
    let starts: Vec<usize> = (0..=n).map(|c| c * len / n).collect();
    let chunk = |c: usize| starts[c % n]..starts[c % n + 1];
    let next = (rank + 1) % n;
    let prev = (rank + n - 1) % n;
    for s in 0..n - 1 {
        let c_send = (rank + n - s) % n;
        let tag_s = Tag::new(iter, (s * n + c_send) as u16, Phase::Reduce);
        transport.send(rank, next, tag_s, buf[chunk(c_send)].to_vec());
        let c_recv = (prev + n - s) % n;
        let tag_r = Tag::new(iter, (s * n + c_recv) as u16, Phase::Reduce);
        let recv = transport.recv_blocking(prev, rank, tag_r);
        for (d, v) in buf[chunk(c_recv)].iter_mut().zip(recv) {
            *d += v;
        }
    }
    for s in 0..n - 1 {
        let c_send = (rank + 1 + n - s) % n;
        let tag_s = Tag::new(iter, ((n + s) * n + c_send) as u16, Phase::Reduce);
        transport.send(rank, next, tag_s, buf[chunk(c_send)].to_vec());
        let c_recv = (prev + 1 + n - s) % n;
        let tag_r = Tag::new(iter, ((n + s) * n + c_recv) as u16, Phase::Reduce);
        let recv = transport.recv_blocking(prev, rank, tag_r);
        buf[chunk(c_recv)].copy_from_slice(&recv);
    }
}

/// The Setup-phase tag of the boundary-set exchange.
fn setup_tag() -> Tag {
    Tag::new(0, 0, Phase::Setup)
}

/// Send half of the boundary-set exchange (`Phase::Setup`, Alg. 1
/// lines 1–5 made real): ship each peer the global ids of the halo rows
/// `rank` needs from it. Moving this through the transport makes byte
/// accounting include the setup traffic a real wire sees.
pub fn setup_send(transport: &dyn Transport, plan: &HaloPlan, rank: usize) {
    let p = &plan.parts[rank];
    for j in 0..plan.n_parts {
        let range = p.halo_ranges[j].clone();
        if j != rank && !range.is_empty() {
            transport.send(rank, j, setup_tag(), encode_u32s(&p.halo[range]));
        }
    }
}

/// Verify half: receive each peer's request and check it matches the
/// plan's send set — this is what establishes `S_{i,j}` on a real
/// deployment, and over TCP it validates the mesh wiring before any
/// tensor moves.
pub fn setup_verify(transport: &dyn Transport, plan: &HaloPlan, rank: usize) {
    let p = &plan.parts[rank];
    for j in 0..plan.n_parts {
        if j != rank && !p.send_sets[j].is_empty() {
            let ids = decode_u32s(&transport.recv_blocking(j, rank, setup_tag()));
            let want: Vec<u32> =
                p.send_sets[j].iter().map(|&li| p.inner[li as usize]).collect();
            assert_eq!(
                ids, want,
                "rank {rank}: peer {j} requested a different boundary set"
            );
        }
    }
}

/// Full per-rank boundary-set exchange (concurrent engines: every rank
/// runs send-then-verify; sends never block, so this cannot deadlock).
pub fn setup_exchange(transport: &dyn Transport, plan: &HaloPlan, rank: usize) {
    setup_send(transport, plan, rank);
    setup_verify(transport, plan, rank);
}

/// Run rank `rank`'s full training schedule over `transport`. Numerics
/// match [`super::trainer::train`] exactly (same seeds ⇒ same
/// parameters); returns the rank's per-epoch *partial* losses (sum
/// across ranks = global loss) and its final parameter copy (identical
/// on every rank).
pub fn run_rank(
    transport: &dyn Transport,
    plan: &HaloPlan,
    rank: usize,
    cfg: &TrainConfig,
) -> (Vec<f64>, Params) {
    let k = plan.n_parts;
    assert_eq!(transport.n_ranks(), k);
    let n_layers = cfg.model.n_layers();
    let dims = cfg.model.dims.clone();
    let (pipe, opts) = match cfg.variant {
        Variant::Vanilla => (false, super::PipeOpts::plain()),
        Variant::Pipe(o) => (true, o),
    };
    let p = &plan.parts[rank];

    setup_exchange(transport, plan, rank);

    let mut backend = NativeBackend::new();
    let prop_id = backend.register_prop(&p.prop);
    let mut rng = crate::util::rng::Rng::new(cfg.seed);
    let mut params = Params::init(&cfg.model, &mut rng);
    let mut flat = params.flatten();
    let mut adam = Adam::new(cfg.lr, flat.len());
    let dropout = cfg.model.dropout;
    let total_train = plan.total_train.max(1) as f64;
    // stale buffers
    let mut feat_buf: Vec<Mat> =
        (0..n_layers).map(|l| Mat::zeros(p.halo.len(), dims[l])).collect();
    let mut grad_buf: Vec<Mat> =
        (0..n_layers).map(|l| Mat::zeros(p.n_inner(), dims[l])).collect();
    let mut losses = Vec::with_capacity(cfg.epochs);
    for t in 1..=cfg.epochs {
        // ---- forward ----
        let mut h_src: Vec<Mat> = vec![p.features.clone()];
        let mut h_full_c: Vec<Mat> = Vec::new();
        let mut masks: Vec<Option<Mat>> = Vec::new();
        let mut z_aggs: Vec<Mat> = Vec::new();
        let mut pres: Vec<Mat> = Vec::new();
        for l in 0..n_layers {
            let f_in = dims[l];
            for j in 0..k {
                if j != rank && !p.send_sets[j].is_empty() {
                    transport.send(
                        rank,
                        j,
                        Tag::new(t as u32, l as u16, Phase::FwdFeat),
                        p.gather_send(j, &h_src[l]),
                    );
                }
            }
            let halo_mat = if !pipe {
                let mut m = Mat::zeros(p.halo.len(), f_in);
                for j in 0..k {
                    let range = p.halo_ranges[j].clone();
                    if !range.is_empty() {
                        let payload = transport.recv_blocking(
                            j,
                            rank,
                            Tag::new(t as u32, l as u16, Phase::FwdFeat),
                        );
                        let cols = m.cols;
                        m.data[range.start * cols..range.start * cols + payload.len()]
                            .copy_from_slice(&payload);
                    }
                }
                m
            } else {
                let used = feat_buf[l].clone();
                let mut fresh = Mat::zeros(p.halo.len(), f_in);
                for j in 0..k {
                    let range = p.halo_ranges[j].clone();
                    if !range.is_empty() {
                        let payload = transport.recv_blocking(
                            j,
                            rank,
                            Tag::new(t as u32, l as u16, Phase::FwdFeat),
                        );
                        let cols = fresh.cols;
                        fresh.data[range.start * cols..range.start * cols + payload.len()]
                            .copy_from_slice(&payload);
                    }
                }
                if opts.smooth_feat && t > 1 {
                    feat_buf[l].scale(opts.gamma);
                    feat_buf[l].axpy(1.0 - opts.gamma, &fresh);
                } else {
                    feat_buf[l] = fresh;
                }
                used
            };
            let assembled = h_src[l].vcat(&halo_mat);
            let (hf, mask) = if dropout > 0.0 {
                let mut r = super::trainer::dropout_rng(cfg.seed, t, rank, l);
                let m = ops::dropout_mask(assembled.rows, assembled.cols, dropout, &mut r);
                (ops::hadamard(&assembled, &m), Some(m))
            } else {
                (assembled, None)
            };
            let lp = &params.layers[l];
            let out = backend.layer_fwd(prop_id, &hf, lp.w_self.as_ref(), &lp.w_neigh);
            let h_next = if l + 1 < n_layers { ops::relu(&out.pre) } else { out.pre.clone() };
            h_full_c.push(hf);
            masks.push(mask);
            z_aggs.push(out.z_agg);
            pres.push(out.pre);
            h_src.push(h_next);
        }
        // ---- loss ----
        let logits = &pres[n_layers - 1];
        let local = p.train_mask.len() as f64;
        let (loss_i, mut j_cur) = match &p.labels {
            PlanLabels::Single(labels) => ops::softmax_xent(logits, labels, &p.train_mask),
            PlanLabels::Multi(targets) => ops::sigmoid_bce(logits, targets, &p.train_mask),
        };
        j_cur.scale((local / total_train) as f32);
        losses.push(loss_i * local / total_train);
        // ---- backward ----
        let mut grads = params.zeros_like();
        for l in (0..n_layers).rev() {
            let f_in = dims[l];
            let mut m = j_cur.clone();
            if l + 1 < n_layers {
                ops::relu_grad_inplace(&mut m, &pres[l]);
            }
            let lp = &params.layers[l];
            let bwd = backend.layer_bwd(
                prop_id,
                &h_full_c[l],
                &z_aggs[l],
                &m,
                lp.w_self.as_ref(),
                &lp.w_neigh,
                l > 0,
            );
            grads.layers[l].w_neigh = bwd.g_neigh;
            if let Some(gs) = bwd.g_self {
                grads.layers[l].w_self = Some(gs);
            }
            if l > 0 {
                let mut j_full = bwd.j_full.unwrap();
                if let Some(mask) = &masks[l] {
                    j_full = ops::hadamard(&j_full, mask);
                }
                let n_inner = p.n_inner();
                for j in 0..k {
                    let range = p.halo_ranges[j].clone();
                    if !range.is_empty() {
                        let payload = j_full.data
                            [(n_inner + range.start) * f_in..(n_inner + range.end) * f_in]
                            .to_vec();
                        transport.send(
                            rank,
                            j,
                            Tag::new(t as u32, l as u16, Phase::BwdGrad),
                            payload,
                        );
                    }
                }
                let mut jg = j_full.rows_range(0, n_inner);
                let recv_into = |dst: &mut Mat| {
                    for j in 0..k {
                        if j != rank && !p.send_sets[j].is_empty() {
                            let payload = transport.recv_blocking(
                                j,
                                rank,
                                Tag::new(t as u32, l as u16, Phase::BwdGrad),
                            );
                            let cols = dst.cols;
                            for (r, chunk) in
                                p.send_sets[j].iter().zip(payload.chunks_exact(cols))
                            {
                                let row = dst.row_mut(*r as usize);
                                for (d, &s) in row.iter_mut().zip(chunk) {
                                    *d += s;
                                }
                            }
                        }
                    }
                };
                if !pipe {
                    recv_into(&mut jg);
                } else {
                    jg.add_assign(&grad_buf[l]);
                    let mut fresh = Mat::zeros(n_inner, f_in);
                    recv_into(&mut fresh);
                    if opts.smooth_grad && t > 1 {
                        grad_buf[l].scale(opts.gamma);
                        grad_buf[l].axpy(1.0 - opts.gamma, &fresh);
                    } else {
                        grad_buf[l] = fresh;
                    }
                }
                j_cur = jg;
            }
        }
        // ---- all-reduce + update (replicated Adam) ----
        let mut gbuf = grads.flatten();
        ring_allreduce_rank(transport, rank, k, &mut gbuf, t as u32);
        match cfg.optimizer {
            super::Optimizer::Adam => adam.step(&mut flat, &gbuf),
            super::Optimizer::Sgd => {
                for (pv, gv) in flat.iter_mut().zip(&gbuf) {
                    *pv -= cfg.lr * *gv;
                }
            }
        }
        params.unflatten(&flat);
    }
    (losses, params)
}

/// Train with one thread per partition over the in-process [`Fabric`].
/// Numerics match [`super::trainer::train`] exactly (same seeds ⇒ same
/// parameters).
pub fn train_threaded(g: &Graph, pt: &Partitioning, cfg: &TrainConfig) -> ThreadedResult {
    let plan = Arc::new(halo::build(g, pt, cfg.model.kind));
    let k = plan.n_parts;
    let fabric = Arc::new(Fabric::new(k));
    let cfg = Arc::new(cfg.clone());

    let mut handles = Vec::new();
    for rank in 0..k {
        let plan = plan.clone();
        let fabric = fabric.clone();
        let cfg = cfg.clone();
        handles.push(std::thread::spawn(move || {
            run_rank(fabric.as_ref(), &plan, rank, &cfg)
        }));
    }
    let mut per_rank: Vec<(Vec<f64>, Params)> = handles
        .into_iter()
        .map(|h| h.join().expect("worker thread panicked"))
        .collect();
    // sum per-epoch partial losses across ranks (rank order, to match the
    // sequential engine's f64 accumulation order bit-for-bit)
    let epochs = cfg.epochs;
    let mut losses = vec![0.0f64; epochs];
    for (ls, _) in &per_rank {
        for (dst, v) in losses.iter_mut().zip(ls) {
            *dst += v;
        }
    }
    let params = per_rank.swap_remove(0).1;
    let (final_val, final_test) = super::evaluate(g, &params, cfg.model.kind);
    ThreadedResult { losses, params, final_val, final_test, comm_bytes: fabric.total_bytes() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{trainer, Optimizer, PipeOpts, TrainConfig};
    use crate::graph::presets;
    use crate::model::ModelConfig;
    use crate::partition::{partition, Method};

    fn cfg(g: &Graph, variant: Variant, dropout: f32) -> TrainConfig {
        TrainConfig {
            model: ModelConfig::sage(g.feat_dim(), 16, 2, g.labels.n_classes(), dropout),
            variant,
            optimizer: Optimizer::Adam,
            lr: 0.01,
            epochs: 6,
            seed: 11,
            eval_every: 0,
            probe_errors: false,
        }
    }

    /// Threads + blocking receives must reproduce the sequential engine
    /// bit-for-bit (staleness lives in tags, not timing).
    #[test]
    fn threaded_matches_sequential_all_variants() {
        let g = presets::by_name("tiny").unwrap().build(42);
        let pt = partition(&g, 3, Method::Multilevel, 2);
        for (variant, dropout) in [
            (Variant::Vanilla, 0.0f32),
            (Variant::Pipe(PipeOpts::plain()), 0.0),
            (
                Variant::Pipe(PipeOpts { smooth_feat: true, smooth_grad: true, gamma: 0.7 }),
                0.5,
            ),
        ] {
            let c = cfg(&g, variant, dropout);
            let mut b = crate::runtime::native::NativeBackend::new();
            let seq = trainer::train(&g, &pt, &c, &mut b);
            let thr = train_threaded(&g, &pt, &c);
            for (e, (a, l)) in seq.curve.iter().zip(&thr.losses).enumerate() {
                assert!(
                    (a.train_loss - l).abs() < 1e-9,
                    "{variant:?} epoch {e}: seq {} vs threaded {l}",
                    a.train_loss
                );
            }
        }
    }

    #[test]
    fn threaded_final_metrics_reasonable() {
        let g = presets::by_name("tiny").unwrap().build(42);
        let pt = partition(&g, 2, Method::Multilevel, 3);
        let mut c = cfg(&g, Variant::Pipe(PipeOpts::plain()), 0.0);
        c.epochs = 25;
        let r = train_threaded(&g, &pt, &c);
        assert!(r.final_test > 0.5, "test {}", r.final_test);
        assert!(r.losses.last().unwrap() < &r.losses[0]);
        assert!(r.comm_bytes > 0);
    }

    /// Setup + per-epoch traffic through the threaded fabric must equal
    /// the sequential fabric's accounting — the volumes experiments
    /// report are engine-independent.
    #[test]
    fn threaded_comm_bytes_match_sequential() {
        let g = presets::by_name("tiny").unwrap().build(42);
        let pt = partition(&g, 3, Method::Multilevel, 2);
        let c = cfg(&g, Variant::Pipe(PipeOpts::plain()), 0.0);
        let mut b = crate::runtime::native::NativeBackend::new();
        let seq = trainer::train(&g, &pt, &c, &mut b);
        let thr = train_threaded(&g, &pt, &c);
        // every epoch moves the same message sizes, so the full run is
        // setup + epochs × steady-state-epoch bytes
        let seq_total = seq.setup_bytes + c.epochs as u64 * seq.comm_bytes_epoch;
        assert_eq!(thr.comm_bytes, seq_total);
    }
}
