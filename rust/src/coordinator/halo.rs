//! Halo (boundary) exchange plan — Algorithm 1 lines 1–6.
//!
//! For each partition `i` the plan materializes:
//! * the **inner** node list `V_i` and the **halo** list (remote nodes
//!   referenced by `P` rows of inner nodes), halo sorted by owner so each
//!   peer's block is contiguous;
//! * the local propagation matrix `P_i` (rows = inner, cols = inner+halo)
//!   sliced from the *global* normalization — degrees are global, exactly
//!   as in partition-parallel training (Eq. 3 uses the true d_v);
//! * the send sets `S_{i,j}` (local indices of my inner nodes that
//!   partition j's halo needs), ordered to match j's contiguous recv
//!   block;
//! * local features / labels / masks.

use crate::graph::generate::Shard;
use crate::graph::{Adj, Graph, Labels};
use crate::model::LayerKind;
use crate::partition::Partitioning;
use crate::tensor::{Csr, Mat};

/// Per-partition plan.
#[derive(Clone, Debug)]
pub struct PartPlan {
    pub part: usize,
    /// global ids of inner nodes, sorted ascending
    pub inner: Vec<u32>,
    /// global ids of halo nodes, sorted by (owner, id)
    pub halo: Vec<u32>,
    /// for each peer: the range of `halo` owned by that peer (empty ok)
    pub halo_ranges: Vec<std::ops::Range<usize>>,
    /// local propagation matrix: inner × (inner + halo)
    pub prop: Csr,
    /// for each peer j: local inner indices to send (order matches j's
    /// halo block for me)
    pub send_sets: Vec<Vec<u32>>,
    /// inner-node features (n_inner × f)
    pub features: Mat,
    /// inner-node labels
    pub labels: PlanLabels,
    /// local inner indices of train/val/test nodes
    pub train_mask: Vec<u32>,
    pub val_mask: Vec<u32>,
    pub test_mask: Vec<u32>,
}

#[derive(Clone, Debug)]
pub enum PlanLabels {
    Single(Vec<u32>),
    Multi(Mat),
}

impl PartPlan {
    pub fn n_inner(&self) -> usize {
        self.inner.len()
    }

    pub fn n_local(&self) -> usize {
        self.inner.len() + self.halo.len()
    }

    /// Gather the rows of `h_inner` listed in `send_sets[peer]` into a
    /// flat payload.
    pub fn gather_send(&self, peer: usize, h_inner: &Mat) -> Vec<f32> {
        let set = &self.send_sets[peer];
        let mut out = Vec::with_capacity(set.len() * h_inner.cols);
        for &li in set {
            out.extend_from_slice(h_inner.row(li as usize));
        }
        out
    }
}

/// The full plan plus global metadata.
#[derive(Clone, Debug)]
pub struct HaloPlan {
    pub n_parts: usize,
    pub parts: Vec<PartPlan>,
    /// total #train nodes (for loss normalization across partitions)
    pub total_train: usize,
    pub n_classes: usize,
    pub multilabel: bool,
}

/// One rank's borrowed slice of a plan — everything the per-rank
/// training loop consumes. The classic path takes it from a full
/// [`HaloPlan`] via [`HaloPlan::view`]; the scale path constructs one
/// directly around a locally built [`PartPlan`], so no rank ever holds
/// the other ranks' plans.
#[derive(Clone, Copy, Debug)]
pub struct PartView<'a> {
    pub n_parts: usize,
    /// global #train nodes (loss normalization across partitions)
    pub total_train: usize,
    pub part: &'a PartPlan,
}

impl PartView<'_> {
    /// The rank this view belongs to.
    pub fn rank(&self) -> usize {
        self.part.part
    }

    /// This rank's boundary connectivity as the schedule IR's link map:
    /// `feat_in[j]` ⇔ peer j owns part of my halo (`halo_ranges[j]`
    /// nonempty), `feat_out[j]` ⇔ peer j's halo needs my inner rows
    /// (`send_sets[j]` nonempty). The schedule generators derive every
    /// gradient/loss/ring link from these.
    pub fn comm_links(&self) -> crate::comm::schedule::RankLinks {
        let p = self.part;
        let rank = self.rank();
        let feat_in: Vec<bool> =
            (0..self.n_parts).map(|j| j != rank && !p.halo_ranges[j].is_empty()).collect();
        let feat_out: Vec<bool> =
            (0..self.n_parts).map(|j| j != rank && !p.send_sets[j].is_empty()).collect();
        crate::comm::schedule::RankLinks::new(rank, feat_in, feat_out)
    }
}

/// Boundary connectivity of **every** rank straight from topology +
/// assignment — the same nonempty-ness predicates [`build_part`]
/// materializes as `halo_ranges` / `send_sets`, without building
/// features, labels, or any plan. `pipegcn check` uses this to generate
/// schedules for paper-scale graphs from the topology-only build.
pub fn comm_links_all(
    adj: Adj<'_>,
    assign: &[u32],
    n_parts: usize,
) -> Vec<crate::comm::schedule::RankLinks> {
    assert_eq!(assign.len(), adj.n);
    // connected[i][j]: some node of part i has a neighbor owned by j —
    // exactly "halo_ranges[j] of part i is nonempty"
    let mut connected = vec![vec![false; n_parts]; n_parts];
    for v in 0..adj.n {
        let pv = assign[v] as usize;
        for &u in adj.neighbors(v) {
            let pu = assign[u as usize] as usize;
            if pu != pv {
                connected[pv][pu] = true;
            }
        }
    }
    (0..n_parts)
        .map(|r| {
            // feat_in[j] ⇔ my halo has a block owned by j; feat_out[j] ⇔
            // peer j's halo needs my inner rows (adjacency symmetry makes
            // these transposes of each other, mirroring S_{i,j} duality)
            let feat_in = (0..n_parts).map(|j| j != r && connected[r][j]).collect();
            let feat_out = (0..n_parts).map(|j| j != r && connected[j][r]).collect();
            crate::comm::schedule::RankLinks::new(r, feat_in, feat_out)
        })
        .collect()
}

/// Where a partition's node payload (features/labels/masks) comes from.
pub enum NodeSource<'a> {
    /// Slice rows out of a fully materialized graph (classic path).
    Graph(&'a Graph),
    /// Adopt the rows of a per-partition shard built by
    /// [`crate::graph::generate::sbm_shard`] with the same assignment
    /// (scale path — nothing full-graph is ever allocated).
    Shard(&'a Shard),
}

/// Build **one** partition's plan from adjacency structure + assignment,
/// without materializing the global propagation matrix or any other
/// part's plan. Weights use global degrees via the exact expressions of
/// [`Graph::propagation_matrix`] / [`Graph::mean_propagation_matrix`],
/// and send sets exploit adjacency symmetry (`S_{i,j}` = my inner nodes
/// with a neighbor in `j`, ascending — precisely peer `j`'s halo block
/// for me), so the result is bit-identical to the matching entry of
/// [`build`].
pub fn build_part(
    adj: Adj<'_>,
    assign: &[u32],
    n_parts: usize,
    part: usize,
    kind: LayerKind,
    src: &NodeSource<'_>,
) -> PartPlan {
    assert_eq!(assign.len(), adj.n);
    let i = part;
    let inner: Vec<u32> =
        (0..adj.n as u32).filter(|&v| assign[v as usize] as usize == i).collect();
    let n_inner = inner.len();
    // halo: remote neighbors of inner nodes, sorted by (owner, id)
    let mut halo: Vec<u32> = Vec::new();
    for &v in &inner {
        for &u in adj.neighbors(v as usize) {
            if assign[u as usize] as usize != i {
                halo.push(u);
            }
        }
    }
    halo.sort_unstable_by_key(|&u| ((assign[u as usize] as u64) << 32) | u as u64);
    halo.dedup();
    // owner ranges + local col index of halo nodes
    let mut halo_ranges = vec![0..0; n_parts];
    {
        let mut s = 0usize;
        while s < halo.len() {
            let owner = assign[halo[s] as usize] as usize;
            let mut e = s;
            while e < halo.len() && assign[halo[e] as usize] as usize == owner {
                e += 1;
            }
            halo_ranges[owner] = s..e;
            s = e;
        }
    }
    let mut halo_col = std::collections::HashMap::with_capacity(halo.len() * 2);
    for (hi, &u) in halo.iter().enumerate() {
        halo_col.insert(u, (n_inner + hi) as u32);
    }
    // `inner` is ascending, so local index = position by binary search
    let local_of = |v: u32| -> u32 { inner.binary_search(&v).unwrap() as u32 };
    let local_col = |u: u32| -> u32 {
        if assign[u as usize] as usize == i {
            local_of(u)
        } else {
            halo_col[&u]
        }
    };
    // local propagation matrix from **global** degrees (Eq. 3 uses the
    // true d_v). The weight expressions mirror the Graph methods
    // byte-for-byte; `Csr::from_triplets` sorts by (row, col), so the
    // emission order here is irrelevant.
    let mut trip = Vec::new();
    match kind {
        LayerKind::Gcn => {
            for (r, &v) in inner.iter().enumerate() {
                let dv = (adj.degree(v as usize) + 1) as f32;
                trip.push((r as u32, r as u32, 1.0 / dv));
                for &u in adj.neighbors(v as usize) {
                    let du = (adj.degree(u as usize) + 1) as f32;
                    trip.push((r as u32, local_col(u), 1.0 / (dv.sqrt() * du.sqrt())));
                }
            }
        }
        LayerKind::SageMean => {
            for (r, &v) in inner.iter().enumerate() {
                let inv = 1.0 / (adj.degree(v as usize) + 1) as f32;
                trip.push((r as u32, r as u32, inv));
                for &u in adj.neighbors(v as usize) {
                    trip.push((r as u32, local_col(u), inv));
                }
            }
        }
    }
    let prop = Csr::from_triplets(n_inner, n_inner + halo.len(), trip);
    // send sets: S_{i,j} = my inner nodes with ≥1 neighbor in j, in
    // ascending id order — by adjacency symmetry exactly the global ids
    // (and order) of peer j's halo block for me
    let mut send_sets: Vec<Vec<u32>> = vec![Vec::new(); n_parts];
    {
        let mut touched = vec![false; n_parts];
        let mut marks: Vec<usize> = Vec::with_capacity(8);
        for (li, &v) in inner.iter().enumerate() {
            for &u in adj.neighbors(v as usize) {
                let pu = assign[u as usize] as usize;
                if pu != i && !touched[pu] {
                    touched[pu] = true;
                    marks.push(pu);
                }
            }
            for &p in &marks {
                touched[p] = false;
                send_sets[p].push(li as u32);
            }
            marks.clear();
        }
    }
    // features / labels / masks from the node source
    let (features, labels, train_mask, val_mask, test_mask) = match src {
        NodeSource::Graph(g) => {
            assert_eq!(g.n, adj.n);
            let mut features = Mat::zeros(n_inner, g.feat_dim());
            for (r, &v) in inner.iter().enumerate() {
                features.set_row(r, g.features.row(v as usize));
            }
            let labels = match &g.labels {
                Labels::Single { labels, .. } => {
                    PlanLabels::Single(inner.iter().map(|&v| labels[v as usize]).collect())
                }
                Labels::Multi { targets } => {
                    let mut t = Mat::zeros(n_inner, targets.cols);
                    for (r, &v) in inner.iter().enumerate() {
                        t.set_row(r, targets.row(v as usize));
                    }
                    PlanLabels::Multi(t)
                }
            };
            let to_local = |mask: &[u32]| -> Vec<u32> {
                mask.iter()
                    .filter(|&&v| assign[v as usize] as usize == i)
                    .map(|&v| local_of(v))
                    .collect()
            };
            (
                features,
                labels,
                to_local(&g.train_mask),
                to_local(&g.val_mask),
                to_local(&g.test_mask),
            )
        }
        NodeSource::Shard(sh) => {
            assert_eq!(sh.n, adj.n);
            assert_eq!(
                sh.owned, inner,
                "shard ownership must match the partition assignment"
            );
            let labels = match &sh.labels {
                Labels::Single { labels, .. } => PlanLabels::Single(labels.clone()),
                Labels::Multi { targets } => PlanLabels::Multi(targets.clone()),
            };
            let to_local =
                |mask: &[u32]| -> Vec<u32> { mask.iter().map(|&v| local_of(v)).collect() };
            (
                sh.features.clone(),
                labels,
                to_local(&sh.train_mask),
                to_local(&sh.val_mask),
                to_local(&sh.test_mask),
            )
        }
    };
    PartPlan {
        part: i,
        inner,
        halo,
        halo_ranges,
        prop,
        send_sets,
        features,
        labels,
        train_mask,
        val_mask,
        test_mask,
    }
}

/// Build the plan. `kind` selects the propagation normalization:
/// GCN → symmetric `D̃^{-1/2}ÃD̃^{-1/2}`, SAGE-mean → `D̃^{-1}Ã`.
/// Assembled as one [`build_part`] per partition — the same construction
/// every scale-path rank runs for its own part alone.
pub fn build(g: &Graph, pt: &Partitioning, kind: LayerKind) -> HaloPlan {
    assert_eq!(pt.assign.len(), g.n);
    let k = pt.n_parts;
    let src = NodeSource::Graph(g);
    let parts: Vec<PartPlan> =
        (0..k).map(|i| build_part(g.adj(), &pt.assign, k, i, kind, &src)).collect();
    HaloPlan {
        n_parts: k,
        parts,
        total_train: g.train_mask.len(),
        n_classes: g.labels.n_classes(),
        multilabel: g.labels.is_multilabel(),
    }
}

impl HaloPlan {
    /// One rank's borrowed slice of this plan.
    pub fn view(&self, rank: usize) -> PartView<'_> {
        PartView { n_parts: self.n_parts, total_train: self.total_train, part: &self.parts[rank] }
    }

    /// Total boundary replicas (= per-layer communication volume in
    /// node-feature units). Matches `partition::quality`'s comm_volume.
    pub fn total_halo(&self) -> usize {
        self.parts.iter().map(|p| p.halo.len()).sum()
    }

    /// Plan invariants (tests / debug builds).
    pub fn validate(&self) -> Result<(), String> {
        for p in &self.parts {
            if p.prop.rows != p.n_inner() || p.prop.cols != p.n_local() {
                return Err(format!("part {}: prop shape", p.part));
            }
            for (j, set) in p.send_sets.iter().enumerate() {
                if j == p.part && !set.is_empty() {
                    return Err("self send set".into());
                }
                // sizes must match the peer's halo block for me
                let peer_block = self.parts[j].halo_ranges[p.part].len();
                if set.len() != peer_block {
                    return Err(format!(
                        "S_{{{},{}}} size {} != peer halo block {}",
                        p.part,
                        j,
                        set.len(),
                        peer_block
                    ));
                }
                if set.iter().any(|&li| li as usize >= p.n_inner()) {
                    return Err("send index out of range".into());
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::{sbm_dataset, SbmConfig};
    use crate::partition::{partition, Method};
    use crate::util::rng::Rng;

    fn small_graph() -> Graph {
        let mut rng = Rng::new(10);
        let cfg = SbmConfig::new(200, 4, 6.0, 1.5);
        sbm_dataset(&cfg, 8, 4, false, 0.5, &mut rng)
    }

    #[test]
    fn plan_valid_and_consistent_with_quality() {
        let g = small_graph();
        let pt = partition(&g, 4, Method::Multilevel, 1);
        let plan = build(&g, &pt, LayerKind::SageMean);
        plan.validate().unwrap();
        let q = crate::partition::quality(&g, &pt);
        assert_eq!(plan.total_halo(), q.comm_volume);
    }

    #[test]
    fn send_set_order_matches_halo_block() {
        let g = small_graph();
        let pt = partition(&g, 3, Method::Bfs, 2);
        let plan = build(&g, &pt, LayerKind::SageMean);
        plan.validate().unwrap();
        for j in 0..3 {
            for i in 0..3 {
                if i == j {
                    continue;
                }
                let block = &plan.parts[j].halo[plan.parts[j].halo_ranges[i].clone()];
                let sent: Vec<u32> = plan.parts[i].send_sets[j]
                    .iter()
                    .map(|&li| plan.parts[i].inner[li as usize])
                    .collect();
                assert_eq!(block, &sent[..], "i={i} j={j}");
            }
        }
    }

    #[test]
    fn local_prop_rows_match_global() {
        let g = small_graph();
        let pt = partition(&g, 2, Method::Multilevel, 3);
        let plan = build(&g, &pt, LayerKind::SageMean);
        let p_global = g.mean_propagation_matrix();
        // local row (weights) must be a permutation of the global row
        for part in &plan.parts {
            for (r, &v) in part.inner.iter().enumerate() {
                let mut local: Vec<f32> = part.prop.row_entries(r).map(|(_, w)| w).collect();
                let mut global: Vec<f32> =
                    p_global.row_entries(v as usize).map(|(_, w)| w).collect();
                local.sort_by(f32::total_cmp);
                global.sort_by(f32::total_cmp);
                assert_eq!(local.len(), global.len());
                for (a, b) in local.iter().zip(&global) {
                    assert!((a - b).abs() < 1e-7);
                }
            }
        }
    }

    #[test]
    fn masks_cover_all_train_nodes() {
        let g = small_graph();
        let pt = partition(&g, 4, Method::Multilevel, 4);
        let plan = build(&g, &pt, LayerKind::SageMean);
        let local_total: usize = plan.parts.iter().map(|p| p.train_mask.len()).sum();
        assert_eq!(local_total, g.train_mask.len());
        assert_eq!(plan.total_train, g.train_mask.len());
        // mapped-back ids must be exactly the global train mask
        let mut back: Vec<u32> = plan
            .parts
            .iter()
            .flat_map(|p| p.train_mask.iter().map(|&li| p.inner[li as usize]))
            .collect();
        back.sort_unstable();
        assert_eq!(back, g.train_mask);
    }

    #[test]
    fn build_part_shard_source_matches_graph_source() {
        let p = crate::graph::presets::by_name("tiny").unwrap();
        let n = 300;
        let g = p.build_scaled(n, 2);
        let pt = partition(&g, 3, Method::Multilevel, 2);
        let src_g = NodeSource::Graph(&g);
        for (kind, i) in [(LayerKind::SageMean, 0), (LayerKind::Gcn, 1), (LayerKind::SageMean, 2)]
        {
            let sh = p.build_shard_scaled(n, 2, &pt.assign, i as u32);
            let src_s = NodeSource::Shard(&sh);
            let a = build_part(g.adj(), &pt.assign, 3, i, kind, &src_g);
            let b = build_part(g.adj(), &pt.assign, 3, i, kind, &src_s);
            assert_eq!(a.inner, b.inner);
            assert_eq!(a.halo, b.halo);
            assert_eq!(a.halo_ranges, b.halo_ranges);
            assert_eq!(a.prop, b.prop);
            assert_eq!(a.features, b.features);
            assert_eq!(a.send_sets, b.send_sets);
            assert_eq!(a.train_mask, b.train_mask);
            assert_eq!(a.val_mask, b.val_mask);
            assert_eq!(a.test_mask, b.test_mask);
        }
    }

    #[test]
    fn build_part_matches_full_build_entry() {
        let g = small_graph();
        let pt = partition(&g, 3, Method::Multilevel, 7);
        let plan = build(&g, &pt, LayerKind::Gcn);
        let one = build_part(g.adj(), &pt.assign, 3, 1, LayerKind::Gcn, &NodeSource::Graph(&g));
        let reference = &plan.parts[1];
        assert_eq!(one.inner, reference.inner);
        assert_eq!(one.prop, reference.prop);
        assert_eq!(one.send_sets, reference.send_sets);
        let view = plan.view(1);
        assert_eq!(view.rank(), 1);
        assert_eq!(view.total_train, plan.total_train);
    }

    #[test]
    fn comm_links_all_matches_plan_views() {
        let g = small_graph();
        for (parts, seed) in [(2, 1), (3, 5), (4, 9)] {
            let pt = partition(&g, parts, Method::Multilevel, seed);
            let plan = build(&g, &pt, LayerKind::SageMean);
            let fast = comm_links_all(g.adj(), &pt.assign, parts);
            for r in 0..parts {
                let slow = plan.view(r).comm_links();
                assert_eq!(fast[r].rank, slow.rank);
                assert_eq!(fast[r].feat_in, slow.feat_in, "parts={parts} rank={r}");
                assert_eq!(fast[r].feat_out, slow.feat_out, "parts={parts} rank={r}");
            }
        }
    }

    #[test]
    fn gather_send_layout() {
        let g = small_graph();
        let pt = partition(&g, 2, Method::Multilevel, 5);
        let plan = build(&g, &pt, LayerKind::SageMean);
        let p0 = &plan.parts[0];
        let payload = p0.gather_send(1, &p0.features);
        assert_eq!(payload.len(), p0.send_sets[1].len() * p0.features.cols);
        // first row of the payload equals the feature row of the first
        // send-set node
        if !p0.send_sets[1].is_empty() {
            let li = p0.send_sets[1][0] as usize;
            assert_eq!(&payload[..p0.features.cols], p0.features.row(li));
        }
    }
}
