//! The sequential training engine: vanilla partition-parallel training and
//! PipeGCN (Algorithm 1) with staleness smoothing (§3.4).
//!
//! All partitions' work executes round-robin on one core, but **dataflow
//! is exactly the distributed schedule**: every boundary tensor moves
//! through the [`crate::comm::Fabric`] with an (iteration, layer, phase)
//! tag, and PipeGCN consumes tensors tagged `t−1` while vanilla consumes
//! `t` — staleness is structural, not a timing accident. The replay uses
//! the same handle API as the concurrent engines: every receive of an
//! epoch is posted up front ([`crate::comm::Fabric::post_recv`]) and
//! claimed with [`crate::comm::RecvHandle::take_now`] at its point of
//! use — the producer always ran earlier in program order, so a missing
//! message is a loud diagnostic naming the exact (src, dst, tag). The
//! threaded runner (`coordinator::threaded`) replays the same schedule
//! on real threads and must produce bit-identical parameters.
//!
//! Fidelity notes (DESIGN.md §4): global degrees in P_i, boundary
//! features zero-initialized (Alg. 1 line 6), dropout applied after
//! communication with a mask shared between fwd and bwd (Appendix F),
//! smoothing EMA on the receiver (Eq. §3.4).

use super::halo::{self, PlanLabels};
use super::state::TrainState;
use super::{EpochStat, ErrorProbe, TrainConfig, TrainResult, Variant};
use crate::ckpt;
use crate::comm::schedule::{self, Cursor, Event, Style};
use crate::comm::{decode_f64s, encode_f64s, Fabric, Phase, RecvHandle, Tag};
use std::collections::HashMap;
use crate::graph::Graph;
use crate::model::Params;
use crate::partition::Partitioning;
use crate::runtime::Backend;
use crate::sim::{LayerCompute, PartitionWork};
use crate::tensor::{ops, Mat};
use crate::util::json::{FileEmitter, Json};
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

/// Deterministic per-(iteration, partition, layer) RNG for dropout masks.
pub(crate) fn dropout_rng(seed: u64, t: usize, part: usize, layer: usize) -> Rng {
    let mix = seed
        ^ (t as u64).wrapping_mul(0x9E3779B97F4A7C15)
        ^ ((part as u64) << 40).wrapping_add(0xD1B54A32D192ED03)
        ^ ((layer as u64) << 20);
    Rng::new(mix)
}

/// Scatter a received payload (rows × cols flat) into `dst` rows `rows`,
/// adding contributions (shared with the per-rank schedule in
/// [`super::threaded`] — the f32 add order is part of the bit-identity
/// contract between engines).
pub(crate) fn scatter_add_rows(dst: &mut Mat, rows: &[u32], payload: &[f32]) {
    let cols = dst.cols;
    assert_eq!(payload.len(), rows.len() * cols, "payload shape");
    for (r, chunk) in rows.iter().zip(payload.chunks_exact(cols)) {
        let row = dst.row_mut(*r as usize);
        for (d, &s) in row.iter_mut().zip(chunk) {
            *d += s;
        }
    }
}

/// Write a received payload into contiguous rows `lo..` of `dst`.
fn write_rows(dst: &mut Mat, lo: usize, payload: &[f32]) {
    let cols = dst.cols;
    assert_eq!(payload.len() % cols, 0);
    let n = payload.len() / cols;
    dst.data[lo * cols..(lo + n) * cols].copy_from_slice(payload);
}

/// The sequential engine core (the `Engine::Sequential` adapter behind
/// [`crate::session::Session`]): optional streaming NDJSON run log, plus
/// crash-safe checkpoint/restore — snapshot every
/// rank's [`TrainState`] into `ckpt_policy.dir` every `ckpt_policy.every`
/// epochs, and/or resume from the latest complete checkpoint under
/// `resume_dir`. A resumed run reproduces the uninterrupted run
/// **bit-for-bit**: everything an epoch consumes is in the snapshots
/// (epoch counter, parameters, Adam moments, stale buffers) or is a pure
/// function of `(seed, epoch)` — dropout masks carry no state. The
/// resumed curve covers epochs `resume_epoch + 1 ..= cfg.epochs`.
pub fn train_resumable(
    g: &Graph,
    pt: &Partitioning,
    cfg: &TrainConfig,
    backend: &mut dyn Backend,
    mut log: Option<&mut FileEmitter>,
    ckpt_policy: Option<&ckpt::Policy>,
    resume_dir: Option<&str>,
) -> crate::util::error::Result<TrainResult> {
    let watch = Stopwatch::start();
    let plan = halo::build(g, pt, cfg.model.kind);
    let k = plan.n_parts;
    let n_layers = cfg.model.n_layers();
    let dims = cfg.model.dims.clone();
    let dropout = cfg.model.dropout;
    let prop_ids: Vec<usize> =
        plan.parts.iter().map(|p| backend.register_prop(&p.prop)).collect();
    backend.take_flops(); // drain any setup flops

    // one TrainState per rank — the sequential engine replicates the
    // model/optimizer exactly as real distributed ranks do, so its
    // checkpoints are the same k files a TCP mesh writes (and either
    // engine can resume the other's run)
    let mut states: Vec<TrainState> = match resume_dir {
        None => (0..k).map(|i| TrainState::init(cfg, &plan.parts[i])).collect(),
        Some(dir) => {
            let epoch = ckpt::latest_complete(dir, k)?.ok_or_else(|| {
                crate::err_msg!("--resume {dir}: no complete checkpoint for {k} ranks")
            })?;
            if epoch >= cfg.epochs {
                crate::bail!(
                    "--resume {dir}: checkpoint epoch {epoch} already covers --epochs {}",
                    cfg.epochs
                );
            }
            (0..k)
                .map(|i| {
                    TrainState::from_snapshot(ckpt::load(dir, epoch, i)?, cfg, &plan.parts[i])
                })
                .collect::<crate::util::error::Result<Vec<_>>>()?
        }
    };
    let fabric = Fabric::new(k);

    let (pipe, opts) = match cfg.variant {
        Variant::Vanilla => (false, super::PipeOpts::plain()),
        Variant::Pipe(o) => (true, o),
    };

    // pre-registered observability handles: one registry lock per series
    // here, then lock-free atomic updates on the epoch path. All of it
    // is observation-only — no effect on schedule, tags, or numerics.
    let reg = crate::obs::global();
    let per_layer = |family: &str, kind: &str| -> Vec<crate::obs::Gauge> {
        (0..n_layers)
            .map(|l| reg.gauge(family, &[("layer", &l.to_string()), ("kind", kind)]))
            .collect()
    };
    let fwd_ms: Vec<crate::obs::Histogram> = (0..n_layers)
        .map(|l| reg.histogram("layer_fwd_ms", &[("layer", &l.to_string())]))
        .collect();
    let bwd_ms: Vec<crate::obs::Histogram> = (0..n_layers)
        .map(|l| reg.histogram("layer_bwd_ms", &[("layer", &l.to_string())]))
        .collect();
    let stale_feat = per_layer("staleness_age_epochs", "feat");
    let stale_grad = per_layer("staleness_age_epochs", "grad");
    let resid_feat = per_layer("gamma_residual_norm", "feat");
    let resid_grad = per_layer("gamma_residual_norm", "grad");
    let epoch_hist = reg.histogram("epoch_ms", &[]);
    let epochs_total = reg.counter("epochs_total", &[]);

    // --- schedule IR: every (peer, tag) below comes from these -------
    let links: Vec<schedule::RankLinks> = (0..k).map(|i| plan.view(i).comm_links()).collect();
    // runtime conformance mode (debug builds, PIPEGCN_CONFORMANCE=1):
    // the fabric hooks cross-check every live operation against the
    // generated inline schedule
    let conformance = schedule::conformance_requested();
    if conformance {
        let sched = schedule::Schedule::generate(
            &links,
            Style::Inline,
            pipe,
            n_layers,
            states[0].epoch as u32 + 1,
            cfg.epochs as u32,
        )?;
        schedule::set_sink(Box::new(schedule::Conformance::new(&sched)));
    }

    // --- boundary-set exchange (Setup phase, Alg. 1 lines 1–5) --------
    // Same send/verify halves the concurrent engines run, driven in
    // two passes (all sends, then all verifies) because one thread
    // plays every rank here.
    {
        let setup_windows: Vec<schedule::Window> =
            links.iter().map(schedule::setup_window).collect();
        let mut setup_curs: Vec<Cursor<'_>> =
            setup_windows.iter().map(|w| Cursor::new(&w.events)).collect();
        for i in 0..k {
            super::threaded::setup_send(&fabric, &plan.view(i), &mut setup_curs[i]);
        }
        for i in 0..k {
            super::threaded::setup_verify(&fabric, &plan.view(i), &mut setup_curs[i]);
        }
        for cur in setup_curs {
            cur.finish();
        }
    }
    let setup_bytes = fabric.total_bytes();

    // (the stale feat/grad buffers live in each rank's TrainState)

    // --- static comm description for the simulator ---------------------
    let comm_desc = |l: usize| -> Vec<Vec<(usize, u64)>> {
        (0..k)
            .map(|i| {
                let p = &plan.parts[i];
                (0..k)
                    .filter(|&j| j != i)
                    .filter_map(|j| {
                        let send = p.send_sets[j].len();
                        let recv = p.halo_ranges[j].len();
                        if send + recv == 0 {
                            None
                        } else {
                            Some((j, ((send + recv) * dims[l] * 4) as u64))
                        }
                    })
                    .collect()
            })
            .collect()
    };
    let mut works: Vec<PartitionWork> = (0..k)
        .map(|i| PartitionWork {
            fwd: vec![LayerCompute::default(); n_layers],
            bwd: vec![LayerCompute::default(); n_layers],
            fwd_comm: (0..n_layers).map(|l| comm_desc(l).swap_remove(i)).collect(),
            bwd_comm: (0..n_layers)
                .map(|l| if l == 0 { Vec::new() } else { comm_desc(l).swap_remove(i) })
                .collect(),
        })
        .collect();

    // --- per-iteration caches ------------------------------------------
    let mut curve: Vec<EpochStat> = Vec::new();
    let mut probes: Vec<ErrorProbe> = Vec::new();
    let mut comm_bytes_epoch = 0u64;
    let mut best_val = f64::NEG_INFINITY;
    let mut best_val_test = 0.0f64;
    let mut final_val = f64::NAN;
    let mut final_test = f64::NAN;
    let mut last_grad: Vec<f32> = Vec::new();

    let start = states[0].epoch + 1;
    // steady-state epoch to instrument: the first executed epoch ≥ 2
    let work_epoch = start.max(2.min(cfg.epochs));

    for t in start..=cfg.epochs {
        let capture = t == work_epoch;
        if capture {
            fabric.reset_counters();
        }
        let epoch_watch = Stopwatch::start();
        let epoch_t0 = crate::obs::trace::now_us();
        let epoch_bytes_start = fabric.total_bytes();
        // γ-EMA residuals ‖buf − fresh‖_F accumulated over partitions
        let mut resid_feat_acc = vec![0.0f64; n_layers];
        let mut resid_grad_acc = vec![0.0f64; n_layers];
        // prefetched replay: post every receive of the epoch up front —
        // the same handle choreography the per-rank engines run, so a
        // producer that fails to send surfaces as a diagnostic naming
        // the exact (src, dst, tag), never a silent wrong payload
        let windows: Vec<schedule::Window> = links
            .iter()
            .map(|lk| schedule::epoch_window(lk, Style::Inline, pipe, n_layers, t as u32))
            .collect::<crate::util::error::Result<_>>()?;
        let mut curs: Vec<Cursor<'_>> = windows.iter().map(|w| Cursor::new(&w.events)).collect();
        let mut posted: HashMap<(usize, usize, Tag), RecvHandle> = HashMap::new();
        for (i, cur) in curs.iter_mut().enumerate() {
            for ev in cur.take_posts() {
                posted.insert((ev.peer(), i, ev.tag()), fabric.post_recv(ev.peer(), i, ev.tag()));
            }
        }
        // epoch-local probe accumulators
        let mut feat_err = vec![0.0f64; n_layers];
        let mut feat_ref = vec![0.0f64; n_layers];
        let mut grad_err = vec![0.0f64; n_layers];
        let mut grad_ref = vec![0.0f64; n_layers];
        let probing = cfg.probe_errors && pipe;

        // caches per partition per layer
        let mut h_src: Vec<Vec<Mat>> = (0..k).map(|_| Vec::with_capacity(n_layers + 1)).collect();
        for i in 0..k {
            h_src[i].push(plan.parts[i].features.clone());
        }
        let mut h_full: Vec<Vec<Mat>> = (0..k).map(|_| Vec::new()).collect();
        let mut drop_masks: Vec<Vec<Option<Mat>>> = (0..k).map(|_| Vec::new()).collect();
        let mut z_aggs: Vec<Vec<Mat>> = (0..k).map(|_| Vec::new()).collect();
        let mut pres: Vec<Vec<Mat>> = (0..k).map(|_| Vec::new()).collect();

        // ---------------- forward ----------------
        for l in 0..n_layers {
            let f_in = dims[l];
            // 1) every partition ships its boundary rows (pre-dropout)
            for i in 0..k {
                let src = &h_src[i][l];
                for ev in curs[i].take_sends(Phase::FwdFeat, l as u16) {
                    let payload = plan.parts[i].gather_send(ev.peer(), src);
                    fabric.send(i, ev.peer(), ev.tag(), payload);
                }
            }
            // 2) assemble halo + compute
            for i in 0..k {
                let p = &plan.parts[i];
                let n_halo = p.halo.len();
                let halo_mat: Mat = if !pipe {
                    let mut m = Mat::zeros(n_halo, f_in);
                    for ev in curs[i].take_claims(Phase::FwdFeat, l as u16) {
                        let range = p.halo_ranges[ev.peer()].clone();
                        let payload =
                            posted.remove(&(ev.peer(), i, ev.tag())).expect("posted").take_now();
                        write_rows(&mut m, range.start, &payload);
                    }
                    m
                } else {
                    // use the buffer (t−1 values; zeros at t=1 — Alg.1 line 6)
                    let used = states[i].feat_buf[l].clone();
                    // claim the fresh tag-t messages → buffer for t+1
                    let mut fresh = Mat::zeros(n_halo, f_in);
                    for ev in curs[i].take_claims(Phase::FwdFeat, l as u16) {
                        let range = p.halo_ranges[ev.peer()].clone();
                        let payload =
                            posted.remove(&(ev.peer(), i, ev.tag())).expect("posted").take_now();
                        write_rows(&mut fresh, range.start, &payload);
                    }
                    if probing && l > 0 {
                        feat_err[l] += used.fro_dist(&fresh).powi(2);
                        feat_ref[l] += fresh.fro_norm().powi(2);
                    }
                    if opts.smooth_feat && t > 1 {
                        // ĥ ← γ·ĥ + (1−γ)·h  (§3.4 applied to features)
                        let buf = &mut states[i].feat_buf[l];
                        resid_feat_acc[l] += buf.fro_dist(&fresh).powi(2);
                        buf.scale(opts.gamma);
                        buf.axpy(1.0 - opts.gamma, &fresh);
                    } else {
                        states[i].feat_buf[l] = fresh;
                    }
                    used
                };
                let mut assembled = h_src[i][l].vcat(&halo_mat);
                let (hf, mask) = if dropout > 0.0 {
                    let mut r = dropout_rng(cfg.seed, t, i, l);
                    let m = ops::dropout_mask(assembled.rows, assembled.cols, dropout, &mut r);
                    ops::hadamard_inplace(&mut assembled, &m);
                    (assembled, Some(m))
                } else {
                    (assembled, None)
                };
                let lp = &states[i].params.layers[l];
                let kernel_watch = Stopwatch::start();
                let kernel_t0 = crate::obs::trace::now_us();
                let out = backend.layer_fwd(prop_ids[i], &hf, lp.w_self.as_ref(), &lp.w_neigh);
                fwd_ms[l].record(kernel_watch.elapsed_secs() * 1e3);
                if crate::obs::trace::enabled() {
                    crate::obs::trace::span(i, crate::obs::trace::Kind::FwdLayer, l, t, kernel_t0);
                }
                let fc = backend.take_flops();
                if capture {
                    works[i].fwd[l] = LayerCompute { spmm_flops: fc.spmm, gemm_flops: fc.gemm };
                }
                let h_next = if l + 1 < n_layers { ops::relu(&out.pre) } else { out.pre.clone() };
                h_full[i].push(hf);
                drop_masks[i].push(mask);
                z_aggs[i].push(out.z_agg);
                pres[i].push(out.pre);
                h_src[i].push(h_next);
            }
        }

        // ---------------- loss ----------------
        let total_train = plan.total_train.max(1) as f64;
        let mut partials: Vec<f64> = Vec::with_capacity(k);
        let mut j_cur: Vec<Mat> = Vec::with_capacity(k);
        for i in 0..k {
            let p = &plan.parts[i];
            let logits = &pres[i][n_layers - 1];
            let local = p.train_mask.len() as f64;
            let (loss_i, mut grad) = match &p.labels {
                PlanLabels::Single(labels) => ops::softmax_xent(logits, labels, &p.train_mask),
                PlanLabels::Multi(targets) => ops::sigmoid_bce(logits, targets, &p.train_mask),
            };
            // rescale local-mean to global-mean semantics
            let scale = (local / total_train) as f32;
            grad.scale(scale);
            partials.push(loss_i * local / total_train);
            j_cur.push(grad);
        }
        // per-epoch loss reduction: ranks 1..k ship their partials to
        // rank 0, which sums in rank order — the same dataflow (and the
        // same f64 accumulation order) `run_rank` drives over a real
        // transport, so byte accounting and loss bits match across
        // engines. The f64↔f32-pair packing is lossless.
        for i in 1..k {
            for ev in curs[i].take_sends(Phase::Loss, 0) {
                fabric.send(i, ev.peer(), ev.tag(), encode_f64s(&[partials[i]]));
            }
        }
        let mut train_loss = partials[0];
        for ev in curs[0].take_claims(Phase::Loss, 0) {
            let payload = posted.remove(&(ev.peer(), 0, ev.tag())).expect("posted").take_now();
            train_loss += decode_f64s(&payload)[0];
        }

        // ---------------- backward ----------------
        let mut grads: Vec<Params> = (0..k).map(|i| states[i].params.zeros_like()).collect();
        for l in (0..n_layers).rev() {
            let f_in = dims[l];
            // compute layer backward + ship halo-row gradients
            let mut inner_grads: Vec<Option<Mat>> = vec![None; k];
            for i in 0..k {
                let p = &plan.parts[i];
                let mut m = j_cur[i].clone();
                if l + 1 < n_layers {
                    ops::relu_grad_inplace(&mut m, &pres[i][l]);
                }
                let lp = &states[i].params.layers[l];
                let kernel_watch = Stopwatch::start();
                let kernel_t0 = crate::obs::trace::now_us();
                let bwd = backend.layer_bwd(
                    prop_ids[i],
                    &h_full[i][l],
                    &z_aggs[i][l],
                    &m,
                    lp.w_self.as_ref(),
                    &lp.w_neigh,
                    l > 0,
                );
                bwd_ms[l].record(kernel_watch.elapsed_secs() * 1e3);
                if crate::obs::trace::enabled() {
                    crate::obs::trace::span(i, crate::obs::trace::Kind::BwdLayer, l, t, kernel_t0);
                }
                let fc = backend.take_flops();
                if capture {
                    works[i].bwd[l] = LayerCompute { spmm_flops: fc.spmm, gemm_flops: fc.gemm };
                }
                grads[i].layers[l].w_neigh = bwd.g_neigh;
                if let Some(gs) = bwd.g_self {
                    grads[i].layers[l].w_self = Some(gs);
                }
                if l > 0 {
                    let mut j_full = bwd.j_full.unwrap();
                    if let Some(mask) = &drop_masks[i][l] {
                        ops::hadamard_inplace(&mut j_full, mask);
                    }
                    // ship halo rows (offset past the inner block) to owners
                    let n_inner = p.n_inner();
                    for ev in curs[i].take_sends(Phase::BwdGrad, l as u16) {
                        let range = p.halo_ranges[ev.peer()].clone();
                        let payload = j_full.data
                            [(n_inner + range.start) * f_in..(n_inner + range.end) * f_in]
                            .to_vec();
                        fabric.send(i, ev.peer(), ev.tag(), payload);
                    }
                    inner_grads[i] = Some(j_full.rows_range(0, p.n_inner()));
                }
            }
            // accumulate boundary-gradient contributions
            if l > 0 {
                for i in 0..k {
                    let p = &plan.parts[i];
                    let mut jg = inner_grads[i].take().unwrap();
                    if !pipe {
                        for ev in curs[i].take_claims(Phase::BwdGrad, l as u16) {
                            let payload = posted
                                .remove(&(ev.peer(), i, ev.tag()))
                                .expect("posted")
                                .take_now();
                            scatter_add_rows(&mut jg, &p.send_sets[ev.peer()], &payload);
                        }
                    } else {
                        // stale contributions (zeros at t=1)
                        jg.add_assign(&states[i].grad_buf[l]);
                        // claim fresh tag-t contributions → buffer
                        let mut fresh = Mat::zeros(p.n_inner(), f_in);
                        for ev in curs[i].take_claims(Phase::BwdGrad, l as u16) {
                            let payload = posted
                                .remove(&(ev.peer(), i, ev.tag()))
                                .expect("posted")
                                .take_now();
                            scatter_add_rows(&mut fresh, &p.send_sets[ev.peer()], &payload);
                        }
                        if probing {
                            grad_err[l] += states[i].grad_buf[l].fro_dist(&fresh).powi(2);
                            grad_ref[l] += fresh.fro_norm().powi(2);
                        }
                        if opts.smooth_grad && t > 1 {
                            // δ̂ ← γ·δ̂ + (1−γ)·δ  (§3.4)
                            let buf = &mut states[i].grad_buf[l];
                            resid_grad_acc[l] += buf.fro_dist(&fresh).powi(2);
                            buf.scale(opts.gamma);
                            buf.axpy(1.0 - opts.gamma, &fresh);
                        } else {
                            states[i].grad_buf[l] = fresh;
                        }
                    }
                    j_cur[i] = jg;
                }
            }
        }

        // ---------------- all-reduce + update ----------------
        debug_assert!(posted.is_empty(), "unconsumed posted receives at epoch end");
        let mut bufs: Vec<Vec<f32>> = grads.iter().map(|gp| gp.flatten()).collect();
        let reduce_t0 = crate::obs::trace::now_us();
        let segs: Vec<&[Event]> = curs.iter_mut().map(|c| c.take_ring()).collect();
        crate::comm::allreduce::ring_allreduce_events(&fabric, &mut bufs, &segs);
        for cur in curs {
            cur.finish();
        }
        if crate::obs::trace::enabled() {
            crate::obs::trace::span(0, crate::obs::trace::Kind::Reduce, 0, t, reduce_t0);
        }
        // each rank steps its own replicated optimizer — the all-reduced
        // gradient is bit-identical everywhere, so the parameter copies
        // never diverge (Alg. 1 lines 32-33)
        for (i, st) in states.iter_mut().enumerate() {
            match cfg.optimizer {
                super::Optimizer::Adam => st.adam.step(&mut st.flat, &bufs[i]),
                super::Optimizer::Sgd => {
                    for (p, g) in st.flat.iter_mut().zip(&bufs[i]) {
                        *p -= cfg.lr * *g;
                    }
                }
            }
            st.params.unflatten(&st.flat);
            st.epoch = t;
        }
        if t == cfg.epochs {
            last_grad = std::mem::take(&mut bufs[0]);
        }
        if let Some(pol) = ckpt_policy {
            if pol.due(t) {
                for (i, st) in states.iter().enumerate() {
                    ckpt::save(&pol.dir, &st.snapshot(i, k))?;
                }
            }
        }

        if capture {
            comm_bytes_epoch = fabric.total_bytes();
        }
        let epoch_ms = epoch_watch.elapsed_secs() * 1e3;
        let epoch_comm_bytes = fabric.total_bytes() - epoch_bytes_start;
        if crate::obs::trace::enabled() {
            crate::obs::trace::span(0, crate::obs::trace::Kind::Epoch, 0, t, epoch_t0);
        }

        // per-epoch metric publication (gauges/histograms only — the
        // training numbers themselves are untouched)
        let peak_rss = crate::obs::sample_peak_rss(&reg).unwrap_or(0);
        epoch_hist.record(epoch_ms);
        epochs_total.inc();
        for l in 0..n_layers {
            // PipeGCN consumes boundary tensors from iteration t−1 (the
            // zero-init buffer at t=1 counts the same) — vanilla is
            // always fresh; layer-0 never exchanges gradients
            stale_feat[l].set(if pipe { 1.0 } else { 0.0 });
            stale_grad[l].set(if pipe && l > 0 { 1.0 } else { 0.0 });
            if opts.smooth_feat && t > 1 {
                resid_feat[l].set(resid_feat_acc[l].sqrt());
            }
            if opts.smooth_grad && t > 1 {
                resid_grad[l].set(resid_grad_acc[l].sqrt());
            }
        }

        // ---------------- eval / probes ----------------
        let do_eval = cfg.eval_every > 0 && (t % cfg.eval_every == 0 || t == cfg.epochs)
            || (cfg.eval_every == 0 && t == cfg.epochs);
        let (val, test) = if do_eval {
            let (v, te) = super::evaluate(g, &states[0].params, cfg.model.kind);
            if v > best_val {
                best_val = v;
                best_val_test = te;
            }
            final_val = v;
            final_test = te;
            (v, te)
        } else {
            (f64::NAN, f64::NAN)
        };
        curve.push(EpochStat {
            epoch: t,
            train_loss,
            val,
            test,
            epoch_ms,
            // uniform definition across engines: comp = epoch − wait;
            // the sequential engine never parks (`take_now`), so its
            // wait is structurally 0 and comp covers the whole epoch
            comp_ms: epoch_ms,
            comm_wait_ms: 0.0,
            comm_wait_by: Vec::new(),
            overlap_ratio: 1.0,
            comm_bytes: epoch_comm_bytes,
            peak_rss_bytes: peak_rss,
        });
        if let Some(emitter) = log.take() {
            let row = Json::obj()
                .set("epoch", t)
                .set("loss", train_loss)
                .set("val", val)
                .set("epoch_ms", epoch_ms)
                .set("comp_ms", epoch_ms)
                .set("comm_wait_ms", 0.0f64)
                .set("overlap_ratio", 1.0f64)
                .set("comm_wait", Json::obj())
                .set("bytes", epoch_comm_bytes)
                .set("rss", peak_rss);
            match emitter.emit(&row) {
                Ok(()) => log = Some(emitter),
                // stop logging, keep training
                Err(e) => eprintln!("run-log write failed: {e}"),
            }
        }
        if probing {
            for l in 0..n_layers {
                probes.push(ErrorProbe {
                    epoch: t,
                    layer: l,
                    feat_err: feat_err[l].sqrt(),
                    feat_ref: feat_ref[l].sqrt(),
                    grad_err: grad_err[l].sqrt(),
                    grad_ref: grad_ref[l].sqrt(),
                });
            }
        }
    }

    if conformance {
        schedule::clear_sink();
    }
    Ok(TrainResult {
        variant: cfg.variant.name(),
        curve,
        final_val,
        final_test,
        best_val_test: if best_val > f64::NEG_INFINITY { best_val_test } else { final_test },
        works,
        model_elems: states[0].flat.len(),
        comm_bytes_epoch,
        setup_bytes,
        probes,
        last_grad,
        wall_secs: watch.elapsed_secs(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{full_graph_forward, PipeOpts, Variant};
    use crate::graph::presets;
    use crate::model::ModelConfig;
    use crate::partition::{partition, Method};
    use crate::runtime::native::NativeBackend;

    fn tiny() -> Graph {
        presets::by_name("tiny").unwrap().build(42)
    }

    /// The engine core without checkpoint I/O (shadows the deprecated
    /// `train` shim these tests used to exercise).
    fn train(
        g: &Graph,
        pt: &Partitioning,
        cfg: &TrainConfig,
        backend: &mut dyn crate::runtime::Backend,
    ) -> TrainResult {
        train_resumable(g, pt, cfg, backend, None, None, None).unwrap()
    }

    fn cfg_for(g: &Graph, variant: Variant, epochs: usize, dropout: f32) -> TrainConfig {
        TrainConfig {
            model: ModelConfig::sage(g.feat_dim(), 16, 2, g.labels.n_classes(), dropout),
            variant,
            optimizer: crate::coordinator::Optimizer::Adam,
            lr: 0.01,
            epochs,
            seed: 7,
            eval_every: 0,
            probe_errors: false,
        }
    }

    /// The cornerstone: vanilla partition-parallel training must be
    /// *numerically equivalent* to full-graph training, for any partition
    /// count (no dropout so the reference is deterministic; SGD so f32
    /// reduction-order noise isn't amplified by Adam's sign-like steps).
    #[test]
    fn vanilla_matches_full_graph_reference() {
        let g = tiny();
        let mut cfg1 = cfg_for(&g, Variant::Vanilla, 4, 0.0);
        cfg1.optimizer = crate::coordinator::Optimizer::Sgd;
        cfg1.lr = 0.1;
        let p1 = partition(&g, 1, Method::Range, 0);
        let mut b1 = NativeBackend::new();
        let r1 = train(&g, &p1, &cfg1, &mut b1);
        for parts in [2, 4] {
            let pk = partition(&g, parts, Method::Multilevel, 1);
            let mut bk = NativeBackend::new();
            let rk = train(&g, &pk, &cfg1, &mut bk);
            for (a, b) in r1.curve.iter().zip(&rk.curve) {
                assert!(
                    (a.train_loss - b.train_loss).abs() < 1e-4,
                    "parts={parts} epoch {}: {} vs {}",
                    a.epoch,
                    a.train_loss,
                    b.train_loss
                );
            }
        }
    }

    /// Distributed forward (vanilla, epoch 1, pre-update) must equal the
    /// full-graph forward exactly — checked indirectly through the loss
    /// above; here check the full forward once directly.
    #[test]
    fn full_forward_consistency() {
        let g = tiny();
        let cfg = cfg_for(&g, Variant::Vanilla, 1, 0.0);
        let mut rng = Rng::new(cfg.seed);
        let params = Params::init(&cfg.model, &mut rng);
        let mut b = NativeBackend::new();
        let logits = full_graph_forward(&g, &params, cfg.model.kind, &mut b);
        assert_eq!(logits.rows, g.n);
        assert!(logits.data.iter().all(|x| x.is_finite()));
    }

    /// The all-reduced model gradient must be identical (up to f32
    /// reduction order) between full-graph and any partitioning — this is
    /// the exactness property of vanilla partition-parallel training that
    /// PipeGCN then deliberately relaxes.
    #[test]
    fn vanilla_gradient_matches_full_graph() {
        let g = tiny();
        let mut cfg1 = cfg_for(&g, Variant::Vanilla, 1, 0.0);
        cfg1.optimizer = crate::coordinator::Optimizer::Sgd;
        let p1 = partition(&g, 1, Method::Range, 0);
        let mut b1 = NativeBackend::new();
        let r1 = train(&g, &p1, &cfg1, &mut b1);
        for parts in [2, 3, 5] {
            let pk = partition(&g, parts, Method::Multilevel, 1);
            let mut bk = NativeBackend::new();
            let rk = train(&g, &pk, &cfg1, &mut bk);
            crate::util::prop::assert_close(&r1.last_grad, &rk.last_grad, 5e-3)
                .unwrap_or_else(|e| panic!("parts={parts}: {e}"));
        }
    }

    #[test]
    fn vanilla_no_message_leaks() {
        let g = tiny();
        let cfg = cfg_for(&g, Variant::Vanilla, 2, 0.5);
        let pk = partition(&g, 3, Method::Multilevel, 2);
        let mut b = NativeBackend::new();
        let _ = train(&g, &pk, &cfg, &mut b);
        // (fabric is internal; leak-freedom is implied by recv_now not
        // panicking and by the pipe test below running beyond t=1)
    }

    #[test]
    fn pipegcn_trains_and_loss_decreases() {
        let g = tiny();
        let mut cfg = cfg_for(&g, Variant::Pipe(PipeOpts::plain()), 30, 0.0);
        cfg.eval_every = 30;
        let pk = partition(&g, 4, Method::Multilevel, 3);
        let mut b = NativeBackend::new();
        let r = train(&g, &pk, &cfg, &mut b);
        let first = r.curve.first().unwrap().train_loss;
        let last = r.curve.last().unwrap().train_loss;
        assert!(last < 0.6 * first, "loss {first} -> {last}");
        assert!(r.final_test > 0.5, "test {:?}", r.final_test);
    }

    #[test]
    fn pipegcn_close_to_vanilla_accuracy() {
        let g = tiny();
        let pk = partition(&g, 4, Method::Multilevel, 3);
        let mut scores = Vec::new();
        for variant in [Variant::Vanilla, Variant::Pipe(PipeOpts::plain())] {
            let mut cfg = cfg_for(&g, variant, 40, 0.0);
            cfg.eval_every = 40;
            let mut b = NativeBackend::new();
            let r = train(&g, &pk, &cfg, &mut b);
            scores.push(r.final_test);
        }
        assert!(
            (scores[0] - scores[1]).abs() < 0.08,
            "vanilla {} vs pipegcn {}",
            scores[0],
            scores[1]
        );
    }

    #[test]
    fn deterministic_across_runs() {
        let g = tiny();
        let cfg = cfg_for(&g, Variant::Pipe(PipeOpts::plain()), 5, 0.3);
        let pk = partition(&g, 3, Method::Multilevel, 4);
        let run = || {
            let mut b = NativeBackend::new();
            train(&g, &pk, &cfg, &mut b).curve.last().unwrap().train_loss
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn probes_recorded_for_pipe_only() {
        let g = tiny();
        let pk = partition(&g, 3, Method::Multilevel, 5);
        let mut cfg = cfg_for(&g, Variant::Pipe(PipeOpts::plain()), 4, 0.0);
        cfg.probe_errors = true;
        let mut b = NativeBackend::new();
        let r = train(&g, &pk, &cfg, &mut b);
        assert_eq!(r.probes.len(), 4 * cfg.model.n_layers());
        // layer-0 feature error is structurally zero (raw features never
        // stale); gradient errors at l>0 are nonzero after warmup
        assert!(r.probes.iter().filter(|p| p.epoch > 2 && p.layer > 0).any(|p| p.grad_err > 0.0));

        let mut cfg_v = cfg_for(&g, Variant::Vanilla, 4, 0.0);
        cfg_v.probe_errors = true;
        let mut b2 = NativeBackend::new();
        let rv = train(&g, &pk, &cfg_v, &mut b2);
        assert!(rv.probes.is_empty());
    }

    /// §3.4's claim: the γ-EMA reduces staleness error. The reduction
    /// holds when gradients fluctuate around a slowly-moving mean (the
    /// paper's active-training regime) — use a small lr so per-step drift
    /// stays below the fluctuation scale, and dropout as the fluctuation
    /// source, as in the real experiments.
    #[test]
    fn smoothing_reduces_gradient_error() {
        let g = tiny();
        let pk = partition(&g, 4, Method::Multilevel, 6);
        let err_of = |variant: Variant| {
            let mut cfg = cfg_for(&g, variant, 15, 0.5);
            cfg.lr = 0.001;
            cfg.probe_errors = true;
            let mut b = NativeBackend::new();
            let r = train(&g, &pk, &cfg, &mut b);
            // mean relative grad error, post-warmup
            let v: Vec<f64> = r
                .probes
                .iter()
                .filter(|p| p.epoch > 5 && p.layer > 0 && p.grad_ref > 0.0)
                .map(|p| p.grad_err / p.grad_ref)
                .collect();
            assert!(!v.is_empty());
            v.iter().sum::<f64>() / v.len() as f64
        };
        let plain = err_of(Variant::Pipe(PipeOpts::plain()));
        let smoothed = err_of(Variant::Pipe(PipeOpts {
            smooth_feat: false,
            smooth_grad: true,
            gamma: 0.95,
        }));
        assert!(
            smoothed < plain,
            "smoothing should reduce error: plain {plain} vs smoothed {smoothed}"
        );
    }

    #[test]
    fn works_and_bytes_populated() {
        let g = tiny();
        let cfg = cfg_for(&g, Variant::Vanilla, 2, 0.0);
        let pk = partition(&g, 2, Method::Multilevel, 7);
        let mut b = NativeBackend::new();
        let r = train(&g, &pk, &cfg, &mut b);
        assert_eq!(r.works.len(), 2);
        assert!(r.works[0].fwd.iter().all(|f| f.total() > 0.0));
        assert!(r.works[0].bwd.iter().all(|f| f.total() > 0.0));
        assert!(r.comm_bytes_epoch > 0);
        assert!(r.works[0].fwd_comm[0].iter().map(|&(_, b)| b).sum::<u64>() > 0);
        assert!(r.works[0].bwd_comm[0].is_empty()); // no layer-0 grad exchange
        assert!(r.model_elems > 0);
    }

    #[test]
    fn setup_bytes_count_boundary_set_exchange() {
        let g = tiny();
        let pk = partition(&g, 3, Method::Multilevel, 2);
        let cfg = cfg_for(&g, Variant::Vanilla, 2, 0.0);
        let mut b = NativeBackend::new();
        let r = train(&g, &pk, &cfg, &mut b);
        // each halo row is requested from its owner exactly once, as one
        // u32 id = 4 bytes on the wire
        let plan = halo::build(&g, &pk, cfg.model.kind);
        assert_eq!(r.setup_bytes, 4 * plan.total_halo() as u64);
        assert!(r.setup_bytes > 0);
    }

    #[test]
    fn epoch_stats_carry_time_and_bytes() {
        let g = tiny();
        let pk = partition(&g, 2, Method::Multilevel, 1);
        let cfg = cfg_for(&g, Variant::Pipe(crate::coordinator::PipeOpts::plain()), 3, 0.0);
        let mut b = NativeBackend::new();
        let r = train(&g, &pk, &cfg, &mut b);
        for e in &r.curve {
            assert!(e.epoch_ms >= 0.0);
            assert!(e.comm_bytes > 0, "epoch {} moved no bytes", e.epoch);
        }
        // steady-state epochs move identical volumes
        assert_eq!(r.curve[1].comm_bytes, r.curve[2].comm_bytes);
    }

    #[test]
    fn ndjson_run_log_streams_per_epoch() {
        let g = tiny();
        let pk = partition(&g, 2, Method::Multilevel, 1);
        let cfg = cfg_for(&g, Variant::Vanilla, 4, 0.0);
        let path = format!("/tmp/pipegcn_runlog_test_{}.ndjson", std::process::id());
        let mut em = crate::util::json::FileEmitter::create(
            &path,
            crate::util::json::Json::obj().set("dataset", "tiny").set("parts", 2usize),
        )
        .unwrap();
        let mut b = NativeBackend::new();
        let r = train_resumable(&g, &pk, &cfg, &mut b, Some(&mut em), None, None).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let rows = crate::util::json::parse_ndjson(&text).unwrap();
        assert_eq!(rows.len(), 1 + cfg.epochs); // header + one per epoch
        assert_eq!(rows[0].get("dataset").unwrap().as_str(), Some("tiny"));
        for (i, row) in rows[1..].iter().enumerate() {
            assert_eq!(row.get("epoch").unwrap().as_usize(), Some(i + 1));
            // losses in the log are bit-identical to the curve
            assert_eq!(
                row.get("loss").unwrap().as_f64().unwrap().to_bits(),
                r.curve[i].train_loss.to_bits()
            );
            assert!(row.get("bytes").unwrap().as_f64().unwrap() > 0.0);
        }
        std::fs::remove_file(&path).ok();
    }

    /// The crash-recovery oracle: training resumed from a mid-run
    /// checkpoint must reproduce the uninterrupted run bit-for-bit —
    /// dropout, smoothing EMAs, and Adam moments included.
    #[test]
    fn resume_reproduces_uninterrupted_run_bitwise() {
        let g = tiny();
        let pk = partition(&g, 3, Method::Multilevel, 4);
        let cfg = cfg_for(
            &g,
            Variant::Pipe(PipeOpts { smooth_feat: true, smooth_grad: true, gamma: 0.9 }),
            8,
            0.3,
        );
        let dir = format!("/tmp/pipegcn_seq_ckpt_{}", std::process::id());
        let _ = std::fs::remove_dir_all(&dir);
        let policy = crate::ckpt::Policy { dir: dir.clone(), every: 3 };
        let mut b1 = NativeBackend::new();
        let full =
            train_resumable(&g, &pk, &cfg, &mut b1, None, Some(&policy), None).unwrap();
        assert_eq!(full.curve.len(), 8);
        // checkpoints landed at epochs 3 and 6, each complete for 3 ranks
        assert_eq!(crate::ckpt::latest_complete(&dir, 3).unwrap(), Some(6));
        // resume from the epoch-6 snapshot: epochs 7..8, bit-identical
        let mut b2 = NativeBackend::new();
        let resumed =
            train_resumable(&g, &pk, &cfg, &mut b2, None, None, Some(&dir)).unwrap();
        assert_eq!(resumed.curve.len(), 2);
        for (a, b) in full.curve[6..].iter().zip(&resumed.curve) {
            assert_eq!(a.epoch, b.epoch);
            assert_eq!(
                a.train_loss.to_bits(),
                b.train_loss.to_bits(),
                "epoch {}: uninterrupted {} vs resumed {}",
                a.epoch,
                a.train_loss,
                b.train_loss
            );
        }
        // a resume that would start past --epochs fails loudly
        let mut b3 = NativeBackend::new();
        let mut short = cfg.clone();
        short.epochs = 5;
        assert!(train_resumable(&g, &pk, &short, &mut b3, None, None, Some(&dir)).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn multilabel_dataset_trains() {
        let p = presets::by_name("yelp-sim").unwrap();
        let g = p.build_scaled(400, 9);
        let mut cfg = TrainConfig {
            model: ModelConfig::sage(g.feat_dim(), 16, 2, g.labels.n_classes(), 0.1),
            variant: Variant::Pipe(PipeOpts::plain()),
            optimizer: crate::coordinator::Optimizer::Adam,
            lr: 0.01,
            epochs: 15,
            seed: 3,
            eval_every: 15,
            probe_errors: false,
        };
        cfg.model.dropout = 0.1;
        let pk = partition(&g, 3, Method::Multilevel, 8);
        let mut b = NativeBackend::new();
        let r = train(&g, &pk, &cfg, &mut b);
        let first = r.curve.first().unwrap().train_loss;
        let last = r.curve.last().unwrap().train_loss;
        assert!(last < first, "bce loss {first} -> {last}");
        assert!(r.final_test > 0.0);
    }
}
