//! Per-rank training state, factored out of the engines so that the
//! sequential trainer and the transport-generic `run_rank` snapshot and
//! resume through the same [`crate::ckpt`] format.
//!
//! A [`TrainState`] is everything that evolves across epochs on one
//! rank: the replicated model/optimizer (`params`/`flat`/`adam`,
//! identical on every rank after each all-reduce) and the rank's PipeGCN
//! stale buffers. Everything else an epoch consumes is either immutable
//! (graph, partition, halo plan — deterministically rebuilt from the
//! seed) or stateless (dropout masks are a pure function of
//! `(seed, epoch, rank, layer)`), which is why restoring a `TrainState`
//! reproduces the uninterrupted run bit-for-bit.

use super::halo::PartPlan;
use super::TrainConfig;
use crate::ckpt::RankState;
use crate::model::{adam::Adam, Params};
use crate::tensor::Mat;
use crate::util::rng::Rng;

/// One rank's full cross-epoch training state.
pub struct TrainState {
    /// completed epochs (0 = fresh run)
    pub epoch: usize,
    pub params: Params,
    /// flattened view of `params` (Adam steps on this; kept in sync)
    pub flat: Vec<f32>,
    pub adam: Adam,
    /// `feat_buf[l]`: stale halo features used as layer-`l` input rows
    pub feat_buf: Vec<Mat>,
    /// `grad_buf[l]` (l ≥ 1): stale boundary-gradient contributions
    /// scattered onto this rank's inner nodes
    pub grad_buf: Vec<Mat>,
}

impl TrainState {
    /// Fresh state for one rank: seeded Glorot parameters (identical on
    /// every rank), zero Adam moments, zero stale buffers (Alg. 1 line 6).
    pub fn init(cfg: &TrainConfig, part: &PartPlan) -> TrainState {
        let mut rng = Rng::new(cfg.seed);
        let params = Params::init(&cfg.model, &mut rng);
        let flat = params.flatten();
        let adam = Adam::new(cfg.lr, flat.len());
        let n_layers = cfg.model.n_layers();
        let dims = &cfg.model.dims;
        let feat_buf = (0..n_layers).map(|l| Mat::zeros(part.halo.len(), dims[l])).collect();
        let grad_buf = (0..n_layers).map(|l| Mat::zeros(part.n_inner(), dims[l])).collect();
        TrainState { epoch: 0, params, flat, adam, feat_buf, grad_buf }
    }

    /// Snapshot as `rank` of `n_ranks` for [`crate::ckpt::save`].
    pub fn snapshot(&self, rank: usize, n_ranks: usize) -> RankState {
        let (m, v, t) = self.adam.state();
        RankState {
            rank: rank as u32,
            n_ranks: n_ranks as u32,
            epoch: self.epoch as u32,
            adam_t: t,
            flat: self.flat.clone(),
            adam_m: m.to_vec(),
            adam_v: v.to_vec(),
            feat_buf: self.feat_buf.clone(),
            grad_buf: self.grad_buf.clone(),
        }
    }

    /// Rebuild live state from a snapshot, validating every shape
    /// against the current config and halo plan so a checkpoint from a
    /// different model/dataset/partitioning fails loudly instead of
    /// silently corrupting training.
    pub fn from_snapshot(
        snap: RankState,
        cfg: &TrainConfig,
        part: &PartPlan,
    ) -> crate::util::error::Result<TrainState> {
        let mut st = TrainState::init(cfg, part);
        if snap.flat.len() != st.flat.len() {
            crate::bail!(
                "checkpoint has {} parameters, the configured model has {}",
                snap.flat.len(),
                st.flat.len()
            );
        }
        if snap.adam_m.len() != snap.flat.len() || snap.adam_v.len() != snap.flat.len() {
            crate::bail!(
                "checkpoint Adam moments ({}, {}) do not match {} parameters",
                snap.adam_m.len(),
                snap.adam_v.len(),
                snap.flat.len()
            );
        }
        for (name, have, want) in [
            ("feat_buf", &snap.feat_buf, &st.feat_buf),
            ("grad_buf", &snap.grad_buf, &st.grad_buf),
        ] {
            if have.len() != want.len() {
                crate::bail!(
                    "checkpoint has {} {name} layers, expected {}",
                    have.len(),
                    want.len()
                );
            }
            for (l, (h, w)) in have.iter().zip(want.iter()).enumerate() {
                if h.rows != w.rows || h.cols != w.cols {
                    crate::bail!(
                        "checkpoint {name}[{l}] is {}×{}, the plan expects {}×{} — \
                         was it written for a different partitioning?",
                        h.rows,
                        h.cols,
                        w.rows,
                        w.cols
                    );
                }
            }
        }
        st.epoch = snap.epoch as usize;
        st.params.unflatten(&snap.flat);
        st.flat = snap.flat;
        st.adam = Adam::restore(cfg.lr, snap.adam_m, snap.adam_v, snap.adam_t);
        st.feat_buf = snap.feat_buf;
        st.grad_buf = snap.grad_buf;
        Ok(st)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{halo, Optimizer, PipeOpts, Variant};
    use crate::graph::presets;
    use crate::model::ModelConfig;
    use crate::partition::{partition, Method};

    fn setup() -> (TrainConfig, halo::HaloPlan) {
        let g = presets::by_name("tiny").unwrap().build(42);
        let cfg = TrainConfig {
            model: ModelConfig::sage(g.feat_dim(), 16, 2, g.labels.n_classes(), 0.0),
            variant: Variant::Pipe(PipeOpts::plain()),
            optimizer: Optimizer::Adam,
            lr: 0.01,
            epochs: 4,
            seed: 7,
            eval_every: 0,
            probe_errors: false,
        };
        let pt = partition(&g, 2, Method::Multilevel, 1);
        let plan = halo::build(&g, &pt, cfg.model.kind);
        (cfg, plan)
    }

    #[test]
    fn snapshot_roundtrip_restores_identical_state() {
        let (cfg, plan) = setup();
        let mut st = TrainState::init(&cfg, &plan.parts[1]);
        st.epoch = 3;
        st.flat[0] = 0.625;
        st.params.unflatten(&st.flat);
        st.feat_buf[1].fill(2.5);
        let snap = st.snapshot(1, 2);
        let back = TrainState::from_snapshot(snap, &cfg, &plan.parts[1]).unwrap();
        assert_eq!(back.epoch, 3);
        assert_eq!(back.flat, st.flat);
        assert_eq!(back.params, st.params);
        assert_eq!(back.feat_buf, st.feat_buf);
        assert_eq!(back.grad_buf, st.grad_buf);
        assert_eq!(back.adam.state().2, st.adam.state().2);
    }

    #[test]
    fn mismatched_snapshot_rejected() {
        let (cfg, plan) = setup();
        let st = TrainState::init(&cfg, &plan.parts[0]);
        // a stale buffer shaped for a different halo is rejected
        let mut snap = st.snapshot(0, 2);
        snap.feat_buf[0] = Mat::zeros(snap.feat_buf[0].rows + 1, snap.feat_buf[0].cols);
        assert!(TrainState::from_snapshot(snap, &cfg, &plan.parts[0]).is_err());
        // and a truncated parameter vector is rejected
        let mut short = st.snapshot(0, 2);
        short.flat.pop();
        assert!(TrainState::from_snapshot(short, &cfg, &plan.parts[0]).is_err());
    }
}
