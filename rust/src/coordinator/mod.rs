//! The PipeGCN coordinator — the paper's system contribution.
//!
//! * [`halo`] — boundary-exchange plan (Alg. 1 lines 1–6).
//! * [`trainer`] — the sequential training engine implementing **vanilla
//!   partition-parallel training** (synchronous boundary exchange, paper's
//!   "GCN") and **PipeGCN** (one-iteration-stale boundary features and
//!   feature gradients, Eq. 3/4) with the §3.4 smoothing variants
//!   (-G / -F / -GF).
//! * [`threaded`] — the transport-generic per-rank schedule
//!   ([`threaded::run_rank`]), **prefetched**: every receive of an epoch
//!   is posted up front through the nonblocking
//!   [`crate::comm::Transport::post_recv`] handles and waited at its
//!   point of use, with park time attributed per (layer, phase). Runs on
//!   real threads over the in-process fabric
//!   ([`threaded::run_threaded_ctl`], the `Engine::Threaded` adapter
//!   behind [`crate::session::Session`]), or one OS process per rank
//!   over [`crate::net::TcpTransport`] (`pipegcn launch`). Numerics
//!   match the sequential engine exactly in every case.
//!
//! Numeric fidelity notes are in DESIGN.md §4.

pub mod halo;
pub mod state;
pub mod threaded;
pub mod trainer;

pub use state::TrainState;

use crate::graph::{Graph, Labels};
use crate::model::{LayerKind, ModelConfig, Params};
use crate::runtime::Backend;
use crate::sim::PartitionWork;
use crate::tensor::{ops, Mat};

/// Smoothing options for PipeGCN (§3.4). `gamma` is the decay rate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PipeOpts {
    pub smooth_feat: bool,
    pub smooth_grad: bool,
    pub gamma: f32,
}

impl PipeOpts {
    pub fn plain() -> PipeOpts {
        PipeOpts { smooth_feat: false, smooth_grad: false, gamma: 0.95 }
    }
}

/// Training variant, named as in the paper's tables.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Variant {
    /// vanilla partition-parallel training ("GCN" rows in the paper)
    Vanilla,
    /// PipeGCN and its smoothing variants
    Pipe(PipeOpts),
}

impl Variant {
    /// The accepted method names, as in the paper's tables.
    pub const NAMES: [&'static str; 5] =
        ["gcn", "pipegcn", "pipegcn-g", "pipegcn-f", "pipegcn-gf"];

    /// Parse the paper's method names: `gcn`, `pipegcn`, `pipegcn-g`,
    /// `pipegcn-f`, `pipegcn-gf`. The error carries the full list of
    /// valid values, so CLI layers can surface it verbatim.
    pub fn parse(s: &str, gamma: f32) -> Result<Variant, String> {
        let opts = |f, g| PipeOpts { smooth_feat: f, smooth_grad: g, gamma };
        match s.to_ascii_lowercase().as_str() {
            "gcn" | "vanilla" => Ok(Variant::Vanilla),
            "pipegcn" => Ok(Variant::Pipe(opts(false, false))),
            "pipegcn-g" => Ok(Variant::Pipe(opts(false, true))),
            "pipegcn-f" => Ok(Variant::Pipe(opts(true, false))),
            "pipegcn-gf" => Ok(Variant::Pipe(opts(true, true))),
            _ => Err(format!(
                "unknown method '{s}' (known: {})",
                Variant::NAMES.join(", ")
            )),
        }
    }

    pub fn name(&self) -> String {
        match self {
            Variant::Vanilla => "GCN".into(),
            Variant::Pipe(o) => match (o.smooth_feat, o.smooth_grad) {
                (false, false) => "PipeGCN".into(),
                (false, true) => "PipeGCN-G".into(),
                (true, false) => "PipeGCN-F".into(),
                (true, true) => "PipeGCN-GF".into(),
            },
        }
    }

    pub fn is_pipelined(&self) -> bool {
        matches!(self, Variant::Pipe(_))
    }
}

/// Optimizer choice (paper uses Adam; SGD is kept for the numerical
/// partition-equivalence tests, where Adam's sign-like first steps would
/// amplify benign f32 reduction-order differences).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Optimizer {
    Adam,
    Sgd,
}

/// Full training configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub model: ModelConfig,
    pub variant: Variant,
    pub optimizer: Optimizer,
    pub lr: f32,
    pub epochs: usize,
    pub seed: u64,
    /// evaluate on val/test every this many epochs (0 = only at the end)
    pub eval_every: usize,
    /// record staleness error probes (Fig. 5/7) — pipe variants only
    pub probe_errors: bool,
}

impl TrainConfig {
    /// Config from a dataset preset + variant.
    pub fn from_preset(p: &crate::graph::presets::Preset, variant: Variant) -> TrainConfig {
        TrainConfig {
            model: ModelConfig::from_preset(p),
            variant,
            optimizer: Optimizer::Adam,
            lr: p.lr,
            epochs: p.epochs,
            seed: 1,
            eval_every: 5,
            probe_errors: false,
        }
    }
}

/// Per-epoch statistics.
#[derive(Clone, Debug)]
pub struct EpochStat {
    pub epoch: usize,
    pub train_loss: f64,
    /// val metric (accuracy or micro-F1), NaN when not evaluated
    pub val: f64,
    pub test: f64,
    /// wall time of this epoch (training only, eval excluded)
    pub epoch_ms: f64,
    /// of `epoch_ms`: everything not spent blocked on a receive
    /// (`epoch_ms − comm_wait_ms`, uniformly defined in every engine)
    pub comp_ms: f64,
    /// of `epoch_ms`: time parked waiting on posted boundary/collective
    /// receives (structurally 0 in the sequential engine — `take_now`
    /// never waits; real in the threaded/TCP per-rank schedule)
    pub comm_wait_ms: f64,
    /// `comm_wait_ms` broken down per schedule point (stable keys:
    /// `fwd_l{l}` / `bwd_l{l}` / `reduce` / `setup`, values in ms
    /// summing to `comm_wait_ms`); empty where wait is structurally 0
    pub comm_wait_by: Vec<(String, f64)>,
    /// fraction of posted receives already complete when waited on
    /// (1.0 = every receive fully hidden behind compute)
    pub overlap_ratio: f64,
    /// payload bytes moved through the fabric during this epoch
    pub comm_bytes: u64,
    /// peak resident set size (`VmHWM`) sampled at the end of the epoch;
    /// 0 where procfs is unavailable
    pub peak_rss_bytes: u64,
}

/// Staleness error probe (Fig. 5/7): Frobenius norms of the gap between
/// the boundary tensor *used* and the fresh value a synchronous exchange
/// would have delivered, accumulated over partitions.
#[derive(Clone, Copy, Debug)]
pub struct ErrorProbe {
    pub epoch: usize,
    /// 0-based layer; feature errors are for layer inputs (ℓ ≥ 1 carries
    /// staleness — layer-0 inputs are the immutable raw features)
    pub layer: usize,
    pub feat_err: f64,
    pub feat_ref: f64,
    pub grad_err: f64,
    pub grad_ref: f64,
}

/// Everything a training run produces.
#[derive(Clone, Debug)]
pub struct TrainResult {
    pub variant: String,
    pub curve: Vec<EpochStat>,
    pub final_val: f64,
    pub final_test: f64,
    /// test metric at the best-val epoch (the paper's reported score)
    pub best_val_test: f64,
    /// per-partition work description of one steady-state iteration
    /// (feeds `sim::epoch_time`)
    pub works: Vec<PartitionWork>,
    pub model_elems: usize,
    /// fabric bytes moved in one steady-state epoch
    pub comm_bytes_epoch: u64,
    /// one-time Setup-phase bytes (boundary-set exchange) — counted so
    /// simulated volumes match what a real transport puts on the wire
    pub setup_bytes: u64,
    pub probes: Vec<ErrorProbe>,
    /// all-reduced model gradient of the final iteration (diagnostics /
    /// equivalence tests)
    pub last_grad: Vec<f32>,
    /// actual wall time of the run (single-core, sequential)
    pub wall_secs: f64,
}

/// Full-graph forward pass (reference semantics, no partitioning, no
/// dropout). Used for evaluation and as the correctness oracle for the
/// distributed forward. Equivalent to [`forward_with_features`] on the
/// graph's own feature matrix.
pub fn full_graph_forward(
    g: &Graph,
    params: &Params,
    kind: LayerKind,
    backend: &mut dyn Backend,
) -> Mat {
    forward_with_features(g, params, kind, backend, &g.features)
}

/// Full-graph forward over an explicit feature matrix (`g.n` × feat):
/// the serving path ([`crate::serve`]) runs queries through this so a
/// query with fresh features reuses exactly the training kernels — and a
/// query over the stored features is bit-identical to
/// [`full_graph_forward`].
pub fn forward_with_features(
    g: &Graph,
    params: &Params,
    kind: LayerKind,
    backend: &mut dyn Backend,
    features: &Mat,
) -> Mat {
    assert_eq!(features.rows, g.n, "feature matrix must cover every node");
    let prop = match kind {
        LayerKind::Gcn => g.propagation_matrix(),
        LayerKind::SageMean => g.mean_propagation_matrix(),
    };
    let pid = backend.register_prop(&prop);
    forward_registered(pid, params, backend, features)
}

/// Forward over an **already-registered** propagation matrix — the
/// serving hot path registers once per connection and runs many batches,
/// skipping the per-query O(edges) matrix build/transpose. The layer
/// loop here is the single forward implementation every entry point
/// shares, so bit-identity between training-time evaluation and served
/// logits holds by construction.
pub fn forward_registered(
    prop_id: usize,
    params: &Params,
    backend: &mut dyn Backend,
    features: &Mat,
) -> Mat {
    let mut h = features.clone();
    let n_layers = params.layers.len();
    for (l, lp) in params.layers.iter().enumerate() {
        let out = backend.layer_fwd(prop_id, &h, lp.w_self.as_ref(), &lp.w_neigh);
        h = if l + 1 < n_layers { ops::relu(&out.pre) } else { out.pre };
    }
    h
}

/// Evaluate `logits` against the graph's labels on `mask`.
pub fn score(g: &Graph, logits: &Mat, mask: &[u32]) -> f64 {
    match &g.labels {
        Labels::Single { labels, .. } => ops::accuracy(logits, labels, mask),
        Labels::Multi { targets } => ops::f1_counts(logits, targets, mask).micro_f1(),
    }
}

/// Convenience: full-graph eval on the val and test splits.
pub fn evaluate(g: &Graph, params: &Params, kind: LayerKind) -> (f64, f64) {
    let mut backend = crate::runtime::native::NativeBackend::new();
    let logits = full_graph_forward(g, params, kind, &mut backend);
    (score(g, &logits, &g.val_mask), score(g, &logits, &g.test_mask))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_parsing_roundtrip() {
        for name in Variant::NAMES {
            let v = Variant::parse(name, 0.95).unwrap();
            assert_eq!(v.name().to_ascii_lowercase(), name.replace("vanilla", "gcn"));
        }
        // the parse error names every valid method, so CLI layers can
        // surface it verbatim (satellite: no more bare "unknown variant")
        let e = Variant::parse("nope", 0.95).unwrap_err();
        for name in Variant::NAMES {
            assert!(e.contains(name), "error '{e}' misses '{name}'");
        }
    }

    #[test]
    fn pipe_flags() {
        let v = Variant::parse("pipegcn-gf", 0.5).unwrap();
        match v {
            Variant::Pipe(o) => {
                assert!(o.smooth_feat && o.smooth_grad);
                assert_eq!(o.gamma, 0.5);
            }
            _ => panic!(),
        }
        assert!(!Variant::Vanilla.is_pipelined());
        assert!(v.is_pipelined());
    }
}
