//! Persistent worker-thread pool for the compute kernels (std-only).
//!
//! PipeGCN's premise is hiding communication behind computation, which is
//! only measurable when computation actually uses the cores it owns. This
//! module is the crate's parallel substrate: a fixed set of spawned
//! worker threads fed through a mutex/condvar work queue, plus scoped
//! helpers that split work into **disjoint output-row blocks**.
//!
//! Determinism contract: every parallel kernel assigns each output
//! element exactly one owner task, and each owner computes its elements
//! in the same order as the serial kernel. The f32 summation order is
//! therefore fixed, so results are **bit-identical at any thread count**
//! — which is what lets the sequential, threaded, and TCP engines keep
//! their bit-identity guarantees while running on all cores.
//!
//! The global pool is sized by `--threads N` (CLI) or the
//! `PIPEGCN_THREADS` env var, defaulting to the machine's available
//! parallelism. [`set_threads`] rebuilds it on changes; the replaced
//! pool's workers are joined when its last in-flight user drops it.
//!
//! Tasks must not submit work to the pool themselves (one job runs at a
//! time; a nested submission from inside a task would deadlock). The
//! kernels only ever use the pool at the leaves, so this never arises.

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A lifetime-erased borrow of a submitted task as two thin pointers.
/// Sound because [`Pool::run`] blocks until every chunk has finished, so
/// the borrowed closure outlives all uses.
#[derive(Clone, Copy)]
struct RawTask {
    data: *const (),
    call: unsafe fn(*const (), usize),
}

// SAFETY: the pointee is a `Fn(usize) + Sync` closure, safe to share and
// call from any thread; the submitter keeps it alive for the job's whole
// lifetime (see `Pool::run`).
unsafe impl Send for RawTask {}

fn make_raw<F: Fn(usize) + Sync>(task: &F) -> RawTask {
    // SAFETY contract: `data` was produced from `&F` below and the
    // submitter guarantees the borrow is still live at every call.
    unsafe fn call<F: Fn(usize)>(data: *const (), chunk: usize) {
        (*(data as *const F))(chunk)
    }
    RawTask { data: task as *const F as *const (), call: call::<F> }
}

/// Execute one chunk, catching panics so a failing task cannot strand
/// the pool's bookkeeping. Returns false if the task panicked.
fn run_raw(task: RawTask, chunk: usize) -> bool {
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe {
        (task.call)(task.data, chunk)
    }))
    .is_ok()
}

struct Job {
    task: RawTask,
    n_chunks: usize,
    /// next chunk to hand out
    next: usize,
    /// chunks currently executing
    running: usize,
    /// some chunk panicked (rethrown by the submitter)
    panicked: bool,
}

impl Job {
    fn done(&self) -> bool {
        self.next >= self.n_chunks && self.running == 0
    }
}

struct State {
    job: Option<Job>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// workers: work may be available (or shutdown was requested)
    work_cv: Condvar,
    /// submitters: the job finished / the job slot freed
    done_cv: Condvar,
}

/// Fixed-size worker pool. The submitting thread participates in every
/// job, so a pool of `threads` uses exactly `threads` cores
/// (`threads - 1` spawned workers plus the caller).
pub struct Pool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
    /// spawned workers currently alive (shutdown / leak tests)
    live: Arc<AtomicUsize>,
    /// pre-registered `pool_queue_depth` gauge (jobs submitted and not
    /// yet finished — >1 means submitters are queueing for the slot)
    depth: crate::obs::Gauge,
    /// pre-registered `pool_job_ms` latency histogram
    job_hist: crate::obs::Histogram,
}

impl Pool {
    pub fn new(threads: usize) -> Pool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State { job: None, shutdown: false }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let live = Arc::new(AtomicUsize::new(0));
        let workers = (1..threads)
            .map(|_| {
                let shared = shared.clone();
                let live = live.clone();
                live.fetch_add(1, Ordering::SeqCst);
                std::thread::spawn(move || {
                    worker_loop(&shared);
                    live.fetch_sub(1, Ordering::SeqCst);
                })
            })
            .collect();
        let reg = crate::obs::global();
        Pool {
            shared,
            workers,
            threads,
            live,
            depth: reg.gauge("pool_queue_depth", &[]),
            job_hist: reg.histogram("pool_job_ms", &[]),
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Spawned workers still alive (0 once `drop` has joined them).
    pub fn live_workers(&self) -> usize {
        self.live.load(Ordering::SeqCst)
    }

    /// Run `task(chunk)` for every chunk in `0..n_chunks`, distributing
    /// chunks over the pool; blocks until every chunk has completed.
    /// One job runs at a time — concurrent submitters (the threaded
    /// engine's ranks) queue for the slot.
    pub fn run<F: Fn(usize) + Sync>(&self, n_chunks: usize, task: F) {
        if n_chunks == 0 {
            return;
        }
        let started = std::time::Instant::now();
        self.depth.add(1.0);
        if self.threads == 1 || n_chunks == 1 {
            for c in 0..n_chunks {
                task(c);
            }
            self.depth.add(-1.0);
            self.job_hist.record(started.elapsed().as_secs_f64() * 1000.0);
            return;
        }
        let raw = make_raw(&task);
        let mut g = self.shared.state.lock().unwrap();
        while g.job.is_some() {
            g = self.shared.done_cv.wait(g).unwrap();
        }
        g.job = Some(Job { task: raw, n_chunks, next: 0, running: 0, panicked: false });
        self.shared.work_cv.notify_all();
        // the submitter is a worker too
        loop {
            let job = g.job.as_mut().expect("submitted job vanished");
            if job.next < job.n_chunks {
                let c = job.next;
                job.next += 1;
                job.running += 1;
                drop(g);
                let ok = run_raw(raw, c);
                g = self.shared.state.lock().unwrap();
                let job = g.job.as_mut().expect("submitted job vanished");
                job.running -= 1;
                if !ok {
                    job.panicked = true;
                }
            } else if job.running > 0 {
                g = self.shared.done_cv.wait(g).unwrap();
            } else {
                break;
            }
        }
        let panicked = g.job.take().expect("submitted job vanished").panicked;
        // free the slot for queued submitters
        self.shared.done_cv.notify_all();
        drop(g);
        self.depth.add(-1.0);
        self.job_hist.record(started.elapsed().as_secs_f64() * 1000.0);
        if panicked {
            panic!("a pool task panicked (rethrown by the submitter)");
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        {
            let mut g = self.shared.state.lock().unwrap();
            g.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    let mut g = shared.state.lock().unwrap();
    loop {
        if g.shutdown {
            return;
        }
        let grabbed = match g.job.as_mut() {
            Some(job) if job.next < job.n_chunks => {
                let c = job.next;
                job.next += 1;
                job.running += 1;
                Some((job.task, c))
            }
            _ => None,
        };
        match grabbed {
            Some((task, c)) => {
                drop(g);
                let ok = run_raw(task, c);
                g = shared.state.lock().unwrap();
                if let Some(job) = g.job.as_mut() {
                    job.running -= 1;
                    if !ok {
                        job.panicked = true;
                    }
                    if job.done() {
                        shared.done_cv.notify_all();
                    }
                }
            }
            None => {
                g = shared.work_cv.wait(g).unwrap();
            }
        }
    }
}

// ---------------------------------------------------------------------
// Global pool
// ---------------------------------------------------------------------

static GLOBAL: Mutex<Option<Arc<Pool>>> = Mutex::new(None);

/// Threads to use when nothing was configured: `PIPEGCN_THREADS`, else
/// the machine's available parallelism.
fn default_threads() -> usize {
    if let Ok(v) = std::env::var("PIPEGCN_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// The process-wide pool the tensor/model kernels dispatch to; built on
/// first use with [`default_threads`].
pub fn global() -> Arc<Pool> {
    let mut g = GLOBAL.lock().unwrap();
    if g.is_none() {
        *g = Some(Arc::new(Pool::new(default_threads())));
    }
    g.as_ref().unwrap().clone()
}

/// Rebuild the global pool with `n` threads (`--threads N`). A no-op
/// when the pool already has that size; a replaced pool's workers are
/// joined once its last in-flight user drops its handle.
pub fn set_threads(n: usize) {
    let n = n.max(1);
    let mut g = GLOBAL.lock().unwrap();
    let rebuild = match g.as_ref() {
        Some(p) => p.threads() != n,
        None => true,
    };
    if rebuild {
        *g = Some(Arc::new(Pool::new(n)));
    }
}

/// Current global thread count (builds the pool if needed).
pub fn threads() -> usize {
    global().threads()
}

// ---------------------------------------------------------------------
// Scoped row-range helpers
// ---------------------------------------------------------------------

/// A raw pointer that may cross threads. Pool tasks use it to take
/// single-owner mutable views of **disjoint** regions of one buffer; the
/// caller is responsible for disjointness.
#[derive(Clone, Copy)]
pub struct SendPtr(pub *mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Split `0..n` into at most `parts` contiguous, balanced ranges that
/// cover `0..n` exactly.
pub fn blocks(n: usize, parts: usize) -> Vec<Range<usize>> {
    let parts = parts.clamp(1, n.max(1));
    (0..parts).map(|c| (c * n / parts)..((c + 1) * n / parts)).collect()
}

/// Run `f` over balanced, disjoint sub-ranges of `0..n` on the pool.
pub fn for_ranges(pool: &Pool, n: usize, f: impl Fn(Range<usize>) + Sync) {
    if n == 0 {
        return;
    }
    let bs = blocks(n, pool.threads());
    pool.run(bs.len(), |c| f(bs[c].clone()));
}

/// Run `f(rows, block)` over disjoint row-blocks of `data`
/// (`rows × cols`, row-major): `block` is the mutable sub-slice holding
/// rows `rows.start..rows.end`. Single-owner rows keep the per-element
/// f32 summation order independent of the thread count.
pub fn for_row_blocks(
    pool: &Pool,
    data: &mut [f32],
    cols: usize,
    f: impl Fn(Range<usize>, &mut [f32]) + Sync,
) {
    if data.is_empty() || cols == 0 {
        return;
    }
    let rows = data.len() / cols;
    debug_assert_eq!(rows * cols, data.len(), "data is not rows × cols");
    let base = SendPtr(data.as_mut_ptr());
    let bs = blocks(rows, pool.threads());
    pool.run(bs.len(), |c| {
        let r = bs[c].clone();
        // SAFETY: blocks are disjoint, so every row has one owner task.
        let block = unsafe {
            std::slice::from_raw_parts_mut(base.0.add(r.start * cols), r.len() * cols)
        };
        f(r, block);
    });
}

/// Parallel elementwise pass: `f(start, chunk)` over disjoint chunks of
/// `data`, where `chunk = &mut data[start..start + chunk.len()]`.
pub fn for_chunks(pool: &Pool, data: &mut [f32], f: impl Fn(usize, &mut [f32]) + Sync) {
    for_row_blocks(pool, data, 1, |r, chunk| f(r.start, chunk));
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn run_executes_every_chunk_once() {
        let p = Pool::new(4);
        let hits: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
        p.run(64, |c| {
            hits[c].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn pool_reuse_across_jobs() {
        let p = Pool::new(3);
        for round in 0..50 {
            let total = AtomicUsize::new(0);
            p.run(7, |c| {
                total.fetch_add(c + 1, Ordering::SeqCst);
            });
            assert_eq!(total.load(Ordering::SeqCst), 28, "round {round}");
        }
    }

    #[test]
    fn shutdown_joins_workers_no_leaks() {
        // repeated engine-style create/run/drop cycles must leave no
        // threads behind: drop() joins, and the live counter proves the
        // workers actually exited
        for _ in 0..10 {
            let p = Pool::new(4);
            assert_eq!(p.live_workers(), 3);
            let n = AtomicUsize::new(0);
            p.run(16, |_| {
                n.fetch_add(1, Ordering::SeqCst);
            });
            assert_eq!(n.load(Ordering::SeqCst), 16);
            let live = p.live.clone();
            drop(p);
            assert_eq!(live.load(Ordering::SeqCst), 0, "workers leaked past drop");
        }
    }

    #[test]
    fn concurrent_submitters_serialize_safely() {
        let p = Arc::new(Pool::new(4));
        let total = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let p = p.clone();
                let total = total.clone();
                std::thread::spawn(move || {
                    for _ in 0..20 {
                        p.run(5, |_| {
                            total.fetch_add(1, Ordering::SeqCst);
                        });
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(total.load(Ordering::SeqCst), 4 * 20 * 5);
    }

    #[test]
    fn blocks_cover_and_balance() {
        for n in [0usize, 1, 5, 64, 1000] {
            for parts in [1usize, 2, 3, 7, 64] {
                let bs = blocks(n, parts);
                let mut covered = 0;
                let mut prev_end = 0;
                for b in &bs {
                    assert_eq!(b.start, prev_end);
                    prev_end = b.end;
                    covered += b.len();
                }
                assert_eq!(covered, n, "n={n} parts={parts}");
            }
        }
    }

    #[test]
    fn for_row_blocks_gives_single_owner_rows() {
        let p = Pool::new(4);
        let mut data = vec![0.0f32; 33 * 7];
        for_row_blocks(&p, &mut data, 7, |rows, block| {
            for (bi, r) in rows.enumerate() {
                for c in 0..7 {
                    block[bi * 7 + c] = (r * 7 + c) as f32;
                }
            }
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i as f32);
        }
    }

    #[test]
    fn set_threads_rebuilds_global() {
        // the only test that touches the global pool (the others build
        // their own), so it cannot race a concurrent reconfiguration
        set_threads(3);
        assert_eq!(global().threads(), 3);
        set_threads(2);
        assert_eq!(global().threads(), 2);
        let before = Arc::as_ptr(&global());
        set_threads(2); // same size: keep the pool
        assert_eq!(Arc::as_ptr(&global()), before);
    }

    #[test]
    fn panicking_task_is_rethrown_and_pool_survives() {
        let p = Pool::new(2);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            p.run(4, |c| {
                if c == 2 {
                    panic!("boom");
                }
            });
        }));
        assert!(res.is_err());
        // the pool still works afterwards
        let n = AtomicUsize::new(0);
        p.run(3, |_| {
            n.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(n.load(Ordering::SeqCst), 3);
    }
}
