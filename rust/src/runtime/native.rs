//! Pure-Rust backend: CSR SpMM + blocked GEMM from [`crate::tensor`].
//!
//! `register_prop` pre-materializes the transpose so the backward
//! scatter (`Pᵀ·X`) runs as a gather-style SpMM (better locality than
//! scattering rows).

use super::{Backend, BwdOut, FlopCount, FwdOut};
use crate::tensor::{Csr, Mat};

struct PropPair {
    p: Csr,
    pt: Csr,
}

#[derive(Default)]
pub struct NativeBackend {
    props: Vec<PropPair>,
    flops: FlopCount,
}

impl NativeBackend {
    pub fn new() -> NativeBackend {
        NativeBackend::default()
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn register_prop(&mut self, prop: &Csr) -> usize {
        self.props.push(PropPair { p: prop.clone(), pt: prop.transpose() });
        self.props.len() - 1
    }

    fn layer_fwd(
        &mut self,
        prop: usize,
        h_full: &Mat,
        w_self: Option<&Mat>,
        w_neigh: &Mat,
    ) -> FwdOut {
        let pp = &self.props[prop];
        let inner = pp.p.rows;
        assert_eq!(h_full.rows, pp.p.cols, "h_full rows vs prop cols");
        let z_agg = pp.p.spmm(h_full);
        self.flops.spmm += 2.0 * pp.p.nnz() as f64 * h_full.cols as f64;
        let mut pre = z_agg.matmul(w_neigh);
        self.flops.gemm +=
            2.0 * (z_agg.rows * z_agg.cols * w_neigh.cols) as f64;
        if let Some(ws) = w_self {
            let h_inner = h_full.rows_range(0, inner);
            let self_term = h_inner.matmul(ws);
            self.flops.gemm += 2.0 * (inner * h_inner.cols * ws.cols) as f64;
            pre.add_assign(&self_term);
        }
        FwdOut { z_agg, pre }
    }

    fn layer_bwd(
        &mut self,
        prop: usize,
        h_full: &Mat,
        z_agg: &Mat,
        m: &Mat,
        w_self: Option<&Mat>,
        w_neigh: &Mat,
        need_input_grad: bool,
    ) -> BwdOut {
        let pp = &self.props[prop];
        let inner = pp.p.rows;
        assert_eq!(m.rows, inner);
        // weight grads
        let g_neigh = z_agg.matmul_tn(m);
        self.flops.gemm += 2.0 * (z_agg.rows * z_agg.cols * m.cols) as f64;
        let g_self = w_self.map(|ws| {
            let h_inner = h_full.rows_range(0, inner);
            let g = h_inner.matmul_tn(m);
            self.flops.gemm += 2.0 * (inner * h_inner.cols * ws.cols) as f64;
            debug_assert_eq!((g.rows, g.cols), (ws.rows, ws.cols));
            g
        });
        // input grads
        let j_full = if need_input_grad {
            let dz = m.matmul_nt(w_neigh); // inner × f_in
            self.flops.gemm += 2.0 * (m.rows * m.cols * w_neigh.rows) as f64;
            let mut j = pp.pt.spmm(&dz); // local × f_in via transpose
            self.flops.spmm += 2.0 * pp.pt.nnz() as f64 * dz.cols as f64;
            if let Some(ws) = w_self {
                let dself = m.matmul_nt(ws); // inner × f_in
                self.flops.gemm += 2.0 * (m.rows * m.cols * ws.rows) as f64;
                for r in 0..inner {
                    let dst = j.row_mut(r);
                    for (d, s) in dst.iter_mut().zip(dself.row(r)) {
                        *d += *s;
                    }
                }
            }
            Some(j)
        } else {
            None
        };
        BwdOut { g_self, g_neigh, j_full }
    }

    fn take_flops(&mut self) -> FlopCount {
        std::mem::take(&mut self.flops)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    #[test]
    fn transpose_path_matches_scatter_spmm_t() {
        let mut rng = Rng::new(7);
        let mut trip = Vec::new();
        for r in 0..8u32 {
            for c in 0..12u32 {
                if rng.bernoulli(0.3) {
                    trip.push((r, c, rng.normal()));
                }
            }
        }
        let p = Csr::from_triplets(8, 12, trip);
        let m = Mat::randn(8, 5, 1.0, &mut rng);
        let via_scatter = p.spmm_t(&m);
        let via_transpose = p.transpose().spmm(&m);
        prop::assert_close(&via_scatter.data, &via_transpose.data, 1e-4).unwrap();
    }
}
