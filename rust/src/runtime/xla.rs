//! XLA/PJRT backend: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` (JAX model + Pallas kernels, lowered once at
//! build time) and executes them on the PJRT CPU client. Python never
//! runs at training time — the artifacts directory is the only contract.
//!
//! **Feature-gated**: the PJRT client lives in the vendored `xla` crate,
//! which not every build image ships. The default build compiles a stub
//! whose constructor returns a clean error (callers already handle the
//! artifacts-missing path), so the crate stays dependency-free offline.
//! Enable with `--features xla` on images that vendor the crate (add
//! `xla = { path = "…" }` under `[dependencies]`).
//!
//! ### Padded layout contract (mirrors `python/compile/model.py`)
//!
//! Artifacts are compiled for fixed shapes `(N_PAD, L_PAD, f_in, f_out)`.
//! A partition with `n_inner ≤ N_PAD` inner and `n_halo ≤ L_PAD − N_PAD`
//! halo nodes maps into them as:
//! * `P` dense `(N_PAD, L_PAD)`: real rows at 0.., inner columns at 0..,
//!   halo columns at `N_PAD..`; zeros elsewhere.
//! * `H` `(L_PAD, f_in)`: inner rows at 0.., halo rows at `N_PAD..`.
//! Zero padding is invariant under the layer math (validated by the
//! python test `test_zero_padding_preserved`), so unpadding is a pure
//! row-slice.

#[cfg(not(feature = "xla"))]
mod imp {
    use crate::runtime::{Backend, BwdOut, FlopCount, FwdOut};
    use crate::tensor::{Csr, Mat};
    use crate::util::error::Result;

    /// Stub compiled when the `xla` feature is off: constructing it
    /// always fails with the same "artifacts unavailable" shape callers
    /// already handle, and the `Backend` methods are unreachable.
    pub struct XlaBackend {
        _private: (),
    }

    impl XlaBackend {
        pub fn from_artifacts(dir: &str) -> Result<XlaBackend> {
            Err(crate::err_msg!(
                "{dir}/manifest.json unusable: built without the `xla` feature \
                 (PJRT client unavailable; rebuild with --features xla on an \
                 image that vendors the xla crate)"
            ))
        }

        pub fn platform(&self) -> String {
            unreachable!("stub XlaBackend cannot be constructed")
        }

        pub fn pads(&self) -> (usize, usize) {
            unreachable!("stub XlaBackend cannot be constructed")
        }

        pub fn layer_configs(&self) -> Vec<(usize, usize)> {
            unreachable!("stub XlaBackend cannot be constructed")
        }
    }

    impl Backend for XlaBackend {
        fn name(&self) -> &'static str {
            "xla-stub"
        }

        fn register_prop(&mut self, _prop: &Csr) -> usize {
            unreachable!("stub XlaBackend cannot be constructed")
        }

        fn layer_fwd(
            &mut self,
            _prop: usize,
            _h_full: &Mat,
            _w_self: Option<&Mat>,
            _w_neigh: &Mat,
        ) -> FwdOut {
            unreachable!("stub XlaBackend cannot be constructed")
        }

        fn layer_bwd(
            &mut self,
            _prop: usize,
            _h_full: &Mat,
            _z_agg: &Mat,
            _m: &Mat,
            _w_self: Option<&Mat>,
            _w_neigh: &Mat,
            _need_input_grad: bool,
        ) -> BwdOut {
            unreachable!("stub XlaBackend cannot be constructed")
        }

        fn take_flops(&mut self) -> FlopCount {
            unreachable!("stub XlaBackend cannot be constructed")
        }
    }
}

#[cfg(feature = "xla")]
mod imp {
    use crate::runtime::{Backend, BwdOut, FlopCount, FwdOut};
    use crate::tensor::{Csr, Mat};
    use crate::util::error::{Context, Result};
    use crate::util::json::Json;
    use std::collections::HashMap;

    struct PaddedProp {
        /// dense padded propagation matrix as a literal-ready buffer
        dense: Vec<f32>,
        n_inner: usize,
        n_halo: usize,
        nnz: usize,
    }

    pub struct XlaBackend {
        client: xla::PjRtClient,
        n_pad: usize,
        l_pad: usize,
        fwd_execs: HashMap<(usize, usize), xla::PjRtLoadedExecutable>,
        bwd_execs: HashMap<(usize, usize), xla::PjRtLoadedExecutable>,
        props: Vec<PaddedProp>,
        flops: FlopCount,
    }

    impl XlaBackend {
        /// Load every artifact listed in `<dir>/manifest.json` and compile
        /// it on the PJRT CPU client.
        pub fn from_artifacts(dir: &str) -> Result<XlaBackend> {
            let manifest_path = format!("{dir}/manifest.json");
            let text = std::fs::read_to_string(&manifest_path)
                .with_context(|| format!("reading {manifest_path} (run `make artifacts`)"))?;
            let manifest =
                Json::parse(&text).map_err(|e| crate::err_msg!("{manifest_path}: {e}"))?;
            let n_pad = manifest
                .get("n_pad")
                .and_then(Json::as_usize)
                .context("manifest missing n_pad")?;
            let l_pad = manifest
                .get("l_pad")
                .and_then(Json::as_usize)
                .context("manifest missing l_pad")?;
            let client = xla::PjRtClient::cpu().context("creating the PJRT CPU client")?;
            let mut fwd_execs = HashMap::new();
            let mut bwd_execs = HashMap::new();
            let arts = manifest
                .get("artifacts")
                .and_then(Json::as_arr)
                .context("manifest missing artifacts")?;
            for a in arts {
                let pass =
                    a.get("pass").and_then(Json::as_str).unwrap_or_default().to_string();
                let f_in = a.get("f_in").and_then(Json::as_usize).unwrap_or(0);
                let f_out = a.get("f_out").and_then(Json::as_usize).unwrap_or(0);
                let file = a.get("file").and_then(Json::as_str).unwrap_or_default();
                let path = format!("{dir}/{file}");
                let proto = xla::HloModuleProto::from_text_file(&path)
                    .with_context(|| format!("parsing {path}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe =
                    client.compile(&comp).with_context(|| format!("compiling {path}"))?;
                match pass.as_str() {
                    "sage_fwd" => {
                        fwd_execs.insert((f_in, f_out), exe);
                    }
                    "sage_bwd" => {
                        bwd_execs.insert((f_in, f_out), exe);
                    }
                    other => crate::bail!("unknown artifact pass '{other}'"),
                }
            }
            if fwd_execs.is_empty() {
                crate::bail!("no forward artifacts in {dir}");
            }
            Ok(XlaBackend {
                client,
                n_pad,
                l_pad,
                fwd_execs,
                bwd_execs,
                props: Vec::new(),
                flops: FlopCount::default(),
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        pub fn pads(&self) -> (usize, usize) {
            (self.n_pad, self.l_pad)
        }

        pub fn layer_configs(&self) -> Vec<(usize, usize)> {
            let mut v: Vec<(usize, usize)> = self.fwd_execs.keys().cloned().collect();
            v.sort_unstable();
            v
        }

        /// Pack a partition-local matrix (rows = inner then halo) into the
        /// padded row layout.
        fn pad_h(&self, h: &Mat, n_inner: usize) -> Vec<f32> {
            let cols = h.cols;
            let mut out = vec![0.0f32; self.l_pad * cols];
            let n_halo = h.rows - n_inner;
            out[..n_inner * cols].copy_from_slice(&h.data[..n_inner * cols]);
            out[self.n_pad * cols..(self.n_pad + n_halo) * cols]
                .copy_from_slice(&h.data[n_inner * cols..]);
            out
        }

        /// Slice a padded (L_PAD × cols) buffer back to the packed local
        /// layout (n_inner + n_halo rows).
        fn unpad_local(&self, data: &[f32], cols: usize, n_inner: usize, n_halo: usize) -> Mat {
            let mut out = Mat::zeros(n_inner + n_halo, cols);
            out.data[..n_inner * cols].copy_from_slice(&data[..n_inner * cols]);
            out.data[n_inner * cols..]
                .copy_from_slice(&data[self.n_pad * cols..(self.n_pad + n_halo) * cols]);
            out
        }

        fn lit(data: &[f32], rows: usize, cols: usize) -> xla::Literal {
            xla::Literal::vec1(data)
                .reshape(&[rows as i64, cols as i64])
                .expect("literal reshape")
        }

        fn run(
            exe: &xla::PjRtLoadedExecutable,
            inputs: &[xla::Literal],
        ) -> Result<Vec<xla::Literal>> {
            let result = exe
                .execute::<xla::Literal>(inputs)
                .context("executing artifact")?[0][0]
                .to_literal_sync()
                .context("fetching result literal")?;
            result.to_tuple().context("untupling result")
        }

        /// `w_self = None` (GCN layer) is emulated with a zero self-weight
        /// — artifacts are compiled for the SAGE signature.
        fn self_or_zero(w_self: Option<&Mat>, w_neigh: &Mat) -> Mat {
            w_self.cloned().unwrap_or_else(|| Mat::zeros(w_neigh.rows, w_neigh.cols))
        }
    }

    impl Backend for XlaBackend {
        fn name(&self) -> &'static str {
            "xla"
        }

        fn register_prop(&mut self, prop: &Csr) -> usize {
            let n_inner = prop.rows;
            let n_halo = prop.cols - prop.rows;
            assert!(
                n_inner <= self.n_pad && n_halo <= self.l_pad - self.n_pad,
                "partition ({n_inner} inner, {n_halo} halo) exceeds artifact padding \
                 ({}, {}) — regenerate artifacts with larger N_PAD/L_PAD",
                self.n_pad,
                self.l_pad
            );
            let mut dense = vec![0.0f32; self.n_pad * self.l_pad];
            for r in 0..n_inner {
                for (c, v) in prop.row_entries(r) {
                    let col = if c < n_inner { c } else { self.n_pad + (c - n_inner) };
                    dense[r * self.l_pad + col] = v;
                }
            }
            self.props.push(PaddedProp { dense, n_inner, n_halo, nnz: prop.nnz() });
            self.props.len() - 1
        }

        fn layer_fwd(
            &mut self,
            prop: usize,
            h_full: &Mat,
            w_self: Option<&Mat>,
            w_neigh: &Mat,
        ) -> FwdOut {
            let (n_inner, n_halo, nnz) = {
                let p = &self.props[prop];
                (p.n_inner, p.n_halo, p.nnz)
            };
            let f_in = h_full.cols;
            let f_out = w_neigh.cols;
            let h_pad = self.pad_h(h_full, n_inner);
            let ws = Self::self_or_zero(w_self, w_neigh);
            let p_lit = Self::lit(&self.props[prop].dense, self.n_pad, self.l_pad);
            let h_lit = Self::lit(&h_pad, self.l_pad, f_in);
            let wn_lit = Self::lit(&w_neigh.data, f_in, f_out);
            let ws_lit = Self::lit(&ws.data, f_in, f_out);
            let exe = self
                .fwd_execs
                .get(&(f_in, f_out))
                .unwrap_or_else(|| panic!("no sage_fwd artifact for ({f_in},{f_out})"));
            let outs = Self::run(exe, &[p_lit, h_lit, wn_lit, ws_lit]).expect("xla fwd");
            let z_pad = outs[0].to_vec::<f32>().expect("z literal");
            let pre_pad = outs[1].to_vec::<f32>().expect("pre literal");
            let _ = n_halo;
            let z_agg = Mat::from_vec(n_inner, f_in, z_pad[..n_inner * f_in].to_vec());
            let pre = Mat::from_vec(n_inner, f_out, pre_pad[..n_inner * f_out].to_vec());
            self.flops.spmm += 2.0 * nnz as f64 * f_in as f64;
            self.flops.gemm += 2.0 * (n_inner * f_in * f_out * 2) as f64;
            FwdOut { z_agg, pre }
        }

        fn layer_bwd(
            &mut self,
            prop: usize,
            h_full: &Mat,
            z_agg: &Mat,
            m: &Mat,
            w_self: Option<&Mat>,
            w_neigh: &Mat,
            need_input_grad: bool,
        ) -> BwdOut {
            let (n_inner, n_halo, nnz) = {
                let p = &self.props[prop];
                (p.n_inner, p.n_halo, p.nnz)
            };
            let f_in = h_full.cols;
            let f_out = w_neigh.cols;
            // pad inputs
            let h_pad = self.pad_h(h_full, n_inner);
            let mut z_pad = vec![0.0f32; self.n_pad * f_in];
            z_pad[..n_inner * f_in].copy_from_slice(&z_agg.data);
            let mut m_pad = vec![0.0f32; self.n_pad * f_out];
            m_pad[..n_inner * f_out].copy_from_slice(&m.data);
            let ws = Self::self_or_zero(w_self, w_neigh);
            let inputs = [
                Self::lit(&self.props[prop].dense, self.n_pad, self.l_pad),
                Self::lit(&h_pad, self.l_pad, f_in),
                Self::lit(&z_pad, self.n_pad, f_in),
                Self::lit(&m_pad, self.n_pad, f_out),
                Self::lit(&w_neigh.data, f_in, f_out),
                Self::lit(&ws.data, f_in, f_out),
            ];
            let exe = self
                .bwd_execs
                .get(&(f_in, f_out))
                .unwrap_or_else(|| panic!("no sage_bwd artifact for ({f_in},{f_out})"));
            let outs = Self::run(exe, &inputs).expect("xla bwd");
            let g_neigh =
                Mat::from_vec(f_in, f_out, outs[0].to_vec::<f32>().expect("g_neigh"));
            let g_self_mat =
                Mat::from_vec(f_in, f_out, outs[1].to_vec::<f32>().expect("g_self"));
            let j_full = if need_input_grad {
                let j_pad = outs[2].to_vec::<f32>().expect("j_full");
                Some(self.unpad_local(&j_pad, f_in, n_inner, n_halo))
            } else {
                None
            };
            self.flops.spmm += 2.0 * nnz as f64 * f_in as f64;
            self.flops.gemm += 2.0 * (n_inner * f_in * f_out * 4) as f64;
            BwdOut {
                g_self: w_self.map(|_| g_self_mat),
                g_neigh,
                j_full,
            }
        }

        fn take_flops(&mut self) -> FlopCount {
            std::mem::take(&mut self.flops)
        }
    }
}

pub use imp::XlaBackend;
