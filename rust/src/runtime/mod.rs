//! Execution backends for the per-partition layer math.
//!
//! The coordinator is backend-agnostic: [`Backend`] exposes the two
//! heavy primitives of a GraphSAGE/GCN layer (forward aggregate+transform
//! and its backward), plus FLOP accounting for the timeline simulator.
//!
//! * [`pool`] — the persistent worker-thread pool every hot-path kernel
//!   dispatches to (std-only: spawned threads + a mutex/condvar work
//!   queue). Parallelism is over disjoint output-row blocks, so each
//!   output element has a single owner and a fixed f32 summation order:
//!   results are bit-identical at any `--threads` count.
//! * [`native`] — pure Rust: CSR SpMM + blocked GEMM from [`crate::tensor`].
//!   Works for any shape; used by the large experiments.
//! * [`xla`] — loads the AOT HLO-text artifacts compiled by
//!   `python/compile/aot.py` (JAX + Pallas kernels) and executes them on
//!   the PJRT CPU client. Fixed shapes per artifact; used by the
//!   end-to-end quickstart and the parity tests. Gated behind the `xla`
//!   cargo feature (the default build ships a clean-erroring stub so the
//!   crate stays dependency-free).

pub mod native;
pub mod pool;
pub mod xla;

use crate::tensor::{Csr, Mat};

/// Forward products of one layer on one partition.
pub struct FwdOut {
    /// aggregated neighborhood features `P·H_full` (inner × f_in)
    pub z_agg: Mat,
    /// pre-activation `H_inner·W_self + z_agg·W_neigh` (inner × f_out)
    pub pre: Mat,
}

/// Backward products of one layer on one partition.
pub struct BwdOut {
    /// gradient w.r.t. `w_self` (None for GCN layers)
    pub g_self: Option<Mat>,
    /// gradient w.r.t. `w_neigh`
    pub g_neigh: Mat,
    /// gradient w.r.t. the layer's full local input H (local_rows × f_in);
    /// halo rows are the boundary contributions shipped to owners.
    /// `None` when the caller passed `need_input_grad = false` (layer 0).
    pub j_full: Option<Mat>,
}

/// FLOPs executed since the last [`Backend::take_flops`] call.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FlopCount {
    pub spmm: f64,
    pub gemm: f64,
}

impl FlopCount {
    pub fn total(&self) -> f64 {
        self.spmm + self.gemm
    }
}

/// A compute backend for partition-local layer math.
///
/// `register_prop` hands the backend the partition's local propagation
/// matrix (rows = inner nodes, cols = inner + halo) once; the returned
/// id is passed to every subsequent call so backends can cache derived
/// forms (transposes, dense copies, compiled executables).
pub trait Backend {
    fn name(&self) -> &'static str;

    fn register_prop(&mut self, prop: &Csr) -> usize;

    /// `z_agg = P·h_full`; `pre = h_inner·w_self + z_agg·w_neigh`
    /// (`w_self = None` ⇒ the self term is skipped — GCN layer).
    fn layer_fwd(
        &mut self,
        prop: usize,
        h_full: &Mat,
        w_self: Option<&Mat>,
        w_neigh: &Mat,
    ) -> FwdOut;

    /// Backward of [`layer_fwd`] given `m = ∂L/∂pre` (σ′ already applied
    /// by the caller):
    /// * `g_self  = h_innerᵀ · m`
    /// * `g_neigh = z_aggᵀ · m`
    /// * `j_full  = Pᵀ·(m·w_neighᵀ) + pad_inner(m·w_selfᵀ)` — skipped when
    ///   `need_input_grad` is false (first layer: inputs are leaf data).
    fn layer_bwd(
        &mut self,
        prop: usize,
        h_full: &Mat,
        z_agg: &Mat,
        m: &Mat,
        w_self: Option<&Mat>,
        w_neigh: &Mat,
        need_input_grad: bool,
    ) -> BwdOut;

    /// Drain the FLOP counters (for `sim::PartitionWork` assembly).
    fn take_flops(&mut self) -> FlopCount;
}

#[cfg(test)]
mod tests {
    use super::native::NativeBackend;
    use super::*;
    use crate::tensor::ops;
    use crate::util::{prop, rng::Rng};

    fn random_prop(rng: &mut Rng, rows: usize, cols: usize) -> Csr {
        let mut trip = Vec::new();
        for r in 0..rows {
            trip.push((r as u32, r as u32, 0.5)); // self
            for c in 0..cols {
                if rng.bernoulli(0.25) {
                    trip.push((r as u32, c as u32, rng.next_f32()));
                }
            }
        }
        Csr::from_triplets(rows, cols, trip)
    }

    /// End-to-end gradient check of layer_fwd/layer_bwd through a ReLU +
    /// quadratic loss, against central finite differences.
    #[test]
    fn native_layer_grad_matches_finite_difference() {
        prop::check("layer fd", 3, |rng| {
            let inner = 4;
            let cols = 6;
            let (fi, fo) = (3, 2);
            let p = random_prop(rng, inner, cols);
            let h = Mat::randn(cols, fi, 1.0, rng);
            let w_self = Mat::randn(fi, fo, 0.5, rng);
            let w_neigh = Mat::randn(fi, fo, 0.5, rng);

            // loss = 0.5 * Σ relu(pre)^2
            let loss = |ws: &Mat, wn: &Mat, hh: &Mat| -> f64 {
                let mut b = NativeBackend::new();
                let pid = b.register_prop(&p);
                let out = b.layer_fwd(pid, hh, Some(ws), wn);
                let a = ops::relu(&out.pre);
                0.5 * a.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()
            };

            let mut b = NativeBackend::new();
            let pid = b.register_prop(&p);
            let out = b.layer_fwd(pid, &h, Some(&w_self), &w_neigh);
            let act = ops::relu(&out.pre);
            let mut m = act.clone(); // dL/da = a ; dL/dpre = a ∘ relu'
            ops::relu_grad_inplace(&mut m, &out.pre);
            let bwd = b.layer_bwd(pid, &h, &out.z_agg, &m, Some(&w_self), &w_neigh, true);

            let eps = 1e-2f32;
            // check a few entries of each gradient
            let j_full = bwd.j_full.as_ref().unwrap();
            for (mat, grad, tag) in [
                (&w_self, bwd.g_self.as_ref().unwrap(), "w_self"),
                (&w_neigh, &bwd.g_neigh, "w_neigh"),
                (&h, j_full, "h"),
            ] {
                for probe in 0..4 {
                    let idx = (probe * 7 + 3) % mat.data.len();
                    let mut mp = (*mat).clone();
                    mp.data[idx] += eps;
                    let mut mm = (*mat).clone();
                    mm.data[idx] -= eps;
                    let (fp_, fm) = match tag {
                        "w_self" => (loss(&mp, &w_neigh, &h), loss(&mm, &w_neigh, &h)),
                        "w_neigh" => (loss(&w_self, &mp, &h), loss(&w_self, &mm, &h)),
                        _ => (loss(&w_self, &w_neigh, &mp), loss(&w_self, &w_neigh, &mm)),
                    };
                    let fd = ((fp_ - fm) / (2.0 * eps as f64)) as f32;
                    let an = grad.data[idx];
                    crate::prop_assert!(
                        (fd - an).abs() < 0.05 * (1.0 + fd.abs().max(an.abs())),
                        "{tag}[{idx}]: fd {fd} vs analytic {an}"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn gcn_mode_skips_self_term() {
        let mut rng = Rng::new(1);
        let p = random_prop(&mut rng, 3, 5);
        let h = Mat::randn(5, 4, 1.0, &mut rng);
        let w_neigh = Mat::randn(4, 2, 0.5, &mut rng);
        let mut b = NativeBackend::new();
        let pid = b.register_prop(&p);
        let out = b.layer_fwd(pid, &h, None, &w_neigh);
        let want = p.spmm(&h).matmul(&w_neigh);
        prop::assert_close(&out.pre.data, &want.data, 1e-4).unwrap();
        let m = Mat::randn(3, 2, 1.0, &mut rng);
        let bwd = b.layer_bwd(pid, &h, &out.z_agg, &m, None, &w_neigh, true);
        assert!(bwd.g_self.is_none());
        // j_full = Pᵀ (m Wᵀ)
        let want_j = p.spmm_t(&m.matmul_nt(&w_neigh));
        prop::assert_close(&bwd.j_full.unwrap().data, &want_j.data, 1e-4).unwrap();
        // need_input_grad=false skips j_full
        let bwd2 = b.layer_bwd(pid, &h, &out.z_agg, &m, None, &w_neigh, false);
        assert!(bwd2.j_full.is_none());
    }

    #[test]
    fn flop_accounting_nonzero_and_drains() {
        let mut rng = Rng::new(2);
        let p = random_prop(&mut rng, 4, 6);
        let h = Mat::randn(6, 3, 1.0, &mut rng);
        let w = Mat::randn(3, 2, 1.0, &mut rng);
        let mut b = NativeBackend::new();
        let pid = b.register_prop(&p);
        let _ = b.layer_fwd(pid, &h, None, &w);
        let f1 = b.take_flops();
        assert!(f1.spmm > 0.0 && f1.gemm > 0.0);
        let f2 = b.take_flops();
        assert_eq!(f2, FlopCount::default());
    }
}
