//! [`TcpTransport`] — the [`Transport`] contract over real TCP sockets,
//! one instance per OS process (one rank each).
//!
//! Topology: every **ordered** pair (src → dst) gets a dedicated socket.
//! Each rank dials every peer (that socket carries only my → peer data,
//! fed by a per-peer **writer thread**, so sends are pipelined and never
//! block the compute path) and accepts one inbound socket per peer (a
//! **reader thread** per socket demuxes frames straight into posted
//! receives: a [`TcpTransport::post_recv`] handle is fulfilled by the
//! reader the moment its frame arrives — while the rank is inside a
//! GEMM — and frames nobody has posted for yet land in per-(src, tag)
//! FIFO queues).
//!
//! Payloads above the 64 MiB frame cap are split into
//! [`Frame::DataChunk`]s on send and reassembled per (src, tag) by the
//! reader thread before delivery, so callers never see the cap.
//!
//! Graceful teardown: [`TcpTransport::shutdown`] flushes a
//! [`Frame::Shutdown`] on every outbound socket and joins the writer
//! threads; reader threads exit when the matching peer's shutdown frame
//! (or a clean EOF) arrives.

use super::chaos::{ChaosProfile, LinkInjector};
use super::frame::{self, Frame};
use crate::comm::{self, RecvHandle, Tag, Transport};
use crate::obs;
use std::collections::HashMap;
use std::collections::VecDeque;
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Default receive watchdog: give up on a blocking receive after this
/// long without the wanted message — a wiring bug should abort with a
/// diagnostic, not hang CI. `--recv-deadline` (or a chaos profile's
/// `recv_deadline_ms`) overrides it per transport.
pub const RECV_DEADLINE: Duration = Duration::from_secs(300);
const WAIT_SLICE: Duration = Duration::from_secs(5);

/// Lock that tolerates a poisoned mutex. A receive watchdog or
/// dead-peer check panics *while holding* the inbox lock (unwinding
/// poisons it); sibling handles are then dropped during that unwind,
/// and their `Drop` must still be able to lock — an `unwrap()` there
/// would panic-in-drop and abort the whole process, killing a rank that
/// the elastic launcher could otherwise replace. Safe because every
/// critical section here leaves the state consistent before any code
/// path that can panic.
fn plock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

enum Out {
    Data(Tag, Vec<f32>),
    /// one slice of an oversized payload (`true` = final chunk)
    Chunk(Tag, Vec<f32>, bool),
    Shutdown,
}

/// Unbounded handoff queue from the compute path to one writer thread.
struct SendQueue {
    q: Mutex<VecDeque<Out>>,
    cv: Condvar,
}

impl SendQueue {
    fn new() -> SendQueue {
        SendQueue { q: Mutex::new(VecDeque::new()), cv: Condvar::new() }
    }

    fn push(&self, msg: Out) {
        plock(&self.q).push_back(msg);
        self.cv.notify_one();
    }

    fn pop_blocking(&self) -> Out {
        let mut g = plock(&self.q);
        loop {
            if let Some(m) = g.pop_front() {
                return m;
            }
            g = self.cv.wait(g).unwrap_or_else(|p| p.into_inner());
        }
    }

    fn is_empty(&self) -> bool {
        plock(&self.q).is_empty()
    }
}

#[derive(Default)]
struct InboxState {
    /// sequence-stamped FIFO per (src, tag) — mirrors the Fabric's
    /// (pair, tag) queues with the dst fixed to the owning rank.
    queues: HashMap<(u32, Tag), VecDeque<comm::Queued>>,
    /// posted-but-unfulfilled receives, FIFO per (src, tag) — the reader
    /// threads fulfill the oldest live reservation before queueing
    reservations: HashMap<(u32, Tag), VecDeque<comm::SlotRef>>,
    /// delivery sequence counter (stamps every delivered message)
    seq: u64,
    /// peers whose stream ended (shutdown frame or EOF)
    closed: std::collections::HashSet<usize>,
    /// reader-thread failures, surfaced on the next receive
    errors: Vec<String>,
}

impl InboxState {
    /// Hand a complete message to the oldest live reservation for
    /// (src, tag), or queue it. Runs on the reader threads, so a posted
    /// receive completes while the owning rank is free to compute.
    fn deliver(&mut self, src: u32, tag: Tag, payload: Vec<f32>) {
        self.seq += 1;
        let mut item = Some((self.seq, payload));
        if let Some(q) = self.reservations.get_mut(&(src, tag)) {
            let (s, p) = item.take().unwrap();
            item = comm::offer(q, s, p);
            // tags are epoch-unique: emptied per-tag entries must go,
            // or long runs leak one dead entry per receive
            if q.is_empty() {
                self.reservations.remove(&(src, tag));
            }
        }
        if let Some((s, p)) = item {
            self.queues.entry((src, tag)).or_default().push_back((s, p));
        }
    }

    /// Pop the oldest queued (src, tag) message, pruning the emptied
    /// per-tag entry (epoch-unique tags never get reused).
    fn pop_queued(&mut self, src: u32, tag: Tag) -> Option<comm::Queued> {
        let q = self.queues.get_mut(&(src, tag))?;
        let p = q.pop_front();
        if q.is_empty() {
            self.queues.remove(&(src, tag));
        }
        p
    }
}

struct Inbox {
    state: Mutex<InboxState>,
    cv: Condvar,
}

/// [`comm::RecvFuture`] fulfilled by this transport's reader threads.
struct TcpRecv {
    inbox: Arc<Inbox>,
    rank: usize,
    src: usize,
    tag: Tag,
    slot: comm::SlotRef,
    /// watchdog: fail a parked wait after this long
    deadline: Duration,
}

impl comm::RecvFuture for TcpRecv {
    fn try_take(&mut self) -> Option<Vec<f32>> {
        comm::take_ready(&self.slot)
    }

    fn wait_take(&mut self) -> Vec<f32> {
        let started = Instant::now();
        let mut g = plock(&self.inbox.state);
        loop {
            if let Some(v) = comm::take_ready(&self.slot) {
                return v;
            }
            // release the inbox before panicking: sibling handles are
            // dropped during the unwind and must not find it poisoned
            if !g.errors.is_empty() {
                let errs = g.errors.join("; ");
                drop(g);
                panic!("[rank {}] transport failed: {errs}", self.rank);
            }
            // fail fast the moment the specific peer we need is gone —
            // don't sit out the deadline while other peers are healthy
            if g.closed.contains(&self.src) {
                drop(g);
                panic!(
                    "[rank {}] peer {} closed while a message for {}->{} {:?} \
                     was still awaited",
                    self.rank, self.src, self.src, self.rank, self.tag
                );
            }
            let elapsed = started.elapsed();
            if elapsed > self.deadline {
                drop(g);
                panic!(
                    "[rank {}] recv timeout waiting for {}->{} {:?} after {} ms \
                     (deadline {} ms — raise --recv-deadline if the network is \
                     just slow)",
                    self.rank,
                    self.src,
                    self.rank,
                    self.tag,
                    elapsed.as_millis(),
                    self.deadline.as_millis()
                );
            }
            // short slices keep a tight deadline responsive
            let slice = WAIT_SLICE.min(self.deadline.saturating_sub(elapsed).max(Duration::from_millis(1)));
            let (guard, _timeout) =
                self.inbox.cv.wait_timeout(g, slice).unwrap_or_else(|p| p.into_inner());
            g = guard;
        }
    }
}

impl Drop for TcpRecv {
    fn drop(&mut self) {
        // lock order: inbox state first, then the slot (same as deliver)
        let mut g = plock(&self.inbox.state);
        let mut slot = plock(&self.slot);
        let key = (self.src as u32, self.tag);
        match std::mem::replace(&mut *slot, comm::SlotState::Cancelled) {
            comm::SlotState::Pending => {
                if let Some(q) = g.reservations.get_mut(&key) {
                    q.retain(|s| !Arc::ptr_eq(s, &self.slot));
                    if q.is_empty() {
                        g.reservations.remove(&key);
                    }
                }
            }
            comm::SlotState::Ready(seq, p) => {
                // fulfilled but never taken: hand the message to the
                // oldest still-pending sibling reservation (which would
                // otherwise sit out the recv deadline — the reader only
                // fulfills once), or reinsert it at its sequence
                // position in the FIFO
                let mut item = Some((seq, p));
                if let Some(q) = g.reservations.get_mut(&key) {
                    let (s, p) = item.take().unwrap();
                    item = comm::offer(q, s, p);
                    if q.is_empty() {
                        g.reservations.remove(&key);
                    }
                }
                if let Some((s, p)) = item {
                    comm::requeue_in_order(g.queues.entry(key).or_default(), s, p);
                }
                self.inbox.cv.notify_all();
            }
            comm::SlotState::Taken => *slot = comm::SlotState::Taken,
            comm::SlotState::Cancelled => {}
        }
    }
}

/// Pre-registered per-link metric handles (`src`/`dst`-labeled series
/// in the global [`obs`] registry) — updates off the send and reader
/// paths are two relaxed atomic adds, no registry lock.
struct LinkCounters {
    bytes: obs::Counter,
    frames: obs::Counter,
}

fn link_counters(bytes_family: &str, frames_family: &str, src: usize, dst: usize) -> LinkCounters {
    let reg = obs::global();
    let s = src.to_string();
    let d = dst.to_string();
    LinkCounters {
        bytes: reg.counter(bytes_family, &[("src", &s), ("dst", &d)]),
        frames: reg.counter(frames_family, &[("src", &s), ("dst", &d)]),
    }
}

/// A [`Transport`] endpoint for exactly one rank of a TCP mesh. Build
/// one per process with [`super::rendezvous::connect`].
pub struct TcpTransport {
    rank: usize,
    n: usize,
    /// per-peer outbound queues (`None` at `self.rank`)
    out: Vec<Option<Arc<SendQueue>>>,
    inbox: Arc<Inbox>,
    payload_bytes_sent: AtomicU64,
    wire_bytes_sent: AtomicU64,
    msgs_sent: AtomicU64,
    /// per-peer payload bytes (this instance only — the labeled registry
    /// series aggregate across instances, these do not)
    link_payload_bytes: Vec<AtomicU64>,
    /// per-peer `link_bytes_sent_total` / `link_frames_sent_total`
    tx_stats: Vec<Option<LinkCounters>>,
    /// receive watchdog applied to every posted handle
    recv_deadline: Duration,
    writers: Vec<std::thread::JoinHandle<()>>,
    readers: Vec<std::thread::JoinHandle<()>>,
    shut: bool,
}

fn writer_loop(
    stream: TcpStream,
    q: Arc<SendQueue>,
    rank: usize,
    peer: usize,
    mut inj: Option<LinkInjector>,
) {
    let mut w = std::io::BufWriter::new(stream);
    loop {
        let f = match q.pop_blocking() {
            Out::Data(tag, payload) => {
                Frame::Data { src: rank as u16, dst: peer as u16, tag, payload }
            }
            Out::Chunk(tag, payload, last) => {
                Frame::DataChunk { src: rank as u16, dst: peer as u16, tag, last, payload }
            }
            Out::Shutdown => {
                let f = Frame::Shutdown { src: rank as u16 };
                let _ = frame::write_frame(&mut w, &f);
                let _ = w.flush();
                return;
            }
        };
        // chaos: stall *before* the write, on this thread — the link's
        // whole FIFO queues up behind the delayed frame, exactly like
        // head-of-line blocking on a slow or lossy TCP connection.
        // Frames are never reordered or lost, so the loss curve and the
        // byte accounting match the chaos-off run bit for bit.
        if let Some(inj) = inj.as_mut() {
            let wire = match &f {
                Frame::Data { payload, .. } => payload.len() * 4 + frame::DATA_OVERHEAD_BYTES,
                Frame::DataChunk { payload, .. } => {
                    payload.len() * 4 + frame::CHUNK_OVERHEAD_BYTES
                }
                _ => 0,
            };
            inj.before_frame(wire);
        }
        if let Err(e) = frame::write_frame(&mut w, &f) {
            // peer died; drain silently — its reader side reports
            eprintln!("[rank {rank}] write to {peer} failed: {e}");
            return;
        }
        // coalesce bursts: only flush once the queue drains
        if q.is_empty() {
            if let Err(e) = w.flush() {
                eprintln!("[rank {rank}] flush to {peer} failed: {e}");
                return;
            }
        }
    }
}

fn reader_loop(
    stream: TcpStream,
    inbox: Arc<Inbox>,
    my_rank: usize,
    peer: usize,
    rx: LinkCounters,
) {
    let mut r = std::io::BufReader::new(stream);
    // partial reassembly buffers for chunked payloads: chunks of one
    // logical message arrive contiguously per tag on this socket
    let mut partial: HashMap<Tag, Vec<f32>> = HashMap::new();
    loop {
        match frame::read_frame(&mut r) {
            Ok(Some(Frame::Data { src, dst, tag, payload })) => {
                rx.bytes.add((payload.len() * 4) as f64);
                rx.frames.inc();
                let mut g = plock(&inbox.state);
                if src as usize != peer || dst as usize != my_rank {
                    g.errors.push(format!(
                        "misrouted frame on {peer}→{my_rank} socket: src {src} dst {dst}"
                    ));
                    inbox.cv.notify_all();
                    return;
                }
                g.deliver(src as u32, tag, payload);
                inbox.cv.notify_all();
            }
            Ok(Some(Frame::DataChunk { src, dst, tag, last, payload })) => {
                rx.bytes.add((payload.len() * 4) as f64);
                rx.frames.inc();
                if src as usize != peer || dst as usize != my_rank {
                    let mut g = plock(&inbox.state);
                    g.errors.push(format!(
                        "misrouted chunk on {peer}→{my_rank} socket: src {src} dst {dst}"
                    ));
                    inbox.cv.notify_all();
                    return;
                }
                let buf = partial.entry(tag).or_default();
                buf.extend_from_slice(&payload);
                if last {
                    let full = partial.remove(&tag).unwrap();
                    let mut g = plock(&inbox.state);
                    g.deliver(src as u32, tag, full);
                    inbox.cv.notify_all();
                }
            }
            Ok(Some(Frame::Shutdown { .. })) => {
                let mut g = plock(&inbox.state);
                g.closed.insert(peer);
                inbox.cv.notify_all();
                return;
            }
            Ok(None) => {
                // EOF with no shutdown frame: the peer process died. Fail
                // the whole transport, not just this link — the schedule
                // depends on every rank transitively (ring steps, loss
                // reduction), so survivors parked on *healthy* links must
                // unwind now and re-enter the rendezvous, instead of
                // sitting out the receive deadline.
                let mut g = plock(&inbox.state);
                g.errors.push(format!(
                    "peer {peer} hung up without a shutdown frame (worker died?)"
                ));
                g.closed.insert(peer);
                inbox.cv.notify_all();
                return;
            }
            Ok(Some(other)) => {
                let mut g = plock(&inbox.state);
                g.errors.push(format!("unexpected control frame from {peer}: {other:?}"));
                g.closed.insert(peer);
                inbox.cv.notify_all();
                return;
            }
            Err(e) => {
                let mut g = plock(&inbox.state);
                g.errors.push(format!("read from {peer} failed: {e}"));
                g.closed.insert(peer);
                inbox.cv.notify_all();
                return;
            }
        }
    }
}

impl TcpTransport {
    /// Assemble a transport from already-established mesh sockets.
    /// `outbound[j]` / `inbound[j]` are the me→j and j→me streams
    /// (`None` at `rank`). Used by [`super::rendezvous::connect`].
    pub(super) fn from_streams(
        rank: usize,
        outbound: Vec<Option<TcpStream>>,
        inbound: Vec<Option<TcpStream>>,
    ) -> TcpTransport {
        TcpTransport::from_streams_tuned(rank, outbound, inbound, None, RECV_DEADLINE)
    }

    /// [`TcpTransport::from_streams`] with the hostile-network knobs: a
    /// chaos profile wrapping this rank's outgoing links and the receive
    /// watchdog deadline.
    pub(super) fn from_streams_tuned(
        rank: usize,
        outbound: Vec<Option<TcpStream>>,
        inbound: Vec<Option<TcpStream>>,
        chaos: Option<&ChaosProfile>,
        recv_deadline: Duration,
    ) -> TcpTransport {
        let n = outbound.len();
        assert_eq!(inbound.len(), n);
        let inbox = Arc::new(Inbox { state: Mutex::new(InboxState::default()), cv: Condvar::new() });
        let mut out: Vec<Option<Arc<SendQueue>>> = Vec::with_capacity(n);
        let mut writers = Vec::new();
        let mut readers = Vec::new();
        for (peer, stream) in outbound.into_iter().enumerate() {
            match stream {
                Some(s) => {
                    let q = Arc::new(SendQueue::new());
                    let q2 = q.clone();
                    let inj = chaos.and_then(|c| c.injector(rank, peer));
                    writers.push(
                        std::thread::Builder::new()
                            .name(format!("pipegcn-w{rank}->{peer}"))
                            .spawn(move || writer_loop(s, q2, rank, peer, inj))
                            .expect("spawn writer"),
                    );
                    out.push(Some(q));
                }
                None => {
                    assert_eq!(peer, rank, "missing outbound stream for peer {peer}");
                    out.push(None);
                }
            }
        }
        for (peer, stream) in inbound.into_iter().enumerate() {
            match stream {
                Some(s) => {
                    let ib = inbox.clone();
                    let rx = link_counters(
                        "link_bytes_recv_total",
                        "link_frames_recv_total",
                        peer,
                        rank,
                    );
                    readers.push(
                        std::thread::Builder::new()
                            .name(format!("pipegcn-r{peer}->{rank}"))
                            .spawn(move || reader_loop(s, ib, rank, peer, rx))
                            .expect("spawn reader"),
                    );
                }
                None => assert_eq!(peer, rank, "missing inbound stream for peer {peer}"),
            }
        }
        let tx_stats = (0..n)
            .map(|peer| {
                if peer == rank {
                    None
                } else {
                    Some(link_counters(
                        "link_bytes_sent_total",
                        "link_frames_sent_total",
                        rank,
                        peer,
                    ))
                }
            })
            .collect();
        TcpTransport {
            rank,
            n,
            out,
            inbox,
            payload_bytes_sent: AtomicU64::new(0),
            wire_bytes_sent: AtomicU64::new(0),
            msgs_sent: AtomicU64::new(0),
            link_payload_bytes: (0..n).map(|_| AtomicU64::new(0)).collect(),
            tx_stats,
            recv_deadline,
            writers,
            readers,
            shut: false,
        }
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Payload bytes this rank has put on the wire (4 per f32) — the
    /// number comparable with [`crate::comm::Fabric`] accounting.
    pub fn payload_bytes_sent(&self) -> u64 {
        self.payload_bytes_sent.load(Ordering::Relaxed)
    }

    /// Actual wire bytes including the per-frame header overhead.
    pub fn wire_bytes_sent(&self) -> u64 {
        self.wire_bytes_sent.load(Ordering::Relaxed)
    }

    pub fn msgs_sent(&self) -> u64 {
        self.msgs_sent.load(Ordering::Relaxed)
    }

    /// Per-peer payload bytes sent by this instance (`[dst] == 0` at
    /// self). Sums to [`TcpTransport::payload_bytes_sent`] — pinned by a
    /// regression test so the per-link series never drift from the
    /// aggregate `comm_bytes` accounting.
    pub fn link_payload_bytes_sent(&self) -> Vec<u64> {
        self.link_payload_bytes.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    /// Messages received but not yet consumed (tests: leak detection).
    pub fn pending(&self) -> usize {
        let g = plock(&self.inbox.state);
        g.queues.values().map(|q| q.len()).sum()
    }

    /// Graceful teardown: enqueue a shutdown frame for every peer and
    /// join the writer threads, guaranteeing all sent data (and the
    /// shutdown markers) reach the OS socket buffers. Reader threads
    /// exit on their own when the matching peer's shutdown frame (or a
    /// clean EOF) arrives — they are deliberately not joined here, so
    /// ranks may tear down in any order without deadlocking.
    pub fn shutdown(&mut self) {
        if self.shut {
            return;
        }
        self.shut = true;
        for q in self.out.iter().flatten() {
            q.push(Out::Shutdown);
        }
        for h in self.writers.drain(..) {
            let _ = h.join();
        }
        self.readers.clear(); // detach
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        // best effort: tell writers to flush shutdown frames, but do not
        // join readers (peers may have died without sending theirs)
        if !self.shut {
            for q in self.out.iter().flatten() {
                q.push(Out::Shutdown);
            }
            for h in self.writers.drain(..) {
                let _ = h.join();
            }
        }
    }
}

impl Transport for TcpTransport {
    fn n_ranks(&self) -> usize {
        self.n
    }

    fn send(&self, src: usize, dst: usize, tag: Tag, payload: Vec<f32>) {
        assert_eq!(src, self.rank, "TcpTransport can only send as its own rank");
        assert!(dst < self.n && dst != self.rank, "bad dst {dst}");
        crate::comm::schedule::observe(crate::comm::schedule::OpKind::Send, src, dst, tag);
        let bytes = (payload.len() * 4) as u64;
        self.payload_bytes_sent.fetch_add(bytes, Ordering::Relaxed);
        self.msgs_sent.fetch_add(1, Ordering::Relaxed);
        self.link_payload_bytes[dst].fetch_add(bytes, Ordering::Relaxed);
        if let Some(tx) = &self.tx_stats[dst] {
            tx.bytes.add(bytes as f64);
            tx.frames.inc();
        }
        let q = self.out[dst].as_ref().expect("peer queue");
        if payload.len() <= frame::MAX_DATA_FLOATS {
            self.wire_bytes_sent
                .fetch_add(bytes + frame::DATA_OVERHEAD_BYTES as u64, Ordering::Relaxed);
            q.push(Out::Data(tag, payload));
        } else {
            // payload exceeds the frame cap: split transparently into
            // DataChunk frames; the peer's reader reassembles before
            // delivery, so recv_blocking still yields one message
            let n_chunks = payload.len().div_ceil(frame::MAX_CHUNK_FLOATS);
            self.wire_bytes_sent.fetch_add(
                bytes + (n_chunks * frame::CHUNK_OVERHEAD_BYTES) as u64,
                Ordering::Relaxed,
            );
            for (i, chunk) in payload.chunks(frame::MAX_CHUNK_FLOATS).enumerate() {
                q.push(Out::Chunk(tag, chunk.to_vec(), i + 1 == n_chunks));
            }
        }
    }

    fn post_recv(&self, src: usize, dst: usize, tag: Tag) -> RecvHandle {
        assert_eq!(dst, self.rank, "TcpTransport can only receive for its own rank");
        assert!(src < self.n && src != self.rank, "bad src {src}");
        let slot = comm::new_slot();
        {
            let mut g = plock(&self.inbox.state);
            match g.pop_queued(src as u32, tag) {
                Some((s, p)) => {
                    let leftover = comm::fulfill(&slot, s, p);
                    debug_assert!(leftover.is_none());
                }
                None => {
                    g.reservations.entry((src as u32, tag)).or_default().push_back(slot.clone());
                }
            }
        }
        RecvHandle::new(
            src,
            dst,
            tag,
            Box::new(TcpRecv {
                inbox: self.inbox.clone(),
                rank: self.rank,
                src,
                tag,
                slot,
                deadline: self.recv_deadline,
            }),
        )
    }

    fn bytes_sent(&self, src: usize) -> u64 {
        assert_eq!(src, self.rank, "TcpTransport accounts only its own rank");
        self.payload_bytes_sent()
    }
}

/// Dial `addr`, retrying while the listener comes up (workers race the
/// rendezvous and each other during mesh formation).
pub(super) fn retry_connect(addr: &str, deadline: Duration) -> std::io::Result<TcpStream> {
    retry_connect_limited(addr, deadline, 0)
}

/// [`retry_connect`] with an attempt cap: give up after `max_attempts`
/// failed dials (0 = unlimited within `deadline`). `--connect-retries`
/// maps here — on a real LAN a bounded attempt count turns a firewalled
/// or mistyped coordinator address into a fast diagnostic instead of a
/// minute of silent retries.
pub(super) fn retry_connect_limited(
    addr: &str,
    deadline: Duration,
    max_attempts: usize,
) -> std::io::Result<TcpStream> {
    let started = Instant::now();
    let mut attempts = 0usize;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => {
                s.set_nodelay(true).ok();
                return Ok(s);
            }
            Err(e) => {
                attempts += 1;
                let exhausted = max_attempts > 0 && attempts >= max_attempts;
                if exhausted || started.elapsed() >= deadline {
                    return Err(std::io::Error::new(
                        e.kind(),
                        format!("connecting to {addr} ({attempts} attempt(s)): {e}"),
                    ));
                }
                std::thread::sleep(Duration::from_millis(25));
            }
        }
    }
}

/// Accept one connection with a deadline (mesh formation must not hang).
pub(super) fn accept_with_deadline(
    listener: &TcpListener,
    deadline: Duration,
) -> std::io::Result<TcpStream> {
    // nonblocking accept + poll keeps this dependency-free and portable
    listener.set_nonblocking(true)?;
    let started = Instant::now();
    loop {
        match listener.accept() {
            Ok((s, _)) => {
                listener.set_nonblocking(false)?;
                s.set_nonblocking(false)?;
                s.set_nodelay(true).ok();
                return Ok(s);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if started.elapsed() > deadline {
                    listener.set_nonblocking(false)?;
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::TimedOut,
                        "timed out waiting for a mesh connection",
                    ));
                }
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => {
                listener.set_nonblocking(false)?;
                return Err(e);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::rendezvous::localhost_mesh;
    use super::*;
    use crate::comm::Phase;

    #[test]
    fn two_rank_send_recv_over_sockets() {
        let mut mesh = localhost_mesh(2).unwrap();
        let t = Tag::new(1, 0, Phase::FwdFeat);
        mesh[0].send(0, 1, t, vec![1.0, 2.0, 3.0]);
        assert_eq!(mesh[1].recv_blocking(0, 1, t), vec![1.0, 2.0, 3.0]);
        // duplex: 1 -> 0 on the same mesh
        mesh[1].send(1, 0, t, vec![4.0]);
        assert_eq!(mesh[0].recv_blocking(1, 0, t), vec![4.0]);
        assert_eq!(mesh[0].bytes_sent(0), 12);
        assert_eq!(mesh[1].bytes_sent(1), 4);
        assert!(mesh[0].wire_bytes_sent() > mesh[0].payload_bytes_sent());
        for m in &mut mesh {
            m.shutdown();
        }
        assert_eq!(mesh[1].pending(), 0);
    }

    #[test]
    fn fifo_per_tag_ordering_across_sockets() {
        let mut mesh = localhost_mesh(2).unwrap();
        let ta = Tag::new(1, 0, Phase::FwdFeat);
        let tb = Tag::new(1, 0, Phase::BwdGrad);
        let tc = Tag::new(2, 0, Phase::FwdFeat);
        // interleave three tags; FIFO must hold within each tag
        for i in 0..5 {
            mesh[0].send(0, 1, ta, vec![i as f32]);
            mesh[0].send(0, 1, tb, vec![10.0 + i as f32]);
            mesh[0].send(0, 1, tc, vec![20.0 + i as f32]);
        }
        // drain out of tag order relative to the sends
        for i in 0..5 {
            assert_eq!(mesh[1].recv_blocking(0, 1, tc), vec![20.0 + i as f32]);
        }
        for i in 0..5 {
            assert_eq!(mesh[1].recv_blocking(0, 1, ta), vec![i as f32]);
            assert_eq!(mesh[1].recv_blocking(0, 1, tb), vec![10.0 + i as f32]);
        }
        for m in &mut mesh {
            m.shutdown();
        }
    }

    #[test]
    fn three_rank_all_pairs() {
        let mut mesh = localhost_mesh(3).unwrap();
        let tag = Tag::new(7, 2, Phase::Reduce);
        for s in 0..3usize {
            for d in 0..3usize {
                if s != d {
                    mesh[s].send(s, d, tag, vec![(10 * s + d) as f32]);
                }
            }
        }
        for d in 0..3usize {
            for s in 0..3usize {
                if s != d {
                    assert_eq!(mesh[d].recv_blocking(s, d, tag), vec![(10 * s + d) as f32]);
                }
            }
        }
        for m in &mut mesh {
            m.shutdown();
        }
    }

    #[test]
    fn payload_bits_survive_the_wire() {
        let mut mesh = localhost_mesh(2).unwrap();
        let tag = Tag::new(1, 0, Phase::Setup);
        let ids = vec![0u32, 7, u32::MAX, 0x7FC0_0001];
        mesh[0].send(0, 1, tag, crate::comm::encode_u32s(&ids));
        let got = crate::comm::decode_u32s(&mesh[1].recv_blocking(0, 1, tag));
        assert_eq!(got, ids);
        for m in &mut mesh {
            m.shutdown();
        }
    }

    /// The point of the handle API on this transport: a posted receive
    /// is completed by the reader-demux thread in the background — the
    /// owning rank never makes another transport call.
    #[test]
    fn posted_recv_is_fulfilled_by_the_reader_thread() {
        let mut mesh = localhost_mesh(2).unwrap();
        let tag = Tag::new(5, 0, Phase::FwdFeat);
        let mut h = mesh[1].post_recv(0, 1, tag);
        assert_eq!(h.try_take(), None);
        mesh[0].send(0, 1, tag, vec![9.0, 8.0]);
        let deadline = Instant::now() + Duration::from_secs(10);
        let payload = loop {
            if let Some(p) = h.try_take() {
                break p;
            }
            assert!(Instant::now() < deadline, "posted receive never completed");
            std::thread::sleep(Duration::from_millis(5));
        };
        assert_eq!(payload, vec![9.0, 8.0]);
        // fulfilled straight off the socket: never sat in the queues
        assert_eq!(mesh[1].pending(), 0);
        for m in &mut mesh {
            m.shutdown();
        }
    }

    #[test]
    #[should_panic(expected = "own rank")]
    fn send_as_foreign_rank_rejected() {
        let mesh = localhost_mesh(2).unwrap();
        mesh[0].send(1, 0, Tag::new(0, 0, Phase::Setup), vec![]);
    }

    /// Regression: a payload just above the 64 MiB frame cap used to
    /// panic at the send site; it must now be chunked and reassembled
    /// transparently, bit-for-bit.
    #[test]
    fn payload_above_frame_cap_is_chunked() {
        let mut mesh = localhost_mesh(2).unwrap();
        let tag = Tag::new(3, 1, Phase::FwdFeat);
        let n = frame::MAX_DATA_FLOATS + 1;
        let payload: Vec<f32> = (0..n).map(|i| (i % 8191) as f32 * 0.5).collect();
        mesh[0].send(0, 1, tag, payload.clone());
        // a small message under a different tag is unaffected by the
        // in-flight reassembly
        let small = Tag::new(3, 2, Phase::FwdFeat);
        mesh[0].send(0, 1, small, vec![42.0]);
        let got = mesh[1].recv_blocking(0, 1, tag);
        assert_eq!(got.len(), payload.len());
        assert!(got == payload, "chunked payload corrupted in transit");
        assert_eq!(mesh[1].recv_blocking(0, 1, small), vec![42.0]);
        // accounting: payload bytes are logical; wire bytes pay one
        // header per chunk (2 chunks + the small frame here)
        assert_eq!(mesh[0].payload_bytes_sent(), (n as u64 + 1) * 4);
        assert_eq!(
            mesh[0].wire_bytes_sent(),
            (n as u64 + 1) * 4
                + 2 * frame::CHUNK_OVERHEAD_BYTES as u64
                + frame::DATA_OVERHEAD_BYTES as u64
        );
        for m in &mut mesh {
            m.shutdown();
        }
        assert_eq!(mesh[1].pending(), 0);
    }

    /// The wait watchdog: a receive parked past the configured deadline
    /// fails naming the link, the tag, and the elapsed time — instead
    /// of hanging the rank for the 300 s default.
    #[test]
    fn recv_watchdog_names_the_link_and_elapsed_time() {
        use super::super::rendezvous::{localhost_mesh_with, ConnectOpts};
        let opts = ConnectOpts {
            recv_deadline: Some(Duration::from_millis(150)),
            ..ConnectOpts::default()
        };
        let mut mesh = localhost_mesh_with(2, &opts).unwrap();
        let tag = Tag::new(4, 2, Phase::BwdGrad);
        let h = mesh[1].post_recv(0, 1, tag);
        let waited = std::thread::spawn(move || {
            let mut st = crate::comm::WaitStats::default();
            h.wait(&mut st)
        })
        .join();
        let msg = *waited.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("recv timeout"), "{msg}");
        assert!(msg.contains("0->1"), "must name the link: {msg}");
        assert!(msg.contains("ms"), "must report elapsed ms: {msg}");
        assert!(msg.contains("--recv-deadline"), "must name the knob: {msg}");
        // the poisoned-unwind path must not have killed the transport:
        // undisturbed tags still flow and teardown is clean
        let t2 = Tag::new(5, 0, Phase::FwdFeat);
        mesh[0].send(0, 1, t2, vec![8.5]);
        assert_eq!(mesh[1].recv_blocking(0, 1, t2), vec![8.5]);
        for m in &mut mesh {
            m.shutdown();
        }
    }

    /// A chaotic link (latency + jitter + drops) delays frames but must
    /// not reorder, lose, or mis-account them.
    #[test]
    fn chaotic_link_preserves_order_and_accounting() {
        use super::super::chaos::ChaosProfile;
        use super::super::rendezvous::{localhost_mesh_with, ConnectOpts};
        let chaos = ChaosProfile::parse(
            r#"{"seed": 11, "default":
                {"latency_ms": 1, "jitter_ms": 2, "drop": 0.25, "rto_ms": 3}}"#,
        )
        .unwrap();
        let opts = ConnectOpts { chaos: Some(chaos), ..ConnectOpts::default() };
        let mut mesh = localhost_mesh_with(2, &opts).unwrap();
        let tag = Tag::new(1, 0, Phase::FwdFeat);
        for i in 0..20 {
            mesh[0].send(0, 1, tag, vec![i as f32]);
        }
        for i in 0..20 {
            assert_eq!(mesh[1].recv_blocking(0, 1, tag), vec![i as f32]);
        }
        assert_eq!(mesh[0].payload_bytes_sent(), 20 * 4);
        assert_eq!(
            mesh[0].wire_bytes_sent(),
            20 * (4 + frame::DATA_OVERHEAD_BYTES as u64)
        );
        // the injected faults surfaced in the registry
        let delays = obs::global()
            .value("link_faults_total", &[("src", "0"), ("dst", "1"), ("kind", "delay")])
            .unwrap_or(0.0);
        assert!(delays >= 20.0, "every frame on this link is delayed: {delays}");
        for m in &mut mesh {
            m.shutdown();
        }
        assert_eq!(mesh[1].pending(), 0);
    }
}
