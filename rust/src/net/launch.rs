//! `pipegcn launch` — spawn one worker process per partition on this
//! machine, serve their rendezvous, and supervise them.
//!
//! The launcher binds an ephemeral rendezvous port, starts `--parts`
//! children running `pipegcn worker --rank i --coord <addr> ...`
//! (stdio inherited, so rank 0's report streams to the console), hands
//! every rank the peer table, and polls the children so one death is
//! detected while the rest are still running.
//!
//! Crash recovery: with `--ckpt-dir`, a failed generation (a worker
//! died, or rendezvous/mesh formation broke) is torn down and the **full
//! mesh is relaunched from the latest complete checkpoint** — a fresh
//! rendezvous generation on a fresh port, every worker passed
//! `--resume <ckpt-dir>`. Up to `--max-restarts` relaunches are
//! attempted before giving up. Without a checkpoint directory a worker
//! death still fails the whole job, as before.

use super::rendezvous;
use crate::util::error::Result;
use std::net::TcpListener;
use std::process::{Child, Command};
use std::time::Duration;

#[derive(Clone, Debug)]
pub struct LaunchOpts {
    pub parts: usize,
    pub dataset: String,
    pub method: String,
    /// node-count override (0 = preset default); non-zero switches the
    /// workers to per-rank lazy shard construction
    pub nodes: usize,
    /// partitioner name forwarded to the workers (None = multilevel)
    pub partitioner: Option<String>,
    /// 0 = preset default
    pub epochs: usize,
    pub seed: u64,
    pub gamma: f32,
    /// NDJSON run log path (given to rank 0; streamed per epoch)
    pub log: Option<String>,
    /// result JSON path (given to rank 0)
    pub out: Option<String>,
    /// checkpoint directory (enables crash recovery)
    pub ckpt_dir: Option<String>,
    /// snapshot every this many epochs (with `ckpt_dir`)
    pub ckpt_every: usize,
    /// start the first generation from this checkpoint directory
    pub resume: Option<String>,
    /// mesh relaunches allowed after a failure (needs `ckpt_dir`)
    pub max_restarts: usize,
    /// compute threads per worker (`--threads`; None = worker default:
    /// `PIPEGCN_THREADS` or the machine's available parallelism)
    pub threads: Option<usize>,
    /// fault injection for the recovery tests: this rank …
    pub fail_rank: Option<usize>,
    /// … exits(13) after this epoch, on the first generation only
    pub fail_epoch: Option<usize>,
    /// merged Chrome trace-event JSON path, forwarded to every rank
    /// (rank 0 writes the file after collecting peers' spans)
    pub trace: Option<String>,
    /// metrics base address `HOST:PORT`: rank i serves Prometheus text
    /// on `HOST:PORT+i` (co-located workers need distinct ports)
    pub metrics_addr: Option<String>,
}

fn kill_all(children: &mut [Child]) {
    for c in children.iter_mut() {
        let _ = c.kill();
        let _ = c.wait();
    }
}

/// Worker kernel-thread count to pass on the command line. Explicit
/// `--threads` wins; otherwise, unless the operator set a *valid*
/// `PIPEGCN_THREADS` (which the workers inherit — same ≥1-integer rule
/// as `pool::default_threads`, so an unparseable value doesn't skip the
/// guard only to be rejected by the workers too), divide the machine's
/// cores across the co-located workers — K processes each defaulting to
/// *full* available parallelism would oversubscribe the host and
/// corrupt the comp/comm-wait overlap numbers in `--log`.
fn worker_threads(opts: &LaunchOpts) -> Option<usize> {
    opts.threads.or_else(|| {
        let env_valid = std::env::var("PIPEGCN_THREADS")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .is_some_and(|n| n >= 1);
        if env_valid {
            None
        } else {
            let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            Some((cores / opts.parts.max(1)).max(1))
        }
    })
}

/// Rank `rank`'s metrics address: `HOST:PORT+rank`. Co-located workers
/// cannot share one listening port, so the operator names a base and
/// each rank takes the next port up — scrape rank i at base+i.
fn rank_metrics_addr(base: &str, rank: usize) -> Result<String> {
    let (host, port) = base
        .rsplit_once(':')
        .ok_or_else(|| crate::err_msg!("--metrics-addr {base}: expected HOST:PORT"))?;
    let port: u16 = port
        .parse()
        .map_err(|e| crate::err_msg!("--metrics-addr {base}: bad port: {e}"))?;
    let port = port
        .checked_add(rank as u16)
        .ok_or_else(|| crate::err_msg!("--metrics-addr {base}: port + rank {rank} overflows"))?;
    Ok(format!("{host}:{port}"))
}

fn spawn_workers(
    bin: &std::path::Path,
    opts: &LaunchOpts,
    coord: &str,
    resume: Option<&str>,
    inject_fault: bool,
) -> Result<Vec<Child>> {
    let threads = worker_threads(opts);
    let mut children: Vec<Child> = Vec::with_capacity(opts.parts);
    for rank in 0..opts.parts {
        let mut cmd = Command::new(bin);
        cmd.arg("worker")
            .arg("--rank")
            .arg(rank.to_string())
            .arg("--parts")
            .arg(opts.parts.to_string())
            .arg("--coord")
            .arg(coord)
            .arg("--dataset")
            .arg(&opts.dataset)
            .arg("--method")
            .arg(&opts.method)
            .arg("--epochs")
            .arg(opts.epochs.to_string())
            .arg("--seed")
            .arg(opts.seed.to_string())
            .arg("--gamma")
            .arg(opts.gamma.to_string());
        if opts.nodes > 0 {
            cmd.arg("--nodes").arg(opts.nodes.to_string());
        }
        if let Some(p) = &opts.partitioner {
            cmd.arg("--partitioner").arg(p);
        }
        if let Some(n) = threads {
            cmd.arg("--threads").arg(n.to_string());
        }
        if let Some(dir) = &opts.ckpt_dir {
            cmd.arg("--ckpt-dir").arg(dir);
            cmd.arg("--ckpt-every").arg(opts.ckpt_every.to_string());
        }
        if let Some(dir) = resume {
            cmd.arg("--resume").arg(dir);
        }
        if inject_fault && opts.fail_rank == Some(rank) {
            if let Some(epoch) = opts.fail_epoch {
                cmd.arg("--fail-epoch").arg(epoch.to_string());
            }
        }
        if let Some(path) = &opts.trace {
            cmd.arg("--trace").arg(path);
        }
        if let Some(base) = &opts.metrics_addr {
            match rank_metrics_addr(base, rank) {
                Ok(addr) => {
                    cmd.arg("--metrics-addr").arg(addr);
                }
                Err(e) => {
                    kill_all(&mut children);
                    return Err(e);
                }
            }
        }
        if rank == 0 {
            if let Some(log) = &opts.log {
                cmd.arg("--log").arg(log);
            }
            if let Some(out) = &opts.out {
                cmd.arg("--out").arg(out);
            }
        }
        match cmd.spawn() {
            Ok(c) => children.push(c),
            Err(e) => {
                kill_all(&mut children);
                return Err(crate::err_msg!("spawning worker rank {rank}: {e}"));
            }
        }
    }
    Ok(children)
}

/// Poll all children until every one exits cleanly; error at the first
/// non-zero exit (the caller tears the rest down). Polling — rather than
/// a rank-ordered `wait()` chain — is what lets the launcher notice a
/// high-rank death while low ranks are still blocked mid-epoch.
fn supervise(children: &mut [Child]) -> Result<()> {
    let mut done = vec![false; children.len()];
    loop {
        let mut all_done = true;
        for (rank, child) in children.iter_mut().enumerate() {
            if done[rank] {
                continue;
            }
            match child.try_wait() {
                Ok(Some(status)) if status.success() => done[rank] = true,
                Ok(Some(status)) => crate::bail!("worker rank {rank} exited with {status}"),
                Ok(None) => all_done = false,
                Err(e) => crate::bail!("waiting for rank {rank}: {e}"),
            }
        }
        if all_done {
            return Ok(());
        }
        std::thread::sleep(Duration::from_millis(30));
    }
}

/// Spawn `opts.parts` workers of `bin` (normally `current_exe()`), serve
/// their rendezvous, and supervise until completion — relaunching the
/// full mesh from the latest complete checkpoint when a generation
/// fails and `--ckpt-dir` is set.
pub fn launch(bin: &std::path::Path, opts: &LaunchOpts) -> Result<()> {
    if opts.parts == 0 {
        crate::bail!("--parts must be at least 1");
    }
    let mut generation = 0usize;
    let mut resume = opts.resume.clone();
    loop {
        // fresh rendezvous generation: new listener, new port
        let listener = TcpListener::bind("127.0.0.1:0")
            .map_err(|e| crate::err_msg!("binding the rendezvous listener: {e}"))?;
        let coord = listener.local_addr()?.to_string();
        // fault injection fires on the first, non-resumed generation
        // only — the relaunched mesh must be allowed to finish
        let inject = generation == 0 && resume.is_none();
        let mut children = spawn_workers(bin, opts, &coord, resume.as_deref(), inject)?;

        let outcome = rendezvous::serve(&listener, opts.parts)
            .map_err(|e| crate::err_msg!("rendezvous failed: {e}"))
            .and_then(|_| supervise(&mut children));
        match outcome {
            Ok(()) => return Ok(()),
            Err(e) => {
                // reap everything *before* scanning for checkpoints, so
                // no straggler is mid-write during the scan
                kill_all(&mut children);
                let Some(dir) = &opts.ckpt_dir else { return Err(e) };
                if generation >= opts.max_restarts {
                    return Err(crate::err_msg!(
                        "{e}; giving up after {generation} restart(s)"
                    ));
                }
                match crate::ckpt::latest_complete(dir, opts.parts)? {
                    Some(epoch) => {
                        generation += 1;
                        eprintln!(
                            "launch: {e}; relaunching all {} workers from the epoch-{epoch} \
                             checkpoint (generation {generation})",
                            opts.parts
                        );
                        resume = Some(dir.clone());
                    }
                    None => {
                        return Err(crate::err_msg!(
                            "{e}; no complete checkpoint under {dir} to recover from"
                        ))
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_metrics_addr_offsets_port_per_rank() {
        assert_eq!(rank_metrics_addr("127.0.0.1:9100", 0).unwrap(), "127.0.0.1:9100");
        assert_eq!(rank_metrics_addr("127.0.0.1:9100", 3).unwrap(), "127.0.0.1:9103");
        assert!(rank_metrics_addr("9100", 0).is_err());
        assert!(rank_metrics_addr("host:notaport", 0).is_err());
        assert!(rank_metrics_addr("host:65535", 1).is_err());
    }
}
