//! `pipegcn launch` — spawn one worker process per partition on this
//! machine, serve their rendezvous, and supervise them.
//!
//! The launcher binds an ephemeral rendezvous port, starts `--parts`
//! children running `pipegcn worker --rank i --coord <addr> ...`
//! (stdio inherited, so rank 0's report streams to the console), hands
//! every rank the peer table, and polls the children so one death is
//! detected while the rest are still running.
//!
//! Crash recovery, in order of preference:
//!
//! 1. **Live rejoin** (with `--ckpt-dir`): when a worker dies mid-run,
//!    the survivors notice the broken link, drop their mesh, and re-dial
//!    the *same* rendezvous address. The launcher respawns only the dead
//!    rank(s) with `--rejoin` and serves a rejoin round on the listener
//!    it never closed — the round's `Resume{epoch}` frame tells every
//!    rank which complete [`crate::ckpt`] checkpoint to roll back to.
//!    The surviving processes are never restarted, and the loss curve
//!    stays bit-identical to an uninterrupted run.
//! 2. **Full relaunch**: if a rejoin round cannot form (the rendezvous
//!    errors or a replacement cannot spawn), the whole mesh is torn down
//!    and relaunched from the latest complete checkpoint — a fresh
//!    rendezvous generation on a fresh port, every worker passed
//!    `--resume <ckpt-dir>`.
//!
//! Both paths draw from the same `--max-restarts` budget. Without a
//! checkpoint directory a worker death still fails the whole job, as
//! before.
//!
//! `--fail-epoch` takes a comma list: each entry arms one spawn of
//! `--fail-rank` (original, then each replacement in turn) to exit(13)
//! after that epoch, so recovery-of-recovery is testable.

use super::rendezvous::{self, ServeOpts, FORM_DEADLINE};
use crate::util::error::Result;
use std::net::TcpListener;
use std::process::{Child, Command, ExitStatus};
use std::time::Duration;

#[derive(Clone, Debug)]
pub struct LaunchOpts {
    pub parts: usize,
    pub dataset: String,
    pub method: String,
    /// node-count override (0 = preset default); non-zero switches the
    /// workers to per-rank lazy shard construction
    pub nodes: usize,
    /// partitioner name forwarded to the workers (None = multilevel)
    pub partitioner: Option<String>,
    /// 0 = preset default
    pub epochs: usize,
    pub seed: u64,
    pub gamma: f32,
    /// NDJSON run log path (given to rank 0; streamed per epoch)
    pub log: Option<String>,
    /// result JSON path (given to rank 0)
    pub out: Option<String>,
    /// checkpoint directory (enables crash recovery)
    pub ckpt_dir: Option<String>,
    /// snapshot every this many epochs (with `ckpt_dir`)
    pub ckpt_every: usize,
    /// start the first generation from this checkpoint directory
    pub resume: Option<String>,
    /// recovery rounds (rejoins + relaunches) allowed (needs `ckpt_dir`)
    pub max_restarts: usize,
    /// compute threads per worker (`--threads`; None = worker default:
    /// `PIPEGCN_THREADS` or the machine's available parallelism)
    pub threads: Option<usize>,
    /// fault injection for the recovery tests: this rank …
    pub fail_rank: Option<usize>,
    /// … exits(13) after these epochs — one entry per spawn of the
    /// rank, so `3,5` kills the original after epoch 3 and its
    /// replacement after epoch 5
    pub fail_epochs: Vec<usize>,
    /// merged Chrome trace-event JSON path, forwarded to every rank
    /// (rank 0 writes the file after collecting peers' spans)
    pub trace: Option<String>,
    /// metrics base address `HOST:PORT`: rank i serves Prometheus text
    /// on `HOST:PORT+i` (co-located workers need distinct ports)
    pub metrics_addr: Option<String>,
    /// chaos profile JSON path (`--chaos`), forwarded to every rank
    pub chaos: Option<String>,
    /// shared mesh secret: the rendezvous challenges every joiner, and
    /// workers inherit it via `PIPEGCN_MESH_SECRET` (kept off argv so it
    /// never shows in the process table)
    pub mesh_secret: Option<String>,
    /// mesh-formation deadline in seconds (`--form-deadline`)
    pub form_deadline_secs: Option<u64>,
    /// receive-watchdog deadline in seconds (`--recv-deadline`),
    /// forwarded to every rank
    pub recv_deadline_secs: Option<u64>,
}

fn kill_all(children: &mut [Child]) {
    for c in children.iter_mut() {
        let _ = c.kill();
        let _ = c.wait();
    }
}

fn form_deadline(opts: &LaunchOpts) -> Duration {
    opts.form_deadline_secs.map(|s| Duration::from_secs(s.max(1))).unwrap_or(FORM_DEADLINE)
}

/// Worker kernel-thread count to pass on the command line. Explicit
/// `--threads` wins; otherwise, unless the operator set a *valid*
/// `PIPEGCN_THREADS` (which the workers inherit — same ≥1-integer rule
/// as `pool::default_threads`, so an unparseable value doesn't skip the
/// guard only to be rejected by the workers too), divide the machine's
/// cores across the co-located workers — K processes each defaulting to
/// *full* available parallelism would oversubscribe the host and
/// corrupt the comp/comm-wait overlap numbers in `--log`.
fn worker_threads(opts: &LaunchOpts) -> Option<usize> {
    opts.threads.or_else(|| {
        let env_valid = std::env::var("PIPEGCN_THREADS")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .is_some_and(|n| n >= 1);
        if env_valid {
            None
        } else {
            let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            Some((cores / opts.parts.max(1)).max(1))
        }
    })
}

/// Rank `rank`'s metrics address: `HOST:PORT+rank`. Co-located workers
/// cannot share one listening port, so the operator names a base and
/// each rank takes the next port up — scrape rank i at base+i.
fn rank_metrics_addr(base: &str, rank: usize) -> Result<String> {
    let (host, port) = base
        .rsplit_once(':')
        .ok_or_else(|| crate::err_msg!("--metrics-addr {base}: expected HOST:PORT"))?;
    let port: u16 = port
        .parse()
        .map_err(|e| crate::err_msg!("--metrics-addr {base}: bad port: {e}"))?;
    let port = port
        .checked_add(rank as u16)
        .ok_or_else(|| crate::err_msg!("--metrics-addr {base}: port + rank {rank} overflows"))?;
    Ok(format!("{host}:{port}"))
}

/// The fail epoch (if any) to arm the next spawn of `rank` with. Each
/// entry in `--fail-epoch` is consumed by one spawn of the fail rank, in
/// order — original first, then each replacement.
fn take_fail_epoch(opts: &LaunchOpts, rank: usize, fail_idx: &mut usize) -> Option<usize> {
    if opts.fail_rank == Some(rank) && *fail_idx < opts.fail_epochs.len() {
        let epoch = opts.fail_epochs[*fail_idx];
        *fail_idx += 1;
        Some(epoch)
    } else {
        None
    }
}

/// Spawn one worker process. `rejoin` marks a replacement joining a live
/// rejoin round (the worker then expects the round to name a resume
/// epoch instead of scanning `--resume` itself).
fn spawn_one(
    bin: &std::path::Path,
    opts: &LaunchOpts,
    coord: &str,
    rank: usize,
    resume: Option<&str>,
    rejoin: bool,
    fail_epoch: Option<usize>,
) -> Result<Child> {
    let threads = worker_threads(opts);
    let mut cmd = Command::new(bin);
    cmd.arg("worker")
        .arg("--rank")
        .arg(rank.to_string())
        .arg("--parts")
        .arg(opts.parts.to_string())
        .arg("--coord")
        .arg(coord)
        .arg("--dataset")
        .arg(&opts.dataset)
        .arg("--method")
        .arg(&opts.method)
        .arg("--epochs")
        .arg(opts.epochs.to_string())
        .arg("--seed")
        .arg(opts.seed.to_string())
        .arg("--gamma")
        .arg(opts.gamma.to_string());
    if opts.nodes > 0 {
        cmd.arg("--nodes").arg(opts.nodes.to_string());
    }
    if let Some(p) = &opts.partitioner {
        cmd.arg("--partitioner").arg(p);
    }
    if let Some(n) = threads {
        cmd.arg("--threads").arg(n.to_string());
    }
    if let Some(dir) = &opts.ckpt_dir {
        cmd.arg("--ckpt-dir").arg(dir);
        cmd.arg("--ckpt-every").arg(opts.ckpt_every.to_string());
    }
    if let Some(dir) = resume {
        cmd.arg("--resume").arg(dir);
    }
    if rejoin {
        cmd.arg("--rejoin");
    }
    if let Some(epoch) = fail_epoch {
        cmd.arg("--fail-epoch").arg(epoch.to_string());
    }
    if let Some(path) = &opts.trace {
        cmd.arg("--trace").arg(path);
    }
    if let Some(path) = &opts.chaos {
        cmd.arg("--chaos").arg(path);
    }
    if let Some(secs) = opts.form_deadline_secs {
        cmd.arg("--form-deadline").arg(secs.to_string());
    }
    if let Some(secs) = opts.recv_deadline_secs {
        cmd.arg("--recv-deadline").arg(secs.to_string());
    }
    if let Some(secret) = &opts.mesh_secret {
        cmd.env("PIPEGCN_MESH_SECRET", secret);
    }
    if let Some(base) = &opts.metrics_addr {
        cmd.arg("--metrics-addr").arg(rank_metrics_addr(base, rank)?);
    }
    if rank == 0 {
        if let Some(log) = &opts.log {
            cmd.arg("--log").arg(log);
        }
        if let Some(out) = &opts.out {
            cmd.arg("--out").arg(out);
        }
    }
    cmd.spawn().map_err(|e| crate::err_msg!("spawning worker rank {rank}: {e}"))
}

fn spawn_workers(
    bin: &std::path::Path,
    opts: &LaunchOpts,
    coord: &str,
    resume: Option<&str>,
    fail_idx: &mut usize,
) -> Result<Vec<Child>> {
    let mut children: Vec<Child> = Vec::with_capacity(opts.parts);
    for rank in 0..opts.parts {
        let fail_epoch = take_fail_epoch(opts, rank, fail_idx);
        match spawn_one(bin, opts, coord, rank, resume, false, fail_epoch) {
            Ok(c) => children.push(c),
            Err(e) => {
                kill_all(&mut children);
                return Err(e);
            }
        }
    }
    Ok(children)
}

/// What one supervision pass observed.
enum Watch {
    /// every worker exited cleanly
    Done,
    /// these ranks died (non-zero exit); the rest are still running or
    /// already finished
    Dead(Vec<(usize, ExitStatus)>),
}

/// Poll the children until every one exits cleanly or at least one
/// dies. Polling — rather than a rank-ordered `wait()` chain — is what
/// lets the launcher notice a high-rank death while low ranks are still
/// blocked mid-epoch. On a death, a short grace window collects the
/// other ranks of a co-dying mesh so one rejoin round replaces them all.
fn watch(children: &mut [Child], done: &mut [bool]) -> Result<Watch> {
    loop {
        let mut all_done = true;
        let mut dead: Vec<(usize, ExitStatus)> = Vec::new();
        for (rank, child) in children.iter_mut().enumerate() {
            if done[rank] {
                continue;
            }
            match child.try_wait() {
                Ok(Some(status)) if status.success() => done[rank] = true,
                Ok(Some(status)) => dead.push((rank, status)),
                Ok(None) => all_done = false,
                Err(e) => crate::bail!("waiting for rank {rank}: {e}"),
            }
        }
        if !dead.is_empty() {
            std::thread::sleep(Duration::from_millis(500));
            for (rank, child) in children.iter_mut().enumerate() {
                if done[rank] || dead.iter().any(|&(r, _)| r == rank) {
                    continue;
                }
                match child.try_wait() {
                    Ok(Some(status)) if status.success() => done[rank] = true,
                    Ok(Some(status)) => dead.push((rank, status)),
                    _ => {}
                }
            }
            dead.sort_unstable_by_key(|&(r, _)| r);
            return Ok(Watch::Dead(dead));
        }
        if all_done {
            return Ok(Watch::Done);
        }
        std::thread::sleep(Duration::from_millis(30));
    }
}

/// Spawn `opts.parts` workers of `bin` (normally `current_exe()`), serve
/// their rendezvous, and supervise until completion. With `--ckpt-dir`,
/// a worker death is healed in place: only the dead ranks are respawned
/// and a rejoin round on the same rendezvous address rolls every rank
/// back to the latest complete checkpoint (full-mesh relaunch is the
/// fallback when the rejoin round cannot form).
pub fn launch(bin: &std::path::Path, opts: &LaunchOpts) -> Result<()> {
    if opts.parts == 0 {
        crate::bail!("--parts must be at least 1");
    }
    let mut restarts = 0usize;
    let mut fail_idx = 0usize;
    let mut resume = opts.resume.clone();
    let sopts = ServeOpts {
        deadline: form_deadline(opts),
        secret: opts.mesh_secret.clone(),
        resume_epoch: None,
    };
    'generation: loop {
        // fresh rendezvous generation: new listener, new port. The
        // listener stays open for the whole generation — survivors of a
        // worker death re-dial this same address to rejoin.
        let listener = TcpListener::bind("127.0.0.1:0")
            .map_err(|e| crate::err_msg!("binding the rendezvous listener: {e}"))?;
        let coord = listener.local_addr()?.to_string();
        let mut children = spawn_workers(bin, opts, &coord, resume.as_deref(), &mut fail_idx)?;
        if let Err(e) = rendezvous::serve_with(&listener, opts.parts, &sopts) {
            let e = crate::err_msg!("rendezvous failed: {e}");
            kill_all(&mut children);
            let (dir, epoch) = plan_recovery(opts, &mut restarts, &e)?;
            eprintln!(
                "launch: {e}; relaunching all {} workers from the epoch-{epoch} \
                 checkpoint (restart {restarts})",
                opts.parts
            );
            resume = Some(dir);
            continue 'generation;
        }

        let mut done = vec![false; opts.parts];
        loop {
            let dead = match watch(&mut children, &mut done)? {
                Watch::Done => return Ok(()),
                Watch::Dead(dead) => dead,
            };
            let (first_rank, first_status) = &dead[0];
            let err = crate::err_msg!("worker rank {first_rank} exited with {first_status}");
            let (dir, epoch) = match plan_recovery(opts, &mut restarts, &err) {
                Ok(plan) => plan,
                Err(e) => {
                    kill_all(&mut children);
                    return Err(e);
                }
            };
            let ranks: Vec<usize> = dead.iter().map(|&(r, _)| r).collect();
            eprintln!(
                "launch: {err}; replacing rank(s) {ranks:?} and rolling the live mesh \
                 back to the epoch-{epoch} checkpoint (restart {restarts})"
            );
            // respawn only the dead ranks, then serve a rejoin round on
            // the listener the survivors are already re-dialing
            let mut respawned = true;
            for &rank in &ranks {
                let fail_epoch = take_fail_epoch(opts, rank, &mut fail_idx);
                match spawn_one(bin, opts, &coord, rank, None, true, fail_epoch) {
                    Ok(c) => {
                        children[rank] = c;
                        done[rank] = false;
                    }
                    Err(e) => {
                        eprintln!("launch: {e}");
                        respawned = false;
                        break;
                    }
                }
            }
            let round = ServeOpts { resume_epoch: Some(epoch as u64), ..sopts.clone() };
            let served = respawned
                .then(|| rendezvous::serve_with(&listener, opts.parts, &round))
                .unwrap_or_else(|| {
                    Err(std::io::Error::new(
                        std::io::ErrorKind::Other,
                        "replacement worker failed to spawn",
                    ))
                });
            match served {
                Ok(_) => {
                    // mesh healed in place: back to supervising the same
                    // children, survivors included
                }
                Err(e) => {
                    eprintln!(
                        "launch: live rejoin round failed ({e}); falling back to a full \
                         relaunch from the epoch-{epoch} checkpoint"
                    );
                    kill_all(&mut children);
                    resume = Some(dir);
                    continue 'generation;
                }
            }
        }
    }
}

/// Gatekeeper for one recovery round (live rejoin or full relaunch):
/// checks the restart budget, finds the latest complete checkpoint, and
/// charges one restart against `--max-restarts`. `err` is what broke
/// the mesh — every refusal names it.
fn plan_recovery(
    opts: &LaunchOpts,
    restarts: &mut usize,
    err: &crate::util::error::Error,
) -> Result<(String, usize)> {
    let Some(dir) = &opts.ckpt_dir else {
        return Err(crate::err_msg!("{err}"));
    };
    if *restarts >= opts.max_restarts {
        return Err(crate::err_msg!("{err}; giving up after {restarts} restart(s)"));
    }
    match crate::ckpt::latest_complete(dir, opts.parts)? {
        Some(epoch) => {
            *restarts += 1;
            Ok((dir.clone(), epoch))
        }
        None => Err(crate::err_msg!("{err}; no complete checkpoint under {dir} to recover from")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_metrics_addr_offsets_port_per_rank() {
        assert_eq!(rank_metrics_addr("127.0.0.1:9100", 0).unwrap(), "127.0.0.1:9100");
        assert_eq!(rank_metrics_addr("127.0.0.1:9100", 3).unwrap(), "127.0.0.1:9103");
        assert!(rank_metrics_addr("9100", 0).is_err());
        assert!(rank_metrics_addr("host:notaport", 0).is_err());
        assert!(rank_metrics_addr("host:65535", 1).is_err());
    }

    #[test]
    fn fail_epochs_are_consumed_one_per_spawn_of_the_fail_rank() {
        let opts = LaunchOpts {
            parts: 2,
            dataset: "tiny".into(),
            method: "pipegcn".into(),
            nodes: 0,
            partitioner: None,
            epochs: 1,
            seed: 1,
            gamma: 0.0,
            log: None,
            out: None,
            ckpt_dir: None,
            ckpt_every: 1,
            resume: None,
            max_restarts: 0,
            threads: None,
            fail_rank: Some(1),
            fail_epochs: vec![3, 5],
            trace: None,
            metrics_addr: None,
            chaos: None,
            mesh_secret: None,
            form_deadline_secs: None,
            recv_deadline_secs: None,
        };
        let mut idx = 0;
        assert_eq!(take_fail_epoch(&opts, 0, &mut idx), None);
        assert_eq!(take_fail_epoch(&opts, 1, &mut idx), Some(3));
        assert_eq!(take_fail_epoch(&opts, 1, &mut idx), Some(5));
        assert_eq!(take_fail_epoch(&opts, 1, &mut idx), None);
        assert_eq!(idx, 2);
    }
}
