//! `pipegcn launch` — spawn one worker process per partition on this
//! machine and serve their rendezvous.
//!
//! The launcher binds an ephemeral rendezvous port, starts `--parts`
//! children running `pipegcn worker --rank i --coord <addr> ...`
//! (stdio inherited, so rank 0's report streams to the console), hands
//! every rank the peer table, and waits for all of them to exit.

use super::rendezvous;
use crate::util::error::{Context, Result};
use std::net::TcpListener;
use std::process::{Child, Command};

#[derive(Clone, Debug)]
pub struct LaunchOpts {
    pub parts: usize,
    pub dataset: String,
    pub method: String,
    /// 0 = preset default
    pub epochs: usize,
    pub seed: u64,
    pub gamma: f32,
    /// NDJSON run log path (given to rank 0)
    pub log: Option<String>,
    /// result JSON path (given to rank 0)
    pub out: Option<String>,
}

fn kill_all(children: &mut [Child]) {
    for c in children.iter_mut() {
        let _ = c.kill();
        let _ = c.wait();
    }
}

/// Spawn `opts.parts` workers of `bin` (normally `current_exe()`), serve
/// their rendezvous, and wait. Errors if any rank exits non-zero.
pub fn launch(bin: &std::path::Path, opts: &LaunchOpts) -> Result<()> {
    if opts.parts == 0 {
        crate::bail!("--parts must be at least 1");
    }
    let listener =
        TcpListener::bind("127.0.0.1:0").context("binding the rendezvous listener")?;
    let coord = listener.local_addr()?.to_string();

    let mut children: Vec<Child> = Vec::with_capacity(opts.parts);
    for rank in 0..opts.parts {
        let mut cmd = Command::new(bin);
        cmd.arg("worker")
            .arg("--rank")
            .arg(rank.to_string())
            .arg("--parts")
            .arg(opts.parts.to_string())
            .arg("--coord")
            .arg(&coord)
            .arg("--dataset")
            .arg(&opts.dataset)
            .arg("--method")
            .arg(&opts.method)
            .arg("--epochs")
            .arg(opts.epochs.to_string())
            .arg("--seed")
            .arg(opts.seed.to_string())
            .arg("--gamma")
            .arg(opts.gamma.to_string());
        if rank == 0 {
            if let Some(log) = &opts.log {
                cmd.arg("--log").arg(log);
            }
            if let Some(out) = &opts.out {
                cmd.arg("--out").arg(out);
            }
        }
        match cmd.spawn() {
            Ok(c) => children.push(c),
            Err(e) => {
                kill_all(&mut children);
                return Err(crate::err_msg!("spawning worker rank {rank}: {e}"));
            }
        }
    }

    // Hand out the peer table. If a child dies before its hello, the
    // accept deadline fires and we tear the job down.
    if let Err(e) = rendezvous::serve(&listener, opts.parts) {
        kill_all(&mut children);
        return Err(crate::err_msg!("rendezvous failed: {e}"));
    }

    let mut failed = Vec::new();
    for (rank, child) in children.iter_mut().enumerate() {
        let status = child.wait().with_context(|| format!("waiting for rank {rank}"))?;
        if !status.success() {
            failed.push(rank);
        }
    }
    if !failed.is_empty() {
        crate::bail!("worker ranks {failed:?} exited with failure");
    }
    Ok(())
}
