//! Deterministic per-link fault injection for the TCP mesh.
//!
//! A [`ChaosProfile`] (loaded from `--chaos profile.json`) describes,
//! per directed link, injected latency, jitter, a bandwidth cap, and a
//! frame-drop probability. The injector lives on the sender's per-peer
//! writer thread and acts *before* each data frame is written: a
//! "dropped" frame is withheld for one retransmission timeout (`rto_ms`)
//! and then sent — exactly what a TCP sender does — so the receiver
//! side exercises its real wait/deadline machinery rather than a
//! simulation shortcut. Because each link has a single writer draining
//! a FIFO, a delayed frame delays everything queued behind it, which is
//! precisely TCP head-of-line blocking.
//!
//! Two invariants make chaos safe to run under the bit-identity
//! oracles:
//!
//! * **Timing only.** Injection never reorders frames within a link and
//!   never changes which payload a tag resolves to, so loss curves stay
//!   bit-identical to an undisturbed run.
//! * **Accounting untouched.** Every frame is written exactly once, so
//!   payload/wire byte counters match the chaos-off run byte for byte.
//!
//! Injected faults are counted in the metrics registry as
//! `pipegcn_link_faults_total{src,dst,kind}` with `kind` ∈
//! {`drop`, `delay`}. The same fault vocabulary feeds the analytic
//! model: `sim::profiles::apply_chaos` degrades a simulated link by the
//! expected value of a [`LinkChaos`].
//!
//! Profile format (all fields optional; omitted numbers default to 0 /
//! off; `links` entries override `default` field-by-field):
//!
//! ```json
//! {
//!   "seed": 7,
//!   "recv_deadline_ms": 30000,
//!   "default": {"latency_ms": 20, "jitter_ms": 5, "drop": 0.01,
//!               "bandwidth_mbps": 200, "rto_ms": 50},
//!   "links": [{"src": 0, "dst": 1, "latency_ms": 80}]
//! }
//! ```

use crate::obs;
use crate::util::error::{Context, Result};
use crate::util::json::Json;
use crate::util::rng::Rng;
use std::time::Duration;

/// Retransmission timeout applied to a "dropped" frame when the profile
/// doesn't set `rto_ms`.
const DEFAULT_RTO_MS: f64 = 50.0;

/// Fault parameters for one directed link.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkChaos {
    /// Fixed delay added before every data frame, in ms.
    pub latency_ms: f64,
    /// Uniform extra delay in `[0, jitter_ms)` per frame.
    pub jitter_ms: f64,
    /// Per-frame drop probability in `[0, 1)`; each drop costs one RTO
    /// before the retransmission goes out (drops can repeat).
    pub drop: f64,
    /// Bandwidth cap in megabits/s (0 = unlimited): each frame is held
    /// for its serialization time at this rate.
    pub bandwidth_mbps: f64,
    /// Retransmission timeout charged per drop, in ms.
    pub rto_ms: f64,
}

impl Default for LinkChaos {
    fn default() -> Self {
        LinkChaos { latency_ms: 0.0, jitter_ms: 0.0, drop: 0.0, bandwidth_mbps: 0.0, rto_ms: DEFAULT_RTO_MS }
    }
}

impl LinkChaos {
    /// True when this link injects nothing (the writer path can skip
    /// the injector entirely).
    pub fn is_noop(&self) -> bool {
        self.latency_ms == 0.0 && self.jitter_ms == 0.0 && self.drop == 0.0 && self.bandwidth_mbps == 0.0
    }

    /// Expected added one-way latency in seconds (the analytic-model
    /// view of this link: mean jitter plus the expected geometric run
    /// of drop→RTO cycles).
    pub fn expected_extra_latency_s(&self) -> f64 {
        let drop_penalty_ms = if self.drop > 0.0 && self.drop < 1.0 {
            self.drop / (1.0 - self.drop) * self.rto_ms
        } else {
            0.0
        };
        (self.latency_ms + self.jitter_ms / 2.0 + drop_penalty_ms) / 1e3
    }

    /// Bandwidth cap in bytes/s, if any.
    pub fn bandwidth_bytes_per_s(&self) -> Option<f64> {
        (self.bandwidth_mbps > 0.0).then(|| self.bandwidth_mbps * 1e6 / 8.0)
    }
}

/// A parsed `--chaos` profile: a default link plus per-(src, dst)
/// overrides, one RNG seed for the whole mesh.
#[derive(Clone, Debug, Default)]
pub struct ChaosProfile {
    pub seed: u64,
    /// Optional receive-watchdog deadline to apply mesh-wide (the
    /// `--recv-deadline` flag still wins over this).
    pub recv_deadline_ms: Option<u64>,
    pub default: LinkChaos,
    links: Vec<(usize, usize, LinkChaos)>,
}

fn field(obj: &Json, key: &str, default: f64) -> std::result::Result<f64, String> {
    match obj.get(key) {
        None => Ok(default),
        Some(v) => v.as_f64().ok_or_else(|| format!("chaos profile: `{key}` must be a number")),
    }
}

fn parse_link(obj: &Json, base: &LinkChaos) -> std::result::Result<LinkChaos, String> {
    let c = LinkChaos {
        latency_ms: field(obj, "latency_ms", base.latency_ms)?,
        jitter_ms: field(obj, "jitter_ms", base.jitter_ms)?,
        drop: field(obj, "drop", base.drop)?,
        bandwidth_mbps: field(obj, "bandwidth_mbps", base.bandwidth_mbps)?,
        rto_ms: field(obj, "rto_ms", base.rto_ms)?,
    };
    for (name, v) in [
        ("latency_ms", c.latency_ms),
        ("jitter_ms", c.jitter_ms),
        ("bandwidth_mbps", c.bandwidth_mbps),
        ("rto_ms", c.rto_ms),
    ] {
        if !v.is_finite() || v < 0.0 {
            return Err(format!("chaos profile: `{name}` must be finite and >= 0, got {v}"));
        }
    }
    if !(0.0..1.0).contains(&c.drop) {
        return Err(format!("chaos profile: `drop` must be in [0, 1), got {}", c.drop));
    }
    Ok(c)
}

impl ChaosProfile {
    /// Parse a profile from JSON text.
    pub fn parse(text: &str) -> std::result::Result<ChaosProfile, String> {
        let root = Json::parse(text)?;
        if root.get("default").is_none() && root.get("links").is_none() {
            return Err("chaos profile: expected a `default` link and/or a `links` array".into());
        }
        let seed = match root.get("seed") {
            None => 0,
            Some(v) => v.as_f64().ok_or("chaos profile: `seed` must be a number")? as u64,
        };
        let recv_deadline_ms = match root.get("recv_deadline_ms") {
            None => None,
            Some(v) => {
                let ms = v.as_f64().ok_or("chaos profile: `recv_deadline_ms` must be a number")?;
                if ms < 1.0 {
                    return Err(format!("chaos profile: `recv_deadline_ms` must be >= 1, got {ms}"));
                }
                Some(ms as u64)
            }
        };
        let default = match root.get("default") {
            None => LinkChaos::default(),
            Some(obj) => parse_link(obj, &LinkChaos::default())?,
        };
        let mut links = Vec::new();
        if let Some(arr) = root.get("links") {
            let arr = arr.as_arr().ok_or("chaos profile: `links` must be an array")?;
            for entry in arr {
                let src = entry
                    .get("src")
                    .and_then(|v| v.as_usize())
                    .ok_or("chaos profile: each link needs an integer `src`")?;
                let dst = entry
                    .get("dst")
                    .and_then(|v| v.as_usize())
                    .ok_or("chaos profile: each link needs an integer `dst`")?;
                links.push((src, dst, parse_link(entry, &default)?));
            }
        }
        Ok(ChaosProfile { seed, recv_deadline_ms, default, links })
    }

    /// Load a profile from a file.
    pub fn load(path: &str) -> Result<ChaosProfile> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading chaos profile {path}"))?;
        ChaosProfile::parse(&text).with_context(|| format!("parsing chaos profile {path}"))
    }

    /// Fault parameters for the directed link `src -> dst`.
    pub fn link(&self, src: usize, dst: usize) -> LinkChaos {
        self.links
            .iter()
            .find(|(s, d, _)| *s == src && *d == dst)
            .map(|(_, _, c)| *c)
            .unwrap_or(self.default)
    }

    /// Build the writer-thread injector for `src -> dst`, or `None` if
    /// the link injects nothing. Deterministic: the per-link RNG stream
    /// depends only on `(seed, src, dst)`, never on creation order.
    pub fn injector(&self, src: usize, dst: usize) -> Option<LinkInjector> {
        let chaos = self.link(src, dst);
        if chaos.is_noop() {
            return None;
        }
        let rng = Rng::new(self.seed).fork(((src as u64) << 20) | dst as u64);
        Some(LinkInjector::new(chaos, rng, src, dst))
    }
}

/// Per-link fault injector, owned by one writer thread.
pub struct LinkInjector {
    chaos: LinkChaos,
    rng: Rng,
    drops: obs::Counter,
    delays: obs::Counter,
}

impl LinkInjector {
    fn new(chaos: LinkChaos, rng: Rng, src: usize, dst: usize) -> LinkInjector {
        let reg = obs::global();
        let s = src.to_string();
        let d = dst.to_string();
        LinkInjector {
            chaos,
            rng,
            drops: reg.counter("link_faults_total", &[("src", &s), ("dst", &d), ("kind", "drop")]),
            delays: reg.counter("link_faults_total", &[("src", &s), ("dst", &d), ("kind", "delay")]),
        }
    }

    /// Decide this frame's fate without sleeping: the number of drops
    /// it suffers and the total injected delay in ms. Split from
    /// [`Self::before_frame`] so determinism is testable without wall
    /// clock.
    fn plan(&mut self, wire_bytes: usize) -> (u32, f64) {
        let mut delay_ms = self.chaos.latency_ms + self.chaos.jitter_ms * self.rng.next_f64();
        if let Some(bps) = self.chaos.bandwidth_bytes_per_s() {
            delay_ms += wire_bytes as f64 / bps * 1e3;
        }
        let mut drops = 0u32;
        while self.chaos.drop > 0.0 && self.rng.next_f64() < self.chaos.drop {
            drops += 1;
            delay_ms += self.chaos.rto_ms;
        }
        (drops, delay_ms)
    }

    /// Apply the link's faults to one outgoing data frame of
    /// `wire_bytes` on-the-wire bytes. Called on the writer thread just
    /// before the frame is written; sleeping here stalls the link's
    /// whole FIFO behind this frame, like real head-of-line blocking.
    pub fn before_frame(&mut self, wire_bytes: usize) {
        let (drops, delay_ms) = self.plan(wire_bytes);
        for _ in 0..drops {
            self.drops.inc();
        }
        if delay_ms > 0.0 {
            self.delays.inc();
            std::thread::sleep(Duration::from_secs_f64(delay_ms / 1e3));
        }
    }
}

/// Total faults this process injected on its outgoing links (`src` is
/// this rank), summed over destinations and fault kinds — read back from
/// the metrics registry for the end-of-run report.
pub fn faults_from(src: usize, n_ranks: usize) -> u64 {
    let reg = obs::global();
    let s = src.to_string();
    let mut total = 0.0;
    for dst in 0..n_ranks {
        let d = dst.to_string();
        for kind in ["drop", "delay"] {
            total += reg
                .value("link_faults_total", &[("src", &s), ("dst", &d), ("kind", kind)])
                .unwrap_or(0.0);
        }
    }
    total as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    const PROFILE: &str = r#"{
        "seed": 7,
        "recv_deadline_ms": 30000,
        "default": {"latency_ms": 20, "jitter_ms": 5, "drop": 0.01, "rto_ms": 40},
        "links": [
            {"src": 0, "dst": 1, "latency_ms": 80, "bandwidth_mbps": 100},
            {"src": 1, "dst": 0, "drop": 0}
        ]
    }"#;

    #[test]
    fn profile_parses_with_per_link_overrides() {
        let p = ChaosProfile::parse(PROFILE).unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.recv_deadline_ms, Some(30000));
        // the default link
        let d = p.link(2, 3);
        assert_eq!(d.latency_ms, 20.0);
        assert_eq!(d.jitter_ms, 5.0);
        assert_eq!(d.drop, 0.01);
        assert_eq!(d.rto_ms, 40.0);
        assert_eq!(d.bandwidth_mbps, 0.0);
        // overrides replace only the named fields
        let l01 = p.link(0, 1);
        assert_eq!(l01.latency_ms, 80.0);
        assert_eq!(l01.jitter_ms, 5.0);
        assert_eq!(l01.bandwidth_mbps, 100.0);
        let l10 = p.link(1, 0);
        assert_eq!(l10.drop, 0.0);
        assert_eq!(l10.latency_ms, 20.0);
    }

    #[test]
    fn bad_profiles_are_rejected_with_a_field_name() {
        let e = ChaosProfile::parse(r#"{"default": {"drop": 1.5}}"#).unwrap_err();
        assert!(e.contains("drop"), "{e}");
        let e = ChaosProfile::parse(r#"{"default": {"latency_ms": -1}}"#).unwrap_err();
        assert!(e.contains("latency_ms"), "{e}");
        let e = ChaosProfile::parse(r#"{"links": [{"dst": 1}]}"#).unwrap_err();
        assert!(e.contains("src"), "{e}");
        let e = ChaosProfile::parse(r#"{"epochs": 3}"#).unwrap_err();
        assert!(e.contains("default"), "{e}");
        assert!(ChaosProfile::parse("not json").is_err());
    }

    #[test]
    fn noop_links_produce_no_injector() {
        let p = ChaosProfile::parse(r#"{"links": [{"src": 0, "dst": 1, "latency_ms": 2}]}"#).unwrap();
        assert!(p.injector(0, 1).is_some());
        assert!(p.injector(1, 0).is_none(), "default link is a no-op here");
        // rto alone doesn't make a link chaotic — only reachable via drop
        assert!(ChaosProfile::parse(r#"{"default": {"rto_ms": 99}}"#).unwrap().injector(0, 1).is_none());
    }

    #[test]
    fn injection_plan_is_deterministic_per_link() {
        let p = ChaosProfile::parse(r#"{"seed": 3, "default": {"latency_ms": 1, "jitter_ms": 4, "drop": 0.3, "rto_ms": 10}}"#)
            .unwrap();
        let plan = |src, dst| {
            let mut inj = p.injector(src, dst).unwrap();
            (0..64).map(|i| inj.plan(100 * (i + 1))).collect::<Vec<_>>()
        };
        assert_eq!(plan(0, 1), plan(0, 1), "same link, same seed, same plan");
        assert_ne!(plan(0, 1), plan(1, 0), "directed links draw independent streams");
        let total_drops: u32 = plan(0, 1).iter().map(|(d, _)| d).sum();
        assert!(total_drops > 0, "drop=0.3 over 64 frames should fire");
        for (_, delay) in plan(0, 1) {
            assert!((1.0..1.0 + 4.0 + 20.0 * 10.0).contains(&delay), "{delay}");
        }
    }

    #[test]
    fn bandwidth_cap_charges_serialization_time() {
        // 100 mbit/s = 12.5 MB/s: a 125 KB frame costs 10 ms on the wire
        let p = ChaosProfile::parse(r#"{"default": {"bandwidth_mbps": 100}}"#).unwrap();
        let mut inj = p.injector(0, 1).unwrap();
        let (drops, delay) = inj.plan(125_000);
        assert_eq!(drops, 0);
        assert!((delay - 10.0).abs() < 1e-9, "{delay}");
    }

    #[test]
    fn faults_from_sums_this_ranks_outgoing_counters() {
        // ranks far outside any real mesh in this test binary, so the
        // process-global registry can't be polluted by other tests
        let p = ChaosProfile::parse(
            r#"{"seed": 5, "default": {"latency_ms": 1, "drop": 0.5, "rto_ms": 1}}"#,
        )
        .unwrap();
        let before = faults_from(41, 43);
        let mut inj = p.injector(41, 42).unwrap();
        for _ in 0..8 {
            inj.before_frame(100);
        }
        assert!(
            faults_from(41, 43) >= before + 8,
            "every frame on this link injects at least a delay"
        );
    }

    #[test]
    fn expected_latency_mirrors_the_injector() {
        let c = LinkChaos { latency_ms: 20.0, jitter_ms: 5.0, drop: 0.01, bandwidth_mbps: 0.0, rto_ms: 50.0 };
        let want = (20.0 + 2.5 + 0.01 / 0.99 * 50.0) / 1e3;
        assert!((c.expected_extra_latency_s() - want).abs() < 1e-12);
        assert_eq!(LinkChaos::default().expected_extra_latency_s(), 0.0);
    }
}
