//! Rendezvous + mesh formation.
//!
//! One process (the launcher, or rank 0 standing alone) serves a known
//! address. Every rank binds its own mesh listener on an ephemeral port,
//! dials the rendezvous with `Hello{rank, mesh_addr}`, and blocks until
//! the `PeerTable` with all `n` addresses comes back. Then the all-to-all
//! mesh forms: each rank dials every peer (introducing itself with a
//! `Hello`) for its outbound sockets and accepts `n − 1` inbound ones.

use super::frame::{self, Frame};
use super::tcp::{accept_with_deadline, retry_connect, TcpTransport};
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

/// How long mesh/rendezvous formation may take before we abort.
pub const FORM_DEADLINE: Duration = Duration::from_secs(60);

fn io_err(msg: String) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
}

/// Serve one rendezvous round on `listener`: collect `Hello`s from all
/// `n` ranks, then answer each with the full peer-address table. Returns
/// the table (index = rank).
pub fn serve(listener: &TcpListener, n: usize) -> std::io::Result<Vec<String>> {
    let mut streams: Vec<Option<(TcpStream, String)>> = (0..n).map(|_| None).collect();
    let mut seen = 0usize;
    while seen < n {
        // read the hello straight off the stream — read_frame reads
        // byte-exact, so nothing beyond the frame is consumed. A read
        // timeout bounds a connector that never sends its hello (e.g. a
        // worker that died right after connect), so serve() cannot hang
        // past the formation deadline.
        let mut s = accept_with_deadline(listener, FORM_DEADLINE)?;
        s.set_read_timeout(Some(FORM_DEADLINE))?;
        match frame::read_frame(&mut s)? {
            Some(Frame::Hello { rank, addr }) => {
                let rank = rank as usize;
                if rank >= n {
                    return Err(io_err(format!("hello from rank {rank} but n = {n}")));
                }
                if streams[rank].is_some() {
                    return Err(io_err(format!("duplicate hello from rank {rank}")));
                }
                if addr.is_empty() {
                    return Err(io_err(format!("rank {rank} sent no mesh address")));
                }
                streams[rank] = Some((s, addr));
                seen += 1;
            }
            other => {
                let _ = s.flush();
                return Err(io_err(format!("expected hello, got {other:?}")));
            }
        }
    }
    let addrs: Vec<String> =
        streams.iter().map(|s| s.as_ref().unwrap().1.clone()).collect();
    let table = Frame::PeerTable { addrs: addrs.clone() };
    for entry in streams.iter_mut() {
        let (stream, _) = entry.as_mut().unwrap();
        frame::write_frame(stream, &table)?;
        stream.flush()?;
    }
    Ok(addrs)
}

/// Join the mesh as `rank` of `n`: rendezvous at `coord_addr`, then form
/// the all-to-all socket mesh and wrap it in a [`TcpTransport`].
pub fn connect(rank: usize, n: usize, coord_addr: &str) -> std::io::Result<TcpTransport> {
    assert!(rank < n, "rank {rank} out of range for {n} ranks");
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let my_addr = listener.local_addr()?.to_string();

    // --- rendezvous: announce, learn everyone's mesh address ----------
    let mut coord = retry_connect(coord_addr, FORM_DEADLINE)?;
    // the peer table legitimately takes until every rank has joined, but
    // never longer than the formation deadline
    coord.set_read_timeout(Some(FORM_DEADLINE))?;
    frame::write_frame(&mut coord, &Frame::Hello { rank: rank as u16, addr: my_addr })?;
    coord.flush()?;
    let addrs = match frame::read_frame(&mut coord)? {
        Some(Frame::PeerTable { addrs }) => addrs,
        other => return Err(io_err(format!("expected peer table, got {other:?}"))),
    };
    if addrs.len() != n {
        return Err(io_err(format!("peer table has {} entries, expected {n}", addrs.len())));
    }
    drop(coord);

    // --- outbound: dial every peer, introduce ourselves ---------------
    // Dials succeed as soon as the peer's listener is bound (backlog),
    // so dialing everything before accepting anything cannot deadlock.
    let mut outbound: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
    for (peer, addr) in addrs.iter().enumerate() {
        if peer == rank {
            continue;
        }
        let mut s = retry_connect(addr, FORM_DEADLINE)?;
        frame::write_frame(&mut s, &Frame::Hello { rank: rank as u16, addr: String::new() })?;
        s.flush()?;
        outbound[peer] = Some(s);
    }

    // --- inbound: accept n − 1 peers, identified by their hello -------
    let mut inbound: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
    for _ in 0..n.saturating_sub(1) {
        let mut s = accept_with_deadline(&listener, FORM_DEADLINE)?;
        // read the hello straight off the stream (byte-exact): data
        // frames may already be queued right behind it from a fast peer,
        // and an intermediate BufReader would swallow them. The read
        // timeout bounds a silent connector; it is cleared before the
        // stream becomes a long-lived data socket.
        s.set_read_timeout(Some(FORM_DEADLINE))?;
        match frame::read_frame(&mut s)? {
            Some(Frame::Hello { rank: peer, .. }) => {
                let peer = peer as usize;
                if peer >= n || peer == rank {
                    return Err(io_err(format!("bad mesh hello from rank {peer}")));
                }
                if inbound[peer].is_some() {
                    return Err(io_err(format!("duplicate mesh connection from {peer}")));
                }
                s.set_read_timeout(None)?;
                inbound[peer] = Some(s);
            }
            other => return Err(io_err(format!("expected mesh hello, got {other:?}"))),
        }
    }
    Ok(TcpTransport::from_streams(rank, outbound, inbound))
}

/// Test/demo helper: a full `n`-rank mesh over localhost in one process
/// (rendezvous served from a scratch thread, one connect thread per
/// rank). Returns transports indexed by rank.
pub fn localhost_mesh(n: usize) -> std::io::Result<Vec<TcpTransport>> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let coord_addr = listener.local_addr()?.to_string();
    let server = std::thread::spawn(move || serve(&listener, n));
    let joiners: Vec<_> = (0..n)
        .map(|r| {
            let addr = coord_addr.clone();
            std::thread::spawn(move || connect(r, n, &addr))
        })
        .collect();
    let mut out = Vec::with_capacity(n);
    for j in joiners {
        out.push(j.join().expect("mesh thread panicked")?);
    }
    server.join().expect("rendezvous thread panicked")?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rendezvous_hands_out_consistent_table() {
        // exercised end-to-end by localhost_mesh: every rank got a table
        // consistent enough to form the full mesh
        let mut mesh = localhost_mesh(4).unwrap();
        assert_eq!(mesh.len(), 4);
        for (r, t) in mesh.iter().enumerate() {
            assert_eq!(t.rank(), r);
        }
        for m in &mut mesh {
            m.shutdown();
        }
    }

    #[test]
    fn single_rank_mesh_is_trivial() {
        let mut mesh = localhost_mesh(1).unwrap();
        assert_eq!(mesh[0].rank(), 0);
        mesh[0].shutdown();
    }

    #[test]
    fn bad_frame_on_rendezvous_rejected() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || serve(&listener, 1));
        let mut s = retry_connect(&addr, FORM_DEADLINE).unwrap();
        frame::write_frame(&mut s, &Frame::Shutdown { src: 0 }).unwrap();
        s.flush().unwrap();
        assert!(server.join().unwrap().is_err());
    }
}
