//! Rendezvous + mesh formation.
//!
//! One process (the launcher, or rank 0 standing alone) serves a known
//! address. Every rank binds its own mesh listener — on loopback by
//! default, or on the interface named by `--bind` for multi-node runs —
//! dials the rendezvous with `Hello{rank, mesh_addr}`, and blocks until
//! the `PeerTable` with all `n` addresses comes back. Then the
//! all-to-all mesh forms: each rank dials every peer (introducing itself
//! with a `Hello`) for its outbound sockets and accepts `n − 1` inbound
//! ones. Advertised addresses must be routable: a wildcard (`0.0.0.0` /
//! `[::]`) bind cannot be dialed by peers, so both the advertising rank
//! and the rendezvous reject it with a diagnostic naming `--bind`.

use super::frame::{self, Frame};
use super::tcp::{accept_with_deadline, retry_connect, retry_connect_limited, TcpTransport};
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

/// How long mesh/rendezvous formation may take before we abort.
pub const FORM_DEADLINE: Duration = Duration::from_secs(60);

fn io_err(msg: String) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
}

/// Mesh-joining knobs for [`connect_with`]. The defaults reproduce the
/// single-host behavior ([`connect`]): loopback bind, the formation
/// deadline, unlimited dial attempts within it.
#[derive(Clone, Debug)]
pub struct ConnectOpts {
    /// local `HOST:PORT` the mesh listener binds (`--bind`). Peers dial
    /// the resulting address, so it must name a routable interface —
    /// wildcards are rejected. Port 0 picks an ephemeral port.
    pub bind: String,
    /// overall deadline for dialing the rendezvous (`--connect-timeout`)
    pub timeout: Duration,
    /// rendezvous dial attempts before giving up (`--connect-retries`;
    /// 0 = unlimited within `timeout`)
    pub retries: usize,
}

impl Default for ConnectOpts {
    fn default() -> ConnectOpts {
        ConnectOpts { bind: "127.0.0.1:0".to_string(), timeout: FORM_DEADLINE, retries: 0 }
    }
}

/// Is `addr` a wildcard address no peer can dial?
fn is_unroutable(addr: &str) -> bool {
    addr.starts_with("0.0.0.0:") || addr.starts_with("[::]:")
}

/// Serve one rendezvous round on `listener`: collect `Hello`s from all
/// `n` ranks, then answer each with the full peer-address table. Returns
/// the table (index = rank).
pub fn serve(listener: &TcpListener, n: usize) -> std::io::Result<Vec<String>> {
    let mut streams: Vec<Option<(TcpStream, String)>> = (0..n).map(|_| None).collect();
    let mut seen = 0usize;
    while seen < n {
        // read the hello straight off the stream — read_frame reads
        // byte-exact, so nothing beyond the frame is consumed. A read
        // timeout bounds a connector that never sends its hello (e.g. a
        // worker that died right after connect), so serve() cannot hang
        // past the formation deadline.
        let mut s = accept_with_deadline(listener, FORM_DEADLINE)?;
        s.set_read_timeout(Some(FORM_DEADLINE))?;
        match frame::read_frame(&mut s)? {
            Some(Frame::Hello { rank, addr }) => {
                let rank = rank as usize;
                if rank >= n {
                    return Err(io_err(format!("hello from rank {rank} but n = {n}")));
                }
                if streams[rank].is_some() {
                    return Err(io_err(format!("duplicate hello from rank {rank}")));
                }
                if addr.is_empty() {
                    return Err(io_err(format!("rank {rank} sent no mesh address")));
                }
                if is_unroutable(&addr) {
                    return Err(io_err(format!(
                        "rank {rank} advertised unroutable mesh address {addr} — peers \
                         cannot dial a wildcard; rebind that worker with \
                         --bind HOST:PORT on a routable interface"
                    )));
                }
                streams[rank] = Some((s, addr));
                seen += 1;
            }
            other => {
                let _ = s.flush();
                return Err(io_err(format!("expected hello, got {other:?}")));
            }
        }
    }
    let addrs: Vec<String> =
        streams.iter().map(|s| s.as_ref().unwrap().1.clone()).collect();
    let table = Frame::PeerTable { addrs: addrs.clone() };
    for entry in streams.iter_mut() {
        let (stream, _) = entry.as_mut().unwrap();
        frame::write_frame(stream, &table)?;
        stream.flush()?;
    }
    Ok(addrs)
}

/// Join the mesh as `rank` of `n`: rendezvous at `coord_addr`, then form
/// the all-to-all socket mesh and wrap it in a [`TcpTransport`]. Binds
/// on loopback — multi-node workers use [`connect_with`] and `--bind`.
pub fn connect(rank: usize, n: usize, coord_addr: &str) -> std::io::Result<TcpTransport> {
    connect_with(rank, n, coord_addr, &ConnectOpts::default())
}

/// [`connect`] with explicit binding/dialing knobs ([`ConnectOpts`]).
pub fn connect_with(
    rank: usize,
    n: usize,
    coord_addr: &str,
    opts: &ConnectOpts,
) -> std::io::Result<TcpTransport> {
    assert!(rank < n, "rank {rank} out of range for {n} ranks");
    let listener = TcpListener::bind(&opts.bind)
        .map_err(|e| io_err(format!("binding the mesh listener on {}: {e}", opts.bind)))?;
    let my_addr = listener.local_addr()?.to_string();
    if is_unroutable(&my_addr) {
        return Err(io_err(format!(
            "mesh listener bound {my_addr}, which peers cannot dial — pass \
             --bind HOST:PORT naming a routable interface instead of the wildcard"
        )));
    }

    // --- rendezvous: announce, learn everyone's mesh address ----------
    let mut coord = retry_connect_limited(coord_addr, opts.timeout, opts.retries)?;
    // the peer table legitimately takes until every rank has joined, but
    // never longer than the formation deadline
    coord.set_read_timeout(Some(FORM_DEADLINE))?;
    frame::write_frame(&mut coord, &Frame::Hello { rank: rank as u16, addr: my_addr })?;
    coord.flush()?;
    let addrs = match frame::read_frame(&mut coord)? {
        Some(Frame::PeerTable { addrs }) => addrs,
        other => return Err(io_err(format!("expected peer table, got {other:?}"))),
    };
    if addrs.len() != n {
        return Err(io_err(format!("peer table has {} entries, expected {n}", addrs.len())));
    }
    // a rendezvous that predates the routability check could still hand
    // out a wildcard — refuse to dial it with the same diagnostic
    if let Some((peer, bad)) = addrs.iter().enumerate().find(|(_, a)| is_unroutable(a)) {
        return Err(io_err(format!(
            "peer table entry for rank {peer} is the wildcard {bad}; that worker \
             must be rebound with --bind HOST:PORT on a routable interface"
        )));
    }
    drop(coord);

    // --- outbound: dial every peer, introduce ourselves ---------------
    // Dials succeed as soon as the peer's listener is bound (backlog),
    // so dialing everything before accepting anything cannot deadlock.
    let mut outbound: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
    for (peer, addr) in addrs.iter().enumerate() {
        if peer == rank {
            continue;
        }
        let mut s = retry_connect(addr, FORM_DEADLINE)?;
        frame::write_frame(&mut s, &Frame::Hello { rank: rank as u16, addr: String::new() })?;
        s.flush()?;
        outbound[peer] = Some(s);
    }

    // --- inbound: accept n − 1 peers, identified by their hello -------
    let mut inbound: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
    for _ in 0..n.saturating_sub(1) {
        let mut s = accept_with_deadline(&listener, FORM_DEADLINE)?;
        // read the hello straight off the stream (byte-exact): data
        // frames may already be queued right behind it from a fast peer,
        // and an intermediate BufReader would swallow them. The read
        // timeout bounds a silent connector; it is cleared before the
        // stream becomes a long-lived data socket.
        s.set_read_timeout(Some(FORM_DEADLINE))?;
        match frame::read_frame(&mut s)? {
            Some(Frame::Hello { rank: peer, .. }) => {
                let peer = peer as usize;
                if peer >= n || peer == rank {
                    return Err(io_err(format!("bad mesh hello from rank {peer}")));
                }
                if inbound[peer].is_some() {
                    return Err(io_err(format!("duplicate mesh connection from {peer}")));
                }
                s.set_read_timeout(None)?;
                inbound[peer] = Some(s);
            }
            other => return Err(io_err(format!("expected mesh hello, got {other:?}"))),
        }
    }
    Ok(TcpTransport::from_streams(rank, outbound, inbound))
}

/// Test/demo helper: a full `n`-rank mesh over localhost in one process
/// (rendezvous served from a scratch thread, one connect thread per
/// rank). Returns transports indexed by rank.
pub fn localhost_mesh(n: usize) -> std::io::Result<Vec<TcpTransport>> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let coord_addr = listener.local_addr()?.to_string();
    let server = std::thread::spawn(move || serve(&listener, n));
    let joiners: Vec<_> = (0..n)
        .map(|r| {
            let addr = coord_addr.clone();
            std::thread::spawn(move || connect(r, n, &addr))
        })
        .collect();
    let mut out = Vec::with_capacity(n);
    for j in joiners {
        out.push(j.join().expect("mesh thread panicked")?);
    }
    server.join().expect("rendezvous thread panicked")?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rendezvous_hands_out_consistent_table() {
        // exercised end-to-end by localhost_mesh: every rank got a table
        // consistent enough to form the full mesh
        let mut mesh = localhost_mesh(4).unwrap();
        assert_eq!(mesh.len(), 4);
        for (r, t) in mesh.iter().enumerate() {
            assert_eq!(t.rank(), r);
        }
        for m in &mut mesh {
            m.shutdown();
        }
    }

    #[test]
    fn single_rank_mesh_is_trivial() {
        let mut mesh = localhost_mesh(1).unwrap();
        assert_eq!(mesh[0].rank(), 0);
        mesh[0].shutdown();
    }

    #[test]
    fn bad_frame_on_rendezvous_rejected() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || serve(&listener, 1));
        let mut s = retry_connect(&addr, FORM_DEADLINE).unwrap();
        frame::write_frame(&mut s, &Frame::Shutdown { src: 0 }).unwrap();
        s.flush().unwrap();
        assert!(server.join().unwrap().is_err());
    }

    /// A worker bound to the wildcard advertises an address no peer can
    /// dial; the error must surface before mesh formation and name the
    /// fix (`--bind`).
    #[test]
    fn wildcard_bind_rejected_at_the_worker() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let coord = listener.local_addr().unwrap().to_string();
        let opts =
            ConnectOpts { bind: "0.0.0.0:0".to_string(), ..ConnectOpts::default() };
        let e = connect_with(0, 2, &coord, &opts).unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("--bind"), "error must name the flag: {msg}");
        assert!(msg.contains("0.0.0.0"), "{msg}");
    }

    /// The rendezvous side independently rejects a wildcard hello, so a
    /// misconfigured worker cannot poison the peer table.
    #[test]
    fn wildcard_hello_rejected_at_the_rendezvous() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || serve(&listener, 1));
        let mut s = retry_connect(&addr, FORM_DEADLINE).unwrap();
        frame::write_frame(
            &mut s,
            &Frame::Hello { rank: 0, addr: "0.0.0.0:9000".to_string() },
        )
        .unwrap();
        s.flush().unwrap();
        let e = server.join().unwrap().unwrap_err();
        assert!(e.to_string().contains("--bind"), "{e}");
    }

    /// `--connect-retries` bounds the dial attempts: a dead coordinator
    /// address fails after N tries instead of sitting out the deadline.
    #[test]
    fn bounded_retries_fail_fast_on_a_dead_address() {
        // bind-then-drop: the port was just free, so dialing it refuses
        let dead = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let opts = ConnectOpts {
            timeout: Duration::from_secs(30),
            retries: 2,
            ..ConnectOpts::default()
        };
        let started = std::time::Instant::now();
        let e = connect_with(0, 2, &dead, &opts).unwrap_err();
        assert!(started.elapsed() < Duration::from_secs(10), "did not fail fast");
        assert!(e.to_string().contains("attempt"), "{e}");
    }
}
