//! Rendezvous + mesh formation.
//!
//! One process (the launcher, or rank 0 standing alone) serves a known
//! address. Every rank binds its own mesh listener — on loopback by
//! default, or on the interface named by `--bind` for multi-node runs —
//! dials the rendezvous with `Hello{rank, mesh_addr}`, and blocks until
//! the `PeerTable` with all `n` addresses comes back. Then the
//! all-to-all mesh forms: each rank dials every peer (introducing itself
//! with a `Hello`) for its outbound sockets and accepts `n − 1` inbound
//! ones. Advertised addresses must be routable: a wildcard (`0.0.0.0` /
//! `[::]`) bind cannot be dialed by peers, so both the advertising rank
//! and the rendezvous reject it with a diagnostic naming `--bind`.
//!
//! Two hardening layers ride on the same exchange:
//!
//! * **Auth** — with a shared secret configured (`--mesh-secret` /
//!   `PIPEGCN_MESH_SECRET`), every `Hello` — to the rendezvous *and* on
//!   every mesh socket — is answered with an [`Frame::AuthChallenge`]
//!   nonce that the joiner must MAC with the secret
//!   (HMAC-SHA256 over nonce ‖ rank ‖ addr). A join presenting a wrong
//!   MAC is rejected with a diagnostic naming the rank and address.
//!   With no secret set, no auth frames are exchanged and the wire is
//!   byte-for-byte the unauthenticated protocol.
//! * **Rejoin rounds** — the same `serve` machinery re-forms a *live*
//!   mesh after a worker death: the launcher serves another round on
//!   the same address (survivors reconnect, a replacement joins in the
//!   dead rank's place) and closes it with a [`Frame::Resume`] naming
//!   the checkpoint epoch every rank restores before training resumes.

use super::chaos::ChaosProfile;
use super::frame::{self, Frame};
use super::tcp::{
    accept_with_deadline, retry_connect, retry_connect_limited, TcpTransport, RECV_DEADLINE,
};
use crate::util::rng::splitmix64;
use crate::util::sha256::{hmac_sha256, macs_equal};
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

/// Default ceiling on mesh/rendezvous formation (`--form-deadline`).
pub const FORM_DEADLINE: Duration = Duration::from_secs(60);

fn io_err(msg: String) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
}

/// Mesh-joining knobs for [`connect_with`]. The defaults reproduce the
/// single-host behavior ([`connect`]): loopback bind, the formation
/// deadline, unlimited dial attempts within it, no auth, no chaos.
#[derive(Clone, Debug)]
pub struct ConnectOpts {
    /// local `HOST:PORT` the mesh listener binds (`--bind`). Peers dial
    /// the resulting address, so it must name a routable interface —
    /// wildcards are rejected. Port 0 picks an ephemeral port.
    pub bind: String,
    /// overall deadline for dialing the rendezvous (`--connect-timeout`)
    pub timeout: Duration,
    /// rendezvous dial attempts before giving up (`--connect-retries`;
    /// 0 = unlimited within `timeout`)
    pub retries: usize,
    /// ceiling on each mesh-formation step (`--form-deadline`)
    pub form_deadline: Duration,
    /// shared mesh secret (`--mesh-secret` / `PIPEGCN_MESH_SECRET`);
    /// when set, every hello this rank sends answers an HMAC challenge
    pub secret: Option<String>,
    /// fault-injection profile (`--chaos`) applied to this rank's
    /// outgoing links
    pub chaos: Option<ChaosProfile>,
    /// receive-watchdog override (`--recv-deadline`); defaults to the
    /// chaos profile's `recv_deadline_ms`, else [`RECV_DEADLINE`]
    pub recv_deadline: Option<Duration>,
    /// true when joining a live-rejoin round: the rendezvous closes the
    /// round with a `Resume{epoch}` frame that [`connect_session`]
    /// returns to the caller
    pub expect_resume: bool,
}

impl Default for ConnectOpts {
    fn default() -> ConnectOpts {
        ConnectOpts {
            bind: "127.0.0.1:0".to_string(),
            timeout: FORM_DEADLINE,
            retries: 0,
            form_deadline: FORM_DEADLINE,
            secret: None,
            chaos: None,
            recv_deadline: None,
            expect_resume: false,
        }
    }
}

/// Knobs for one rendezvous round ([`serve_with`]).
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// ceiling on the whole round (`--form-deadline`)
    pub deadline: Duration,
    /// shared mesh secret; when set, every joiner is challenged
    pub secret: Option<String>,
    /// when set, this is a live-rejoin round: after the peer table,
    /// every rank is told to restore from this checkpoint epoch
    pub resume_epoch: Option<u64>,
}

impl Default for ServeOpts {
    fn default() -> ServeOpts {
        ServeOpts { deadline: FORM_DEADLINE, secret: None, resume_epoch: None }
    }
}

/// Is `addr` a wildcard address no peer can dial?
fn is_unroutable(addr: &str) -> bool {
    addr.starts_with("0.0.0.0:") || addr.starts_with("[::]:")
}

/// A fresh 16-byte challenge nonce. Not a CSPRNG — the secret's
/// strength carries the auth; the nonce only has to be unpredictable
/// enough never to repeat across handshakes.
fn fresh_nonce() -> [u8; 16] {
    use std::sync::atomic::{AtomicU64, Ordering};
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let now = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    let mut state = now
        ^ (std::process::id() as u64).rotate_left(32)
        ^ COUNTER.fetch_add(1, Ordering::Relaxed).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let mut nonce = [0u8; 16];
    nonce[..8].copy_from_slice(&splitmix64(&mut state).to_le_bytes());
    nonce[8..].copy_from_slice(&splitmix64(&mut state).to_le_bytes());
    nonce
}

/// The MAC a joiner presents: HMAC-SHA256(secret, nonce ‖ rank ‖ addr),
/// binding the response to this handshake's hello.
fn hello_mac(secret: &str, nonce: &[u8; 16], rank: u16, addr: &str) -> [u8; 32] {
    let mut msg = Vec::with_capacity(16 + 2 + addr.len());
    msg.extend_from_slice(nonce);
    msg.extend_from_slice(&rank.to_le_bytes());
    msg.extend_from_slice(addr.as_bytes());
    hmac_sha256(secret.as_bytes(), &msg)
}

/// Accepting side of the auth handshake: challenge the joiner whose
/// `Hello{rank, addr}` was just read off `s`, verify the response.
fn challenge_peer(
    s: &mut TcpStream,
    secret: &str,
    rank: usize,
    addr: &str,
    what: &str,
) -> std::io::Result<()> {
    let nonce = fresh_nonce();
    frame::write_frame(s, &Frame::AuthChallenge { nonce })?;
    s.flush()?;
    let who = if addr.is_empty() {
        format!("rank {rank}")
    } else {
        format!("rank {rank} ({addr})")
    };
    match frame::read_frame(s)? {
        Some(Frame::AuthResponse { mac }) => {
            if !macs_equal(&mac, &hello_mac(secret, &nonce, rank as u16, addr)) {
                return Err(io_err(format!(
                    "mesh auth failed: {what} from {who} presented a MAC that does not \
                     match the shared secret — join rejected"
                )));
            }
            Ok(())
        }
        other => Err(io_err(format!(
            "mesh auth failed: {what} from {who} answered the challenge with {other:?} \
             — is --mesh-secret set on that process?"
        ))),
    }
}

/// Dialing side of the auth handshake: read the challenge the accepter
/// sends right after our `Hello{rank, addr}` and answer it.
fn answer_challenge(
    s: &mut TcpStream,
    secret: &str,
    rank: u16,
    addr: &str,
    what: &str,
) -> std::io::Result<()> {
    match frame::read_frame(s)? {
        Some(Frame::AuthChallenge { nonce }) => {
            frame::write_frame(s, &Frame::AuthResponse { mac: hello_mac(secret, &nonce, rank, addr) })?;
            s.flush()
        }
        other => Err(io_err(format!(
            "--mesh-secret is set here but the {what} answered with {other:?} instead \
             of an auth challenge — it has no mesh secret configured"
        ))),
    }
}

/// Serve one rendezvous round on `listener`: collect `Hello`s from all
/// `n` ranks, then answer each with the full peer-address table. Returns
/// the table (index = rank).
pub fn serve(listener: &TcpListener, n: usize) -> std::io::Result<Vec<String>> {
    serve_with(listener, n, &ServeOpts::default())
}

/// [`serve`] with explicit deadline/auth/rejoin knobs ([`ServeOpts`]).
pub fn serve_with(
    listener: &TcpListener,
    n: usize,
    opts: &ServeOpts,
) -> std::io::Result<Vec<String>> {
    let started = std::time::Instant::now();
    let mut streams: Vec<Option<(TcpStream, String)>> = (0..n).map(|_| None).collect();
    let mut seen = 0usize;
    while seen < n {
        // read the hello straight off the stream — read_frame reads
        // byte-exact, so nothing beyond the frame is consumed. A read
        // timeout bounds a connector that never sends its hello (e.g. a
        // worker that died right after connect), so serve() cannot hang
        // past the formation deadline — which counts down across the
        // whole round, not per accept.
        let remaining = opts.deadline.saturating_sub(started.elapsed());
        let mut s = accept_with_deadline(listener, remaining).map_err(|e| {
            if e.kind() == std::io::ErrorKind::TimedOut {
                let missing: Vec<usize> =
                    (0..n).filter(|&r| streams[r].is_none()).collect();
                io_err(format!(
                    "mesh formation timed out after {:.0?}: ranks {missing:?} never \
                     arrived ({seen} of {n} joined) — raise --form-deadline if the \
                     hosts are just slow",
                    opts.deadline
                ))
            } else {
                e
            }
        })?;
        s.set_read_timeout(Some(opts.deadline))?;
        match frame::read_frame(&mut s)? {
            Some(Frame::Hello { rank, addr }) => {
                let rank = rank as usize;
                if rank >= n {
                    return Err(io_err(format!("hello from rank {rank} but n = {n}")));
                }
                if streams[rank].is_some() {
                    return Err(io_err(format!("duplicate hello from rank {rank}")));
                }
                if addr.is_empty() {
                    return Err(io_err(format!("rank {rank} sent no mesh address")));
                }
                if is_unroutable(&addr) {
                    return Err(io_err(format!(
                        "rank {rank} advertised unroutable mesh address {addr} — peers \
                         cannot dial a wildcard; rebind that worker with \
                         --bind HOST:PORT on a routable interface"
                    )));
                }
                if let Some(secret) = &opts.secret {
                    challenge_peer(&mut s, secret, rank, &addr, "rendezvous hello")?;
                }
                streams[rank] = Some((s, addr));
                seen += 1;
            }
            other => {
                let _ = s.flush();
                return Err(io_err(format!("expected hello, got {other:?}")));
            }
        }
    }
    let addrs: Vec<String> =
        streams.iter().map(|s| s.as_ref().unwrap().1.clone()).collect();
    let table = Frame::PeerTable { addrs: addrs.clone() };
    for entry in streams.iter_mut() {
        let (stream, _) = entry.as_mut().unwrap();
        frame::write_frame(stream, &table)?;
        if let Some(epoch) = opts.resume_epoch {
            frame::write_frame(stream, &Frame::Resume { epoch })?;
        }
        stream.flush()?;
    }
    Ok(addrs)
}

/// Join the mesh as `rank` of `n`: rendezvous at `coord_addr`, then form
/// the all-to-all socket mesh and wrap it in a [`TcpTransport`]. Binds
/// on loopback — multi-node workers use [`connect_with`] and `--bind`.
pub fn connect(rank: usize, n: usize, coord_addr: &str) -> std::io::Result<TcpTransport> {
    connect_with(rank, n, coord_addr, &ConnectOpts::default())
}

/// [`connect`] with explicit binding/dialing knobs ([`ConnectOpts`]).
pub fn connect_with(
    rank: usize,
    n: usize,
    coord_addr: &str,
    opts: &ConnectOpts,
) -> std::io::Result<TcpTransport> {
    connect_session(rank, n, coord_addr, opts).map(|(t, _)| t)
}

/// [`connect_with`], also surfacing the rejoin epilogue: on a
/// live-rejoin round (`opts.expect_resume`) the rendezvous follows the
/// peer table with `Resume{epoch}` — the checkpoint epoch this rank
/// must restore before training resumes.
pub fn connect_session(
    rank: usize,
    n: usize,
    coord_addr: &str,
    opts: &ConnectOpts,
) -> std::io::Result<(TcpTransport, Option<u64>)> {
    assert!(rank < n, "rank {rank} out of range for {n} ranks");
    let form_deadline = opts.form_deadline;
    let listener = TcpListener::bind(&opts.bind)
        .map_err(|e| io_err(format!("binding the mesh listener on {}: {e}", opts.bind)))?;
    let my_addr = listener.local_addr()?.to_string();
    if is_unroutable(&my_addr) {
        return Err(io_err(format!(
            "mesh listener bound {my_addr}, which peers cannot dial — pass \
             --bind HOST:PORT naming a routable interface instead of the wildcard"
        )));
    }

    // --- rendezvous: announce, learn everyone's mesh address ----------
    let mut coord = retry_connect_limited(coord_addr, opts.timeout, opts.retries)?;
    // the peer table legitimately takes until every rank has joined, but
    // never longer than the formation deadline
    coord.set_read_timeout(Some(form_deadline))?;
    frame::write_frame(
        &mut coord,
        &Frame::Hello { rank: rank as u16, addr: my_addr.clone() },
    )?;
    coord.flush()?;
    if let Some(secret) = &opts.secret {
        answer_challenge(&mut coord, secret, rank as u16, &my_addr, "rendezvous")?;
    }
    let addrs = match frame::read_frame(&mut coord)? {
        Some(Frame::PeerTable { addrs }) => addrs,
        Some(Frame::AuthChallenge { .. }) => {
            return Err(io_err(
                "the rendezvous requires mesh auth — set --mesh-secret (or \
                 PIPEGCN_MESH_SECRET) on this worker"
                    .to_string(),
            ))
        }
        other => return Err(io_err(format!("expected peer table, got {other:?}"))),
    };
    if addrs.len() != n {
        return Err(io_err(format!("peer table has {} entries, expected {n}", addrs.len())));
    }
    // a rendezvous that predates the routability check could still hand
    // out a wildcard — refuse to dial it with the same diagnostic
    if let Some((peer, bad)) = addrs.iter().enumerate().find(|(_, a)| is_unroutable(a)) {
        return Err(io_err(format!(
            "peer table entry for rank {peer} is the wildcard {bad}; that worker \
             must be rebound with --bind HOST:PORT on a routable interface"
        )));
    }
    let resume_epoch = if opts.expect_resume {
        match frame::read_frame(&mut coord)? {
            Some(Frame::Resume { epoch }) => Some(epoch),
            other => {
                return Err(io_err(format!(
                    "rejoin round ended without a resume epoch (got {other:?})"
                )))
            }
        }
    } else {
        None
    };
    drop(coord);

    // --- mesh: dial every peer while accepting the n − 1 inbound ones.
    // The two halves run concurrently: with auth on, a dial blocks until
    // the peer's accept loop answers the challenge, so dial-then-accept
    // would deadlock (both sides dialing, nobody accepting).
    let dialed: Vec<Option<TcpStream>>;
    let accepted: Vec<Option<TcpStream>>;
    {
        let (d, a) = std::thread::scope(|sc| {
            let acceptor = sc.spawn(|| -> std::io::Result<Vec<Option<TcpStream>>> {
                let mut inbound: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
                for _ in 0..n.saturating_sub(1) {
                    let mut s = accept_with_deadline(&listener, form_deadline).map_err(|e| {
                        if e.kind() == std::io::ErrorKind::TimedOut {
                            let missing: Vec<usize> = (0..n)
                                .filter(|&p| p != rank && inbound[p].is_none())
                                .collect();
                            io_err(format!(
                                "mesh formation timed out after {form_deadline:.0?}: \
                                 peers {missing:?} never dialed rank {rank}"
                            ))
                        } else {
                            e
                        }
                    })?;
                    // read the hello straight off the stream (byte-exact):
                    // data frames may already be queued right behind it from
                    // a fast peer, and an intermediate BufReader would
                    // swallow them. The read timeout bounds a silent
                    // connector; it is cleared before the stream becomes a
                    // long-lived data socket.
                    s.set_read_timeout(Some(form_deadline))?;
                    match frame::read_frame(&mut s)? {
                        Some(Frame::Hello { rank: peer, addr }) => {
                            let peer = peer as usize;
                            if peer >= n || peer == rank {
                                return Err(io_err(format!("bad mesh hello from rank {peer}")));
                            }
                            if inbound[peer].is_some() {
                                return Err(io_err(format!(
                                    "duplicate mesh connection from {peer}"
                                )));
                            }
                            if let Some(secret) = &opts.secret {
                                challenge_peer(&mut s, secret, peer, &addr, "mesh hello")?;
                            }
                            s.set_read_timeout(None)?;
                            inbound[peer] = Some(s);
                        }
                        other => {
                            return Err(io_err(format!("expected mesh hello, got {other:?}")))
                        }
                    }
                }
                Ok(inbound)
            });
            let dial = || -> std::io::Result<Vec<Option<TcpStream>>> {
                let mut outbound: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
                for (peer, addr) in addrs.iter().enumerate() {
                    if peer == rank {
                        continue;
                    }
                    let mut s = retry_connect(addr, form_deadline)?;
                    frame::write_frame(
                        &mut s,
                        &Frame::Hello { rank: rank as u16, addr: String::new() },
                    )?;
                    s.flush()?;
                    if let Some(secret) = &opts.secret {
                        s.set_read_timeout(Some(form_deadline))?;
                        answer_challenge(&mut s, secret, rank as u16, "", "mesh peer")?;
                        s.set_read_timeout(None)?;
                    }
                    outbound[peer] = Some(s);
                }
                Ok(outbound)
            };
            let outbound = dial();
            let inbound = acceptor.join().expect("mesh accept thread panicked");
            (outbound, inbound)
        });
        dialed = d?;
        accepted = a?;
    }
    let recv_deadline = opts
        .recv_deadline
        .or_else(|| {
            opts.chaos
                .as_ref()
                .and_then(|c| c.recv_deadline_ms)
                .map(Duration::from_millis)
        })
        .unwrap_or(RECV_DEADLINE);
    let transport = TcpTransport::from_streams_tuned(
        rank,
        dialed,
        accepted,
        opts.chaos.as_ref(),
        recv_deadline,
    );
    Ok((transport, resume_epoch))
}

/// Test/demo helper: a full `n`-rank mesh over localhost in one process
/// (rendezvous served from a scratch thread, one connect thread per
/// rank). Returns transports indexed by rank.
pub fn localhost_mesh(n: usize) -> std::io::Result<Vec<TcpTransport>> {
    localhost_mesh_with(n, &ConnectOpts::default())
}

/// [`localhost_mesh`] with explicit joining knobs applied to every rank
/// (the rendezvous side mirrors the secret, so authenticated meshes
/// form).
pub fn localhost_mesh_with(n: usize, opts: &ConnectOpts) -> std::io::Result<Vec<TcpTransport>> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let coord_addr = listener.local_addr()?.to_string();
    let sopts = ServeOpts {
        deadline: opts.form_deadline,
        secret: opts.secret.clone(),
        resume_epoch: None,
    };
    let server = std::thread::spawn(move || serve_with(&listener, n, &sopts));
    let joiners: Vec<_> = (0..n)
        .map(|r| {
            let addr = coord_addr.clone();
            let opts = opts.clone();
            std::thread::spawn(move || connect_with(r, n, &addr, &opts))
        })
        .collect();
    let mut out = Vec::with_capacity(n);
    for j in joiners {
        out.push(j.join().expect("mesh thread panicked")?);
    }
    server.join().expect("rendezvous thread panicked")?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rendezvous_hands_out_consistent_table() {
        // exercised end-to-end by localhost_mesh: every rank got a table
        // consistent enough to form the full mesh
        let mut mesh = localhost_mesh(4).unwrap();
        assert_eq!(mesh.len(), 4);
        for (r, t) in mesh.iter().enumerate() {
            assert_eq!(t.rank(), r);
        }
        for m in &mut mesh {
            m.shutdown();
        }
    }

    #[test]
    fn single_rank_mesh_is_trivial() {
        let mut mesh = localhost_mesh(1).unwrap();
        assert_eq!(mesh[0].rank(), 0);
        mesh[0].shutdown();
    }

    #[test]
    fn bad_frame_on_rendezvous_rejected() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || serve(&listener, 1));
        let mut s = retry_connect(&addr, FORM_DEADLINE).unwrap();
        frame::write_frame(&mut s, &Frame::Shutdown { src: 0 }).unwrap();
        s.flush().unwrap();
        assert!(server.join().unwrap().is_err());
    }

    /// A worker bound to the wildcard advertises an address no peer can
    /// dial; the error must surface before mesh formation and name the
    /// fix (`--bind`).
    #[test]
    fn wildcard_bind_rejected_at_the_worker() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let coord = listener.local_addr().unwrap().to_string();
        let opts =
            ConnectOpts { bind: "0.0.0.0:0".to_string(), ..ConnectOpts::default() };
        let e = connect_with(0, 2, &coord, &opts).unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("--bind"), "error must name the flag: {msg}");
        assert!(msg.contains("0.0.0.0"), "{msg}");
    }

    /// The rendezvous side independently rejects a wildcard hello, so a
    /// misconfigured worker cannot poison the peer table.
    #[test]
    fn wildcard_hello_rejected_at_the_rendezvous() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || serve(&listener, 1));
        let mut s = retry_connect(&addr, FORM_DEADLINE).unwrap();
        frame::write_frame(
            &mut s,
            &Frame::Hello { rank: 0, addr: "0.0.0.0:9000".to_string() },
        )
        .unwrap();
        s.flush().unwrap();
        let e = server.join().unwrap().unwrap_err();
        assert!(e.to_string().contains("--bind"), "{e}");
    }

    /// `--connect-retries` bounds the dial attempts: a dead coordinator
    /// address fails after N tries instead of sitting out the deadline.
    #[test]
    fn bounded_retries_fail_fast_on_a_dead_address() {
        // bind-then-drop: the port was just free, so dialing it refuses
        let dead = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let opts = ConnectOpts {
            timeout: Duration::from_secs(30),
            retries: 2,
            ..ConnectOpts::default()
        };
        let started = std::time::Instant::now();
        let e = connect_with(0, 2, &dead, &opts).unwrap_err();
        assert!(started.elapsed() < Duration::from_secs(10), "did not fail fast");
        assert!(e.to_string().contains("attempt"), "{e}");
    }

    /// The formation timeout names exactly the ranks that never showed
    /// up, so a half-formed mesh is debuggable from the one-line error.
    #[test]
    fn form_timeout_names_the_missing_ranks() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let sopts =
            ServeOpts { deadline: Duration::from_millis(300), ..ServeOpts::default() };
        let server = std::thread::spawn(move || serve_with(&listener, 3, &sopts));
        // only rank 1 arrives
        let mut s = retry_connect(&addr, FORM_DEADLINE).unwrap();
        frame::write_frame(&mut s, &Frame::Hello { rank: 1, addr: "127.0.0.1:9".into() })
            .unwrap();
        s.flush().unwrap();
        let e = server.join().unwrap().unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("[0, 2]"), "must name the absent ranks: {msg}");
        assert!(msg.contains("1 of 3"), "{msg}");
        assert!(msg.contains("--form-deadline"), "{msg}");
    }

    /// An authenticated mesh forms end to end when every rank holds the
    /// same secret — through the rendezvous *and* the n·(n−1) mesh
    /// sockets — and still moves data.
    #[test]
    fn authenticated_mesh_forms_and_moves_data() {
        use crate::comm::{Phase, Tag, Transport};
        let opts = ConnectOpts {
            secret: Some("correct horse battery staple".to_string()),
            ..ConnectOpts::default()
        };
        let mut mesh = localhost_mesh_with(3, &opts).unwrap();
        mesh[0].send(0, 2, Tag::new(1, 0, Phase::FwdFeat), vec![4.25, -1.5]);
        assert_eq!(
            mesh[2].recv_blocking(0, 2, Tag::new(1, 0, Phase::FwdFeat)),
            vec![4.25, -1.5]
        );
        for m in &mut mesh {
            m.shutdown();
        }
    }

    /// A joiner presenting the wrong secret is rejected with a
    /// diagnostic naming the rank — the auth-rejected-join oracle.
    #[test]
    fn wrong_secret_join_is_rejected_with_a_diagnostic() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let sopts = ServeOpts { secret: Some("right".to_string()), ..ServeOpts::default() };
        let server = std::thread::spawn(move || serve_with(&listener, 1, &sopts));
        let copts = ConnectOpts { secret: Some("wrong".to_string()), ..ConnectOpts::default() };
        // the joiner fails (rendezvous closed on it), and the rendezvous
        // error names the rejected rank
        let joiner = connect_with(0, 1, &addr, &copts);
        let e = server.join().unwrap().unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("mesh auth failed"), "{msg}");
        assert!(msg.contains("rank 0"), "{msg}");
        assert!(joiner.is_err());
    }

    /// A joiner with no secret against an authenticated rendezvous gets
    /// an error naming the missing flag, not a confusing frame mismatch.
    #[test]
    fn missing_secret_is_named_on_both_sides() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let sopts = ServeOpts { secret: Some("s".to_string()), ..ServeOpts::default() };
        let server = std::thread::spawn(move || serve_with(&listener, 1, &sopts));
        let e = connect(0, 1, &addr).unwrap_err();
        assert!(e.to_string().contains("--mesh-secret"), "{e}");
        assert!(server.join().unwrap().is_err());
    }

    /// A rejoin round delivers the resume epoch to every participant.
    #[test]
    fn rejoin_round_carries_the_resume_epoch() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let coord = listener.local_addr().unwrap().to_string();
        let sopts = ServeOpts { resume_epoch: Some(42), ..ServeOpts::default() };
        let server = std::thread::spawn(move || serve_with(&listener, 2, &sopts));
        let joiners: Vec<_> = (0..2)
            .map(|r| {
                let coord = coord.clone();
                std::thread::spawn(move || {
                    let opts = ConnectOpts { expect_resume: true, ..ConnectOpts::default() };
                    connect_session(r, 2, &coord, &opts)
                })
            })
            .collect();
        let mut mesh = Vec::new();
        for j in joiners {
            let (t, resume) = j.join().unwrap().unwrap();
            assert_eq!(resume, Some(42));
            mesh.push(t);
        }
        server.join().unwrap().unwrap();
        for m in &mut mesh {
            m.shutdown();
        }
    }
}
