//! One rank of a multi-process training run (`pipegcn worker`).
//!
//! Every worker deterministically rebuilds the same partition from the
//! shared seed (synthetic datasets make the graph a pure function of its
//! preset — no input files to ship) but assembles only **its own**
//! partition plan, joins the TCP mesh through the rendezvous, and runs
//! [`crate::coordinator::threaded::run_rank_ctl`] over its
//! [`super::TcpTransport`]. With `--nodes N` the worker takes the scale
//! path: it builds the feature-free topology, partitions it, and
//! generates just its shard's features/labels — no rank ever
//! materializes the full graph. Every epoch's partial losses flow to rank 0
//! inside the schedule (the per-epoch loss reduction), so rank 0 holds
//! the live global loss, streams NDJSON run-log rows as epochs finish,
//! evaluates the final model, and owns all reporting.
//!
//! Crash safety: with `--ckpt-dir` every rank snapshots its full
//! [`TrainState`] every `--ckpt-every` epochs; with `--resume <dir>` a
//! worker restores the latest complete checkpoint and continues the
//! uninterrupted run bit-for-bit. When a *peer* dies mid-run, the
//! transport fails every parked receive; instead of dying with it, a
//! worker with a checkpoint directory catches the failure, drops its
//! mesh, and re-dials the same rendezvous address — the launcher's
//! rejoin round tells it which checkpoint epoch to roll back to, and
//! training resumes bit-for-bit without this process ever restarting.
//! `--fail-epoch` is fault injection for the recovery tests (exit(13)
//! after that epoch completes).
//!
//! Multi-node reachability: `--bind HOST:PORT` puts the worker's mesh
//! listener on a routable interface (default loopback; wildcards are
//! rejected with a diagnostic), and `--connect-timeout` /
//! `--connect-retries` tune the rendezvous dial for real LAN latencies.

use super::rendezvous::{self, ConnectOpts};
use crate::ckpt;
use crate::comm::schedule;
use crate::coordinator::threaded::{self, RankCtl};
use crate::coordinator::{evaluate, halo, TrainState};
use crate::exp::{self, RunOpts};
use crate::graph::Graph;
use crate::partition::Method;
use crate::util::error::{Context, Result};
use crate::util::json::{FileEmitter, Json};
use std::time::Duration;

#[derive(Clone, Debug, Default)]
pub struct WorkerOpts {
    pub rank: usize,
    pub parts: usize,
    /// rendezvous address (the launcher's listener)
    pub coord: String,
    pub dataset: String,
    pub method: String,
    /// node-count override (0 = preset default). Non-zero switches to
    /// per-rank lazy construction: this rank materializes only the
    /// feature-free topology plus its own shard — never a full `Graph`.
    pub nodes: usize,
    /// partitioner name (`--partitioner`; None = multilevel)
    pub partitioner: Option<String>,
    /// 0 = preset default
    pub epochs: usize,
    pub seed: u64,
    pub gamma: f32,
    /// NDJSON run log (rank 0 only), streamed per epoch
    pub log: Option<String>,
    /// result JSON path (rank 0 only)
    pub out: Option<String>,
    /// snapshot training state into this directory
    pub ckpt_dir: Option<String>,
    /// snapshot every this many epochs (with `ckpt_dir`)
    pub ckpt_every: usize,
    /// restore the latest complete checkpoint under this directory
    pub resume: Option<String>,
    /// fault injection: exit(13) after this epoch (recovery tests)
    pub fail_epoch: Option<usize>,
    /// mesh listener bind address (`--bind`; default loopback). Must
    /// name an interface the peers can route to — wildcards rejected.
    pub bind: Option<String>,
    /// rendezvous dial deadline in seconds (`--connect-timeout`;
    /// default: the 60 s formation deadline)
    pub connect_timeout_secs: Option<u64>,
    /// rendezvous dial attempts (`--connect-retries`; 0 = unlimited
    /// within the timeout)
    pub connect_retries: Option<usize>,
    /// write a merged Chrome trace-event JSON here (`--trace`; rank 0
    /// writes the file — every other rank records spans and ships them
    /// to rank 0 over the mesh at shutdown, clock-aligned NTP-style)
    pub trace: Option<String>,
    /// serve live Prometheus text on this address (`--metrics-addr`)
    /// for the lifetime of the run
    pub metrics_addr: Option<String>,
    /// chaos profile JSON path (`--chaos`): deterministic per-link
    /// latency/jitter/bandwidth/drop injection on this rank's outgoing
    /// frames
    pub chaos: Option<String>,
    /// shared mesh secret (`--mesh-secret` / `PIPEGCN_MESH_SECRET`):
    /// every join answers the rendezvous' HMAC challenge
    pub mesh_secret: Option<String>,
    /// mesh-formation deadline in seconds (`--form-deadline`)
    pub form_deadline_secs: Option<u64>,
    /// receive-watchdog deadline in seconds (`--recv-deadline`)
    pub recv_deadline_secs: Option<u64>,
    /// this worker is a replacement joining a live-rejoin round
    /// (`--rejoin`, set by the launcher): the round must name the
    /// checkpoint epoch to restore before training
    pub rejoin: bool,
}

/// What rank 0 learns at the end of a distributed run.
pub struct WorkerSummary {
    /// per-epoch global train loss for the epochs this incarnation ran
    /// (`start_epoch + 1 ..= epochs`), summed across ranks in rank
    /// order — bit-identical to the sequential and threaded engines
    pub losses: Vec<f64>,
    /// completed epochs restored from a checkpoint (0 on a fresh run)
    pub start_epoch: usize,
    pub final_val: f64,
    pub final_test: f64,
    /// payload bytes this rank sent (comparable with Fabric accounting)
    pub payload_bytes_sent: u64,
    /// actual wire bytes including frame headers
    pub wire_bytes_sent: u64,
    /// total ms rank 0 sat parked in receives (prefetched schedule)
    pub comm_wait_ms: f64,
    /// fraction of rank 0's receives already complete when waited on
    pub overlap_ratio: f64,
    /// quality of the partitioning every rank derived from the shared
    /// seed (edge cut, comm volume, replication, balance)
    pub quality: crate::partition::Quality,
    /// live-rejoin rounds this process went through (peer deaths it
    /// survived in place, plus one if it started as a `--rejoin`
    /// replacement)
    pub rejoins: u64,
}

/// Run one rank end to end. Returns `Some(summary)` on rank 0, `None`
/// elsewhere.
pub fn run_worker(o: &WorkerOpts) -> Result<Option<WorkerSummary>> {
    let pmethod = match o.partitioner.as_deref() {
        None => Method::Multilevel,
        Some(name) => Method::parse(name).ok_or_else(|| {
            crate::err_msg!("unknown partitioner '{name}' (try: multilevel, simple, range, bfs)")
        })?,
    };
    let run_opts = RunOpts {
        epochs: o.epochs,
        seed: o.seed,
        gamma: o.gamma,
        partitioner: pmethod,
        nodes: o.nodes,
        ..Default::default()
    };
    // validates preset/method up front: a bad flag is a diagnostic here,
    // not a panic deep inside the dataset build
    let (preset, cfg) = exp::try_config(&o.dataset, o.parts, &o.method, run_opts)?;

    // Build only this rank's partition plan. Every rank derives the same
    // partition from the shared seed, so rank 0 can report its quality
    // without extra coordination. Two modes:
    //  * default: rebuild the full dataset (rank 0 needs it for the
    //    final evaluation) but assemble just our own plan entry;
    //  * `--nodes N` (scale): no rank ever holds a full `Graph` — build
    //    the feature-free topology, partition it, generate this rank's
    //    shard directly, and drop both before training starts.
    let (graph, part, total_train, quality): (Option<Graph>, _, _, _) = if o.nodes == 0 {
        let g = preset.build(o.seed);
        let pt = crate::partition::partition(&g, o.parts, pmethod, o.seed);
        let quality = crate::partition::quality(&g, &pt);
        let src = halo::NodeSource::Graph(&g);
        let part = halo::build_part(g.adj(), &pt.assign, o.parts, o.rank, cfg.model.kind, &src);
        let total_train = g.train_mask.len();
        (Some(g), part, total_train, quality)
    } else {
        let topo = preset.build_topology_scaled(o.nodes, o.seed);
        let pt = crate::partition::partition_adj(topo.adj(), o.parts, pmethod, o.seed);
        let quality = crate::partition::quality_adj(topo.adj(), &pt);
        let shard = preset.build_shard_scaled(o.nodes, o.seed, &pt.assign, o.rank as u32);
        let total_train = shard.total_train;
        let src = halo::NodeSource::Shard(&shard);
        let part =
            halo::build_part(topo.adj(), &pt.assign, o.parts, o.rank, cfg.model.kind, &src);
        (None, part, total_train, quality)
    };
    let view = halo::PartView { n_parts: o.parts, total_train, part: &part };

    // live metrics endpoint: up before the mesh forms, so a scrape can
    // watch the whole run (held until the end of this function)
    let _metrics = match &o.metrics_addr {
        Some(addr) => {
            let srv = crate::obs::http::serve(addr)
                .with_context(|| format!("rank {}: --metrics-addr {addr}", o.rank))?;
            eprintln!("[rank {}] metrics on http://{}/metrics", o.rank, srv.addr());
            Some(srv)
        }
        None => None,
    };

    // training state: fresh, or the latest complete checkpoint. Every
    // worker scans the same directory tree, so all ranks agree on the
    // resume epoch without extra coordination.
    let mut st = match &o.resume {
        None => TrainState::init(&cfg, &part),
        Some(dir) => {
            let epoch = ckpt::latest_complete(dir, o.parts)?.with_context(|| {
                format!("--resume {dir}: no complete checkpoint for {} ranks", o.parts)
            })?;
            let snap = ckpt::load(dir, epoch, o.rank)?;
            TrainState::from_snapshot(snap, &cfg, &part)?
        }
    };
    let mut start_epoch = st.epoch;
    if start_epoch >= cfg.epochs {
        // a recovered mesh whose last checkpoint landed on the final
        // epoch: nothing left to train — still join the mesh so rank 0
        // evaluates the restored model and writes the report
        eprintln!(
            "[rank {}] checkpoint epoch {start_epoch} already covers --epochs {}; \
             evaluating and reporting only",
            o.rank, cfg.epochs
        );
    }
    let policy = o
        .ckpt_dir
        .as_ref()
        .map(|dir| ckpt::Policy { dir: dir.clone(), every: o.ckpt_every.max(1) });
    let mut log_em = match (&o.log, o.rank) {
        (Some(path), 0) => Some(open_log(path, o, &quality)?),
        _ => None,
    };

    let mut conn = ConnectOpts::default();
    if let Some(bind) = &o.bind {
        conn.bind = bind.clone();
    }
    if let Some(secs) = o.connect_timeout_secs {
        conn.timeout = Duration::from_secs(secs.max(1));
    }
    if let Some(n) = o.connect_retries {
        conn.retries = n;
    }
    if let Some(secs) = o.form_deadline_secs {
        conn.form_deadline = Duration::from_secs(secs.max(1));
    }
    if let Some(secs) = o.recv_deadline_secs {
        conn.recv_deadline = Some(Duration::from_secs(secs.max(1)));
    }
    conn.secret = o.mesh_secret.clone();
    conn.chaos = match &o.chaos {
        Some(path) => Some(super::chaos::ChaosProfile::load(path)?),
        None => None,
    };

    // Join the mesh and train — and when a *peer* dies under a
    // checkpoint policy, rejoin in place. The transport fails every
    // parked receive the moment a link breaks; that panic is caught
    // here, the broken mesh is dropped, and this process re-dials the
    // same rendezvous address. The launcher's rejoin round then names
    // the checkpoint epoch all ranks roll back to, so the healed mesh
    // continues bit-for-bit. Anything that is not a transport failure —
    // or a failure with no checkpoints to roll back to — still unwinds.
    let mut expect_resume = o.rejoin;
    let mut rejoins: u64 = 0;
    let (rep, mut transport) = loop {
        conn.expect_resume = expect_resume;
        let (transport, resume_epoch) =
            rendezvous::connect_session(o.rank, o.parts, &o.coord, &conn)
                .with_context(|| format!("rank {} joining mesh via {}", o.rank, o.coord))?;
        if let Some(epoch) = resume_epoch {
            let dir = o.ckpt_dir.as_deref().with_context(|| {
                format!(
                    "rank {}: rejoin round names checkpoint epoch {epoch} but no \
                     --ckpt-dir is set",
                    o.rank
                )
            })?;
            let snap = ckpt::load(dir, epoch as usize, o.rank)?;
            st = TrainState::from_snapshot(snap, &cfg, &part)?;
            start_epoch = st.epoch;
            rejoins += 1;
            eprintln!(
                "[rank {}] rejoined the mesh at the epoch-{epoch} checkpoint",
                o.rank
            );
        }
        // span tracing: enable the per-process recorder, then align
        // clocks across the mesh (NTP-style ping/pong against rank 0) so
        // the merged timeline reads as one machine — redone per mesh, so
        // a rejoined run stays aligned. Strictly gated on --trace:
        // untraced runs move exactly the bytes they always did.
        if o.trace.is_some() {
            crate::obs::trace::enable();
            if o.rank == 0 {
                crate::obs::trace::serve_clock_sync(&transport, o.parts);
            } else {
                let off = crate::obs::trace::clock_sync_offset(&transport, o.rank);
                crate::obs::trace::set_offset_us(off);
            }
        }
        // runtime conformance (debug builds, PIPEGCN_CONFORMANCE=1):
        // regenerate this rank's schedule for the epochs this mesh
        // generation trains and cross-check the live transport against
        // it. Peers run in other processes, so their link maps are
        // placeholders — for_rank keeps only this rank's stream.
        let conformance = schedule::conformance_requested();
        if conformance {
            let all_links: Vec<schedule::RankLinks> = (0..o.parts)
                .map(|r| {
                    if r == o.rank {
                        view.comm_links()
                    } else {
                        schedule::RankLinks::new(r, vec![false; o.parts], vec![false; o.parts])
                    }
                })
                .collect();
            let sched = schedule::Schedule::generate(
                &all_links,
                schedule::Style::Prefetched,
                matches!(cfg.variant, crate::coordinator::Variant::Pipe(_)),
                cfg.model.n_layers(),
                st.epoch as u32 + 1,
                cfg.epochs as u32,
            )?;
            schedule::set_sink(Box::new(schedule::Conformance::for_rank(&sched, o.rank)));
        }
        let ctl = RankCtl {
            ckpt: policy.as_ref(),
            log: log_em.as_mut(),
            kill_after_epoch: o.fail_epoch,
        };
        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            threaded::run_rank_ctl(&transport, &view, &cfg, &mut st, ctl)
        }));
        if conformance {
            // only drop the sink this generation installed — an
            // in-process caller's recorder must survive the run
            schedule::clear_sink();
        }
        match run {
            Ok(rep) => break (rep?, transport),
            Err(payload) => {
                let msg = panic_text(payload.as_ref());
                let transient = ["transport failed", "closed while", "recv timeout"]
                    .iter()
                    .any(|marker| msg.contains(marker));
                if !transient || o.ckpt_dir.is_none() {
                    std::panic::resume_unwind(payload);
                }
                eprintln!(
                    "[rank {}] mesh broke mid-run ({}); re-entering the rendezvous at {}",
                    o.rank,
                    msg.lines().next().unwrap_or("?"),
                    o.coord
                );
                drop(transport);
                expect_resume = true;
            }
        }
    };

    if o.rank != 0 {
        if o.trace.is_some() {
            crate::obs::trace::ship_spans(&transport, o.rank);
        }
        transport.shutdown();
        return Ok(None);
    }
    if let Some(path) = &o.trace {
        let spans = crate::obs::trace::collect_spans(&transport, o.parts);
        crate::obs::trace::write_chrome_trace(path, &spans)?;
        eprintln!("[rank 0] wrote {} trace spans to {path}", spans.len());
    }

    // rank 0 already holds the global per-epoch losses (the per-epoch
    // reduction replaced the old post-hoc gather). Full-graph evaluation
    // needs the materialized graph — on the scale path no rank has one,
    // so the metrics stay NaN (rendered as null in the report).
    let (final_val, final_test) = match &graph {
        Some(g) => evaluate(g, &st.params, cfg.model.kind),
        None => (f64::NAN, f64::NAN),
    };
    let summary = WorkerSummary {
        losses: rep.losses,
        start_epoch,
        final_val,
        final_test,
        payload_bytes_sent: transport.payload_bytes_sent(),
        wire_bytes_sent: transport.wire_bytes_sent(),
        comm_wait_ms: rep.comm_wait_ms,
        overlap_ratio: rep.overlap_ratio,
        quality,
        rejoins,
    };
    transport.shutdown();

    if let Some(path) = &o.out {
        let mut breakdown = Json::obj();
        for (key, ms) in &rep.comm_wait_by {
            breakdown = breakdown.set(key, *ms);
        }
        let mut row = Json::obj()
            .set("dataset", o.dataset.as_str())
            .set("parts", o.parts)
            .set("method", o.method.as_str())
            .set("engine", "tcp")
            .set("epochs", cfg.epochs);
        if o.nodes > 0 {
            row = row.set("nodes", o.nodes);
        }
        let mut row = row
            .set("start_epoch", summary.start_epoch)
            .set("final_loss", *summary.losses.last().unwrap_or(&f64::NAN))
            .set("losses", &summary.losses[..])
            .set("final_val", summary.final_val)
            .set("final_test", summary.final_test)
            .set("payload_bytes_sent", summary.payload_bytes_sent)
            .set("wire_bytes_sent", summary.wire_bytes_sent)
            .set("comm_wait_ms", summary.comm_wait_ms)
            .set("overlap_ratio", summary.overlap_ratio)
            .set("rejoins", summary.rejoins)
            .set("comm_wait", breakdown)
            .set("quality", quality.to_json())
            .set("peak_rss_bytes", crate::obs::peak_rss_bytes().unwrap_or(0));
        if o.chaos.is_some() {
            row = row.set("link_faults", super::chaos::faults_from(o.rank, o.parts));
        }
        row.write_file(path)?;
    }
    Ok(Some(summary))
}

/// Best-effort text of a caught panic payload (what `panic!` carried).
fn panic_text(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Open rank 0's run log: freshly created with a header on a new run,
/// appended (rows only) when resuming so the original epochs survive.
fn open_log(
    path: &str,
    o: &WorkerOpts,
    quality: &crate::partition::Quality,
) -> Result<FileEmitter> {
    let header = Json::obj()
        .set("dataset", o.dataset.as_str())
        .set("parts", o.parts)
        .set("method", o.method.as_str())
        .set("engine", "tcp")
        .set("quality", quality.to_json());
    let em = if o.resume.is_some() {
        FileEmitter::append_or_create(path, header)
    } else {
        FileEmitter::create(path, header)
    };
    em.with_context(|| format!("creating run log {path}"))
}
