//! One rank of a multi-process training run (`pipegcn worker`).
//!
//! Every worker deterministically rebuilds the same dataset, partition,
//! and halo plan from the shared seed (synthetic datasets make the graph
//! a pure function of its preset — no input files to ship), joins the
//! TCP mesh through the rendezvous, and runs
//! [`crate::coordinator::threaded::run_rank`] over its
//! [`super::TcpTransport`]. Rank 0 gathers the per-rank partial losses
//! (bit-losslessly, as f64 halves in the f32 payload channel), evaluates
//! the final model, and owns all reporting.

use super::rendezvous;
use crate::comm::{decode_f64s, encode_f64s, Phase, Tag, Transport};
use crate::coordinator::{evaluate, halo, threaded};
use crate::exp::{self, RunOpts};
use crate::util::error::{Context, Result};
use crate::util::json::{FileEmitter, Json};

/// The loss-gather rendezvous tag: iteration `u32::MAX` cannot collide
/// with training iterations (epochs are far smaller), layer = src rank.
fn loss_tag(src: usize) -> Tag {
    Tag::new(u32::MAX, src as u16, Phase::Setup)
}

#[derive(Clone, Debug)]
pub struct WorkerOpts {
    pub rank: usize,
    pub parts: usize,
    /// rendezvous address (the launcher's listener)
    pub coord: String,
    pub dataset: String,
    pub method: String,
    /// 0 = preset default
    pub epochs: usize,
    pub seed: u64,
    pub gamma: f32,
    /// NDJSON run log (rank 0 only)
    pub log: Option<String>,
    /// result JSON (rank 0 only)
    pub out: Option<String>,
}

/// What rank 0 learns at the end of a distributed run.
pub struct WorkerSummary {
    /// per-epoch global train loss, summed across ranks in rank order —
    /// bit-identical to the sequential and threaded engines
    pub losses: Vec<f64>,
    pub final_val: f64,
    pub final_test: f64,
    /// payload bytes this rank sent (comparable with Fabric accounting)
    pub payload_bytes_sent: u64,
    /// actual wire bytes including frame headers
    pub wire_bytes_sent: u64,
}

/// Run one rank end to end. Returns `Some(summary)` on rank 0, `None`
/// elsewhere.
pub fn run_worker(o: &WorkerOpts) -> Result<Option<WorkerSummary>> {
    let run_opts = RunOpts { epochs: o.epochs, seed: o.seed, gamma: o.gamma, ..Default::default() };
    let (_preset, graph, parts, cfg) = exp::prepare(&o.dataset, o.parts, &o.method, run_opts);
    let plan = halo::build(&graph, &parts, cfg.model.kind);

    let mut transport = rendezvous::connect(o.rank, o.parts, &o.coord)
        .with_context(|| format!("rank {} joining mesh via {}", o.rank, o.coord))?;
    let (losses, params) = threaded::run_rank(&transport, &plan, o.rank, &cfg);

    if o.rank != 0 {
        transport.send(o.rank, 0, loss_tag(o.rank), encode_f64s(&losses));
        transport.shutdown();
        return Ok(None);
    }

    // rank 0: gather partial losses in rank order (f64 addition order
    // matches the in-process engines, keeping sums bit-identical)
    let mut total = losses;
    for j in 1..o.parts {
        let part = decode_f64s(&transport.recv_blocking(j, 0, loss_tag(j)));
        if part.len() != total.len() {
            crate::bail!("rank {j} reported {} epochs, expected {}", part.len(), total.len());
        }
        for (dst, v) in total.iter_mut().zip(&part) {
            *dst += v;
        }
    }
    let (final_val, final_test) = evaluate(&graph, &params, cfg.model.kind);
    let summary = WorkerSummary {
        losses: total,
        final_val,
        final_test,
        payload_bytes_sent: transport.payload_bytes_sent(),
        wire_bytes_sent: transport.wire_bytes_sent(),
    };
    transport.shutdown();

    // NDJSON run log. Unlike the sequential engine's streaming log, the
    // distributed rows are written after the gather (global loss only
    // exists once every rank has reported), so rows carry just
    // {epoch, loss} and the header says post_hoc — readers should treat
    // per-epoch val/epoch_ms/bytes as sequential-engine-only fields.
    if let Some(path) = &o.log {
        let mut em = FileEmitter::create(
            path,
            Json::obj()
                .set("dataset", o.dataset.as_str())
                .set("parts", o.parts)
                .set("method", o.method.as_str())
                .set("engine", "tcp")
                .set("post_hoc", true),
        )
        .with_context(|| format!("creating run log {path}"))?;
        for (i, &loss) in summary.losses.iter().enumerate() {
            em.emit(&Json::obj().set("epoch", i + 1).set("loss", loss))?;
        }
    }
    if let Some(path) = &o.out {
        Json::obj()
            .set("dataset", o.dataset.as_str())
            .set("parts", o.parts)
            .set("method", o.method.as_str())
            .set("engine", "tcp")
            .set("epochs", summary.losses.len())
            .set("final_loss", *summary.losses.last().unwrap_or(&f64::NAN))
            .set("losses", &summary.losses[..])
            .set("final_val", summary.final_val)
            .set("final_test", summary.final_test)
            .set("payload_bytes_sent", summary.payload_bytes_sent)
            .set("wire_bytes_sent", summary.wire_bytes_sent)
            .write_file(path)?;
    }
    Ok(Some(summary))
}
