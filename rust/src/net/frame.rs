//! Length-prefixed binary frames — the wire format of [`super::tcp`].
//!
//! Every frame is `len: u32 LE` (body byte count) followed by the body:
//!
//! ```text
//! body := kind: u8, fields...
//! Data      (0): src u16, dst u16, iter u32, layer u16, phase u8,
//!                payload: [f32 bits, LE]
//! Hello     (1): rank u16, addr (u16 len + utf8)   — dialer introduces
//!                itself (to the rendezvous: with its mesh listen addr)
//! PeerTable (2): n u16, n × (u16 len + utf8)       — rendezvous reply
//! Shutdown  (3): src u16                           — graceful close
//! DataChunk (4): src u16, dst u16, iter u32, layer u16, phase u8,
//!                last u8, payload: [f32 bits, LE]  — slice of an
//!                oversized Data payload, reassembled on receive
//! AuthChallenge (5): nonce [u8; 16]                — mesh-auth nonce,
//!                sent in reply to a Hello when a secret is configured
//! AuthResponse  (6): mac [u8; 32]                  — HMAC-SHA256 over
//!                the challenge, proving knowledge of the mesh secret
//! Resume    (7): epoch u64                         — rejoin-round
//!                epilogue: the checkpoint epoch every rank restores
//! Ctrl      (8): op u8, arg (u16 len + utf8)       — serving-tier
//!                control plane (ping/drain/reload and their acks)
//! ```
//!
//! Payload floats travel as raw bit patterns (`to_bits`/`from_bits`), so
//! the wire never canonicalizes NaNs and bit-exactness holds end to end.

use crate::comm::{Phase, Tag};
use std::io::{Read, Write};

/// Hard cap on a frame body (64 MiB) — a corrupt or hostile length
/// prefix must not drive an allocation.
pub const MAX_BODY_BYTES: usize = 64 << 20;

/// Bytes of framing around a Data payload (length prefix + header).
pub const DATA_OVERHEAD_BYTES: usize = 4 + 1 + 2 + 2 + 4 + 2 + 1;

/// Bytes of framing around a DataChunk payload (Data header + `last`).
pub const CHUNK_OVERHEAD_BYTES: usize = DATA_OVERHEAD_BYTES + 1;

/// Most floats a single Data frame may carry under [`MAX_BODY_BYTES`].
pub const MAX_DATA_FLOATS: usize = (MAX_BODY_BYTES - (DATA_OVERHEAD_BYTES - 4)) / 4;

/// Floats per chunk when an oversized payload is split into DataChunks.
pub const MAX_CHUNK_FLOATS: usize = (MAX_BODY_BYTES - (CHUNK_OVERHEAD_BYTES - 4)) / 4;

const KIND_DATA: u8 = 0;
const KIND_HELLO: u8 = 1;
const KIND_PEER_TABLE: u8 = 2;
const KIND_SHUTDOWN: u8 = 3;
const KIND_DATA_CHUNK: u8 = 4;
const KIND_AUTH_CHALLENGE: u8 = 5;
const KIND_AUTH_RESPONSE: u8 = 6;
const KIND_RESUME: u8 = 7;
const KIND_CTRL: u8 = 8;

/// [`Frame::Ctrl`] ops — the serving tier's control plane. A request op
/// is answered with [`CTRL_ACK`] (arg: op-specific detail, e.g. the
/// artifact version after a reload) or [`CTRL_ERR`] (arg: diagnostic).
pub const CTRL_PING: u8 = 0;
/// Stop accepting new work, finish in-flight queries, then exit.
pub const CTRL_DRAIN: u8 = 1;
/// Hot-swap the params artifact at the path in `arg` (zero-downtime
/// model update; the graph and propagation matrix are unchanged).
pub const CTRL_RELOAD: u8 = 2;
/// Success reply to a control request.
pub const CTRL_ACK: u8 = 3;
/// Failure reply to a control request (arg carries the diagnostic).
pub const CTRL_ERR: u8 = 4;

#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// One tagged tensor message, exactly a `Transport::send`.
    Data { src: u16, dst: u16, tag: Tag, payload: Vec<f32> },
    /// Connection introduction. `addr` is the sender's mesh listen
    /// address when dialing the rendezvous, and empty when dialing a peer.
    Hello { rank: u16, addr: String },
    /// The full rank → address table, from the rendezvous to every rank.
    PeerTable { addrs: Vec<String> },
    /// Graceful end-of-stream from `src`; the reader thread exits cleanly.
    Shutdown { src: u16 },
    /// One slice of a payload larger than [`MAX_BODY_BYTES`]: the sender
    /// splits transparently, the receiver reassembles per (src, tag)
    /// until the `last` chunk arrives. Chunks of one logical message are
    /// contiguous on their socket (the writer thread drains its queue in
    /// order), so reassembly needs no sequence numbers.
    DataChunk { src: u16, dst: u16, tag: Tag, last: bool, payload: Vec<f32> },
    /// Mesh-auth challenge: the accepting side answers a `Hello` with a
    /// fresh nonce when a shared secret is configured. Never sent on an
    /// unauthenticated mesh, so default wire traffic is unchanged.
    AuthChallenge { nonce: [u8; 16] },
    /// Mesh-auth proof: HMAC-SHA256(secret, nonce ‖ rank ‖ addr) from
    /// the `Hello` this responds to.
    AuthResponse { mac: [u8; 32] },
    /// Epilogue of a live-rejoin rendezvous round: every participant —
    /// survivor or replacement — restores from this checkpoint epoch
    /// before training resumes. Absent on a first-formation round.
    Resume { epoch: u64 },
    /// Serving-tier control message ([`CTRL_PING`]/[`CTRL_DRAIN`]/
    /// [`CTRL_RELOAD`] requests; [`CTRL_ACK`]/[`CTRL_ERR`] replies).
    /// `arg` is op-specific: the artifact path for a reload, the
    /// diagnostic or version string in a reply, empty otherwise. Never
    /// sent by the training mesh, so its wire traffic is unchanged.
    Ctrl { op: u8, arg: String },
}

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    assert!(s.len() <= u16::MAX as usize, "address string too long");
    put_u16(out, s.len() as u16);
    out.extend_from_slice(s.as_bytes());
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.pos + n > self.buf.len() {
            return Err(format!(
                "truncated frame: wanted {n} bytes at offset {}, body is {}",
                self.pos,
                self.buf.len()
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, String> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32, String> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn str(&mut self) -> Result<String, String> {
        let n = self.u16()? as usize;
        let b = self.take(n)?;
        String::from_utf8(b.to_vec()).map_err(|e| format!("bad utf8 in frame: {e}"))
    }
}

/// Encode a frame body (without the length prefix).
pub fn encode_body(f: &Frame) -> Vec<u8> {
    let mut out = Vec::new();
    match f {
        Frame::Data { src, dst, tag, payload } => {
            out.reserve(DATA_OVERHEAD_BYTES + payload.len() * 4);
            out.push(KIND_DATA);
            put_u16(&mut out, *src);
            put_u16(&mut out, *dst);
            put_u32(&mut out, tag.iter);
            put_u16(&mut out, tag.layer);
            out.push(tag.phase.code());
            for v in payload {
                put_u32(&mut out, v.to_bits());
            }
        }
        Frame::Hello { rank, addr } => {
            out.push(KIND_HELLO);
            put_u16(&mut out, *rank);
            put_str(&mut out, addr);
        }
        Frame::PeerTable { addrs } => {
            out.push(KIND_PEER_TABLE);
            assert!(addrs.len() <= u16::MAX as usize);
            put_u16(&mut out, addrs.len() as u16);
            for a in addrs {
                put_str(&mut out, a);
            }
        }
        Frame::Shutdown { src } => {
            out.push(KIND_SHUTDOWN);
            put_u16(&mut out, *src);
        }
        Frame::DataChunk { src, dst, tag, last, payload } => {
            out.reserve(CHUNK_OVERHEAD_BYTES + payload.len() * 4);
            out.push(KIND_DATA_CHUNK);
            put_u16(&mut out, *src);
            put_u16(&mut out, *dst);
            put_u32(&mut out, tag.iter);
            put_u16(&mut out, tag.layer);
            out.push(tag.phase.code());
            out.push(*last as u8);
            for v in payload {
                put_u32(&mut out, v.to_bits());
            }
        }
        Frame::AuthChallenge { nonce } => {
            out.push(KIND_AUTH_CHALLENGE);
            out.extend_from_slice(nonce);
        }
        Frame::AuthResponse { mac } => {
            out.push(KIND_AUTH_RESPONSE);
            out.extend_from_slice(mac);
        }
        Frame::Resume { epoch } => {
            out.push(KIND_RESUME);
            out.extend_from_slice(&epoch.to_le_bytes());
        }
        Frame::Ctrl { op, arg } => {
            out.push(KIND_CTRL);
            out.push(*op);
            put_str(&mut out, arg);
        }
    }
    out
}

/// Decode a frame body (the bytes after the length prefix).
pub fn decode_body(buf: &[u8]) -> Result<Frame, String> {
    let mut c = Cursor { buf, pos: 0 };
    let kind = c.u8()?;
    let frame = match kind {
        KIND_DATA => {
            let src = c.u16()?;
            let dst = c.u16()?;
            let iter = c.u32()?;
            let layer = c.u16()?;
            let phase_code = c.u8()?;
            let phase = Phase::from_code(phase_code)
                .ok_or_else(|| format!("bad phase code {phase_code}"))?;
            let rest = buf.len() - c.pos;
            if rest % 4 != 0 {
                return Err(format!("data payload not f32-aligned ({rest} bytes)"));
            }
            let mut payload = Vec::with_capacity(rest / 4);
            for _ in 0..rest / 4 {
                payload.push(f32::from_bits(c.u32()?));
            }
            Frame::Data { src, dst, tag: Tag::new(iter, layer, phase), payload }
        }
        KIND_HELLO => Frame::Hello { rank: c.u16()?, addr: c.str()? },
        KIND_PEER_TABLE => {
            let n = c.u16()? as usize;
            let mut addrs = Vec::with_capacity(n);
            for _ in 0..n {
                addrs.push(c.str()?);
            }
            Frame::PeerTable { addrs }
        }
        KIND_SHUTDOWN => Frame::Shutdown { src: c.u16()? },
        KIND_DATA_CHUNK => {
            let src = c.u16()?;
            let dst = c.u16()?;
            let iter = c.u32()?;
            let layer = c.u16()?;
            let phase_code = c.u8()?;
            let phase = Phase::from_code(phase_code)
                .ok_or_else(|| format!("bad phase code {phase_code}"))?;
            let last = c.u8()? != 0;
            let rest = buf.len() - c.pos;
            if rest % 4 != 0 {
                return Err(format!("chunk payload not f32-aligned ({rest} bytes)"));
            }
            let mut payload = Vec::with_capacity(rest / 4);
            for _ in 0..rest / 4 {
                payload.push(f32::from_bits(c.u32()?));
            }
            Frame::DataChunk { src, dst, tag: Tag::new(iter, layer, phase), last, payload }
        }
        KIND_AUTH_CHALLENGE => {
            let mut nonce = [0u8; 16];
            nonce.copy_from_slice(c.take(16)?);
            Frame::AuthChallenge { nonce }
        }
        KIND_AUTH_RESPONSE => {
            let mut mac = [0u8; 32];
            mac.copy_from_slice(c.take(32)?);
            Frame::AuthResponse { mac }
        }
        KIND_RESUME => {
            let b = c.take(8)?;
            Frame::Resume { epoch: u64::from_le_bytes(b.try_into().unwrap()) }
        }
        KIND_CTRL => Frame::Ctrl { op: c.u8()?, arg: c.str()? },
        other => return Err(format!("unknown frame kind {other}")),
    };
    if c.pos != buf.len() {
        return Err(format!("trailing bytes in frame body ({} of {})", c.pos, buf.len()));
    }
    Ok(frame)
}

/// Write one length-prefixed frame (caller flushes).
///
/// Data and DataChunk frames — the transport hot path — are streamed
/// straight into the writer (length prefix, 12/13-byte header from a
/// stack buffer, then the payload bits), skipping [`encode_body`]'s
/// intermediate `Vec` copy; the byte layout is identical. Control
/// frames go through [`encode_body`].
pub fn write_frame<W: Write>(w: &mut W, f: &Frame) -> std::io::Result<()> {
    let (kind, src, dst, tag, last, payload) = match f {
        Frame::Data { src, dst, tag, payload } => (KIND_DATA, src, dst, tag, None, payload),
        Frame::DataChunk { src, dst, tag, last, payload } => {
            (KIND_DATA_CHUNK, src, dst, tag, Some(*last), payload)
        }
        other => {
            let body = encode_body(other);
            w.write_all(&(body.len() as u32).to_le_bytes())?;
            return w.write_all(&body);
        }
    };
    let head_len = if last.is_some() { CHUNK_OVERHEAD_BYTES - 4 } else { DATA_OVERHEAD_BYTES - 4 };
    let body_len = head_len + payload.len() * 4;
    w.write_all(&(body_len as u32).to_le_bytes())?;
    let mut head = [0u8; CHUNK_OVERHEAD_BYTES - 4];
    head[0] = kind;
    head[1..3].copy_from_slice(&src.to_le_bytes());
    head[3..5].copy_from_slice(&dst.to_le_bytes());
    head[5..9].copy_from_slice(&tag.iter.to_le_bytes());
    head[9..11].copy_from_slice(&tag.layer.to_le_bytes());
    head[11] = tag.phase.code();
    if let Some(last) = last {
        head[12] = last as u8;
    }
    w.write_all(&head[..head_len])?;
    for v in payload {
        w.write_all(&v.to_bits().to_le_bytes())?;
    }
    Ok(())
}

/// Read one length-prefixed frame. `Ok(None)` on clean EOF at a frame
/// boundary; mid-frame EOF and oversized/corrupt frames are errors.
pub fn read_frame<R: Read>(r: &mut R) -> std::io::Result<Option<Frame>> {
    let mut len_buf = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        match r.read(&mut len_buf[got..])? {
            0 if got == 0 => return Ok(None), // clean EOF
            0 => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "EOF inside frame length prefix",
                ))
            }
            n => got += n,
        }
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_BODY_BYTES {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame body {len} bytes exceeds cap {MAX_BODY_BYTES}"),
        ));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    decode_body(&body)
        .map(Some)
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(f: Frame) {
        let body = encode_body(&f);
        assert_eq!(decode_body(&body).unwrap(), f);
        // and through the stream API
        let mut wire = Vec::new();
        write_frame(&mut wire, &f).unwrap();
        // the streamed fast path must produce exactly prefix+encode_body
        let mut expect = (body.len() as u32).to_le_bytes().to_vec();
        expect.extend_from_slice(&body);
        assert_eq!(wire, expect, "streamed bytes differ from encode_body");
        let mut r = &wire[..];
        assert_eq!(read_frame(&mut r).unwrap(), Some(f));
        assert_eq!(read_frame(&mut r).unwrap(), None); // clean EOF after
    }

    #[test]
    fn data_frame_roundtrip() {
        // NaN payloads are covered by the bit-exactness test below —
        // PartialEq would reject them here even when transport is perfect
        roundtrip(Frame::Data {
            src: 3,
            dst: 0,
            tag: Tag::new(42, 7, Phase::BwdGrad),
            payload: vec![1.5, -0.0, 3.25e-8, f32::MIN_POSITIVE],
        });
        roundtrip(Frame::Data {
            src: 0,
            dst: 1,
            tag: Tag::new(0, 0, Phase::Setup),
            payload: Vec::new(),
        });
    }

    #[test]
    fn data_payload_bits_survive_exactly() {
        let payload: Vec<f32> =
            [0x0000_0001u32, 0x7F80_0000, 0xFFC0_1234, 0x8000_0000]
                .iter()
                .map(|&b| f32::from_bits(b))
                .collect();
        let f = Frame::Data { src: 1, dst: 2, tag: Tag::new(9, 1, Phase::FwdFeat), payload };
        let body = encode_body(&f);
        match decode_body(&body).unwrap() {
            Frame::Data { payload: back, .. } => match &f {
                Frame::Data { payload, .. } => {
                    for (a, b) in payload.iter().zip(&back) {
                        assert_eq!(a.to_bits(), b.to_bits());
                    }
                }
                _ => unreachable!(),
            },
            _ => panic!("wrong kind"),
        }
    }

    #[test]
    fn data_chunk_roundtrip() {
        for last in [false, true] {
            roundtrip(Frame::DataChunk {
                src: 1,
                dst: 2,
                tag: Tag::new(9, 3, Phase::Reduce),
                last,
                payload: vec![0.5, -1.0, 2.0],
            });
        }
        roundtrip(Frame::DataChunk {
            src: 0,
            dst: 1,
            tag: Tag::new(1, 0, Phase::FwdFeat),
            last: true,
            payload: Vec::new(),
        });
    }

    #[test]
    fn chunk_sizing_constants_respect_the_cap() {
        assert!((CHUNK_OVERHEAD_BYTES - 4) + MAX_CHUNK_FLOATS * 4 <= MAX_BODY_BYTES);
        assert!((DATA_OVERHEAD_BYTES - 4) + MAX_DATA_FLOATS * 4 <= MAX_BODY_BYTES);
        // one more float would not fit a single frame
        assert!((DATA_OVERHEAD_BYTES - 4) + (MAX_DATA_FLOATS + 1) * 4 > MAX_BODY_BYTES);
    }

    #[test]
    fn control_frames_roundtrip() {
        roundtrip(Frame::Hello { rank: 2, addr: "127.0.0.1:45123".into() });
        roundtrip(Frame::Hello { rank: 0, addr: String::new() });
        roundtrip(Frame::PeerTable {
            addrs: vec!["127.0.0.1:1".into(), "127.0.0.1:2".into(), "127.0.0.1:3".into()],
        });
        roundtrip(Frame::Shutdown { src: 5 });
        let nonce: [u8; 16] = core::array::from_fn(|i| i as u8);
        roundtrip(Frame::AuthChallenge { nonce });
        let mac: [u8; 32] = core::array::from_fn(|i| 0xff - i as u8);
        roundtrip(Frame::AuthResponse { mac });
        roundtrip(Frame::Resume { epoch: 0 });
        roundtrip(Frame::Resume { epoch: u64::MAX });
    }

    #[test]
    fn ctrl_frames_roundtrip_and_reject_corruption() {
        roundtrip(Frame::Ctrl { op: CTRL_PING, arg: String::new() });
        roundtrip(Frame::Ctrl { op: CTRL_RELOAD, arg: "/tmp/params.pgp".into() });
        roundtrip(Frame::Ctrl { op: CTRL_ACK, arg: "3735928559".into() });
        roundtrip(Frame::Ctrl { op: CTRL_ERR, arg: "no healthy replica".into() });
        // unknown ops still travel (forward compatibility is the
        // receiver's policy, not the codec's)
        roundtrip(Frame::Ctrl { op: 200, arg: "x".into() });
        // truncated arg and trailing bytes are rejected
        let body = encode_body(&Frame::Ctrl { op: CTRL_DRAIN, arg: "drain".into() });
        assert!(decode_body(&body[..body.len() - 2]).is_err());
        let mut padded = body.clone();
        padded.push(0);
        assert!(decode_body(&padded).is_err());
    }

    #[test]
    fn auth_frames_have_fixed_width_bodies() {
        // truncated or padded auth bodies must be rejected, not zero-filled
        let ch = encode_body(&Frame::AuthChallenge { nonce: [7; 16] });
        assert_eq!(ch.len(), 1 + 16);
        assert!(decode_body(&ch[..ch.len() - 1]).is_err());
        let mut padded = ch.clone();
        padded.push(0);
        assert!(decode_body(&padded).is_err());
        let resp = encode_body(&Frame::AuthResponse { mac: [9; 32] });
        assert_eq!(resp.len(), 1 + 32);
        assert!(decode_body(&resp[..16]).is_err());
        let resume = encode_body(&Frame::Resume { epoch: 3 });
        assert_eq!(resume.len(), 1 + 8);
        assert!(decode_body(&resume[..5]).is_err());
    }

    #[test]
    fn corrupt_frames_rejected() {
        assert!(decode_body(&[]).is_err()); // no kind
        assert!(decode_body(&[9]).is_err()); // unknown kind
        let mut body = encode_body(&Frame::Shutdown { src: 1 });
        body.push(0); // trailing byte
        assert!(decode_body(&body).is_err());
        // truncated data header
        let body = encode_body(&Frame::Data {
            src: 0,
            dst: 1,
            tag: Tag::new(1, 0, Phase::FwdFeat),
            payload: vec![1.0],
        });
        assert!(decode_body(&body[..6]).is_err());
        // misaligned payload
        let mut body2 = body.clone();
        body2.push(0);
        assert!(decode_body(&body2).is_err());
    }

    #[test]
    fn oversized_length_prefix_rejected() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&(u32::MAX).to_le_bytes());
        wire.extend_from_slice(&[0; 16]);
        let mut r = &wire[..];
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn eof_inside_frame_is_error() {
        let mut wire = Vec::new();
        write_frame(&mut wire, &Frame::Shutdown { src: 0 }).unwrap();
        wire.truncate(wire.len() - 1);
        let mut r = &wire[..];
        assert!(read_frame(&mut r).is_err());
        // EOF inside the length prefix itself
        let mut r2 = &wire[..2];
        assert!(read_frame(&mut r2).is_err());
    }

    #[test]
    fn stream_of_frames_in_order() {
        let frames = vec![
            Frame::Hello { rank: 1, addr: "a:1".into() },
            Frame::Data {
                src: 1,
                dst: 0,
                tag: Tag::new(1, 0, Phase::FwdFeat),
                payload: vec![1.0, 2.0],
            },
            Frame::Shutdown { src: 1 },
        ];
        let mut wire = Vec::new();
        for f in &frames {
            write_frame(&mut wire, f).unwrap();
        }
        let mut r = &wire[..];
        for f in &frames {
            assert_eq!(read_frame(&mut r).unwrap().as_ref(), Some(f));
        }
        assert_eq!(read_frame(&mut r).unwrap(), None);
    }
}
