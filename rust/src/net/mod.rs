//! Real network transport + multi-process runtime.
//!
//! Everything the in-process [`crate::comm::Fabric`] simulates, made
//! executable over the wire:
//!
//! * [`frame`] — length-prefixed binary frames (tag header:
//!   iter/layer/phase/src/dst + raw-bit f32 payload, plus the
//!   hello/peer-table/shutdown control frames).
//! * [`tcp`] — [`TcpTransport`]: the [`crate::comm::Transport`] contract
//!   over `std::net` sockets, with per-peer writer threads (sends are
//!   pipelined and never block the compute path) and per-socket reader
//!   threads that fulfill posted receive handles the moment their frame
//!   arrives — falling back to per-(src, tag) FIFO queues for frames
//!   nobody has posted for yet.
//! * [`rendezvous`] — rank-0-style bootstrap: every rank dials one known
//!   address, announces its mesh listener (loopback by default,
//!   `--bind HOST:PORT` for a routable interface — wildcards rejected
//!   on both sides), receives the full peer table, then the all-to-all
//!   socket mesh forms. `--connect-timeout`/`--connect-retries` tune
//!   the rendezvous dial for real LAN latencies.
//! * [`worker`] / [`launch`] — the multi-process runtime: `pipegcn
//!   launch --parts K ...` spawns K OS processes that train over real
//!   localhost sockets; each runs
//!   [`crate::coordinator::threaded::run_rank_ctl`] unchanged. The
//!   launcher supervises its children and, with `--ckpt-dir`, survives a
//!   worker death *elastically*: only the dead rank is respawned, the
//!   survivors re-rendezvous on the same coordinator address, and every
//!   rank rolls back to the latest complete [`crate::ckpt`] checkpoint
//!   (full-mesh relaunch remains the fallback when a rejoin round cannot
//!   form).
//! * [`chaos`] — deterministic per-link fault injection (`--chaos
//!   profile.json`): latency/jitter/bandwidth/drops on the writer path,
//!   counted as `pipegcn_link_faults_total{src,dst,kind}`.
//!
//! The schedule is deterministic over any transport (staleness lives in
//! message tags), so a TCP run's loss curve is bit-identical to the
//! sequential and threaded engines — asserted by `tests/net_e2e.rs`.

pub mod chaos;
pub mod frame;
pub mod launch;
pub mod rendezvous;
pub mod tcp;
pub mod worker;

pub use rendezvous::{connect, localhost_mesh, localhost_mesh_with};
pub use tcp::TcpTransport;
