//! `pipegcn` — launcher CLI for the PipeGCN reproduction.
//!
//! Every training subcommand is a thin flag-parser over
//! [`pipegcn::session::Session`] — one builder, one `run()`, with the
//! engine picked per subcommand:
//!
//! ```text
//! pipegcn train         --dataset reddit-sim --parts 4 --method pipegcn-gf   (Engine::Sequential)
//! pipegcn launch        --parts 4 --dataset reddit-sim [--epochs N]          (Engine::Tcp: K processes over localhost TCP)
//! pipegcn worker        --rank 0 --parts 4 --coord 127.0.0.1:PORT            (Engine::TcpWorker; normally spawned by `launch`)
//! pipegcn export-params --from-ckpt DIR --dataset <preset> --parts K --out params.pgp
//! pipegcn serve         --params params.pgp --dataset <preset> [--bind ADDR] (feature→logit inference server)
//! pipegcn route         --replicas A,B[,C...] [--bind ADDR]                  (replica router: health, failover, rolling reload)
//! pipegcn ctl           --addr HOST:PORT --ping|--drain|--reload FILE        (serving control plane)
//! pipegcn query         --addr HOST:PORT --nodes 0,1,2 [--repeat N]          (client + latency/QPS report)
//! pipegcn query         --addr HOST:PORT --concurrency N|--rate QPS --duration S  (load generator)
//! pipegcn gen-graph     --dataset yelp-sim --out graph.bin [--nodes N]
//! pipegcn partition     --dataset reddit-sim --parts 4 [--algo multilevel|hash|range|bfs]
//! pipegcn sim           --dataset reddit-sim --parts 4 --method pipegcn      (simulated epoch breakdown)
//! pipegcn check         --dataset reddit-sim --parts 4 --method pipegcn      (static schedule verification)
//! pipegcn bench         [--smoke]                                            (kernel/epoch/serve throughput sweep)
//! pipegcn presets       (list dataset presets)
//! ```

use pipegcn::ckpt;
use pipegcn::comm::schedule;
use pipegcn::coordinator::Variant;
use pipegcn::exp::{self, RunOpts};
use pipegcn::graph::{io, presets};
use pipegcn::model::{artifact, ModelConfig};
use pipegcn::partition::{partition, quality, Method};
use pipegcn::session::{Engine, Session};
use pipegcn::sim::Mode;
use pipegcn::util::cli::Args;
use pipegcn::util::error::{Context, Result};
use pipegcn::util::json::{FileEmitter, Json};
use pipegcn::util::timer::Stopwatch;
use pipegcn::util::{fmt_bytes, fmt_secs};

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_str() {
        "train" => cmd_train(&args),
        "launch" => cmd_launch(&args),
        "worker" => cmd_worker(&args),
        "export-params" => cmd_export_params(&args),
        "serve" => cmd_serve(&args),
        "route" => cmd_route(&args),
        "ctl" => cmd_ctl(&args),
        "query" => cmd_query(&args),
        "gen-graph" => cmd_gen_graph(&args),
        "partition" => cmd_partition(&args),
        "sim" => cmd_sim(&args),
        "check" => cmd_check(&args),
        "bench" => cmd_bench(&args),
        "presets" => cmd_presets(),
        "" | "help" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            pipegcn::bail!("unknown subcommand '{other}'")
        }
    }
}

/// Apply `--threads N` to the global kernel pool (default: the
/// `PIPEGCN_THREADS` env var, else the machine's available parallelism).
fn apply_threads_flag(args: &Args) -> Result<()> {
    if args.has("threads") {
        let n = args.get_usize("threads", 0);
        if n == 0 {
            pipegcn::bail!("--threads must be at least 1");
        }
        pipegcn::runtime::pool::set_threads(n);
    }
    Ok(())
}

fn print_help() {
    println!(
        "pipegcn — PipeGCN (ICLR'22) reproduction\n\
         subcommands:\n\
         \x20 train      --dataset <preset> --parts K --method gcn|pipegcn|pipegcn-g|pipegcn-f|pipegcn-gf\n\
         \x20            [--epochs N] [--gamma G] [--seed S] [--probe-errors] [--out results.json]\n\
         \x20            [--log run.ndjson] [--ckpt-dir DIR] [--ckpt-every N] [--resume DIR]\n\
         \x20            (--ckpt-dir snapshots full training state — params, Adam moments,\n\
         \x20             stale buffers — every --ckpt-every epochs; --resume continues the\n\
         \x20             latest complete checkpoint bit-identically)\n\
         \x20 launch     --parts K --dataset <preset> [--method <m>] [--epochs N] [--seed S]\n\
         \x20            [--gamma G] [--log run.ndjson] [--out results.json]\n\
         \x20            [--ckpt-dir DIR] [--ckpt-every N] [--resume DIR] [--max-restarts N]\n\
         \x20            (spawns K worker processes training over real localhost TCP sockets;\n\
         \x20             with --ckpt-dir a worker death is healed in place: only the dead\n\
         \x20             rank is respawned, survivors rejoin on the same address, and every\n\
         \x20             rank rolls back to the latest complete checkpoint — up to\n\
         \x20             --max-restarts recovery rounds, full relaunch as the fallback)\n\
         \x20            [--chaos profile.json] (deterministic per-link latency/jitter/\n\
         \x20             bandwidth/drop injection — see the net::chaos docs for the format)\n\
         \x20            [--mesh-secret S] (HMAC-authenticated mesh formation; also read\n\
         \x20             from PIPEGCN_MESH_SECRET, which keeps it off the process table)\n\
         \x20            [--form-deadline SECS] [--recv-deadline SECS] (mesh-formation and\n\
         \x20             parked-receive watchdogs; both name the culprit on timeout)\n\
         \x20            train/launch/worker also take [--nodes N] (rebuild the preset at N\n\
         \x20             nodes; under launch each rank lazily builds only its own shard —\n\
         \x20             no process holds the full graph) and\n\
         \x20            [--partitioner multilevel|simple|range|bfs] (default multilevel)\n\
         \x20 worker     --rank R --parts K --coord HOST:PORT [--dataset ...] (spawned by launch)\n\
         \x20            [--ckpt-dir DIR] [--ckpt-every N] [--resume DIR] [--rejoin]\n\
         \x20            [--bind HOST:PORT] [--connect-timeout SECS] [--connect-retries N]\n\
         \x20            [--chaos profile.json] [--mesh-secret S] [--form-deadline SECS]\n\
         \x20            [--recv-deadline SECS]\n\
         \x20            (--bind puts the mesh listener on a routable interface for\n\
         \x20             multi-node runs — wildcards like 0.0.0.0 are rejected;\n\
         \x20             connect flags tune the rendezvous dial for LAN latencies;\n\
         \x20             --rejoin marks a replacement joining a live-rejoin round)\n\
         \x20 export-params  --from-ckpt DIR --dataset <preset> --parts K [--epoch N]\n\
         \x20            [--out params.pgp]  (distill a training checkpoint into a\n\
         \x20             standalone serving artifact: model shape + weights only)\n\
         \x20 serve      --params params.pgp --dataset <preset> [--seed S] [--bind HOST:PORT]\n\
         \x20            [--addr-file F] [--max-conns N] [--threads N] [--nodes N]\n\
         \x20            [--shard I/K]  (feature→logit inference over the frame protocol;\n\
         \x20             logits are bit-identical to the full-graph forward. --shard loads\n\
         \x20             only partition I's owned nodes + L-hop closure and answers for\n\
         \x20             owned nodes only — still bit-identical)\n\
         \x20            [--batch-window-ms MS] [--max-batch N] [--no-cache]  (serving tier:\n\
         \x20             queries queued within the window fuse into one kernel pass, and a\n\
         \x20             per-layer activation cache answers plain queries from the final\n\
         \x20             layer only — both bit-transparent; --no-cache restores the\n\
         \x20             full-forward-per-query path)\n\
         \x20 route      --replicas HOST:PORT,HOST:PORT[,...] [--bind HOST:PORT]\n\
         \x20            [--addr-file F] [--max-conns N] [--probe-ms MS] [--metrics-addr A]\n\
         \x20            (one front door for N serve replicas: health-checked least-loaded\n\
         \x20             dispatch, automatic failover on replica death, and rolling\n\
         \x20             artifact reload — `ctl --reload` against the router updates every\n\
         \x20             replica with zero downtime)\n\
         \x20 ctl        --addr HOST:PORT (--ping | --drain | --reload params.pgp)\n\
         \x20            (control a serve replica or router: health/version probe, graceful\n\
         \x20             drain, artifact hot-swap)\n\
         \x20 query      --addr HOST:PORT --nodes 0,1,2 [--repeat N] [--report lat.ndjson]\n\
         \x20            (one batched query per repeat; prints p50/p99 latency and QPS)\n\
         \x20 query --concurrency N | --rate QPS [--workers W]  --addr HOST:PORT\n\
         \x20            [--duration SECS] [--nodes 0,1,2] [--report load.ndjson]\n\
         \x20            (load generator: closed-loop at fixed concurrency, or open-loop at\n\
         \x20             a fixed arrival rate with latency measured from the scheduled\n\
         \x20             send time; reports sustained QPS + p50/p90/p99 and an error count)\n\
         \x20 gen-graph  --dataset <preset> --out graph.bin [--nodes N] [--seed S]\n\
         \x20 partition  --dataset <preset> --parts K [--algo multilevel|hash|range|bfs]\n\
         \x20            [--nodes N]  (--nodes partitions the scaled topology only —\n\
         \x20             no features/labels materialized)\n\
         \x20 sim        --dataset <preset> --parts K --method <m> [--nodes-x-gpus AxB]\n\
         \x20 check      --dataset <preset> --parts K [--method <m>] [--epochs N]\n\
         \x20            [--nodes N] [--seed S] [--partitioner <p>] [--out report.ndjson]\n\
         \x20            (statically verify the generated communication schedule of both\n\
         \x20             executor styles: send/receive matching, tag aliasing, deadlock\n\
         \x20             freedom, the variant's staleness bound, and handle hygiene —\n\
         \x20             topology-only, so --nodes scales without materializing features;\n\
         \x20             violations print with rank/epoch/link/tag and exit nonzero)\n\
         \x20 bench      [--smoke] [--threads 1,2,4] [--out BENCH_kernels.json]\n\
         \x20            [--preset <name>] [--parts K] [--epochs N]\n\
         \x20            (kernel + end-to-end epoch + serve-latency sweep, NDJSON rows)\n\
         \x20 bench --scale  [--preset reddit-1m] [--parts 4] [--epochs 2] [--smoke]\n\
         \x20            [--out BENCH_scale.json]  (per-rank lazy-build trajectory at\n\
         \x20             n = 100K and 1M: build_ms, epoch_ms, peak_rss_bytes, comm_bytes)\n\
         \x20 bench --serve  [--preset <name>] [--smoke] [--out BENCH_serve.json]\n\
         \x20            (serving-tier sustained-QPS sweep: batched vs unbatched at\n\
         \x20             several concurrency levels, p50/p90/p99 per row)\n\
         \x20 presets\n\
         train/launch/worker/sim/bench/serve accept --threads N (kernel worker\n\
         threads; default: PIPEGCN_THREADS or the available parallelism)\n\
         observability: train/launch/worker accept --trace out.json (merged\n\
         Chrome trace-event timeline; open in chrome://tracing or Perfetto)\n\
         and, like serve, --metrics-addr HOST:PORT (live Prometheus text;\n\
         under launch, rank i serves on PORT+i)"
    );
}

/// Shared flag plumbing for the three Session-backed training
/// subcommands: experiment knobs, checkpoint policy, resume, run log.
fn session_from_flags<'a>(args: &Args, dataset: &str, method: &str) -> Result<Session<'a>> {
    let mut s = Session::preset(dataset)
        .parts(args.get_usize("parts", 2))
        .variant(method)
        .epochs(args.get_usize("epochs", 0))
        .seed(args.get_u64("seed", 1))
        .gamma(args.get_f32("gamma", 0.95));
    if args.has("threads") {
        s = s.threads(args.get_usize("threads", 0));
    }
    // scale path: rebuild the preset at --nodes (Tcp engine workers then
    // materialize only their own shard) and/or pick the partitioner
    if args.has("nodes") {
        s = s.scale(args.get_usize("nodes", 0));
    }
    if let Some(p) = args.get_opt("partitioner") {
        s = s.partitioner(p);
    }
    match args.get_opt("ckpt-dir") {
        Some(dir) => {
            s = s.ckpt(ckpt::Policy {
                dir: dir.to_string(),
                every: args.get_usize("ckpt-every", 1),
            })
        }
        None => {
            if args.has("ckpt-every") {
                pipegcn::bail!("--ckpt-every needs --ckpt-dir");
            }
        }
    }
    if let Some(dir) = args.get_opt("resume") {
        s = s.resume(dir);
    }
    if let Some(path) = args.get_opt("log") {
        s = s.log(path);
    }
    // observability: merged Chrome trace + live Prometheus endpoint
    if let Some(path) = args.get_opt("trace") {
        s = s.trace(path);
    }
    if let Some(addr) = args.get_opt("metrics-addr") {
        s = s.metrics_addr(addr);
    }
    Ok(s)
}

/// Hostile-network knobs shared by `launch` and `worker`: chaos
/// injection, mesh auth (flag, or the `PIPEGCN_MESH_SECRET` env var the
/// launcher hands its children so the secret stays off the process
/// table), and the formation/receive deadlines.
fn apply_net_flags<'a>(mut s: Session<'a>, args: &Args) -> Session<'a> {
    if let Some(path) = args.get_opt("chaos") {
        s = s.chaos(path);
    }
    let secret = match args.get_opt("mesh-secret") {
        Some(secret) => Some(secret.to_string()),
        None => std::env::var("PIPEGCN_MESH_SECRET").ok(),
    };
    if let Some(secret) = secret.filter(|s| !s.is_empty()) {
        s = s.mesh_secret(&secret);
    }
    if args.has("form-deadline") {
        s = s.form_deadline(args.get_u64("form-deadline", 60).max(1));
    }
    if args.has("recv-deadline") {
        s = s.recv_deadline(args.get_u64("recv-deadline", 300).max(1));
    }
    s
}

fn cmd_train(args: &Args) -> Result<()> {
    args.assert_known(&[
        "dataset", "parts", "method", "epochs", "gamma", "seed", "probe-errors", "out",
        "eval-every", "log", "ckpt-dir", "ckpt-every", "resume", "threads", "trace",
        "metrics-addr", "nodes", "partitioner",
    ])?;
    let dataset = args.get_str("dataset", "tiny");
    let parts = args.get_usize("parts", 2);
    let method = args.get_str("method", "pipegcn");
    // parse up front for the banner; the error names every valid method
    let variant = Variant::parse(&method, args.get_f32("gamma", 0.95))?;
    let session = session_from_flags(args, &dataset, &method)?
        .eval_every(args.get_usize("eval-every", 5))
        .probe_errors(args.get_bool("probe-errors", false))
        .engine(Engine::Sequential);
    println!(
        "train {dataset} parts={parts} method={} epochs={}",
        variant.name(),
        if args.get_usize("epochs", 0) > 0 {
            args.get_usize("epochs", 0)
        } else {
            presets::by_name(&dataset).map(|p| p.epochs).unwrap_or(0)
        }
    );
    let report = session.run()?;
    if report.log_rows > 0 {
        println!("streamed {} epochs to {}", report.log_rows, args.get_str("log", ""));
    }
    let out = report.into_output();
    let r = &out.result;
    for e in &r.curve {
        if !e.val.is_nan() {
            println!(
                "epoch {:4}  loss {:.4}  val {:.4}  test {:.4}",
                e.epoch, e.train_loss, e.val, e.test
            );
        }
    }
    let v = exp::simulate_default(&out, Mode::Vanilla);
    let p = exp::simulate_default(&out, Mode::Pipelined);
    let b = if variant.is_pipelined() { p } else { v };
    println!(
        "final: test {:.4} (best-val test {:.4}) | comm/epoch {} | sim epoch {} ({} epochs/s, speedup vs vanilla {:.2}x)",
        r.final_test,
        r.best_val_test,
        fmt_bytes(r.comm_bytes_epoch),
        fmt_secs(b.total),
        format!("{:.2}", exp::sim_epochs_per_s(&b)),
        v.total / b.total,
    );
    if let Some(path) = args.get_opt("out") {
        let mut curve = Vec::new();
        for e in &r.curve {
            curve.push(
                Json::obj()
                    .set("epoch", e.epoch)
                    .set("loss", e.train_loss)
                    .set("val", e.val)
                    .set("test", e.test),
            );
        }
        Json::obj()
            .set("dataset", dataset.as_str())
            .set("parts", parts)
            .set("method", r.variant.as_str())
            .set("final_test", r.final_test)
            .set("best_val_test", r.best_val_test)
            .set("comm_bytes_epoch", r.comm_bytes_epoch)
            .set("sim_epoch_s", b.total)
            .set("curve", Json::Arr(curve))
            .write_file(path)?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_launch(args: &Args) -> Result<()> {
    args.assert_known(&[
        "parts", "dataset", "method", "epochs", "seed", "gamma", "log", "out", "ckpt-dir",
        "ckpt-every", "resume", "max-restarts", "fail-rank", "fail-epoch", "threads",
        "trace", "metrics-addr", "nodes", "partitioner", "chaos", "mesh-secret",
        "form-deadline", "recv-deadline",
    ])?;
    let dataset = args.get_str("dataset", "tiny");
    let method = args.get_str("method", "pipegcn");
    let parts = args.get_usize("parts", 2);
    let mut session = session_from_flags(args, &dataset, &method)?
        .engine(Engine::Tcp { max_restarts: args.get_usize("max-restarts", 3) });
    session = apply_net_flags(session, args);
    if let Some(path) = args.get_opt("out") {
        session = session.out(path);
    }
    match (args.has("fail-rank"), args.has("fail-epoch")) {
        (true, true) => {
            // a comma list arms one spawn of the fail rank per entry:
            // original first, then each replacement in turn
            session = session.fail_epochs(
                args.get_usize("fail-rank", 0),
                args.get_usize_list("fail-epoch", &[]),
            );
        }
        (false, false) => {}
        _ => pipegcn::bail!("--fail-rank and --fail-epoch (fault injection) go together"),
    }
    println!(
        "launch {dataset} × {parts} worker processes over localhost TCP (method {method})"
    );
    // Session validates preset/method/resume before spawning anything
    let report = session.run()?;
    println!(
        "launch complete: {} epochs | final loss {:.6} | val {:.4} test {:.4} | \
         rank-0 comm wait {:.1} ms (overlap {:.0}%)",
        report.start_epoch + report.losses.len(),
        report.losses.last().copied().unwrap_or(f64::NAN),
        report.final_val,
        report.final_test,
        report.comm_wait_ms,
        100.0 * report.overlap_ratio,
    );
    Ok(())
}

fn cmd_worker(args: &Args) -> Result<()> {
    args.assert_known(&[
        "rank", "parts", "coord", "dataset", "method", "epochs", "seed", "gamma", "log", "out",
        "ckpt-dir", "ckpt-every", "resume", "fail-epoch", "threads", "bind",
        "connect-timeout", "connect-retries", "trace", "metrics-addr", "nodes",
        "partitioner", "chaos", "mesh-secret", "form-deadline", "recv-deadline", "rejoin",
    ])?;
    let coord = args
        .get_opt("coord")
        .context("worker requires --coord HOST:PORT (normally set by `pipegcn launch`)")?
        .to_string();
    let rank = args.get_usize("rank", 0);
    let dataset = args.get_str("dataset", "tiny");
    let method = args.get_str("method", "pipegcn");
    let mut session = session_from_flags(args, &dataset, &method)?
        .engine(Engine::TcpWorker { rank, coord });
    session = apply_net_flags(session, args);
    if let Some(path) = args.get_opt("out") {
        session = session.out(path);
    }
    if args.has("fail-epoch") {
        session = session.fail_epoch(rank, args.get_usize("fail-epoch", 0));
    }
    if args.get_bool("rejoin", false) {
        session = session.rejoin(true);
    }
    // multi-node reachability: routable mesh listener + rendezvous
    // dial tuning (defaults keep today's localhost behavior)
    if let Some(addr) = args.get_opt("bind") {
        session = session.bind(addr);
    }
    if args.has("connect-timeout") {
        session = session.connect_timeout(args.get_u64("connect-timeout", 60).max(1));
    }
    if args.has("connect-retries") {
        session = session.connect_retries(args.get_usize("connect-retries", 0));
    }
    // bad preset/method names surface as diagnostics (not deep panics)
    // via exp::try_prepare, the worker adapter's first call
    let report = session.run()?;
    if rank == 0 {
        for (i, loss) in report.losses.iter().enumerate() {
            println!("epoch {:4}  loss {:.4}", report.start_epoch + i + 1, loss);
        }
        println!(
            "final: loss {:.6} | val {:.4} test {:.4} | rank-0 sent {} payload ({} on the wire)",
            report.losses.last().unwrap_or(&f64::NAN),
            report.final_val,
            report.final_test,
            fmt_bytes(report.comm_bytes),
            fmt_bytes(report.wire_bytes),
        );
    }
    Ok(())
}

fn cmd_export_params(args: &Args) -> Result<()> {
    args.assert_known(&["from-ckpt", "dataset", "parts", "epoch", "out"])?;
    let dir = args
        .get_opt("from-ckpt")
        .context("export-params requires --from-ckpt DIR (a training checkpoint directory)")?;
    let dataset = args.get_str("dataset", "tiny");
    let parts = args.get_usize("parts", 2);
    let out = args.get_str("out", "params.pgp");
    let preset = presets::by_name(&dataset)
        .ok_or_else(|| pipegcn::err_msg!("unknown preset '{dataset}'"))?;
    // the same preset→model mapping training used, so shapes cannot drift
    let cfg = ModelConfig::from_preset(preset);
    let epoch = args.get_opt("epoch").map(|_| args.get_usize("epoch", 0));
    let (pf, epoch) = artifact::export_from_ckpt(dir, parts, &cfg, epoch)?;
    artifact::save(&out, &pf)?;
    println!(
        "wrote {out}: {} layers, {} parameters (epoch-{epoch} checkpoint of {dir})",
        pf.params.layers.len(),
        pf.params.n_elems(),
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    args.assert_known(&[
        "params", "dataset", "seed", "bind", "addr-file", "max-conns", "threads",
        "metrics-addr", "nodes", "shard", "batch-window-ms", "max-batch", "no-cache",
    ])?;
    apply_threads_flag(args)?;
    // live Prometheus endpoint (per-query latency histogram, active
    // connections), held for the server's lifetime
    let _metrics = match args.get_opt("metrics-addr") {
        Some(addr) => {
            let srv = pipegcn::obs::http::serve(addr)
                .with_context(|| format!("--metrics-addr {addr}"))?;
            println!("metrics on http://{}/metrics", srv.addr());
            Some(srv)
        }
        None => None,
    };
    // --shard I/K: serve only partition I's owned nodes, loading just
    // their L-hop closure instead of the full graph
    let shard = match args.get_opt("shard") {
        Some(spec) => {
            let (i, k) = spec
                .split_once('/')
                .ok_or_else(|| pipegcn::err_msg!("--shard expects I/K (e.g. 0/4)"))?;
            Some((i.trim().parse::<usize>()?, k.trim().parse::<usize>()?))
        }
        None => None,
    };
    let opts = pipegcn::serve::ServeOpts {
        params_path: args
            .get_opt("params")
            .context("serve requires --params FILE (see `pipegcn export-params`)")?
            .to_string(),
        dataset: args.get_str("dataset", "tiny"),
        seed: args.get_u64("seed", 1),
        bind: args.get_str("bind", "127.0.0.1:0"),
        nodes: args.get_opt("nodes").map(|_| args.get_usize("nodes", 0)),
        shard,
    };
    let server = pipegcn::serve::Server::bind(&opts)?;
    let ctx = server.ctx();
    let scope_note = match &ctx.scope {
        Some(s) => format!(
            ", shard {}/{}: {} owned, {} in closure",
            s.part,
            s.parts,
            s.owned.len(),
            s.closure.len()
        ),
        None => String::new(),
    };
    println!(
        "serving {} on {} ({} nodes, feat {}, {} classes{scope_note})",
        opts.dataset,
        server.addr(),
        ctx.n,
        ctx.feat_dim,
        ctx.n_classes,
    );
    if let Some(path) = args.get_opt("addr-file") {
        std::fs::write(path, server.addr())
            .with_context(|| format!("writing addr file {path}"))?;
    }
    let max_conns = args.get_opt("max-conns").map(|_| args.get_usize("max-conns", 1));
    let mut tier = pipegcn::serve::tier::TierOpts::default();
    if args.has("batch-window-ms") {
        tier.window_ms = args.get_f64("batch-window-ms", 1.0);
    }
    if args.has("max-batch") {
        tier.max_batch = args.get_usize("max-batch", 32).max(1);
    }
    tier.cache = !args.get_bool("no-cache", false);
    server.run_tier(max_conns, tier)
}

fn cmd_route(args: &Args) -> Result<()> {
    args.assert_known(&[
        "bind", "replicas", "addr-file", "max-conns", "probe-ms", "metrics-addr",
    ])?;
    let _metrics = match args.get_opt("metrics-addr") {
        Some(addr) => {
            let srv = pipegcn::obs::http::serve(addr)
                .with_context(|| format!("--metrics-addr {addr}"))?;
            println!("metrics on http://{}/metrics", srv.addr());
            Some(srv)
        }
        None => None,
    };
    let replicas: Vec<String> = args
        .get_opt("replicas")
        .context("route requires --replicas HOST:PORT,HOST:PORT[,...]")?
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    let opts = pipegcn::serve::tier::RouterOpts {
        bind: args.get_str("bind", "127.0.0.1:0"),
        replicas,
        probe_ms: args.get_u64("probe-ms", 500),
    };
    let router = pipegcn::serve::tier::Router::bind(&opts)?;
    println!("routing {} replicas on {}", opts.replicas.len(), router.addr());
    if let Some(path) = args.get_opt("addr-file") {
        std::fs::write(path, router.addr())
            .with_context(|| format!("writing addr file {path}"))?;
    }
    let max_conns = args.get_opt("max-conns").map(|_| args.get_usize("max-conns", 1));
    router.run(max_conns)
}

fn cmd_ctl(args: &Args) -> Result<()> {
    args.assert_known(&["addr", "ping", "drain", "reload"])?;
    let addr = args.get_opt("addr").context("ctl requires --addr HOST:PORT")?;
    let mut client = pipegcn::serve::Client::connect(addr)
        .with_context(|| format!("connecting to {addr}"))?;
    if args.get_bool("ping", false) {
        let ack = client.ping().context("ping")?;
        println!("{addr}: {ack}");
    } else if args.get_bool("drain", false) {
        client.drain().context("drain")?;
        println!("{addr}: draining");
    } else if let Some(path) = args.get_opt("reload") {
        let ack = client.reload(path).context("reload")?;
        println!("{addr}: reloaded → {ack}");
    } else {
        pipegcn::bail!("ctl needs one of --ping, --drain, --reload FILE");
    }
    client.close();
    Ok(())
}

fn cmd_query(args: &Args) -> Result<()> {
    args.assert_known(&[
        "addr", "nodes", "repeat", "report", "concurrency", "rate", "duration", "workers",
    ])?;
    let addr = args.get_opt("addr").context("query requires --addr HOST:PORT")?;
    let ids: Vec<u32> =
        args.get_usize_list("nodes", &[0]).iter().map(|&v| v as u32).collect();
    // load-generator path: --concurrency (closed loop) or --rate (open
    // loop) turn the single-client latency probe into a sustained-QPS
    // measurement; the flagless path below is byte-for-byte unchanged
    if args.has("concurrency") || args.has("rate") {
        if args.has("repeat") {
            pipegcn::bail!("--repeat belongs to the single-client path; use --duration");
        }
        let mode = if args.has("rate") {
            pipegcn::serve::tier::LoadMode::Open {
                rate: args.get_f64("rate", 100.0),
                workers: args.get_usize("workers", 4),
            }
        } else {
            pipegcn::serve::tier::LoadMode::Closed {
                concurrency: args.get_usize("concurrency", 1),
            }
        };
        let r = pipegcn::serve::tier::loadgen::run(&pipegcn::serve::tier::LoadOpts {
            addr: addr.to_string(),
            ids: ids.clone(),
            mode,
            duration_s: args.get_f64("duration", 5.0),
        });
        println!(
            "{} load on {addr}: {} queries in {:.2}s → {:.1} qps | p50 {:.3} ms  \
             p90 {:.3} ms  p99 {:.3} ms | {} errors",
            r.mode, r.queries, r.duration_s, r.qps, r.p50_ms, r.p90_ms, r.p99_ms, r.errors
        );
        if let Some(path) = args.get_opt("report") {
            let mut em = FileEmitter::create(
                path,
                Json::obj().set("bench", "pipegcn-serve-load").set("addr", addr),
            )
            .with_context(|| format!("creating load report {path}"))?;
            em.emit(
                &Json::obj()
                    .set("mode", r.mode)
                    .set("concurrency", r.concurrency)
                    .set("rate_qps", r.rate_qps)
                    .set("duration_s", r.duration_s)
                    .set("queries", r.queries)
                    .set("errors", r.errors)
                    .set("qps", r.qps)
                    .set("p50_ms", r.p50_ms)
                    .set("p90_ms", r.p90_ms)
                    .set("p99_ms", r.p99_ms),
            )?;
            println!("wrote {path}");
        }
        if r.errors > 0 {
            pipegcn::bail!("{} queries failed", r.errors);
        }
        return Ok(());
    }
    let repeat = args.get_usize("repeat", 1).max(1);
    let mut client = pipegcn::serve::Client::connect(addr)
        .with_context(|| format!("connecting to {addr}"))?;
    let mut lats_ms = Vec::with_capacity(repeat);
    let mut logits = None;
    // the same log-bucketed histogram the serve endpoint exports —
    // client-side round-trip view of the query latency distribution
    let hist = pipegcn::obs::global().histogram("query_roundtrip_ms", &[]);
    let total_watch = Stopwatch::start();
    for _ in 0..repeat {
        let w = Stopwatch::start();
        let m = client.query(&ids)?;
        let ms = w.elapsed_secs() * 1e3;
        lats_ms.push(ms);
        hist.record(ms);
        logits = Some(m);
    }
    let total_secs = total_watch.elapsed_secs();
    client.close();
    let logits = logits.expect("repeat >= 1 always yields a response");
    if logits.data.is_empty() {
        pipegcn::bail!("server returned no logits");
    }
    lats_ms.sort_by(f64::total_cmp);
    let p50 = pipegcn::perf::percentile(&lats_ms, 0.50);
    let p99 = pipegcn::perf::percentile(&lats_ms, 0.99);
    let qps = repeat as f64 / total_secs.max(1e-12);
    // peek at the first queried node so "non-empty logits" is visible
    let row0: Vec<String> =
        logits.row(0).iter().take(8).map(|v| format!("{v:.4}")).collect();
    println!("node {} logits: [{}{}]", ids[0], row0.join(", "), if logits.cols > 8 { ", …" } else { "" });
    println!(
        "ok: {} nodes × {} classes | p50 {:.3} ms  p99 {:.3} ms | {:.1} qps ({repeat} queries)",
        logits.rows, logits.cols, p50, p99, qps
    );
    if let Some(path) = args.get_opt("report") {
        let mut em = FileEmitter::create(
            path,
            Json::obj()
                .set("addr", addr)
                .set("batch", ids.len())
                .set("repeat", repeat),
        )
        .with_context(|| format!("creating latency report {path}"))?;
        for (i, ms) in lats_ms.iter().enumerate() {
            em.emit(&Json::obj().set("query", i).set("ms", *ms))?;
        }
        // exact nearest-rank percentiles stay under their original keys
        // (bit-compatible with older reports); the histogram view adds
        // log-bucketed quantiles plus the full bucket shape
        let buckets: Vec<Json> = hist
            .nonzero_buckets()
            .into_iter()
            .map(|(le, n)| Json::Arr(vec![Json::from(le), Json::from(n)]))
            .collect();
        em.emit(
            &Json::obj()
                .set("p50_ms", p50)
                .set("p99_ms", p99)
                .set("qps", qps)
                .set("hist_p50_ms", hist.quantile(0.50))
                .set("hist_p90_ms", hist.quantile(0.90))
                .set("hist_p99_ms", hist.quantile(0.99))
                .set("hist_buckets", Json::Arr(buckets)),
        )?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    args.assert_known(&[
        "out", "threads", "smoke", "preset", "parts", "epochs", "scale", "serve",
    ])?;
    let smoke = args.get_bool("smoke", false);
    let scale = args.get_bool("scale", false);
    let serve = args.get_bool("serve", false);
    if scale && serve {
        pipegcn::bail!("--scale and --serve are separate sweeps; pick one");
    }
    let opts = pipegcn::perf::BenchOpts {
        out: args.get_str(
            "out",
            if scale {
                "BENCH_scale.json"
            } else if serve {
                "BENCH_serve.json"
            } else {
                "BENCH_kernels.json"
            },
        ),
        threads: args.get_usize_list("threads", &[1, 2, 4]),
        smoke,
        preset: args.get_str(
            "preset",
            if scale {
                "reddit-1m"
            } else if smoke {
                "tiny"
            } else {
                "reddit-sim"
            },
        ),
        parts: args.get_usize("parts", if smoke && !scale { 2 } else { 4 }),
        epochs: args.get_usize("epochs", if scale || smoke { 2 } else { 3 }),
        scale,
        serve,
    };
    if opts.threads.iter().any(|&t| t == 0) {
        pipegcn::bail!("--threads entries must be at least 1");
    }
    if opts.scale {
        pipegcn::perf::run_scale_bench(&opts)
    } else if opts.serve {
        pipegcn::perf::run_serve_bench(&opts)
    } else {
        pipegcn::perf::run_bench(&opts)
    }
}

fn cmd_gen_graph(args: &Args) -> Result<()> {
    args.assert_known(&["dataset", "out", "nodes", "seed"])?;
    let dataset = args.get_str("dataset", "tiny");
    let out = args.get_str("out", "graph.bin");
    let seed = args.get_u64("seed", 1);
    let preset = presets::by_name(&dataset)
        .ok_or_else(|| pipegcn::err_msg!("unknown preset '{dataset}'"))?;
    let g = match args.get_opt("nodes") {
        Some(_) => preset.build_scaled(args.get_usize("nodes", preset.n), seed),
        None => preset.build(seed),
    };
    io::save(&g, &out)?;
    println!(
        "wrote {out}: {} nodes, {} edges, feat {}, {} classes ({})",
        g.n,
        g.num_edges(),
        g.feat_dim(),
        g.labels.n_classes(),
        if g.labels.is_multilabel() { "multi-label" } else { "single-label" }
    );
    Ok(())
}

fn cmd_partition(args: &Args) -> Result<()> {
    args.assert_known(&["dataset", "parts", "algo", "seed", "nodes"])?;
    let dataset = args.get_str("dataset", "tiny");
    let parts = args.get_usize("parts", 2);
    let algo = args.get_str("algo", "multilevel");
    let seed = args.get_u64("seed", 1);
    let method = Method::parse(&algo).ok_or_else(|| pipegcn::err_msg!("bad --algo '{algo}'"))?;
    let preset = presets::by_name(&dataset)
        .ok_or_else(|| pipegcn::err_msg!("unknown preset '{dataset}'"))?;
    let q = match args.get_opt("nodes") {
        // topology-only path: partition a scaled build without ever
        // materializing features or labels
        Some(_) => {
            let topo = preset.build_topology_scaled(args.get_usize("nodes", preset.n), seed);
            let pt = pipegcn::partition::partition_adj(topo.adj(), parts, method, seed);
            pipegcn::partition::quality_adj(topo.adj(), &pt)
        }
        None => {
            let g = preset.build(seed);
            let pt = partition(&g, parts, method, seed);
            quality(&g, &pt)
        }
    };
    println!(
        "{dataset} × {parts} parts via {algo}: edge-cut {} | comm volume {} | replication {:.3} | balance {:.3}",
        q.edge_cut, q.comm_volume, q.replication_factor, q.balance
    );
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<()> {
    args.assert_known(&[
        "dataset", "parts", "method", "nodes-x-gpus", "epochs", "seed", "threads",
    ])?;
    apply_threads_flag(args)?;
    let dataset = args.get_str("dataset", "reddit-sim");
    let parts = args.get_usize("parts", 2);
    let method = args.get_str("method", "pipegcn");
    let opts = RunOpts {
        epochs: args.get_usize("epochs", 4),
        seed: args.get_u64("seed", 1),
        eval_every: 0,
        ..Default::default()
    };
    // validated up front (the Session would too, but the mode choice
    // below needs the parsed variant anyway)
    let variant = Variant::parse(&method, 0.95)?;
    let out = Session::preset(&dataset)
        .parts(parts)
        .variant(&method)
        .run_opts(opts)
        .run()?
        .into_output();
    let mode = if variant.is_pipelined() { Mode::Pipelined } else { Mode::Vanilla };
    let breakdown = match args.get_opt("nodes-x-gpus") {
        Some(spec) => {
            let (nodes, per) = spec
                .split_once('x')
                .ok_or_else(|| pipegcn::err_msg!("--nodes-x-gpus expects AxB"))?;
            let (profile, topo) =
                pipegcn::sim::profiles::rig_mi60(nodes.parse()?, per.parse()?);
            exp::simulate(&out, &profile, &topo, mode)
        }
        None => exp::simulate_default(&out, mode),
    };
    println!(
        "{dataset} × {parts} [{}]: total {} | compute {} | comm {} (exposed {}) | reduce {} | comm ratio {:.1}%",
        variant.name(),
        fmt_secs(breakdown.total),
        fmt_secs(breakdown.compute),
        fmt_secs(breakdown.comm_total),
        fmt_secs(breakdown.comm_exposed),
        fmt_secs(breakdown.reduce),
        100.0 * breakdown.comm_ratio()
    );
    Ok(())
}

/// `pipegcn check`: statically verify the communication schedule the
/// engines would execute for a preset × parts × variant, via
/// `comm::schedule`. Topology-only — features and labels are never
/// materialized, so `--nodes` scales to paper-size graphs cheaply.
/// Both executor styles (the threaded/TCP prefetched order and the
/// sequential inline replay) are generated and verified; any violation
/// prints its rank/epoch/link/tag diagnostic and the command exits
/// nonzero.
fn cmd_check(args: &Args) -> Result<()> {
    args.assert_known(&[
        "dataset", "preset", "parts", "method", "variant", "epochs", "nodes", "seed",
        "partitioner", "out",
    ])?;
    // `--preset`/`--variant` are aliases for the `--dataset`/`--method`
    // spellings the training subcommands use
    let dataset = match args.get_opt("preset") {
        Some(p) => p.to_string(),
        None => args.get_str("dataset", "tiny"),
    };
    let method = match args.get_opt("variant") {
        Some(v) => v.to_string(),
        None => args.get_str("method", "pipegcn"),
    };
    let parts = args.get_usize("parts", 2);
    let epochs = args.get_usize("epochs", 2);
    let seed = args.get_u64("seed", 1);
    if parts == 0 {
        pipegcn::bail!("--parts must be at least 1");
    }
    let variant = Variant::parse(&method, 0.95)?;
    let preset = presets::by_name(&dataset)
        .ok_or_else(|| pipegcn::err_msg!("unknown preset '{dataset}'"))?;
    let cfg = ModelConfig::from_preset(preset);
    let algo = args.get_str("partitioner", "multilevel");
    let pmethod =
        Method::parse(&algo).ok_or_else(|| pipegcn::err_msg!("bad --partitioner '{algo}'"))?;
    let topo = match args.get_opt("nodes") {
        Some(_) => preset.build_topology_scaled(args.get_usize("nodes", preset.n), seed),
        None => preset.build_topology(seed),
    };
    let pt = pipegcn::partition::partition_adj(topo.adj(), parts, pmethod, seed);
    let links = pipegcn::coordinator::halo::comm_links_all(topo.adj(), &pt.assign, parts);

    let mut emitter = match args.get_opt("out") {
        Some(path) => Some(
            FileEmitter::create(
                path,
                Json::obj()
                    .set("dataset", dataset.as_str())
                    .set("parts", parts)
                    .set("method", variant.name())
                    .set("epochs", epochs)
                    .set("layers", cfg.n_layers()),
            )
            .with_context(|| format!("creating check report {path}"))?,
        ),
        None => None,
    };
    println!(
        "check {dataset} × {parts} parts [{}]: {} layers, {epochs} epochs",
        variant.name(),
        cfg.n_layers()
    );
    let mut total = 0usize;
    for (style, name) in
        [(schedule::Style::Prefetched, "prefetched"), (schedule::Style::Inline, "inline")]
    {
        let sched = schedule::Schedule::generate(
            &links,
            style,
            variant.is_pipelined(),
            cfg.n_layers(),
            1,
            epochs as u32,
        )?;
        let violations = schedule::verify(&sched);
        println!(
            "  {name:<10} {:>7} events: {}",
            sched.n_events(),
            if violations.is_empty() {
                "ok — matching, aliasing, deadlock, staleness, hygiene all hold".to_string()
            } else {
                format!("{} violation(s)", violations.len())
            }
        );
        for v in &violations {
            println!("    {v}");
            if let Some(em) = emitter.as_mut() {
                em.emit(&v.to_json().set("style", name))?;
            }
        }
        total += violations.len();
    }
    if let Some(path) = args.get_opt("out") {
        println!("wrote {path}");
    }
    if total > 0 {
        pipegcn::bail!("schedule verification failed: {total} violation(s)");
    }
    Ok(())
}

fn cmd_presets() -> Result<()> {
    println!(
        "{:<14} {:<16} {:>7} {:>6} {:>5} {:>7} {:>7} {:>7}",
        "preset", "mirrors", "nodes", "feat", "cls", "layers", "hidden", "epochs"
    );
    for p in &presets::PRESETS {
        println!(
            "{:<14} {:<16} {:>7} {:>6} {:>5} {:>7} {:>7} {:>7}",
            p.name, p.mirrors, p.n, p.feat_dim, p.n_classes, p.layers, p.hidden, p.epochs
        );
    }
    Ok(())
}
