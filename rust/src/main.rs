//! `pipegcn` — launcher CLI for the PipeGCN reproduction.
//!
//! ```text
//! pipegcn train      --dataset reddit-sim --parts 4 --method pipegcn-gf [--epochs N] [--gamma G] [--log run.ndjson]
//! pipegcn launch     --parts 4 --dataset reddit-sim [--epochs N]  (multi-process training over localhost TCP)
//! pipegcn worker     --rank 0 --parts 4 --coord 127.0.0.1:PORT    (one rank; normally spawned by `launch`)
//! pipegcn gen-graph  --dataset yelp-sim --out graph.bin [--nodes N]
//! pipegcn partition  --dataset reddit-sim --parts 4 [--algo multilevel|hash|range|bfs]
//! pipegcn sim        --dataset reddit-sim --parts 4 --method pipegcn  (simulated epoch breakdown)
//! pipegcn presets    (list dataset presets)
//! ```

use pipegcn::coordinator::Variant;
use pipegcn::exp::{self, RunOpts};
use pipegcn::graph::{io, presets};
use pipegcn::net::{launch::LaunchOpts, worker::WorkerOpts};
use pipegcn::partition::{partition, quality, Method};
use pipegcn::sim::Mode;
use pipegcn::util::cli::Args;
use pipegcn::util::error::{Context, Result};
use pipegcn::util::json::{FileEmitter, Json};
use pipegcn::util::{fmt_bytes, fmt_secs};

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_str() {
        "train" => cmd_train(&args),
        "launch" => cmd_launch(&args),
        "worker" => cmd_worker(&args),
        "gen-graph" => cmd_gen_graph(&args),
        "partition" => cmd_partition(&args),
        "sim" => cmd_sim(&args),
        "bench" => cmd_bench(&args),
        "presets" => cmd_presets(),
        "" | "help" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            pipegcn::bail!("unknown subcommand '{other}'")
        }
    }
}

/// Apply `--threads N` to the global kernel pool (default: the
/// `PIPEGCN_THREADS` env var, else the machine's available parallelism).
fn apply_threads_flag(args: &Args) -> Result<()> {
    if args.has("threads") {
        let n = args.get_usize("threads", 0);
        if n == 0 {
            pipegcn::bail!("--threads must be at least 1");
        }
        pipegcn::runtime::pool::set_threads(n);
    }
    Ok(())
}

fn print_help() {
    println!(
        "pipegcn — PipeGCN (ICLR'22) reproduction\n\
         subcommands:\n\
         \x20 train      --dataset <preset> --parts K --method gcn|pipegcn|pipegcn-g|pipegcn-f|pipegcn-gf\n\
         \x20            [--epochs N] [--gamma G] [--seed S] [--probe-errors] [--out results.json]\n\
         \x20            [--log run.ndjson] [--ckpt-dir DIR] [--ckpt-every N] [--resume DIR]\n\
         \x20            (--ckpt-dir snapshots full training state — params, Adam moments,\n\
         \x20             stale buffers — every --ckpt-every epochs; --resume continues the\n\
         \x20             latest complete checkpoint bit-identically)\n\
         \x20 launch     --parts K --dataset <preset> [--method <m>] [--epochs N] [--seed S]\n\
         \x20            [--gamma G] [--log run.ndjson] [--out results.json]\n\
         \x20            [--ckpt-dir DIR] [--ckpt-every N] [--resume DIR] [--max-restarts N]\n\
         \x20            (spawns K worker processes training over real localhost TCP sockets;\n\
         \x20             with --ckpt-dir a worker death relaunches the mesh from the latest\n\
         \x20             complete checkpoint, up to --max-restarts times)\n\
         \x20 worker     --rank R --parts K --coord HOST:PORT [--dataset ...] (spawned by launch)\n\
         \x20            [--ckpt-dir DIR] [--ckpt-every N] [--resume DIR]\n\
         \x20 gen-graph  --dataset <preset> --out graph.bin [--nodes N] [--seed S]\n\
         \x20 partition  --dataset <preset> --parts K [--algo multilevel|hash|range|bfs]\n\
         \x20 sim        --dataset <preset> --parts K --method <m> [--nodes-x-gpus AxB]\n\
         \x20 bench      [--smoke] [--threads 1,2,4] [--out BENCH_kernels.json]\n\
         \x20            [--preset <name>] [--parts K] [--epochs N]\n\
         \x20            (kernel + end-to-end throughput sweep, NDJSON rows)\n\
         \x20 presets\n\
         train/launch/worker/sim/bench accept --threads N (kernel worker\n\
         threads; default: PIPEGCN_THREADS or the available parallelism)"
    );
}

fn cmd_bench(args: &Args) -> Result<()> {
    args.assert_known(&["out", "threads", "smoke", "preset", "parts", "epochs"])?;
    let smoke = args.get_bool("smoke", false);
    let opts = pipegcn::perf::BenchOpts {
        out: args.get_str("out", "BENCH_kernels.json"),
        threads: args.get_usize_list("threads", &[1, 2, 4]),
        smoke,
        preset: args.get_str("preset", if smoke { "tiny" } else { "reddit-sim" }),
        parts: args.get_usize("parts", if smoke { 2 } else { 4 }),
        epochs: args.get_usize("epochs", if smoke { 2 } else { 3 }),
    };
    if opts.threads.iter().any(|&t| t == 0) {
        pipegcn::bail!("--threads entries must be at least 1");
    }
    pipegcn::perf::run_bench(&opts)
}

fn cmd_launch(args: &Args) -> Result<()> {
    args.assert_known(&[
        "parts", "dataset", "method", "epochs", "seed", "gamma", "log", "out", "ckpt-dir",
        "ckpt-every", "resume", "max-restarts", "fail-rank", "fail-epoch", "threads",
    ])?;
    if args.has("threads") && args.get_usize("threads", 0) == 0 {
        pipegcn::bail!("--threads must be at least 1");
    }
    let opts = LaunchOpts {
        parts: args.get_usize("parts", 2),
        dataset: args.get_str("dataset", "tiny"),
        method: args.get_str("method", "pipegcn"),
        epochs: args.get_usize("epochs", 0),
        seed: args.get_u64("seed", 1),
        gamma: args.get_f32("gamma", 0.95),
        log: args.get_opt("log").map(String::from),
        out: args.get_opt("out").map(String::from),
        ckpt_dir: args.get_opt("ckpt-dir").map(String::from),
        ckpt_every: args.get_usize("ckpt-every", 1),
        resume: args.get_opt("resume").map(String::from),
        max_restarts: args.get_usize("max-restarts", 3),
        fail_rank: args.get_opt("fail-rank").map(|_| args.get_usize("fail-rank", 0)),
        fail_epoch: args.get_opt("fail-epoch").map(|_| args.get_usize("fail-epoch", 0)),
        threads: args.get_opt("threads").map(|_| args.get_usize("threads", 1)),
    };
    // validate before spawning: a bad flag must fail here, not as K
    // worker panics followed by a rendezvous timeout
    if Variant::parse(&opts.method, opts.gamma).is_none() {
        pipegcn::bail!("bad --method '{}'", opts.method);
    }
    if presets::by_name(&opts.dataset).is_none() {
        pipegcn::bail!(
            "unknown preset '{}' (try `pipegcn presets` for the list)",
            opts.dataset
        );
    }
    if opts.ckpt_dir.is_none() && args.has("ckpt-every") {
        pipegcn::bail!("--ckpt-every needs --ckpt-dir");
    }
    if opts.ckpt_dir.is_some() && opts.ckpt_every == 0 {
        pipegcn::bail!("--ckpt-every must be at least 1");
    }
    if opts.fail_rank.is_some() != opts.fail_epoch.is_some() {
        pipegcn::bail!("--fail-rank and --fail-epoch (fault injection) go together");
    }
    if let Some(dir) = &opts.resume {
        if pipegcn::ckpt::latest_complete(dir, opts.parts)?.is_none() {
            pipegcn::bail!(
                "--resume {dir}: no complete checkpoint for {} ranks",
                opts.parts
            );
        }
    }
    println!(
        "launch {} × {} worker processes over localhost TCP (method {})",
        opts.dataset, opts.parts, opts.method
    );
    let bin = std::env::current_exe().context("resolving the pipegcn binary path")?;
    pipegcn::net::launch::launch(&bin, &opts)
}

fn cmd_worker(args: &Args) -> Result<()> {
    args.assert_known(&[
        "rank", "parts", "coord", "dataset", "method", "epochs", "seed", "gamma", "log", "out",
        "ckpt-dir", "ckpt-every", "resume", "fail-epoch", "threads",
    ])?;
    apply_threads_flag(args)?;
    let coord = args
        .get_opt("coord")
        .context("worker requires --coord HOST:PORT (normally set by `pipegcn launch`)")?
        .to_string();
    let opts = WorkerOpts {
        rank: args.get_usize("rank", 0),
        parts: args.get_usize("parts", 2),
        coord,
        dataset: args.get_str("dataset", "tiny"),
        method: args.get_str("method", "pipegcn"),
        epochs: args.get_usize("epochs", 0),
        seed: args.get_u64("seed", 1),
        gamma: args.get_f32("gamma", 0.95),
        log: args.get_opt("log").map(String::from),
        out: args.get_opt("out").map(String::from),
        ckpt_dir: args.get_opt("ckpt-dir").map(String::from),
        ckpt_every: args.get_usize("ckpt-every", 1),
        resume: args.get_opt("resume").map(String::from),
        fail_epoch: args.get_opt("fail-epoch").map(|_| args.get_usize("fail-epoch", 0)),
    };
    // bad preset/method names surface as diagnostics (not deep panics)
    // via exp::try_prepare, run_worker's first call
    if let Some(summary) = pipegcn::net::worker::run_worker(&opts)? {
        for (i, loss) in summary.losses.iter().enumerate() {
            println!("epoch {:4}  loss {:.4}", summary.start_epoch + i + 1, loss);
        }
        println!(
            "final: loss {:.6} | val {:.4} test {:.4} | rank-0 sent {} payload ({} on the wire)",
            summary.losses.last().unwrap_or(&f64::NAN),
            summary.final_val,
            summary.final_test,
            fmt_bytes(summary.payload_bytes_sent),
            fmt_bytes(summary.wire_bytes_sent),
        );
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    args.assert_known(&[
        "dataset", "parts", "method", "epochs", "gamma", "seed", "probe-errors", "out",
        "eval-every", "log", "ckpt-dir", "ckpt-every", "resume", "threads",
    ])?;
    apply_threads_flag(args)?;
    let dataset = args.get_str("dataset", "tiny");
    let parts = args.get_usize("parts", 2);
    let method = args.get_str("method", "pipegcn");
    let opts = RunOpts {
        epochs: args.get_usize("epochs", 0),
        seed: args.get_u64("seed", 1),
        probe_errors: args.get_bool("probe-errors", false),
        gamma: args.get_f32("gamma", 0.95),
        eval_every: args.get_usize("eval-every", 5),
    };
    let variant = Variant::parse(&method, opts.gamma)
        .ok_or_else(|| pipegcn::err_msg!("bad --method '{method}'"))?;
    let ckpt_policy = args.get_opt("ckpt-dir").map(|dir| pipegcn::ckpt::Policy {
        dir: dir.to_string(),
        every: args.get_usize("ckpt-every", 1),
    });
    if ckpt_policy.is_none() && args.has("ckpt-every") {
        pipegcn::bail!("--ckpt-every needs --ckpt-dir");
    }
    if let Some(p) = &ckpt_policy {
        if p.every == 0 {
            pipegcn::bail!("--ckpt-every must be at least 1");
        }
    }
    let resume = args.get_opt("resume").map(String::from);
    println!(
        "train {dataset} parts={parts} method={} epochs={}",
        variant.name(),
        if opts.epochs > 0 { opts.epochs } else { presets::by_name(&dataset).map(|p| p.epochs).unwrap_or(0) }
    );
    let out = match args.get_opt("log") {
        Some(log_path) => {
            let header = Json::obj()
                .set("dataset", dataset.as_str())
                .set("parts", parts)
                .set("method", variant.name())
                .set("seed", opts.seed)
                .set("engine", "sequential");
            // resuming appends, so the pre-crash epoch rows survive
            let mut emitter = if resume.is_some() {
                FileEmitter::append_or_create(log_path, header)
            } else {
                FileEmitter::create(log_path, header)
            }
            .with_context(|| format!("creating run log {log_path}"))?;
            let out = exp::run_resumable(
                &dataset,
                parts,
                &method,
                opts,
                Some(&mut emitter),
                ckpt_policy.as_ref(),
                resume.as_deref(),
            )?;
            println!("streamed {} epochs to {log_path}", emitter.rows());
            out
        }
        None => exp::run_resumable(
            &dataset,
            parts,
            &method,
            opts,
            None,
            ckpt_policy.as_ref(),
            resume.as_deref(),
        )?,
    };
    let r = &out.result;
    for e in &r.curve {
        if !e.val.is_nan() {
            println!(
                "epoch {:4}  loss {:.4}  val {:.4}  test {:.4}",
                e.epoch, e.train_loss, e.val, e.test
            );
        }
    }
    let v = exp::simulate_default(&out, Mode::Vanilla);
    let p = exp::simulate_default(&out, Mode::Pipelined);
    let b = if variant.is_pipelined() { p } else { v };
    println!(
        "final: test {:.4} (best-val test {:.4}) | comm/epoch {} | sim epoch {} ({} epochs/s, speedup vs vanilla {:.2}x)",
        r.final_test,
        r.best_val_test,
        fmt_bytes(r.comm_bytes_epoch),
        fmt_secs(b.total),
        format!("{:.2}", exp::sim_epochs_per_s(&b)),
        v.total / b.total,
    );
    if let Some(path) = args.get_opt("out") {
        let mut curve = Vec::new();
        for e in &r.curve {
            curve.push(
                Json::obj()
                    .set("epoch", e.epoch)
                    .set("loss", e.train_loss)
                    .set("val", e.val)
                    .set("test", e.test),
            );
        }
        Json::obj()
            .set("dataset", dataset.as_str())
            .set("parts", parts)
            .set("method", r.variant.as_str())
            .set("final_test", r.final_test)
            .set("best_val_test", r.best_val_test)
            .set("comm_bytes_epoch", r.comm_bytes_epoch)
            .set("sim_epoch_s", b.total)
            .set("curve", Json::Arr(curve))
            .write_file(path)?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_gen_graph(args: &Args) -> Result<()> {
    args.assert_known(&["dataset", "out", "nodes", "seed"])?;
    let dataset = args.get_str("dataset", "tiny");
    let out = args.get_str("out", "graph.bin");
    let seed = args.get_u64("seed", 1);
    let preset = presets::by_name(&dataset)
        .ok_or_else(|| pipegcn::err_msg!("unknown preset '{dataset}'"))?;
    let g = match args.get_opt("nodes") {
        Some(_) => preset.build_scaled(args.get_usize("nodes", preset.n), seed),
        None => preset.build(seed),
    };
    io::save(&g, &out)?;
    println!(
        "wrote {out}: {} nodes, {} edges, feat {}, {} classes ({})",
        g.n,
        g.num_edges(),
        g.feat_dim(),
        g.labels.n_classes(),
        if g.labels.is_multilabel() { "multi-label" } else { "single-label" }
    );
    Ok(())
}

fn cmd_partition(args: &Args) -> Result<()> {
    args.assert_known(&["dataset", "parts", "algo", "seed"])?;
    let dataset = args.get_str("dataset", "tiny");
    let parts = args.get_usize("parts", 2);
    let algo = args.get_str("algo", "multilevel");
    let seed = args.get_u64("seed", 1);
    let method = Method::parse(&algo).ok_or_else(|| pipegcn::err_msg!("bad --algo '{algo}'"))?;
    let preset = presets::by_name(&dataset)
        .ok_or_else(|| pipegcn::err_msg!("unknown preset '{dataset}'"))?;
    let g = preset.build(seed);
    let pt = partition(&g, parts, method, seed);
    let q = quality(&g, &pt);
    println!(
        "{dataset} × {parts} parts via {algo}: edge-cut {} | comm volume {} | replication {:.3} | balance {:.3}",
        q.edge_cut, q.comm_volume, q.replication_factor, q.balance
    );
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<()> {
    args.assert_known(&[
        "dataset", "parts", "method", "nodes-x-gpus", "epochs", "seed", "threads",
    ])?;
    apply_threads_flag(args)?;
    let dataset = args.get_str("dataset", "reddit-sim");
    let parts = args.get_usize("parts", 2);
    let method = args.get_str("method", "pipegcn");
    let opts = RunOpts {
        epochs: args.get_usize("epochs", 4),
        seed: args.get_u64("seed", 1),
        eval_every: 0,
        ..Default::default()
    };
    // validate before the (expensive) experiment runs, not after it
    let variant = Variant::parse(&method, 0.95)
        .ok_or_else(|| pipegcn::err_msg!("bad --method '{method}'"))?;
    if presets::by_name(&dataset).is_none() {
        pipegcn::bail!("unknown preset '{dataset}' (try `pipegcn presets` for the list)");
    }
    let out = exp::run(&dataset, parts, &method, opts);
    let mode = if variant.is_pipelined() { Mode::Pipelined } else { Mode::Vanilla };
    let breakdown = match args.get_opt("nodes-x-gpus") {
        Some(spec) => {
            let (nodes, per) = spec
                .split_once('x')
                .ok_or_else(|| pipegcn::err_msg!("--nodes-x-gpus expects AxB"))?;
            let (profile, topo) =
                pipegcn::sim::profiles::rig_mi60(nodes.parse()?, per.parse()?);
            exp::simulate(&out, &profile, &topo, mode)
        }
        None => exp::simulate_default(&out, mode),
    };
    println!(
        "{dataset} × {parts} [{}]: total {} | compute {} | comm {} (exposed {}) | reduce {} | comm ratio {:.1}%",
        variant.name(),
        fmt_secs(breakdown.total),
        fmt_secs(breakdown.compute),
        fmt_secs(breakdown.comm_total),
        fmt_secs(breakdown.comm_exposed),
        fmt_secs(breakdown.reduce),
        100.0 * breakdown.comm_ratio()
    );
    Ok(())
}

fn cmd_presets() -> Result<()> {
    println!(
        "{:<14} {:<16} {:>7} {:>6} {:>5} {:>7} {:>7} {:>7}",
        "preset", "mirrors", "nodes", "feat", "cls", "layers", "hidden", "epochs"
    );
    for p in &presets::PRESETS {
        println!(
            "{:<14} {:<16} {:>7} {:>6} {:>5} {:>7} {:>7} {:>7}",
            p.name, p.mirrors, p.n, p.feat_dim, p.n_classes, p.layers, p.hidden, p.epochs
        );
    }
    Ok(())
}
