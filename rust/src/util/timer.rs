//! Wall-clock timing helpers and a labeled accumulator used for the
//! epoch-time breakdowns (compute / communication / reduce).

use std::collections::BTreeMap;
use std::time::Instant;

/// Simple stopwatch over `Instant`.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Stopwatch {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> f64 {
        let e = self.elapsed_secs();
        self.start = Instant::now();
        e
    }
}

/// Accumulates named durations (seconds). Used for real-wall-clock
/// breakdowns; the *simulated* breakdowns live in `sim::`.
#[derive(Default, Clone, Debug)]
pub struct TimeBreakdown {
    buckets: BTreeMap<&'static str, f64>,
}

impl TimeBreakdown {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, bucket: &'static str, secs: f64) {
        *self.buckets.entry(bucket).or_insert(0.0) += secs;
    }

    /// Time `f` and charge it to `bucket`.
    pub fn timed<T>(&mut self, bucket: &'static str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.add(bucket, t.elapsed().as_secs_f64());
        out
    }

    pub fn get(&self, bucket: &str) -> f64 {
        self.buckets.get(bucket).copied().unwrap_or(0.0)
    }

    pub fn total(&self) -> f64 {
        self.buckets.values().sum()
    }

    pub fn buckets(&self) -> impl Iterator<Item = (&'static str, f64)> + '_ {
        self.buckets.iter().map(|(k, v)| (*k, *v))
    }

    pub fn merge(&mut self, other: &TimeBreakdown) {
        for (k, v) in other.buckets() {
            self.add(k, v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_accumulates() {
        let mut b = TimeBreakdown::new();
        b.add("compute", 1.0);
        b.add("compute", 0.5);
        b.add("comm", 2.0);
        assert!((b.get("compute") - 1.5).abs() < 1e-12);
        assert!((b.total() - 3.5).abs() < 1e-12);
    }

    #[test]
    fn timed_charges_bucket() {
        let mut b = TimeBreakdown::new();
        let v = b.timed("compute", || 21 * 2);
        assert_eq!(v, 42);
        assert!(b.get("compute") >= 0.0);
    }

    #[test]
    fn merge_sums() {
        let mut a = TimeBreakdown::new();
        a.add("x", 1.0);
        let mut b = TimeBreakdown::new();
        b.add("x", 2.0);
        b.add("y", 3.0);
        a.merge(&b);
        assert!((a.get("x") - 3.0).abs() < 1e-12);
        assert!((a.get("y") - 3.0).abs() < 1e-12);
    }

    #[test]
    fn stopwatch_monotonic() {
        let s = Stopwatch::start();
        let a = s.elapsed_secs();
        let b = s.elapsed_secs();
        assert!(b >= a);
    }
}
