//! Deterministic PRNG: xoshiro256** seeded via splitmix64.
//!
//! Every stochastic component in the repo (graph generation, partition
//! seeding, dropout, weight init, property tests) takes an explicit
//! [`Rng`] so runs are reproducible from a single `u64` seed.

/// splitmix64 step — used for seeding and cheap stateless hashing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller normal
    spare_normal: Option<f32>,
}

impl Rng {
    /// Seed deterministically from a single u64.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream (for per-partition / per-worker RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        let mut sm = self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        // 24 high bits -> [0,1) with full float precision
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) — Lemire's multiply-shift rejection method.
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n {
                return (m >> 64) as usize;
            }
            // reject only in the tiny biased zone
            if lo >= n.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f32) -> bool {
        self.next_f32() < p
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f32 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        loop {
            let u1 = self.next_f32();
            if u1 <= f32::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f32();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f32::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // For small k relative to n use a set-based approach; else shuffle.
        if k * 4 < n {
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let v = self.gen_range(n);
                if seen.insert(v) {
                    out.push(v);
                }
            }
            out
        } else {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut hit = [false; 10];
        for _ in 0..1000 {
            let v = r.gen_range(10);
            assert!(v < 10);
            hit[v] = true;
        }
        assert!(hit.iter().all(|&h| h));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
        let var =
            xs.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        for &(n, k) in &[(100, 5), (100, 90), (10, 10)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&x| x < n));
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(1);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
