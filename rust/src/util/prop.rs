//! A small property-based testing harness (no `proptest` on this image).
//!
//! [`check`] runs a property closure against many deterministic seeds and
//! reports the first failing seed so a failure is reproducible with
//! `check_seed`. Used across the crate for partitioner, collective, and
//! coordinator invariants.

use super::rng::Rng;

/// Result of a single property evaluation.
pub type PropResult = Result<(), String>;

/// Run `prop` for `iters` seeds (0..iters); panic with the failing seed
/// and message on first failure.
pub fn check(name: &str, iters: u64, prop: impl Fn(&mut Rng) -> PropResult) {
    for seed in 0..iters {
        let mut rng = Rng::new(0xC0FFEE ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at seed {seed}: {msg}");
        }
    }
}

/// Re-run a single seed (for debugging a reported failure).
pub fn check_seed(name: &str, seed: u64, prop: impl Fn(&mut Rng) -> PropResult) {
    let mut rng = Rng::new(0xC0FFEE ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
    if let Err(msg) = prop(&mut rng) {
        panic!("property '{name}' failed at seed {seed}: {msg}");
    }
}

/// Assert helper: `prop_assert!(cond, "format", args...)`.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err(format!($($arg)+));
        }
    };
}

/// Assert two f32 slices are close within `tol` (absolute + relative).
pub fn assert_close(a: &[f32], b: &[f32], tol: f32) -> PropResult {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let scale = 1.0f32.max(x.abs()).max(y.abs());
        if (x - y).abs() > tol * scale {
            return Err(format!(
                "elem {i}: {x} vs {y} (|diff|={} > tol*scale={})",
                (x - y).abs(),
                tol * scale
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_seeds() {
        let mut count = std::cell::Cell::new(0u64);
        let c = &mut count;
        check("trivial", 16, |_| {
            c.set(c.get() + 1);
            Ok(())
        });
        assert_eq!(count.get(), 16);
    }

    #[test]
    #[should_panic(expected = "failed at seed")]
    fn failing_property_reports_seed() {
        check("always-fails", 4, |_| Err("boom".into()));
    }

    #[test]
    fn close_slices_pass() {
        assert!(assert_close(&[1.0, 2.0], &[1.0 + 1e-6, 2.0], 1e-4).is_ok());
    }

    #[test]
    fn distant_slices_fail() {
        assert!(assert_close(&[1.0], &[1.1], 1e-4).is_err());
    }

    #[test]
    fn length_mismatch_fails() {
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-4).is_err());
    }
}
