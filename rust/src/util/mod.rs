//! Zero-dependency substrates: PRNG, JSON emission, CLI parsing, timing,
//! error handling, and a small property-based testing harness.
//!
//! The crate carries **no external dependencies** so it builds offline on
//! any image with a Rust toolchain: the usual crates (`rand`, `serde`,
//! `clap`, `criterion`, `proptest`, `anyhow`) are reimplemented here at
//! the scale this project needs. The PJRT `xla` crate is optional and
//! feature-gated (see `runtime::xla`).

pub mod rng;
pub mod json;
pub mod cli;
pub mod timer;
pub mod prop;
pub mod error;
pub mod sha256;

/// Format a byte count human-readably (e.g. `1.50 GiB`).
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", b, UNITS[0])
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// Format seconds with adaptive precision (`1.23 s`, `45.6 ms`, `789 µs`).
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{:.3} s", s)
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(fmt_secs(1.5), "1.500 s");
        assert_eq!(fmt_secs(0.0123), "12.300 ms");
        assert!(fmt_secs(1e-5).ends_with("µs"));
    }
}
