//! Minimal error type (no `anyhow` on this image).
//!
//! A string-message error with the three affordances the crate actually
//! uses: `bail!`-style early returns, `.context(...)` wrapping, and `?`
//! conversions from the std error types that appear at the I/O and
//! parsing boundaries. Kept deliberately tiny so the crate stays
//! dependency-free and builds offline.

use std::fmt;

/// Crate-wide error: a message, optionally built from a chain of
/// contexts (`outer: inner`).
pub struct Error {
    msg: String,
}

/// Crate-wide result alias (the `anyhow::Result` role).
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    pub fn msg(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    // `fn main() -> Result<()>` prints errors via Debug; show the plain
    // message there too.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl From<String> for Error {
    fn from(msg: String) -> Error {
        Error { msg }
    }
}

impl From<&str> for Error {
    fn from(msg: &str) -> Error {
        Error::msg(msg)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e.to_string())
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Error {
        Error::msg(e.to_string())
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Error {
        Error::msg(e.to_string())
    }
}

/// `anyhow::Context` stand-in: attach a message to any displayable error.
pub trait Context<T> {
    fn context(self, msg: impl Into<String>) -> Result<T>;
    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl Into<String>) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", msg.into())))
    }

    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl Into<String>) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg.into()))
    }

    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Early-return with a formatted [`Error`] (the `anyhow::bail!` role).
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($t)*)))
    };
}

/// Build a formatted [`Error`] value (the `anyhow::anyhow!` role).
#[macro_export]
macro_rules! err_msg {
    ($($t:tt)*) => {
        $crate::util::error::Error::msg(format!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("bad {}", 42)
    }

    #[test]
    fn bail_formats() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "bad 42");
        // alternate formatting (used by callers as `{err:#}`) still shows
        // the message
        assert_eq!(format!("{e:#}"), "bad 42");
    }

    #[test]
    fn context_wraps_display_errors() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        let e = r.context("reading manifest.json").unwrap_err();
        assert!(e.to_string().starts_with("reading manifest.json: "));
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert_eq!(v.context("missing n_pad").unwrap_err().to_string(), "missing n_pad");
        assert_eq!(Some(7u32).context("x").unwrap(), 7);
    }

    #[test]
    fn question_mark_conversions() {
        fn io() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(io().is_err());
        fn parse() -> Result<usize> {
            Ok("12".parse::<usize>()?)
        }
        assert_eq!(parse().unwrap(), 12);
    }
}
