//! Minimal ordered JSON value + writer (no serde on this image).
//!
//! Only what the result emitters need: construction helpers, escaping,
//! compact and pretty printing, and a streaming NDJSON [`Emitter`] for
//! run logs. Object keys preserve insertion order so emitted result
//! files diff cleanly across runs.

use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert (or replace) a key in an object; panics on non-objects.
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(pairs) => {
                let val = val.into();
                if let Some(p) = pairs.iter_mut().find(|(k, _)| k == key) {
                    p.1 = val;
                } else {
                    pairs.push((key.to_string(), val));
                }
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    fn write_escaped(s: &str, out: &mut String) {
        out.push('"');
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\r' => out.push_str("\\r"),
                '\t' => out.push_str("\\t"),
                c if (c as u32) < 0x20 => {
                    let _ = write!(out, "\\u{:04x}", c as u32);
                }
                c => out.push(c),
            }
        }
        out.push('"');
    }

    fn write_num(n: f64, out: &mut String) {
        if n.is_finite() {
            if n == n.trunc() && n.abs() < 1e15 {
                let _ = write!(out, "{}", n as i64);
            } else {
                let _ = write!(out, "{}", n);
            }
        } else {
            out.push_str("null"); // JSON has no NaN/Inf
        }
    }

    fn render(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => Self::write_num(*n, out),
            Json::Str(s) => Self::write_escaped(s, out),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    it.render(out, indent + 1, pretty);
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                if pairs.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    Self::write_escaped(k, out);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.render(out, indent + 1, pretty);
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push('}');
            }
        }
    }

    pub fn to_compact(&self) -> String {
        let mut s = String::new();
        self.render(&mut s, 0, false);
        s
    }

    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.render(&mut s, 0, true);
        s
    }

    /// Write pretty JSON to a file, creating parent dirs.
    pub fn write_file(&self, path: &str) -> std::io::Result<()> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_pretty() + "\n")
    }
}

// ---------------------------------------------------------------------
// Parsing (for artifact manifests and saved results)
// ---------------------------------------------------------------------

struct Parser<'a> {
    s: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> String {
        format!("json parse error at byte {}: {}", self.pos, msg)
    }

    fn skip_ws(&mut self) {
        while self.pos < self.s.len() && self.s[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn parse_value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.parse_obj(),
            Some(b'[') => self.parse_arr(),
            Some(b'"') => Ok(Json::Str(self.parse_string()?)),
            Some(b't') => self.parse_lit("true", Json::Bool(true)),
            Some(b'f') => self.parse_lit("false", Json::Bool(false)),
            Some(b'n') => self.parse_lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.parse_num(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn parse_lit(&mut self, lit: &str, v: Json) -> Result<Json, String> {
        if self.s[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn parse_num(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.s[start..self.pos])
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn parse_string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.s.len() {
                                return Err(self.err("bad \\u"));
                            }
                            let hex = std::str::from_utf8(&self.s[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                c => {
                    // collect the full UTF-8 sequence
                    let len = match c {
                        0x00..=0x7F => 0,
                        0xC0..=0xDF => 1,
                        0xE0..=0xEF => 2,
                        _ => 3,
                    };
                    let start = self.pos - 1;
                    self.pos += len;
                    let chunk = std::str::from_utf8(&self.s[start..self.pos])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(chunk);
                }
            }
        }
    }

    fn parse_arr(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.parse_value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn parse_obj(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.parse_string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.parse_value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { s: text.as_bytes(), pos: 0 };
        let v = p.parse_value()?;
        p.skip_ws();
        if p.pos != p.s.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
}

impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<f32> for Json {
    fn from(v: f32) -> Json {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u32> for Json {
    fn from(v: u32) -> Json {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}
impl From<&[f32]> for Json {
    fn from(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}
impl From<&[f64]> for Json {
    fn from(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }
}

// ---------------------------------------------------------------------
// Streaming NDJSON emission (run logs)
// ---------------------------------------------------------------------

/// Milliseconds since the Unix epoch, anchored once per process: the
/// wall clock is read a single time and subsequent calls advance it by
/// a monotonic `Instant`, so `ts_ms` values within one process never go
/// backwards even if the system clock steps mid-run.
pub fn now_ms() -> f64 {
    use std::time::{Instant, SystemTime, UNIX_EPOCH};
    static ANCHOR: std::sync::Mutex<Option<(f64, Instant)>> = std::sync::Mutex::new(None);
    let mut g = ANCHOR.lock().unwrap();
    let (epoch_ms, base) = *g.get_or_insert_with(|| {
        let wall = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_secs_f64() * 1000.0)
            .unwrap_or(0.0);
        (wall, Instant::now())
    });
    epoch_ms + base.elapsed().as_secs_f64() * 1000.0
}

/// Streaming newline-delimited-JSON writer for run logs: one compact
/// object per line, flushed after every row so `tail -f` (or a crashed
/// run's partial log) always shows complete records.
///
/// An optional header row (run metadata) is written lazily before the
/// first data row — the `started` flag — so a run that dies before its
/// first epoch leaves an empty file rather than a headers-only one.
///
/// File-backed emitters stamp every object row with a wall-clock
/// `ts_ms` field ([`now_ms`]) so streamed logs from different ranks and
/// runs can be correlated; in-memory emitters (tests, capture buffers)
/// stay byte-stable unless [`Emitter::stamp_ts`] is opted into.
pub struct Emitter<W: std::io::Write> {
    out: W,
    header: Option<Json>,
    started: bool,
    rows: usize,
    stamp_ts: bool,
}

impl<W: std::io::Write> Emitter<W> {
    pub fn new(out: W) -> Emitter<W> {
        Emitter { out, header: None, started: false, rows: 0, stamp_ts: false }
    }

    /// Set a metadata row to emit as the first line (lazily, before the
    /// first [`Emitter::emit`]).
    pub fn with_header(out: W, header: Json) -> Emitter<W> {
        Emitter { out, header: Some(header), started: false, rows: 0, stamp_ts: false }
    }

    /// Stamp each emitted object row (header included) with `ts_ms` —
    /// wall-clock milliseconds from [`now_ms`] — unless the row already
    /// carries one. On by default for [`FileEmitter`]s.
    pub fn stamp_ts(mut self, on: bool) -> Emitter<W> {
        self.stamp_ts = on;
        self
    }

    fn stamped(&self, row: &Json) -> Option<Json> {
        if !self.stamp_ts {
            return None;
        }
        match row {
            Json::Obj(_) if row.get("ts_ms").is_none() => {
                Some(row.clone().set("ts_ms", now_ms()))
            }
            _ => None,
        }
    }

    /// Append one row (compact, newline-terminated) and flush.
    pub fn emit(&mut self, row: &Json) -> std::io::Result<()> {
        if !self.started {
            self.started = true;
            if let Some(h) = self.header.take() {
                let h = self.stamped(&h).unwrap_or(h);
                self.out.write_all(h.to_compact().as_bytes())?;
                self.out.write_all(b"\n")?;
            }
        }
        let line = match self.stamped(row) {
            Some(s) => s.to_compact(),
            None => row.to_compact(),
        };
        self.out.write_all(line.as_bytes())?;
        self.out.write_all(b"\n")?;
        self.rows += 1;
        self.out.flush()
    }

    /// Data rows emitted so far (header excluded).
    pub fn rows(&self) -> usize {
        self.rows
    }
}

/// The common file-backed emitter (`--log <path>`).
pub type FileEmitter = Emitter<std::io::BufWriter<std::fs::File>>;

impl FileEmitter {
    /// Create (truncate) `path` — parent dirs included — for streaming.
    pub fn create(path: &str, header: Json) -> std::io::Result<FileEmitter> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let f = std::fs::File::create(path)?;
        Ok(Emitter::with_header(std::io::BufWriter::new(f), header).stamp_ts(true))
    }

    /// Continue an existing log: append without re-emitting a header, or
    /// fall back to [`FileEmitter::create`] (header included) when the
    /// file is missing or empty. Used when a resumed run extends the
    /// original run's log — readers should keep the *last* row per epoch
    /// if a crash re-ran a partially-logged epoch.
    pub fn append_or_create(path: &str, header: Json) -> std::io::Result<FileEmitter> {
        use std::io::{Read, Seek, SeekFrom, Write};
        let has_rows = std::fs::metadata(path).map(|m| m.len() > 0).unwrap_or(false);
        if !has_rows {
            return FileEmitter::create(path, header);
        }
        let mut f = std::fs::OpenOptions::new().read(true).append(true).open(path)?;
        // a crash can tear the final line (flushed mid-row, no newline);
        // terminate it so the torn fragment stays on its own line
        // instead of merging with the first resumed row
        f.seek(SeekFrom::End(-1))?;
        let mut last = [0u8; 1];
        f.read_exact(&mut last)?;
        if last[0] != b'\n' {
            f.write_all(b"\n")?;
        }
        Ok(Emitter::new(std::io::BufWriter::new(f)).stamp_ts(true))
    }
}

/// Parse an NDJSON string back into rows (tests / result readers).
pub fn parse_ndjson(text: &str) -> Result<Vec<Json>, String> {
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(Json::parse)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compact_rendering() {
        let j = Json::obj()
            .set("name", "pipe\"gcn")
            .set("n", 3usize)
            .set("ok", true)
            .set("xs", vec![1.5f64, 2.0]);
        assert_eq!(
            j.to_compact(),
            r#"{"name":"pipe\"gcn","n":3,"ok":true,"xs":[1.5,2]}"#
        );
    }

    #[test]
    fn integers_render_without_decimal() {
        assert_eq!(Json::Num(42.0).to_compact(), "42");
        assert_eq!(Json::Num(0.25).to_compact(), "0.25");
    }

    #[test]
    fn nan_renders_null() {
        assert_eq!(Json::Num(f64::NAN).to_compact(), "null");
    }

    #[test]
    fn set_replaces_existing_key() {
        let j = Json::obj().set("a", 1usize).set("a", 2usize);
        assert_eq!(j.get("a").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn pretty_roundtrip_contains_newlines() {
        let j = Json::obj().set("a", vec![1usize, 2]);
        let p = j.to_pretty();
        assert!(p.contains('\n'));
        assert!(p.starts_with('{') && p.ends_with('}'));
    }

    #[test]
    fn control_chars_escaped() {
        let j = Json::Str("a\u{1}b".into());
        assert_eq!(j.to_compact(), "\"a\\u0001b\"");
    }

    #[test]
    fn parse_roundtrip_compact() {
        let j = Json::obj()
            .set("name", "pipe\"gcn")
            .set("n", 3usize)
            .set("neg", -1.5f64)
            .set("ok", true)
            .set("none", Json::Null)
            .set("xs", vec![1.5f64, 2.0])
            .set("nested", Json::obj().set("a", vec![Json::Bool(false)]));
        let parsed = Json::parse(&j.to_compact()).unwrap();
        assert_eq!(parsed, j);
        let parsed_pretty = Json::parse(&j.to_pretty()).unwrap();
        assert_eq!(parsed_pretty, j);
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let j = Json::parse(r#"{"s": "a\nb\t\"c\" A é"}"#).unwrap();
        assert_eq!(j.get("s").unwrap().as_str().unwrap(), "a\nb\t\"c\" A é");
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{'a':1}").is_err());
    }

    #[test]
    fn emitter_streams_ndjson_with_lazy_header() {
        let mut e = Emitter::with_header(Vec::new(), Json::obj().set("run", "t1"));
        // nothing written until the first row
        assert!(e.out.is_empty());
        e.emit(&Json::obj().set("epoch", 1usize).set("loss", 0.5f64)).unwrap();
        e.emit(&Json::obj().set("epoch", 2usize).set("loss", 0.25f64)).unwrap();
        assert_eq!(e.rows(), 2);
        let text = String::from_utf8(e.out).unwrap();
        assert_eq!(text.lines().count(), 3);
        let rows = parse_ndjson(&text).unwrap();
        assert_eq!(rows[0].get("run").unwrap().as_str(), Some("t1"));
        assert_eq!(rows[2].get("epoch").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn emitter_without_header() {
        let mut e = Emitter::new(Vec::new());
        e.emit(&Json::obj().set("x", 1usize)).unwrap();
        let text = String::from_utf8(e.out).unwrap();
        assert_eq!(text, "{\"x\":1}\n");
    }

    #[test]
    fn f64_roundtrips_exactly_through_ndjson() {
        // run logs are compared bit-for-bit across engines; Rust's f64
        // Display is shortest-roundtrip so parse(print(x)) == x exactly
        let xs = [0.1f64, 1.0 / 3.0, 2.517382910473e-5, 123456.789012345];
        for &x in &xs {
            let mut e = Emitter::new(Vec::new());
            e.emit(&Json::obj().set("v", x)).unwrap();
            let text = String::from_utf8(e.out).unwrap();
            let back = parse_ndjson(&text).unwrap()[0].get("v").unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "{x}");
        }
    }

    #[test]
    fn append_or_create_extends_without_duplicate_header() {
        let path = format!("/tmp/pipegcn_json_append_{}.ndjson", std::process::id());
        let _ = std::fs::remove_file(&path);
        let header = || Json::obj().set("run", "t");
        // missing file: behaves like create (header + row)
        let mut e = FileEmitter::append_or_create(&path, header()).unwrap();
        e.emit(&Json::obj().set("epoch", 1usize)).unwrap();
        drop(e);
        // existing file: appends rows only
        let mut e = FileEmitter::append_or_create(&path, header()).unwrap();
        e.emit(&Json::obj().set("epoch", 2usize)).unwrap();
        drop(e);
        let rows = parse_ndjson(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(rows.len(), 3); // one header, two rows
        assert_eq!(rows[0].get("run").unwrap().as_str(), Some("t"));
        assert_eq!(rows[2].get("epoch").unwrap().as_usize(), Some(2));
        // a torn final line (crash mid-row, no trailing newline) is
        // terminated first, so the fragment stays on its own line
        std::fs::write(&path, b"{\"run\":\"t\"}\n{\"epoch\":9,\"lo").unwrap();
        let mut e = FileEmitter::append_or_create(&path, header()).unwrap();
        e.emit(&Json::obj().set("epoch", 10usize)).unwrap();
        drop(e);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.trim_end().lines().collect();
        assert_eq!(lines.len(), 3, "{text}");
        assert!(Json::parse(lines[1]).is_err(), "torn fragment kept isolated");
        assert_eq!(Json::parse(lines[2]).unwrap().get("epoch").unwrap().as_usize(), Some(10));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn now_ms_is_monotonic_and_plausible() {
        let a = now_ms();
        let b = now_ms();
        assert!(b >= a);
        // after 2020-01-01 and before 2100-01-01 (anchored wall clock)
        assert!(a > 1.577e12, "{a}");
        assert!(a < 4.102e12, "{a}");
    }

    #[test]
    fn stamp_ts_adds_wall_clock_to_rows() {
        let mut e = Emitter::with_header(Vec::new(), Json::obj().set("run", "t")).stamp_ts(true);
        e.emit(&Json::obj().set("epoch", 1usize)).unwrap();
        // a row that already carries ts_ms is left untouched
        e.emit(&Json::obj().set("epoch", 2usize).set("ts_ms", 7.0f64)).unwrap();
        let rows = parse_ndjson(&String::from_utf8(e.out).unwrap()).unwrap();
        assert!(rows[0].get("ts_ms").unwrap().as_f64().unwrap() > 1.577e12);
        assert!(rows[1].get("ts_ms").unwrap().as_f64().unwrap() > 1.577e12);
        assert_eq!(rows[2].get("ts_ms").unwrap().as_f64(), Some(7.0));
        // default emitters stay byte-stable (no stamping)
        let mut plain = Emitter::new(Vec::new());
        plain.emit(&Json::obj().set("x", 1usize)).unwrap();
        assert_eq!(String::from_utf8(plain.out).unwrap(), "{\"x\":1}\n");
    }

    #[test]
    fn file_emitter_stamps_ts_ms() {
        let path = format!("/tmp/pipegcn_json_ts_{}.ndjson", std::process::id());
        let _ = std::fs::remove_file(&path);
        let mut e = FileEmitter::create(&path, Json::obj().set("run", "t")).unwrap();
        e.emit(&Json::obj().set("epoch", 1usize)).unwrap();
        drop(e);
        let rows = parse_ndjson(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert!(rows.iter().all(|r| r.get("ts_ms").is_some()), "{rows:?}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn parse_numbers() {
        let j = Json::parse("[0, -2, 3.5, 1e3, -1.2E-2]").unwrap();
        let xs = j.as_arr().unwrap();
        assert_eq!(xs[0].as_f64(), Some(0.0));
        assert_eq!(xs[1].as_f64(), Some(-2.0));
        assert_eq!(xs[2].as_f64(), Some(3.5));
        assert_eq!(xs[3].as_f64(), Some(1000.0));
        assert_eq!(xs[4].as_f64(), Some(-0.012));
    }
}
