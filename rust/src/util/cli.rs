//! Tiny CLI argument parser (no `clap` on this image).
//!
//! Grammar: `pipegcn <subcommand> [--flag value] [--flag=value] [--switch]`.
//! Typed getters with defaults; unknown-flag detection is left to callers
//! via [`Args::assert_known`].

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: String,
    pub positionals: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    args.flags
                        .insert(stripped[..eq].to_string(), stripped[eq + 1..].to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let val = it.next().unwrap();
                    args.flags.insert(stripped.to_string(), val);
                } else {
                    args.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else if args.subcommand.is_empty() {
                args.subcommand = tok;
            } else {
                args.positionals.push(tok);
            }
        }
        args
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn get_opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.flags
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.flags
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn get_f32(&self, key: &str, default: f32) -> f32 {
        self.flags
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a float, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.flags
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a float, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        self.flags
            .get(key)
            .map(|v| matches!(v.as_str(), "true" | "1" | "yes" | "on"))
            .unwrap_or(default)
    }

    /// Parse a comma-separated list of usizes, e.g. `--parts 2,4,8`.
    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.flags.get(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim()
                        .parse()
                        .unwrap_or_else(|_| panic!("--{key} expects ints, got '{v}'"))
                })
                .collect(),
        }
    }

    /// Parse a comma-separated list of f32, e.g. `--gammas 0,0.5,0.95`.
    pub fn get_f32_list(&self, key: &str, default: &[f32]) -> Vec<f32> {
        match self.flags.get(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim()
                        .parse()
                        .unwrap_or_else(|_| panic!("--{key} expects floats, got '{v}'"))
                })
                .collect(),
        }
    }

    /// Error out (with a list) if any flag is not in `known`.
    pub fn assert_known(&self, known: &[&str]) -> crate::util::error::Result<()> {
        let bad: Vec<&String> = self
            .flags
            .keys()
            .filter(|k| !known.contains(&k.as_str()))
            .collect();
        if bad.is_empty() {
            Ok(())
        } else {
            crate::bail!("unknown flags: {:?} (known: {:?})", bad, known)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("train --dataset reddit-sim --parts 4 --pipeline");
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.get_str("dataset", ""), "reddit-sim");
        assert_eq!(a.get_usize("parts", 0), 4);
        assert!(a.get_bool("pipeline", false));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("train --lr=0.01 --gamma=0.95");
        assert!((a.get_f32("lr", 0.0) - 0.01).abs() < 1e-9);
        assert!((a.get_f32("gamma", 0.0) - 0.95).abs() < 1e-9);
    }

    #[test]
    fn lists() {
        let a = parse("bench --parts 2,4,8 --gammas 0,0.5");
        assert_eq!(a.get_usize_list("parts", &[]), vec![2, 4, 8]);
        assert_eq!(a.get_f32_list("gammas", &[]), vec![0.0, 0.5]);
    }

    #[test]
    fn defaults() {
        let a = parse("train");
        assert_eq!(a.get_usize("epochs", 100), 100);
        assert_eq!(a.get_str("mode", "vanilla"), "vanilla");
        assert!(!a.get_bool("pipeline", false));
    }

    #[test]
    fn positionals() {
        let a = parse("partition graph.bin out.bin --parts 4");
        assert_eq!(a.positionals, vec!["graph.bin", "out.bin"]);
    }

    #[test]
    fn unknown_flags_detected() {
        let a = parse("train --typo 1");
        assert!(a.assert_known(&["dataset"]).is_err());
        assert!(a.assert_known(&["typo"]).is_ok());
    }

    #[test]
    fn trailing_switch() {
        let a = parse("train --verbose");
        assert!(a.get_bool("verbose", false));
    }
}
