//! # PipeGCN — partition-parallel full-graph GCN training with pipelined
//! # boundary feature/feature-gradient communication
//!
//! Reproduction of *PipeGCN: Efficient Full-Graph Training of Graph
//! Convolutional Networks with Pipelined Feature Communication* (ICLR 2022).
//!
//! The crate is organized bottom-up:
//!
//! * [`util`] — zero-dependency substrates: PRNG, JSON writer, CLI parser,
//!   timers, a property-test harness.
//! * [`tensor`] — dense matrices with cache-blocked GEMM, CSR sparse
//!   matrices with SpMM, activations and loss heads. The hot-path
//!   kernels run row-blocked on the [`runtime::pool`] worker threads:
//!   every output row has a single owner task with the serial summation
//!   order, so results are bit-identical at any `--threads` count.
//! * [`graph`] — CSR graphs, synthetic generators (SBM / Barabási–Albert /
//!   Erdős–Rényi / grid), feature synthesis, GCN normalization, binary IO,
//!   and dataset presets mirroring the paper's four datasets.
//! * [`partition`] — a METIS-like multilevel partitioner (heavy-edge
//!   matching, greedy initial partition, FM refinement with a
//!   communication-volume objective) plus hash/range/BFS baselines.
//! * [`comm`] — the communication layer: the **nonblocking,
//!   handle-based** [`comm::Transport`] contract (`post_recv` returns a
//!   [`comm::RecvHandle`]; `try_take`/`wait` claim the payload, with
//!   park time attributed per (layer, phase) in a [`comm::WaitStats`]),
//!   the in-process mailbox fabric with reservation queues and byte
//!   accounting, a ring all-reduce, and link/topology descriptions.
//!   `recv_blocking` survives as a default-method shim for control
//!   paths. [`comm::schedule`] is the declarative IR of the per-rank
//!   communication schedule: every executor consumes generated
//!   `Event` windows instead of re-deriving tags inline, and
//!   `schedule::verify` statically checks matching, aliasing,
//!   deadlock-freedom, staleness bounds, and handle hygiene
//!   (`pipegcn check`; `PIPEGCN_CONFORMANCE=1` cross-checks the live
//!   transport against the IR in debug builds).
//! * [`ckpt`] — crash-safe checkpoint/restore: versioned, CRC-checked
//!   binary snapshots of full training state (epoch, parameters, Adam
//!   moments, PipeGCN stale buffers), one file per rank per epoch, with
//!   atomic writes and latest-complete-checkpoint discovery. A resumed
//!   run reproduces the uninterrupted run bit-for-bit
//!   (`--ckpt-dir` / `--ckpt-every` / `--resume`).
//!   [`model::artifact`] distills a checkpoint into a standalone
//!   serving artifact (`ModelConfig` + weights only,
//!   `pipegcn export-params`).
//! * [`net`] — the real transport: length-prefixed binary frames over
//!   TCP ([`net::TcpTransport`], whose reader-demux threads fulfill
//!   posted receive handles straight off the socket), a rank-0
//!   rendezvous/peer-table bootstrap with routable-address validation
//!   (`--bind`, `--connect-timeout`/`--connect-retries`), and the
//!   `launch`/`worker` multi-process runtime that trains over genuine
//!   sockets — `launch` supervises its workers and relaunches the mesh
//!   from the latest complete checkpoint when one dies.
//! * [`sim`] — the discrete-event timeline simulator that models what the
//!   training schedule costs on a described cluster (the paper's testbeds
//!   are encoded as [`sim::DeviceProfile`]s / [`sim::Topology`]s).
//! * [`model`] — GraphSAGE / GCN layer definitions, parameter init, Adam.
//! * [`runtime`] — the [`runtime::Backend`] trait with a pure-Rust `native`
//!   implementation and an `xla` implementation that loads the AOT HLO-text
//!   artifacts produced by `python/compile/aot.py` and runs them on PJRT;
//!   plus [`runtime::pool`], the persistent std-only worker-thread pool
//!   behind every parallel kernel (`--threads` / `PIPEGCN_THREADS`).
//! * [`perf`] — the `pipegcn bench` harness: kernel + end-to-end epoch
//!   throughput at a thread-count sweep, streamed to NDJSON
//!   (`BENCH_kernels.json`).
//! * [`coordinator`] — the paper's contribution: vanilla partition-parallel
//!   training and PipeGCN (Algorithm 1) with staleness smoothing (§3.4),
//!   metric/error probes, and epoch time breakdowns. The per-rank
//!   schedule is **prefetched**: every receive of an epoch is posted up
//!   front and waited at its point of use, so the pipelined variants'
//!   fresh-tensor waits sink behind the whole epoch's compute; rank 0
//!   streams a per-(layer, phase) `comm_wait` breakdown and an
//!   `overlap_ratio` in its run-log rows.
//! * [`session`] — **the crate's front door**: the [`session::Session`]
//!   builder collapses every run configuration (dataset, variant,
//!   threads, run log, checkpoints, fault injection) behind one `run()`
//!   returning a unified [`session::RunReport`], with the execution
//!   strategy picked by [`session::Engine`]
//!   (`Sequential | Threaded | Tcp | TcpWorker`). The nine pre-Session
//!   entry points (`exp::run*`/`trainer::train*`/`train_threaded`) have
//!   been deleted; only the engine cores remain underneath.
//! * [`obs`] — observability: a lock-light metrics registry (counters /
//!   gauges / log-bucketed histograms) behind a live Prometheus-text
//!   endpoint (`--metrics-addr`), and a cross-rank span tracer whose
//!   merged Chrome trace-event JSON (`--trace`) makes the per-layer
//!   comm/compute overlap visible — clock offsets are estimated against
//!   rank 0 and worker buffers ship home over the frame protocol at
//!   shutdown. Observation-only: loss curves stay bit-identical with
//!   instrumentation on or off.
//! * [`serve`] — the online workload: `pipegcn serve` loads a params
//!   artifact, binds the `net::frame` protocol, and answers
//!   feature→logit queries bit-identical to
//!   [`coordinator::full_graph_forward`]; `pipegcn query` is the
//!   client (batched latency/QPS reporting, plus closed-/open-loop
//!   load generation). [`serve::tier`] is the production front over
//!   that path: request coalescing under a latency budget
//!   (`--batch-window-ms`/`--max-batch`), per-layer activation caching
//!   keyed by `(artifact_version, graph_version)` with exact cone
//!   invalidation on feature overrides, and `pipegcn route` — N
//!   health-checked replicas behind one address with least-loaded
//!   dispatch, automatic failover, and rolling artifact reload
//!   (`pipegcn ctl --reload`). All of it bit-transparent, and every
//!   v2 response stamped with the serving artifact's version.
//! * [`baselines`] — ROC-like and CAGNET-like communication cost models.
//!
//! See `DESIGN.md` for the experiment index and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod util;
pub mod tensor;
pub mod graph;
pub mod partition;
pub mod comm;
pub mod ckpt;
pub mod net;
pub mod sim;
pub mod model;
pub mod runtime;
pub mod coordinator;
pub mod baselines;
pub mod exp;
pub mod session;
pub mod obs;
pub mod serve;
pub mod perf;
