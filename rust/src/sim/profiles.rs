//! Calibrated device/topology presets for the paper's two testbeds.
//!
//! Calibration target is the paper's **Table 6** epoch-time breakdown on
//! Reddit (4-layer GraphSAGE, 256 hidden):
//!
//! | method           | total | compute | comm  |
//! |------------------|-------|---------|-------|
//! | GCN (2 GPUs)     | 0.52s | 0.17s   | 0.34s |
//! | PipeGCN (2 GPUs) | 0.27s | 0.25s   | ~0s   |
//! | GCN (4 GPUs)     | 0.48s | 0.07s   | 0.40s |
//! | PipeGCN (4 GPUs) | 0.23s | 0.10s   | 0.10s |
//!
//! Notable structure in those rows that the model reproduces:
//! * vanilla comm (0.34 s) is ~2× the wire time of the same bytes —
//!   synchronous bursty transfers don't saturate the link and pay a
//!   barrier per layer (`vanilla_bw_derate`, `barrier_s`);
//! * PipeGCN's *compute* rises 0.17→0.25 s — overlapped DMA contends
//!   with kernels (`overlap_compute_derate ≈ 0.68`).
//!
//! Reddit full-scale per-partition FLOPs (2 parts, 233K nodes, 114M
//! directed edges, feat 602, hidden 256, 4 layers, bwd≈2×fwd):
//! SpMM ≈ 3.5e11 FLOP, GEMM ≈ 3.2e11 FLOP → ≈0.16 s at the rates below.
//! Boundary traffic ≈ 0.35 GB/epoch → wire ≈ 0.16 s at Gloo-PCIe
//! effective 2.2 GB/s; vanilla sees 0.16/0.5 + barriers ≈ 0.33 s.

use super::DeviceProfile;
use crate::comm::topology::{eth10g_link, pcie3_link, Link, Topology};

/// RTX-2080Ti effective rates under PyTorch+DGL kernels.
pub const RTX_2080TI: DeviceProfile = DeviceProfile {
    name: "rtx2080ti",
    spmm_flops: 3.2e12,
    gemm_flops: 7.0e12,
    layer_overhead_s: 120e-6,
    barrier_s: 300e-6,
    vanilla_bw_derate: 0.5,
    overlap_compute_derate: 0.68,
};

/// AMD MI60 effective rates (14.7 TFLOP/s fp32 peak, HBM2 1 TB/s).
pub const MI60: DeviceProfile = DeviceProfile {
    name: "mi60",
    spmm_flops: 3.6e12,
    gemm_flops: 7.6e12,
    layer_overhead_s: 150e-6,
    barrier_s: 500e-6,
    vanilla_bw_derate: 0.5,
    overlap_compute_derate: 0.7,
};

/// Gloo-over-PCIe effective point-to-point bandwidth: staging through
/// host memory roughly quarters the raw PCIe rate (paper App. F notes
/// the CPU-GPU + CPU-CPU relay).
pub fn gloo_pcie_link() -> Link {
    Link { latency_s: 60e-6, bytes_per_s: 2.2e9 }
}

/// Single-chassis testbed: n × RTX-2080Ti over PCIe3 (the paper's main
/// rig has 10).
pub fn rig_2080ti(n_gpus: usize) -> (DeviceProfile, Topology) {
    (RTX_2080TI, Topology::single_node(n_gpus, gloo_pcie_link()))
}

/// Multi-server testbed: `nodes` × `per_node` MI60s, PCIe intra, 10 GbE
/// inter (Appendix E).
pub fn rig_mi60(nodes: usize, per_node: usize) -> (DeviceProfile, Topology) {
    (MI60, Topology::multi_node(nodes, per_node, pcie3_link(), eth10g_link()))
}

/// Degrade a simulated link by the expected value of a chaos profile's
/// per-link faults — the analytic mirror of running `--chaos` on a real
/// mesh. Added latency is the injector's mean per-frame delay (fixed
/// latency + mean jitter + the expected geometric run of drop→RTO
/// cycles); a bandwidth cap clamps the link's byte rate.
pub fn apply_chaos(link: Link, chaos: &crate::net::chaos::LinkChaos) -> Link {
    let bytes_per_s = match chaos.bandwidth_bytes_per_s() {
        Some(cap) => link.bytes_per_s.min(cap),
        None => link.bytes_per_s,
    };
    Link { latency_s: link.latency_s + chaos.expected_extra_latency_s(), bytes_per_s }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::chaos::LinkChaos;
    use crate::sim::{epoch_time, LayerCompute, Mode, PartitionWork};

    #[test]
    fn apply_chaos_degrades_a_link_by_expectation() {
        let base = gloo_pcie_link();
        // no faults: the link is untouched
        let same = apply_chaos(base, &LinkChaos::default());
        assert_eq!(same.latency_s, base.latency_s);
        assert_eq!(same.bytes_per_s, base.bytes_per_s);
        // 20ms fixed + 5ms jitter (mean 2.5) + 1% drops at 50ms RTO,
        // capped at 100 mbit/s = 12.5 MB/s
        let c = LinkChaos {
            latency_ms: 20.0,
            jitter_ms: 5.0,
            drop: 0.01,
            bandwidth_mbps: 100.0,
            rto_ms: 50.0,
        };
        let hostile = apply_chaos(base, &c);
        let want_extra = (20.0 + 2.5 + 0.01 / 0.99 * 50.0) / 1e3;
        assert!((hostile.latency_s - base.latency_s - want_extra).abs() < 1e-12);
        assert_eq!(hostile.bytes_per_s, 12.5e6);
        // a cap looser than the link leaves its rate alone
        let loose = apply_chaos(base, &LinkChaos { bandwidth_mbps: 1e6, ..c });
        assert_eq!(loose.bytes_per_s, base.bytes_per_s);
    }

    /// Reconstruct the paper's Reddit/2-GPU Table 6 rows from first
    /// principles and check the calibration lands near them.
    #[test]
    fn table6_reddit_2gpu_calibration() {
        let (profile, topo) = rig_2080ti(2);
        let n: f64 = 233_000.0;
        let nnz_dir: f64 = 114_000_000.0; // directed edges (DGL reddit)
        let feats = [602.0, 256.0, 256.0, 256.0];
        let hidden = 256.0;
        // ~32% of each partition's nodes are boundary replicas at 2 parts
        let boundary_nodes = 0.32 * n / 2.0;
        let mut fwd = Vec::new();
        let mut bwd = Vec::new();
        let mut fwd_comm = Vec::new();
        let mut bwd_comm = Vec::new();
        for l in 0..4 {
            let f_in = feats[l];
            let rows = n / 2.0 * 1.32; // inner + halo rows
            let lc = LayerCompute {
                spmm_flops: 2.0 * (nnz_dir / 2.0) * hidden,
                gemm_flops: 2.0 * rows * f_in * hidden,
            };
            fwd.push(lc);
            bwd.push(LayerCompute {
                spmm_flops: 2.0 * lc.spmm_flops,
                gemm_flops: 2.0 * lc.gemm_flops,
            });
            let bytes = (boundary_nodes * f_in * 4.0) as u64;
            fwd_comm.push(vec![(1usize, bytes)]);
            let gbytes = (boundary_nodes * hidden * 4.0) as u64;
            bwd_comm.push(vec![(1usize, gbytes)]);
        }
        let w = PartitionWork { fwd, bwd, fwd_comm, bwd_comm };
        let works = vec![w.clone(), w];
        let model_elems = (602 * 256 + 3 * 256 * 256) * 2; // sage dual weights
        let v = epoch_time(&works, model_elems, &profile, &topo, Mode::Vanilla);
        let p = epoch_time(&works, model_elems, &profile, &topo, Mode::Pipelined);
        // Paper: vanilla total 0.52 (compute 0.17, comm 0.34);
        //        PipeGCN total 0.27 (compute 0.25).
        assert!(
            v.compute > 0.12 && v.compute < 0.22,
            "compute {:.3}s vs paper 0.17s",
            v.compute
        );
        assert!(
            v.comm_total > 0.25 && v.comm_total < 0.45,
            "comm {:.3}s vs paper 0.34s",
            v.comm_total
        );
        assert!(
            v.total > 0.40 && v.total < 0.65,
            "total {:.3}s vs paper 0.52s",
            v.total
        );
        assert!(
            p.total > 0.20 && p.total < 0.34,
            "pipe total {:.3}s vs paper 0.27s",
            p.total
        );
        let speedup = v.total / p.total;
        assert!(
            speedup > 1.55 && speedup < 2.4,
            "speedup {:.2} vs paper 1.93×",
            speedup
        );
    }
}
