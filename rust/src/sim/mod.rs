//! Discrete-event timeline simulator for distributed GCN training.
//!
//! The repo runs on a single CPU core, so training *numerics* execute
//! sequentially (bit-identical to a parallel run — the dataflow is
//! deterministic), while this module answers "what would this schedule
//! cost on the paper's testbed?". The coordinator records, per partition
//! and per layer, the exact FLOPs executed and the exact bytes exchanged
//! (from the [`crate::comm::Fabric`] counters); [`epoch_time`] lays those
//! onto per-partition compute/communication lanes:
//!
//! * **Vanilla** partition-parallel training interleaves lanes serially —
//!   each layer's boundary exchange blocks the next compute (Fig. 1(b)),
//!   paying a synchronization barrier per exchange and moving bursty,
//!   unpipelined transfers below wire saturation (`vanilla_bw_derate`).
//! * **PipeGCN** overlaps the lanes — an iteration costs
//!   `max(compute′, comm_wire)` per partition (Fig. 1(c)) where compute′
//!   is slowed by PCIe/memory contention during overlap
//!   (`overlap_compute_derate`; the paper's Table 6 shows exactly this:
//!   compute 0.17 s → 0.25 s when communication is overlapped).
//!
//! followed by a ring all-reduce of model gradients at the slowest link.
//!
//! Calibration to the paper's hardware lives in [`profiles`].

pub mod profiles;

use crate::comm::topology::Topology;

/// Execution schedule being modeled.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// synchronous boundary exchange each layer (paper's "GCN")
    Vanilla,
    /// pipelined exchange across iterations (paper's "PipeGCN")
    Pipelined,
}

/// Effective device compute rates plus the communication-schedule
/// constants. Rates are *effective* (not peak) throughputs of the two
/// kernel classes in a GCN layer.
#[derive(Clone, Copy, Debug)]
pub struct DeviceProfile {
    pub name: &'static str,
    /// effective FLOP/s of sparse aggregation (SpMM)
    pub spmm_flops: f64,
    /// effective FLOP/s of dense transform (GEMM)
    pub gemm_flops: f64,
    /// fixed overhead per layer per pass (kernel launches, framework)
    pub layer_overhead_s: f64,
    /// synchronization barrier cost per blocking boundary exchange
    pub barrier_s: f64,
    /// fraction of wire bandwidth that synchronous bursty transfers
    /// achieve (vanilla training stalls between layers)
    pub vanilla_bw_derate: f64,
    /// compute slowdown factor while communication is overlapped
    /// (PCIe/memory contention): effective compute = compute / this
    pub overlap_compute_derate: f64,
}

impl DeviceProfile {
    /// A neutral profile for unit tests: no barriers, no derating.
    pub fn ideal(spmm_flops: f64, gemm_flops: f64) -> DeviceProfile {
        DeviceProfile {
            name: "ideal",
            spmm_flops,
            gemm_flops,
            layer_overhead_s: 0.0,
            barrier_s: 0.0,
            vanilla_bw_derate: 1.0,
            overlap_compute_derate: 1.0,
        }
    }
}

/// One layer's compute on one partition (forward; backward is derived).
#[derive(Clone, Copy, Debug, Default)]
pub struct LayerCompute {
    pub spmm_flops: f64,
    pub gemm_flops: f64,
}

impl LayerCompute {
    pub fn total(&self) -> f64 {
        self.spmm_flops + self.gemm_flops
    }

    pub fn time(&self, p: &DeviceProfile) -> f64 {
        self.spmm_flops / p.spmm_flops + self.gemm_flops / p.gemm_flops + p.layer_overhead_s
    }
}

/// Everything one partition does in one training iteration.
#[derive(Clone, Debug, Default)]
pub struct PartitionWork {
    /// forward compute per layer
    pub fwd: Vec<LayerCompute>,
    /// backward compute per layer (≈2× forward FLOPs in practice)
    pub bwd: Vec<LayerCompute>,
    /// forward boundary-feature transfers per layer: (peer, bytes in+out)
    pub fwd_comm: Vec<Vec<(usize, u64)>>,
    /// backward boundary-gradient transfers per layer
    pub bwd_comm: Vec<Vec<(usize, u64)>>,
}

impl PartitionWork {
    pub fn compute_time(&self, p: &DeviceProfile) -> f64 {
        self.fwd.iter().chain(&self.bwd).map(|l| l.time(p)).sum()
    }

    /// Wire-speed communication time (transfers to distinct peers in one
    /// layer serialize through the device's single NIC/PCIe port).
    pub fn comm_wire_time(&self, me: usize, topo: &Topology) -> f64 {
        self.fwd_comm
            .iter()
            .chain(&self.bwd_comm)
            .flat_map(|layer| layer.iter())
            .map(|&(peer, bytes)| topo.link(me, peer).transfer_time(bytes))
            .sum()
    }

    /// Number of layer-passes that actually exchange data (each costs a
    /// barrier in vanilla mode).
    pub fn n_exchanges(&self) -> usize {
        self.fwd_comm
            .iter()
            .chain(&self.bwd_comm)
            .filter(|l| !l.is_empty())
            .count()
    }
}

/// Simulated epoch breakdown (all seconds).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EpochBreakdown {
    pub compute: f64,
    /// communication time on the wire (max over partitions, incl. derate)
    pub comm_total: f64,
    /// communication time *not* hidden by compute
    pub comm_exposed: f64,
    pub reduce: f64,
    pub total: f64,
}

impl EpochBreakdown {
    pub fn comm_ratio(&self) -> f64 {
        if self.total > 0.0 {
            (self.comm_exposed + self.reduce) / self.total
        } else {
            0.0
        }
    }
}

/// Ring all-reduce wall time of `elems` f32 across the topology.
pub fn allreduce_time(elems: usize, topo: &Topology) -> f64 {
    let n = topo.n_devices();
    if n <= 1 || elems == 0 {
        return 0.0;
    }
    let link = topo.ring_bottleneck();
    let steps = 2 * (n - 1);
    let chunk_bytes = (elems * 4 / n).max(1) as u64;
    steps as f64 * link.transfer_time(chunk_bytes)
}

/// Assemble one iteration's simulated time from per-partition work.
pub fn epoch_time(
    works: &[PartitionWork],
    model_elems: usize,
    profile: &DeviceProfile,
    topo: &Topology,
    mode: Mode,
) -> EpochBreakdown {
    assert!(works.len() <= topo.n_devices());
    let reduce = allreduce_time(model_elems, topo);
    let mut max_total = 0.0f64;
    let mut max_compute = 0.0f64;
    let mut max_comm = 0.0f64;
    let mut max_exposed = 0.0f64;
    for (i, w) in works.iter().enumerate() {
        let compute = w.compute_time(profile);
        let wire = w.comm_wire_time(i, topo);
        let (t, comm, exposed, comp) = match mode {
            Mode::Vanilla => {
                let comm = wire / profile.vanilla_bw_derate
                    + w.n_exchanges() as f64 * profile.barrier_s;
                (compute + comm, comm, comm, compute)
            }
            Mode::Pipelined => {
                // compute slows under overlap only if there is anything
                // to overlap with
                let comp = if wire > 0.0 {
                    compute / profile.overlap_compute_derate
                } else {
                    compute
                };
                (comp.max(wire), wire, (wire - comp).max(0.0), comp)
            }
        };
        max_total = max_total.max(t);
        max_compute = max_compute.max(comp);
        max_comm = max_comm.max(comm);
        max_exposed = max_exposed.max(exposed);
    }
    EpochBreakdown {
        compute: max_compute,
        comm_total: max_comm,
        comm_exposed: max_exposed,
        reduce,
        total: max_total + reduce,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::topology::{pcie3_link, Topology};

    fn profile() -> DeviceProfile {
        DeviceProfile::ideal(1e9, 1e10)
    }

    fn work(flops: f64, bytes: u64, peer: usize) -> PartitionWork {
        PartitionWork {
            fwd: vec![LayerCompute { spmm_flops: flops, gemm_flops: 0.0 }],
            bwd: vec![LayerCompute { spmm_flops: flops, gemm_flops: 0.0 }],
            fwd_comm: vec![vec![(peer, bytes)]],
            bwd_comm: vec![vec![(peer, bytes)]],
        }
    }

    #[test]
    fn vanilla_serializes_pipeline_overlaps() {
        let topo = Topology::single_node(2, pcie3_link());
        let p = profile();
        // compute 2×1s, comm 2×~1s (9e9 bytes at 9 GB/s)
        let works = vec![work(1e9, 9_000_000_000, 1), work(1e9, 9_000_000_000, 0)];
        let v = epoch_time(&works, 0, &p, &topo, Mode::Vanilla);
        let pl = epoch_time(&works, 0, &p, &topo, Mode::Pipelined);
        assert!((v.total - 4.0).abs() < 0.01, "vanilla {v:?}");
        assert!((pl.total - 2.0).abs() < 0.01, "pipelined {pl:?}");
        assert!(v.comm_ratio() > 0.49);
        assert!(pl.comm_exposed < 1e-3, "{pl:?}");
    }

    #[test]
    fn pipeline_exposes_comm_when_dominant() {
        let topo = Topology::single_node(2, pcie3_link());
        let p = profile();
        // comm 4s total, compute 2s → pipelined total 4s, exposed ~2s
        let works = vec![work(1e9, 18_000_000_000, 1), work(1e9, 18_000_000_000, 0)];
        let pl = epoch_time(&works, 0, &p, &topo, Mode::Pipelined);
        assert!((pl.total - 4.0).abs() < 0.01, "{pl:?}");
        assert!((pl.comm_exposed - 2.0).abs() < 0.01, "{pl:?}");
    }

    #[test]
    fn vanilla_pays_barriers_and_derate() {
        let topo = Topology::single_node(2, pcie3_link());
        let mut p = profile();
        p.barrier_s = 0.5;
        p.vanilla_bw_derate = 0.5;
        let works = vec![work(1e9, 9_000_000_000, 1), work(1e9, 9_000_000_000, 0)];
        let v = epoch_time(&works, 0, &p, &topo, Mode::Vanilla);
        // compute 2s + wire 2s/0.5 + 2 barriers = 2 + 4 + 1 = 7
        assert!((v.total - 7.0).abs() < 0.01, "{v:?}");
        // pipelined path ignores barriers, uses wire speed
        let pl = epoch_time(&works, 0, &p, &topo, Mode::Pipelined);
        assert!((pl.total - 2.0).abs() < 0.02, "{pl:?}");
    }

    #[test]
    fn overlap_contention_slows_compute() {
        let topo = Topology::single_node(2, pcie3_link());
        let mut p = profile();
        p.overlap_compute_derate = 0.5;
        // comm tiny but non-zero → compute dominates at 2/0.5 = 4s
        let works = vec![work(1e9, 9_000, 1), work(1e9, 9_000, 0)];
        let pl = epoch_time(&works, 0, &p, &topo, Mode::Pipelined);
        assert!((pl.total - 4.0).abs() < 0.01, "{pl:?}");
        // no comm at all → no contention
        let works2 = vec![
            PartitionWork {
                fwd: vec![LayerCompute { spmm_flops: 1e9, gemm_flops: 0.0 }],
                bwd: vec![LayerCompute { spmm_flops: 1e9, gemm_flops: 0.0 }],
                fwd_comm: vec![vec![]],
                bwd_comm: vec![vec![]],
            };
            2
        ];
        let pl2 = epoch_time(&works2, 0, &p, &topo, Mode::Pipelined);
        assert!((pl2.total - 2.0).abs() < 0.01, "{pl2:?}");
    }

    #[test]
    fn reduce_added_on_top() {
        let topo = Topology::single_node(4, pcie3_link());
        let p = profile();
        let works: Vec<PartitionWork> = (0..4).map(|i| work(1e9, 0, (i + 1) % 4)).collect();
        let with = epoch_time(&works, 1_000_000, &p, &topo, Mode::Vanilla);
        let without = epoch_time(&works, 0, &p, &topo, Mode::Vanilla);
        assert!(with.reduce > 0.0);
        assert!((with.total - without.total - with.reduce).abs() < 1e-9);
    }

    #[test]
    fn allreduce_time_scales_with_slowest_link() {
        use crate::comm::topology::eth10g_link;
        let fast = Topology::single_node(4, pcie3_link());
        let slow = Topology::multi_node(2, 2, pcie3_link(), eth10g_link());
        let tf = allreduce_time(10_000_000, &fast);
        let ts = allreduce_time(10_000_000, &slow);
        assert!(ts > 5.0 * tf, "fast {tf} slow {ts}");
    }

    #[test]
    fn layer_overhead_counted_per_layer_pass() {
        let topo = Topology::single_node(1, pcie3_link());
        let mut p = profile();
        p.layer_overhead_s = 0.1;
        let w = PartitionWork {
            fwd: vec![LayerCompute::default(); 3],
            bwd: vec![LayerCompute::default(); 3],
            fwd_comm: vec![vec![]; 3],
            bwd_comm: vec![vec![]; 3],
        };
        let e = epoch_time(&[w], 0, &p, &topo, Mode::Vanilla);
        assert!((e.compute - 0.6).abs() < 1e-9);
    }
}
