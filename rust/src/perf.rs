//! `pipegcn bench` — kernel and end-to-end throughput tracking.
//!
//! Runs the training hot-path kernels (SpMM and the three GEMM variants),
//! a short end-to-end epoch benchmark, a comm/compute **overlap sweep**
//! (multi-rank threaded runs under the prefetched schedule), and a
//! serve-path latency/QPS sweep (batched feature→logit queries against
//! an in-process [`crate::serve::Server`]) at a sweep of thread counts,
//! and streams one NDJSON row per measurement through
//! [`crate::util::json::Emitter`] into `BENCH_kernels.json`
//! (`{kernel, shape, threads, ns_iter, gflops}`; overlap rows add
//! `{comm_wait_ms, overlap_ratio}`, serve rows `{p50_ms, p90_ms,
//! p99_ms, qps}` — p90 read from the shared [`crate::obs`] histogram),
//! so the perf trajectory is tracked from PR 3 on. `--smoke` shrinks
//! shapes and iteration counts to CI scale.

use crate::exp::RunOpts;
use crate::runtime::pool;
use crate::session::{Engine, Session};
use crate::tensor::{Csr, Mat};
use crate::util::error::{Context, Result};
use crate::util::json::{FileEmitter, Json};
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

#[derive(Clone, Debug)]
pub struct BenchOpts {
    /// NDJSON output path
    pub out: String,
    /// thread counts to sweep (the speedup summary compares min vs max)
    pub threads: Vec<usize>,
    /// CI mode: small shapes, few iterations
    pub smoke: bool,
    /// preset for the end-to-end epoch benchmark
    pub preset: String,
    pub parts: usize,
    pub epochs: usize,
    /// run the BENCH_scale trajectory instead of the kernel sweep
    pub scale: bool,
    /// run the BENCH_serve sustained-QPS sweep instead of the kernel sweep
    pub serve: bool,
}

/// Time `f` for `iters` iterations (after one warmup), emit the NDJSON
/// row, and return the achieved GFLOP/s.
fn bench_kernel(
    em: &mut FileEmitter,
    name: &str,
    shape: &str,
    threads: usize,
    flops: f64,
    iters: usize,
    mut f: impl FnMut(),
) -> Result<f64> {
    f(); // warmup
    let w = Stopwatch::start();
    for _ in 0..iters {
        f();
    }
    let secs = w.elapsed_secs().max(1e-12);
    let ns_iter = secs * 1e9 / iters as f64;
    let gflops = flops * iters as f64 / secs / 1e9;
    em.emit(
        &Json::obj()
            .set("kernel", name)
            .set("shape", shape)
            .set("threads", threads)
            .set("ns_iter", ns_iter)
            .set("gflops", gflops),
    )
    .with_context(|| format!("writing bench row for {name}"))?;
    Ok(gflops)
}

/// Nearest-rank percentile of an **ascending-sorted** latency list —
/// the one definition shared by the serve bench rows and `pipegcn
/// query`'s report, so their p50/p99 are the same statistic.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of an empty sample");
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Deterministic random CSR for benches and the parallel-kernel tests
/// (O(rows·cols) bernoulli scan — fine at bench/test shapes).
pub fn random_csr(rng: &mut Rng, rows: usize, cols: usize, density: f32) -> Csr {
    let mut trip = Vec::new();
    for r in 0..rows {
        for c in 0..cols {
            if rng.bernoulli(density) {
                trip.push((r as u32, c as u32, rng.normal()));
            }
        }
    }
    Csr::from_triplets(rows, cols, trip)
}

/// Run the full sweep, writing `opts.out` and printing a speedup
/// summary. Restores nothing: the process-wide thread count is left at
/// the last swept value (the CLI exits right after).
pub fn run_bench(o: &BenchOpts) -> Result<()> {
    if o.threads.is_empty() {
        crate::bail!("--threads list must name at least one thread count");
    }
    let mut em = FileEmitter::create(
        &o.out,
        Json::obj()
            .set("bench", "pipegcn-kernels")
            .set("smoke", o.smoke)
            .set("preset", o.preset.as_str())
            .set("threads", o.threads.iter().map(|&t| Json::from(t)).collect::<Vec<Json>>()),
    )
    .with_context(|| format!("creating {}", o.out))?;

    // kernel shapes ≈ one medium partition: `rows` nodes, `feat`-wide
    // activations, `hidden`-wide next layer
    let (rows, feat, hidden, density, iters) =
        if o.smoke { (512, 32, 16, 0.01, 3) } else { (4000, 128, 64, 0.004, 20) };
    let mut rng = Rng::new(42);
    let csr = random_csr(&mut rng, rows, rows, density);
    let h = Mat::randn(rows, feat, 1.0, &mut rng); // layer input
    let a = Mat::randn(rows, feat, 1.0, &mut rng); // activations
    let w = Mat::randn(feat, hidden, 0.5, &mut rng); // weights
    let m = Mat::randn(rows, hidden, 1.0, &mut rng); // upstream grad
    let nnz = csr.nnz() as f64;
    let spmm_flops = 2.0 * nnz * feat as f64;
    let gemm_flops = 2.0 * (rows * feat * hidden) as f64;

    let mut gf_at: Vec<(&'static str, usize, f64)> = Vec::new();
    for &t in &o.threads {
        pool::set_threads(t);
        let sp_shape = format!("{rows}x{rows}x{feat}");
        let mm_shape = format!("{rows}x{feat}x{hidden}");
        let gfs = bench_kernel(&mut em, "spmm", &sp_shape, t, spmm_flops, iters, || {
            let _ = csr.spmm(&h);
        })?;
        gf_at.push(("spmm", t, gfs));
        let gfs = bench_kernel(&mut em, "spmm_t", &sp_shape, t, spmm_flops, iters, || {
            let _ = csr.spmm_t(&h);
        })?;
        gf_at.push(("spmm_t", t, gfs));
        let gfs = bench_kernel(&mut em, "matmul", &mm_shape, t, gemm_flops, iters, || {
            let _ = a.matmul(&w);
        })?;
        gf_at.push(("matmul", t, gfs));
        let gfs = bench_kernel(&mut em, "matmul_tn", &mm_shape, t, gemm_flops, iters, || {
            let _ = a.matmul_tn(&m);
        })?;
        gf_at.push(("matmul_tn", t, gfs));
        let gfs = bench_kernel(&mut em, "matmul_nt", &mm_shape, t, gemm_flops, iters, || {
            let _ = m.matmul_nt(&w);
        })?;
        gf_at.push(("matmul_nt", t, gfs));
    }

    // end-to-end epochs: preset training through the sequential engine;
    // per-epoch FLOPs come from the backend's own counters
    for &t in &o.threads {
        pool::set_threads(t);
        let run_opts = RunOpts { epochs: o.epochs, eval_every: 0, ..Default::default() };
        let out = Session::preset(&o.preset)
            .parts(o.parts)
            .variant("pipegcn")
            .run_opts(run_opts)
            .run()?
            .into_output();
        let n_epochs = out.result.curve.len().max(1) as f64;
        let mean_ms = out.result.curve.iter().map(|e| e.epoch_ms).sum::<f64>() / n_epochs;
        let flops: f64 = out
            .result
            .works
            .iter()
            .map(|wk| wk.fwd.iter().chain(wk.bwd.iter()).map(|l| l.total()).sum::<f64>())
            .sum();
        let gfs = flops / (mean_ms / 1e3).max(1e-12) / 1e9;
        em.emit(
            &Json::obj()
                .set("kernel", "epoch")
                .set("shape", format!("{}x{}", o.preset, o.parts))
                .set("threads", t)
                .set("ns_iter", mean_ms * 1e6)
                .set("gflops", gfs),
        )
        .context("writing epoch bench row")?;
        gf_at.push(("epoch", t, gfs));
    }

    // overlap sweep: a multi-rank *threaded* run per thread count — the
    // prefetched schedule's measured comm/compute overlap. Rows report
    // rank 0's total parked-receive time and the hidden-receive
    // fraction; ns_iter keeps the common schema (wait per epoch).
    for &t in &o.threads {
        pool::set_threads(t);
        let run_opts = RunOpts { epochs: o.epochs, eval_every: 0, ..Default::default() };
        let report = Session::preset(&o.preset)
            .parts(o.parts)
            .variant("pipegcn")
            .run_opts(run_opts)
            .engine(Engine::Threaded)
            .run()?;
        let epochs = report.losses.len().max(1) as f64;
        em.emit(
            &Json::obj()
                .set("kernel", "overlap")
                .set("shape", format!("{}x{}", o.preset, o.parts))
                .set("threads", t)
                .set("ns_iter", report.comm_wait_ms / epochs * 1e6)
                .set("comm_wait_ms", report.comm_wait_ms)
                .set("overlap_ratio", report.overlap_ratio),
        )
        .context("writing overlap bench row")?;
    }

    // serve sweep: batched feature→logit query latency (p50/p99) and QPS
    // against an in-process server, at the sweep's min and max thread
    // counts (the default 1,2,4 sweep measures at 1 and 4 threads). Each
    // query runs a real full-graph batch inference — the kernels on the
    // pool — so the thread count genuinely moves the numbers.
    {
        let t0 = *o.threads.iter().min().unwrap();
        let tm = *o.threads.iter().max().unwrap();
        let preset = crate::graph::presets::by_name(&o.preset)
            .ok_or_else(|| crate::err_msg!("unknown preset '{}'", o.preset))?;
        let cfg = crate::model::ModelConfig::from_preset(preset);
        let params = crate::model::Params::init(&cfg, &mut Rng::new(7));
        let batch = if o.smoke { 16 } else { 64 };
        let queries = if o.smoke { 5 } else { 50 };
        let ids: Vec<u32> = (0..batch as u32).collect();
        let mut serve_threads = vec![t0];
        if tm != t0 {
            serve_threads.push(tm);
        }
        for &t in &serve_threads {
            pool::set_threads(t);
            let server = crate::serve::Server::from_parts(
                preset.build(1),
                cfg.clone(),
                params.clone(),
            )?;
            let addr = server.addr().to_string();
            // pin the tier to unbatched/uncached so this row keeps
            // measuring the raw per-query forward — trend-compatible
            // with pre-tier BENCH rows (batched numbers live in
            // `bench --serve`)
            let tier = crate::serve::tier::TierOpts {
                window_ms: 0.0,
                max_batch: 1,
                cache: false,
                queue: 256,
            };
            let handle = std::thread::spawn(move || server.run_tier(Some(1), tier));
            let mut client = crate::serve::Client::connect(&addr)?;
            let _ = client.query(&ids)?; // warmup
            // obs histogram alongside the exact sample: the same
            // log-bucketed view the serve endpoint exposes, labeled per
            // thread count so sweep points stay separate
            let hist = crate::obs::global()
                .histogram("bench_serve_ms", &[("threads", &t.to_string())]);
            let total_watch = Stopwatch::start();
            let mut lats_ms = Vec::with_capacity(queries);
            for _ in 0..queries {
                let w = Stopwatch::start();
                let m = client.query(&ids)?;
                let ms = w.elapsed_secs() * 1e3;
                lats_ms.push(ms);
                hist.record(ms);
                debug_assert_eq!(m.rows, batch);
            }
            let total_secs = total_watch.elapsed_secs();
            client.close();
            handle.join().expect("serve thread panicked")?;
            lats_ms.sort_by(f64::total_cmp);
            let p50 = percentile(&lats_ms, 0.50);
            let p99 = percentile(&lats_ms, 0.99);
            em.emit(
                &Json::obj()
                    .set("kernel", "serve")
                    .set("shape", format!("{}x{batch}", o.preset))
                    .set("threads", t)
                    .set("ns_iter", p50 * 1e6)
                    .set("p50_ms", p50)
                    .set("p90_ms", hist.quantile(0.90))
                    .set("p99_ms", p99)
                    .set("qps", queries as f64 / total_secs.max(1e-12)),
            )
            .context("writing serve bench row")?;
        }
    }

    // summary: geo-mean spmm+GEMM speedup, max vs min thread count
    let t0 = *o.threads.iter().min().unwrap();
    let tm = *o.threads.iter().max().unwrap();
    let mut ratios = Vec::new();
    for name in ["spmm", "matmul", "matmul_tn", "matmul_nt"] {
        let at = |tt: usize| {
            gf_at.iter().find(|&&(n, t, _)| n == name && t == tt).map(|&(_, _, g)| g)
        };
        if let (Some(g0), Some(gm)) = (at(t0), at(tm)) {
            if g0 > 0.0 {
                ratios.push(gm / g0);
            }
        }
    }
    let speedup = if ratios.is_empty() {
        1.0
    } else {
        ratios.iter().product::<f64>().powf(1.0 / ratios.len() as f64)
    };
    em.emit(
        &Json::obj()
            .set("kernel", "summary")
            .set("threads_base", t0)
            .set("threads_max", tm)
            .set("spmm_gemm_speedup", speedup),
    )
    .context("writing bench summary row")?;
    println!(
        "bench: {} rows -> {} | spmm+GEMM geo-mean speedup {tm}t vs {t0}t: {speedup:.2}x",
        em.rows(),
        o.out,
    );
    Ok(())
}

/// `pipegcn bench --scale` — the BENCH_scale trajectory. Per point
/// `n`, time the lean per-rank build in-process (topology → partition →
/// rank 0's shard → rank 0's halo plan: the exact sequence every worker
/// of a scaled mesh runs), then train a short real-TCP mesh with
/// per-rank lazy construction (`Session::scale`) and record wall-clock
/// per epoch plus rank 0's peak RSS and wire bytes from its report.
/// One NDJSON row per point:
/// `{preset, n, parts, build_ms, epoch_ms, peak_rss_bytes, comm_bytes}`.
/// `epoch_ms` includes the mesh's own rendezvous + build amortized over
/// the epochs — it tracks the end-to-end trajectory, not kernel time.
pub fn run_scale_bench(o: &BenchOpts) -> Result<()> {
    let preset = crate::graph::presets::by_name(&o.preset)
        .ok_or_else(|| crate::err_msg!("unknown preset '{}'", o.preset))?;
    if o.parts == 0 {
        crate::bail!("--parts must be at least 1");
    }
    let points: &[usize] = if o.smoke { &[100_000] } else { &[100_000, 1_000_000] };
    let mut em = FileEmitter::create(
        &o.out,
        Json::obj()
            .set("bench", "pipegcn-scale")
            .set("preset", o.preset.as_str())
            .set("parts", o.parts)
            .set("smoke", o.smoke),
    )
    .with_context(|| format!("creating {}", o.out))?;
    let epochs = o.epochs.max(1);
    let cfg = crate::model::ModelConfig::from_preset(preset);
    for &n in points {
        let w = Stopwatch::start();
        let build_ms;
        {
            let topo = preset.build_topology_scaled(n, 1);
            let pt = crate::partition::partition_adj(
                topo.adj(),
                o.parts,
                crate::partition::Method::Multilevel,
                1,
            );
            let shard = preset.build_shard_scaled(n, 1, &pt.assign, 0);
            let src = crate::coordinator::halo::NodeSource::Shard(&shard);
            let _plan = crate::coordinator::halo::build_part(
                topo.adj(),
                &pt.assign,
                o.parts,
                0,
                cfg.kind,
                &src,
            );
            build_ms = w.elapsed_secs() * 1e3;
        }
        let w = Stopwatch::start();
        let report = Session::preset(&o.preset)
            .parts(o.parts)
            .variant("pipegcn")
            .epochs(epochs)
            .scale(n)
            .engine(Engine::Tcp { max_restarts: 0 })
            .run()?;
        let epoch_ms = w.elapsed_secs() * 1e3 / epochs as f64;
        em.emit(
            &Json::obj()
                .set("preset", o.preset.as_str())
                .set("n", n)
                .set("parts", o.parts)
                .set("build_ms", build_ms)
                .set("epoch_ms", epoch_ms)
                .set("peak_rss_bytes", report.peak_rss_bytes)
                .set("comm_bytes", report.wire_bytes),
        )
        .context("writing scale bench row")?;
        println!(
            "scale: {} n={n} parts={} build {build_ms:.0}ms epoch {epoch_ms:.0}ms \
             peak_rss {}MiB",
            o.preset,
            o.parts,
            report.peak_rss_bytes >> 20,
        );
    }
    println!("scale bench: {} rows -> {}", em.rows(), o.out);
    Ok(())
}

/// `pipegcn bench --serve` — the BENCH_serve sustained-QPS sweep.
/// For each tier configuration (unbatched/uncached — the pre-tier
/// behavior — then micro-batching + activation caching), stand up an
/// in-process [`crate::serve::Server`] and drive it with the closed-loop
/// load generator at several concurrency levels. One NDJSON row per
/// `(config, concurrency)` point:
/// `{kernel: "serve_tier", batched, concurrency, queries, errors, qps,
/// p50_ms, p90_ms, p99_ms}` — the micro-batching win is the `qps` gap
/// between batched and unbatched rows at equal concurrency (and equal
/// or better p99).
pub fn run_serve_bench(o: &BenchOpts) -> Result<()> {
    let preset = crate::graph::presets::by_name(&o.preset)
        .ok_or_else(|| crate::err_msg!("unknown preset '{}'", o.preset))?;
    let cfg = crate::model::ModelConfig::from_preset(preset);
    let params = crate::model::Params::init(&cfg, &mut Rng::new(7));
    let duration_s = if o.smoke { 1.0 } else { 3.0 };
    let levels: &[usize] = if o.smoke { &[1, 4] } else { &[1, 4, 16] };
    let mut em = FileEmitter::create(
        &o.out,
        Json::obj()
            .set("bench", "pipegcn-serve")
            .set("preset", o.preset.as_str())
            .set("smoke", o.smoke)
            .set("duration_s", duration_s),
    )
    .with_context(|| format!("creating {}", o.out))?;
    let ids: Vec<u32> = (0..16u32).collect();
    let mut qps_at: Vec<(bool, usize, f64)> = Vec::new();
    for batched in [false, true] {
        let tier = if batched {
            crate::serve::tier::TierOpts { window_ms: 2.0, max_batch: 64, cache: true, queue: 256 }
        } else {
            crate::serve::tier::TierOpts { window_ms: 0.0, max_batch: 1, cache: false, queue: 256 }
        };
        let server =
            crate::serve::Server::from_parts(preset.build(1), cfg.clone(), params.clone())?;
        let addr = server.addr().to_string();
        let handle = std::thread::spawn(move || server.run_tier(None, tier));
        for &conc in levels {
            let r = crate::serve::tier::loadgen::run(&crate::serve::tier::LoadOpts {
                addr: addr.clone(),
                ids: ids.clone(),
                mode: crate::serve::tier::LoadMode::Closed { concurrency: conc },
                duration_s,
            });
            em.emit(
                &Json::obj()
                    .set("kernel", "serve_tier")
                    .set("batched", batched)
                    .set("concurrency", conc)
                    .set("queries", r.queries)
                    .set("errors", r.errors)
                    .set("qps", r.qps)
                    .set("p50_ms", r.p50_ms)
                    .set("p90_ms", r.p90_ms)
                    .set("p99_ms", r.p99_ms),
            )
            .context("writing serve tier bench row")?;
            println!(
                "serve_tier: batched={batched} concurrency={conc} → {:.1} qps \
                 (p50 {:.2} ms, p99 {:.2} ms, {} errors)",
                r.qps, r.p50_ms, r.p99_ms, r.errors
            );
            qps_at.push((batched, conc, r.qps));
        }
        let mut ctl = crate::serve::Client::connect(&addr)
            .with_context(|| format!("connecting to {addr} for drain"))?;
        ctl.drain().map_err(|e| crate::err_msg!("draining the bench server: {e}"))?;
        ctl.close();
        handle.join().expect("serve thread panicked")?;
    }
    let top = *levels.last().unwrap();
    let at = |b: bool| {
        qps_at.iter().find(|&&(bb, c, _)| bb == b && c == top).map(|&(_, _, q)| q)
    };
    if let (Some(unb), Some(bat)) = (at(false), at(true)) {
        em.emit(
            &Json::obj()
                .set("kernel", "summary")
                .set("concurrency", top)
                .set("qps_unbatched", unb)
                .set("qps_batched", bat)
                .set("batched_speedup", if unb > 0.0 { bat / unb } else { 0.0 }),
        )
        .context("writing serve bench summary row")?;
        println!(
            "serve bench: {} rows -> {} | batched vs unbatched at c={top}: {:.2}x qps",
            em.rows(),
            o.out,
            if unb > 0.0 { bat / unb } else { 0.0 }
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: the full smoke-bench roundtrip test lives in
    // `tests/parallel_kernels.rs` — it reconfigures the global pool,
    // which the lib-test binary reserves for `runtime::pool`'s own test.

    #[test]
    fn percentile_is_nearest_rank() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 0.5), 3.0);
        assert_eq!(percentile(&xs, 0.99), 5.0);
        assert_eq!(percentile(&[7.5], 0.99), 7.5);
    }

    #[test]
    fn empty_threads_list_rejected() {
        let o = BenchOpts {
            out: "/tmp/pipegcn_bench_empty.ndjson".into(),
            threads: vec![],
            smoke: true,
            preset: "tiny".into(),
            parts: 2,
            epochs: 1,
            scale: false,
            serve: false,
        };
        assert!(run_bench(&o).is_err());
    }

    #[test]
    fn scale_bench_rejects_bad_inputs() {
        let mut o = BenchOpts {
            out: "/tmp/pipegcn_bench_scale_bad.ndjson".into(),
            threads: vec![1],
            smoke: true,
            preset: "no-such-preset".into(),
            parts: 4,
            epochs: 1,
            scale: true,
            serve: false,
        };
        assert!(run_scale_bench(&o).is_err());
        assert!(run_serve_bench(&o).is_err());
        o.preset = "tiny".into();
        o.parts = 0;
        assert!(run_scale_bench(&o).is_err());
    }
}
