//! Cluster topology description: devices grouped into nodes, with
//! intra-node (PCIe) and inter-node (Ethernet) links.
//!
//! Mirrors the paper's two testbeds:
//! * 10× RTX-2080Ti in one chassis, PCIe3 ×16 CPU-GPU and GPU-GPU;
//! * 4 nodes × 8 MI60, PCIe3 ×48 lanes intra, 10 Gbps Ethernet inter.

/// A point-to-point link model: `time(bytes) = latency + bytes/bandwidth`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Link {
    pub latency_s: f64,
    pub bytes_per_s: f64,
}

impl Link {
    pub fn transfer_time(&self, bytes: u64) -> f64 {
        self.latency_s + bytes as f64 / self.bytes_per_s
    }
}

/// Devices `0..n_devices`, `node_of[d]` gives the chassis id.
#[derive(Clone, Debug)]
pub struct Topology {
    pub node_of: Vec<usize>,
    pub intra: Link,
    pub inter: Link,
}

impl Topology {
    /// Single node with `n` devices, all pairs on the intra link.
    pub fn single_node(n: usize, intra: Link) -> Topology {
        Topology { node_of: vec![0; n], intra, inter: intra }
    }

    /// `nodes` × `per_node` devices.
    pub fn multi_node(nodes: usize, per_node: usize, intra: Link, inter: Link) -> Topology {
        let node_of = (0..nodes * per_node).map(|d| d / per_node).collect();
        Topology { node_of, intra, inter }
    }

    pub fn n_devices(&self) -> usize {
        self.node_of.len()
    }

    pub fn link(&self, a: usize, b: usize) -> Link {
        if self.node_of[a] == self.node_of[b] {
            self.intra
        } else {
            self.inter
        }
    }

    /// The slowest link in a ring 0→1→…→n−1→0 (ring collectives run at
    /// the pace of the slowest hop).
    pub fn ring_bottleneck(&self) -> Link {
        let n = self.n_devices();
        let mut worst = self.intra;
        for d in 0..n {
            let l = self.link(d, (d + 1) % n);
            if l.bytes_per_s < worst.bytes_per_s {
                worst = l;
            }
        }
        worst
    }
}

/// PCIe 3.0 ×16 effective point-to-point (≈12 GB/s raw, ~9 effective
/// through host bridges with contention).
pub fn pcie3_link() -> Link {
    Link { latency_s: 20e-6, bytes_per_s: 9.0e9 }
}

/// 10 Gbps Ethernet effective (~1.1 GB/s with TCP overheads).
pub fn eth10g_link() -> Link {
    Link { latency_s: 150e-6, bytes_per_s: 1.1e9 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_affine() {
        let l = Link { latency_s: 1e-3, bytes_per_s: 1e6 };
        assert!((l.transfer_time(0) - 1e-3).abs() < 1e-12);
        assert!((l.transfer_time(1_000_000) - 1.001).abs() < 1e-9);
    }

    #[test]
    fn single_node_all_intra() {
        let t = Topology::single_node(4, pcie3_link());
        assert_eq!(t.link(0, 3), pcie3_link());
        assert_eq!(t.ring_bottleneck(), pcie3_link());
    }

    #[test]
    fn multi_node_link_selection() {
        let t = Topology::multi_node(2, 2, pcie3_link(), eth10g_link());
        assert_eq!(t.n_devices(), 4);
        assert_eq!(t.link(0, 1), pcie3_link()); // same node
        assert_eq!(t.link(1, 2), eth10g_link()); // crosses nodes
        // ring 0-1-2-3-0 crosses nodes at 1→2 and 3→0
        assert_eq!(t.ring_bottleneck(), eth10g_link());
    }
}
