//! Communication fabric for partition-parallel training.
//!
//! [`Transport`] is the message-passing contract the training schedule is
//! written against, and it is **nonblocking by construction**: a receive
//! is *posted* with [`Transport::post_recv`], which returns a
//! [`RecvHandle`] immediately; the payload is claimed later with
//! [`RecvHandle::try_take`] (never blocks) or [`RecvHandle::wait`]
//! (parks, and charges the parked time to the handle's `(layer, phase)`
//! in a [`WaitStats`]). This is what makes PipeGCN's namesake mechanism
//! real at the API level: the per-rank schedule posts every receive of
//! an epoch up front and computes past them, so communication completes
//! *behind* the kernels instead of serializing with them —
//! [`Transport::recv_blocking`] survives only as a default-method shim
//! (`post_recv(..).wait_untracked()`) for incremental migration and
//! one-shot control paths.
//!
//! Two implementations exist:
//!
//! * [`Fabric`] (here) — an in-process mailbox with per-pair byte
//!   accounting, shared by every rank of a sequential or threaded run.
//!   Posted receives reserve a slot on the (src, dst, tag) FIFO; a send
//!   fulfills the oldest live reservation directly, waking any parked
//!   waiter. Experiments get exact communication volumes "for free";
//!   those byte counts feed the [`crate::sim`] link model.
//! * [`crate::net::TcpTransport`] — real length-prefixed frames over TCP
//!   sockets, one instance per OS process (one rank each). Its reader
//!   threads fulfill posted handles straight from the socket demux, so a
//!   receive posted before a GEMM is complete by the time the rank asks
//!   for it.
//!
//! Both implementations run the shared conformance suite in
//! `tests/transport_conformance.rs` (post/try/wait ordering, FIFO per
//! tag, drop-without-wait safety, byte accounting). Staleness is encoded
//! in [`Tag`]s, so the same schedule is deterministic over either
//! transport.

pub mod allreduce;
pub mod schedule;
pub mod topology;

use std::collections::BTreeMap;
use std::collections::HashMap;
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

use crate::util::timer::Stopwatch;

/// Which tensor a message carries (Algorithm 1's two comm streams).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Phase {
    /// boundary features, forward pass (thread_f in Alg. 1)
    FwdFeat,
    /// boundary feature gradients, backward pass (thread_b in Alg. 1)
    BwdGrad,
    /// model-gradient all-reduce chunks
    Reduce,
    /// control/setup (boundary-set exchange, trace clock sync)
    Setup,
    /// per-epoch scalar loss reduction to rank 0
    Loss,
}

/// Message identity: (iteration, layer, phase). PipeGCN tags messages
/// with the *producing* iteration so the consumer can explicitly pick up
/// iteration `t-1` tensors — staleness is in the tag, not in timing luck.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Tag {
    pub iter: u32,
    pub layer: u16,
    pub phase: Phase,
}

impl Phase {
    /// Stable wire encoding (used by `net::frame`).
    pub fn code(self) -> u8 {
        match self {
            Phase::FwdFeat => 0,
            Phase::BwdGrad => 1,
            Phase::Reduce => 2,
            Phase::Setup => 3,
            Phase::Loss => 4,
        }
    }

    pub fn from_code(c: u8) -> Option<Phase> {
        match c {
            0 => Some(Phase::FwdFeat),
            1 => Some(Phase::BwdGrad),
            2 => Some(Phase::Reduce),
            3 => Some(Phase::Setup),
            4 => Some(Phase::Loss),
            _ => None,
        }
    }
}

impl Tag {
    pub fn new(iter: u32, layer: u16, phase: Phase) -> Tag {
        Tag { iter, layer, phase }
    }

    /// The epoch-`iter` loss-partial tag. Loss messages carry their own
    /// phase so no field is ever punned: the (src, dst) link identifies
    /// the sender, and `layer` stays 0 — the schedule analyzer's
    /// aliasing check needs no special case for them.
    pub fn loss(iter: u32) -> Tag {
        Tag { iter, layer: 0, phase: Phase::Loss }
    }
}

// ---------------------------------------------------------------------
// Posted-receive machinery shared by every transport implementation
// ---------------------------------------------------------------------

/// State of one posted receive, shared between the poster's handle and
/// the transport side that fulfills it. The slot mutex is only ever held
/// briefly; blocking waits park on the owning transport's condvar. Lock
/// order everywhere: transport state first, then the slot.
#[derive(Debug)]
pub(crate) enum SlotState {
    /// posted, no payload yet
    Pending,
    /// fulfilled (delivery sequence number + payload), not yet claimed.
    /// The sequence number is what lets a dropped-without-take handle
    /// reinsert its payload at the right FIFO position.
    Ready(u64, Vec<f32>),
    /// payload claimed by the handle (terminal)
    Taken,
    /// handle dropped before fulfillment (terminal) — fulfillers skip
    /// cancelled reservations and deliver to the next one (or the queue)
    Cancelled,
}

pub(crate) type SlotRef = Arc<Mutex<SlotState>>;

/// A message parked in a transport queue: (delivery sequence, payload).
pub(crate) type Queued = (u64, Vec<f32>);

pub(crate) fn new_slot() -> SlotRef {
    Arc::new(Mutex::new(SlotState::Pending))
}

/// Fulfill `slot` with `payload` (delivery sequence `seq`) if it is
/// still pending. Returns the message back when the reservation was
/// cancelled (the caller must deliver it elsewhere).
pub(crate) fn fulfill(slot: &SlotRef, seq: u64, payload: Vec<f32>) -> Option<Queued> {
    let mut g = slot.lock().unwrap();
    match &*g {
        SlotState::Pending => {
            *g = SlotState::Ready(seq, payload);
            None
        }
        SlotState::Cancelled => Some((seq, payload)),
        other => panic!("fulfilling a receive slot in state {other:?}"),
    }
}

/// Offer a message to the oldest live reservation in `q` (cancelled
/// slots are discarded as they are found). Returns the message back
/// when no live reservation remains — the caller queues it. This is
/// the one fulfillment loop both transports (and the drop-recovery
/// paths) share, so delivery order has a single implementation.
pub(crate) fn offer(q: &mut VecDeque<SlotRef>, seq: u64, payload: Vec<f32>) -> Option<Queued> {
    let mut item = Some((seq, payload));
    while let Some(slot) = q.pop_front() {
        let (s, p) = item.take().unwrap();
        match fulfill(&slot, s, p) {
            // delivered to a live handle
            None => return None,
            // cancelled reservation: try the next
            Some(back) => item = Some(back),
        }
    }
    item
}

/// Reinsert a recovered message at its sequence position — dropped
/// fulfilled handles restore exact send order no matter how many
/// recover, in whatever order.
pub(crate) fn requeue_in_order(q: &mut VecDeque<Queued>, seq: u64, payload: Vec<f32>) {
    let pos = q.iter().position(|(s, _)| *s > seq).unwrap_or(q.len());
    q.insert(pos, (seq, payload));
}

/// Claim a fulfilled slot's payload (→ `Taken`); `None` while pending.
pub(crate) fn take_ready(slot: &SlotRef) -> Option<Vec<f32>> {
    let mut g = slot.lock().unwrap();
    if matches!(&*g, SlotState::Ready(..)) {
        match std::mem::replace(&mut *g, SlotState::Taken) {
            SlotState::Ready(_, p) => Some(p),
            _ => unreachable!(),
        }
    } else {
        None
    }
}

/// Transport-specific completion backend behind a [`RecvHandle`]. The
/// concrete type's `Drop` owns cancellation: a handle dropped without
/// taking its payload must remove its reservation (still pending), or —
/// already fulfilled — hand the payload to the oldest pending sibling
/// reservation, falling back to the head of the FIFO. A dropped handle
/// never loses a message and never strands a sibling.
pub(crate) trait RecvFuture: Send {
    /// Claim the payload if it has arrived; never blocks.
    fn try_take(&mut self) -> Option<Vec<f32>>;
    /// Park until the payload arrives, then claim it.
    fn wait_take(&mut self) -> Vec<f32>;
}

/// A pending receive posted with [`Transport::post_recv`]. The handle is
/// the completion side of the nonblocking contract: the transport keeps
/// delivering behind it while the rank computes, and the schedule only
/// parks — via [`RecvHandle::wait`] — at the true point of use.
pub struct RecvHandle {
    src: usize,
    dst: usize,
    tag: Tag,
    fut: Box<dyn RecvFuture>,
}

impl RecvHandle {
    pub(crate) fn new(src: usize, dst: usize, tag: Tag, fut: Box<dyn RecvFuture>) -> RecvHandle {
        // every transport constructs its handles here, so this is the
        // one conformance hook for the PostRecv side of the schedule
        schedule::observe(schedule::OpKind::PostRecv, dst, src, tag);
        RecvHandle { src, dst, tag, fut }
    }

    pub fn src(&self) -> usize {
        self.src
    }

    pub fn dst(&self) -> usize {
        self.dst
    }

    pub fn tag(&self) -> Tag {
        self.tag
    }

    /// Claim the payload if it has already arrived; never blocks. After
    /// `Some`, the handle is spent (dropping it is a no-op).
    pub fn try_take(&mut self) -> Option<Vec<f32>> {
        let v = self.fut.try_take();
        if v.is_some() {
            schedule::observe(schedule::OpKind::Claim, self.dst, self.src, self.tag);
        }
        v
    }

    /// Block until the payload arrives. Time actually spent parked is
    /// charged to `stats` under this handle's `(layer, phase)`; a
    /// receive that had already completed counts as *hidden* (fully
    /// overlapped with compute) and charges ~nothing. When the span
    /// tracer is on, a parked wait also records a `comm_wait` span on
    /// the receiving rank's comm lane (the stall made visible).
    pub fn wait(mut self, stats: &mut WaitStats) -> Vec<f32> {
        schedule::observe(schedule::OpKind::Wait, self.dst, self.src, self.tag);
        if let Some(v) = self.fut.try_take() {
            stats.hit(self.tag);
            return v;
        }
        let t0 = crate::obs::trace::now_us();
        let w = Stopwatch::start();
        let v = self.fut.wait_take();
        stats.charge(self.tag, w.elapsed_secs());
        if crate::obs::trace::enabled() {
            crate::obs::trace::span(
                self.dst,
                crate::obs::trace::Kind::CommWait,
                self.tag.layer as usize,
                self.tag.iter as usize,
                t0,
            );
        }
        v
    }

    /// [`RecvHandle::wait`] without attribution (setup/control paths
    /// and the [`Transport::recv_blocking`] shim).
    pub fn wait_untracked(mut self) -> Vec<f32> {
        schedule::observe(schedule::OpKind::Wait, self.dst, self.src, self.tag);
        self.fut.wait_take()
    }

    /// Claim a payload that must already be there (the sequential
    /// engine's replay, where the producer ran earlier in program
    /// order). Panics with a diagnostic naming the exact message.
    pub fn take_now(mut self) -> Vec<f32> {
        schedule::observe(schedule::OpKind::Claim, self.dst, self.src, self.tag);
        match self.fut.try_take() {
            Some(v) => v,
            None => panic!(
                "no message {}->{} for {:?} (the posted receive was never fulfilled)",
                self.src, self.dst, self.tag
            ),
        }
    }
}

impl std::fmt::Debug for RecvHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RecvHandle")
            .field("src", &self.src)
            .field("dst", &self.dst)
            .field("tag", &self.tag)
            .finish()
    }
}

/// Per-`(layer, phase)` comm-wait accounting, filled by
/// [`RecvHandle::wait`]. This is the measured overlap of the pipelined
/// schedule: `total_secs` is the time the rank sat parked in receives,
/// broken down by where in the schedule it parked, and
/// [`WaitStats::overlap_ratio`] is the fraction of receives whose
/// communication was fully hidden behind compute.
#[derive(Default, Clone, Debug)]
pub struct WaitStats {
    /// seconds parked, keyed by (phase, layer) — BTreeMap so emitted
    /// breakdowns have a stable key order
    by: BTreeMap<(Phase, u16), f64>,
    hidden: u64,
    exposed: u64,
}

impl WaitStats {
    /// A receive that had to park for `secs`.
    pub fn charge(&mut self, tag: Tag, secs: f64) {
        self.exposed += 1;
        *self.by.entry((tag.phase, tag.layer)).or_insert(0.0) += secs;
    }

    /// A receive that was already complete when waited on (its key still
    /// appears in the breakdown, at +0 time).
    pub fn hit(&mut self, tag: Tag) {
        self.hidden += 1;
        self.by.entry((tag.phase, tag.layer)).or_insert(0.0);
    }

    /// Receives already complete at their wait point.
    pub fn hidden(&self) -> u64 {
        self.hidden
    }

    /// Receives that had to park.
    pub fn exposed(&self) -> u64 {
        self.exposed
    }

    /// Total parked seconds across every key.
    pub fn total_secs(&self) -> f64 {
        self.by.values().sum()
    }

    /// Fraction of waited receives that were already complete — 1.0 when
    /// every receive was hidden behind compute (or none were waited).
    pub fn overlap_ratio(&self) -> f64 {
        let n = self.hidden + self.exposed;
        if n == 0 {
            1.0
        } else {
            self.hidden as f64 / n as f64
        }
    }

    /// Breakdown in milliseconds under stable keys: `fwd_l{layer}` /
    /// `bwd_l{layer}` per layer, `reduce` and `setup` collapsed across
    /// the tag's layer field (ring steps / source ranks are not layers).
    /// The values sum to the total the epoch rows report as
    /// `comm_wait_ms`.
    pub fn entries_ms(&self) -> Vec<(String, f64)> {
        let mut out: Vec<(String, f64)> = Vec::new();
        for (&(phase, layer), &secs) in &self.by {
            let key = match phase {
                Phase::FwdFeat => format!("fwd_l{layer}"),
                Phase::BwdGrad => format!("bwd_l{layer}"),
                Phase::Reduce => "reduce".to_string(),
                Phase::Setup => "setup".to_string(),
                Phase::Loss => "loss".to_string(),
            };
            match out.iter_mut().find(|(k, _)| *k == key) {
                Some(e) => e.1 += secs * 1e3,
                None => out.push((key, secs * 1e3)),
            }
        }
        out
    }

    pub fn merge(&mut self, other: &WaitStats) {
        for (&k, &v) in &other.by {
            *self.by.entry(k).or_insert(0.0) += v;
        }
        self.hidden += other.hidden;
        self.exposed += other.exposed;
    }
}

/// The message-passing contract the training schedule runs over: tagged
/// f32 payloads between ranks, FIFO per (src, dst, tag), nonblocking
/// sends, posted (handle-completed) receives, and per-rank payload-byte
/// accounting.
///
/// A shared implementation ([`Fabric`]) serves every rank of an
/// in-process run; a per-process implementation
/// ([`crate::net::TcpTransport`]) serves exactly one rank and may panic
/// if asked to send as (or receive for) a rank it does not own.
pub trait Transport: Send + Sync {
    fn n_ranks(&self) -> usize;

    /// Send `payload` from `src` to `dst` under `tag`. Never blocks on
    /// the consumer (queued in-process, or handed to a writer thread).
    fn send(&self, src: usize, dst: usize, tag: Tag, payload: Vec<f32>);

    /// Post a receive for the oldest (src → dst, tag) message and return
    /// immediately; the transport completes the handle in the background
    /// (a send into the fabric, or a frame off the reader thread) while
    /// the caller computes. Reservations for one (src, dst, tag) are
    /// served in post order.
    fn post_recv(&self, src: usize, dst: usize, tag: Tag) -> RecvHandle;

    /// Blocking receive of the oldest (src → dst, tag) message — a shim
    /// over [`Transport::post_recv`] + [`RecvHandle::wait_untracked`],
    /// kept so control paths (and downstream code migrating to handles)
    /// stay one call. Park time is not attributed anywhere; schedule hot
    /// paths should post early and [`RecvHandle::wait`] instead.
    fn recv_blocking(&self, src: usize, dst: usize, tag: Tag) -> Vec<f32> {
        self.post_recv(src, dst, tag).wait_untracked()
    }

    /// Total payload bytes rank `src` has sent so far (4 bytes per f32;
    /// framing overhead excluded so volumes are comparable across
    /// transports).
    fn bytes_sent(&self, src: usize) -> u64;
}

impl Transport for Fabric {
    fn n_ranks(&self) -> usize {
        Fabric::n_ranks(self)
    }

    fn send(&self, src: usize, dst: usize, tag: Tag, payload: Vec<f32>) {
        Fabric::send(self, src, dst, tag, payload)
    }

    fn post_recv(&self, src: usize, dst: usize, tag: Tag) -> RecvHandle {
        Fabric::post_recv(self, src, dst, tag)
    }

    fn bytes_sent(&self, src: usize) -> u64 {
        let g = self.shared.inner.lock().unwrap();
        g.bytes[src].iter().sum()
    }
}

/// Pack `u32` values (node ids, control words) into the f32 payload
/// channel bit-for-bit. No float arithmetic ever touches payloads in
/// transit (both transports move raw bit patterns), so this is lossless
/// even for patterns that alias NaNs.
pub fn encode_u32s(vals: &[u32]) -> Vec<f32> {
    vals.iter().map(|&v| f32::from_bits(v)).collect()
}

pub fn decode_u32s(payload: &[f32]) -> Vec<u32> {
    payload.iter().map(|v| v.to_bits()).collect()
}

/// Pack `f64` values (loss curves) into the f32 payload channel as two
/// bit-halves each — lossless, so cross-process loss aggregation stays
/// bit-identical to the in-process engines.
pub fn encode_f64s(vals: &[f64]) -> Vec<f32> {
    let mut out = Vec::with_capacity(vals.len() * 2);
    for &v in vals {
        let bits = v.to_bits();
        out.push(f32::from_bits((bits >> 32) as u32));
        out.push(f32::from_bits(bits as u32));
    }
    out
}

pub fn decode_f64s(payload: &[f32]) -> Vec<f64> {
    assert_eq!(payload.len() % 2, 0, "f64 payload must have even length");
    payload
        .chunks_exact(2)
        .map(|c| f64::from_bits(((c[0].to_bits() as u64) << 32) | c[1].to_bits() as u64))
        .collect()
}

#[derive(Default)]
struct FabricInner {
    /// queues[(src, dst)][tag] — sequence-stamped FIFO per (pair, tag)
    queues: HashMap<(u32, u32), HashMap<Tag, VecDeque<Queued>>>,
    /// posted-but-unfulfilled receives, FIFO per (pair, tag) — a send
    /// fulfills the oldest live reservation before touching the queue
    reservations: HashMap<(u32, u32), HashMap<Tag, VecDeque<SlotRef>>>,
    /// delivery sequence counter (stamps every sent message)
    seq: u64,
    /// bytes[src][dst]
    bytes: Vec<Vec<u64>>,
    /// messages[src][dst]
    msgs: Vec<Vec<u64>>,
}

/// The lock + condvar the fabric and its outstanding receive handles
/// share (handles outlive any borrow of the [`Fabric`] itself).
struct FabricShared {
    inner: Mutex<FabricInner>,
    cv: Condvar,
}

/// In-process fabric between `n` ranks. Thread-safe; posted receives
/// park on a condvar, so a threaded runner genuinely overlaps.
pub struct Fabric {
    n: usize,
    shared: Arc<FabricShared>,
}

/// [`RecvFuture`] over the in-process fabric.
struct FabricRecv {
    shared: Arc<FabricShared>,
    key: (u32, u32),
    tag: Tag,
    slot: SlotRef,
}

impl RecvFuture for FabricRecv {
    fn try_take(&mut self) -> Option<Vec<f32>> {
        take_ready(&self.slot)
    }

    fn wait_take(&mut self) -> Vec<f32> {
        let mut g = self.shared.inner.lock().unwrap();
        loop {
            if let Some(v) = take_ready(&self.slot) {
                return v;
            }
            g = self.shared.cv.wait(g).unwrap();
        }
    }
}

impl Drop for FabricRecv {
    fn drop(&mut self) {
        // lock order: fabric inner first, then the slot (same as send)
        let mut g = self.shared.inner.lock().unwrap();
        let mut slot = self.slot.lock().unwrap();
        match std::mem::replace(&mut *slot, SlotState::Cancelled) {
            SlotState::Pending => {
                // withdraw the reservation so no send fulfills a ghost
                if let Some(m) = g.reservations.get_mut(&self.key) {
                    if let Some(q) = m.get_mut(&self.tag) {
                        q.retain(|s| !Arc::ptr_eq(s, &self.slot));
                        if q.is_empty() {
                            m.remove(&self.tag);
                        }
                    }
                }
            }
            SlotState::Ready(seq, p) => {
                // fulfilled but never taken: hand the message to the
                // oldest still-pending sibling reservation (which would
                // otherwise park forever — sends only fulfill once), or
                // reinsert it at its sequence position in the FIFO
                let mut item = Some((seq, p));
                if let Some(m) = g.reservations.get_mut(&self.key) {
                    if let Some(q) = m.get_mut(&self.tag) {
                        let (s, p) = item.take().unwrap();
                        item = offer(q, s, p);
                        if q.is_empty() {
                            m.remove(&self.tag);
                        }
                    }
                }
                if let Some((s, p)) = item {
                    let q = g.queues.entry(self.key).or_default().entry(self.tag).or_default();
                    requeue_in_order(q, s, p);
                }
                self.shared.cv.notify_all();
            }
            SlotState::Taken => *slot = SlotState::Taken,
            SlotState::Cancelled => {}
        }
    }
}

impl Fabric {
    pub fn new(n: usize) -> Fabric {
        Fabric {
            n,
            shared: Arc::new(FabricShared {
                inner: Mutex::new(FabricInner {
                    queues: HashMap::new(),
                    reservations: HashMap::new(),
                    seq: 0,
                    bytes: vec![vec![0; n]; n],
                    msgs: vec![vec![0; n]; n],
                }),
                cv: Condvar::new(),
            }),
        }
    }

    pub fn n_ranks(&self) -> usize {
        self.n
    }

    /// Send `payload` from `src` to `dst` under `tag`: fulfill the
    /// oldest live reservation, or queue for a later receive.
    pub fn send(&self, src: usize, dst: usize, tag: Tag, payload: Vec<f32>) {
        assert!(src < self.n && dst < self.n);
        schedule::observe(schedule::OpKind::Send, src, dst, tag);
        let key = (src as u32, dst as u32);
        let mut g = self.shared.inner.lock().unwrap();
        g.bytes[src][dst] += (payload.len() * 4) as u64;
        g.msgs[src][dst] += 1;
        g.seq += 1;
        let seq = g.seq;
        let mut item = Some((seq, payload));
        if let Some(m) = g.reservations.get_mut(&key) {
            if let Some(q) = m.get_mut(&tag) {
                let (s, p) = item.take().unwrap();
                item = offer(q, s, p);
                // tags are epoch-unique: emptied per-tag entries must
                // go, or long runs leak one dead entry per receive
                if q.is_empty() {
                    m.remove(&tag);
                }
            }
        }
        if let Some((s, p)) = item {
            g.queues.entry(key).or_default().entry(tag).or_default().push_back((s, p));
        }
        self.shared.cv.notify_all();
    }

    /// Pop the oldest queued (key, tag) message, pruning emptied per-tag
    /// entries (tags are epoch-unique, so dead entries never get reused).
    fn pop_queued(g: &mut FabricInner, key: (u32, u32), tag: Tag) -> Option<Queued> {
        let m = g.queues.get_mut(&key)?;
        let q = m.get_mut(&tag)?;
        let p = q.pop_front();
        if q.is_empty() {
            m.remove(&tag);
        }
        p
    }

    /// Post a receive for the oldest (src → dst, tag) message; completes
    /// immediately when one is already queued, otherwise the next
    /// matching send fulfills it.
    pub fn post_recv(&self, src: usize, dst: usize, tag: Tag) -> RecvHandle {
        assert!(src < self.n && dst < self.n);
        let key = (src as u32, dst as u32);
        let slot = new_slot();
        {
            let mut g = self.shared.inner.lock().unwrap();
            match Fabric::pop_queued(&mut g, key, tag) {
                Some((s, p)) => {
                    let leftover = fulfill(&slot, s, p);
                    debug_assert!(leftover.is_none());
                }
                None => {
                    g.reservations
                        .entry(key)
                        .or_default()
                        .entry(tag)
                        .or_default()
                        .push_back(slot.clone());
                }
            }
        }
        RecvHandle::new(
            src,
            dst,
            tag,
            Box::new(FabricRecv { shared: self.shared.clone(), key, tag, slot }),
        )
    }

    /// Non-blocking receive of the oldest queued message (src→dst, tag).
    /// Bypasses posted reservations (tests / diagnostics).
    pub fn try_recv(&self, src: usize, dst: usize, tag: Tag) -> Option<Vec<f32>> {
        let mut g = self.shared.inner.lock().unwrap();
        Fabric::pop_queued(&mut g, (src as u32, dst as u32), tag).map(|(_, p)| p)
    }

    /// Blocking receive (control paths) — the handle API end to end.
    pub fn recv_blocking(&self, src: usize, dst: usize, tag: Tag) -> Vec<f32> {
        self.post_recv(src, dst, tag).wait_untracked()
    }

    /// Receive that must succeed immediately (sequential trainer, where
    /// the producer already ran). Routed through the handle API so the
    /// failure diagnostic always names the exact (src, dst, tag).
    pub fn recv_now(&self, src: usize, dst: usize, tag: Tag) -> Vec<f32> {
        self.post_recv(src, dst, tag).take_now()
    }

    /// Total bytes sent src→dst so far.
    pub fn bytes(&self, src: usize, dst: usize) -> u64 {
        self.shared.inner.lock().unwrap().bytes[src][dst]
    }

    /// Full byte matrix snapshot.
    pub fn byte_matrix(&self) -> Vec<Vec<u64>> {
        self.shared.inner.lock().unwrap().bytes.clone()
    }

    pub fn total_bytes(&self) -> u64 {
        self.shared.inner.lock().unwrap().bytes.iter().flatten().sum()
    }

    pub fn total_msgs(&self) -> u64 {
        self.shared.inner.lock().unwrap().msgs.iter().flatten().sum()
    }

    /// Reset byte/message counters (keep queued messages).
    pub fn reset_counters(&self) {
        let mut g = self.shared.inner.lock().unwrap();
        for row in g.bytes.iter_mut() {
            row.iter_mut().for_each(|b| *b = 0);
        }
        for row in g.msgs.iter_mut() {
            row.iter_mut().for_each(|b| *b = 0);
        }
    }

    /// Number of messages still queued (tests: catch leaks / wrong
    /// tags). Messages already delivered to a live posted handle are not
    /// queued — they are accounted by that handle.
    pub fn pending(&self) -> usize {
        let g = self.shared.inner.lock().unwrap();
        g.queues.values().flat_map(|m| m.values()).map(|q| q.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn send_recv_fifo_per_tag() {
        let f = Fabric::new(2);
        let t = Tag::new(1, 0, Phase::FwdFeat);
        f.send(0, 1, t, vec![1.0]);
        f.send(0, 1, t, vec![2.0]);
        assert_eq!(f.try_recv(0, 1, t), Some(vec![1.0]));
        assert_eq!(f.try_recv(0, 1, t), Some(vec![2.0]));
        assert_eq!(f.try_recv(0, 1, t), None);
    }

    #[test]
    fn tags_isolate_messages() {
        let f = Fabric::new(2);
        let t1 = Tag::new(1, 0, Phase::FwdFeat);
        let t2 = Tag::new(1, 0, Phase::BwdGrad);
        let t3 = Tag::new(2, 0, Phase::FwdFeat);
        f.send(0, 1, t1, vec![1.0]);
        f.send(0, 1, t2, vec![2.0]);
        f.send(0, 1, t3, vec![3.0]);
        assert_eq!(f.try_recv(0, 1, t3), Some(vec![3.0]));
        assert_eq!(f.try_recv(0, 1, t1), Some(vec![1.0]));
        assert_eq!(f.try_recv(0, 1, t2), Some(vec![2.0]));
    }

    #[test]
    fn byte_accounting() {
        let f = Fabric::new(3);
        let t = Tag::new(0, 0, Phase::Setup);
        f.send(0, 2, t, vec![0.0; 10]);
        f.send(2, 0, t, vec![0.0; 5]);
        assert_eq!(f.bytes(0, 2), 40);
        assert_eq!(f.bytes(2, 0), 20);
        assert_eq!(f.total_bytes(), 60);
        assert_eq!(f.total_msgs(), 2);
        f.reset_counters();
        assert_eq!(f.total_bytes(), 0);
        // queued messages survive the counter reset
        assert_eq!(f.pending(), 2);
    }

    #[test]
    fn blocking_recv_across_threads() {
        let f = Arc::new(Fabric::new(2));
        let t = Tag::new(5, 1, Phase::FwdFeat);
        let f2 = f.clone();
        let h = std::thread::spawn(move || f2.recv_blocking(0, 1, t));
        std::thread::sleep(std::time::Duration::from_millis(20));
        f.send(0, 1, t, vec![7.0]);
        assert_eq!(h.join().unwrap(), vec![7.0]);
    }

    #[test]
    fn posted_recv_completes_on_send() {
        let f = Fabric::new(2);
        let t = Tag::new(3, 1, Phase::FwdFeat);
        let mut h = f.post_recv(0, 1, t);
        assert_eq!(h.try_take(), None, "nothing sent yet");
        f.send(0, 1, t, vec![4.0, 5.0]);
        // fulfilled directly by the send — never entered the queue
        assert_eq!(f.pending(), 0);
        assert_eq!(h.try_take(), Some(vec![4.0, 5.0]));
    }

    #[test]
    fn posted_recv_wait_parks_until_send() {
        let f = Arc::new(Fabric::new(2));
        let t = Tag::new(9, 0, Phase::BwdGrad);
        let h = f.post_recv(0, 1, t);
        let waiter = std::thread::spawn(move || {
            let mut stats = WaitStats::default();
            let v = h.wait(&mut stats);
            (v, stats)
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        f.send(0, 1, t, vec![6.0]);
        let (v, stats) = waiter.join().unwrap();
        assert_eq!(v, vec![6.0]);
        // exactly one receive was accounted; whether it parked or the
        // send won the race is scheduler timing, not a contract
        assert_eq!(stats.hidden() + stats.exposed(), 1);
        assert!(stats.total_secs() >= 0.0);
    }

    #[test]
    fn reservations_serve_in_post_order() {
        let f = Fabric::new(2);
        let t = Tag::new(1, 2, Phase::FwdFeat);
        let mut h1 = f.post_recv(0, 1, t);
        let mut h2 = f.post_recv(0, 1, t);
        f.send(0, 1, t, vec![1.0]);
        f.send(0, 1, t, vec![2.0]);
        assert_eq!(h2.try_take(), Some(vec![2.0]));
        assert_eq!(h1.try_take(), Some(vec![1.0]));
    }

    #[test]
    fn dropped_pending_handle_leaks_nothing() {
        let f = Fabric::new(2);
        let t = Tag::new(4, 0, Phase::FwdFeat);
        drop(f.post_recv(0, 1, t));
        f.send(0, 1, t, vec![8.0]);
        // the cancelled reservation did not swallow the message
        assert_eq!(f.pending(), 1);
        assert_eq!(f.recv_blocking(0, 1, t), vec![8.0]);
    }

    #[test]
    fn dropped_fulfilled_handle_requeues_payload() {
        let f = Fabric::new(2);
        let t = Tag::new(4, 1, Phase::BwdGrad);
        f.send(0, 1, t, vec![1.5]);
        f.send(0, 1, t, vec![2.5]);
        let h = f.post_recv(0, 1, t); // claims 1.5
        drop(h); // never taken: 1.5 goes back to the head
        assert_eq!(f.recv_blocking(0, 1, t), vec![1.5]);
        assert_eq!(f.recv_blocking(0, 1, t), vec![2.5]);
    }

    #[test]
    fn dropped_fulfilled_handles_restore_send_order() {
        let f = Fabric::new(2);
        let t = Tag::new(5, 0, Phase::FwdFeat);
        f.send(0, 1, t, vec![1.0]);
        f.send(0, 1, t, vec![2.0]);
        let h1 = f.post_recv(0, 1, t); // claims 1.0
        let h2 = f.post_recv(0, 1, t); // claims 2.0
        // drop in fulfillment order — naive head-reinsertion would
        // reverse the FIFO here; sequence stamps must restore it
        drop(h1);
        drop(h2);
        assert_eq!(f.recv_blocking(0, 1, t), vec![1.0]);
        assert_eq!(f.recv_blocking(0, 1, t), vec![2.0]);
        assert_eq!(f.pending(), 0);
    }

    #[test]
    fn dropped_fulfilled_handle_refulfills_pending_sibling() {
        let f = Fabric::new(2);
        let t = Tag::new(4, 2, Phase::BwdGrad);
        f.send(0, 1, t, vec![9.5]);
        let h_old = f.post_recv(0, 1, t); // claims 9.5
        let mut h_next = f.post_recv(0, 1, t); // still pending
        drop(h_old); // must re-fulfill the sibling, not strand it
        assert_eq!(h_next.try_take(), Some(vec![9.5]));
        assert_eq!(f.pending(), 0);
    }

    #[test]
    fn wait_stats_attribute_per_layer_and_phase() {
        let mut s = WaitStats::default();
        s.charge(Tag::new(1, 0, Phase::FwdFeat), 0.25);
        s.charge(Tag::new(1, 0, Phase::FwdFeat), 0.25);
        s.charge(Tag::new(1, 1, Phase::BwdGrad), 0.5);
        s.charge(Tag::new(1, 3, Phase::Reduce), 0.125);
        s.charge(Tag::new(1, 7, Phase::Reduce), 0.125);
        s.hit(Tag::new(1, 1, Phase::FwdFeat));
        assert_eq!(s.hidden(), 1);
        assert_eq!(s.exposed(), 5);
        assert!((s.total_secs() - 1.25).abs() < 1e-12);
        assert!((s.overlap_ratio() - 1.0 / 6.0).abs() < 1e-12);
        let entries = s.entries_ms();
        let get = |k: &str| entries.iter().find(|(e, _)| e == k).map(|(_, v)| *v);
        assert_eq!(get("fwd_l0"), Some(500.0));
        assert_eq!(get("fwd_l1"), Some(0.0)); // hidden receives keep keys
        assert_eq!(get("bwd_l1"), Some(500.0));
        // ring steps collapse into one key regardless of tag layer
        assert_eq!(get("reduce"), Some(250.0));
        let sum: f64 = entries.iter().map(|(_, v)| v).sum();
        assert!((sum - s.total_secs() * 1e3).abs() < 1e-9);
        // empty stats: nothing waited means nothing exposed
        assert_eq!(WaitStats::default().overlap_ratio(), 1.0);
    }

    #[test]
    #[should_panic(expected = "no message")]
    fn recv_now_panics_when_empty() {
        let f = Fabric::new(2);
        f.recv_now(0, 1, Tag::new(0, 0, Phase::FwdFeat));
    }

    #[test]
    fn recv_now_diagnostic_names_src_dst_tag() {
        let f = Fabric::new(3);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f.recv_now(2, 1, Tag::new(7, 3, Phase::BwdGrad))
        }))
        .unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(msg.contains("2->1"), "missing src/dst: {msg}");
        assert!(msg.contains("BwdGrad"), "missing phase: {msg}");
        assert!(msg.contains("7"), "missing iter: {msg}");
    }

    #[test]
    fn u32_payload_roundtrip_including_nan_patterns() {
        let vals = vec![0, 1, 0x7FC0_0001, u32::MAX, 0x8000_0000];
        assert_eq!(decode_u32s(&encode_u32s(&vals)), vals);
    }

    #[test]
    fn f64_payload_roundtrip_is_bit_exact() {
        let vals = vec![0.0, -0.0, 1.0 / 3.0, f64::MIN_POSITIVE, 6.02214076e23, -1.5e-300];
        let back = decode_f64s(&encode_f64s(&vals));
        for (a, b) in vals.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn phase_codes_roundtrip() {
        for p in [Phase::FwdFeat, Phase::BwdGrad, Phase::Reduce, Phase::Setup, Phase::Loss] {
            assert_eq!(Phase::from_code(p.code()), Some(p));
        }
        assert_eq!(Phase::from_code(9), None);
    }

    #[test]
    fn fabric_implements_transport() {
        let f = Fabric::new(2);
        let t: &dyn Transport = &f;
        let tag = Tag::new(3, 1, Phase::FwdFeat);
        t.send(0, 1, tag, vec![1.0, 2.0]);
        assert_eq!(t.recv_blocking(0, 1, tag), vec![1.0, 2.0]);
        assert_eq!(t.bytes_sent(0), 8);
        assert_eq!(t.bytes_sent(1), 0);
        assert_eq!(t.n_ranks(), 2);
        // the handle path through the trait object
        t.send(0, 1, tag, vec![3.0]);
        let mut h = t.post_recv(0, 1, tag);
        assert_eq!(h.try_take(), Some(vec![3.0]));
    }
}
